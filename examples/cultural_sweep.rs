//! Fig. 2 (scaled): cultural dynamics — simulation time T versus the
//! task-size proxy F (number of cultural features) for n ∈ {1..5} workers
//! on the virtual-core testbed.
//!
//! ```bash
//! cargo run --release --example cultural_sweep
//! ```
//!
//! For the paper's full workload use the CLI instead:
//! `adapar sweep --preset fig2 --paper-scale`.

use adapar::coordinator::config::{EngineKind, SweepConfig};
use adapar::coordinator::report::figure_pivot;
use adapar::coordinator::run_sweep;

fn main() -> adapar::Result<()> {
    let cfg = SweepConfig {
        model: "axelrod".to_string(),
        engine: EngineKind::Virtual,
        sizes: vec![25, 50, 100, 200, 400],
        workers: vec![1, 2, 3, 4, 5],
        seeds: vec![1, 2, 3],
        agents: 1_000,
        steps: 20_000,
        calibrate: true,
        ..Default::default()
    };
    eprintln!("running {} grid points...", cfg.sizes.len() * cfg.workers.len());
    let res = run_sweep(&cfg)?;
    println!("{}", figure_pivot(&res).to_markdown());

    // The paper's qualitative claims, checked on the spot:
    for &f in &cfg.sizes {
        let s4 = res.speedup(f, 4).unwrap();
        eprintln!("F={f:>4}: T(1)/T(4) = {s4:.2}x");
    }
    let small = res.speedup(25, 4).unwrap();
    let large = res.speedup(400, 4).unwrap();
    eprintln!(
        "speedup grows with task size: {small:.2}x (F=25) -> {large:.2}x (F=400): {}",
        if large > small { "confirmed" } else { "NOT confirmed" }
    );
    Ok(())
}
