//! Plugging *your own* MABS into the protocol: implement the recipe /
//! record / source interface (paper §3.5) for a model the library does not
//! ship — here, a colony of foraging ants on a shared pheromone grid —
//! then **register it** so the `Simulation` facade (and therefore the CLI
//! and sweep configs) can run it by name, exactly like a bundled model.
//!
//! ```bash
//! cargo run --release --example custom_model
//! ```
//!
//! Each task moves one ant: it reads the pheromone level of its cell and
//! of a candidate cell, moves (probabilistically uphill), and deposits
//! pheromone. The footprint is {ant, two grid cells}; the record tracks
//! touched cells and moved ants conservatively.

use adapar::api::registry;
use adapar::model::{Model, Record, TaskSource};
use adapar::protocol::{ParallelEngine, ProtocolConfig, SequentialEngine};
use adapar::sim::rng::{Rng, TaskRng};
use adapar::sim::state::SharedSim;
use adapar::util::u32set::U32Set;
use adapar::{EngineKind, ModelInfo, ObsValue, Runnable, Simulation};

const GRID: usize = 64; // 64×64 torus

struct AntWorld {
    /// Pheromone per cell (fixed-point, to keep updates exact).
    pheromone: SharedSim<Vec<u64>>,
    /// Cell of each ant.
    position: SharedSim<Vec<u32>>,
    steps: u64,
    ants: usize,
}

#[derive(Clone, Copy, Debug)]
struct AntMove {
    ant: u32,
    /// Candidate destination (picked at creation — the "task depth" split:
    /// selection at creation, evaluation at execution).
    candidate: u32,
}

struct AntRecord {
    ants: U32Set,
    cells: U32Set,
}

impl Record for AntRecord {
    type Recipe = AntMove;
    fn depends(&self, r: &AntMove) -> bool {
        // A task's footprint is exactly {its ant} ∪ {its candidate cell}
        // (execution never touches the ant's current cell — see
        // `execute`), so claiming the ant id and the candidate cell is a
        // *precise* record, not just a conservative one.
        self.ants.contains(r.ant) || self.cells.contains(r.candidate)
    }
    fn absorb(&mut self, r: &AntMove) {
        self.ants.insert(r.ant);
        self.cells.insert(r.candidate);
    }
    fn reset(&mut self) {
        self.ants.clear();
        self.cells.clear();
    }
}

struct AntSource {
    rng: Rng,
    remaining: u64,
    ants: usize,
}

impl TaskSource for AntSource {
    type Recipe = AntMove;
    fn next_task(&mut self) -> Option<AntMove> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        Some(AntMove {
            ant: self.rng.index(self.ants) as u32,
            candidate: self.rng.index(GRID * GRID) as u32,
        })
    }
}

impl Model for AntWorld {
    type Recipe = AntMove;
    type Record = AntRecord;
    type Source = AntSource;

    fn source(&self, seed: u64) -> AntSource {
        AntSource {
            rng: Rng::stream(seed, 0xA27),
            remaining: self.steps,
            ants: self.ants,
        }
    }

    fn record(&self) -> AntRecord {
        AntRecord {
            ants: U32Set::new(),
            cells: U32Set::new(),
        }
    }

    fn execute(&self, r: &AntMove, rng: &mut TaskRng) {
        // Design note: execution must stay inside the record's claimed
        // footprint — {pos[ant], pher[candidate]}. In particular it must
        // NOT deposit at the ant's *current* cell: that cell is unknown to
        // the record, and another in-flight task could be inspecting it as
        // its candidate (a write-after-read race the determinism assert
        // below would catch).
        let u = rng.unit_f64();
        // SAFETY: record discipline as argued above.
        unsafe {
            let pher = self.pheromone.get_mut();
            let pos = self.position.get_mut();
            let there = r.candidate as usize;
            // Inspect the candidate; the stronger its trail, the likelier
            // the ant relocates there and reinforces it.
            let attract = (pher[there] + 1) as f64 / (pher[there] + 3) as f64;
            if u < attract {
                pos[r.ant as usize] = r.candidate;
                pher[there] += 2; // trail reinforcement
            } else {
                pher[there] += 1; // scent marking while scouting
            }
        }
    }
}

fn total_pheromone(w: &AntWorld) -> u64 {
    unsafe { w.pheromone.get() }.iter().sum()
}

fn build(seed: u64, ants: usize, steps: u64) -> AntWorld {
    let mut rng = Rng::stream(seed, 1);
    AntWorld {
        pheromone: SharedSim::new(vec![0; GRID * GRID]),
        position: SharedSim::new((0..ants).map(|_| rng.index(GRID * GRID) as u32).collect()),
        steps,
        ants,
    }
}

/// Make `ants` a first-class registry citizen: after this call the model
/// is runnable from the facade, the CLI (`adapar run --model ants`) and
/// sweep configs — with zero changes to any launcher code.
fn register_ants() -> adapar::Result<()> {
    let info = ModelInfo::new("ants", "foraging ants on a shared pheromone grid (plug-in demo)")
        .agents(500, 500)
        .steps(50_000, 50_000);
    registry::register(info, |ctx| {
        let model = build(ctx.seed, ctx.agents, ctx.steps);
        Ok(Runnable::new("ants", model)
            .observed(|w| {
                vec![(
                    "total_pheromone".to_string(),
                    ObsValue::Int(total_pheromone(w) as i64),
                )]
            })
            .boxed())
    })
}

fn main() -> adapar::Result<()> {
    let seed = 7;

    // --- Raw engine API: the interface the registry factory wraps -------
    let reference = build(seed, 500, 50_000);
    SequentialEngine::new(seed).run(&reference);

    let world = build(seed, 500, 50_000);
    let report = ParallelEngine::new(ProtocolConfig {
        workers: 4,
        tasks_per_cycle: 6,
        seed,
        ..Default::default()
    })
    .run(&world);

    println!("parallel: {}", report.summary());
    assert_eq!(
        unsafe { reference.pheromone.get() }.clone(),
        unsafe { world.pheromone.get() }.clone(),
        "custom model must stay deterministic under the protocol"
    );
    assert_eq!(
        unsafe { reference.position.get() }.clone(),
        unsafe { world.position.get() }.clone()
    );
    println!(
        "OK: 500 ants, 50k moves, total pheromone = {}, states bit-identical",
        total_pheromone(&world)
    );

    // --- Registry + facade: the same model as a named plug-in -----------
    register_ants()?;
    let run = |engine| {
        Simulation::builder()
            .model("ants")
            .engine(engine)
            .workers(4)
            .seed(seed)
            .run()
    };
    let seq = run(EngineKind::Sequential)?;
    let par = run(EngineKind::Parallel)?;
    println!("facade sequential: {}", seq.observable);
    println!("facade parallel:   {}", par.observable);
    assert_eq!(
        seq.observable, par.observable,
        "registered model must stay deterministic through the facade"
    );
    println!("OK: `ants` runs by name through the Simulation facade");
    Ok(())
}
