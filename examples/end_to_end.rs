//! End-to-end driver: exercises every layer of the system on a real small
//! workload and reports the paper's headline results. This is the run
//! recorded in EXPERIMENTS.md §End-to-end.
//!
//! Pipeline:
//!   1. calibrate the virtual testbed's cost model on this machine;
//!   2. cross-engine validation (sequential / parallel / virtual /
//!      stepwise agree bit-for-bit) for both paper models;
//!   3. regenerate Fig. 2 and Fig. 3 series on the virtual testbed
//!      (scaled workloads; CSV + markdown under target/figures/);
//!   4. if AOT artifacts are present, validate the XLA task path;
//!   5. print the headline metrics: speedup growth with task size,
//!      saturation worker count, fine-granularity overhead wall.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```

use std::path::Path;

use adapar::coordinator::config::{EngineKind, SweepConfig};
use adapar::coordinator::report::{figure_pivot, write_report};
use adapar::coordinator::run_sweep;
use adapar::models::sir::{SirModel, SirParams};
use adapar::protocol::{ParallelEngine, ProtocolConfig, SequentialEngine, StepwiseEngine};
use adapar::vtime::calibrate;

fn main() -> adapar::Result<()> {
    println!("== 1. cost-model calibration ==");
    let cost = calibrate();
    println!(
        "measured: visit={:.0}ns create={:.0}ns erase={:.0}ns absorb={:.0}ns exec_fixed={:.0}ns",
        cost.visit_ns, cost.create_ns, cost.erase_ns, cost.absorb_ns, cost.exec_fixed_ns
    );

    println!("\n== 2. cross-engine validation ==");
    {
        let params = SirParams::scaled(25, 500, 60);
        let seed = 9;
        let reference = {
            let m = SirModel::new(params, 1);
            SequentialEngine::new(seed).run(&m);
            m.snapshot()
        };
        for n in [1, 2, 4] {
            let m = SirModel::new(params, 1);
            ParallelEngine::new(ProtocolConfig {
                workers: n,
                tasks_per_cycle: 6,
                seed,
                ..Default::default()
            })
            .run(&m);
            assert_eq!(m.snapshot(), reference);
            println!("  SIR parallel n={n}: bit-identical to sequential ✓");
        }
        let m = SirModel::new(params, 1);
        StepwiseEngine::new(3, seed).run(&m);
        assert_eq!(m.snapshot(), reference);
        println!("  SIR stepwise baseline: bit-identical ✓");
    }

    println!("\n== 3a. Fig. 2 series (cultural dynamics, virtual testbed) ==");
    let fig2 = run_sweep(&SweepConfig {
        model: "axelrod".to_string(),
        engine: EngineKind::Virtual,
        sizes: vec![25, 50, 100, 200, 400],
        workers: vec![1, 2, 3, 4, 5],
        seeds: vec![1, 2, 3],
        agents: 1_000,
        steps: 20_000,
        calibrate: true,
        ..Default::default()
    })?;
    println!("{}", figure_pivot(&fig2).to_markdown());
    write_report(&fig2, Path::new("target/figures"), "e2e_fig2")?;

    println!("== 3b. Fig. 3 series (disease spreading, virtual testbed) ==");
    let fig3 = run_sweep(&SweepConfig {
        model: "sir".to_string(),
        engine: EngineKind::Virtual,
        sizes: vec![10, 20, 50, 100, 200, 500],
        workers: vec![1, 2, 3, 4, 5],
        seeds: vec![1, 2, 3],
        agents: 4_000,
        steps: 100,
        calibrate: true,
        ..Default::default()
    })?;
    println!("{}", figure_pivot(&fig3).to_markdown());
    write_report(&fig3, Path::new("target/figures"), "e2e_fig3")?;

    println!("== 4. XLA artifact path ==");
    #[cfg(feature = "xla")]
    match adapar::runtime::Manifest::load(adapar::runtime::Manifest::default_dir()) {
        Err(_) => println!("  artifacts not built — skipped (run `make artifacts`)"),
        Ok(manifest) => {
            let rt = adapar::runtime::XlaRuntime::cpu()?;
            let params = SirParams::scaled(30, 300, 20);
            let seed = 4;
            let native = SirModel::new(params, 2);
            SequentialEngine::new(seed).run(&native);
            let xla = adapar::runtime::xla_engine::XlaSirModel::from_manifest(
                &rt,
                &manifest,
                SirModel::new(params, 2),
            )?;
            SequentialEngine::new(seed).run(&xla);
            assert_eq!(native.snapshot(), xla.snapshot());
            println!("  SIR with JAX+Pallas task bodies via PJRT: bit-identical ✓");
        }
    }
    #[cfg(not(feature = "xla"))]
    println!("  built without the `xla` feature — skipped");

    println!("\n== 5. headline metrics ==");
    let s_small = fig2.speedup(25, 4).unwrap();
    let s_large = fig2.speedup(400, 4).unwrap();
    println!("  Fig2: T(1)/T(4) grows with F: {s_small:.2}x @F=25 -> {s_large:.2}x @F=400");
    let s4 = fig2.speedup(400, 4).unwrap();
    let s5 = fig2.speedup(400, 5).unwrap();
    println!(
        "  Fig2: saturation: n=5 adds {:+.1}% over n=4 at F=400",
        (s5 / s4 - 1.0) * 100.0
    );
    let wall = fig3.point(10, 3).unwrap().mean_s / fig3.point(200, 3).unwrap().mean_s;
    println!("  Fig3: fine-granularity wall: s=10 is {wall:.1}x slower than s=200 at n=3");
    let p4 = fig3.speedup(200, 4).unwrap();
    println!("  Fig3: plateau speedup T(1)/T(4) @s=200: {p4:.2}x");
    println!("\nend-to-end driver completed; figure data in target/figures/e2e_fig*.csv");
    Ok(())
}
