//! Fig. 3 (scaled): disease spreading — simulation time T versus the
//! task-size proxy s (agents per subset) for n ∈ {1..5} workers on the
//! virtual-core testbed.
//!
//! ```bash
//! cargo run --release --example epidemic_sweep
//! ```

use adapar::coordinator::config::{EngineKind, SweepConfig};
use adapar::coordinator::report::figure_pivot;
use adapar::coordinator::run_sweep;

fn main() -> adapar::Result<()> {
    let cfg = SweepConfig {
        model: "sir".to_string(),
        engine: EngineKind::Virtual,
        sizes: vec![10, 20, 50, 100, 200, 500],
        workers: vec![1, 2, 3, 4, 5],
        seeds: vec![1, 2, 3],
        agents: 4_000,
        steps: 100,
        calibrate: true,
        ..Default::default()
    };
    eprintln!("running {} grid points...", cfg.sizes.len() * cfg.workers.len());
    let res = run_sweep(&cfg)?;
    println!("{}", figure_pivot(&res).to_markdown());

    // Fig. 3's shape: fine granularity is overhead-dominated...
    let t_fine = res.point(10, 3).unwrap().mean_s;
    let t_plateau = res.point(200, 3).unwrap().mean_s;
    eprintln!(
        "s=10 is {:.1}x slower than s=200 at n=3 (overhead wall): {}",
        t_fine / t_plateau,
        if t_fine > t_plateau { "confirmed" } else { "NOT confirmed" }
    );
    // ...and in the plateau more workers help until saturation.
    let s4 = res.speedup(200, 4).unwrap();
    eprintln!("plateau speedup T(1)/T(4) at s=200: {s4:.2}x");
    Ok(())
}
