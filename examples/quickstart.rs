//! Quickstart: run a bundled MABS through the `Simulation` facade — the
//! single entry point the CLI, sweeps and benches use — then drop one
//! level down to the raw engines to see what the facade wires together.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use adapar::models::sir::{SirModel, SirParams};
use adapar::protocol::{ParallelEngine, ProtocolConfig, SequentialEngine};
use adapar::{EngineKind, ObservePlan, Simulation};

fn main() -> adapar::Result<()> {
    // ------------------------------------------------------------------
    // The facade: model by registry name, engine by kind, builder-style
    // workload overrides. Any registered model runs on any legal engine.
    // ------------------------------------------------------------------
    let seed = 42;
    let sequential = Simulation::builder()
        .model("sir")
        .engine(EngineKind::Sequential)
        .agents(1_000)
        .size(50) // subset size s — the task-size proxy
        .steps(200)
        .seed(seed)
        .run()?;
    let parallel = Simulation::builder()
        .model("sir")
        .engine(EngineKind::Parallel)
        .workers(4)
        .agents(1_000)
        .size(50)
        .steps(200)
        .seed(seed)
        .run()?;

    println!("sequential: {}", sequential.report.summary());
    println!("parallel:   {}", parallel.report.summary());
    println!("observable: {}", parallel.observable);

    // The protocol preserves the evolution of the system *exactly*.
    assert_eq!(
        sequential.observable, parallel.observable,
        "parallel must be bit-identical to sequential"
    );
    println!(
        "protocol overhead: {:.1}% of task visits were skips/passes/retries",
        parallel.report.overhead_ratio() * 100.0
    );

    // ------------------------------------------------------------------
    // Typed observation: snapshot the epidemic census every 200 tasks
    // (an *epoch*; the parallel engine drains to quiescence first, so
    // the trace below is byte-identical on every engine) and stream the
    // curve to a CSV.
    // ------------------------------------------------------------------
    let observed = Simulation::builder()
        .model("sir")
        .engine(EngineKind::Parallel)
        .workers(4)
        .agents(1_000)
        .size(50)
        .steps(200)
        .seed(seed)
        .observe(ObservePlan::every(200).csv("target/epidemic_curve.csv"))
        .run()?;
    println!(
        "epidemic curve: {} frames -> target/epidemic_curve.csv",
        observed.observable.len()
    );
    for (tasks, census) in observed.observable.series("census").iter().take(3) {
        println!("  after {tasks:>5} tasks: {census}");
    }
    assert_eq!(
        observed.observable.final_frame().map(|f| f.to_string()),
        Some(parallel.observable.to_string()),
        "the trace's final frame is the run's final state"
    );

    // ------------------------------------------------------------------
    // The same run against the raw engine API (what the facade builds):
    // recipe/record models plugged straight into an engine.
    // ------------------------------------------------------------------
    let params = SirParams {
        agents: 1_000,
        subset_size: 50,
        steps: 200,
        ..SirParams::default()
    };
    let reference = SirModel::new(params, seed ^ 0x51); // facade's init stream
    SequentialEngine::new(seed).run(&reference);
    let direct = SirModel::new(params, seed ^ 0x51);
    ParallelEngine::new(ProtocolConfig {
        workers: 4,
        tasks_per_cycle: 6, // the paper's C
        seed,
        ..Default::default()
    })
    .run(&direct);
    assert_eq!(reference.snapshot(), direct.snapshot());
    let (s, i, r) = direct.census();
    println!("raw-engine final census: S={s} I={i} R={r}");
    println!("OK: facade and raw engines agree; parallel state is bit-identical");
    Ok(())
}
