//! Quickstart: plug a bundled MABS into the adaptive protocol and run it.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use adapar::models::sir::{SirModel, SirParams};
use adapar::protocol::{ParallelEngine, ProtocolConfig, SequentialEngine};

fn main() {
    // The paper's Fig. 3 model at a small scale: 1 000 agents on a ring
    // lattice of degree 14, partitioned into subsets of 50 agents.
    let params = SirParams {
        agents: 1_000,
        subset_size: 50,
        steps: 200,
        ..SirParams::default()
    };
    let seed = 42;

    // Ground truth: canonical sequential execution.
    let sequential = SirModel::new(params, seed);
    let seq_report = SequentialEngine::new(seed).run(&sequential);

    // The paper's protocol: n workers iterate the task chain, executing
    // whatever their records prove independent.
    let parallel = SirModel::new(params, seed);
    let par_report = ParallelEngine::new(ProtocolConfig {
        workers: 4,
        tasks_per_cycle: 6, // the paper's C
        seed,
        collect_timing: false,
    })
    .run(&parallel);

    println!("sequential: {}", seq_report.summary());
    println!("parallel:   {}", par_report.summary());

    // The protocol preserves the evolution of the system *exactly*.
    assert_eq!(sequential.snapshot(), parallel.snapshot());
    let (s, i, r) = parallel.census();
    println!("final census: S={s} I={i} R={r}");
    println!(
        "protocol overhead: {:.1}% of task visits were skips/passes/retries",
        par_report.overhead_ratio() * 100.0
    );
    println!("OK: parallel state is bit-identical to sequential");
}
