//! The three-layer path end to end: L3 Rust protocol scheduling tasks
//! whose bodies run through the AOT-compiled JAX + Pallas artifacts via
//! PJRT — and a dispatch-cost comparison against native task bodies.
//!
//! ```bash
//! make artifacts   # once
//! cargo run --release --example xla_accelerated
//! ```

use std::time::Instant;

use adapar::models::sir::{SirModel, SirParams};
use adapar::protocol::SequentialEngine;
use adapar::runtime::xla_engine::{XlaAxelrodInteractor, XlaSirModel};
use adapar::runtime::{Manifest, XlaRuntime};

fn main() -> adapar::Result<()> {
    let dir = Manifest::default_dir();
    let manifest = Manifest::load(&dir).map_err(|e| {
        adapar::err!("{e:#}\nhint: run `make artifacts` first")
    })?;
    let rt = XlaRuntime::cpu()?;
    println!("PJRT platform={} devices={}", rt.platform(), rt.device_count());

    // --- SIR: whole simulation with XLA-backed compute tasks -------------
    let params = SirParams::scaled(30, 300, 25); // matches the exported artifact
    let seed = 11;

    let native = SirModel::new(params, 3);
    let t0 = Instant::now();
    SequentialEngine::new(seed).run(&native);
    let t_native = t0.elapsed();

    let xla = XlaSirModel::from_manifest(&rt, &manifest, SirModel::new(params, 3))?;
    let t0 = Instant::now();
    SequentialEngine::new(seed).run(&xla);
    let t_xla = t0.elapsed();

    assert_eq!(
        native.snapshot(),
        xla.snapshot(),
        "XLA task bodies must reproduce native results bit for bit"
    );
    println!(
        "SIR 300 agents × 25 steps: native {t_native:?}, via PJRT per-task dispatch {t_xla:?} \
         ({:.0}x dispatch overhead — the reason production batches tasks)",
        t_xla.as_secs_f64() / t_native.as_secs_f64().max(1e-9)
    );

    // --- Axelrod: one interaction through the Pallas kernel --------------
    let interactor = XlaAxelrodInteractor::from_manifest(&rt, &manifest)?;
    let f = interactor.features();
    let src = vec![2i32; f];
    let mut tgt = vec![2i32; f];
    tgt[3] = 0;
    tgt[17] = 1;
    let out = interactor.interact(&src, &tgt, 0.0, 0.7)?; // interacts, picks 2nd differing
    let changed: Vec<usize> = (0..f).filter(|&i| out[i] != tgt[i]).collect();
    println!("Axelrod kernel: differing features before = [3, 17], copied = {changed:?}");
    assert_eq!(changed, vec![17]);
    println!("OK: three-layer stack (Rust → PJRT → HLO(JAX+Pallas)) verified");
    Ok(())
}
