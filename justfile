# Task runner recipes (https://github.com/casey/just). CI mirrors these;
# plain `cargo` equivalents are listed in README.md for hosts without just.

default: test

build:
    cargo build --release

test:
    cargo test -q

# The perf-trajectory benches CI uploads as artifacts (lenient: wall-clock
# gates report instead of failing on noisy machines).
bench:
    ADAPAR_BENCH_LENIENT=1 cargo bench --bench bench_sched
    ADAPAR_BENCH_LENIENT=1 cargo bench --bench bench_chain --features bench-alloc
    ADAPAR_BENCH_LENIENT=1 cargo bench --bench bench_scale --features bench-alloc

# The >=1M-agent scale tier alone (BENCH_scale.json): streaming-window
# arena bounds gate hard; the streamed-vs-materialized throughput ratio
# is report-only under lenient.
bench-scale:
    ADAPAR_BENCH_LENIENT=1 cargo bench --bench bench_scale --features bench-alloc

# Compare the current tree's deterministic structural metrics (and
# advisory wall-clock) against the committed run-over-run baseline.
perf-diff:
    cargo run --release -- perf-diff --ledger experiments/ledger/BENCH_baseline.json

# Regenerate the committed baseline from this machine: re-runs the ledger
# scenarios (single-worker, fixed seeds — bit-reproducible) and pins every
# metric, including wall-clock (ADAPAR_PIN_WALL — only run this on a
# reference machine; a bare `perf-diff --update` leaves wall_* unpinned).
# Review and commit the result.
ledger-update:
    ADAPAR_PIN_WALL=1 cargo run --release -- perf-diff --update --ledger experiments/ledger/BENCH_baseline.json
    git diff --stat experiments/ledger/BENCH_baseline.json
