"""AOT export: lower the L2 computations to HLO *text* artifacts.

HLO text — NOT ``lowered.compile().serialize()`` and NOT serialized
``HloModuleProto`` bytes — is the interchange format: jax ≥ 0.5 emits
protos with 64-bit instruction ids which the Rust side's xla_extension
0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Each artifact is listed in ``manifest.txt`` with its static parameters so
the Rust runtime (rust/src/runtime/artifact.rs) can validate shapes:

    <name> path=<file> key=value ...

Usage: ``python -m compile.aot --out-dir ../artifacts [--paper-scale]``
Idempotent per the Makefile (only rebuilt when inputs change).
"""

import argparse
import os

import jax

from . import model

jax.config.update("jax_enable_x64", True)


def to_hlo_text(lowered) -> str:
    """Convert a jax lowering to XLA HLO text via stablehlo."""
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export(fn, args, out_dir, name, **meta):
    """Lower ``fn(*args)``, write ``<name>.hlo.txt``, return manifest line."""
    lowered = fn.lower(*args)
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as fh:
        fh.write(text)
    fields = " ".join(f"{k}={v}" for k, v in meta.items())
    print(f"  exported {fname} ({len(text)} chars)")
    return f"{name} path={fname} {fields}".strip()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--paper-scale",
        action="store_true",
        help="also export artifacts at the paper's full system sizes",
    )
    ns = ap.parse_args()
    os.makedirs(ns.out_dir, exist_ok=True)

    omega = 0.95
    p_si, p_ir, p_rs = 0.8, 0.1, 0.3
    lines = []

    # Axelrod: single interaction (the protocol-task-sized unit) and a
    # batch variant (amortized dispatch).
    for b, f in [(1, 100), (32, 100)]:
        fn, args = model.jitted_axelrod(b, f, omega)
        lines.append(
            export(fn, args, ns.out_dir, f"axelrod_b{b}_f{f}",
                   kind="axelrod", b=b, f=f, omega=omega)
        )

    # SIR: full synchronous sweep + block-sized compute task.
    sir_shapes = [(300, 14, 30)]
    if ns.paper_scale:
        sir_shapes.append((4000, 14, 100))
    for n, k, s in sir_shapes:
        fn, args = model.jitted_sir_step(n, k, p_si, p_ir, p_rs)
        lines.append(
            export(fn, args, ns.out_dir, f"sir_step_n{n}_k{k}",
                   kind="sir_step", n=n, k=k, p_si=p_si, p_ir=p_ir, p_rs=p_rs)
        )
        fn, args = model.jitted_sir_block(n, k, s, p_si, p_ir, p_rs)
        lines.append(
            export(fn, args, ns.out_dir, f"sir_block_n{n}_k{k}_s{s}",
                   kind="sir_block", n=n, k=k, s=s,
                   p_si=p_si, p_ir=p_ir, p_rs=p_rs)
        )

    manifest = os.path.join(ns.out_dir, "manifest.txt")
    with open(manifest, "w") as fh:
        fh.write("# adapar AOT artifact manifest: <name> path=<file> key=value ...\n")
        fh.write("\n".join(lines) + "\n")
    print(f"wrote {manifest} ({len(lines)} artifacts)")


if __name__ == "__main__":
    main()
