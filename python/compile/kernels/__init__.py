"""L1 Pallas kernels: the paper models' compute hot-spots + jnp oracles."""
