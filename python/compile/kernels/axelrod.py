"""L1 Pallas kernel: batched Axelrod pairwise interactions.

The task-execution hot-spot of the cultural-dynamics experiment — the O(F)
overlap scan plus the probabilistic trait copy — expressed as a Pallas
kernel tiled over the interaction batch.

TPU shaping (DESIGN.md §Hardware-Adaptation): the batch dimension is the
grid; each program instance holds a ``(block_b, F)`` tile of source and
target traits in VMEM and performs lane-vectorized comparisons/reductions
along F on the VPU (no MXU involvement — the model has no matmul). On this
repository's CPU-only image the kernel runs with ``interpret=True``; real
TPU lowering would emit a Mosaic custom-call the CPU PJRT client cannot
execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

jax.config.update("jax_enable_x64", True)


def _kernel(src_ref, tgt_ref, u1_ref, u2_ref, out_ref, *, omega, features):
    src = src_ref[...]
    tgt = tgt_ref[...]
    u1 = u1_ref[...]
    u2 = u2_ref[...]

    same = jnp.sum((src == tgt).astype(jnp.int32), axis=1)
    o = same.astype(jnp.float64) / features
    d = features - same
    eligible = (d > 0) & (o >= 1.0 - omega) & (u1 < o)
    k = jnp.floor(u2 * d.astype(jnp.float64)).astype(jnp.int32)
    k = jnp.minimum(k, jnp.maximum(d - 1, 0))
    diff = src != tgt
    idx = jnp.cumsum(diff.astype(jnp.int32), axis=1) - 1
    copy = diff & (idx == k[:, None]) & eligible[:, None]
    out_ref[...] = jnp.where(copy, src, tgt)


def axelrod_interact(src, tgt, u_interact, u_pick, *, omega, block_b=None):
    """Run the batched interaction kernel.

    Args:
      src, tgt: (B, F) int32 trait tiles.
      u_interact, u_pick: (B,) float64 uniforms.
      omega: bounded-confidence threshold (static).
      block_b: batch tile size (defaults to min(B, 16); must divide B).

    Returns:
      (B, F) int32 — new target traits. Matches ``ref.axelrod_ref``.
    """
    b, f = src.shape
    if block_b is None:
        block_b = next(x for x in range(min(b, 16), 0, -1) if b % x == 0)
    assert b % block_b == 0, f"block_b={block_b} must divide B={b}"
    grid = (b // block_b,)
    kernel = functools.partial(_kernel, omega=omega, features=f)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, f), lambda i: (i, 0)),
            pl.BlockSpec((block_b, f), lambda i: (i, 0)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_b, f), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, f), jnp.int32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(src, tgt, u_interact, u_pick)
