"""Pure-jnp correctness oracles for the Pallas kernels.

These are the semantic ground truth: every kernel in this package must
match its oracle bit-for-bit (f64 probability arithmetic, i32 states), and
the Rust native models implement the *same* arithmetic so that the PJRT
execution path can reproduce native results when fed identical uniforms.
"""

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)


def axelrod_ref(src, tgt, u_interact, u_pick, *, omega):
    """Batched Axelrod interaction (oracle).

    Args:
      src: (B, F) int32 — source agents' traits (read-only).
      tgt: (B, F) int32 — target agents' traits.
      u_interact: (B,) float64 — uniform for the interaction draw.
      u_pick: (B,) float64 — uniform for the differing-feature pick.
      omega: bounded-confidence threshold (static).

    Returns:
      (B, F) int32 — new target traits.

    Semantics (must match ``rust/src/models/axelrod.rs``): with overlap
    ``o = |{f: src_f == tgt_f}| / F``, the pair interacts iff
    ``1 - omega <= o < 1`` and ``u_interact < o``; then the target copies
    the source's value on differing feature number ``floor(u_pick * d)``
    (0-based among the ``d`` differing features, in feature order).
    """
    b, f = src.shape
    same = jnp.sum((src == tgt).astype(jnp.int32), axis=1)  # (B,)
    o = same.astype(jnp.float64) / f
    d = f - same
    eligible = (d > 0) & (o >= 1.0 - omega) & (u_interact < o)
    k = jnp.floor(u_pick * d.astype(jnp.float64)).astype(jnp.int32)
    k = jnp.minimum(k, jnp.maximum(d - 1, 0))  # guard u_pick -> 1.0 edge
    diff = src != tgt  # (B, F)
    # 0-based index of each differing slot along the feature axis.
    idx = jnp.cumsum(diff.astype(jnp.int32), axis=1) - 1
    copy = diff & (idx == k[:, None]) & eligible[:, None]
    return jnp.where(copy, src, tgt)


def sir_transition_ref(cur, frac, u, *, p_si, p_ir, p_rs):
    """Batched SIR state transition (oracle).

    Args:
      cur: (N,) int32 in {0 (S), 1 (I), 2 (R)}.
      frac: (N,) float64 — infected fraction among each agent's neighbours.
      u: (N,) float64 — one uniform per agent.
      p_si, p_ir, p_rs: transition parameters (static).

    Returns:
      (N,) int32 — next states.
    """
    s_next = jnp.where(u < p_si * frac, 1, 0)
    i_next = jnp.where(u < p_ir, 2, 1)
    r_next = jnp.where(u < p_rs, 0, 2)
    return jnp.where(cur == 0, s_next, jnp.where(cur == 1, i_next, r_next)).astype(jnp.int32)


def infected_fraction_ref(cur, nbrs):
    """Infected-neighbour fraction.

    Args:
      cur: (N,) int32 states.
      nbrs: (N, k) int32 neighbour indices.

    Returns:
      (N,) float64 — fraction of neighbours in state I.
    """
    k = nbrs.shape[1]
    infected = (jnp.take(cur, nbrs, axis=0) == 1).astype(jnp.float64)
    return jnp.sum(infected, axis=1) / k


def sir_step_ref(cur, nbrs, u, *, p_si, p_ir, p_rs):
    """Full synchronous SIR step (oracle): gather + transition."""
    frac = infected_fraction_ref(cur, nbrs)
    return sir_transition_ref(cur, frac, u, p_si=p_si, p_ir=p_ir, p_rs=p_rs)
