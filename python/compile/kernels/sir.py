"""L1 Pallas kernel: batched SIR state transitions.

The per-agent transition logic (the compute half of a type-1 task, after
the neighbour gather which stays in the surrounding L2 graph where XLA's
native gather is optimal) as a Pallas kernel tiled over agents.

TPU shaping (DESIGN.md §Hardware-Adaptation): agents tile along the grid;
each instance holds ``(block_n,)`` state/fraction/uniform vectors in VMEM
and evaluates the three-way transition with lane-vectorized selects — a
purely elementwise, memory-bound kernel whose roofline is HBM bandwidth.
Runs with ``interpret=True`` on this CPU-only image.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

jax.config.update("jax_enable_x64", True)


def _divisor_block(n: int, target: int) -> int:
    """Largest divisor of ``n`` that is ≤ ``target`` (≥ 1)."""
    for b in range(min(n, target), 0, -1):
        if n % b == 0:
            return b
    return 1


def _kernel(cur_ref, frac_ref, u_ref, out_ref, *, p_si, p_ir, p_rs):
    cur = cur_ref[...]
    frac = frac_ref[...]
    u = u_ref[...]
    s_next = jnp.where(u < p_si * frac, 1, 0)
    i_next = jnp.where(u < p_ir, 2, 1)
    r_next = jnp.where(u < p_rs, 0, 2)
    out_ref[...] = jnp.where(
        cur == 0, s_next, jnp.where(cur == 1, i_next, r_next)
    ).astype(jnp.int32)


def sir_transition(cur, frac, u, *, p_si, p_ir, p_rs, block_n=None):
    """Run the batched transition kernel.

    Args:
      cur: (N,) int32 states in {0, 1, 2}.
      frac: (N,) float64 infected-neighbour fractions.
      u: (N,) float64 uniforms (one per agent).
      p_si, p_ir, p_rs: transition parameters (static).
      block_n: agent tile size (defaults to min(N, 128); must divide N).

    Returns:
      (N,) int32 — next states. Matches ``ref.sir_transition_ref``.
    """
    n = cur.shape[0]
    if block_n is None:
        block_n = _divisor_block(n, 128)
    assert n % block_n == 0, f"block_n={block_n} must divide N={n}"
    grid = (n // block_n,)
    kernel = functools.partial(_kernel, p_si=p_si, p_ir=p_ir, p_rs=p_rs)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(cur, frac, u)
