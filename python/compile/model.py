"""L2: the JAX compute graphs lowered into the AOT artifacts.

Each public function here is a *jit-able, array-in/array-out* computation
that calls the L1 Pallas kernels for its hot-spot and is exported once to
HLO text by ``aot.py``. Python never runs on the Rust request path.

Exports:
  * ``axelrod_step``  — batched pairwise interactions (kernel: axelrod).
  * ``sir_step``      — full synchronous SIR sweep: XLA gather for the
                        neighbour fractions + the transition kernel.
  * ``sir_block_step``— the protocol-task-sized variant: computes new
                        states for one contiguous agent block (dynamic
                        start index), matching the Rust SIR model's
                        compute-task semantics.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import axelrod as axelrod_kernel
from .kernels import sir as sir_kernel

jax.config.update("jax_enable_x64", True)


def axelrod_step(src, tgt, u_interact, u_pick, *, omega):
    """Batched Axelrod interactions; see ``kernels.axelrod``.

    Shapes: src/tgt (B, F) int32; uniforms (B,) float64 → (B, F) int32.
    """
    return axelrod_kernel.axelrod_interact(src, tgt, u_interact, u_pick, omega=omega)


def sir_step(cur, nbrs, u, *, p_si, p_ir, p_rs):
    """One synchronous SIR sweep over all agents.

    Shapes: cur (N,) int32, nbrs (N, k) int32, u (N,) float64 → (N,) int32.

    The neighbour gather + mean runs as plain XLA (gather lowers to an
    optimal loop on CPU and to efficient dynamic-slices on TPU); the
    transition logic is the Pallas kernel.
    """
    k = nbrs.shape[1]
    infected = (jnp.take(cur, nbrs, axis=0) == 1).astype(jnp.float64)
    frac = jnp.sum(infected, axis=1) / k
    return sir_kernel.sir_transition(cur, frac, u, p_si=p_si, p_ir=p_ir, p_rs=p_rs)


def sir_block_step(cur, nbrs, u, start, *, block, p_si, p_ir, p_rs):
    """New states for one contiguous agent block (a protocol compute task).

    Args:
      cur: (N,) int32 — current states of the whole system.
      nbrs: (N, k) int32 — neighbour matrix.
      u: (block,) float64 — uniforms for the block's agents.
      start: () int32 — first agent of the block.
      block: static block size `s`.

    Returns:
      (block,) int32 — new states for agents ``start .. start+block``.
    """
    k = nbrs.shape[1]
    cur_block = jax.lax.dynamic_slice(cur, (start,), (block,))
    nbrs_block = jax.lax.dynamic_slice(nbrs, (start, jnp.int32(0)), (block, k))
    infected = (jnp.take(cur, nbrs_block, axis=0) == 1).astype(jnp.float64)
    frac = jnp.sum(infected, axis=1) / k
    return sir_kernel.sir_transition(
        cur_block, frac, u, p_si=p_si, p_ir=p_ir, p_rs=p_rs, block_n=min(block, 128)
    )


def jitted_axelrod(b, f, omega):
    """Jitted ``axelrod_step`` closed over static params, with arg specs."""
    fn = jax.jit(functools.partial(axelrod_step, omega=omega))
    args = (
        jax.ShapeDtypeStruct((b, f), jnp.int32),
        jax.ShapeDtypeStruct((b, f), jnp.int32),
        jax.ShapeDtypeStruct((b,), jnp.float64),
        jax.ShapeDtypeStruct((b,), jnp.float64),
    )
    return fn, args


def jitted_sir_step(n, k, p_si, p_ir, p_rs):
    """Jitted ``sir_step`` closed over static params, with arg specs."""
    fn = jax.jit(functools.partial(sir_step, p_si=p_si, p_ir=p_ir, p_rs=p_rs))
    args = (
        jax.ShapeDtypeStruct((n,), jnp.int32),
        jax.ShapeDtypeStruct((n, k), jnp.int32),
        jax.ShapeDtypeStruct((n,), jnp.float64),
    )
    return fn, args


def jitted_sir_block(n, k, block, p_si, p_ir, p_rs):
    """Jitted ``sir_block_step`` closed over static params, with arg specs."""
    fn = jax.jit(
        functools.partial(sir_block_step, block=block, p_si=p_si, p_ir=p_ir, p_rs=p_rs)
    )
    args = (
        jax.ShapeDtypeStruct((n,), jnp.int32),
        jax.ShapeDtypeStruct((n, k), jnp.int32),
        jax.ShapeDtypeStruct((block,), jnp.float64),
        jax.ShapeDtypeStruct((), jnp.int32),
    )
    return fn, args
