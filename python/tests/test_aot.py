"""AOT export path: HLO text generation and manifest structure."""

import os
import subprocess
import sys

import jax

from compile import aot, model

jax.config.update("jax_enable_x64", True)


def test_to_hlo_text_produces_parseable_module():
    fn, args = model.jitted_axelrod(1, 10, 0.95)
    text = aot.to_hlo_text(fn.lower(*args))
    assert "HloModule" in text
    assert "ENTRY" in text
    # f64 probability arithmetic must survive lowering.
    assert "f64" in text


def test_sir_block_lowering_has_dynamic_slice():
    fn, args = model.jitted_sir_block(60, 4, 15, p_si=0.8, p_ir=0.1, p_rs=0.3)
    text = aot.to_hlo_text(fn.lower(*args))
    assert "HloModule" in text
    assert "dynamic-slice" in text


def test_full_export_writes_manifest(tmp_path):
    out = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path)],
        capture_output=True,
        text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert out.returncode == 0, out.stderr
    manifest = (tmp_path / "manifest.txt").read_text().strip().splitlines()
    entries = [l for l in manifest if l and not l.startswith("#")]
    assert len(entries) >= 4
    for line in entries:
        name, *fields = line.split()
        kv = dict(f.split("=", 1) for f in fields)
        assert "path" in kv and "kind" in kv
        assert (tmp_path / kv["path"]).exists(), f"missing artifact for {name}"
        head = (tmp_path / kv["path"]).read_text(encoding="utf-8")[:4096]
        assert "HloModule" in head
