"""Axelrod Pallas kernel vs pure-jnp oracle — the L1 correctness signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.axelrod import axelrod_interact
from compile.kernels.ref import axelrod_ref

jax.config.update("jax_enable_x64", True)


def _case(seed, b, f, q=3):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, q, size=(b, f)).astype(np.int32)
    tgt = rng.integers(0, q, size=(b, f)).astype(np.int32)
    u1 = rng.random(size=(b,))
    u2 = rng.random(size=(b,))
    return src, tgt, u1, u2


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    b=st.sampled_from([1, 2, 4, 8, 16]),
    f=st.integers(2, 40),
    omega=st.sampled_from([0.3, 0.95, 1.0]),
)
def test_kernel_matches_ref(seed, b, f, omega):
    src, tgt, u1, u2 = _case(seed, b, f)
    got = axelrod_interact(src, tgt, u1, u2, omega=omega, block_b=min(b, 4) if b % 4 == 0 or b < 4 else 1)
    want = axelrod_ref(src, tgt, u1, u2, omega=omega)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_identical_agents_are_noop():
    src = np.ones((4, 10), dtype=np.int32)
    tgt = np.ones((4, 10), dtype=np.int32)
    u1 = np.zeros(4)
    u2 = np.zeros(4)
    out = axelrod_interact(src, tgt, u1, u2, omega=1.0)
    np.testing.assert_array_equal(np.asarray(out), tgt)


def test_interaction_copies_exactly_one_feature():
    src, tgt, _, _ = _case(7, 8, 20)
    u1 = np.zeros(8)  # u1 < o whenever o > 0: interact if any overlap
    u2 = np.full(8, 0.5)
    out = np.asarray(axelrod_interact(src, tgt, u1, u2, omega=1.0))
    for row in range(8):
        same_before = int((src[row] == tgt[row]).sum())
        changed = int((out[row] != tgt[row]).sum())
        overlap = same_before / 20
        if 0 < overlap < 1:
            assert changed == 1, f"row {row} changed {changed} features"
            # The changed feature must now equal the source's value.
            i = int(np.nonzero(out[row] != tgt[row])[0][0])
            assert out[row, i] == src[row, i]
        else:
            assert changed == 0


def test_bounded_confidence_window_blocks_interaction():
    # Overlap = 0.5; with omega = 0.3 the window is [0.7, 1): ineligible.
    f = 10
    src = np.zeros((1, f), dtype=np.int32)
    tgt = np.concatenate([np.zeros((1, f // 2)), np.ones((1, f // 2))], axis=1).astype(np.int32)
    out = axelrod_interact(src, tgt, np.zeros(1), np.zeros(1), omega=0.3)
    np.testing.assert_array_equal(np.asarray(out), tgt)


def test_u_interact_threshold_is_strict():
    # o = 0.5: u1 = 0.5 must NOT interact (u < o is strict), u1 < 0.5 must.
    f = 4
    src = np.array([[1, 1, 2, 2]], dtype=np.int32)
    tgt = np.array([[1, 1, 3, 3]], dtype=np.int32)
    out_eq = np.asarray(axelrod_interact(src, tgt, np.array([0.5]), np.array([0.0]), omega=1.0))
    np.testing.assert_array_equal(out_eq, tgt)
    out_lt = np.asarray(axelrod_interact(src, tgt, np.array([0.49]), np.array([0.0]), omega=1.0))
    assert (out_lt != tgt).sum() == 1


def test_pick_selects_kth_differing_feature():
    # d = 4 differing features at positions 1, 3, 5, 7; u2 = 0.6 -> k = 2
    # -> position 5.
    f = 8
    src = np.zeros((1, f), dtype=np.int32)
    tgt = np.zeros((1, f), dtype=np.int32)
    tgt[0, [1, 3, 5, 7]] = 1
    out = np.asarray(
        axelrod_interact(src, tgt, np.array([0.0]), np.array([0.6]), omega=1.0)
    )
    expect = tgt.copy()
    expect[0, 5] = 0
    np.testing.assert_array_equal(out, expect)


@pytest.mark.parametrize("block_b", [1, 2, 4, 8])
def test_block_size_invariance(block_b):
    src, tgt, u1, u2 = _case(3, 8, 16)
    out = axelrod_interact(src, tgt, u1, u2, omega=0.95, block_b=block_b)
    want = axelrod_ref(src, tgt, u1, u2, omega=0.95)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_dtype_is_preserved():
    src, tgt, u1, u2 = _case(1, 4, 8)
    out = axelrod_interact(src, tgt, u1, u2, omega=0.95)
    assert out.dtype == jnp.int32
