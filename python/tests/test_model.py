"""L2 model graphs: composition, block/full consistency, jit stability."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.ref import axelrod_ref, sir_step_ref

jax.config.update("jax_enable_x64", True)

P = dict(p_si=0.8, p_ir=0.1, p_rs=0.3)


def _ring_nbrs(n, k):
    return np.stack(
        [np.roll(np.arange(n), -d) for d in range(1, k // 2 + 1)]
        + [np.roll(np.arange(n), d) for d in range(1, k // 2 + 1)],
        axis=1,
    ).astype(np.int32)


def test_axelrod_step_matches_ref():
    rng = np.random.default_rng(0)
    src = rng.integers(0, 3, size=(16, 25)).astype(np.int32)
    tgt = rng.integers(0, 3, size=(16, 25)).astype(np.int32)
    u1, u2 = rng.random(16), rng.random(16)
    got = model.axelrod_step(src, tgt, u1, u2, omega=0.95)
    want = axelrod_ref(src, tgt, u1, u2, omega=0.95)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_sir_step_matches_ref():
    rng = np.random.default_rng(1)
    n, k = 128, 6
    cur = rng.integers(0, 3, size=n).astype(np.int32)
    nbrs = _ring_nbrs(n, k)
    u = rng.random(n)
    got = model.sir_step(cur, nbrs, u, **P)
    want = sir_step_ref(cur, nbrs, u, **P)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), start_block=st.integers(0, 3))
def test_block_step_equals_full_step_slice(seed, start_block):
    rng = np.random.default_rng(seed)
    n, k, s = 120, 6, 30
    cur = rng.integers(0, 3, size=n).astype(np.int32)
    nbrs = _ring_nbrs(n, k)
    u_full = rng.random(n)
    start = start_block * s
    full = np.asarray(model.sir_step(cur, nbrs, u_full, **P))
    block = np.asarray(
        model.sir_block_step(
            cur, nbrs, u_full[start : start + s], jnp.int32(start), block=s, **P
        )
    )
    np.testing.assert_array_equal(block, full[start : start + s])


def test_jitted_wrappers_lower_and_run():
    fn, args = model.jitted_axelrod(4, 10, 0.95)
    lowered = fn.lower(*args)
    assert lowered is not None
    rng = np.random.default_rng(2)
    out = fn(
        rng.integers(0, 3, size=(4, 10)).astype(np.int32),
        rng.integers(0, 3, size=(4, 10)).astype(np.int32),
        rng.random(4),
        rng.random(4),
    )
    assert out.shape == (4, 10) and out.dtype == jnp.int32

    fn, args = model.jitted_sir_step(64, 4, **P)
    out = fn(
        rng.integers(0, 3, size=64).astype(np.int32),
        _ring_nbrs(64, 4),
        rng.random(64),
    )
    assert out.shape == (64,) and out.dtype == jnp.int32

    fn, args = model.jitted_sir_block(64, 4, 16, **P)
    out = fn(
        rng.integers(0, 3, size=64).astype(np.int32),
        _ring_nbrs(64, 4),
        rng.random(16),
        jnp.int32(16),
    )
    assert out.shape == (16,) and out.dtype == jnp.int32
