"""SIR Pallas kernel vs pure-jnp oracle."""

import jax
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import infected_fraction_ref, sir_step_ref, sir_transition_ref
from compile.kernels.sir import sir_transition

jax.config.update("jax_enable_x64", True)

P = dict(p_si=0.8, p_ir=0.1, p_rs=0.3)


def _case(seed, n):
    rng = np.random.default_rng(seed)
    cur = rng.integers(0, 3, size=n).astype(np.int32)
    frac = rng.random(size=n)
    u = rng.random(size=n)
    return cur, frac, u


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.sampled_from([1, 8, 64, 128, 256]),
    p_si=st.sampled_from([0.0, 0.5, 0.8, 1.0]),
)
def test_kernel_matches_ref(seed, n, p_si):
    cur, frac, u = _case(seed, n)
    params = dict(P, p_si=p_si)
    got = sir_transition(cur, frac, u, **params, block_n=min(n, 64))
    want = sir_transition_ref(cur, frac, u, **params)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_states_stay_in_range():
    cur, frac, u = _case(5, 512)
    out = np.asarray(sir_transition(cur, frac, u, **P))
    assert set(np.unique(out)).issubset({0, 1, 2})


def test_transition_structure():
    # S with zero infected fraction never infects; I->R and R->S move only
    # one step; nobody jumps S->R or I->S.
    n = 256
    cur, _, u = _case(9, n)
    frac = np.zeros(n)
    out = np.asarray(sir_transition(cur, frac, u, **P))
    for before, after in zip(cur, out):
        if before == 0:
            assert after == 0, "S with no infected neighbours stays S"
        elif before == 1:
            assert after in (1, 2)
        else:
            assert after in (2, 0)


def test_certain_infection():
    # frac = 1, p_si = 1, u < 1: S always becomes I.
    n = 64
    cur = np.zeros(n, dtype=np.int32)
    frac = np.ones(n)
    u = np.full(n, 0.999)
    out = np.asarray(sir_transition(cur, frac, u, p_si=1.0, p_ir=0.1, p_rs=0.3))
    assert (out == 1).all()


def test_infected_fraction_ref_on_ring():
    # 4-ring, agent 0's neighbours are 1 and 3.
    cur = np.array([0, 1, 0, 1], dtype=np.int32)
    nbrs = np.array([[1, 3], [2, 0], [3, 1], [0, 2]], dtype=np.int32)
    frac = np.asarray(infected_fraction_ref(cur, nbrs))
    np.testing.assert_allclose(frac, [1.0, 0.0, 1.0, 0.0])


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_full_step_composes_gather_and_transition(seed):
    rng = np.random.default_rng(seed)
    n, k = 120, 6
    cur = rng.integers(0, 3, size=n).astype(np.int32)
    nbrs = np.stack(
        [np.roll(np.arange(n), -d) for d in range(1, k + 1)], axis=1
    ).astype(np.int32)
    u = rng.random(size=n)
    want = sir_step_ref(cur, nbrs, u, **P)
    frac = infected_fraction_ref(cur, nbrs)
    got = sir_transition(cur, np.asarray(frac), u, **P, block_n=60)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
