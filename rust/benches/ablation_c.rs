//! ABL-C — the paper keeps C = 6 fixed, "since separate experimentation
//! showed its effect to be negligible". This ablation reproduces that
//! claim: sweep C ∈ {1, 2, 6, 16, 64} on both paper models, on the virtual
//! testbed (timing) and the native engine (correct completion), and
//! report the relative spread of T.

use adapar::coordinator::config::{EngineKind, SweepConfig};
use adapar::coordinator::run_once;
use adapar::util::csv::Table;
use adapar::util::stats::Online;
use adapar::vtime::CostModel;

fn main() -> adapar::Result<()> {
    let cs = [1u32, 2, 6, 16, 64];
    let cost = CostModel::default();
    let mut table = Table::new(["model", "C", "mean_T_s", "rel_to_C6"]);
    let mut worst_spread: f64 = 0.0;

    for model in ["axelrod", "sir"] {
        let mut means = Vec::new();
        for &c in &cs {
            let cfg = SweepConfig {
                model: model.to_string(),
                engine: EngineKind::Virtual,
                sizes: vec![0], // unused below
                workers: vec![3],
                seeds: vec![1],
                tasks_per_cycle: c,
                agents: if model == "axelrod" { 1_000 } else { 4_000 },
                steps: if model == "axelrod" { 30_000 } else { 150 },
                ..Default::default()
            };
            let size = 100;
            let mut acc = Online::new();
            for seed in [1u64, 2, 3] {
                acc.push(run_once(&cfg, size, 3, seed, &cost)?.time_s);
            }
            means.push((c, acc.mean()));
        }
        let t6 = means.iter().find(|(c, _)| *c == 6).unwrap().1;
        for &(c, t) in &means {
            let rel = t / t6;
            worst_spread = worst_spread.max((rel - 1.0).abs());
            table.push([
                model.to_string(),
                c.to_string(),
                format!("{t:.6}"),
                format!("{rel:.4}"),
            ]);
        }
    }

    println!("{}", table.to_markdown());
    table.write_csv("target/bench-data/ablation_c.csv")?;
    eprintln!(
        "max |T(C)/T(6) - 1| = {:.1}% (paper: \"effect negligible\"; {} at 10% tolerance)",
        worst_spread * 100.0,
        if worst_spread < 0.10 { "PASS" } else { "FAIL" }
    );
    adapar::ensure!(worst_spread < 0.10, "C ablation spread too large");
    Ok(())
}
