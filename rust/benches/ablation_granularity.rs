//! ABL-G — granularity/overhead anatomy of the SIR experiment (§4.2) plus
//! a partition-quality ablation the paper's design implies: the contiguous
//! partition keeps the aggregate graph sparse (each subset touches 2
//! neighbours on a ring); a round-robin partition makes every subset
//! adjacent to every other, collapsing available parallelism — the record
//! then serializes everything.

use adapar::models::sir::{SirModel, SirParams, SirPhase, SirTask};
use adapar::model::Model as _;
use adapar::model::Record as _;
use adapar::sim::graph::{aggregate_graph, contiguous_partition, ring_lattice, round_robin_partition};
use adapar::util::csv::Table;
use adapar::vtime::{CostModel, VirtualEngine};

fn main() -> adapar::Result<()> {
    // Part 1: protocol-op counters across granularity (virtual, n = 3).
    let mut t1 = Table::new(["s", "blocks", "T_s", "overhead", "max_chain", "skips_per_task"]);
    for s in [10usize, 20, 50, 100, 200, 500] {
        let m = SirModel::new(SirParams::scaled(s, 4_000, 100), 1);
        let rep = VirtualEngine {
            workers: 3,
            tasks_per_cycle: 6,
            seed: 1,
            cost: CostModel::default(),
            trace: adapar::TraceMode::Off,
            window: 0,
        }
        .run(&m);
        let tasks = rep.totals.executed.max(1);
        t1.push([
            s.to_string(),
            m.blocks().to_string(),
            format!("{:.6}", rep.time_s),
            format!(
                "{:.3}",
                (rep.totals.skipped_dependent + rep.totals.passed_executing) as f64
                    / (rep.totals.skipped_dependent + rep.totals.passed_executing + tasks) as f64
            ),
            rep.chain.max_chain_len.to_string(),
            format!("{:.2}", rep.totals.skipped_dependent as f64 / tasks as f64),
        ]);
    }
    println!("== granularity anatomy (SIR, virtual n=3) ==");
    println!("{}", t1.to_markdown());
    t1.write_csv("target/bench-data/ablation_granularity.csv")?;

    // Part 2: partition quality — aggregate-graph degree under contiguous
    // vs round-robin partitions, and the dependence-density consequence.
    let n = 4_000;
    let k = 14;
    let g = ring_lattice(n, k);
    let mut t2 = Table::new(["partition", "s", "agg_mean_degree", "frac_dependent_pairs"]);
    for s in [50usize, 200] {
        let blocks = n / s;
        for (name, part) in [
            ("contiguous", contiguous_partition(n, s)),
            ("round_robin", round_robin_partition(n, blocks)),
        ] {
            let agg = aggregate_graph(&g, &part);
            let mean_deg =
                (0..agg.n()).map(|v| agg.degree(v)).sum::<usize>() as f64 / agg.n() as f64;
            // Fraction of block pairs that conflict (swap-vs-compute).
            let mut dependent = 0usize;
            let mut total = 0usize;
            let model = SirModel::new(SirParams::scaled(s, n, 1), 0);
            for a in 0..blocks.min(40) {
                let mut rec = model.record();
                rec.absorb(&SirTask { phase: SirPhase::Compute, block: a as u32 });
                for b in 0..blocks {
                    total += 1;
                    // NOTE: this uses the *contiguous* model's masks for the
                    // round-robin row too, so we compute dependence from the
                    // aggregate graph directly instead:
                    let dep = a == b || agg.has_edge(a, b);
                    let _ = &mut rec;
                    if dep {
                        dependent += 1;
                    }
                }
            }
            t2.push([
                name.to_string(),
                s.to_string(),
                format!("{mean_deg:.1}"),
                format!("{:.4}", dependent as f64 / total as f64),
            ]);
        }
    }
    println!("== partition quality (aggregate-graph density) ==");
    println!("{}", t2.to_markdown());
    t2.write_csv("target/bench-data/ablation_partition.csv")?;

    eprintln!("ablation_granularity: done");
    Ok(())
}
