//! BASE — the related-work comparison (§2): the chain protocol vs the
//! per-step barrier parallelization, plus the sequential reference.
//!
//! Two claims are checked:
//!   1. Synchronous SIR runs on both parallel engines; in virtual time the
//!      protocol keeps cores busy across phase boundaries while the
//!      stepwise engine stalls at barriers (advantage grows with block
//!      heterogeneity).
//!   2. Axelrod has **no** stepwise form at all (one update per step) —
//!      only the protocol parallelizes it. This is asserted via the config
//!      validator, not hand-waved.

use adapar::coordinator::config::{EngineKind, SweepConfig};
use adapar::coordinator::run_once;
use adapar::util::csv::Table;
use adapar::util::stats::Online;
use adapar::vtime::CostModel;

fn main() -> adapar::Result<()> {
    let cost = CostModel::default();
    let mut table = Table::new(["model", "engine", "workers", "mean_T_s", "sem"]);

    // SIR across engines. Real-thread engines on this 1-core host measure
    // overhead, not speedup, so the wall-clock comparison is taken from
    // the virtual testbed for parallel; stepwise/sequential are native.
    for (engine, workers) in [
        (EngineKind::Sequential, 1usize),
        (EngineKind::Stepwise, 1),
        (EngineKind::Stepwise, 4),
        (EngineKind::Parallel, 1),
        (EngineKind::Parallel, 4),
        (EngineKind::Virtual, 1),
        (EngineKind::Virtual, 4),
    ] {
        let cfg = SweepConfig {
            model: "sir".to_string(),
            engine,
            sizes: vec![100],
            workers: vec![workers],
            seeds: vec![1, 2, 3],
            agents: 4_000,
            steps: 120,
            ..Default::default()
        };
        let mut acc = Online::new();
        for seed in [1u64, 2, 3] {
            acc.push(run_once(&cfg, 100, workers, seed, &cost)?.time_s);
        }
        table.push([
            "sir".into(),
            engine.to_string(),
            workers.to_string(),
            format!("{:.6}", acc.mean()),
            format!("{:.6}", acc.sem()),
        ]);
    }

    // Axelrod: sequential vs protocol (stepwise is impossible — checked).
    for (engine, workers) in [
        (EngineKind::Sequential, 1usize),
        (EngineKind::Virtual, 1),
        (EngineKind::Virtual, 4),
    ] {
        let cfg = SweepConfig {
            model: "axelrod".to_string(),
            engine,
            sizes: vec![100],
            workers: vec![workers],
            seeds: vec![1],
            agents: 1_000,
            steps: 40_000,
            ..Default::default()
        };
        let mut acc = Online::new();
        for seed in [1u64, 2, 3] {
            acc.push(run_once(&cfg, 100, workers, seed, &cost)?.time_s);
        }
        table.push([
            "axelrod".into(),
            engine.to_string(),
            workers.to_string(),
            format!("{:.6}", acc.mean()),
            format!("{:.6}", acc.sem()),
        ]);
    }

    println!("{}", table.to_markdown());
    table.write_csv("target/bench-data/baseline_comparison.csv")?;

    // Claim 2: the stepwise engine rejects sequential-form models.
    let bad = SweepConfig {
        model: "axelrod".to_string(),
        engine: EngineKind::Stepwise,
        ..Default::default()
    };
    adapar::ensure!(
        bad.validate().is_err(),
        "stepwise must reject sequential-form models (the paper's argument)"
    );
    eprintln!("axelrod has no stepwise form (validator rejects): PASS");
    eprintln!("baseline_comparison: done");
    Ok(())
}
