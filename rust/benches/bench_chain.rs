//! BENCH_chain — arena-backed chain core: batched creation vs the
//! classic protocol shape, on the paper's two workloads (ISSUE 5).
//!
//! `B = 1` is the *pre-refactor throughput proxy*: one task linked per
//! tail-lock acquisition, exactly the old protocol's creation pattern
//! (now arena-backed, so the comparison isolates batching). `B = 64`
//! amortizes the lock across a whole batch. For SIR + Axelrod at
//! 1/2/4/8 workers the bench records tasks/s, tail-lock acquisitions,
//! tasks-per-lock and arena telemetry; a third section measures
//! allocation traffic with the `bench-alloc` counting allocator (the
//! zero-steady-state-allocation criterion, DESIGN.md §3) on the
//! allocation-free `IncModel` so the chain is the only allocator in the
//! loop.
//!
//! Emits `BENCH_chain.json` into the invocation directory (repo root
//! under `cargo bench`), where per-PR perf tracking — and the CI
//! artifact upload — pick `BENCH_*.json` files up. A fourth section
//! compares the bit-packed SoA state layout against the legacy AoS
//! buffers on the three migrated models (SIR, voter, Ising) and emits
//! it as a separate `BENCH_soa.json` artifact.
//!
//! Acceptance:
//! * **hard, deterministic**: at `B = 64` every configuration takes ≥10×
//!   fewer tail-lock acquisitions than at `B = 1` (lock counts do not
//!   depend on wall clocks);
//! * **lenient-gated** (`ADAPAR_BENCH_LENIENT=1` downgrades to
//!   report-only): with `bench-alloc`, the single-worker execution loop
//!   allocates < 16 bytes per task — i.e. nothing at steady state
//!   beyond the pre-sized slab;
//! * **lenient-gated**: `--trace-mode spans` costs ≤5% tasks/s vs
//!   tracing off on the SIR workload (ISSUE 8's overhead budget).

#[cfg(feature = "bench-alloc")]
#[global_allocator]
static ALLOC: adapar::util::alloc::Counting = adapar::util::alloc::Counting;

use adapar::model::testkit::IncModel;
use adapar::protocol::{ParallelEngine, ProtocolConfig};
use adapar::util::json::Json;
use adapar::{EngineKind, Simulation};

const WORKERS: [usize; 4] = [1, 2, 4, 8];
const BATCHES: [u32; 2] = [1, 64];

struct Workload {
    model: &'static str,
    agents: usize,
    steps: u64,
    size: usize,
}

const WORKLOADS: [Workload; 2] = [
    Workload {
        model: "sir",
        agents: 2_000,
        steps: 500,
        size: 100,
    },
    Workload {
        model: "axelrod",
        agents: 400,
        steps: 30_000,
        size: 50,
    },
];

fn run_one(w: &Workload, workers: usize, batch: u32) -> adapar::Result<Json> {
    let out = Simulation::builder()
        .model(w.model)
        .engine(EngineKind::Parallel)
        .workers(workers)
        // The effective batch is min(B, remaining C), so raise C to the
        // deepest batch under test — otherwise the paper-default C = 6
        // would clamp the B = 64 axis down to 6.
        .tasks_per_cycle(64)
        .batch(batch)
        .agents(w.agents)
        .steps(w.steps)
        .size(w.size)
        .seed(7)
        .run()?;
    let chain = &out.report.chain;
    let tasks = chain.tasks_executed;
    let throughput = tasks as f64 / out.report.time_s.max(1e-12);
    eprintln!(
        "{:<8} n={workers} B={batch:<3}: {:>9.0} tasks/s  tail_locks={:<8} \
         ({:.1} tasks/lock)  arena {}/{} slots, {} recycled",
        w.model,
        throughput,
        chain.tail_locks,
        chain.tasks_per_tail_lock(),
        chain.arena_high_water,
        chain.arena_capacity,
        chain.arena_recycled
    );
    Ok(Json::Obj(vec![
        ("model".into(), Json::from(w.model)),
        ("workers".into(), Json::from(workers)),
        ("batch".into(), Json::from(batch)),
        ("tasks".into(), Json::from(tasks)),
        ("time_s".into(), Json::from(out.report.time_s)),
        ("throughput_tasks_per_s".into(), Json::from(throughput)),
        ("tail_locks".into(), Json::from(chain.tail_locks)),
        (
            "tasks_per_tail_lock".into(),
            Json::from(chain.tasks_per_tail_lock()),
        ),
        ("arena_capacity".into(), Json::from(chain.arena_capacity)),
        (
            "arena_high_water".into(),
            Json::from(chain.arena_high_water),
        ),
        ("arena_recycled".into(), Json::from(chain.arena_recycled)),
        ("max_chain_len".into(), Json::from(chain.max_chain_len)),
    ]))
}

/// Allocation traffic of one engine run, measured with the counting
/// allocator when the `bench-alloc` feature is on (`None` otherwise).
fn alloc_run(tasks: u64, workers: usize, batch: u32) -> (f64, Option<(u64, u64)>) {
    let model = IncModel::new(tasks, 64);
    let engine = ParallelEngine::new(ProtocolConfig {
        workers,
        tasks_per_cycle: 64, // let the B = 64 axis batch fully
        batch,
        seed: 11,
        ..Default::default()
    });
    #[cfg(feature = "bench-alloc")]
    {
        let before = adapar::util::alloc::snapshot();
        let report = engine.run(&model);
        let delta = adapar::util::alloc::since(before);
        assert_eq!(report.totals.executed, tasks);
        (
            delta.bytes as f64 / tasks as f64,
            Some((delta.bytes, delta.count)),
        )
    }
    #[cfg(not(feature = "bench-alloc"))]
    {
        let report = engine.run(&model);
        assert_eq!(report.totals.executed, tasks);
        (0.0, None)
    }
}

fn main() -> adapar::Result<()> {
    eprintln!("== BENCH_chain: arena chain, batched creation (B=1 proxy vs B=64) ==");
    let mut configs = Vec::new();
    // tail_locks per (model, workers) at each batch size, for the
    // deterministic amortization gate.
    let mut amortization_ok = true;
    for w in &WORKLOADS {
        for &workers in &WORKERS {
            let mut locks = [0u64; 2];
            for (i, &batch) in BATCHES.iter().enumerate() {
                let json = run_one(w, workers, batch)?;
                if let Json::Obj(fields) = &json {
                    if let Some((_, Json::Int(l))) =
                        fields.iter().find(|(k, _)| k == "tail_locks")
                    {
                        locks[i] = *l as u64;
                    }
                }
                configs.push(json);
            }
            if locks[1] * 10 > locks[0] {
                amortization_ok = false;
                eprintln!(
                    "AMORTIZATION MISS: {} n={workers}: B=64 locks={} vs B=1 locks={}",
                    w.model, locks[1], locks[0]
                );
            }
        }
    }

    // Allocation section: IncModel keeps model/source/execute
    // allocation-free, so the measured traffic is the chain's own.
    let alloc_tasks = 200_000u64;
    let mut alloc_rows = Vec::new();
    let mut bytes_per_task_n1 = None;
    for &workers in &[1usize, 4] {
        for &batch in &BATCHES {
            let (per_task, raw) = alloc_run(alloc_tasks, workers, batch);
            let (bytes, count) = raw.unwrap_or((0, 0));
            if raw.is_some() {
                eprintln!(
                    "alloc    n={workers} B={batch:<3}: {bytes} B total ({count} allocs) \
                     = {per_task:.2} B/task over {alloc_tasks} tasks"
                );
                if workers == 1 && batch == 64 {
                    bytes_per_task_n1 = Some(per_task);
                }
            }
            alloc_rows.push(Json::Obj(vec![
                ("workers".into(), Json::from(workers)),
                ("batch".into(), Json::from(batch)),
                ("tasks".into(), Json::from(alloc_tasks)),
                (
                    "bytes_total".into(),
                    if raw.is_some() {
                        Json::from(bytes)
                    } else {
                        Json::Null
                    },
                ),
                (
                    "alloc_calls".into(),
                    if raw.is_some() {
                        Json::from(count)
                    } else {
                        Json::Null
                    },
                ),
                (
                    "bytes_per_task".into(),
                    if raw.is_some() {
                        Json::from(per_task)
                    } else {
                        Json::Null
                    },
                ),
            ]));
        }
    }

    // Trace-overhead section (ISSUE 8): span recording must stay cheap
    // enough to leave on under observation — tasks/s at
    // `--trace-mode spans` within 5% of tracing off, on the SIR
    // workload. Wall-clock-dependent, so lenient-gated like the
    // allocation check; best-of-3 on each side damps runner noise.
    let trace_w = &WORKLOADS[0];
    let trace_run = |mode: adapar::TraceMode| -> adapar::Result<f64> {
        let mut best = 0f64;
        for rep in 0..3 {
            let out = Simulation::builder()
                .model(trace_w.model)
                .engine(EngineKind::Parallel)
                .workers(4)
                .tasks_per_cycle(64)
                .batch(64)
                .agents(trace_w.agents)
                .steps(trace_w.steps)
                .size(trace_w.size)
                .seed(7 + rep)
                .trace(mode)
                .run()?;
            best = best.max(
                out.report.chain.tasks_executed as f64 / out.report.time_s.max(1e-12),
            );
        }
        Ok(best)
    };
    let off_tps = trace_run(adapar::TraceMode::Off)?;
    let spans_tps = trace_run(adapar::TraceMode::Spans)?;
    let trace_ratio = spans_tps / off_tps.max(1e-12);
    let trace_ok = trace_ratio >= 0.95;
    eprintln!(
        "trace    {} n=4 B=64: off {:>9.0} tasks/s, spans {:>9.0} tasks/s \
         ({:.1}% of off){}",
        trace_w.model,
        off_tps,
        spans_tps,
        trace_ratio * 100.0,
        if trace_ok { "" } else { "  OVERHEAD MISS" }
    );

    // Structural section: the perf-ledger scenarios (single-worker,
    // seeded, wall-clock-free apart from the advisory `wall_s` field).
    // These are the exact rows `adapar perf-diff` gates against
    // `experiments/ledger/BENCH_baseline.json`.
    let structural: Vec<Json> = adapar::coordinator::ledger::collect()?
        .into_iter()
        .map(|b| {
            eprintln!(
                "ledger   {}: {}",
                b.name,
                b.metrics
                    .iter()
                    .map(|(k, v)| format!("{k}={v}"))
                    .collect::<Vec<_>>()
                    .join(" ")
            );
            Json::Obj(vec![
                ("name".into(), Json::from(b.name)),
                (
                    "metrics".into(),
                    Json::Obj(
                        b.metrics
                            .into_iter()
                            .map(|(k, v)| (k, Json::from(v)))
                            .collect(),
                    ),
                ),
            ])
        })
        .collect();

    // SoA layout section (ISSUE 9): the bit-packed state layer vs the
    // legacy AoS buffers on the three migrated models, emitted as its
    // own `BENCH_soa.json` artifact (the CI `BENCH_*.json` glob picks it
    // up). `bytes_per_task` is structural — derived from the model's
    // per-task state estimate, never from the clock — so "packed moves
    // fewer bytes than legacy" is a hard deterministic gate, as is
    // observable equality across layouts. Throughput (and, with
    // `bench-alloc`, allocation traffic) rides along lenient-gated like
    // every wall-clock number.
    let soa_workloads: [(&str, usize, u64, usize); 3] = [
        ("sir", 2_000, 500, 100),
        ("voter", 2_000, 20_000, 1),
        ("ising", 4_096, 20_000, 1),
    ];
    let mut soa_rows = Vec::new();
    let mut soa_bytes_ok = true;
    let mut soa_tps_ok = true;
    for &(model, agents, steps, size) in &soa_workloads {
        let run = |layout: adapar::Layout| -> adapar::Result<_> {
            #[cfg(feature = "bench-alloc")]
            let before = adapar::util::alloc::snapshot();
            let out = Simulation::builder()
                .model(model)
                .engine(EngineKind::Parallel)
                .workers(4)
                .tasks_per_cycle(64)
                .batch(64)
                .agents(agents)
                .steps(steps)
                .size(size)
                .seed(7)
                .layout(layout)
                .run()?;
            #[cfg(feature = "bench-alloc")]
            let alloc_bytes = Some(adapar::util::alloc::since(before).bytes);
            #[cfg(not(feature = "bench-alloc"))]
            let alloc_bytes: Option<u64> = None;
            Ok((out, alloc_bytes))
        };
        let (legacy, legacy_alloc) = run(adapar::Layout::Legacy)?;
        let (packed, packed_alloc) = run(adapar::Layout::Packed)?;
        adapar::ensure!(
            legacy.observable == packed.observable,
            "{model}: packed layout diverged from the legacy observables"
        );
        let tps = |o: &adapar::SimOutcome| -> f64 {
            o.report.chain.tasks_executed as f64 / o.report.time_s.max(1e-12)
        };
        let legacy_bpt = legacy.report.chain.bytes_per_task();
        let packed_bpt = packed.report.chain.bytes_per_task();
        let legacy_tps = tps(&legacy);
        let packed_tps = tps(&packed);
        let tps_ratio = packed_tps / legacy_tps.max(1e-12);
        if packed_bpt >= legacy_bpt {
            soa_bytes_ok = false;
        }
        if tps_ratio < 0.8 {
            soa_tps_ok = false;
        }
        eprintln!(
            "soa      {model:<8} n=4 B=64: bytes/task {legacy_bpt:.2} -> {packed_bpt:.2} \
             ({:.1}x), tasks/s {legacy_tps:>9.0} -> {packed_tps:>9.0} ({:.0}%)",
            legacy_bpt / packed_bpt.max(1e-12),
            tps_ratio * 100.0
        );
        let opt = |v: Option<u64>| v.map_or(Json::Null, Json::from);
        soa_rows.push(Json::Obj(vec![
            ("model".into(), Json::from(model)),
            ("workers".into(), Json::from(4usize)),
            ("agents".into(), Json::from(agents)),
            ("steps".into(), Json::from(steps)),
            ("legacy_bytes_per_task".into(), Json::from(legacy_bpt)),
            ("packed_bytes_per_task".into(), Json::from(packed_bpt)),
            (
                "bytes_reduction".into(),
                Json::from(legacy_bpt / packed_bpt.max(1e-12)),
            ),
            ("legacy_tasks_per_s".into(), Json::from(legacy_tps)),
            ("packed_tasks_per_s".into(), Json::from(packed_tps)),
            ("throughput_ratio".into(), Json::from(tps_ratio)),
            ("legacy_alloc_bytes".into(), opt(legacy_alloc)),
            ("packed_alloc_bytes".into(), opt(packed_alloc)),
        ]));
    }
    let soa_json = Json::Obj(vec![
        ("bench".into(), Json::from("soa")),
        ("layouts".into(), Json::Arr(soa_rows)),
        (
            "acceptance".into(),
            Json::Obj(vec![
                (
                    "packed_bytes_per_task_below_legacy".into(),
                    Json::from(soa_bytes_ok),
                ),
                (
                    "packed_throughput_within_20pct".into(),
                    Json::from(soa_tps_ok),
                ),
                ("pass".into(), Json::from(soa_bytes_ok && soa_tps_ok)),
            ]),
        ),
    ]);
    let soa_path = std::path::Path::new("BENCH_soa.json");
    std::fs::write(soa_path, soa_json.render())?;
    eprintln!("wrote {}", soa_path.display());

    let alloc_pass = bytes_per_task_n1.map(|b| b < 16.0);
    let json = Json::Obj(vec![
        ("bench".into(), Json::from("chain")),
        ("configs".into(), Json::Arr(configs)),
        ("alloc".into(), Json::Arr(alloc_rows)),
        (
            "trace_overhead".into(),
            Json::Obj(vec![
                ("model".into(), Json::from(trace_w.model)),
                ("workers".into(), Json::from(4usize)),
                ("off_tasks_per_s".into(), Json::from(off_tps)),
                ("spans_tasks_per_s".into(), Json::from(spans_tps)),
                ("ratio".into(), Json::from(trace_ratio)),
            ]),
        ),
        ("structural".into(), Json::Arr(structural)),
        (
            "acceptance".into(),
            Json::Obj(vec![
                (
                    "tail_locks_amortized_10x_at_b64".into(),
                    Json::from(amortization_ok),
                ),
                (
                    "steady_state_bytes_per_task_n1_b64".into(),
                    match bytes_per_task_n1 {
                        Some(b) => Json::from(b),
                        None => Json::Null, // bench-alloc feature off
                    },
                ),
                (
                    "trace_spans_within_5pct".into(),
                    Json::from(trace_ok),
                ),
                (
                    "pass".into(),
                    Json::from(amortization_ok && alloc_pass.unwrap_or(true) && trace_ok),
                ),
            ]),
        ),
    ]);
    let path = std::path::Path::new("BENCH_chain.json");
    std::fs::write(path, json.render())?;
    eprintln!("wrote {}", path.display());

    // Lock counts are wall-clock-independent, so the amortization gate
    // is hard even in CI's lenient mode.
    adapar::ensure!(
        amortization_ok,
        "B=64 failed to amortize tail locks 10x over B=1"
    );
    // The allocation gate involves real allocator behaviour; lenient
    // mode records the verdict instead of failing the job.
    if let Some(false) = alloc_pass {
        let lenient = std::env::var("ADAPAR_BENCH_LENIENT").is_ok_and(|v| v == "1");
        adapar::ensure!(
            lenient,
            "execution loop allocated ≥16 B/task at n=1 B=64: {:?}",
            bytes_per_task_n1
        );
        eprintln!("bench_chain: alloc acceptance MISS tolerated (lenient mode)");
    }
    // Trace overhead is likewise wall-clock-bound: lenient mode records
    // the verdict (in the artifact above) instead of failing the job.
    if !trace_ok {
        let lenient = std::env::var("ADAPAR_BENCH_LENIENT").is_ok_and(|v| v == "1");
        adapar::ensure!(
            lenient,
            "spans tracing cost >5% tasks/s on {} ({:.1}% of off)",
            trace_w.model,
            trace_ratio * 100.0
        );
        eprintln!("bench_chain: trace overhead MISS tolerated (lenient mode)");
    }
    // The packed layout must move fewer state bytes per task than
    // legacy on every migrated model. `bytes_per_task` is structural,
    // so this gate is hard even in CI's lenient mode.
    adapar::ensure!(
        soa_bytes_ok,
        "packed layout failed to reduce bytes/task below legacy"
    );
    // Packed throughput is wall-clock-bound: lenient mode records the
    // verdict (in BENCH_soa.json) instead of failing the job.
    if !soa_tps_ok {
        let lenient = std::env::var("ADAPAR_BENCH_LENIENT").is_ok_and(|v| v == "1");
        adapar::ensure!(
            lenient,
            "packed layout lost >20% tasks/s vs legacy on a migrated model"
        );
        eprintln!("bench_chain: soa throughput MISS tolerated (lenient mode)");
    }
    eprintln!("bench_chain: acceptance PASS");
    Ok(())
}
