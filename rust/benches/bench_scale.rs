//! BENCH_scale — streaming task sources + bounded arenas at the ≥1M-agent
//! scale tier (ISSUE 10).
//!
//! Three sections:
//!
//! 1. **Window gate (hard, deterministic)**: single worker, `C = 4096`,
//!    on the allocation-free `IncModel`. Materialized, the chain's
//!    high-water tracks the workload (the worker creates up to `C` per
//!    cycle and drains one); through a 256-task streaming window it must
//!    stay ≤ window + 2 sentinel slots — and strictly below the
//!    materialized run — while the results stay byte-identical. Slot
//!    counts do not depend on wall clocks, so this gate is hard even in
//!    CI's lenient mode.
//! 2. **Scale SIR**: the 2^20-agent contact graph (ring lattice + seeded
//!    long links) through the facade, materialized vs streamed: tasks/s,
//!    arena high-water, structural bytes/task, and — with `bench-alloc` —
//!    the peak live heap from the counting allocator. The throughput
//!    ratio (streamed/materialized ≥ 0.5) is wall-clock-bound and
//!    therefore lenient-gated (`ADAPAR_BENCH_LENIENT=1` reports instead
//!    of failing); the window bound and observable equality stay hard.
//! 3. **Scale Ising**: the 1024² torus, streamed, report-only.
//!
//! Emits `BENCH_scale.json` into the invocation directory (repo root
//! under `cargo bench`), where the CI `BENCH_*.json` artifact glob picks
//! it up.

#[cfg(feature = "bench-alloc")]
#[global_allocator]
static ALLOC: adapar::util::alloc::Counting = adapar::util::alloc::Counting;

use adapar::model::testkit::IncModel;
use adapar::protocol::{ParallelEngine, ProtocolConfig};
use adapar::util::json::Json;
use adapar::{EngineKind, Params, Simulation};

/// Peak live heap over `f`, when the counting allocator is installed.
fn with_peak<T>(f: impl FnOnce() -> T) -> (T, Option<u64>) {
    #[cfg(feature = "bench-alloc")]
    {
        adapar::util::alloc::reset_peak();
        let out = f();
        let base = adapar::util::alloc::live_bytes();
        let peak = adapar::util::alloc::peak_bytes();
        (out, Some(peak.saturating_sub(base.min(peak))))
    }
    #[cfg(not(feature = "bench-alloc"))]
    {
        (f(), None)
    }
}

fn opt(v: Option<u64>) -> Json {
    v.map_or(Json::Null, Json::from)
}

fn main() -> adapar::Result<()> {
    let lenient = std::env::var("ADAPAR_BENCH_LENIENT").is_ok_and(|v| v == "1");
    eprintln!("== BENCH_scale: streaming windows + bounded arenas ==");

    // ---------------------------------------------- 1. window gate (hard)
    const GATE_TASKS: u64 = 50_000;
    const GATE_WINDOW: u64 = 256;
    let gate_run = |window: u64| {
        let m = IncModel::new(GATE_TASKS, 64);
        let rep = ParallelEngine::new(ProtocolConfig {
            workers: 1,
            tasks_per_cycle: 4_096,
            batch: 64,
            seed: 3,
            window,
            ..Default::default()
        })
        .run(&m);
        (rep, m.cells_snapshot())
    };
    let (mat_rep, mat_cells) = gate_run(0);
    let (str_rep, str_cells) = gate_run(GATE_WINDOW);
    adapar::ensure!(
        mat_cells == str_cells && str_rep.totals.executed == GATE_TASKS,
        "streaming changed the results (the window must be semantically inert)"
    );
    let mat_hw = mat_rep.chain.arena_high_water as u64;
    let str_hw = str_rep.chain.arena_high_water as u64;
    let window_bounded = str_hw <= GATE_WINDOW + 2;
    let below_materialized = str_hw < mat_hw;
    eprintln!(
        "window   n=1 C=4096 tasks={GATE_TASKS}: materialized hw={mat_hw}, \
         window={GATE_WINDOW} hw={str_hw} (bound {}){}",
        GATE_WINDOW + 2,
        if window_bounded && below_materialized {
            ""
        } else {
            "  WINDOW MISS"
        }
    );

    // ---------------------------------------------------- 2. scale SIR
    let sir_agents = 1usize << 20;
    let mut sir_params = Params::new();
    sir_params.set("long_links", 4i64);
    let sir_run = |window: u64| {
        with_peak(|| {
            Simulation::builder()
                .model("sir")
                .engine(EngineKind::Parallel)
                .workers(4)
                .tasks_per_cycle(64)
                .batch(16)
                .agents(sir_agents)
                .steps(3)
                .size(1_000)
                .seed(7)
                .window(window)
                .params(sir_params.clone())
                .run()
        })
    };
    let (sir_mat, sir_mat_peak) = sir_run(0);
    let sir_mat = sir_mat?;
    let (sir_str, sir_str_peak) = sir_run(4_096);
    let sir_str = sir_str?;
    adapar::ensure!(
        sir_mat.observable == sir_str.observable,
        "scale SIR: streaming changed the observables"
    );
    let tps = |o: &adapar::SimOutcome| {
        o.report.chain.tasks_executed as f64 / o.report.time_s.max(1e-12)
    };
    let sir_tasks = sir_str.report.chain.tasks_executed;
    let sir_mat_tps = tps(&sir_mat);
    let sir_str_tps = tps(&sir_str);
    let sir_ratio = sir_str_tps / sir_mat_tps.max(1e-12);
    let sir_hw = sir_str.report.chain.arena_high_water;
    let sir_bounded = sir_hw <= 4_096 + 2;
    let throughput_ok = sir_ratio >= 0.5;
    eprintln!(
        "sir      N={sir_agents} n=4 tasks={sir_tasks}: materialized {:.0} tasks/s \
         (hw={}), streamed {:.0} tasks/s (hw={sir_hw}) ratio {:.0}%{}",
        sir_mat_tps,
        sir_mat.report.chain.arena_high_water,
        sir_str_tps,
        sir_ratio * 100.0,
        if throughput_ok { "" } else { "  THROUGHPUT MISS" }
    );
    if let (Some(m), Some(s)) = (sir_mat_peak, sir_str_peak) {
        eprintln!(
            "sir      peak alloc: materialized {:.1} MiB, streamed {:.1} MiB",
            m as f64 / (1024.0 * 1024.0),
            s as f64 / (1024.0 * 1024.0)
        );
    }

    // --------------------------------------------------- 3. scale Ising
    let (ising, ising_peak) = with_peak(|| {
        Simulation::builder()
            .model("ising")
            .engine(EngineKind::Parallel)
            .workers(4)
            .tasks_per_cycle(64)
            .batch(16)
            .agents(1024 * 1024)
            .steps(50_000)
            .size(1)
            .seed(7)
            .window(4_096)
            .run()
    });
    let ising = ising?;
    let ising_tps = tps(&ising);
    let ising_hw = ising.report.chain.arena_high_water;
    eprintln!(
        "ising    1024^2 n=4 tasks={}: {:.0} tasks/s (hw={ising_hw})",
        ising.report.chain.tasks_executed,
        ising_tps
    );

    let run_row = |label: &str, o: &adapar::SimOutcome, window: u64, peak: Option<u64>| {
        Json::Obj(vec![
            ("label".into(), Json::from(label)),
            ("window".into(), Json::from(window)),
            ("tasks".into(), Json::from(o.report.chain.tasks_executed)),
            ("time_s".into(), Json::from(o.report.time_s)),
            ("throughput_tasks_per_s".into(), Json::from(tps(o))),
            (
                "arena_high_water".into(),
                Json::from(o.report.chain.arena_high_water),
            ),
            (
                "arena_capacity".into(),
                Json::from(o.report.chain.arena_capacity),
            ),
            (
                "bytes_per_task".into(),
                Json::from(o.report.chain.bytes_per_task()),
            ),
            ("peak_alloc_bytes".into(), opt(peak)),
        ])
    };

    let pass = window_bounded && sir_bounded && below_materialized && throughput_ok;
    let json = Json::Obj(vec![
        ("bench".into(), Json::from("scale")),
        (
            "window_gate".into(),
            Json::Obj(vec![
                ("tasks".into(), Json::from(GATE_TASKS)),
                ("window".into(), Json::from(GATE_WINDOW)),
                ("materialized_high_water".into(), Json::from(mat_hw)),
                ("streamed_high_water".into(), Json::from(str_hw)),
            ]),
        ),
        (
            "runs".into(),
            Json::Arr(vec![
                run_row("sir_1m_materialized", &sir_mat, 0, sir_mat_peak),
                run_row("sir_1m_streamed", &sir_str, 4_096, sir_str_peak),
                run_row("ising_1024sq_streamed", &ising, 4_096, ising_peak),
            ]),
        ),
        (
            "acceptance".into(),
            Json::Obj(vec![
                (
                    "streamed_high_water_within_window".into(),
                    Json::from(window_bounded && sir_bounded),
                ),
                (
                    "streamed_below_materialized".into(),
                    Json::from(below_materialized),
                ),
                (
                    "streamed_throughput_within_2x".into(),
                    Json::from(throughput_ok),
                ),
                ("pass".into(), Json::from(pass)),
            ]),
        ),
    ]);
    let path = std::path::Path::new("BENCH_scale.json");
    std::fs::write(path, json.render())?;
    eprintln!("wrote {}", path.display());

    // Slot counts are wall-clock-independent: the window bound is a hard
    // gate even in CI's lenient mode.
    adapar::ensure!(
        window_bounded && sir_bounded,
        "streaming arena high-water escaped the window bound \
         (gate {str_hw} vs {}, sir {sir_hw} vs {})",
        GATE_WINDOW + 2,
        4_096 + 2
    );
    adapar::ensure!(
        below_materialized,
        "streamed high-water ({str_hw}) did not drop below materialized ({mat_hw})"
    );
    // Throughput is wall-clock-bound: lenient mode records the verdict
    // (in the artifact above) instead of failing the job.
    if !throughput_ok {
        adapar::ensure!(
            lenient,
            "streaming cost >50% tasks/s on the scale SIR workload \
             ({:.0}% of materialized)",
            sir_ratio * 100.0
        );
        eprintln!("bench_scale: throughput MISS tolerated (lenient mode)");
    }
    eprintln!("bench_scale: acceptance PASS");
    Ok(())
}
