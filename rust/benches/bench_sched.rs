//! BENCH_sched — parallel vs sharded engine throughput on a ring-local
//! workload, 1/2/4/8 workers, uniform vs skewed per-block cost.
//!
//! The workload is a block-ring model built for this comparison: tasks
//! sweep the blocks round-robin, each reading its ring neighbourhood and
//! writing its own block, with tunable per-block busy work. *Uniform*
//! gives every block the same cost; *skewed* makes the first quarter of
//! the ring 8× heavier — the heterogeneous-cost regime the sharded
//! engine's EWMA rebalancer (DESIGN.md §8) is built for: the hot blocks
//! start concentrated in one shard and migrate out at epoch boundaries.
//!
//! Emits `BENCH_sched.json` into the invocation directory (repo root
//! under `cargo bench`), where per-PR perf tracking — and the CI artifact
//! upload — pick `BENCH_*.json` files up.
//!
//! A second section exercises the **lattice workloads** (ISSUE 4): Ising
//! and bounded-relocation Schelling on a 256² torus, sharded with the
//! grid partitioner vs the forced BFS baseline at 1/2/4/8 workers,
//! emitting `BENCH_grid.json`. Its hard acceptance is deterministic —
//! the grid partition's edge cut must not exceed BFS's on any lattice
//! workload — while throughput ratios are report-only.

use std::time::Instant;

use adapar::model::{Model, Record, TaskSource};
use adapar::models::ising::{IsingModel, IsingParams};
use adapar::models::schelling::{SchellingModel, SchellingParams};
use adapar::protocol::{ParallelEngine, ProtocolConfig, SequentialEngine};
use adapar::sched::{PartitionPolicy, ShardableModel, ShardedConfig, ShardedEngine};
use adapar::sim::graph::{bfs_partition, edge_cut, grid_partition, ring_lattice, Csr};
use adapar::sim::rng::TaskRng;
use adapar::sim::state::SharedSim;
use adapar::util::json::Json;
use adapar::util::u32set::U32Set;

/// Ring of `blocks` cells; task t updates block `t % blocks` from its
/// ring neighbourhood, spinning `work[block]` units of busy work.
struct RingBlockModel {
    cells: SharedSim<Vec<u64>>,
    blocks: u32,
    rounds: u64,
    work: Vec<u32>,
}

impl RingBlockModel {
    fn new(blocks: u32, rounds: u64, work: Vec<u32>) -> Self {
        assert_eq!(work.len(), blocks as usize);
        Self {
            cells: SharedSim::new(vec![1; blocks as usize]),
            blocks,
            rounds,
            work,
        }
    }

    fn checksum(&self) -> u64 {
        unsafe { self.cells.get() }
            .iter()
            .fold(0u64, |acc, &c| acc.rotate_left(1).wrapping_add(c))
    }
}

#[derive(Clone, Copy, Debug)]
struct BlockTask {
    block: u32,
}

struct BlockRecord {
    touched: U32Set,
    blocks: u32,
}

impl Record for BlockRecord {
    type Recipe = BlockTask;
    fn depends(&self, r: &BlockTask) -> bool {
        let b = r.block;
        let n = self.blocks;
        self.touched.contains(b)
            || self.touched.contains((b + 1) % n)
            || self.touched.contains((b + n - 1) % n)
    }
    fn absorb(&mut self, r: &BlockTask) {
        let b = r.block;
        let n = self.blocks;
        self.touched.insert(b);
        self.touched.insert((b + 1) % n);
        self.touched.insert((b + n - 1) % n);
    }
    fn reset(&mut self) {
        self.touched.clear();
    }
}

struct BlockSource {
    next: u64,
    total: u64,
    blocks: u64,
}

impl TaskSource for BlockSource {
    type Recipe = BlockTask;
    fn next_task(&mut self) -> Option<BlockTask> {
        if self.next >= self.total {
            return None;
        }
        let block = (self.next % self.blocks) as u32;
        self.next += 1;
        Some(BlockTask { block })
    }
    fn size_hint(&self) -> Option<u64> {
        Some(self.total - self.next)
    }
}

impl Model for RingBlockModel {
    type Recipe = BlockTask;
    type Record = BlockRecord;
    type Source = BlockSource;

    fn source(&self, _seed: u64) -> BlockSource {
        BlockSource {
            next: 0,
            total: self.rounds * self.blocks as u64,
            blocks: self.blocks as u64,
        }
    }

    fn record(&self) -> BlockRecord {
        BlockRecord {
            touched: U32Set::new(),
            blocks: self.blocks,
        }
    }

    fn execute(&self, r: &BlockTask, rng: &mut TaskRng) {
        let b = r.block as usize;
        let n = self.blocks as usize;
        let mut v = rng.below(1 << 20);
        for _ in 0..(self.work[b] * 64) {
            v = v.wrapping_mul(0x9E37_79B9_7F4A_7C15).rotate_left(29) ^ 0xC3A5;
        }
        // SAFETY: record discipline — reads the ±1 neighbourhood, writes
        // only block b; conflicting tasks are ordered by the engines.
        unsafe {
            let cells = self.cells.get_mut();
            let left = cells[(b + n - 1) % n];
            let right = cells[(b + 1) % n];
            cells[b] = cells[b]
                .wrapping_mul(3)
                .wrapping_add(left ^ right)
                .wrapping_add(v);
        }
    }

    fn task_work(&self, r: &BlockTask) -> f64 {
        1.0 + self.work[r.block as usize] as f64
    }

    fn state_bytes_per_task(&self) -> f64 {
        // Each task reads its ±1 ring neighbourhood and writes its own
        // block: three u64 cells.
        3.0 * 8.0
    }
}

impl ShardableModel for RingBlockModel {
    fn sched_topology(&self) -> Csr {
        ring_lattice(self.blocks as usize, 2)
    }
    fn footprint(&self, r: &BlockTask, out: &mut Vec<u32>) {
        let (b, n) = (r.block, self.blocks);
        out.push(b);
        out.push((b + 1) % n);
        out.push((b + n - 1) % n);
    }
}

const BLOCKS: u32 = 96;
const ROUNDS: u64 = 250;
const SAMPLES: usize = 3;

fn workload(skewed: bool) -> Vec<u32> {
    (0..BLOCKS)
        .map(|b| if skewed && b < BLOCKS / 4 { 8 } else { 1 })
        .collect()
}

/// Best-of-`SAMPLES` wall time for one engine/worker/workload config;
/// also checks byte-identity against the sequential reference.
fn measure(engine: &str, workers: usize, skewed: bool, reference: u64) -> f64 {
    let seed = 42;
    let mut best = f64::INFINITY;
    for _ in 0..SAMPLES {
        let model = RingBlockModel::new(BLOCKS, ROUNDS, workload(skewed));
        let t0 = Instant::now();
        match engine {
            "parallel" => {
                ParallelEngine::new(ProtocolConfig {
                    workers,
                    seed,
                    ..Default::default()
                })
                .run(&model);
            }
            "sharded" => {
                ShardedEngine::new(ShardedConfig {
                    workers,
                    seed,
                    rebalance_every: 2_048,
                    ..Default::default()
                })
                .run(&model);
            }
            other => unreachable!("unknown engine {other}"),
        }
        best = best.min(t0.elapsed().as_secs_f64());
        assert_eq!(
            model.checksum(),
            reference,
            "{engine} n={workers} skewed={skewed} diverged from sequential"
        );
    }
    best
}

// ---------------------------------------------------------------------------
// BENCH_grid: lattice workloads, grid vs BFS partition (ISSUE 4)
// ---------------------------------------------------------------------------

/// Lattice side for the grid bench (n = side² ≥ 256² footprint blocks).
const GRID_SIDE: usize = 256;
const GRID_SEED: u64 = 71;
const GRID_SAMPLES: usize = 2;

/// One lattice workload through the sharded engine: sequential
/// reference checksum, per-shard-count cut comparison (hard acceptance:
/// grid ≤ BFS), and timed grid-vs-BFS sharded runs at 1/2/4/8 workers.
/// Returns `(workload json, cuts all ok)`.
fn grid_workload<M, B, S>(name: &str, tasks: u64, build: B, checksum: S) -> (Json, bool)
where
    M: ShardableModel,
    B: Fn() -> M,
    S: Fn(&M) -> u64,
{
    let reference = {
        let model = build();
        SequentialEngine::new(GRID_SEED).run(&model);
        checksum(&model)
    };

    let topology = build().sched_topology();
    let mut cuts = Vec::new();
    let mut cuts_ok = true;
    for shards in [1usize, 2, 4, 8] {
        let grid = edge_cut(&topology, &grid_partition(GRID_SIDE, GRID_SIDE, shards));
        let bfs = edge_cut(&topology, &bfs_partition(&topology, shards));
        let ok = grid <= bfs;
        cuts_ok &= ok;
        eprintln!("{name:<10} shards={shards}: edge cut grid={grid} bfs={bfs}");
        cuts.push(Json::Obj(vec![
            ("shards".into(), Json::from(shards)),
            ("grid".into(), Json::from(grid)),
            ("bfs".into(), Json::from(bfs)),
            ("ok".into(), Json::from(ok)),
        ]));
    }

    let mut runs = Vec::new();
    let mut grid_tp_n4 = 0.0f64;
    let mut bfs_tp_n4 = 0.0f64;
    for workers in [1usize, 2, 4, 8] {
        for (policy, label) in [
            (PartitionPolicy::Auto, "grid"),
            (PartitionPolicy::ForceGeneral, "bfs"),
        ] {
            let mut best = f64::INFINITY;
            for _ in 0..GRID_SAMPLES {
                let model = build();
                let t0 = Instant::now();
                let report = ShardedEngine::new(ShardedConfig {
                    workers,
                    seed: GRID_SEED,
                    partition: policy,
                    ..Default::default()
                })
                .run(&model);
                best = best.min(t0.elapsed().as_secs_f64());
                assert_eq!(
                    checksum(&model),
                    reference,
                    "{name} {label} n={workers} diverged from sequential"
                );
                let sched = report.sched.expect("sharded runs report telemetry");
                assert_eq!(sched.partition, label, "policy must reach the partitioner");
            }
            let throughput = tasks as f64 / best;
            eprintln!(
                "{name:<10} partition={label:<4} n={workers}: {best:.4}s  \
                 ({throughput:.0} tasks/s)"
            );
            if workers == 4 {
                if label == "grid" {
                    grid_tp_n4 = throughput;
                } else {
                    bfs_tp_n4 = throughput;
                }
            }
            runs.push(Json::Obj(vec![
                ("partition".into(), Json::from(label)),
                ("workers".into(), Json::from(workers)),
                ("tasks".into(), Json::from(tasks)),
                ("time_s".into(), Json::from(best)),
                ("throughput_tasks_per_s".into(), Json::from(throughput)),
            ]));
        }
    }
    let speedup = grid_tp_n4 / bfs_tp_n4;
    eprintln!("{name:<10} grid/bfs throughput at n=4 = {speedup:.2}x (report-only)");
    (
        Json::Obj(vec![
            ("model".into(), Json::from(name)),
            ("side".into(), Json::from(GRID_SIDE)),
            ("blocks".into(), Json::from(GRID_SIDE * GRID_SIDE)),
            ("cuts".into(), Json::Arr(cuts)),
            ("runs".into(), Json::Arr(runs)),
            ("grid_over_bfs_throughput_n4".into(), Json::from(speedup)),
        ]),
        cuts_ok,
    )
}

fn bench_grid() -> adapar::Result<()> {
    eprintln!("== BENCH_grid: lattice workloads at {GRID_SIDE}², grid vs BFS partition ==");
    let ising_tasks = 150_000u64;
    let (ising_json, ising_ok) = grid_workload(
        "ising",
        ising_tasks,
        || {
            IsingModel::new(
                IsingParams {
                    side: GRID_SIDE,
                    temperature: 2.269,
                    steps: ising_tasks,
                },
                9,
            )
        },
        |m| {
            m.snapshot()
                .iter()
                .fold(0u64, |acc, &s| acc.rotate_left(1).wrapping_add(s as u8 as u64))
        },
    );
    let schelling_tasks = 120_000u64;
    let (schelling_json, schelling_ok) = grid_workload(
        "schelling",
        schelling_tasks,
        || {
            SchellingModel::new(
                SchellingParams {
                    side: GRID_SIDE,
                    agents: 51_000, // ~78% occupancy
                    tolerance: 0.4,
                    steps: schelling_tasks,
                    move_radius: 2,
                },
                9,
            )
        },
        |m| {
            m.snapshot()
                .iter()
                .fold(0u64, |acc, &c| acc.rotate_left(1).wrapping_add(c as u64))
        },
    );

    let pass = ising_ok && schelling_ok;
    let json = Json::Obj(vec![
        ("bench".into(), Json::from("grid")),
        (
            "workloads".into(),
            Json::Arr(vec![ising_json, schelling_json]),
        ),
        (
            "acceptance".into(),
            Json::Obj(vec![
                ("grid_cut_le_bfs_everywhere".into(), Json::from(pass)),
                ("pass".into(), Json::from(pass)),
            ]),
        ),
    ]);
    let path = std::path::Path::new("BENCH_grid.json");
    std::fs::write(path, json.render())?;
    eprintln!("wrote {}", path.display());
    // The cut comparison is deterministic (no wall clocks involved), so
    // it is a hard gate even in CI's lenient mode.
    adapar::ensure!(
        pass,
        "grid partition lost the edge-cut comparison on a lattice workload"
    );
    eprintln!("bench_grid: acceptance PASS");
    Ok(())
}

fn main() -> adapar::Result<()> {
    let tasks = ROUNDS * BLOCKS as u64;
    eprintln!("== BENCH_sched: parallel vs sharded, {tasks} tasks/run ==");

    let mut configs = Vec::new();
    let mut sharded_tp_skew4 = 0.0f64;
    let mut parallel_tp_skew4 = 0.0f64;
    for skewed in [false, true] {
        let reference = {
            let model = RingBlockModel::new(BLOCKS, ROUNDS, workload(skewed));
            SequentialEngine::new(42).run(&model);
            model.checksum()
        };
        for workers in [1usize, 2, 4, 8] {
            for engine in ["parallel", "sharded"] {
                let time_s = measure(engine, workers, skewed, reference);
                let throughput = tasks as f64 / time_s;
                eprintln!(
                    "{:<9} workload={:<7} n={workers}: {:.4}s  ({:.0} tasks/s)",
                    engine,
                    if skewed { "skewed" } else { "uniform" },
                    time_s,
                    throughput
                );
                if workers == 4 && skewed {
                    if engine == "sharded" {
                        sharded_tp_skew4 = throughput;
                    } else {
                        parallel_tp_skew4 = throughput;
                    }
                }
                configs.push(Json::Obj(vec![
                    (
                        "workload".into(),
                        Json::from(if skewed { "skewed" } else { "uniform" }),
                    ),
                    ("engine".into(), Json::from(engine)),
                    ("workers".into(), Json::from(workers)),
                    ("tasks".into(), Json::from(tasks)),
                    ("time_s".into(), Json::from(time_s)),
                    ("throughput_tasks_per_s".into(), Json::from(throughput)),
                ]));
            }
        }
    }

    // Structural pass: one single-worker sharded run per workload. With
    // n=1 every counter is deterministic (no thread interleaving), so
    // these rows are comparable run-over-run without any wall clock —
    // the same discipline the perf ledger gates on.
    let mut structural = Vec::new();
    for skewed in [false, true] {
        let model = RingBlockModel::new(BLOCKS, ROUNDS, workload(skewed));
        let report = ShardedEngine::new(ShardedConfig {
            workers: 1,
            seed: 42,
            rebalance_every: 2_048,
            ..Default::default()
        })
        .run(&model);
        let sched = report.sched.expect("sharded runs report telemetry");
        eprintln!(
            "structural workload={:<7}: local={} boundary={} edge_cut={} migrations={} \
             tail_locks={} arena_high_water={} bytes/task={:.1}",
            if skewed { "skewed" } else { "uniform" },
            sched.local_tasks,
            sched.boundary_tasks,
            sched.edge_cut,
            sched.migrations,
            report.chain.tail_locks,
            report.chain.arena_high_water,
            report.chain.bytes_per_task()
        );
        structural.push(Json::Obj(vec![
            (
                "workload".into(),
                Json::from(if skewed { "skewed" } else { "uniform" }),
            ),
            ("tasks_executed".into(), Json::from(report.chain.tasks_executed)),
            ("local_tasks".into(), Json::from(sched.local_tasks)),
            ("boundary_tasks".into(), Json::from(sched.boundary_tasks)),
            ("edge_cut".into(), Json::from(sched.edge_cut)),
            ("migrations".into(), Json::from(sched.migrations)),
            ("rebalances".into(), Json::from(sched.rebalances)),
            ("tail_locks".into(), Json::from(report.chain.tail_locks)),
            (
                "arena_high_water".into(),
                Json::from(report.chain.arena_high_water),
            ),
            ("arena_occupancy".into(), Json::from(sched.arena_occupancy)),
            (
                "bytes_per_task".into(),
                Json::from(report.chain.bytes_per_task()),
            ),
        ]));
    }

    let ratio = sharded_tp_skew4 / parallel_tp_skew4;
    let json = Json::Obj(vec![
        ("bench".into(), Json::from("sched")),
        ("blocks".into(), Json::from(BLOCKS)),
        ("rounds".into(), Json::from(ROUNDS)),
        ("configs".into(), Json::Arr(configs)),
        ("structural".into(), Json::Arr(structural)),
        (
            "acceptance".into(),
            Json::Obj(vec![
                (
                    "sharded_over_parallel_skewed_n4".into(),
                    Json::from(ratio),
                ),
                ("pass".into(), Json::from(ratio >= 0.95)),
            ]),
        ),
    ]);
    let path = std::path::Path::new("BENCH_sched.json");
    std::fs::write(path, json.render())?;
    eprintln!("wrote {}", path.display());

    // Acceptance: sharded ≥ parallel throughput on the skewed workload
    // at 4 workers, with a 5% jitter allowance. A wall-clock comparison
    // is not a reliable CI gate on shared runners, so
    // `ADAPAR_BENCH_LENIENT=1` (set by the CI bench job) downgrades a
    // miss to a report-only warning — the verdict is still recorded in
    // BENCH_sched.json either way.
    eprintln!(
        "skewed n=4: sharded/parallel throughput = {ratio:.2}x {}",
        if ratio >= 1.0 { "(PASS)" } else { "" }
    );
    if ratio < 0.95 {
        let lenient = std::env::var("ADAPAR_BENCH_LENIENT").is_ok_and(|v| v == "1");
        adapar::ensure!(
            lenient,
            "sharded engine fell behind parallel on the skewed workload: {ratio:.2}x"
        );
        eprintln!("bench_sched: acceptance MISS ({ratio:.2}x) tolerated (lenient mode)");
    } else {
        eprintln!("bench_sched: acceptance PASS");
    }

    bench_grid()
}
