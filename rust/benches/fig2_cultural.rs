//! FIG2 — regenerates the paper's Figure 2: cultural dynamics, simulation
//! time T vs task-size proxy F (number of features), one curve per worker
//! count n ∈ {1..5}, averaged over seeds with SEM error bars.
//!
//! Two series are produced:
//!   * `virtual` — the multi-core testbed (the figure's actual content;
//!     this host has one core, see DESIGN.md §2);
//!   * `native n=1` — real single-worker protocol wall-clock, which checks
//!     the *overhead* aspect visible on any host: T grows with F and the
//!     per-task protocol cost amortizes.
//!
//! `ADAPAR_PAPER_SCALE=1 cargo bench --bench fig2_cultural` runs the
//! paper's full N=10⁴ / 2×10⁶-step workload (hours); the default is a
//! faithfully-shaped scaled workload.

use adapar::coordinator::config::{EngineKind, SweepConfig};
use adapar::coordinator::report::{figure_pivot, long_table, write_bench_json, write_report};
use adapar::coordinator::run_sweep;
use adapar::models::axelrod::{AxelrodModel, AxelrodParams};
use adapar::protocol::{ParallelEngine, ProtocolConfig};
use adapar::util::bench::{Bench, fmt_secs};

fn paper_scale() -> bool {
    std::env::var("ADAPAR_PAPER_SCALE").is_ok_and(|v| v == "1")
}

fn main() -> adapar::Result<()> {
    let paper = paper_scale();
    let cfg = SweepConfig {
        model: "axelrod".to_string(),
        engine: EngineKind::Virtual,
        sizes: vec![25, 50, 100, 200, 400, 800],
        workers: vec![1, 2, 3, 4, 5],
        seeds: if paper { vec![1, 2, 3, 4, 5] } else { vec![1, 2, 3] },
        agents: if paper { 10_000 } else { 1_000 },
        steps: if paper { 2_000_000 } else { 30_000 },
        paper_scale: paper,
        calibrate: true,
        ..Default::default()
    };

    eprintln!("== FIG2 virtual-testbed series (T vs F, n=1..5) ==");
    let res = run_sweep(&cfg)?;
    println!("{}", figure_pivot(&res).to_markdown());
    write_report(&res, std::path::Path::new("target/bench-data"), "fig2_virtual")?;

    // Acceptance criteria from DESIGN.md §9.
    let mut ok = true;
    for &f in &cfg.sizes {
        let t1 = res.point(f, 1).unwrap().mean_s;
        let t4 = res.point(f, 4).unwrap().mean_s;
        eprintln!("F={f:>4}: T(1)={} T(4)={} speedup={:.2}x", fmt_secs(t1), fmt_secs(t4), t1 / t4);
    }
    let grow = res.speedup(800, 4).unwrap() > res.speedup(25, 4).unwrap();
    eprintln!("speedup grows with F: {}", if grow { "PASS" } else { "FAIL" });
    ok &= grow;
    let t_monotone = res.point(25, 1).unwrap().mean_s < res.point(800, 1).unwrap().mean_s;
    eprintln!("T increases with F: {}", if t_monotone { "PASS" } else { "FAIL" });
    ok &= t_monotone;

    // Native single-worker wall-clock: the overhead amortization aspect.
    eprintln!("\n== FIG2 native n=1 wall-clock (overhead aspect) ==");
    let mut bench = Bench::new("fig2_native_n1");
    for &f in &[25usize, 100, 400] {
        let steps = if paper { 200_000 } else { 30_000 };
        let agents = if paper { 10_000 } else { 1_000 };
        let mut seed = 0u64;
        bench.measure(&format!("axelrod F={f} native n=1"), Default::default(), || {
            seed += 1;
            let m = AxelrodModel::new(
                AxelrodParams { agents, features: f, traits: 3, omega: 0.95, steps },
                seed,
            );
            ParallelEngine::new(ProtocolConfig {
                workers: 1,
                tasks_per_cycle: 6,
                seed,
                ..Default::default()
            })
            .run(&m)
        });
    }
    bench.write_csv()?;
    let _ = long_table(&res);
    // Perf-trajectory artifact: the full grid as JSON. Deliberately
    // written to the invocation directory (repo root under `cargo
    // bench`), where per-PR tracking tooling picks BENCH_*.json up; the
    // CLI sweep writes its copy under --out instead.
    let bench_json = write_bench_json(&res, std::path::Path::new("BENCH_fig2.json"))?;
    eprintln!("wrote {}", bench_json.display());
    adapar::ensure!(ok, "FIG2 acceptance criteria failed");
    eprintln!("fig2_cultural: all acceptance criteria PASS");
    Ok(())
}
