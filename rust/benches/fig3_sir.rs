//! FIG3 — regenerates the paper's Figure 3: disease spreading, simulation
//! time T vs task-size proxy s (agents per subset), one curve per worker
//! count n ∈ {1..5}.
//!
//! Expected shape (paper §4.2 / DESIGN.md §9): sharp T decrease with s at
//! small s (protocol overhead per agent ∝ 1/s), then stabilization; in the
//! plateau T decreases with n, saturating around n = 4; at very small s
//! extra workers may *hurt*.

use adapar::coordinator::config::{EngineKind, SweepConfig};
use adapar::coordinator::report::{figure_pivot, write_bench_json, write_report};
use adapar::coordinator::run_sweep;
use adapar::util::bench::fmt_secs;

fn paper_scale() -> bool {
    std::env::var("ADAPAR_PAPER_SCALE").is_ok_and(|v| v == "1")
}

fn main() -> adapar::Result<()> {
    let paper = paper_scale();
    let cfg = SweepConfig {
        model: "sir".to_string(),
        engine: EngineKind::Virtual,
        sizes: vec![10, 20, 50, 100, 200, 500, 1000],
        workers: vec![1, 2, 3, 4, 5],
        seeds: if paper { vec![1, 2, 3, 4, 5] } else { vec![1, 2, 3] },
        agents: 4_000,
        steps: if paper { 3_000 } else { 150 },
        paper_scale: paper,
        calibrate: true,
        ..Default::default()
    };

    eprintln!("== FIG3 virtual-testbed series (T vs s, n=1..5) ==");
    let res = run_sweep(&cfg)?;
    println!("{}", figure_pivot(&res).to_markdown());
    write_report(&res, std::path::Path::new("target/bench-data"), "fig3_virtual")?;

    for &s in &cfg.sizes {
        let t1 = res.point(s, 1).unwrap().mean_s;
        let t4 = res.point(s, 4).unwrap().mean_s;
        let ov = res.point(s, 4).unwrap().overhead;
        eprintln!(
            "s={s:>5}: T(1)={} T(4)={} speedup={:.2}x overhead={:.0}%",
            fmt_secs(t1),
            fmt_secs(t4),
            t1 / t4,
            ov * 100.0
        );
    }

    // Acceptance criteria (DESIGN.md §9).
    let mut ok = true;
    let fine = res.point(10, 3).unwrap().mean_s;
    let plateau = res.point(200, 3).unwrap().mean_s;
    let wall = fine > plateau * 1.3;
    eprintln!("fine-granularity wall (s=10 ≫ s=200 at n=3): {}", if wall { "PASS" } else { "FAIL" });
    ok &= wall;
    let plateau_speedup = res.speedup(200, 4).unwrap();
    let helps = plateau_speedup > 1.4;
    eprintln!("plateau parallelism T(1)/T(4)={plateau_speedup:.2}x > 1.4: {}", if helps { "PASS" } else { "FAIL" });
    ok &= helps;
    // At tiny s extra workers gain little (or hurt): speedup(10, 5) should
    // be well below speedup(200, 5).
    let tiny = res.speedup(10, 5).unwrap();
    let plateau5 = res.speedup(200, 5).unwrap();
    let saturates = tiny < plateau5;
    eprintln!(
        "small-s saturation (T(1)/T(5): {tiny:.2}x @s=10 < {plateau5:.2}x @s=200): {}",
        if saturates { "PASS" } else { "FAIL" }
    );
    ok &= saturates;

    // Perf-trajectory artifact: the full grid as JSON. Deliberately
    // written to the invocation directory (repo root under `cargo
    // bench`), where per-PR tracking tooling picks BENCH_*.json up; the
    // CLI sweep writes its copy under --out instead.
    let bench_json = write_bench_json(&res, std::path::Path::new("BENCH_fig3.json"))?;
    eprintln!("wrote {}", bench_json.display());

    adapar::ensure!(ok, "FIG3 acceptance criteria failed");
    eprintln!("fig3_sir: all acceptance criteria PASS");
    Ok(())
}
