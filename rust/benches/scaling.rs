//! SCALE — §1's premise: "scaling is at least linear in system size". T/N
//! should be roughly flat for SIR (work ∝ N per step) and T/steps flat for
//! Axelrod (work per interaction independent of N), measured natively
//! (sequential) and on the virtual testbed at n = 4.

use adapar::coordinator::config::{EngineKind, SweepConfig};
use adapar::coordinator::run_once;
use adapar::util::csv::Table;
use adapar::vtime::CostModel;

fn main() -> adapar::Result<()> {
    let cost = CostModel::default();
    let mut table = Table::new(["model", "N", "engine", "T_s", "T_per_agent_us"]);

    for n_agents in [1_000usize, 2_000, 4_000, 8_000] {
        for engine in [EngineKind::Sequential, EngineKind::Virtual] {
            let cfg = SweepConfig {
                model: "sir".to_string(),
                engine,
                sizes: vec![100],
                workers: vec![4],
                seeds: vec![1],
                agents: n_agents,
                steps: 100,
                ..Default::default()
            };
            let t = run_once(&cfg, 100, 4, 1, &cost)?.time_s;
            table.push([
                "sir".into(),
                n_agents.to_string(),
                engine.to_string(),
                format!("{t:.6}"),
                format!("{:.3}", t / n_agents as f64 * 1e6),
            ]);
        }
    }

    for n_agents in [500usize, 1_000, 2_000, 4_000] {
        let cfg = SweepConfig {
            model: "axelrod".to_string(),
            engine: EngineKind::Sequential,
            sizes: vec![100],
            workers: vec![1],
            seeds: vec![1],
            agents: n_agents,
            steps: 30_000,
            ..Default::default()
        };
        let t = run_once(&cfg, 100, 1, 1, &cost)?.time_s;
        table.push([
            "axelrod".into(),
            n_agents.to_string(),
            "sequential".into(),
            format!("{t:.6}"),
            format!("{:.3}", t / 30_000.0 * 1e6), // per step, not per agent
        ]);
    }

    println!("{}", table.to_markdown());
    table.write_csv("target/bench-data/scaling.csv")?;
    eprintln!("scaling: done (expect ~flat per-agent/per-step columns)");
    Ok(())
}
