//! XLA — the three-layer integration cost anatomy: per-task PJRT dispatch
//! vs native task bodies, and batch amortization (b=1 vs b=32 Axelrod
//! artifacts). Artifact-gated: prints a skip notice without
//! `make artifacts`.

use std::time::Instant;

use adapar::models::sir::{SirModel, SirParams};
use adapar::protocol::SequentialEngine;
use adapar::runtime::xla_engine::{XlaAxelrodInteractor, XlaSirModel};
use adapar::runtime::{Manifest, XlaRuntime};
use adapar::runtime::exec::{lit_f64, lit_i32_2d};
use adapar::util::csv::Table;

fn main() -> adapar::Result<()> {
    let dir = Manifest::default_dir();
    let Ok(manifest) = Manifest::load(&dir) else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return Ok(());
    };
    let rt = XlaRuntime::cpu()?;
    let mut table = Table::new(["path", "what", "per_unit_us"]);

    // --- SIR: native vs XLA-dispatched compute tasks ---------------------
    let params = SirParams::scaled(30, 300, 40);
    let seed = 2;
    let native = SirModel::new(params, 1);
    let t0 = Instant::now();
    SequentialEngine::new(seed).run(&native);
    let t_native = t0.elapsed().as_secs_f64();
    let n_tasks = (params.steps * 2 * (params.agents / params.subset_size) as u64) as f64;

    let xla = XlaSirModel::from_manifest(&rt, &manifest, SirModel::new(params, 1))?;
    let t0 = Instant::now();
    SequentialEngine::new(seed).run(&xla);
    let t_xla = t0.elapsed().as_secs_f64();
    assert_eq!(native.snapshot(), xla.snapshot());

    table.push([
        "native".into(),
        "sir task".into(),
        format!("{:.3}", t_native / n_tasks * 1e6),
    ]);
    table.push([
        "pjrt per-task".into(),
        "sir task".into(),
        format!("{:.3}", t_xla / n_tasks * 1e6),
    ]);
    eprintln!(
        "sir: native {:.3}s vs per-task PJRT {:.3}s => dispatch multiplier {:.0}x",
        t_native,
        t_xla,
        t_xla / t_native.max(1e-12)
    );

    // --- Axelrod: single-pair vs batched artifact amortization -----------
    let single = XlaAxelrodInteractor::from_manifest(&rt, &manifest)?;
    let f = single.features();
    let src = vec![1i32; f];
    let mut tgt = vec![1i32; f];
    tgt[0] = 2;
    let reps = 300;
    let t0 = Instant::now();
    for i in 0..reps {
        let u = i as f64 / reps as f64;
        std::hint::black_box(single.interact(&src, &tgt, u, u)?);
    }
    let per_single = t0.elapsed().as_secs_f64() / reps as f64;
    table.push([
        "pjrt b=1".into(),
        "axelrod interaction".into(),
        format!("{:.3}", per_single * 1e6),
    ]);

    if let Some(entry) = manifest
        .entries()
        .iter()
        .find(|e| e.kind() == "axelrod" && e.get("b") == Some("32"))
    {
        let exe = rt.load_hlo_text(&entry.path)?;
        let b = 32usize;
        let srcs = vec![1i32; b * f];
        let mut tgts = vec![1i32; b * f];
        for row in 0..b {
            tgts[row * f] = 2;
        }
        let u: Vec<f64> = (0..b).map(|i| i as f64 / b as f64).collect();
        let t0 = Instant::now();
        for _ in 0..reps {
            std::hint::black_box(exe.call1(&[
                lit_i32_2d(&srcs, b, f)?,
                lit_i32_2d(&tgts, b, f)?,
                lit_f64(&u),
                lit_f64(&u),
            ])?);
        }
        let per_batched = t0.elapsed().as_secs_f64() / (reps * b) as f64;
        table.push([
            "pjrt b=32".into(),
            "axelrod interaction".into(),
            format!("{:.3}", per_batched * 1e6),
        ]);
        eprintln!(
            "axelrod: batching 32 interactions per dispatch amortizes {:.1}x",
            per_single / per_batched.max(1e-12)
        );
    }

    println!("{}", table.to_markdown());
    table.write_csv("target/bench-data/xla_dispatch.csv")?;
    eprintln!("xla_dispatch: done");
    Ok(())
}
