//! The object-safe [`Engine`] abstraction over execution backends.
//!
//! Every backend — the paper's adaptive [`ParallelEngine`], the
//! [`SequentialEngine`] ground truth, the related-work [`StepwiseEngine`]
//! baseline, the [`VirtualEngine`] testbed and the sharded adaptive
//! [`ShardedEngine`] — implements `Engine` and returns the *same*
//! [`RunReport`], so launcher code (facade, sweeps, CLI) dispatches
//! through one `&dyn Engine` and never matches on the backend.

use std::str::FromStr;

use crate::api::model::DynModel;
use crate::api::observe::Observer;
use crate::error::{Error, Result};
use crate::protocol::{
    ParallelEngine, ProtocolConfig, RunReport, SequentialEngine, StepwiseEngine,
};
use crate::sched::{ShardedConfig, ShardedEngine};
use crate::telemetry::TelemetryMode;
use crate::trace::TraceMode;
use crate::vtime::{CostModel, VirtualEngine};

/// An execution backend able to run any [`DynModel`].
pub trait Engine: Send + Sync {
    /// Engine label (`"parallel"`, `"sequential"`, `"stepwise"`,
    /// `"virtual"`, `"sharded"`).
    fn name(&self) -> &'static str;

    /// Run the model to completion. With an [`Observer`], the engine
    /// records epoch snapshots at quiescent points (the deterministic
    /// trace contract of `api::observe`); with `None` it runs the
    /// unmodified hot path.
    fn run_observed(
        &self,
        model: &dyn DynModel,
        obs: Option<&mut Observer>,
    ) -> Result<RunReport>;

    /// Run the model to completion without observation.
    fn run(&self, model: &dyn DynModel) -> Result<RunReport> {
        self.run_observed(model, None)
    }
}

impl Engine for SequentialEngine {
    fn name(&self) -> &'static str {
        "sequential"
    }

    fn run_observed(
        &self,
        model: &dyn DynModel,
        obs: Option<&mut Observer>,
    ) -> Result<RunReport> {
        Ok(model.run_sequential(self.seed, self.trace, obs))
    }
}

impl Engine for ParallelEngine {
    fn name(&self) -> &'static str {
        "parallel"
    }

    fn run_observed(
        &self,
        model: &dyn DynModel,
        obs: Option<&mut Observer>,
    ) -> Result<RunReport> {
        Ok(model.run_parallel(self.config(), obs))
    }
}

impl Engine for StepwiseEngine {
    fn name(&self) -> &'static str {
        "stepwise"
    }

    fn run_observed(
        &self,
        model: &dyn DynModel,
        obs: Option<&mut Observer>,
    ) -> Result<RunReport> {
        model.run_stepwise(self.workers, self.seed, self.trace, obs)
    }
}

impl Engine for ShardedEngine {
    fn name(&self) -> &'static str {
        "sharded"
    }

    fn run_observed(
        &self,
        model: &dyn DynModel,
        obs: Option<&mut Observer>,
    ) -> Result<RunReport> {
        model.run_sharded(self.config(), obs)
    }
}

impl Engine for VirtualEngine {
    fn name(&self) -> &'static str {
        "virtual"
    }

    fn run_observed(
        &self,
        model: &dyn DynModel,
        obs: Option<&mut Observer>,
    ) -> Result<RunReport> {
        let cfg = ProtocolConfig {
            workers: self.workers,
            tasks_per_cycle: self.tasks_per_cycle,
            batch: 1, // the DES models unbatched creation
            seed: self.seed,
            trace: self.trace,
            window: self.window,
            ..Default::default()
        };
        Ok(model.run_virtual(&cfg, &self.cost, obs))
    }
}

/// Which execution engine (the config/CLI-facing selector).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// The paper's adaptive protocol on real threads.
    Parallel,
    /// Canonical single-threaded execution.
    Sequential,
    /// The virtual-core testbed (reproduces multi-core figures on a
    /// single-core host).
    Virtual,
    /// The barrier-based step-parallel baseline (synchronous models only).
    Stepwise,
    /// The sharded adaptive scheduler: per-shard chains + spillover +
    /// epoch-boundary rebalancing (shardable models only).
    Sharded,
}

impl EngineKind {
    /// Every selectable engine.
    pub const ALL: [EngineKind; 5] = [
        EngineKind::Parallel,
        EngineKind::Sequential,
        EngineKind::Virtual,
        EngineKind::Stepwise,
        EngineKind::Sharded,
    ];

    /// Canonical engine name — the single source every listing prints,
    /// `Display` renders and [`FromStr`] accepts.
    pub fn name(self) -> &'static str {
        match self {
            EngineKind::Parallel => "parallel",
            EngineKind::Sequential => "sequential",
            EngineKind::Virtual => "virtual",
            EngineKind::Stepwise => "stepwise",
            EngineKind::Sharded => "sharded",
        }
    }

    /// Canonical names, for error listings.
    pub fn names() -> String {
        Self::ALL
            .iter()
            .map(|k| k.name())
            .collect::<Vec<_>>()
            .join("|")
    }
}

impl FromStr for EngineKind {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "parallel" | "protocol" => EngineKind::Parallel,
            "sequential" | "seq" => EngineKind::Sequential,
            "virtual" | "vtime" => EngineKind::Virtual,
            "stepwise" | "barrier" => EngineKind::Stepwise,
            "sharded" | "shards" => EngineKind::Sharded,
            other => {
                return Err(crate::err!(
                    "unknown engine `{other}`; valid engines: {}",
                    EngineKind::names()
                ))
            }
        })
    }
}

impl std::fmt::Display for EngineKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Build a boxed engine for a kind and workflow parameters. `batch` is
/// the chain engines' creation/routing batch size `B`; `window` the
/// streaming materialization window `W` (`0` = fully materialized;
/// DESIGN.md §14 — honoured by every chain-based engine, ignored by the
/// chainless baselines); `cost` is only consulted by the virtual
/// testbed; `telemetry` selects the (inert) histogram-sampling mode on
/// the threaded engines; `trace` the equally inert causal-tracing mode
/// (every engine honours it).
#[allow(clippy::too_many_arguments)]
pub fn engine_for(
    kind: EngineKind,
    workers: usize,
    tasks_per_cycle: u32,
    batch: u32,
    window: u64,
    seed: u64,
    cost: CostModel,
    telemetry: TelemetryMode,
    trace: TraceMode,
) -> Box<dyn Engine> {
    match kind {
        EngineKind::Sequential => Box::new(SequentialEngine { seed, trace }),
        EngineKind::Parallel => Box::new(ParallelEngine::new(ProtocolConfig {
            workers,
            tasks_per_cycle,
            batch,
            window,
            seed,
            collect_timing: false,
            telemetry,
            trace,
        })),
        EngineKind::Stepwise => {
            let mut e = StepwiseEngine::new(workers, seed);
            e.trace = trace;
            Box::new(e)
        }
        EngineKind::Sharded => Box::new(ShardedEngine::new(ShardedConfig {
            workers,
            tasks_per_cycle,
            batch,
            window,
            seed,
            telemetry,
            trace,
            ..Default::default()
        })),
        EngineKind::Virtual => Box::new(VirtualEngine {
            workers,
            tasks_per_cycle,
            seed,
            cost,
            trace,
            window,
        }),
    }
}
