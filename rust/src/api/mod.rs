//! The public execution API: engine abstraction, model registry, and the
//! [`Simulation`] facade.
//!
//! The paper's protocol is model-agnostic by design (§3.5's recipe/record
//! interface); this module makes the *launcher* side equally agnostic:
//!
//! * [`engine`] — the object-safe [`Engine`] trait implemented by every
//!   backend (parallel, sequential, stepwise, virtual, sharded), all
//!   returning the unified [`crate::protocol::RunReport`].
//! * [`model`] — [`DynModel`], the type-erased runnable model, and
//!   [`Runnable`], the adapter that erases any [`crate::model::Model`].
//! * [`observe`] — the typed observation pipeline: [`ObsValue`] metrics,
//!   the [`Observable`] model trait, the [`Observer`]/[`Sink`] recorder,
//!   and deterministic epoch snapshots across every engine.
//! * [`registry`] — the dynamic model registry: name + parameter bag →
//!   runnable model. The five bundled models self-register; downstream
//!   crates register their own at runtime.
//! * [`simulation`] — the builder-style [`Simulation`] facade, the single
//!   entry point used by the CLI, the sweep coordinator, the benches and
//!   the examples.
//!
//! ```no_run
//! use adapar::{EngineKind, ObservePlan, Simulation};
//!
//! let out = Simulation::builder()
//!     .model("sir")
//!     .agents(10_000)
//!     .engine(EngineKind::Parallel)
//!     .workers(4)
//!     .seed(7)
//!     .observe(ObservePlan::every(10_000))
//!     .run()?;
//! println!("T = {}s, {}", out.report.time_s, out.observable);
//! println!("{} epoch frames", out.observable.len());
//! # Ok::<(), adapar::error::Error>(())
//! ```

pub mod engine;
pub mod model;
pub mod observe;
pub mod registry;
pub mod simulation;

pub use engine::{engine_for, Engine, EngineKind};
pub use model::{DynModel, Runnable};
pub use observe::{
    Metrics, ObsFrame, ObsValue, Observable, Observations, ObservePlan, Observer, Sink, SinkSpec,
};
pub use registry::{BuildCtx, ModelInfo, Params, Registry};
pub use simulation::{SimOutcome, Simulation, SimulationBuilder};
