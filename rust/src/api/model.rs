//! Type-erased runnable models.
//!
//! [`crate::model::Model`] is deeply generic (recipe/record/source
//! associated types), which is what the engines need — but the launcher
//! layers (registry, facade, CLI, sweep coordinator) must handle models
//! *uniformly*. [`DynModel`] is the object-safe bridge: it exposes one
//! generic-free entry point per engine family, each implemented exactly
//! once by the [`Runnable`] adapter (double dispatch, in the style of
//! `erased-serde`). Adding a model therefore never touches the dispatch
//! code; adding an engine means one more method here and one [`Engine`]
//! impl — never a per-model match.
//!
//! [`Engine`]: crate::api::Engine

use crate::error::Result;
use crate::model::Model;
use crate::protocol::{
    ParallelEngine, ProtocolConfig, RunReport, SequentialEngine, StepwiseEngine, SyncModel,
};
use crate::vtime::{calibrate_exec, CostModel, VirtualEngine};

/// An object-safe, engine-agnostic runnable model: [`Model`] with its
/// associated types erased, plus the launcher-facing extras (observable,
/// post-run consistency check, exec-cost calibration).
pub trait DynModel: Send + Sync {
    /// Model name (registry key or ad-hoc label).
    fn name(&self) -> &str;

    /// Run on the canonical single-threaded engine.
    fn run_sequential(&self, seed: u64) -> RunReport;

    /// Run on the paper's adaptive parallel engine.
    fn run_parallel(&self, cfg: &ProtocolConfig) -> RunReport;

    /// Run on the virtual-core testbed with the given cost model.
    fn run_virtual(&self, cfg: &ProtocolConfig, cost: &CostModel) -> RunReport;

    /// Run on the barrier-based stepwise baseline. Errors unless the model
    /// has a synchronous (phase-structured) form — the paper's point about
    /// sequential-form models (§2).
    fn run_stepwise(&self, workers: usize, seed: u64) -> Result<RunReport>;

    /// Whether the model has a synchronous form (can run stepwise).
    fn has_sync_form(&self) -> bool;

    /// Human-readable post-run observable (e.g. an SIR census) used by
    /// determinism validation and run summaries.
    fn observable(&self) -> String;

    /// Post-run internal consistency check (e.g. Schelling's grid/position
    /// agreement). Default: nothing to check.
    fn check_consistency(&self) -> Result<()>;

    /// Measure ns per `task_work` unit by executing a task sample
    /// sequentially (advances model state — use a throwaway instance).
    fn calibrate_exec_unit(&self, sample_tasks: u64, cost: &CostModel) -> f64;
}

/// Adapter erasing a concrete [`Model`] into a [`DynModel`].
///
/// Configure launcher-facing behaviour with the builder methods:
/// [`observed`](Runnable::observed) attaches the observable,
/// [`checked`](Runnable::checked) a post-run consistency check, and
/// [`with_sync`](Runnable::with_sync) unlocks the stepwise engine for
/// models that also implement [`SyncModel`].
pub struct Runnable<M: Model> {
    name: String,
    model: M,
    observe: Option<Box<dyn Fn(&M) -> String + Send + Sync>>,
    check: Option<Box<dyn Fn(&M) -> std::result::Result<(), String> + Send + Sync>>,
    stepwise: Option<fn(&M, usize, u64) -> RunReport>,
}

fn run_stepwise_impl<M: Model + SyncModel>(m: &M, workers: usize, seed: u64) -> RunReport {
    StepwiseEngine::new(workers, seed).run(m)
}

impl<M: Model> Runnable<M> {
    /// Wrap a model under a display name.
    pub fn new(name: impl Into<String>, model: M) -> Self {
        Self {
            name: name.into(),
            model,
            observe: None,
            check: None,
            stepwise: None,
        }
    }

    /// Attach the post-run observable.
    pub fn observed(mut self, f: impl Fn(&M) -> String + Send + Sync + 'static) -> Self {
        self.observe = Some(Box::new(f));
        self
    }

    /// Attach a post-run consistency check.
    pub fn checked(
        mut self,
        f: impl Fn(&M) -> std::result::Result<(), String> + Send + Sync + 'static,
    ) -> Self {
        self.check = Some(Box::new(f));
        self
    }

    /// Unlock the stepwise engine (requires the synchronous form).
    pub fn with_sync(mut self) -> Self
    where
        M: SyncModel,
    {
        self.stepwise = Some(run_stepwise_impl::<M>);
        self
    }

    /// Access the wrapped model.
    pub fn model(&self) -> &M {
        &self.model
    }

    /// Box into a trait object (convenience for registry factories).
    pub fn boxed(self) -> Box<dyn DynModel> {
        Box::new(self)
    }
}

impl<M: Model> DynModel for Runnable<M> {
    fn name(&self) -> &str {
        &self.name
    }

    fn run_sequential(&self, seed: u64) -> RunReport {
        SequentialEngine::new(seed).run(&self.model)
    }

    fn run_parallel(&self, cfg: &ProtocolConfig) -> RunReport {
        ParallelEngine::new(*cfg).run(&self.model)
    }

    fn run_virtual(&self, cfg: &ProtocolConfig, cost: &CostModel) -> RunReport {
        VirtualEngine {
            workers: cfg.workers,
            tasks_per_cycle: cfg.tasks_per_cycle,
            seed: cfg.seed,
            cost: *cost,
        }
        .run(&self.model)
    }

    fn run_stepwise(&self, workers: usize, seed: u64) -> Result<RunReport> {
        match self.stepwise {
            Some(f) => Ok(f(&self.model, workers, seed)),
            None => Err(crate::err!(
                "model `{}` has no synchronous form; the stepwise engine requires one \
                 (that is the paper's point about sequential-form models)",
                self.name
            )),
        }
    }

    fn has_sync_form(&self) -> bool {
        self.stepwise.is_some()
    }

    fn observable(&self) -> String {
        match &self.observe {
            Some(f) => f(&self.model),
            None => format!("{}: run complete", self.name),
        }
    }

    fn check_consistency(&self) -> Result<()> {
        if let Some(f) = &self.check {
            f(&self.model)
                .map_err(|e| crate::err!("model `{}` state corrupted: {e}", self.name))?;
        }
        Ok(())
    }

    fn calibrate_exec_unit(&self, sample_tasks: u64, cost: &CostModel) -> f64 {
        calibrate_exec(&self.model, sample_tasks, cost).0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testkit::IncModel;

    #[test]
    fn erased_model_runs_on_every_core_engine() {
        let dyn_model: Box<dyn DynModel> = Runnable::new("inc", IncModel::new(200, 8))
            .observed(|m| format!("cells={:?}", &m.cells_snapshot()[..2]))
            .boxed();
        let seq = dyn_model.run_sequential(3);
        assert_eq!(seq.totals.executed, 200);
        let par = dyn_model.run_parallel(&ProtocolConfig {
            workers: 2,
            tasks_per_cycle: 6,
            seed: 3,
            collect_timing: false,
        });
        assert_eq!(par.totals.executed, 200);
        let virt = dyn_model.run_virtual(
            &ProtocolConfig {
                workers: 3,
                tasks_per_cycle: 6,
                seed: 3,
                collect_timing: false,
            },
            &CostModel::default(),
        );
        assert_eq!(virt.totals.executed, 200);
        assert!(virt.time_s > 0.0);
        assert!(dyn_model.observable().starts_with("cells="));
        assert!(!dyn_model.has_sync_form());
        assert!(dyn_model.run_stepwise(2, 3).is_err());
        dyn_model.check_consistency().unwrap();
    }
}
