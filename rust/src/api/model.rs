//! Type-erased runnable models.
//!
//! [`crate::model::Model`] is deeply generic (recipe/record/source
//! associated types), which is what the engines need — but the launcher
//! layers (registry, facade, CLI, sweep coordinator) must handle models
//! *uniformly*. [`DynModel`] is the object-safe bridge: it exposes one
//! generic-free entry point per engine family, each implemented exactly
//! once by the [`Runnable`] adapter (double dispatch, in the style of
//! `erased-serde`). Adding a model therefore never touches the dispatch
//! code; adding an engine means one more method here and one [`Engine`]
//! impl — never a per-model match.
//!
//! Every run entry point takes an optional [`Observer`]: with one, the
//! engine records typed epoch snapshots (drained to quiescence, so the
//! trace is deterministic across engines — see
//! [`api::observe`](crate::api::observe)); without one, the engine runs
//! the unmodified hot path.
//!
//! [`Engine`]: crate::api::Engine

use crate::api::observe::{Metrics, Observable, Observer};
use crate::chaos::FaultHook;
use crate::error::Result;
use crate::model::{Model, TaskSource};
use crate::protocol::{
    ParallelEngine, ProtocolConfig, RunReport, SequentialEngine, StepwiseEngine, SyncModel,
};
use crate::sched::{ShardableModel, ShardedConfig, ShardedEngine};
use crate::trace::TraceMode;
use crate::vtime::{calibrate_exec, CostModel, VirtualEngine};

/// An object-safe, engine-agnostic runnable model: [`Model`] with its
/// associated types erased, plus the launcher-facing extras (typed
/// observation, post-run consistency check, exec-cost calibration).
pub trait DynModel: Send + Sync {
    /// Model name (registry key or ad-hoc label).
    fn name(&self) -> &str;

    /// Run on the canonical single-threaded engine. `trace` selects the
    /// (inert) causal-tracing mode, like `ProtocolConfig::trace` does for
    /// the chain engines.
    fn run_sequential(
        &self,
        seed: u64,
        trace: TraceMode,
        obs: Option<&mut Observer>,
    ) -> RunReport;

    /// Run on the paper's adaptive parallel engine.
    fn run_parallel(&self, cfg: &ProtocolConfig, obs: Option<&mut Observer>) -> RunReport;

    /// Run on the virtual-core testbed with the given cost model.
    fn run_virtual(
        &self,
        cfg: &ProtocolConfig,
        cost: &CostModel,
        obs: Option<&mut Observer>,
    ) -> RunReport;

    /// Run on the virtual-core testbed under fault injection
    /// ([`FaultHook`], DESIGN.md §10): stalls/jitter advance worker
    /// clocks and cost skews scale the cost model, once per epoch
    /// boundary. The soak runner's virtual-engine entry point.
    fn run_virtual_chaos(
        &self,
        cfg: &ProtocolConfig,
        cost: &CostModel,
        obs: Option<&mut Observer>,
        hook: &mut FaultHook,
    ) -> RunReport;

    /// Run on the sharded adaptive scheduler under fault injection
    /// (capped wall stalls, cost-probe skew, boundary invariant checks
    /// recording into the hook). Errors unless the model is
    /// sharded-capable, like [`DynModel::run_sharded`].
    fn run_sharded_chaos(
        &self,
        cfg: &ShardedConfig,
        obs: Option<&mut Observer>,
        hook: &mut FaultHook,
    ) -> Result<RunReport>;

    /// Run on the barrier-based stepwise baseline. Errors unless the model
    /// has a synchronous (phase-structured) form — the paper's point about
    /// sequential-form models (§2).
    fn run_stepwise(
        &self,
        workers: usize,
        seed: u64,
        trace: TraceMode,
        obs: Option<&mut Observer>,
    ) -> Result<RunReport>;

    /// Run on the sharded adaptive scheduler. Errors unless the model
    /// exposes a footprint topology
    /// ([`ShardableModel`], unlocked via
    /// [`Runnable::with_sharding`]).
    fn run_sharded(
        &self,
        cfg: &ShardedConfig,
        obs: Option<&mut Observer>,
    ) -> Result<RunReport>;

    /// Whether the model has a synchronous form (can run stepwise).
    fn has_sync_form(&self) -> bool;

    /// Whether the model exposes a footprint topology (can run sharded).
    fn has_sharded_form(&self) -> bool;

    /// Snapshot the model's typed metrics from quiescent state (empty if
    /// the model exports none).
    fn observe(&self) -> Metrics;

    /// Expected total task count for a run at `seed`, if the model's
    /// source knows it ([`TaskSource::size_hint`]); used to pre-size
    /// observation traces and drive progress reporting.
    fn task_count_hint(&self, seed: u64) -> Option<u64>;

    /// Post-run internal consistency check (e.g. Schelling's grid/position
    /// agreement). Default: nothing to check.
    fn check_consistency(&self) -> Result<()>;

    /// Measure ns per `task_work` unit by executing a task sample
    /// sequentially (advances model state — use a throwaway instance).
    fn calibrate_exec_unit(&self, sample_tasks: u64, cost: &CostModel) -> f64;
}

/// Adapter erasing a concrete [`Model`] into a [`DynModel`].
///
/// Configure launcher-facing behaviour with the builder methods:
/// [`observable`](Runnable::observable) exports the model's
/// [`Observable`] metrics (or [`observed`](Runnable::observed) attaches a
/// custom probe), [`checked`](Runnable::checked) a post-run consistency
/// check, and [`with_sync`](Runnable::with_sync) unlocks the stepwise
/// engine for models that also implement [`SyncModel`].
pub struct Runnable<M: Model> {
    name: String,
    model: M,
    probe: Option<Box<dyn Fn(&M) -> Metrics + Send + Sync>>,
    check: Option<Box<dyn Fn(&M) -> std::result::Result<(), String> + Send + Sync>>,
    stepwise: Option<StepwiseFn<M>>,
    sharded: Option<ShardedFn<M>>,
    sharded_chaos: Option<ShardedChaosFn<M>>,
}

/// The monomorphized stepwise entry point stored by [`Runnable`] when the
/// model has a synchronous form.
type StepwiseFn<M> =
    fn(&M, usize, u64, TraceMode, Option<(&dyn Fn() -> Metrics, &mut Observer)>) -> RunReport;

/// The monomorphized sharded entry point stored by [`Runnable`] when the
/// model exposes a footprint topology.
type ShardedFn<M> =
    fn(&M, &ShardedConfig, Option<(&dyn Fn() -> Metrics, &mut Observer)>) -> RunReport;

/// The monomorphized sharded chaos entry point (stored alongside
/// [`ShardedFn`] by [`Runnable::with_sharding`]).
type ShardedChaosFn<M> = fn(
    &M,
    &ShardedConfig,
    Option<(&dyn Fn() -> Metrics, &mut Observer)>,
    &mut FaultHook,
) -> RunReport;

fn run_stepwise_impl<M: Model + SyncModel>(
    m: &M,
    workers: usize,
    seed: u64,
    trace: TraceMode,
    obs: Option<(&dyn Fn() -> Metrics, &mut Observer)>,
) -> RunReport {
    let mut engine = StepwiseEngine::new(workers, seed);
    engine.trace = trace;
    match obs {
        None => engine.run(m),
        Some((probe, observer)) => engine.run_observed(m, probe, observer),
    }
}

fn run_sharded_impl<M: ShardableModel>(
    m: &M,
    cfg: &ShardedConfig,
    obs: Option<(&dyn Fn() -> Metrics, &mut Observer)>,
) -> RunReport {
    let engine = ShardedEngine::new(*cfg);
    match obs {
        None => engine.run(m),
        Some((probe, observer)) => engine.run_observed(m, probe, observer),
    }
}

fn run_sharded_chaos_impl<M: ShardableModel>(
    m: &M,
    cfg: &ShardedConfig,
    obs: Option<(&dyn Fn() -> Metrics, &mut Observer)>,
    hook: &mut FaultHook,
) -> RunReport {
    let engine = ShardedEngine::new(*cfg);
    match obs {
        None => engine.run_chaos(m, hook),
        Some((probe, observer)) => engine.run_chaos_observed(m, probe, observer, hook),
    }
}

impl<M: Model> Runnable<M> {
    /// Wrap a model under a display name.
    pub fn new(name: impl Into<String>, model: M) -> Self {
        Self {
            name: name.into(),
            model,
            probe: None,
            check: None,
            stepwise: None,
            sharded: None,
            sharded_chaos: None,
        }
    }

    /// Export the model's own [`Observable`] metrics through the
    /// observation pipeline.
    pub fn observable(mut self) -> Self
    where
        M: Observable,
    {
        self.probe = Some(Box::new(|m: &M| m.observe()));
        self
    }

    /// Attach a custom metric probe (for models that do not implement
    /// [`Observable`], e.g. ad-hoc plug-ins).
    pub fn observed(mut self, f: impl Fn(&M) -> Metrics + Send + Sync + 'static) -> Self {
        self.probe = Some(Box::new(f));
        self
    }

    /// Attach a post-run consistency check.
    pub fn checked(
        mut self,
        f: impl Fn(&M) -> std::result::Result<(), String> + Send + Sync + 'static,
    ) -> Self {
        self.check = Some(Box::new(f));
        self
    }

    /// Unlock the stepwise engine (requires the synchronous form).
    pub fn with_sync(mut self) -> Self
    where
        M: SyncModel,
    {
        self.stepwise = Some(run_stepwise_impl::<M>);
        self
    }

    /// Unlock the sharded adaptive scheduler (requires a footprint
    /// topology).
    pub fn with_sharding(mut self) -> Self
    where
        M: ShardableModel,
    {
        self.sharded = Some(run_sharded_impl::<M>);
        self.sharded_chaos = Some(run_sharded_chaos_impl::<M>);
        self
    }

    /// Access the wrapped model.
    pub fn model(&self) -> &M {
        &self.model
    }

    /// Box into a trait object (convenience for registry factories).
    pub fn boxed(self) -> Box<dyn DynModel> {
        Box::new(self)
    }

    /// Snapshot via the attached probe (empty metrics without one).
    fn probe_now(&self) -> Metrics {
        match &self.probe {
            Some(p) => p(&self.model),
            None => Vec::new(),
        }
    }
}

impl<M: Model> DynModel for Runnable<M> {
    fn name(&self) -> &str {
        &self.name
    }

    fn run_sequential(
        &self,
        seed: u64,
        trace: TraceMode,
        obs: Option<&mut Observer>,
    ) -> RunReport {
        let engine = SequentialEngine { seed, trace };
        match obs {
            None => engine.run(&self.model),
            Some(observer) => engine.run_observed(&self.model, &|| self.probe_now(), observer),
        }
    }

    fn run_parallel(&self, cfg: &ProtocolConfig, obs: Option<&mut Observer>) -> RunReport {
        let engine = ParallelEngine::new(*cfg);
        match obs {
            None => engine.run(&self.model),
            Some(observer) => engine.run_observed(&self.model, &|| self.probe_now(), observer),
        }
    }

    fn run_virtual(
        &self,
        cfg: &ProtocolConfig,
        cost: &CostModel,
        obs: Option<&mut Observer>,
    ) -> RunReport {
        let engine = VirtualEngine {
            workers: cfg.workers,
            tasks_per_cycle: cfg.tasks_per_cycle,
            seed: cfg.seed,
            cost: *cost,
            trace: cfg.trace,
            window: cfg.window,
        };
        match obs {
            None => engine.run(&self.model),
            Some(observer) => engine.run_observed(&self.model, &|| self.probe_now(), observer),
        }
    }

    fn run_virtual_chaos(
        &self,
        cfg: &ProtocolConfig,
        cost: &CostModel,
        obs: Option<&mut Observer>,
        hook: &mut FaultHook,
    ) -> RunReport {
        let engine = VirtualEngine {
            workers: cfg.workers,
            tasks_per_cycle: cfg.tasks_per_cycle,
            seed: cfg.seed,
            cost: *cost,
            trace: cfg.trace,
            window: cfg.window,
        };
        match obs {
            None => engine.run_chaos(&self.model, hook),
            Some(observer) => {
                engine.run_chaos_observed(&self.model, &|| self.probe_now(), observer, hook)
            }
        }
    }

    fn run_sharded_chaos(
        &self,
        cfg: &ShardedConfig,
        obs: Option<&mut Observer>,
        hook: &mut FaultHook,
    ) -> Result<RunReport> {
        match self.sharded_chaos {
            Some(f) => Ok(match obs {
                None => f(&self.model, cfg, None, hook),
                Some(observer) => f(
                    &self.model,
                    cfg,
                    Some((&|| self.probe_now(), observer)),
                    hook,
                ),
            }),
            None => Err(crate::err!(
                "model `{}` exposes no footprint topology; the sharded engine needs \
                 ShardableModel (wrap it with Runnable::with_sharding)",
                self.name
            )),
        }
    }

    fn run_stepwise(
        &self,
        workers: usize,
        seed: u64,
        trace: TraceMode,
        obs: Option<&mut Observer>,
    ) -> Result<RunReport> {
        match self.stepwise {
            Some(f) => Ok(match obs {
                None => f(&self.model, workers, seed, trace, None),
                Some(observer) => f(
                    &self.model,
                    workers,
                    seed,
                    trace,
                    Some((&|| self.probe_now(), observer)),
                ),
            }),
            None => Err(crate::err!(
                "model `{}` has no synchronous form; the stepwise engine requires one \
                 (that is the paper's point about sequential-form models)",
                self.name
            )),
        }
    }

    fn run_sharded(
        &self,
        cfg: &ShardedConfig,
        obs: Option<&mut Observer>,
    ) -> Result<RunReport> {
        match self.sharded {
            Some(f) => Ok(match obs {
                None => f(&self.model, cfg, None),
                Some(observer) => f(
                    &self.model,
                    cfg,
                    Some((&|| self.probe_now(), observer)),
                ),
            }),
            None => Err(crate::err!(
                "model `{}` exposes no footprint topology; the sharded engine needs \
                 ShardableModel (wrap it with Runnable::with_sharding)",
                self.name
            )),
        }
    }

    fn has_sync_form(&self) -> bool {
        self.stepwise.is_some()
    }

    fn has_sharded_form(&self) -> bool {
        self.sharded.is_some()
    }

    fn observe(&self) -> Metrics {
        self.probe_now()
    }

    fn task_count_hint(&self, seed: u64) -> Option<u64> {
        self.model.source(seed).size_hint()
    }

    fn check_consistency(&self) -> Result<()> {
        if let Some(f) = &self.check {
            f(&self.model)
                .map_err(|e| crate::err!("model `{}` state corrupted: {e}", self.name))?;
        }
        Ok(())
    }

    fn calibrate_exec_unit(&self, sample_tasks: u64, cost: &CostModel) -> f64 {
        calibrate_exec(&self.model, sample_tasks, cost).0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::observe::{frame_count, ObsValue};
    use crate::model::testkit::IncModel;

    #[test]
    fn erased_model_runs_on_every_core_engine() {
        let dyn_model: Box<dyn DynModel> = Runnable::new("inc", IncModel::new(200, 8))
            .observed(|m| {
                vec![(
                    "cell0".to_string(),
                    ObsValue::Int(m.cells_snapshot()[0] as i64),
                )]
            })
            .boxed();
        let seq = dyn_model.run_sequential(3, TraceMode::Off, None);
        assert_eq!(seq.totals.executed, 200);
        let par = dyn_model.run_parallel(
            &ProtocolConfig {
                workers: 2,
                tasks_per_cycle: 6,
                seed: 3,
                ..Default::default()
            },
            None,
        );
        assert_eq!(par.totals.executed, 200);
        let virt = dyn_model.run_virtual(
            &ProtocolConfig {
                workers: 3,
                tasks_per_cycle: 6,
                seed: 3,
                ..Default::default()
            },
            &CostModel::default(),
            None,
        );
        assert_eq!(virt.totals.executed, 200);
        assert!(virt.time_s > 0.0);
        assert!(matches!(
            dyn_model.observe().as_slice(),
            [(name, ObsValue::Int(_))] if name == "cell0"
        ));
        assert_eq!(dyn_model.task_count_hint(3), Some(200));
        assert!(!dyn_model.has_sync_form());
        assert!(dyn_model.run_stepwise(2, 3, TraceMode::Off, None).is_err());
        assert!(!dyn_model.has_sharded_form(), "sharding is opt-in");
        assert!(dyn_model.run_sharded(&ShardedConfig::default(), None).is_err());
        dyn_model.check_consistency().unwrap();
    }

    #[test]
    fn with_sharding_unlocks_the_sharded_engine() {
        let dyn_model: Box<dyn DynModel> = Runnable::new("inc", IncModel::new(300, 8))
            .with_sharding()
            .boxed();
        assert!(dyn_model.has_sharded_form());
        let cfg = ShardedConfig {
            workers: 2,
            seed: 3,
            ..Default::default()
        };
        let report = dyn_model.run_sharded(&cfg, None).unwrap();
        assert_eq!(report.engine, "sharded");
        assert_eq!(report.totals.executed, 300);
        assert!(report.sched.is_some());
    }

    #[test]
    fn observed_runs_produce_the_same_trace_on_every_engine() {
        let build = || {
            Runnable::new("inc", IncModel::new(100, 8))
                .observed(|m| {
                    vec![(
                        "cells".to_string(),
                        ObsValue::Series(
                            m.cells_snapshot().iter().map(|&c| c as f64).collect(),
                        ),
                    )]
                })
                .boxed()
        };
        let trace = |run: &dyn Fn(&dyn DynModel, &mut Observer)| {
            let model = build();
            let mut obs = Observer::new(30);
            run(model.as_ref(), &mut obs);
            obs.finish().unwrap()
        };
        let reference = trace(&|m, o| {
            m.run_sequential(5, TraceMode::Off, Some(o));
        });
        assert_eq!(reference.len() as u64, frame_count(30, 100), "0,30,60,90,100");
        for workers in [1, 2, 4] {
            let cfg = ProtocolConfig {
                workers,
                tasks_per_cycle: 6,
                seed: 5,
                ..Default::default()
            };
            let got = trace(&|m, o| {
                m.run_parallel(&cfg, Some(o));
            });
            assert_eq!(got, reference, "parallel n={workers}");
            let got = trace(&|m, o| {
                m.run_virtual(&cfg, &CostModel::default(), Some(o));
            });
            assert_eq!(got, reference, "virtual n={workers}");
        }
    }
}
