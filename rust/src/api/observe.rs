//! The typed observation pipeline: named metrics, deterministic epoch
//! snapshots, and pluggable sinks.
//!
//! The paper's experiments are about *trajectories* (epidemic curves,
//! cultural-domain counts over time), so observation is a first-class
//! subsystem, not a post-run string:
//!
//! * [`ObsValue`] — a typed metric value (scalar / integer / series /
//!   labelled counts).
//! * [`Observable`] — implemented by models to export named typed metrics
//!   from quiescent state (SIR census, Axelrod domain counts, Ising
//!   magnetization, ...).
//! * [`Observer`] — the engine-facing recorder: collects [`ObsFrame`]s at
//!   a cadence of `every` canonical tasks (an *epoch*) and streams them to
//!   attached [`Sink`]s (CSV, JSON-lines, progress line).
//! * [`Observations`] — the finished trace carried by
//!   [`SimOutcome`](crate::api::SimOutcome); structurally comparable
//!   (`PartialEq`) and `Display`-compatible with the old stringly
//!   observable.
//! * [`EpochGate`] — a [`TaskSource`] adapter that marks epoch boundaries
//!   every `N` canonical tasks by reporting (temporary) exhaustion, which
//!   is how the chain engines reach quiescence before snapshotting.
//!
//! ## Determinism contract (DESIGN.md §6a)
//!
//! A frame at task count `t` is only ever taken when the executed tasks
//! are exactly the canonical prefix `0..t` and no task is in flight. The
//! parallel engine drains its chain at epoch boundaries, the virtual
//! testbed drains its DES, and the stepwise baseline splits phases at the
//! boundary block — so at a fixed seed the *whole trace* is bit-identical
//! across engines and worker counts, not just the final state.

use std::io::Write;
use std::path::{Path, PathBuf};

use crate::error::{Context, Result};
use crate::model::TaskSource;
use crate::util::json::Json;

/// A snapshot's payload: ordered `(metric name, value)` pairs.
pub type Metrics = Vec<(String, ObsValue)>;

/// A borrowed quiescent-state probe: engines call it only while no task
/// is executing.
pub type ObsProbe<'a> = &'a (dyn Fn() -> Metrics + 'a);

/// One typed metric value.
#[derive(Clone, Debug, PartialEq)]
pub enum ObsValue {
    /// A real-valued scalar (e.g. magnetization, segregation index).
    Float(f64),
    /// An integer scalar (e.g. number of cultural domains).
    Int(i64),
    /// A fixed-order series of reals (e.g. a per-bin histogram).
    Series(Vec<f64>),
    /// Labelled counts (e.g. the SIR census `S`/`I`/`R`).
    Counts(Vec<(String, i64)>),
}

impl ObsValue {
    /// Build a [`ObsValue::Counts`] from `(label, count)` pairs.
    pub fn counts<L: Into<String>, I: IntoIterator<Item = (L, i64)>>(pairs: I) -> Self {
        ObsValue::Counts(pairs.into_iter().map(|(l, c)| (l.into(), c)).collect())
    }

    /// The value as JSON (counts become an object, series an array).
    pub fn to_json(&self) -> Json {
        match self {
            ObsValue::Float(x) => Json::from(*x),
            ObsValue::Int(i) => Json::from(*i),
            ObsValue::Series(v) => Json::Arr(v.iter().map(|&x| Json::from(x)).collect()),
            ObsValue::Counts(c) => {
                Json::Obj(c.iter().map(|(l, n)| (l.clone(), Json::from(*n))).collect())
            }
        }
    }
}

impl std::fmt::Display for ObsValue {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ObsValue::Float(x) => write!(f, "{x}"),
            ObsValue::Int(i) => write!(f, "{i}"),
            ObsValue::Series(v) => {
                f.write_str("[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{x}")?;
                }
                f.write_str("]")
            }
            ObsValue::Counts(c) => {
                f.write_str("{")?;
                for (i, (l, n)) in c.iter().enumerate() {
                    if i > 0 {
                        f.write_str(" ")?;
                    }
                    write!(f, "{l}={n}")?;
                }
                f.write_str("}")
            }
        }
    }
}

/// A model that exports named typed metrics.
///
/// Implementations read **quiescent** state only: the engines guarantee
/// that [`observe`](Observable::observe) is never called while a task is
/// executing (epoch boundaries drain first).
pub trait Observable {
    /// Snapshot the model's metrics, in a fixed order.
    fn observe(&self) -> Metrics;
}

/// One snapshot of a run at an epoch boundary.
#[derive(Clone, Debug, PartialEq)]
pub struct ObsFrame {
    /// Canonical task count at which the snapshot was taken (`0` is the
    /// initial state, before any task executed).
    pub tasks: u64,
    /// The metric values, in the model's fixed order.
    pub values: Metrics,
}

impl ObsFrame {
    /// Value of a metric by name.
    pub fn get(&self, name: &str) -> Option<&ObsValue> {
        self.values.iter().find(|(n, _)| n == name).map(|(_, v)| v)
    }
}

impl std::fmt::Display for ObsFrame {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.values.is_empty() {
            return write!(f, "(no metrics)");
        }
        for (i, (name, value)) in self.values.iter().enumerate() {
            if i > 0 {
                f.write_str(" ")?;
            }
            write!(f, "{name}={value}")?;
        }
        Ok(())
    }
}

/// A finished observation trace — the structured replacement for the old
/// post-run `observable: String`.
///
/// Structurally comparable across engines (`PartialEq`); `Display` prints
/// the final frame, so the old string uses (`println!`, equality in
/// validation output) keep working.
#[derive(Clone, Debug, PartialEq)]
pub struct Observations {
    /// Epoch cadence in canonical tasks (`0` = final frame only).
    pub every: u64,
    /// Frames in task-count order; the last frame is the final state.
    pub frames: Vec<ObsFrame>,
}

impl Observations {
    /// An empty trace (no frames recorded).
    pub fn empty() -> Self {
        Self {
            every: 0,
            frames: Vec::new(),
        }
    }

    /// Number of frames.
    pub fn len(&self) -> usize {
        self.frames.len()
    }

    /// Whether no frames were recorded.
    pub fn is_empty(&self) -> bool {
        self.frames.is_empty()
    }

    /// The final frame, if any.
    pub fn final_frame(&self) -> Option<&ObsFrame> {
        self.frames.last()
    }

    /// Final value of a metric by name.
    pub fn value(&self, name: &str) -> Option<&ObsValue> {
        self.final_frame().and_then(|f| f.get(name))
    }

    /// The `(tasks, value)` trajectory of one metric across all frames.
    pub fn series(&self, name: &str) -> Vec<(u64, &ObsValue)> {
        self.frames
            .iter()
            .filter_map(|f| f.get(name).map(|v| (f.tasks, v)))
            .collect()
    }

    /// The whole trace as JSON: `{"every": N, "frames": [...]}`.
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("every".into(), Json::from(self.every)),
            (
                "frames".into(),
                Json::Arr(
                    self.frames
                        .iter()
                        .map(|f| {
                            let mut fields = vec![("tasks".into(), Json::from(f.tasks))];
                            fields.extend(
                                f.values.iter().map(|(n, v)| (n.clone(), v.to_json())),
                            );
                            Json::Obj(fields)
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

impl std::fmt::Display for Observations {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.final_frame() {
            Some(frame) => std::fmt::Display::fmt(frame, f),
            None => f.write_str("(no observations)"),
        }
    }
}

/// Number of frames a full trace holds: the initial frame at `t = 0`,
/// one per full epoch, and the final (possibly partial) epoch's frame.
/// `every == 0` means "final frame only".
pub fn frame_count(every: u64, total_tasks: u64) -> u64 {
    if every == 0 || total_tasks == 0 {
        return 1;
    }
    1 + total_tasks / every + u64::from(total_tasks % every != 0)
}

// ---------------------------------------------------------------------------
// Sinks
// ---------------------------------------------------------------------------

/// A destination for frames, fed in task-count order during the run.
pub trait Sink: Send {
    /// Consume one frame.
    fn record(&mut self, frame: &ObsFrame) -> Result<()>;

    /// Flush at end of run.
    fn finish(&mut self) -> Result<()> {
        Ok(())
    }
}

/// Flatten a frame into CSV column names: `tasks`, one column per
/// scalar/series metric, one `metric.label` column per counts label.
fn csv_columns(frame: &ObsFrame) -> Vec<String> {
    let mut cols = vec!["tasks".to_string()];
    for (name, value) in &frame.values {
        match value {
            ObsValue::Counts(c) => {
                cols.extend(c.iter().map(|(l, _)| format!("{name}.{l}")));
            }
            _ => cols.push(name.clone()),
        }
    }
    cols
}

/// Flatten a frame into CSV cells matching [`csv_columns`]'s order.
fn csv_cells(frame: &ObsFrame) -> Vec<String> {
    let mut cells = vec![frame.tasks.to_string()];
    for (_, value) in &frame.values {
        match value {
            ObsValue::Float(x) => cells.push(format!("{x}")),
            ObsValue::Int(i) => cells.push(format!("{i}")),
            ObsValue::Series(v) => cells.push(
                v.iter()
                    .map(|x| format!("{x}"))
                    .collect::<Vec<_>>()
                    .join(";"),
            ),
            ObsValue::Counts(c) => cells.extend(c.iter().map(|(_, n)| n.to_string())),
        }
    }
    cells
}

/// Open a buffered sink file, creating parent directories.
fn create_sink_file(path: &Path) -> Result<Box<dyn Write + Send>> {
    crate::util::create_parent_dirs(path)?;
    let file = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    Ok(Box::new(std::io::BufWriter::new(file)))
}

/// Streams frames as CSV rows (header derived from the first frame).
pub struct CsvSink {
    out: Box<dyn Write + Send>,
    header: Option<Vec<String>>,
}

impl CsvSink {
    /// Create (truncate) a CSV file, making parent directories.
    pub fn create<P: AsRef<Path>>(path: P) -> Result<Self> {
        Ok(Self::from_writer(create_sink_file(path.as_ref())?))
    }

    /// Stream to an arbitrary writer.
    pub fn from_writer(out: Box<dyn Write + Send>) -> Self {
        Self { out, header: None }
    }
}

impl Sink for CsvSink {
    fn record(&mut self, frame: &ObsFrame) -> Result<()> {
        let cols = csv_columns(frame);
        match &self.header {
            None => {
                writeln!(self.out, "{}", cols.join(","))?;
                self.header = Some(cols);
            }
            Some(h) => crate::ensure!(
                *h == cols,
                "observation metrics changed shape mid-run (CSV sink): \
                 had {h:?}, got {cols:?}"
            ),
        }
        writeln!(self.out, "{}", csv_cells(frame).join(","))?;
        Ok(())
    }

    fn finish(&mut self) -> Result<()> {
        self.out.flush()?;
        Ok(())
    }
}

/// Streams frames as JSON-lines: one `{"tasks": N, "<metric>": ...}`
/// object per line.
pub struct JsonLinesSink {
    out: Box<dyn Write + Send>,
}

impl JsonLinesSink {
    /// Create (truncate) a `.jsonl` file, making parent directories.
    pub fn create<P: AsRef<Path>>(path: P) -> Result<Self> {
        Ok(Self::from_writer(create_sink_file(path.as_ref())?))
    }

    /// Stream to an arbitrary writer.
    pub fn from_writer(out: Box<dyn Write + Send>) -> Self {
        Self { out }
    }
}

impl Sink for JsonLinesSink {
    fn record(&mut self, frame: &ObsFrame) -> Result<()> {
        let mut fields = vec![("tasks".to_string(), Json::from(frame.tasks))];
        fields.extend(frame.values.iter().map(|(n, v)| (n.clone(), v.to_json())));
        writeln!(self.out, "{}", Json::Obj(fields).render())?;
        Ok(())
    }

    fn finish(&mut self) -> Result<()> {
        self.out.flush()?;
        Ok(())
    }
}

/// Writes a progress line per frame to stderr; uses
/// [`TaskSource::size_hint`] for a percentage when the total is known and
/// falls back to a plain frame counter when it is not.
pub struct ProgressSink {
    total: Option<u64>,
    frames_seen: u64,
}

impl ProgressSink {
    /// `total` is the expected task count, if known.
    pub fn new(total: Option<u64>) -> Self {
        Self {
            total,
            frames_seen: 0,
        }
    }
}

impl Sink for ProgressSink {
    fn record(&mut self, frame: &ObsFrame) -> Result<()> {
        self.frames_seen += 1;
        match self.total {
            Some(total) if total > 0 => eprintln!(
                "observe: {}/{} tasks ({:.0}%) {}",
                frame.tasks,
                total,
                100.0 * frame.tasks as f64 / total as f64,
                frame
            ),
            _ => eprintln!(
                "observe: {} tasks (frame {}) {}",
                frame.tasks, self.frames_seen, frame
            ),
        }
        Ok(())
    }
}

/// Moves a wrapped sink's I/O onto a dedicated writer thread behind a
/// bounded channel, so the coordinating thread never blocks on disk
/// inside an epoch fence (it only pays a frame clone + channel send).
///
/// Output is **byte-identical** to the wrapped sink run synchronously:
/// there is exactly one consumer and the channel is FIFO, so frames
/// reach the inner sink in record order. [`finish`](Sink::finish) is
/// the flush fence — it closes the channel, joins the writer (which
/// runs the inner sink's `finish`), and surfaces any deferred write
/// error. A full channel applies backpressure (the send blocks) rather
/// than dropping frames: observation output is lossless by contract,
/// unlike telemetry ring samples.
///
/// Dropping an unfinished `AsyncSink` still closes the channel and
/// joins the writer — the error-path guard that leaves complete,
/// parseable files behind a failed run (errors are swallowed; `Drop`
/// cannot surface them).
pub struct AsyncSink {
    tx: Option<std::sync::mpsc::SyncSender<ObsFrame>>,
    writer: Option<std::thread::JoinHandle<Result<()>>>,
}

impl AsyncSink {
    /// Default channel depth (frames buffered before backpressure).
    pub const DEFAULT_DEPTH: usize = 256;

    /// Wrap `inner`, spawning the writer thread.
    pub fn new(inner: Box<dyn Sink>) -> Self {
        Self::with_depth(inner, Self::DEFAULT_DEPTH)
    }

    /// Wrap `inner` with an explicit channel depth (min 1).
    pub fn with_depth(mut inner: Box<dyn Sink>, depth: usize) -> Self {
        let (tx, rx) = std::sync::mpsc::sync_channel::<ObsFrame>(depth.max(1));
        let writer = std::thread::Builder::new()
            .name("adapar-obs-sink".to_string())
            .spawn(move || -> Result<()> {
                // Frames drain in FIFO order; the loop ends when every
                // sender is dropped (finish or the drop guard).
                for frame in rx {
                    inner.record(&frame)?;
                }
                inner.finish()
            })
            .expect("spawn observation sink writer");
        Self {
            tx: Some(tx),
            writer: Some(writer),
        }
    }

    /// Close the channel and join the writer; idempotent.
    fn join(&mut self) -> Result<()> {
        drop(self.tx.take());
        match self.writer.take() {
            Some(h) => h
                .join()
                .map_err(|_| crate::error::Error::msg("observation sink writer panicked"))?,
            None => Ok(()),
        }
    }
}

impl Sink for AsyncSink {
    fn record(&mut self, frame: &ObsFrame) -> Result<()> {
        let Some(tx) = &self.tx else {
            return Err(crate::error::Error::msg(
                "record after observation sink finished",
            ));
        };
        if tx.send(frame.clone()).is_err() {
            // The writer exited early — its deferred error is the real
            // diagnosis, not the broken channel.
            return match self.join() {
                Ok(()) => Err(crate::error::Error::msg(
                    "observation sink writer exited early",
                )),
                Err(e) => Err(e),
            };
        }
        Ok(())
    }

    fn finish(&mut self) -> Result<()> {
        self.join()
    }
}

impl Drop for AsyncSink {
    fn drop(&mut self) {
        let _ = self.join();
    }
}

// ---------------------------------------------------------------------------
// Observer + plan
// ---------------------------------------------------------------------------

/// The engine-facing recorder: cadence, the in-memory trace, and any
/// attached sinks. Engines call [`record`](Observer::record) only at
/// quiescent points; [`finish`](Observer::finish) yields the trace.
pub struct Observer {
    every: u64,
    frames: Vec<ObsFrame>,
    sinks: Vec<Box<dyn Sink>>,
    sink_error: Option<crate::error::Error>,
}

impl Observer {
    /// A recorder with the given epoch cadence (`0` = final frame only).
    pub fn new(every: u64) -> Self {
        Self {
            every,
            frames: Vec::new(),
            sinks: Vec::new(),
            sink_error: None,
        }
    }

    /// Attach a sink (builder style).
    pub fn with_sink(mut self, sink: impl Sink + 'static) -> Self {
        self.sinks.push(Box::new(sink));
        self
    }

    /// Attach a boxed sink.
    pub fn add_sink(&mut self, sink: Box<dyn Sink>) {
        self.sinks.push(sink);
    }

    /// Epoch cadence in canonical tasks (`0` = final frame only).
    pub fn every(&self) -> u64 {
        self.every
    }

    /// The cadence as an [`EpochGate`] budget: cadence `0` ("final frame
    /// only") becomes one unbounded epoch. All engines derive their epoch
    /// length from this, so the contract lives in one place.
    pub fn gate_cadence(&self) -> u64 {
        if self.every == 0 {
            u64::MAX
        } else {
            self.every
        }
    }

    /// Record the initial frame (task count 0) — a no-op at cadence `0`,
    /// which records the final frame only. Engines call this once before
    /// executing anything.
    pub fn record_initial(&mut self, probe: ObsProbe<'_>) {
        if self.every > 0 {
            self.record(0, probe());
        }
    }

    /// Whether `executed` is an epoch boundary at this cadence. Task
    /// count 0 is never a boundary — the initial frame is
    /// [`record_initial`](Observer::record_initial)'s job.
    pub fn due(&self, executed: u64) -> bool {
        executed > 0 && self.every > 0 && executed % self.every == 0
    }

    /// Pre-size the trace from the source's
    /// [`size_hint`](TaskSource::size_hint); a `None` hint is a no-op.
    pub fn reserve_for(&mut self, total_tasks: Option<u64>) {
        if let Some(total) = total_tasks {
            // Cap the reservation: a bogus hint must not pre-allocate
            // unbounded memory.
            let n = frame_count(self.every, total).min(1 << 20);
            self.frames.reserve(n as usize);
        }
    }

    /// Record a frame at canonical task count `tasks`. A repeat of the
    /// last frame's task count is skipped (the final boundary may
    /// coincide with the last epoch). Sink errors are deferred to
    /// [`finish`](Observer::finish).
    pub fn record(&mut self, tasks: u64, values: Metrics) {
        if self.frames.last().is_some_and(|f| f.tasks == tasks) {
            return;
        }
        let frame = ObsFrame { tasks, values };
        if self.sink_error.is_none() {
            for sink in &mut self.sinks {
                if let Err(e) = sink.record(&frame) {
                    self.sink_error = Some(e);
                    break;
                }
            }
        }
        self.frames.push(frame);
    }

    /// Frames recorded so far.
    pub fn frames(&self) -> &[ObsFrame] {
        &self.frames
    }

    /// Flush sinks and return the finished trace; surfaces any deferred
    /// sink error.
    pub fn finish(mut self) -> Result<Observations> {
        if let Some(e) = self.sink_error.take() {
            return Err(e.context("observation sink failed"));
        }
        for sink in &mut self.sinks {
            sink.finish()?;
        }
        // Finished cleanly — disarm the drop guard so sinks are not
        // flushed twice.
        self.sinks.clear();
        Ok(Observations {
            every: self.every,
            frames: std::mem::take(&mut self.frames),
        })
    }
}

impl Drop for Observer {
    /// Error-path guard: a run that unwinds past [`finish`](Observer::finish)
    /// (engine error, `?` in the caller) still flushes and closes every
    /// sink, so red runs leave complete CSV/JSON-lines files behind.
    /// Errors are swallowed — `Drop` has nowhere to surface them, and the
    /// original failure is the diagnosis the user needs.
    fn drop(&mut self) {
        for sink in &mut self.sinks {
            let _ = sink.finish();
        }
    }
}

/// Declarative sink configuration — kept on the (cloneable)
/// [`Simulation`](crate::api::Simulation) and materialized at run time.
#[derive(Clone, Debug, PartialEq)]
pub enum SinkSpec {
    /// Write the trace as CSV to a file.
    Csv(PathBuf),
    /// Write the trace as JSON-lines to a file.
    JsonLines(PathBuf),
    /// Print a progress line per epoch to stderr.
    Progress,
}

impl SinkSpec {
    /// Materialize the sink. `total_tasks` is the run's
    /// [`size_hint`](TaskSource::size_hint), used by the progress sink.
    ///
    /// Every variant is wrapped in an [`AsyncSink`], so file and terminal
    /// I/O happens off the coordinating thread; output bytes and order
    /// are identical to the synchronous sink.
    pub fn build(&self, total_tasks: Option<u64>) -> Result<Box<dyn Sink>> {
        let inner: Box<dyn Sink> = match self {
            SinkSpec::Csv(path) => Box::new(CsvSink::create(path)?),
            SinkSpec::JsonLines(path) => Box::new(JsonLinesSink::create(path)?),
            SinkSpec::Progress => Box::new(ProgressSink::new(total_tasks)),
        };
        Ok(Box::new(AsyncSink::new(inner)))
    }
}

/// The builder-facing observation request: cadence plus sinks.
///
/// ```
/// use adapar::ObservePlan;
///
/// let plan = ObservePlan::every(2_000).csv("target/epidemic.csv");
/// assert!(plan.active());
/// ```
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ObservePlan {
    /// Epoch cadence in canonical tasks (`0` = final frame only).
    pub every: u64,
    /// Sinks to attach.
    pub sinks: Vec<SinkSpec>,
}

impl ObservePlan {
    /// A plan snapshotting every `n` canonical tasks.
    pub fn every(n: u64) -> Self {
        Self {
            every: n,
            sinks: Vec::new(),
        }
    }

    /// Also write the trace as CSV to `path`.
    pub fn csv<P: Into<PathBuf>>(mut self, path: P) -> Self {
        self.sinks.push(SinkSpec::Csv(path.into()));
        self
    }

    /// Also write the trace as JSON-lines to `path`.
    pub fn jsonl<P: Into<PathBuf>>(mut self, path: P) -> Self {
        self.sinks.push(SinkSpec::JsonLines(path.into()));
        self
    }

    /// Also print a progress line per epoch to stderr.
    pub fn progress(mut self) -> Self {
        self.sinks.push(SinkSpec::Progress);
        self
    }

    /// Whether the engines need epoch snapshots (cadence set).
    pub fn active(&self) -> bool {
        self.every > 0
    }
}

// ---------------------------------------------------------------------------
// Epoch gating of a task source
// ---------------------------------------------------------------------------

/// A [`TaskSource`] adapter that marks epoch boundaries: it hands out the
/// inner source's tasks until the current epoch's budget is spent, then
/// reports exhaustion. The engine drains to quiescence, snapshots, asks
/// [`finished`](EpochGate::finished), and [`open`](EpochGate::open)s the
/// next epoch.
///
/// The canonical task order — and with it every per-task RNG stream — is
/// untouched by epoching: the only lookahead is the single task
/// [`finished`](EpochGate::finished) may buffer, drawn at a quiescent
/// boundary whose state is identical to the start of the next epoch.
pub struct EpochGate<S: TaskSource> {
    inner: S,
    /// Task buffered by [`finished`](EpochGate::finished); served first.
    pending: Option<S::Recipe>,
    emitted: u64,
    budget: u64,
    inner_exhausted: bool,
    /// Optional bounded materialization window (ISSUE 10). The window
    /// lives *inside* the gate — not wrapped around it — because the
    /// gate must distinguish a temporary window stall from the inner
    /// source's true exhaustion (a wrapped `StreamingSource`'s `None`
    /// would be latched as permanent by `next_task`/`finished`).
    window: Option<crate::model::Window>,
}

impl<S: TaskSource> EpochGate<S> {
    /// Wrap a source; the gate starts closed ([`open`](EpochGate::open)
    /// the first epoch before running).
    pub fn new(inner: S) -> Self {
        Self {
            inner,
            pending: None,
            emitted: 0,
            budget: 0,
            inner_exhausted: false,
            window: None,
        }
    }

    /// Clamp emission to a bounded materialization window: `next_task`
    /// returns `None` — reported by [`window_stalled`](Self::window_stalled),
    /// *not* latched as exhaustion — while `emitted - retired` would
    /// reach the cap. Set before the first epoch opens.
    pub fn set_window(&mut self, window: Option<crate::model::Window>) {
        debug_assert_eq!(self.emitted, 0, "window must be set before the run");
        self.window = window;
    }

    /// The window's retirement handle, if a window is installed. The
    /// engine hands this to workers so each erased task reopens window
    /// room.
    pub fn retire_handle(&self) -> Option<crate::model::RetireHandle> {
        self.window.as_ref().map(|w| w.handle())
    }

    /// Whether the last `None` from [`next_task`](TaskSource::next_task)
    /// was a *temporary* window stall: budget remains, the source can
    /// still produce, but the window is full. Engines must treat this as
    /// "keep cycling" (outstanding tasks will retire and reopen room),
    /// never as epoch exhaustion — that is what keeps streaming traces
    /// byte-identical to materialized ones (DESIGN.md §14).
    pub fn window_stalled(&self) -> bool {
        let Some(w) = &self.window else {
            return false;
        };
        self.emitted < self.budget
            && !(self.inner_exhausted && self.pending.is_none())
            && !w.has_room(self.emitted)
    }

    /// Open the next epoch: allow `every` more tasks (`u64::MAX`-safe).
    pub fn open(&mut self, every: u64) {
        self.budget = self.emitted.saturating_add(every);
    }

    /// Canonical tasks emitted so far.
    pub fn emitted(&self) -> u64 {
        self.emitted
    }

    /// Whether the *inner* source is truly exhausted (as opposed to the
    /// epoch budget being spent).
    pub fn source_exhausted(&self) -> bool {
        self.inner_exhausted
    }

    /// Whether the run is over: nothing buffered and the inner source has
    /// no further task. Called by engines at a drained epoch boundary; it
    /// may buffer one task so that a budget spent exactly at exhaustion
    /// does not cost a spurious empty epoch.
    pub fn finished(&mut self) -> bool {
        if self.pending.is_some() {
            return false;
        }
        if self.inner_exhausted {
            return true;
        }
        match self.inner.next_task() {
            Some(recipe) => {
                self.pending = Some(recipe);
                false
            }
            None => {
                self.inner_exhausted = true;
                true
            }
        }
    }
}

impl<S: TaskSource> TaskSource for EpochGate<S> {
    type Recipe = S::Recipe;

    fn next_task(&mut self) -> Option<S::Recipe> {
        if self.emitted >= self.budget {
            return None;
        }
        // Window stall: a *temporary* `None` (window room reappears as
        // workers retire tasks). Checked before the pending/inner draws
        // so a full window never consumes lookahead or latches
        // `inner_exhausted`.
        if let Some(w) = &self.window {
            if !w.has_room(self.emitted) {
                return None;
            }
        }
        if let Some(recipe) = self.pending.take() {
            self.emitted += 1;
            return Some(recipe);
        }
        if self.inner_exhausted {
            return None;
        }
        match self.inner.next_task() {
            Some(recipe) => {
                self.emitted += 1;
                Some(recipe)
            }
            None => {
                self.inner_exhausted = true;
                None
            }
        }
    }

    fn size_hint(&self) -> Option<u64> {
        self.inner
            .size_hint()
            .map(|n| n + u64::from(self.pending.is_some()))
    }

    fn stalled(&self) -> bool {
        self.window_stalled()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_count_boundary_math() {
        // Final-only cadence.
        assert_eq!(frame_count(0, 400), 1);
        // Exact division: 0, E, 2E, ..., T.
        assert_eq!(frame_count(100, 400), 5);
        // Partial last epoch adds one frame.
        assert_eq!(frame_count(150, 400), 4); // 0, 150, 300, 400
        // Epoch longer than the whole run: initial + final.
        assert_eq!(frame_count(10_000, 400), 2);
        // Degenerate runs.
        assert_eq!(frame_count(10, 0), 1);
        assert_eq!(frame_count(1, 3), 4); // 0, 1, 2, 3
    }

    #[test]
    fn observer_dedups_coinciding_final_frame() {
        let mut obs = Observer::new(100);
        assert!(obs.due(100) && obs.due(200) && !obs.due(150) && !obs.due(0));
        obs.record(0, vec![]);
        obs.record(100, vec![]);
        obs.record(200, vec![]);
        obs.record(200, vec![]); // final boundary == last epoch
        let trace = obs.finish().unwrap();
        assert_eq!(trace.len(), 3);
        assert_eq!(
            trace.frames.iter().map(|f| f.tasks).collect::<Vec<_>>(),
            vec![0, 100, 200]
        );
        assert_eq!(trace.every, 100);
    }

    #[test]
    fn epoch_gate_budget_and_resume() {
        struct Seq(u64, u64); // next, total
        impl TaskSource for Seq {
            type Recipe = u64;
            fn next_task(&mut self) -> Option<u64> {
                if self.0 >= self.1 {
                    return None;
                }
                self.0 += 1;
                Some(self.0 - 1)
            }
            fn size_hint(&self) -> Option<u64> {
                Some(self.1 - self.0)
            }
        }
        let mut gate = EpochGate::new(Seq(0, 10));
        assert_eq!(gate.next_task(), None, "gate starts closed");
        gate.open(4);
        assert_eq!(
            std::iter::from_fn(|| gate.next_task()).collect::<Vec<_>>(),
            vec![0, 1, 2, 3]
        );
        assert!(!gate.finished(), "more tasks remain (one gets buffered)");
        assert_eq!(gate.emitted(), 4);
        assert_eq!(gate.size_hint(), Some(6), "buffered task still counts");
        gate.open(4);
        assert_eq!(
            std::iter::from_fn(|| gate.next_task()).collect::<Vec<_>>(),
            vec![4, 5, 6, 7],
            "the buffered task is served first, in canonical order"
        );
        assert!(!gate.finished());
        gate.open(4); // partial final epoch
        assert_eq!(
            std::iter::from_fn(|| gate.next_task()).collect::<Vec<_>>(),
            vec![8, 9]
        );
        assert!(gate.finished());
        assert!(gate.source_exhausted());
        assert_eq!(gate.emitted(), 10);
        gate.open(4);
        assert_eq!(gate.next_task(), None, "exhaustion is permanent");
    }

    #[test]
    fn epoch_gate_exact_division_needs_no_extra_epoch() {
        struct Seq(u64, u64);
        impl TaskSource for Seq {
            type Recipe = u64;
            fn next_task(&mut self) -> Option<u64> {
                if self.0 >= self.1 {
                    return None;
                }
                self.0 += 1;
                Some(self.0 - 1)
            }
        }
        let mut gate = EpochGate::new(Seq(0, 8));
        gate.open(8);
        assert_eq!(std::iter::from_fn(|| gate.next_task()).count(), 8);
        assert!(
            gate.finished(),
            "budget spent exactly at exhaustion must not cost an empty epoch"
        );
    }

    #[test]
    fn obsvalue_display_and_json() {
        let census = ObsValue::counts([("S", 3), ("I", 2), ("R", 1)]);
        assert_eq!(census.to_string(), "{S=3 I=2 R=1}");
        assert_eq!(census.to_json().render(), r#"{"S":3,"I":2,"R":1}"#);
        assert_eq!(ObsValue::Float(0.25).to_string(), "0.25");
        assert_eq!(ObsValue::Int(-4).to_string(), "-4");
        assert_eq!(ObsValue::Series(vec![1.0, 2.5]).to_string(), "[1,2.5]");
        assert_eq!(
            ObsValue::Series(vec![1.0, 2.5]).to_json().render(),
            "[1,2.5]"
        );
    }

    #[test]
    fn frame_and_trace_display() {
        let frame = ObsFrame {
            tasks: 40,
            values: vec![
                ("census".into(), ObsValue::counts([("S", 9), ("I", 1)])),
                ("m".into(), ObsValue::Float(0.5)),
            ],
        };
        assert_eq!(frame.to_string(), "census={S=9 I=1} m=0.5");
        let trace = Observations {
            every: 20,
            frames: vec![frame.clone()],
        };
        assert_eq!(trace.to_string(), frame.to_string());
        assert_eq!(trace.value("m"), Some(&ObsValue::Float(0.5)));
        assert_eq!(trace.series("m"), vec![(40, &ObsValue::Float(0.5))]);
        assert_eq!(Observations::empty().to_string(), "(no observations)");
    }

    #[test]
    fn csv_flattening() {
        let frame = ObsFrame {
            tasks: 7,
            values: vec![
                ("census".into(), ObsValue::counts([("S", 9), ("I", 1)])),
                ("m".into(), ObsValue::Float(0.5)),
                ("h".into(), ObsValue::Series(vec![1.0, 2.0])),
            ],
        };
        assert_eq!(csv_columns(&frame), vec!["tasks", "census.S", "census.I", "m", "h"]);
        assert_eq!(csv_cells(&frame), vec!["7", "9", "1", "0.5", "1;2"]);
    }

    #[test]
    fn observations_json_shape() {
        let trace = Observations {
            every: 5,
            frames: vec![ObsFrame {
                tasks: 0,
                values: vec![("m".into(), ObsValue::Int(3))],
            }],
        };
        assert_eq!(
            trace.to_json().render(),
            r#"{"every":5,"frames":[{"tasks":0,"m":3}]}"#
        );
    }
}
