//! The dynamic model registry: `name + parameter bag → runnable model`.
//!
//! The paper's plug-in concept (§3.5) made a first-class runtime feature:
//! the coordinator, CLI and sweep configs refer to models purely by name,
//! and the registry maps that name — plus a [`Params`] bag of
//! model-specific knobs from the TOML config / CLI — to a type-erased
//! [`DynModel`]. The five bundled models self-register into the global
//! registry on first use; downstream code (see `examples/custom_model.rs`)
//! registers its own with [`register`], after which the model is runnable
//! from the CLI and sweep configs with **zero** coordinator edits.

use std::collections::BTreeMap;
use std::sync::{Arc, OnceLock, RwLock};

use crate::api::model::{DynModel, Runnable};
use crate::error::Result;
use crate::util::toml::Value;

/// A model-specific parameter bag (string-keyed TOML scalars).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Params(BTreeMap<String, Value>);

impl Params {
    /// Empty bag.
    pub fn new() -> Self {
        Self::default()
    }

    /// Build from a parsed TOML table.
    pub fn from_table(table: &BTreeMap<String, Value>) -> Self {
        Self(table.clone())
    }

    /// Whether the bag holds no keys.
    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    /// Set a key.
    pub fn set(&mut self, key: impl Into<String>, value: impl Into<Value>) -> &mut Self {
        self.0.insert(key.into(), value.into());
        self
    }

    /// Raw value by key.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.0.get(key)
    }

    /// Merge another bag into this one, key by key (`other` wins on
    /// conflicts).
    pub fn merge(&mut self, other: &Params) {
        for (k, v) in &other.0 {
            self.0.insert(k.clone(), v.clone());
        }
    }

    /// Iterate keys.
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.0.keys().map(String::as_str)
    }

    /// Integer parameter with default.
    pub fn i64_or(&self, key: &str, default: i64) -> Result<i64> {
        match self.0.get(key) {
            None => Ok(default),
            Some(v) => v
                .as_int()
                .ok_or_else(|| crate::err!("param `{key}` must be an integer, got {v:?}")),
        }
    }

    /// `usize` parameter with default.
    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        let v = self.i64_or(key, default as i64)?;
        crate::ensure!(v >= 0, "param `{key}` must be non-negative, got {v}");
        Ok(v as usize)
    }

    /// `u64` parameter with default.
    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        let v = self.i64_or(key, default as i64)?;
        crate::ensure!(v >= 0, "param `{key}` must be non-negative, got {v}");
        Ok(v as u64)
    }

    /// Float parameter with default.
    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.0.get(key) {
            None => Ok(default),
            Some(v) => v
                .as_float()
                .ok_or_else(|| crate::err!("param `{key}` must be a number, got {v:?}")),
        }
    }

    /// Boolean parameter with default.
    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        match self.0.get(key) {
            None => Ok(default),
            Some(v) => v
                .as_bool()
                .ok_or_else(|| crate::err!("param `{key}` must be a boolean, got {v:?}")),
        }
    }
}

/// Registry metadata for one model: name, aliases, and the per-model
/// workload defaults the launcher layers used to hardcode.
#[derive(Clone, Debug)]
pub struct ModelInfo {
    /// Canonical registry key.
    pub name: String,
    /// Accepted alternative names.
    pub aliases: Vec<String>,
    /// One-line description.
    pub summary: String,
    /// Default task-size proxy grid for sweeps.
    pub default_sizes: Vec<usize>,
    /// Default agent count (scaled workload).
    pub default_agents: usize,
    /// Agent count at the paper's full scale.
    pub paper_agents: usize,
    /// Default step count (scaled workload).
    pub default_steps: u64,
    /// Step count at the paper's full scale.
    pub paper_steps: u64,
    /// Shrunk step count for determinism validation runs.
    pub validate_steps: u64,
    /// Whether the model has a synchronous form (stepwise-capable).
    pub has_sync_form: bool,
    /// Whether the model exposes a footprint topology (sharded-capable).
    pub has_sharded_form: bool,
}

impl ModelInfo {
    /// New info with conservative defaults; refine with the builder
    /// methods.
    pub fn new(name: impl Into<String>, summary: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            aliases: Vec::new(),
            summary: summary.into(),
            default_sizes: vec![1],
            default_agents: 1_000,
            paper_agents: 1_000,
            default_steps: 10_000,
            paper_steps: 10_000,
            validate_steps: 10_000,
            has_sync_form: false,
            has_sharded_form: false,
        }
    }

    /// Set accepted aliases.
    pub fn aliases(mut self, aliases: &[&str]) -> Self {
        self.aliases = aliases.iter().map(|s| s.to_string()).collect();
        self
    }

    /// Set the default sweep grid.
    pub fn sizes(mut self, sizes: &[usize]) -> Self {
        self.default_sizes = sizes.to_vec();
        self
    }

    /// Set scaled/paper agent counts.
    pub fn agents(mut self, scaled: usize, paper: usize) -> Self {
        self.default_agents = scaled;
        self.paper_agents = paper;
        self
    }

    /// Set scaled/paper step counts.
    pub fn steps(mut self, scaled: u64, paper: u64) -> Self {
        self.default_steps = scaled;
        self.paper_steps = paper;
        self
    }

    /// Set the validation-run step count.
    pub fn validate_steps(mut self, steps: u64) -> Self {
        self.validate_steps = steps;
        self
    }

    /// Mark the model stepwise-capable.
    pub fn sync(mut self) -> Self {
        self.has_sync_form = true;
        self
    }

    /// Mark the model sharded-capable.
    pub fn sharded(mut self) -> Self {
        self.has_sharded_form = true;
        self
    }

    /// Names of the engines this model supports, in [`EngineKind::ALL`]
    /// order — the single source of truth for the CLI listings and the
    /// cross-engine conformance matrix.
    ///
    /// [`EngineKind::ALL`]: crate::api::EngineKind::ALL
    pub fn engines(&self) -> Vec<&'static str> {
        crate::api::EngineKind::ALL
            .iter()
            .filter(|&&k| self.supports(k))
            .map(|&k| k.name())
            .collect()
    }

    /// Whether the model can run on `kind` (stepwise needs a synchronous
    /// form, sharded a footprint topology; every model runs on the rest).
    pub fn supports(&self, kind: crate::api::EngineKind) -> bool {
        match kind {
            crate::api::EngineKind::Stepwise => self.has_sync_form,
            crate::api::EngineKind::Sharded => self.has_sharded_form,
            _ => true,
        }
    }

    /// Agent count for a scale.
    pub fn agents_for(&self, paper_scale: bool) -> usize {
        if paper_scale {
            self.paper_agents
        } else {
            self.default_agents
        }
    }

    /// Step count for a scale.
    pub fn steps_for(&self, paper_scale: bool) -> u64 {
        if paper_scale {
            self.paper_steps
        } else {
            self.default_steps
        }
    }
}

/// Everything a factory needs to instantiate a model for one run.
#[derive(Clone, Debug, Default)]
pub struct BuildCtx {
    /// Task-size proxy (`F` for Axelrod, `s` for SIR; model-defined).
    pub size: usize,
    /// Number of agents `N`.
    pub agents: usize,
    /// Number of steps.
    pub steps: u64,
    /// Simulation seed (factories derive their init streams from it).
    pub seed: u64,
    /// Agent-state storage layout (semantically inert; DESIGN.md §13).
    /// Factories of packed-capable models must pass this through.
    pub layout: crate::sim::soa::Layout,
    /// Model-specific knobs.
    pub params: Params,
}

type Factory = Arc<dyn Fn(&BuildCtx) -> Result<Box<dyn DynModel>> + Send + Sync>;

struct ModelEntry {
    info: ModelInfo,
    factory: Factory,
}

/// A model registry. Most callers use the process-global one (via
/// [`register`], [`build`], [`info`]); tests may hold private instances.
#[derive(Default)]
pub struct Registry {
    entries: BTreeMap<String, ModelEntry>,
    aliases: BTreeMap<String, String>,
}

impl Registry {
    /// An empty registry.
    pub fn empty() -> Self {
        Self::default()
    }

    /// A registry pre-loaded with the five bundled models.
    pub fn bundled() -> Self {
        let mut r = Self::empty();
        bundled::register_all(&mut r).expect("bundled model registration cannot conflict");
        r
    }

    /// Register a model. Errors if the name or an alias is already taken.
    pub fn register<F>(&mut self, info: ModelInfo, factory: F) -> Result<()>
    where
        F: Fn(&BuildCtx) -> Result<Box<dyn DynModel>> + Send + Sync + 'static,
    {
        crate::ensure!(
            !self.entries.contains_key(&info.name) && !self.aliases.contains_key(&info.name),
            "model `{}` is already registered",
            info.name
        );
        for a in &info.aliases {
            crate::ensure!(
                !self.entries.contains_key(a) && !self.aliases.contains_key(a),
                "model alias `{a}` is already registered"
            );
        }
        for a in &info.aliases {
            self.aliases.insert(a.clone(), info.name.clone());
        }
        self.entries.insert(
            info.name.clone(),
            ModelEntry {
                info,
                factory: Arc::new(factory),
            },
        );
        Ok(())
    }

    fn resolve(&self, name: &str) -> Result<&ModelEntry> {
        let key = self.aliases.get(name).map(String::as_str).unwrap_or(name);
        self.entries.get(key).ok_or_else(|| {
            crate::err!(
                "unknown model `{name}`; registered models: {}",
                self.names().join("|")
            )
        })
    }

    /// Canonical names of all registered models, sorted.
    pub fn names(&self) -> Vec<String> {
        self.entries.keys().cloned().collect()
    }

    /// Metadata of every registered model, in name order — the
    /// registry-driven iteration surface the conformance matrix and the
    /// CLI listings are built on (any future registration is
    /// automatically covered).
    pub fn models(&self) -> Vec<ModelInfo> {
        self.entries.values().map(|e| e.info.clone()).collect()
    }

    /// Whether a name (or alias) is registered.
    pub fn contains(&self, name: &str) -> bool {
        self.resolve(name).is_ok()
    }

    /// Metadata for a model.
    pub fn info(&self, name: &str) -> Result<ModelInfo> {
        Ok(self.resolve(name)?.info.clone())
    }

    /// Instantiate a model for one run.
    pub fn build(&self, name: &str, ctx: &BuildCtx) -> Result<Box<dyn DynModel>> {
        (self.resolve(name)?.factory)(ctx)
    }

    /// The factory for a model, cloned out (lets the global wrappers drop
    /// the registry lock before running it — factories may re-enter the
    /// registry).
    fn factory(&self, name: &str) -> Result<Factory> {
        Ok(Arc::clone(&self.resolve(name)?.factory))
    }
}

fn global() -> &'static RwLock<Registry> {
    static GLOBAL: OnceLock<RwLock<Registry>> = OnceLock::new();
    GLOBAL.get_or_init(|| RwLock::new(Registry::bundled()))
}

/// Register a model in the process-global registry.
pub fn register<F>(info: ModelInfo, factory: F) -> Result<()>
where
    F: Fn(&BuildCtx) -> Result<Box<dyn DynModel>> + Send + Sync + 'static,
{
    global().write().unwrap().register(info, factory)
}

/// Metadata for a globally-registered model.
pub fn info(name: &str) -> Result<ModelInfo> {
    global().read().unwrap().info(name)
}

/// Instantiate a globally-registered model. The registry lock is released
/// before the factory runs, so factories may themselves call back into
/// the registry (e.g. composite models building sub-models).
pub fn build(name: &str, ctx: &BuildCtx) -> Result<Box<dyn DynModel>> {
    let factory = global().read().unwrap().factory(name)?;
    factory(ctx)
}

/// Names of all globally-registered models.
pub fn model_names() -> Vec<String> {
    global().read().unwrap().names()
}

/// Metadata of every globally-registered model, in name order.
pub fn models() -> Vec<ModelInfo> {
    global().read().unwrap().models()
}

/// Whether a name (or alias) is globally registered.
pub fn is_registered(name: &str) -> bool {
    global().read().unwrap().contains(name)
}

mod bundled {
    //! Self-registration of the five bundled models. The factories carry
    //! over the launcher's historical parameter mapping exactly (init-seed
    //! xors included) so results stay bit-identical to the pre-registry
    //! dispatch.

    use super::*;
    use crate::models::axelrod::{AxelrodModel, AxelrodParams};
    use crate::models::ising::{IsingModel, IsingParams};
    use crate::models::schelling::{SchellingModel, SchellingParams};
    use crate::models::sir::{SirModel, SirParams};
    use crate::models::voter::{VoterModel, VoterParams};
    use crate::sim::graph::ring_lattice;

    pub(super) fn register_all(r: &mut Registry) -> Result<()> {
        register_axelrod(r)?;
        register_sir(r)?;
        register_voter(r)?;
        register_ising(r)?;
        register_schelling(r)?;
        Ok(())
    }

    fn register_axelrod(r: &mut Registry) -> Result<()> {
        let info = ModelInfo::new("axelrod", "Axelrod cultural dynamics (paper §4.1, Fig. 2)")
            .aliases(&["cultural"])
            .sizes(&[25, 50, 100, 200, 400, 800])
            .agents(2_000, 10_000)
            .steps(60_000, 2_000_000)
            .validate_steps(20_000)
            .sharded();
        r.register(info, |ctx| {
            let params = AxelrodParams {
                agents: ctx.agents,
                features: ctx.size.max(1),
                traits: ctx.params.usize_or("traits", 3)? as u8,
                omega: ctx.params.f64_or("omega", 0.95)?,
                steps: ctx.steps,
            };
            let model = AxelrodModel::new(params, ctx.seed ^ 0x1217);
            Ok(Runnable::new("axelrod", model)
                .observable()
                .with_sharding()
                .boxed())
        })
    }

    fn register_sir(r: &mut Registry) -> Result<()> {
        let info = ModelInfo::new("sir", "SIR epidemic on a ring lattice (paper §4.2, Fig. 3)")
            .aliases(&["epidemic"])
            .sizes(&[10, 20, 50, 100, 200, 500, 1000])
            .agents(4_000, 4_000)
            .steps(120, 3_000)
            .validate_steps(60)
            .sync()
            .sharded();
        r.register(info, |ctx| {
            let params = SirParams {
                agents: ctx.agents,
                subset_size: ctx.size.max(1),
                steps: ctx.steps,
                degree: ctx.params.usize_or("degree", SirParams::default().degree)?,
                p_si: ctx.params.f64_or("p_si", SirParams::default().p_si)?,
                p_ir: ctx.params.f64_or("p_ir", SirParams::default().p_ir)?,
                p_rs: ctx.params.f64_or("p_rs", SirParams::default().p_rs)?,
                initial_infected: ctx
                    .params
                    .f64_or("initial_infected", SirParams::default().initial_infected)?,
                // Scale-tier contact graph (ISSUE 10): extra seeded
                // long-range strides; 0 keeps the paper's ring lattice.
                long_links: ctx.params.usize_or("long_links", 0)?,
            };
            let model = SirModel::with_layout(params, ctx.seed ^ 0x51, ctx.layout);
            Ok(Runnable::new("sir", model)
                .observable()
                .with_sync()
                .with_sharding()
                .boxed())
        })
    }

    fn register_voter(r: &mut Registry) -> Result<()> {
        let info = ModelInfo::new("voter", "voter model on a ring lattice (extra)")
            .sizes(&[1])
            .agents(2_000, 2_000)
            .steps(100_000, 100_000)
            .validate_steps(20_000)
            .sharded();
        r.register(info, |ctx| {
            let degree = ctx.params.usize_or("degree", 6)?;
            let opinions = ctx.params.usize_or("opinions", 3)? as u8;
            let model = VoterModel::with_layout(
                ring_lattice(ctx.agents, degree),
                VoterParams {
                    opinions,
                    steps: ctx.steps,
                },
                ctx.seed ^ 0x70,
                ctx.layout,
            );
            Ok(Runnable::new("voter", model)
                .observable()
                .with_sharding()
                .boxed())
        })
    }

    fn register_ising(r: &mut Registry) -> Result<()> {
        let info = ModelInfo::new("ising", "Ising/Glauber dynamics on a 2D torus (extra)")
            .sizes(&[1])
            .agents(64 * 64, 64 * 64)
            .steps(100_000, 100_000)
            .validate_steps(20_000)
            .sharded();
        r.register(info, |ctx| {
            let side = ((ctx.agents as f64).sqrt() as usize).max(8);
            let params = IsingParams {
                side: ctx.params.usize_or("side", side)?,
                temperature: ctx.params.f64_or("temperature", 2.269)?,
                steps: ctx.steps,
            };
            let model = IsingModel::with_layout(params, ctx.seed ^ 0x15, ctx.layout);
            Ok(Runnable::new("ising", model)
                .observable()
                .with_sharding()
                .boxed())
        })
    }

    fn register_schelling(r: &mut Registry) -> Result<()> {
        let info = ModelInfo::new(
            "schelling",
            "Schelling segregation with moving agents (future-work extension)",
        )
        .sizes(&[1])
        .agents(1_800, 1_800)
        .steps(100_000, 100_000)
        .validate_steps(20_000)
        .sharded();
        r.register(info, |ctx| {
            // ~78% occupancy on the smallest torus that fits `agents`.
            let side = ((ctx.agents as f64 / 0.78).sqrt().ceil() as usize).max(8);
            let params = SchellingParams {
                side: ctx.params.usize_or("side", side)?,
                agents: ctx.agents,
                tolerance: ctx.params.f64_or("tolerance", 0.4)?,
                steps: ctx.steps,
                // 0 keeps the classic unbounded relocation; sharded runs
                // want a bound (e.g. --move-radius 2) for locality.
                move_radius: ctx.params.usize_or("move_radius", 0)?,
            };
            let model = SchellingModel::new(params, ctx.seed ^ 0x5C);
            Ok(Runnable::new("schelling", model)
                .observable()
                .checked(|m| m.check_consistency())
                .with_sharding()
                .boxed())
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bundled_registry_knows_all_five_models() {
        let r = Registry::bundled();
        assert_eq!(
            r.names(),
            vec!["axelrod", "ising", "schelling", "sir", "voter"]
        );
        assert!(r.contains("cultural"), "alias resolves");
        assert!(r.info("sir").unwrap().has_sync_form);
        assert!(!r.info("axelrod").unwrap().has_sync_form);
        for name in ["sir", "voter", "axelrod", "ising", "schelling"] {
            assert!(r.info(name).unwrap().has_sharded_form, "{name}");
        }
        let infos = r.models();
        assert_eq!(
            infos.iter().map(|i| i.name.as_str()).collect::<Vec<_>>(),
            r.names(),
            "models() iterates in name order"
        );
    }

    #[test]
    fn model_info_reports_engine_support() {
        use crate::api::EngineKind;
        let r = Registry::bundled();
        let sir = r.info("sir").unwrap();
        assert_eq!(
            sir.engines(),
            vec!["parallel", "sequential", "virtual", "stepwise", "sharded"]
        );
        assert!(sir.supports(EngineKind::Stepwise));
        let ising = r.info("ising").unwrap();
        assert_eq!(
            ising.engines(),
            vec!["parallel", "sequential", "virtual", "sharded"]
        );
        assert!(!ising.supports(EngineKind::Stepwise));
        assert!(ising.supports(EngineKind::Sharded));
        let bare = ModelInfo::new("bare", "no capabilities");
        assert_eq!(bare.engines(), vec!["parallel", "sequential", "virtual"]);
    }

    #[test]
    fn unknown_model_error_lists_registered_names() {
        let r = Registry::bundled();
        let e = r.info("nope").unwrap_err().to_string();
        assert!(e.contains("unknown model `nope`"), "{e}");
        for name in ["axelrod", "ising", "schelling", "sir", "voter"] {
            assert!(e.contains(name), "{e} should list {name}");
        }
    }

    #[test]
    fn duplicate_registration_is_rejected() {
        let mut r = Registry::bundled();
        let err = r.register(ModelInfo::new("sir", "dup"), |_| {
            unreachable!("factory never called")
        });
        assert!(err.is_err());
        let err = r.register(ModelInfo::new("fresh", "aliased dup").aliases(&["cultural"]), |_| {
            unreachable!("factory never called")
        });
        assert!(err.is_err());
    }

    #[test]
    fn factory_builds_with_param_overrides() {
        let r = Registry::bundled();
        let mut params = Params::new();
        params.set("omega", 0.5).set("traits", 4i64);
        let m = r
            .build(
                "axelrod",
                &BuildCtx {
                    size: 8,
                    agents: 50,
                    steps: 10,
                    seed: 1,
                    layout: Default::default(),
                    params,
                },
            )
            .unwrap();
        assert_eq!(m.name(), "axelrod");
        let rep = m.run_sequential(1, crate::trace::TraceMode::Off, None);
        assert_eq!(rep.totals.executed, 10);
    }

    #[test]
    fn params_typed_getters() {
        let mut p = Params::new();
        p.set("n", 42i64).set("x", 1.5).set("flag", true).set("s", "hi");
        assert_eq!(p.usize_or("n", 0).unwrap(), 42);
        assert_eq!(p.u64_or("missing", 7).unwrap(), 7);
        assert_eq!(p.f64_or("x", 0.0).unwrap(), 1.5);
        assert_eq!(p.f64_or("n", 0.0).unwrap(), 42.0, "ints coerce to float");
        assert!(p.bool_or("flag", false).unwrap());
        assert!(p.usize_or("s", 0).is_err(), "type mismatch is an error");
    }
}
