//! The builder-style [`Simulation`] facade — the single entry point for
//! running one simulation, used by the CLI, the sweep coordinator, the
//! benches and the examples.
//!
//! ```no_run
//! use adapar::{EngineKind, ObservePlan, Simulation};
//!
//! let out = Simulation::builder()
//!     .model("sir")
//!     .agents(1_000_000)
//!     .engine(EngineKind::Parallel)
//!     .workers(8)
//!     .seed(7)
//!     // Snapshot the typed metrics every 50k tasks → an epidemic curve.
//!     .observe(ObservePlan::every(50_000).csv("target/epidemic.csv"))
//!     .run()?;
//! println!("{}: T={}s {}", out.report.engine, out.report.time_s, out.observable);
//! for (tasks, census) in out.observable.series("census") {
//!     println!("{tasks}: {census}");
//! }
//! # Ok::<(), adapar::error::Error>(())
//! ```
//!
//! Models are resolved by name through the [registry](crate::api::registry),
//! so anything registered there — bundled or user-defined — runs on any
//! legal engine with no launcher edits. Observation traces are
//! deterministic: the engines snapshot only at drained epoch boundaries,
//! so the trace above is byte-identical across engines and worker counts
//! at a fixed seed.

use crate::api::engine::{engine_for, EngineKind};
use crate::api::observe::{Observations, ObservePlan, Observer};
use crate::api::registry::{self, BuildCtx, Params};
use crate::error::Result;
use crate::protocol::{ProtocolConfig, RunReport};
use crate::sim::soa::Layout;
use crate::telemetry::TelemetryMode;
use crate::trace::TraceMode;
use crate::util::toml::Value;
use crate::vtime::CostModel;

/// Outcome of one facade run.
#[derive(Clone, Debug)]
pub struct SimOutcome {
    /// The engine's unified report (timings + protocol counters).
    pub report: RunReport,
    /// The typed observation trace. Without an observation plan this
    /// holds exactly one frame — the final state — so `Display` (and
    /// structural comparison) replace the old post-run string.
    pub observable: Observations,
}

/// A fully-specified single simulation. Build with
/// [`Simulation::builder`]; `0` values for `agents`/`steps`/`size` mean
/// "use the model's registered default".
#[derive(Clone, Debug)]
pub struct Simulation {
    /// Registry name of the model.
    pub model: String,
    /// Engine selector.
    pub engine: EngineKind,
    /// Worker count `n`.
    pub workers: usize,
    /// Per-cycle creation cap `C`.
    pub tasks_per_cycle: u32,
    /// Creation/routing batch size `B` (tasks linked per tail-lock
    /// acquisition on the chain engines; `1` = classic unbatched
    /// protocol). Trace-invariant: any value yields the same results.
    pub batch: u32,
    /// Streaming materialization window `W` (DESIGN.md §14): at most
    /// this many tasks are live per chain engine at any instant; `0` =
    /// fully materialized. Result-invariant like `batch`; only memory
    /// (`chain.arena_high_water`) changes. Defaults from
    /// `ADAPAR_WINDOW`/`ADAPAR_STREAMING`.
    pub window: u64,
    /// Simulation seed.
    pub seed: u64,
    /// Agent count `N` (0 = model default).
    pub agents: usize,
    /// Step count (0 = model default).
    pub steps: u64,
    /// Task-size proxy (0 = first of the model's default grid).
    pub size: usize,
    /// Use the paper's full workload defaults.
    pub paper_scale: bool,
    /// Model-specific parameter bag.
    pub params: Params,
    /// Cost model for the virtual testbed (None = built-in defaults).
    pub cost: Option<CostModel>,
    /// Observation request: epoch cadence + sinks.
    pub observe: ObservePlan,
    /// Telemetry sampling mode (semantically inert; defaults from
    /// `ADAPAR_TELEMETRY`).
    pub telemetry: TelemetryMode,
    /// Causal-tracing mode (semantically inert; defaults from
    /// `ADAPAR_TRACE`).
    pub trace: TraceMode,
    /// Agent-state storage layout (semantically inert; defaults from
    /// `ADAPAR_LAYOUT`, see DESIGN.md §13).
    pub layout: Layout,
}

impl Default for Simulation {
    fn default() -> Self {
        Self {
            model: "axelrod".to_string(),
            engine: EngineKind::Parallel,
            workers: ProtocolConfig::default().workers,
            tasks_per_cycle: 6,
            batch: ProtocolConfig::default().batch,
            window: crate::model::stream::env_window(),
            seed: 1,
            agents: 0,
            steps: 0,
            size: 0,
            paper_scale: false,
            params: Params::new(),
            cost: None,
            observe: ObservePlan::default(),
            telemetry: TelemetryMode::env_default(),
            trace: TraceMode::env_default(),
            layout: Layout::env_default(),
        }
    }
}

impl Simulation {
    /// Start building a simulation.
    pub fn builder() -> SimulationBuilder {
        SimulationBuilder {
            sim: Simulation::default(),
        }
    }

    /// Run to completion: registry lookup → engine dispatch (with epoch
    /// observation when requested) → post-run consistency check.
    pub fn run(&self) -> Result<SimOutcome> {
        let info = registry::info(&self.model)?;
        let ctx = BuildCtx {
            size: if self.size != 0 {
                self.size
            } else {
                info.default_sizes.first().copied().unwrap_or(1)
            },
            agents: if self.agents != 0 {
                self.agents
            } else {
                info.agents_for(self.paper_scale)
            },
            steps: if self.steps != 0 {
                self.steps
            } else {
                info.steps_for(self.paper_scale)
            },
            seed: self.seed,
            layout: self.layout,
            params: self.params.clone(),
        };
        crate::ensure!(self.workers >= 1, "workers must be >= 1");
        crate::ensure!(self.tasks_per_cycle >= 1, "tasks_per_cycle must be >= 1");
        crate::ensure!(self.batch >= 1, "batch must be >= 1");
        let model = registry::build(&self.model, &ctx)?;
        let engine = engine_for(
            self.engine,
            self.workers,
            self.tasks_per_cycle,
            self.batch,
            self.window,
            self.seed,
            self.cost.unwrap_or_default(),
            self.telemetry,
            self.trace,
        );

        // Materialize the observation pipeline: the in-memory trace is
        // always produced; sinks and pre-sizing come from the plan and
        // the source's size hint. The hint builds a throwaway source, so
        // it is only computed when something consumes it.
        let mut observer = Observer::new(self.observe.every);
        if self.observe.active() || !self.observe.sinks.is_empty() {
            let hint = model.task_count_hint(self.seed);
            observer.reserve_for(hint);
            for spec in &self.observe.sinks {
                observer.add_sink(spec.build(hint)?);
            }
        }

        let report = if self.observe.active() {
            engine.run_observed(model.as_ref(), Some(&mut observer))?
        } else {
            engine.run(model.as_ref())?
        };
        model.check_consistency()?;
        // The final frame: a no-op when the observed run already recorded
        // it (same task count), the whole trace when cadence was 0.
        observer.record(report.chain.tasks_executed, model.observe());
        Ok(SimOutcome {
            report,
            observable: observer.finish()?,
        })
    }
}

/// Builder for [`Simulation`].
#[derive(Clone, Debug, Default)]
pub struct SimulationBuilder {
    sim: Simulation,
}

impl SimulationBuilder {
    /// Model registry name (or alias).
    pub fn model(mut self, name: impl Into<String>) -> Self {
        self.sim.model = name.into();
        self
    }

    /// Execution engine.
    pub fn engine(mut self, kind: EngineKind) -> Self {
        self.sim.engine = kind;
        self
    }

    /// Execution engine by name (`"parallel"`, `"virtual"`, ...).
    pub fn engine_name(mut self, name: &str) -> Result<Self> {
        self.sim.engine = name.parse()?;
        Ok(self)
    }

    /// Worker count `n`.
    pub fn workers(mut self, n: usize) -> Self {
        self.sim.workers = n;
        self
    }

    /// Per-cycle creation cap `C`.
    pub fn tasks_per_cycle(mut self, c: u32) -> Self {
        self.sim.tasks_per_cycle = c;
        self
    }

    /// Creation/routing batch size `B` (`1` = classic unbatched
    /// protocol; results are identical at any value).
    pub fn batch(mut self, b: u32) -> Self {
        self.sim.batch = b;
        self
    }

    /// Streaming materialization window `W` (`0` = fully materialized;
    /// results are identical at any value — only peak memory changes).
    pub fn window(mut self, w: u64) -> Self {
        self.sim.window = w;
        self
    }

    /// Simulation seed.
    pub fn seed(mut self, seed: u64) -> Self {
        self.sim.seed = seed;
        self
    }

    /// Agent count `N` (0 = model default).
    pub fn agents(mut self, n: usize) -> Self {
        self.sim.agents = n;
        self
    }

    /// Step count (0 = model default).
    pub fn steps(mut self, steps: u64) -> Self {
        self.sim.steps = steps;
        self
    }

    /// Task-size proxy (`F`/`s`; 0 = model default).
    pub fn size(mut self, size: usize) -> Self {
        self.sim.size = size;
        self
    }

    /// Use the paper's full workload defaults.
    pub fn paper_scale(mut self, on: bool) -> Self {
        self.sim.paper_scale = on;
        self
    }

    /// Set one model-specific parameter.
    pub fn param(mut self, key: impl Into<String>, value: impl Into<Value>) -> Self {
        self.sim.params.set(key, value);
        self
    }

    /// Replace the whole parameter bag.
    pub fn params(mut self, params: Params) -> Self {
        self.sim.params = params;
        self
    }

    /// Cost model for the virtual testbed.
    pub fn cost(mut self, cost: CostModel) -> Self {
        self.sim.cost = Some(cost);
        self
    }

    /// Request typed observation: epoch cadence plus sinks.
    pub fn observe(mut self, plan: ObservePlan) -> Self {
        self.sim.observe = plan;
        self
    }

    /// Telemetry sampling mode (inert — results are identical in any
    /// mode; only the report's `telemetry` histograms change).
    pub fn telemetry(mut self, mode: TelemetryMode) -> Self {
        self.sim.telemetry = mode;
        self
    }

    /// Causal-tracing mode (inert — results are identical in any mode;
    /// only the report's `trace` timeline changes).
    pub fn trace(mut self, mode: TraceMode) -> Self {
        self.sim.trace = mode;
        self
    }

    /// Agent-state storage layout (inert — every layout yields identical
    /// results; only memory traffic and `chain.bytes_per_task` change).
    pub fn layout(mut self, layout: Layout) -> Self {
        self.sim.layout = layout;
        self
    }

    /// Shorthand: snapshot every `n` canonical tasks (keeps any sinks
    /// already configured via [`observe`](SimulationBuilder::observe)).
    pub fn every(mut self, n: u64) -> Self {
        self.sim.observe.every = n;
        self
    }

    /// Finish building without running.
    pub fn build(self) -> Simulation {
        self.sim
    }

    /// Build and run.
    pub fn run(self) -> Result<SimOutcome> {
        self.sim.run()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::observe::{frame_count, ObsValue};

    #[test]
    fn facade_runs_a_bundled_model_end_to_end() {
        let out = Simulation::builder()
            .model("sir")
            .engine(EngineKind::Parallel)
            .workers(2)
            .agents(200)
            .steps(20)
            .size(20)
            .seed(7)
            .run()
            .unwrap();
        assert!(out.report.totals.executed > 0);
        assert_eq!(out.observable.len(), 1, "no cadence → final frame only");
        assert!(out.observable.to_string().starts_with("census="));
        match out.observable.value("census") {
            Some(ObsValue::Counts(c)) => {
                assert_eq!(c.iter().map(|(_, n)| n).sum::<i64>(), 200);
            }
            other => panic!("expected census counts, got {other:?}"),
        }
        assert_eq!(out.report.engine, "parallel");
    }

    #[test]
    fn facade_is_deterministic_across_engines() {
        let run = |engine| {
            Simulation::builder()
                .model("voter")
                .engine(engine)
                .workers(3)
                .agents(150)
                .steps(2_000)
                .seed(11)
                .run()
                .unwrap()
                .observable
        };
        let seq = run(EngineKind::Sequential);
        assert_eq!(run(EngineKind::Parallel), seq);
        assert_eq!(run(EngineKind::Virtual), seq);
    }

    #[test]
    fn observed_facade_run_yields_a_multi_epoch_trace() {
        let out = Simulation::builder()
            .model("sir")
            .engine(EngineKind::Parallel)
            .workers(2)
            .agents(200)
            .steps(20)
            .size(20)
            .seed(7)
            .observe(ObservePlan::every(64))
            .run()
            .unwrap();
        let total = out.report.totals.executed;
        assert_eq!(total, 20 * 2 * 10, "20 steps × 2 phases × 10 blocks");
        assert_eq!(out.observable.len() as u64, frame_count(64, total));
        assert_eq!(out.observable.frames[0].tasks, 0);
        assert_eq!(out.observable.final_frame().unwrap().tasks, total);
        // Conservation holds in every frame, not just the last.
        for frame in &out.observable.frames {
            match frame.get("census") {
                Some(ObsValue::Counts(c)) => {
                    assert_eq!(c.iter().map(|(_, n)| n).sum::<i64>(), 200, "{frame}");
                }
                other => panic!("expected census counts, got {other:?}"),
            }
        }
    }

    #[test]
    fn batch_flows_from_builder_to_report_and_is_result_invariant() {
        let run = |batch| {
            Simulation::builder()
                .model("voter")
                .engine(EngineKind::Parallel)
                .workers(2)
                .agents(120)
                .steps(1_500)
                .seed(4)
                .batch(batch)
                .run()
                .unwrap()
        };
        let b1 = run(1);
        let b64 = run(64);
        assert_eq!(b1.report.chain.batch, 1);
        assert_eq!(b64.report.chain.batch, 64);
        assert_eq!(
            b1.observable, b64.observable,
            "batching must not change results"
        );
        assert!(
            b1.report.to_json().render().contains("\"batch\":1"),
            "batch must surface in --json reports"
        );
    }

    #[test]
    fn window_flows_from_builder_and_bounds_the_arena() {
        let run = |window| {
            Simulation::builder()
                .model("voter")
                .engine(EngineKind::Parallel)
                .workers(2)
                .agents(120)
                .steps(1_500)
                .seed(4)
                .window(window)
                .run()
                .unwrap()
        };
        let full = run(0);
        let streamed = run(16);
        assert_eq!(
            full.observable, streamed.observable,
            "streaming must not change results"
        );
        // Live tasks never exceed W, so peak occupancy is W + sentinels.
        assert!(
            streamed.report.chain.arena_high_water <= 16 + 2,
            "high_water={}",
            streamed.report.chain.arena_high_water
        );
        assert!(
            streamed.report.chain.arena_high_water < full.report.chain.arena_high_water,
            "streamed {} vs materialized {}",
            streamed.report.chain.arena_high_water,
            full.report.chain.arena_high_water
        );
    }

    #[test]
    fn unknown_model_and_engine_errors_list_choices() {
        let err = Simulation::builder().model("martian").run().unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("unknown model `martian`"), "{msg}");
        assert!(msg.contains("axelrod") && msg.contains("voter"), "{msg}");

        let err = Simulation::builder().engine_name("warp").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("unknown engine `warp`"), "{msg}");
        assert!(msg.contains("parallel") && msg.contains("stepwise"), "{msg}");
    }

    #[test]
    fn stepwise_requires_a_sync_model() {
        let err = Simulation::builder()
            .model("axelrod")
            .engine(EngineKind::Stepwise)
            .agents(100)
            .steps(50)
            .size(5)
            .run()
            .unwrap_err();
        assert!(err.to_string().contains("no synchronous form"));

        let ok = Simulation::builder()
            .model("sir")
            .engine(EngineKind::Stepwise)
            .workers(2)
            .agents(200)
            .steps(10)
            .size(20)
            .run()
            .unwrap();
        assert_eq!(ok.report.engine, "stepwise");
    }
}
