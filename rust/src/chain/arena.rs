//! The node arena: a chunked slab of node slots addressed by
//! generation-tagged [`Handle`]s, with an intrusive lock-free free list
//! so steady-state execution allocates nothing (DESIGN.md §3).
//!
//! Design constraints:
//!
//! * **Stable addresses.** Workers hold `&Slot` references across
//!   blocking operations, so growth must never move existing slots: the
//!   slab is a sequence of doubling chunks (`OnceLock`-published, so
//!   readers pay one atomic load), not a reallocating `Vec`.
//! * **Single allocator, single releaser.** Allocation is serialized by
//!   the chain's creation discipline (tail visitor slot, or the
//!   splitter/erase lock) and release by the erase lock — but the two
//!   race *each other*, so the free list is a tagged Treiber stack
//!   (the tag makes the pop CAS immune to index reuse).
//! * **Stale handles are detectable.** Every slot carries a generation
//!   counter bumped at erase; a [`Handle`] pairs the slot index with the
//!   generation observed at link time, so any later dereference can be
//!   validated (the chain layer does this on arrival and in slot-free
//!   walks — see DESIGN.md §3 for why this kills the recycling ABA).

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::OnceLock;

use super::node::Slot;

/// Maximum number of slab chunks. Chunk 0 holds the pre-sized capacity
/// `c0`; chunk `k ≥ 1` holds `c0 << (k - 1)` slots, so the total
/// addressable capacity is `c0 << (MAX_CHUNKS - 1)` — far beyond the
/// `u32` index space for any real pre-size.
const MAX_CHUNKS: usize = 27;

/// A generation-tagged reference to an arena slot.
///
/// Handles are plain data: copying one neither pins nor leaks anything.
/// A handle is *live* while its generation matches the slot's; erasing
/// the node bumps the slot generation, invalidating every outstanding
/// handle to that incarnation at once.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct Handle {
    pub(crate) idx: u32,
    pub(crate) gen: u32,
}

impl Handle {
    /// The null handle (unlinked ends).
    pub const NONE: Handle = Handle {
        idx: u32::MAX,
        gen: 0,
    };

    /// Whether this is the null handle.
    #[inline]
    pub fn is_none(self) -> bool {
        self.idx == u32::MAX
    }

    /// The slot index (diagnostics / tests; slot reuse means two handles
    /// may share an index while differing in generation).
    #[inline]
    pub fn index(self) -> u32 {
        self.idx
    }

    /// The generation tag observed when the handle was created.
    #[inline]
    pub fn generation(self) -> u32 {
        self.gen
    }
}

/// The slab. See the module docs for the concurrency contract.
pub struct Arena<R> {
    /// `log2` of chunk 0's capacity.
    c0_shift: u32,
    chunks: [OnceLock<Box<[Slot<R>]>>; MAX_CHUNKS],
    /// Bump pointer over never-used slots (allocator-only).
    next_fresh: AtomicU32,
    /// Treiber head: `(tag << 32) | idx`, idx `u32::MAX` = empty.
    free: AtomicU64,
    /// Slots currently backed by initialized chunks.
    capacity: AtomicU32,
    /// Slots currently allocated (live incarnations, incl. sentinels).
    in_use: AtomicU32,
    /// High-water mark of `in_use`.
    high_water: AtomicU32,
    /// Allocations served from the free list (recycle counter).
    recycled: AtomicU64,
}

impl<R> std::fmt::Debug for Arena<R> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Arena")
            .field("capacity", &self.capacity())
            .field("in_use", &self.in_use.load(Ordering::Relaxed))
            .field("high_water", &self.high_water())
            .field("recycled", &self.recycled())
            .finish()
    }
}

impl<R> Arena<R> {
    /// An arena whose first chunk holds at least `cap_hint` slots
    /// (clamped to a sane range and rounded up to a power of two). The
    /// first chunk is allocated eagerly, so a well-hinted run never
    /// grows.
    pub fn with_capacity(cap_hint: usize) -> Self {
        let c0 = cap_hint.clamp(64, 1 << 22).next_power_of_two();
        let arena = Arena {
            c0_shift: c0.trailing_zeros(),
            chunks: std::array::from_fn(|_| OnceLock::new()),
            next_fresh: AtomicU32::new(0),
            free: AtomicU64::new(u32::MAX as u64),
            capacity: AtomicU32::new(0),
            in_use: AtomicU32::new(0),
            high_water: AtomicU32::new(0),
            recycled: AtomicU64::new(0),
        };
        arena.init_chunk(0);
        arena
    }

    /// Locate `idx` in the chunked slab: chunk 0 spans `[0, c0)`, chunk
    /// `k ≥ 1` spans `[c0 << (k-1), c0 << k)` — so the chunk index falls
    /// out of `floor(log2(idx))`.
    #[inline]
    fn locate(&self, idx: u32) -> (usize, usize) {
        debug_assert_ne!(idx, u32::MAX, "dereferencing the null handle");
        if idx < (1u32 << self.c0_shift) {
            (0, idx as usize)
        } else {
            let top = 31 - idx.leading_zeros(); // floor(log2(idx)) ≥ c0_shift
            let chunk = (top - self.c0_shift + 1) as usize;
            (chunk, (idx - (1u32 << top)) as usize)
        }
    }

    /// Number of slots chunk `c` holds.
    fn chunk_len(&self, c: usize) -> usize {
        if c == 0 {
            1usize << self.c0_shift
        } else {
            1usize << (self.c0_shift as usize + c - 1)
        }
    }

    fn init_chunk(&self, c: usize) {
        assert!(c < MAX_CHUNKS, "arena exhausted the u32 index space");
        self.chunks[c].get_or_init(|| {
            let n = self.chunk_len(c);
            self.capacity.fetch_add(n as u32, Ordering::Relaxed);
            (0..n).map(|_| Slot::new()).collect()
        });
    }

    /// The slot behind `idx`. The chunk is always initialized before any
    /// handle with that index escapes the allocator.
    #[inline]
    pub(crate) fn slot(&self, idx: u32) -> &Slot<R> {
        let (c, off) = self.locate(idx);
        let chunk = self.chunks[c]
            .get()
            .expect("handle into an uninitialized arena chunk");
        &chunk[off]
    }

    /// Take a slot: recycled from the free list when possible, fresh
    /// otherwise (growing the slab by a doubling chunk if needed).
    ///
    /// # Concurrency contract
    /// At most one thread allocates at a time (the chain's creation
    /// discipline); allocation may race [`release`](Arena::release).
    pub(crate) fn alloc(&self) -> u32 {
        let idx = match self.pop_free() {
            Some(idx) => {
                self.recycled.fetch_add(1, Ordering::Relaxed);
                idx
            }
            None => {
                let idx = self.next_fresh.load(Ordering::Relaxed);
                if idx >= self.capacity.load(Ordering::Relaxed) {
                    let (c, _) = self.locate(idx);
                    self.init_chunk(c);
                }
                self.next_fresh.store(idx + 1, Ordering::Relaxed);
                idx
            }
        };
        let used = self.in_use.fetch_add(1, Ordering::Relaxed) + 1;
        // Check-before-RMW: the high-water mark rarely moves.
        if used > self.high_water.load(Ordering::Relaxed) {
            self.high_water.fetch_max(used, Ordering::Relaxed);
        }
        idx
    }

    /// Return a slot to the free list.
    ///
    /// # Concurrency contract
    /// At most one thread releases at a time (the erase lock); release
    /// may race [`alloc`](Arena::alloc).
    pub(crate) fn release(&self, idx: u32) {
        self.in_use.fetch_sub(1, Ordering::Relaxed);
        self.push_free(idx);
    }

    fn pop_free(&self) -> Option<u32> {
        let mut head = self.free.load(Ordering::Acquire);
        loop {
            let idx = head as u32;
            if idx == u32::MAX {
                return None;
            }
            let next = self.slot(idx).free_next.load(Ordering::Relaxed);
            let tagged = (head >> 32).wrapping_add(1) & 0xFFFF_FFFF;
            let tagged = (tagged << 32) | next as u64;
            match self.free.compare_exchange_weak(
                head,
                tagged,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => return Some(idx),
                Err(h) => head = h,
            }
        }
    }

    fn push_free(&self, idx: u32) {
        let mut head = self.free.load(Ordering::Acquire);
        loop {
            self.slot(idx).free_next.store(head as u32, Ordering::Relaxed);
            let tagged = (head >> 32).wrapping_add(1) & 0xFFFF_FFFF;
            let tagged = (tagged << 32) | idx as u64;
            match self.free.compare_exchange_weak(
                head,
                tagged,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => return,
                Err(h) => head = h,
            }
        }
    }

    /// Drop initialized growth chunks beyond the smallest prefix whose
    /// capacity covers `keep_slots`, and reset the allocator to a
    /// pristine state — the shrink half of the bounded-arena contract
    /// (DESIGN.md §14): after a burst, `capacity` falls back toward the
    /// live-estimate instead of pinning the peak forever.
    ///
    /// # Quiescence contract
    /// Exclusive access (`&mut self`), plus: the only live slots are the
    /// chain's sentinels, which occupy the lowest indices of chunk 0
    /// (they are allocated first and never released), and no handle into
    /// any chunk survives outside the arena. The chain layer guarantees
    /// this by calling only on a drained chain between epochs. The free
    /// list is rebuilt empty and the bump pointer rewound past the
    /// sentinels, so freed slots in kept chunks become reachable again
    /// through fresh allocation and nothing can reference a dropped
    /// chunk. Chunk 0 is never dropped (the sentinels live there);
    /// `high_water` is deliberately untouched — it reports the run's
    /// true peak.
    pub(crate) fn shrink_on_quiesce(&mut self, keep_slots: usize) {
        let live = self.in_use.load(Ordering::Relaxed);
        debug_assert!(
            (live as usize) <= self.chunk_len(0),
            "live slots must all sit in chunk 0 at quiesce"
        );
        let mut kept = self.chunk_len(0);
        let mut dropped = 0usize;
        for c in 1..MAX_CHUNKS {
            if self.chunks[c].get().is_none() {
                continue;
            }
            let len = self.chunk_len(c);
            if kept >= keep_slots {
                // Once the kept prefix covers the target, every later
                // chunk goes: kept chunks stay a contiguous prefix, as
                // `locate` requires.
                self.chunks[c] = OnceLock::new();
                dropped += len;
            } else {
                kept += len;
            }
        }
        self.free.store(u32::MAX as u64, Ordering::Release);
        self.next_fresh.store(live, Ordering::Relaxed);
        if dropped > 0 {
            self.capacity.fetch_sub(dropped as u32, Ordering::Relaxed);
        }
    }

    /// Slots currently backed by allocated chunks.
    pub fn capacity(&self) -> usize {
        self.capacity.load(Ordering::Relaxed) as usize
    }

    /// High-water mark of simultaneously live slots (incl. sentinels).
    pub fn high_water(&self) -> usize {
        self.high_water.load(Ordering::Relaxed) as usize
    }

    /// Slots currently live (allocated and not released). A drained
    /// chain holds exactly its two sentinels — the chaos harness's
    /// leak-freedom invariant reads this at teardown (DESIGN.md §10).
    pub fn live(&self) -> usize {
        self.in_use.load(Ordering::Relaxed) as usize
    }

    /// Allocations served by recycling a freed slot.
    pub fn recycled(&self) -> u64 {
        self.recycled.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn locate_covers_chunk_boundaries() {
        let a: Arena<u32> = Arena::with_capacity(64);
        assert_eq!(a.locate(0), (0, 0));
        assert_eq!(a.locate(63), (0, 63));
        assert_eq!(a.locate(64), (1, 0)); // chunk 1: [64, 128)
        assert_eq!(a.locate(127), (1, 63));
        assert_eq!(a.locate(128), (2, 0)); // chunk 2: [128, 256)
        assert_eq!(a.locate(255), (2, 127));
        assert_eq!(a.locate(256), (3, 0));
    }

    #[test]
    fn alloc_is_dense_then_recycles() {
        let a: Arena<u32> = Arena::with_capacity(8); // clamps to 64
        assert_eq!(a.capacity(), 64);
        let i0 = a.alloc();
        let i1 = a.alloc();
        assert_eq!((i0, i1), (0, 1));
        a.release(i0);
        assert_eq!(a.alloc(), 0, "freed slot is reused before fresh ones");
        assert_eq!(a.recycled(), 1);
        assert_eq!(a.high_water(), 2);
    }

    #[test]
    fn growth_past_the_first_chunk() {
        let a: Arena<u32> = Arena::with_capacity(64);
        for expect in 0..200u32 {
            assert_eq!(a.alloc(), expect);
        }
        assert!(a.capacity() >= 200);
        assert_eq!(a.high_water(), 200);
        // Every allocated slot is addressable.
        for idx in 0..200u32 {
            let _ = a.slot(idx);
        }
    }

    #[test]
    fn free_list_is_lifo_and_tagged() {
        let a: Arena<u32> = Arena::with_capacity(64);
        let i: Vec<u32> = (0..4).map(|_| a.alloc()).collect();
        a.release(i[1]);
        a.release(i[3]);
        assert_eq!(a.alloc(), i[3], "LIFO reuse");
        assert_eq!(a.alloc(), i[1]);
        assert_eq!(a.recycled(), 2);
    }

    #[test]
    fn shrink_drops_growth_chunks_and_keeps_the_prefix() {
        let mut a: Arena<u32> = Arena::with_capacity(64);
        let _sentinels = (a.alloc(), a.alloc());
        let idxs: Vec<u32> = (0..500).map(|_| a.alloc()).collect();
        assert!(a.capacity() >= 502);
        for &i in &idxs {
            a.release(i);
        }
        a.shrink_on_quiesce(64);
        assert_eq!(a.capacity(), 64, "growth chunks dropped");
        assert_eq!(a.live(), 2, "sentinels survive");
        assert_eq!(a.high_water(), 502, "the run's peak is preserved");
        assert_eq!(a.alloc(), 2, "allocator rewound past the sentinels");
    }

    #[test]
    fn shrink_keeps_enough_chunks_to_cover_the_target() {
        let mut a: Arena<u32> = Arena::with_capacity(64);
        let _sentinels = (a.alloc(), a.alloc());
        let idxs: Vec<u32> = (0..500).map(|_| a.alloc()).collect();
        for &i in &idxs {
            a.release(i);
        }
        // 64 + 64 + 128 = 256 covers 130; chunk 3 (256 slots) goes.
        a.shrink_on_quiesce(130);
        assert_eq!(a.capacity(), 256);
        // Regrowth after a shrink is clean: fresh allocations walk the
        // kept prefix and re-initialize dropped chunks on demand.
        for expect in 2..400u32 {
            assert_eq!(a.alloc(), expect);
        }
        assert!(a.capacity() >= 400);
    }

    #[test]
    fn null_handle_is_none() {
        assert!(Handle::NONE.is_none());
        assert!(!Handle { idx: 0, gen: 0 }.is_none());
    }
}
