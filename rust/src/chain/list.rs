//! The chain container: sentinels, structural mutation (batched append /
//! unlink with slot recycling), and counters — all over the node
//! [`Arena`](super::arena::Arena).
//!
//! Structural discipline (who may touch what):
//!
//! * **Append** — only a worker holding the *tail sentinel's* visitor slot
//!   (and located at the current last node, holding its slot too) may
//!   append; [`fill_tail`](Chain::fill_tail) links a whole batch of up to
//!   `B` tasks under that one tail-slot acquisition. This realizes "at
//!   most one task is created at any instant" (§3.3) — batch members are
//!   published in canonical order by a single appender — and the
//!   enter-lock's empty-chain case.
//! * **Unlink** — only the worker that executed a task may unlink it, while
//!   holding the task's visitor slot and the chain's [`erase
//!   lock`](Chain::unlink); "the erase-lock ensures that at most one task
//!   is being erased at any given point in time" (§3.3). Unlinking clears
//!   the slot's recipe, bumps its generation (invalidating every
//!   outstanding [`Handle`] to the node) and returns the slot to the
//!   arena's free list — steady-state execution allocates nothing.
//! * **Pointer reads** — any worker, under the node's link lock (a leaf
//!   lock, never held across blocking operations). Readers that cannot
//!   pin the node (no visitor slot) must use the validated accessors
//!   ([`next_validated`](Chain::next_validated) /
//!   [`with_recipe`](Chain::with_recipe)), which check the generation tag
//!   under the link lock.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

use super::arena::{Arena, Handle};
use super::node::{Meta, NodeKind, NodeState};

/// The task chain. `R` is the model's recipe type.
#[derive(Debug)]
pub struct Chain<R> {
    arena: Arena<R>,
    head: Handle,
    tail: Handle,
    erase_lock: Mutex<()>,
    /// Live (linked, not-erased) task count.
    len: AtomicUsize,
    /// High-water mark of `len`.
    max_len: AtomicUsize,
    /// Total tasks ever appended; also the next task's `seq`.
    created: AtomicU64,
    /// Total tasks erased (== executed).
    erased: AtomicU64,
    /// Set once the task source returns `None`.
    exhausted: AtomicBool,
    /// Creation-lock acquisitions ([`fill_tail`](Chain::fill_tail) tail
    /// slot holds + [`append_tail`](Chain::append_tail) erase-lock
    /// appends). `created / tail_locks` is the batching amortization.
    tail_locks: AtomicU64,
}

impl<R> Default for Chain<R> {
    fn default() -> Self {
        Self::new()
    }
}

impl<R> Chain<R> {
    /// An empty chain (`head ↔ tail`) with the default arena pre-size.
    pub fn new() -> Self {
        Self::with_capacity(0)
    }

    /// An empty chain whose arena is pre-sized for about `cap_hint`
    /// simultaneously live tasks (engines derive the hint from
    /// `TaskSource::size_hint` and the workload shape; a low hint only
    /// costs amortized chunk growth, never correctness).
    pub fn with_capacity(cap_hint: usize) -> Self {
        let arena = Arena::with_capacity(cap_hint.saturating_add(2));
        let h = arena.alloc();
        let t = arena.alloc();
        debug_assert_eq!((h, t), (0, 1), "sentinels own the first two slots");
        let head = Handle { idx: h, gen: 0 };
        let tail = Handle { idx: t, gen: 0 };
        {
            let mut l = arena.slot(h).links.lock().unwrap();
            l.prev = Handle::NONE;
            l.next = tail;
        }
        {
            let mut l = arena.slot(t).links.lock().unwrap();
            l.prev = head;
            l.next = Handle::NONE;
        }
        Self {
            arena,
            head,
            tail,
            erase_lock: Mutex::new(()),
            len: AtomicUsize::new(0),
            max_len: AtomicUsize::new(0),
            created: AtomicU64::new(0),
            erased: AtomicU64::new(0),
            exhausted: AtomicBool::new(false),
            tail_locks: AtomicU64::new(0),
        }
    }

    /// Head sentinel.
    #[inline]
    pub fn head(&self) -> Handle {
        self.head
    }

    /// Tail sentinel.
    #[inline]
    pub fn tail(&self) -> Handle {
        self.tail
    }

    /// Whether `h` is the tail sentinel.
    #[inline]
    pub fn is_tail(&self, h: Handle) -> bool {
        h.idx == self.tail.idx
    }

    /// Node kind — a property of the slot index (sentinels own slots 0
    /// and 1 forever).
    #[inline]
    pub fn kind(&self, h: Handle) -> NodeKind {
        match h.idx {
            0 => NodeKind::Head,
            1 => NodeKind::Tail,
            _ => NodeKind::Task,
        }
    }

    // -- visitor slot -------------------------------------------------------

    /// Block until `h`'s visitor slot is free, then take it. The slot
    /// device belongs to the *slot*: acquiring via a stale handle simply
    /// takes (and should promptly release) the current incarnation's
    /// slot — callers detect staleness with [`stale`](Chain::stale)
    /// after acquiring.
    #[inline]
    pub fn acquire(&self, h: Handle) {
        self.arena.slot(h.idx).visitor.acquire();
    }

    /// Release `h`'s visitor slot.
    #[inline]
    pub fn release(&self, h: Handle) {
        self.arena.slot(h.idx).visitor.release();
    }

    /// Take `h`'s visitor slot if free; `true` on success.
    #[inline]
    pub fn try_acquire(&self, h: Handle) -> bool {
        self.arena.slot(h.idx).visitor.try_acquire()
    }

    // -- per-node reads -----------------------------------------------------

    /// Whether `h` no longer names a live node (its incarnation was
    /// erased; the slot may already host a different task). The check is
    /// exact for a caller holding the visitor slot: erasure requires the
    /// slot, so the generation cannot change under a holder.
    #[inline]
    pub fn stale(&self, h: Handle) -> bool {
        self.arena.slot(h.idx).gen.load(Ordering::Acquire) != h.gen
    }

    /// Current lifecycle state. Caller must know `h` is live (sentinel,
    /// visitor slot held, or execution claimed).
    #[inline]
    pub fn state(&self, h: Handle) -> NodeState {
        self.arena.slot(h.idx).load_state()
    }

    /// Transition `Pending → Executing`. Caller must hold the visitor
    /// slot of a live `h` (only the located worker may claim execution),
    /// which serializes the transition.
    #[inline]
    pub fn begin_execution(&self, h: Handle) {
        debug_assert_eq!(self.kind(h), NodeKind::Task);
        debug_assert!(!self.stale(h), "claiming a stale node");
        let prev = self.arena.slot(h.idx).state.swap(
            NodeState::Executing as u8,
            Ordering::AcqRel,
        );
        debug_assert_eq!(prev, NodeState::Pending as u8, "double execution");
    }

    /// Task sequence number.
    ///
    /// # Safety
    /// `h` must be live and pinned: the caller holds its visitor slot,
    /// has claimed its execution (`Executing` — only the claimant
    /// erases), or the chain is quiescent.
    #[inline]
    pub unsafe fn seq(&self, h: Handle) -> u64 {
        debug_assert_eq!(self.kind(h), NodeKind::Task);
        (*self.arena.slot(h.idx).meta.get()).seq
    }

    /// The recipe. Immutable while the node is live, so concurrent reads
    /// by passing workers and the executing worker are fine.
    ///
    /// # Safety
    /// Same pinning contract as [`seq`](Chain::seq): the node must not be
    /// erasable while the returned borrow is alive.
    #[inline]
    pub unsafe fn recipe(&self, h: Handle) -> &R {
        debug_assert!(!self.stale(h), "reading a recycled slot's recipe");
        (*self.arena.slot(h.idx).meta.get())
            .recipe
            .as_ref()
            .expect("live task node has a recipe")
    }

    /// Validated recipe read for *unpinned* readers (slot-free walks):
    /// runs `f` on the recipe under the node's link lock iff `h` is
    /// still live, `None` if the node was erased. `f` must not block
    /// (the link lock is a leaf lock).
    pub fn with_recipe<T>(&self, h: Handle, f: impl FnOnce(&R) -> T) -> Option<T> {
        let slot = self.arena.slot(h.idx);
        let _links = slot.links.lock().unwrap();
        if slot.gen.load(Ordering::Relaxed) != h.gen {
            return None;
        }
        // SAFETY: the generation matches under the link lock, so this is
        // `h`'s incarnation and both meta mutation points (allocation,
        // erase) are excluded while we hold the lock (node.rs).
        let recipe = unsafe {
            (*slot.meta.get())
                .recipe
                .as_ref()
                .expect("live task node has a recipe")
        };
        Some(f(recipe))
    }

    /// Snapshot of the forward pointer. Caller must have `h` pinned
    /// (visitor slot held); use
    /// [`next_validated`](Chain::next_validated) otherwise.
    #[inline]
    pub fn next(&self, h: Handle) -> Handle {
        self.arena.slot(h.idx).links.lock().unwrap().next
    }

    /// Forward pointer for unpinned readers: `None` once `h`'s
    /// incarnation was erased (the walk must restart from a pinned
    /// position — erased nodes are never traversed through).
    pub fn next_validated(&self, h: Handle) -> Option<Handle> {
        let slot = self.arena.slot(h.idx);
        let links = slot.links.lock().unwrap();
        if slot.gen.load(Ordering::Relaxed) != h.gen {
            return None;
        }
        Some(links.next)
    }

    // -- counters -----------------------------------------------------------

    /// Live task count.
    #[inline]
    pub fn len(&self) -> usize {
        self.len.load(Ordering::Acquire)
    }

    /// Whether no live tasks remain.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// High-water mark of the live task count.
    pub fn max_len(&self) -> usize {
        self.max_len.load(Ordering::Relaxed)
    }

    /// Total tasks appended so far.
    pub fn created(&self) -> u64 {
        self.created.load(Ordering::Relaxed)
    }

    /// Total tasks erased so far.
    pub fn erased(&self) -> u64 {
        self.erased.load(Ordering::Relaxed)
    }

    /// Creation-lock acquisitions so far (each amortizes a whole batch).
    pub fn tail_locks(&self) -> u64 {
        self.tail_locks.load(Ordering::Relaxed)
    }

    /// Arena slots currently backed by memory (incl. the two sentinels).
    pub fn arena_capacity(&self) -> usize {
        self.arena.capacity()
    }

    /// High-water mark of simultaneously live arena slots.
    pub fn arena_high_water(&self) -> usize {
        self.arena.high_water()
    }

    /// Node allocations served by recycling an erased slot.
    pub fn arena_recycled(&self) -> u64 {
        self.arena.recycled()
    }

    /// Arena slots currently live. A drained chain holds exactly its
    /// two sentinels; the chaos harness's leak-freedom checker sums
    /// this across chains at teardown (DESIGN.md §10).
    pub fn arena_live(&self) -> usize {
        self.arena.live()
    }

    /// Mark the task source as exhausted (no more tasks will ever appear).
    pub fn set_exhausted(&self) {
        self.exhausted.store(true, Ordering::Release);
    }

    /// Whether the task source is exhausted.
    #[inline]
    pub fn exhausted(&self) -> bool {
        self.exhausted.load(Ordering::Acquire)
    }

    /// Clear the exhausted flag for another epoch of task creation.
    ///
    /// Used by the observed parallel run between epochs: an epoch-gated
    /// source reports (temporary) exhaustion at the boundary so workers
    /// drain the chain to quiescence; once the snapshot is taken the
    /// engine re-opens the chain. **Quiescent use only** — must not race
    /// task creation (no workers are running between epochs).
    pub fn reopen(&self) {
        self.exhausted.store(false, Ordering::Release);
    }

    /// Shrink the arena back toward a `keep_tasks` live-task capacity
    /// (plus the two sentinels), dropping growth chunks acquired during
    /// a burst so `arena_capacity` tracks the live estimate instead of
    /// pinning the run's peak (DESIGN.md §14). **Quiescent use only** —
    /// the chain must be drained (no live tasks, no running workers),
    /// exactly like [`reopen`](Chain::reopen); `&mut self` enforces the
    /// exclusivity.
    pub fn shrink_on_quiesce(&mut self, keep_tasks: usize) {
        debug_assert!(self.is_empty(), "shrink requires a drained chain");
        self.arena.shrink_on_quiesce(keep_tasks.saturating_add(2));
    }

    // -- structural mutation ------------------------------------------------

    /// Allocate and initialize one unpublished node. The slot comes from
    /// the free list when possible; its generation is whatever the erase
    /// path left (bumping happens at erase, so a matching tag always
    /// means "live").
    fn init_node(&self, seq: u64, recipe: R, prev: Handle, next: Handle) -> Handle {
        let idx = self.arena.alloc();
        let slot = self.arena.slot(idx);
        let gen = slot.gen.load(Ordering::Relaxed);
        {
            let mut links = slot.links.lock().unwrap();
            // SAFETY: the slot is off the free list and unpublished; the
            // only other parties that may touch `meta` are validated
            // readers with stale handles, excluded by the gen check they
            // perform under this very lock (node.rs safety argument).
            unsafe {
                *slot.meta.get() = Meta {
                    seq,
                    recipe: Some(recipe),
                };
            }
            links.prev = prev;
            links.next = next;
        }
        slot.state.store(NodeState::Pending as u8, Ordering::Release);
        Handle { idx, gen }
    }

    /// Append a whole batch after `last` (the node immediately before
    /// the tail) under **one** creation-lock acquisition, draining
    /// `recipes` in order. Returns the first appended node's handle.
    ///
    /// # Locking contract
    /// The caller holds `last`'s visitor slot *and* the tail's visitor
    /// slot; the former pins `last` (it cannot be erased under us), the
    /// latter serializes creation. `recipes` must be non-empty.
    ///
    /// The batch is built unpublished (each node's links pre-set — no
    /// contended locks) and becomes visible atomically with the single
    /// `last.next` store, so traversing workers observe either the old
    /// chain or the whole batch in canonical order — a batch can never
    /// reorder or interleave with other creations (DESIGN.md §3).
    pub fn fill_tail(&self, last: Handle, recipes: &mut Vec<R>) -> Handle {
        debug_assert!(!recipes.is_empty(), "fill_tail needs at least one recipe");
        self.tail_locks.fetch_add(1, Ordering::Relaxed);
        let count = recipes.len();
        let mut first = Handle::NONE;
        let mut prev = last;
        for recipe in recipes.drain(..) {
            let seq = self.created.fetch_add(1, Ordering::AcqRel);
            let node = self.init_node(seq, recipe, prev, self.tail);
            if first.is_none() {
                first = node;
            } else {
                // Point the previous batch member forward. This is a
                // second (uncontended) lock round-trip per interior
                // member — the successor's handle does not exist yet at
                // init time, and unlocked link writes would race the
                // validated readers' gen-check-under-lock discipline.
                // The lock batching amortizes is the *contended* tail
                // slot, which stays at one acquisition per batch.
                self.arena.slot(prev.idx).links.lock().unwrap().next = node;
            }
            prev = node;
        }
        {
            let mut ll = self.arena.slot(last.idx).links.lock().unwrap();
            debug_assert!(
                ll.next == self.tail,
                "fill_tail: `last` is not the last node"
            );
            ll.next = first; // publication point
        }
        self.arena.slot(self.tail.idx).links.lock().unwrap().prev = prev;
        self.note_appended(count);
        first
    }

    /// Build, link and publish one node after `last` (which the caller
    /// has pinned — visitor slot or erase lock — as the node before the
    /// tail). Shared body of [`append_after`](Chain::append_after) and
    /// [`append_tail`](Chain::append_tail); allocation-free beyond the
    /// arena slot itself.
    fn link_single(&self, last: Handle, recipe: R) -> Handle {
        let seq = self.created.fetch_add(1, Ordering::AcqRel);
        let node = self.init_node(seq, recipe, last, self.tail);
        {
            let mut ll = self.arena.slot(last.idx).links.lock().unwrap();
            debug_assert!(ll.next == self.tail, "append: `last` is not the last node");
            ll.next = node; // publication point
        }
        self.arena.slot(self.tail.idx).links.lock().unwrap().prev = node;
        self.note_appended(1);
        node
    }

    /// Append a single task after `last` — the `B = 1` creation path
    /// (also the vtime calibration's structural microbench, which is
    /// why this must not allocate beyond the arena slot). Same locking
    /// contract as [`fill_tail`](Chain::fill_tail).
    pub fn append_after(&self, last: Handle, recipe: R) -> Handle {
        self.tail_locks.fetch_add(1, Ordering::Relaxed);
        self.link_single(last, recipe)
    }

    /// Append a task at the tail **without taking visitor slots** — the
    /// sharded scheduler's append path (DESIGN.md §8).
    ///
    /// The classic [`fill_tail`](Chain::fill_tail) discipline pins the
    /// last node via its visitor slot, which only works when the appender
    /// is the worker located there. The sharded splitter appends to
    /// *other* workers' chains while those workers hold slots in them, so
    /// it pins the last node with the **erase lock** instead: unlinks are
    /// excluded, hence `tail.prev` cannot be erased or displaced
    /// mid-append (displacement by a concurrent append is excluded by the
    /// caller's own serialization — see the locking contract).
    ///
    /// # Locking contract
    /// Callers must serialize `append_tail` invocations on one chain
    /// externally (the splitter holds its router mutex across the call).
    /// No visitor slot is required, so appenders never wait on traversing
    /// workers and vice versa.
    pub fn append_tail(&self, recipe: R) -> Handle {
        let _erase = self.erase_lock.lock().unwrap();
        self.tail_locks.fetch_add(1, Ordering::Relaxed);
        let last = self.arena.slot(self.tail.idx).links.lock().unwrap().prev;
        self.link_single(last, recipe)
    }

    fn note_appended(&self, count: usize) {
        let len = self.len.fetch_add(count, Ordering::AcqRel) + count;
        // Check-before-RMW: the high-water mark rarely moves, so skip the
        // atomic max in the common case (EXPERIMENTS.md §Perf).
        if len > self.max_len.load(Ordering::Relaxed) {
            self.max_len.fetch_max(len, Ordering::Relaxed);
        }
    }

    /// Unlink an executed task node, erase it, and recycle its slot.
    ///
    /// # Locking contract
    /// The caller holds `h`'s visitor slot and `h` is in state
    /// `Executing` (execution finished). Takes the erase lock internally.
    ///
    /// After return every outstanding handle to the node is stale (the
    /// generation was bumped) and the slot is on the free list; a new
    /// incarnation may be published at any later moment — which is why
    /// arrival paths must check [`stale`](Chain::stale) after acquiring
    /// a slot they did not already hold.
    pub fn unlink(&self, h: Handle) {
        debug_assert_eq!(self.kind(h), NodeKind::Task);
        let _erase = self.erase_lock.lock().unwrap();
        let slot = self.arena.slot(h.idx);
        // Snapshot neighbours. They are stable for the rest of the
        // operation: other unlinks are excluded by the erase lock, and an
        // append can only rewire the *last* node's next — `h` cannot be
        // the last node for an appender, because `fill_tail` appenders
        // must hold the last node's visitor slot (ours) and `append_tail`
        // appenders the erase lock (ours).
        let (prev, next) = {
            let links = slot.links.lock().unwrap();
            debug_assert!(
                !links.next.is_none(),
                "unlink of an already-unlinked node"
            );
            (links.prev, links.next)
        };
        {
            // Lock prev → h → next (chain order). Nesting is deadlock-free
            // because unlink is the only multi-link-lock holder and the
            // erase lock admits one unlink at a time.
            let mut pl = self.arena.slot(prev.idx).links.lock().unwrap();
            let mut hl = slot.links.lock().unwrap();
            let mut xl = self.arena.slot(next.idx).links.lock().unwrap();
            debug_assert!(pl.next == h, "prev/next snapshot went stale");
            debug_assert!(xl.prev == h, "prev/next snapshot went stale");
            pl.next = next;
            xl.prev = prev;
            // Retire the incarnation: clear the links (visitors finding
            // the node erased retry from their previous position instead
            // of following stale pointers), drop the recipe (erased
            // nodes must not keep payloads alive), bump the generation
            // (every outstanding handle goes stale atomically w.r.t.
            // validated readers, who check under this lock).
            hl.prev = Handle::NONE;
            hl.next = Handle::NONE;
            // SAFETY: we hold the visitor slot (no pinned reader can be
            // borrowing meta) and the link lock (no validated reader is
            // mid-read).
            unsafe {
                (*slot.meta.get()).recipe = None;
            }
            slot.gen.fetch_add(1, Ordering::Release);
        }
        let prev_state = slot.state.swap(NodeState::Erased as u8, Ordering::AcqRel);
        debug_assert_eq!(
            prev_state,
            NodeState::Executing as u8,
            "erase before execute"
        );
        self.len.fetch_sub(1, Ordering::AcqRel);
        self.erased.fetch_add(1, Ordering::Relaxed);
        // Recycle. The new incarnation may be published while we still
        // hold the visitor slot (our caller releases it right after); a
        // visitor arriving at the recycled node simply waits that brief
        // moment out.
        self.arena.release(h.idx);
    }

    /// Walk the chain forward and check all structural invariants.
    /// **Quiescent use only** (tests / debug): takes no visitor slots.
    pub fn validate(&self) -> Result<Vec<u64>, String> {
        let mut seqs = Vec::new();
        let mut cur = self.head;
        let mut last_seq: Option<u64> = None;
        loop {
            let next = self.next(cur);
            if next.is_none() {
                return Err(format!("node idx={} has no next", cur.idx));
            }
            if self.stale(next) {
                return Err(format!("stale handle linked at idx={}", next.idx));
            }
            // prev(next) == cur
            {
                let xl = self.arena.slot(next.idx).links.lock().unwrap();
                if xl.prev != cur {
                    return Err(format!("prev mismatch at idx={}", next.idx));
                }
            }
            if self.is_tail(next) {
                break;
            }
            // SAFETY: quiescent walk — nothing is erased concurrently.
            let seq = unsafe { self.seq(next) };
            if last_seq.is_some_and(|l| seq <= l) {
                return Err(format!("seq not increasing: {seq} after {last_seq:?}"));
            }
            last_seq = Some(seq);
            seqs.push(seq);
            cur = next;
        }
        if seqs.len() != self.len() {
            return Err(format!(
                "len counter {} != walked {}",
                self.len(),
                seqs.len()
            ));
        }
        Ok(seqs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Append helper for quiescent tests: takes the required visitor slots
    /// the way a worker would.
    fn append<R>(chain: &Chain<R>, recipe: R) -> Handle {
        // Find the last node by walking (test-only).
        let mut last = chain.head();
        loop {
            let next = chain.next(last);
            if chain.is_tail(next) {
                break;
            }
            last = next;
        }
        chain.acquire(last);
        chain.acquire(chain.tail());
        let node = chain.append_after(last, recipe);
        chain.release(chain.tail());
        chain.release(last);
        node
    }

    /// Execute-and-erase helper (quiescent).
    fn erase<R>(chain: &Chain<R>, h: Handle) {
        chain.acquire(h);
        chain.begin_execution(h);
        chain.release(h);
        // (execution happens here)
        chain.acquire(h);
        chain.unlink(h);
        chain.release(h);
    }

    #[test]
    fn empty_chain_shape() {
        let c: Chain<u32> = Chain::new();
        assert!(c.is_empty());
        let n = c.next(c.head());
        assert!(c.is_tail(n));
        assert_eq!(c.validate().unwrap(), Vec::<u64>::new());
        assert_eq!(c.kind(c.head()), NodeKind::Head);
        assert_eq!(c.kind(c.tail()), NodeKind::Tail);
    }

    #[test]
    fn append_three_then_unlink_middle() {
        let c: Chain<u32> = Chain::new();
        let _a = append(&c, 10);
        let b = append(&c, 20);
        let _d = append(&c, 30);
        assert_eq!(c.len(), 3);
        assert_eq!(c.validate().unwrap(), vec![0, 1, 2]);
        assert_eq!(c.max_len(), 3);
        assert_eq!(unsafe { *c.recipe(b) }, 20);

        erase(&c, b);

        assert_eq!(c.len(), 2);
        assert_eq!(c.validate().unwrap(), vec![0, 2]);
        assert!(c.stale(b), "erased handle must be stale");
        assert_eq!(c.next_validated(b), None, "erased node yields no next");
        assert_eq!(c.with_recipe(b, |r| *r), None, "no validated recipe read");
    }

    #[test]
    fn unlink_last_and_first() {
        let c: Chain<u32> = Chain::new();
        let a = append(&c, 1);
        let b = append(&c, 2);
        for n in [b, a] {
            erase(&c, n);
        }
        assert!(c.is_empty());
        assert_eq!(c.validate().unwrap(), Vec::<u64>::new());
        assert_eq!(c.created(), 2);
        assert_eq!(c.erased(), 2);
    }

    #[test]
    fn seq_numbers_are_creation_order() {
        let c: Chain<u32> = Chain::new();
        for i in 0..5 {
            let n = append(&c, i);
            assert_eq!(unsafe { c.seq(n) }, i as u64);
        }
    }

    #[test]
    fn batched_fill_links_in_canonical_order() {
        let c: Chain<u32> = Chain::new();
        let _a = append(&c, 0);
        // Find last (the node just appended) and batch-append 4 more.
        let last = {
            let mut last = c.head();
            loop {
                let next = c.next(last);
                if c.is_tail(next) {
                    break last;
                }
                last = next;
            }
        };
        c.acquire(last);
        c.acquire(c.tail());
        let mut batch = vec![1u32, 2, 3, 4];
        let first = c.fill_tail(last, &mut batch);
        c.release(c.tail());
        c.release(last);
        assert!(batch.is_empty(), "fill_tail drains the batch");
        assert_eq!(unsafe { c.seq(first) }, 1);
        assert_eq!(c.len(), 5);
        assert_eq!(c.validate().unwrap(), vec![0, 1, 2, 3, 4]);
        assert_eq!(
            c.tail_locks(),
            2,
            "one lock for the single append, one for the whole batch"
        );
        // Recipes landed in order.
        let mut cur = c.head();
        for want in 0u32..5 {
            cur = c.next(cur);
            assert_eq!(unsafe { *c.recipe(cur) }, want);
        }
    }

    #[test]
    fn recycling_reuses_slots_and_bumps_generations() {
        let c: Chain<u32> = Chain::new();
        let a = append(&c, 7);
        let idx = a.index();
        erase(&c, a);
        assert_eq!(c.erased(), 1);
        let b = append(&c, 8);
        assert_eq!(b.index(), idx, "freed slot is recycled");
        assert_ne!(b.generation(), a.generation(), "generation must bump");
        assert!(c.stale(a) && !c.stale(b));
        assert_eq!(c.arena_recycled(), 1);
        assert_eq!(unsafe { *c.recipe(b) }, 8);
        assert_eq!(c.validate().unwrap(), vec![1]);
    }

    #[test]
    fn steady_state_stays_within_the_initial_arena() {
        let c: Chain<u64> = Chain::with_capacity(16);
        let cap0 = c.arena_capacity();
        for i in 0..10_000 {
            let n = append(&c, i);
            erase(&c, n);
        }
        assert_eq!(c.arena_capacity(), cap0, "no growth at steady state");
        assert!(c.arena_high_water() <= 3, "2 sentinels + 1 live task");
        assert_eq!(c.arena_recycled(), 9_999, "all but the first alloc reuse");
        assert!(c.is_empty());
    }

    #[test]
    fn shrink_on_quiesce_rewinds_burst_growth() {
        let mut c: Chain<u64> = Chain::with_capacity(16);
        let cap0 = c.arena_capacity();
        // Burst: hold 2 000 live tasks, forcing growth chunks.
        let nodes: Vec<Handle> = (0..2_000).map(|i| append(&c, i)).collect();
        assert!(c.arena_capacity() > cap0, "burst must grow the arena");
        for n in nodes {
            erase(&c, n);
        }
        c.shrink_on_quiesce(16);
        assert_eq!(c.arena_capacity(), cap0, "drained chain falls back");
        assert_eq!(c.arena_live(), 2, "only the sentinels survive");
        assert!(c.arena_high_water() >= 2_000, "peak stays reported");
        // The chain keeps working after a shrink: canonical order and
        // recycling behave as on a fresh chain.
        let a = append(&c, 7);
        let b = append(&c, 8);
        assert_eq!(c.validate().unwrap(), vec![2_000, 2_001]);
        erase(&c, a);
        erase(&c, b);
        assert!(c.is_empty());
    }

    #[test]
    fn long_chain_grows_and_tears_down() {
        let c: Chain<u64> = Chain::with_capacity(64);
        for i in 0..200_000u64 {
            // Direct low-level append to keep the test fast: emulate the
            // worker's slot acquisition on the last node via tail.prev.
            let last = {
                let tl = c.arena.slot(c.tail().idx).links.lock().unwrap();
                tl.prev
            };
            c.acquire(last);
            c.acquire(c.tail());
            c.append_after(last, i);
            c.release(c.tail());
            c.release(last);
        }
        assert_eq!(c.len(), 200_000);
        assert!(c.arena_capacity() >= 200_002);
        drop(c); // flat storage: no recursive drops, no stack overflow
    }

    #[test]
    fn concurrent_append_unlink_preserves_structure() {
        // Three threads churning append→execute→unlink against one chain;
        // afterwards the chain must be structurally pristine.
        let chain: std::sync::Arc<Chain<u64>> = std::sync::Arc::new(Chain::new());
        let iters = 2_000u64;
        std::thread::scope(|s| {
            for t in 0..3u64 {
                let chain = chain.clone();
                s.spawn(move || {
                    for i in 0..iters {
                        let node = loop {
                            let last = {
                                let tl =
                                    chain.arena.slot(chain.tail().idx).links.lock().unwrap();
                                tl.prev
                            };
                            if !chain.try_acquire(last) {
                                std::thread::yield_now();
                                continue;
                            }
                            // `last` may have been erased (stale handle)
                            // or displaced while we acquired; re-check.
                            let still_last =
                                !chain.stale(last) && chain.is_tail(chain.next(last));
                            if !still_last {
                                chain.release(last);
                                std::thread::yield_now();
                                continue;
                            }
                            chain.acquire(chain.tail());
                            let node = chain.append_after(last, t * iters + i);
                            chain.release(chain.tail());
                            chain.release(last);
                            break node;
                        };
                        chain.acquire(node);
                        chain.begin_execution(node);
                        chain.release(node);
                        chain.acquire(node);
                        chain.unlink(node);
                        chain.release(node);
                    }
                });
            }
        });
        assert!(chain.is_empty());
        assert_eq!(chain.created(), 3 * iters);
        assert_eq!(chain.erased(), 3 * iters);
        assert_eq!(chain.validate().unwrap(), Vec::<u64>::new());
    }

    #[test]
    fn append_tail_matches_slot_based_appends() {
        let c: Chain<u32> = Chain::new();
        let a = append(&c, 1); // slot-based
        let b = c.append_tail(2); // lock-based
        let d = append(&c, 3);
        assert_eq!(c.validate().unwrap(), vec![0, 1, 2]);
        assert_eq!(
            unsafe { (c.seq(a), c.seq(b), c.seq(d)) },
            (0, 1, 2)
        );
        for n in [a, b, d] {
            erase(&c, n);
        }
        assert!(c.is_empty());
        assert_eq!(c.validate().unwrap(), Vec::<u64>::new());
    }

    #[test]
    fn append_tail_races_unlink_safely() {
        // One thread appends (serialized appender, like the splitter),
        // another executes+unlinks from the front: the erase lock keeps
        // the structure consistent without visitor-slot handshakes, and
        // slot recycling keeps the arena flat.
        let chain: std::sync::Arc<Chain<u64>> = std::sync::Arc::new(Chain::new());
        let n = 4_000u64;
        std::thread::scope(|s| {
            {
                let chain = chain.clone();
                s.spawn(move || {
                    for i in 0..n {
                        chain.append_tail(i);
                    }
                });
            }
            {
                let chain = chain.clone();
                s.spawn(move || {
                    let mut done = 0u64;
                    while done < n {
                        let first = chain.next(chain.head());
                        if chain.is_tail(first) {
                            std::thread::yield_now();
                            continue;
                        }
                        chain.acquire(first);
                        if chain.stale(first) {
                            chain.release(first);
                            continue;
                        }
                        chain.begin_execution(first);
                        chain.unlink(first);
                        chain.release(first);
                        done += 1;
                    }
                });
            }
        });
        assert!(chain.is_empty());
        assert_eq!(chain.created(), n);
        assert_eq!(chain.erased(), n);
        assert_eq!(chain.validate().unwrap(), Vec::<u64>::new());
        // Live backlog during the race is timing-dependent, so assert
        // recycling deterministically instead: with the whole free list
        // populated, another round of churn must reuse slots and never
        // grow the slab.
        let cap_after = chain.arena_capacity();
        let recycled_before = chain.arena_recycled();
        for i in 0..100 {
            let node = chain.append_tail(i);
            chain.acquire(node);
            chain.begin_execution(node);
            chain.unlink(node);
            chain.release(node);
        }
        assert_eq!(chain.arena_capacity(), cap_after, "steady state never grows");
        assert_eq!(
            chain.arena_recycled(),
            recycled_before + 100,
            "every post-race alloc must come from the free list"
        );
    }

    #[test]
    fn exhausted_flag() {
        let c: Chain<u32> = Chain::new();
        assert!(!c.exhausted());
        c.set_exhausted();
        assert!(c.exhausted());
        c.reopen();
        assert!(!c.exhausted(), "reopen clears the flag for the next epoch");
        c.set_exhausted();
        assert!(c.exhausted());
    }
}
