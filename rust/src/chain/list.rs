//! The chain container: sentinels, structural mutation (append/unlink),
//! and counters.
//!
//! Structural discipline (who may touch what):
//!
//! * **Append** — only a worker holding the *tail sentinel's* visitor slot
//!   (and located at the current last node, holding its slot too) may
//!   append. This realizes "at most one task is created at any instant"
//!   (§3.3) and the enter-lock's empty-chain case.
//! * **Unlink** — only the worker that executed a task may unlink it, while
//!   holding the task's visitor slot and the chain's [`erase
//!   lock`](Chain::unlink); "the erase-lock ensures that at most one task
//!   is being erased at any given point in time" (§3.3).
//! * **Pointer reads** — any worker, under the node's link lock (a leaf
//!   lock, never held across blocking operations).
//!
//! Appends and unlinks can interleave, so `unlink` revalidates the
//! neighbour snapshot after taking the three link locks (ascending `order`,
//! hence deadlock-free) and retries if an append slipped in.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use super::node::{Links, Node, NodeKind};

/// The task chain. `R` is the model's recipe type.
#[derive(Debug)]
pub struct Chain<R> {
    head: Arc<Node<R>>,
    tail: Arc<Node<R>>,
    erase_lock: Mutex<()>,
    /// Live (linked, not-erased) task count.
    len: AtomicUsize,
    /// High-water mark of `len`.
    max_len: AtomicUsize,
    /// Total tasks ever appended; also the next task's `seq`.
    created: AtomicU64,
    /// Total tasks erased (== executed).
    erased: AtomicU64,
    /// Set once the task source returns `None`.
    exhausted: AtomicBool,
}

impl<R> Default for Chain<R> {
    fn default() -> Self {
        Self::new()
    }
}

impl<R> Chain<R> {
    /// An empty chain (`head ↔ tail`).
    pub fn new() -> Self {
        let head = Node::sentinel(NodeKind::Head, 0);
        let tail = Node::sentinel(NodeKind::Tail, u64::MAX);
        {
            let mut hl = head.links.lock().unwrap();
            hl.next = Some(tail.clone());
        }
        {
            let mut tl = tail.links.lock().unwrap();
            tl.prev = Arc::downgrade(&head);
        }
        Self {
            head,
            tail,
            erase_lock: Mutex::new(()),
            len: AtomicUsize::new(0),
            max_len: AtomicUsize::new(0),
            created: AtomicU64::new(0),
            erased: AtomicU64::new(0),
            exhausted: AtomicBool::new(false),
        }
    }

    /// Head sentinel.
    #[inline]
    pub fn head(&self) -> &Arc<Node<R>> {
        &self.head
    }

    /// Tail sentinel.
    #[inline]
    pub fn tail(&self) -> &Arc<Node<R>> {
        &self.tail
    }

    /// Whether `node` is the tail sentinel.
    #[inline]
    pub fn is_tail(&self, node: &Arc<Node<R>>) -> bool {
        Arc::ptr_eq(node, &self.tail)
    }

    /// Live task count.
    #[inline]
    pub fn len(&self) -> usize {
        self.len.load(Ordering::Acquire)
    }

    /// Whether no live tasks remain.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// High-water mark of the live task count.
    pub fn max_len(&self) -> usize {
        self.max_len.load(Ordering::Relaxed)
    }

    /// Total tasks appended so far.
    pub fn created(&self) -> u64 {
        self.created.load(Ordering::Relaxed)
    }

    /// Total tasks erased so far.
    pub fn erased(&self) -> u64 {
        self.erased.load(Ordering::Relaxed)
    }

    /// Mark the task source as exhausted (no more tasks will ever appear).
    pub fn set_exhausted(&self) {
        self.exhausted.store(true, Ordering::Release);
    }

    /// Whether the task source is exhausted.
    #[inline]
    pub fn exhausted(&self) -> bool {
        self.exhausted.load(Ordering::Acquire)
    }

    /// Clear the exhausted flag for another epoch of task creation.
    ///
    /// Used by the observed parallel run between epochs: an epoch-gated
    /// source reports (temporary) exhaustion at the boundary so workers
    /// drain the chain to quiescence; once the snapshot is taken the
    /// engine re-opens the chain. **Quiescent use only** — must not race
    /// task creation (no workers are running between epochs).
    pub fn reopen(&self) {
        self.exhausted.store(false, Ordering::Release);
    }

    /// Append a task after `last` (which must be the node immediately
    /// before the tail).
    ///
    /// # Locking contract
    /// The caller holds `last`'s visitor slot *and* the tail's visitor
    /// slot; the former pins `last` (it cannot be erased under us), the
    /// latter serializes appends.
    pub fn append_after(&self, last: &Arc<Node<R>>, recipe: R) -> Arc<Node<R>> {
        self.link_before_tail(last, recipe)
    }

    /// The shared linking body of [`append_after`](Chain::append_after)
    /// and [`append_tail`](Chain::append_tail): build a pre-linked node,
    /// publish it after `last`, update `tail.prev` and the counters. The
    /// caller guarantees `last` is pinned (visitor slot or erase lock)
    /// and that appends are serialized.
    fn link_before_tail(&self, last: &Arc<Node<R>>, recipe: R) -> Arc<Node<R>> {
        let seq = self.created.fetch_add(1, Ordering::AcqRel);
        // Pre-linked construction: the node is unpublished, so its own
        // link lock is not needed (perf: one fewer lock round-trip).
        let node = Node::task_linked(seq, recipe, Arc::downgrade(last), Some(self.tail.clone()));
        {
            let mut ll = last.links.lock().unwrap();
            debug_assert!(
                ll.next.as_ref().is_some_and(|n| Arc::ptr_eq(n, &self.tail)),
                "append: `last` is not the last node"
            );
            ll.next = Some(node.clone());
        }
        {
            let mut tl = self.tail.links.lock().unwrap();
            tl.prev = Arc::downgrade(&node);
        }
        let len = self.len.fetch_add(1, Ordering::AcqRel) + 1;
        // Check-before-RMW: the high-water mark rarely moves, so skip the
        // atomic max in the common case (EXPERIMENTS.md §Perf).
        if len > self.max_len.load(Ordering::Relaxed) {
            self.max_len.fetch_max(len, Ordering::Relaxed);
        }
        node
    }

    /// Append a task at the tail **without taking visitor slots** — the
    /// sharded scheduler's append path (DESIGN.md §7).
    ///
    /// The classic [`append_after`](Chain::append_after) discipline pins
    /// the last node via its visitor slot, which only works when the
    /// appender is the worker located there. The sharded splitter appends
    /// to *other* workers' chains while those workers hold slots in them,
    /// so it pins the last node with the **erase lock** instead: unlinks
    /// are excluded, hence `tail.prev` cannot be erased or displaced
    /// mid-append (displacement by a concurrent append is excluded by the
    /// caller's own serialization — see the locking contract).
    ///
    /// # Locking contract
    /// Callers must serialize `append_tail` invocations on one chain
    /// externally (the splitter holds its router mutex across the call).
    /// No visitor slot is required, so appenders never wait on traversing
    /// workers and vice versa.
    pub fn append_tail(&self, recipe: R) -> Arc<Node<R>> {
        let _erase = self.erase_lock.lock().unwrap();
        let last = {
            let tl = self.tail.links.lock().unwrap();
            tl.prev
                .upgrade()
                .expect("tail.prev target is kept alive by the forward chain")
        };
        self.link_before_tail(&last, recipe)
    }

    /// Unlink an executed task node and mark it erased.
    ///
    /// # Locking contract
    /// The caller holds `node`'s visitor slot and `node` is in state
    /// `Executing` (execution finished). Takes the erase lock internally.
    pub fn unlink(&self, node: &Arc<Node<R>>) {
        debug_assert_eq!(node.kind(), NodeKind::Task);
        let _erase = self.erase_lock.lock().unwrap();
        loop {
            // Snapshot neighbours.
            let (prev_w, next) = {
                let nl = node.links.lock().unwrap();
                (
                    nl.prev.clone(),
                    nl.next.clone().expect("unlink of already-unlinked node"),
                )
            };
            let prev = prev_w
                .upgrade()
                .expect("prev of a linked node is kept alive by the forward chain");
            debug_assert!(prev.order < node.order && node.order < next.order);

            // Lock links in ascending `order`, then revalidate (an append
            // may have replaced node.next while we were acquiring).
            let mut pl = prev.links.lock().unwrap();
            let mut nl = node.links.lock().unwrap();
            let still_valid = nl.next.as_ref().is_some_and(|n| Arc::ptr_eq(n, &next))
                && nl.prev.ptr_eq(&Arc::downgrade(&prev));
            if !still_valid {
                continue;
            }
            let mut xl = next.links.lock().unwrap();
            // prev.next must still point at node: only erases change it and
            // we hold the erase lock.
            debug_assert!(pl.next.as_ref().is_some_and(|n| Arc::ptr_eq(n, node)));
            pl.next = Some(next.clone());
            xl.prev = nl.prev.clone();
            // Clear the node's own links: erased nodes must not keep
            // successors alive (prevents tombstone chains / recursive
            // drops) and visitors finding the node erased retry from their
            // previous position instead of following stale pointers.
            *nl = Links {
                prev: std::sync::Weak::new(),
                next: None,
            };
            break;
        }
        node.mark_erased();
        self.len.fetch_sub(1, Ordering::AcqRel);
        self.erased.fetch_add(1, Ordering::Relaxed);
    }

    /// Walk the chain forward and check all structural invariants.
    /// **Quiescent use only** (tests / debug): takes no visitor slots.
    pub fn validate(&self) -> Result<Vec<u64>, String> {
        let mut seqs = Vec::new();
        let mut cur = self.head.clone();
        let mut last_order = 0u64;
        loop {
            let next = cur
                .next()
                .ok_or_else(|| format!("node order={} has no next", cur.order))?;
            // prev(next) == cur
            {
                let xl = next.links.lock().unwrap();
                let p = xl
                    .prev
                    .upgrade()
                    .ok_or_else(|| format!("dangling prev at order={}", next.order))?;
                if !Arc::ptr_eq(&p, &cur) {
                    return Err(format!("prev mismatch at order={}", next.order));
                }
            }
            if next.order <= last_order {
                return Err(format!(
                    "order not increasing: {} after {last_order}",
                    next.order
                ));
            }
            last_order = next.order;
            if self.is_tail(&next) {
                break;
            }
            seqs.push(next.seq());
            cur = next;
        }
        if seqs.len() != self.len() {
            return Err(format!(
                "len counter {} != walked {}",
                self.len(),
                seqs.len()
            ));
        }
        Ok(seqs)
    }
}

impl<R> Drop for Chain<R> {
    fn drop(&mut self) {
        // Iterative teardown: break the forward Arc chain so drops do not
        // recurse through millions of nodes.
        let mut cur = self.head.links.lock().unwrap().next.take();
        while let Some(node) = cur {
            cur = node.links.lock().unwrap().next.take();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Append helper for quiescent tests: takes the required visitor slots
    /// the way a worker would.
    fn append<R: Clone>(chain: &Chain<R>, recipe: R) -> Arc<Node<R>> {
        // Find the last node by walking (test-only).
        let mut last = chain.head().clone();
        while let Some(next) = last.next() {
            if chain.is_tail(&next) {
                break;
            }
            last = next;
        }
        last.visitor.acquire();
        chain.tail().visitor.acquire();
        let node = chain.append_after(&last, recipe);
        chain.tail().visitor.release();
        last.visitor.release();
        node
    }

    #[test]
    fn empty_chain_shape() {
        let c: Chain<u32> = Chain::new();
        assert!(c.is_empty());
        let n = c.head().next().unwrap();
        assert!(c.is_tail(&n));
        assert_eq!(c.validate().unwrap(), Vec::<u64>::new());
    }

    #[test]
    fn append_three_then_unlink_middle() {
        let c: Chain<u32> = Chain::new();
        let _a = append(&c, 10);
        let b = append(&c, 20);
        let _d = append(&c, 30);
        assert_eq!(c.len(), 3);
        assert_eq!(c.validate().unwrap(), vec![0, 1, 2]);
        assert_eq!(c.max_len(), 3);

        b.visitor.acquire();
        b.begin_execution();
        b.visitor.release();
        // (execution happens here)
        b.visitor.acquire();
        c.unlink(&b);
        b.visitor.release();

        assert_eq!(c.len(), 2);
        assert_eq!(c.validate().unwrap(), vec![0, 2]);
        assert_eq!(b.state(), crate::chain::NodeState::Erased);
        assert!(b.next().is_none(), "erased node must not hold successors");
    }

    #[test]
    fn unlink_last_and_first() {
        let c: Chain<u32> = Chain::new();
        let a = append(&c, 1);
        let b = append(&c, 2);
        for n in [b, a] {
            n.visitor.acquire();
            n.begin_execution();
            c.unlink(&n);
            n.visitor.release();
        }
        assert!(c.is_empty());
        assert_eq!(c.validate().unwrap(), Vec::<u64>::new());
        assert_eq!(c.created(), 2);
        assert_eq!(c.erased(), 2);
    }

    #[test]
    fn seq_numbers_are_creation_order() {
        let c: Chain<u32> = Chain::new();
        for i in 0..5 {
            let n = append(&c, i);
            assert_eq!(n.seq(), i as u64);
        }
    }

    #[test]
    fn drop_long_chain_does_not_overflow_stack() {
        let c: Chain<u64> = Chain::new();
        for i in 0..200_000 {
            // Direct low-level append to keep the test fast: we emulate the
            // worker's slot acquisition on the last node via tail.prev.
            let last = {
                let tl = c.tail().links.lock().unwrap();
                tl.prev.upgrade().unwrap()
            };
            last.visitor.acquire();
            c.tail().visitor.acquire();
            c.append_after(&last, i);
            c.tail().visitor.release();
            last.visitor.release();
        }
        assert_eq!(c.len(), 200_000);
        drop(c); // must not blow the stack
    }

    #[test]
    fn concurrent_append_unlink_preserves_structure() {
        // Three threads churning append→execute→unlink against one chain;
        // afterwards the chain must be structurally pristine.
        let chain: std::sync::Arc<Chain<u64>> = std::sync::Arc::new(Chain::new());
        let iters = 2_000u64;
        std::thread::scope(|s| {
            for t in 0..3u64 {
                let chain = chain.clone();
                s.spawn(move || {
                    for i in 0..iters {
                        let node = loop {
                            let last = {
                                let tl = chain.tail().links.lock().unwrap();
                                tl.prev.upgrade().unwrap()
                            };
                            if !last.visitor.try_acquire() {
                                std::thread::yield_now();
                                continue;
                            }
                            // `last` may have been erased or displaced
                            // while we acquired; re-check.
                            let still_last = {
                                let ll = last.links.lock().unwrap();
                                ll.next.as_ref().is_some_and(|n| chain.is_tail(n))
                            };
                            if !still_last
                                || last.state() == crate::chain::NodeState::Erased
                            {
                                last.visitor.release();
                                std::thread::yield_now();
                                continue;
                            }
                            chain.tail().visitor.acquire();
                            let node = chain.append_after(&last, t * iters + i);
                            chain.tail().visitor.release();
                            last.visitor.release();
                            break node;
                        };
                        node.visitor.acquire();
                        node.begin_execution();
                        node.visitor.release();
                        node.visitor.acquire();
                        chain.unlink(&node);
                        node.visitor.release();
                    }
                });
            }
        });
        assert!(chain.is_empty());
        assert_eq!(chain.created(), 3 * iters);
        assert_eq!(chain.erased(), 3 * iters);
        assert_eq!(chain.validate().unwrap(), Vec::<u64>::new());
    }

    #[test]
    fn append_tail_matches_slot_based_appends() {
        let c: Chain<u32> = Chain::new();
        let a = append(&c, 1); // slot-based
        let b = c.append_tail(2); // lock-based
        let d = append(&c, 3);
        assert_eq!(c.validate().unwrap(), vec![0, 1, 2]);
        assert_eq!((a.seq(), b.seq(), d.seq()), (0, 1, 2));
        for n in [a, b, d] {
            n.visitor.acquire();
            n.begin_execution();
            c.unlink(&n);
            n.visitor.release();
        }
        assert!(c.is_empty());
        assert_eq!(c.validate().unwrap(), Vec::<u64>::new());
    }

    #[test]
    fn append_tail_races_unlink_safely() {
        // One thread appends (serialized appender, like the splitter),
        // another executes+unlinks from the front: the erase lock keeps
        // the structure consistent without visitor-slot handshakes.
        let chain: std::sync::Arc<Chain<u64>> = std::sync::Arc::new(Chain::new());
        let n = 4_000u64;
        std::thread::scope(|s| {
            {
                let chain = chain.clone();
                s.spawn(move || {
                    for i in 0..n {
                        chain.append_tail(i);
                    }
                });
            }
            {
                let chain = chain.clone();
                s.spawn(move || {
                    let mut done = 0u64;
                    while done < n {
                        let first = {
                            let hl = chain.head().links.lock().unwrap();
                            hl.next.clone().unwrap()
                        };
                        if chain.is_tail(&first) {
                            std::thread::yield_now();
                            continue;
                        }
                        first.visitor.acquire();
                        if first.state() == crate::chain::NodeState::Erased {
                            first.visitor.release();
                            continue;
                        }
                        first.begin_execution();
                        chain.unlink(&first);
                        first.visitor.release();
                        done += 1;
                    }
                });
            }
        });
        assert!(chain.is_empty());
        assert_eq!(chain.created(), n);
        assert_eq!(chain.erased(), n);
        assert_eq!(chain.validate().unwrap(), Vec::<u64>::new());
    }

    #[test]
    fn exhausted_flag() {
        let c: Chain<u32> = Chain::new();
        assert!(!c.exhausted());
        c.set_exhausted();
        assert!(c.exhausted());
        c.reopen();
        assert!(!c.exhausted(), "reopen clears the flag for the next epoch");
        c.set_exhausted();
        assert!(c.exhausted());
    }
}
