//! The task chain (paper §3.3): a bidirectional linked list of tasks with
//! head/tail sentinels, traversed concurrently by workers under a
//! lock-coupling discipline — stored in an index-based node **arena**
//! with generation-tagged handles, slot recycling and batched task
//! creation (DESIGN.md §3).
//!
//! Lock inventory (mapping to the paper's locks):
//!
//! | Paper | Here |
//! |---|---|
//! | "dedicated mutex lock attached to each task" (waiting of one worker behind another) | [`node::Occupancy`] — the per-slot *visitor slot* |
//! | "enter-lock" (task creation when the chain is empty) | the **head sentinel's** visitor slot: entering workers serialize on it, and an empty chain is just `head ↔ tail`, so creation-from-empty uses the ordinary creation path |
//! | "erase-lock" (at most one erase at a time) | [`list::Chain::unlink`]'s internal erase lock |
//!
//! Additional, implementation-level locks: each slot carries a tiny link
//! mutex guarding its prev/next handles (the paper's C++ can rely on
//! word-sized pointer stores; Rust's memory model requires the accesses
//! to be synchronized). Link locks are *leaf* locks — never held while
//! blocking on anything else — so they cannot participate in deadlock
//! cycles.
//!
//! Nodes are addressed by [`Handle`]s — a `u32` slot index plus the
//! generation tag observed at link time. Erasing a node bumps the slot's
//! generation and returns it to the chain's free list, so steady-state
//! execution allocates nothing; every dereference that cannot pin the
//! node validates the tag first, which is what makes recycling safe (the
//! ABA argument in DESIGN.md §3). See `protocol::worker` for the full
//! traversal state machine and DESIGN.md §6 for the consistency argument.

pub mod arena;
pub mod list;
pub mod node;

pub use arena::Handle;
pub use list::Chain;
pub use node::{NodeKind, NodeState};
