//! The task chain (paper §3.3): a bidirectional linked list of tasks with
//! head/tail sentinels, traversed concurrently by workers under a
//! lock-coupling discipline.
//!
//! Lock inventory (mapping to the paper's locks):
//!
//! | Paper | Here |
//! |---|---|
//! | "dedicated mutex lock attached to each task" (waiting of one worker behind another) | [`node::Occupancy`] — the per-node *visitor slot* |
//! | "enter-lock" (task creation when the chain is empty) | the **head sentinel's** visitor slot: entering workers serialize on it, and an empty chain is just `head ↔ tail`, so creation-from-empty uses the ordinary creation path |
//! | "erase-lock" (at most one erase at a time) | [`list::Chain::erase_lock`] |
//!
//! Additional, implementation-level locks: each node carries a tiny `links`
//! mutex guarding its prev/next pointers (the paper's C++ can rely on
//! word-sized pointer stores; Rust's memory model requires the accesses to
//! be synchronized). Link locks are *leaf* locks — never held while
//! blocking on anything else — so they cannot participate in deadlock
//! cycles. See `protocol::worker` for the full traversal state machine and
//! DESIGN.md §6 for the consistency argument.

pub mod list;
pub mod node;

pub use list::Chain;
pub use node::{Node, NodeState};
