//! Chain nodes: sentinels and task nodes, with their two per-node
//! synchronization devices (visitor slot + link lock).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Arc, Condvar, Mutex, Weak};

/// Lifecycle of a task node. Sentinels stay `Pending` forever.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum NodeState {
    /// Created, not yet executed.
    Pending = 0,
    /// A worker is executing the task (workers may pass it, absorbing its
    /// recipe).
    Executing = 1,
    /// Executed and unlinked; any visitor that reaches it must retry from
    /// its previous position.
    Erased = 2,
}

impl NodeState {
    fn from_u8(v: u8) -> NodeState {
        match v {
            0 => NodeState::Pending,
            1 => NodeState::Executing,
            2 => NodeState::Erased,
            _ => unreachable!("invalid node state {v}"),
        }
    }
}

/// The per-node *visitor slot* — the paper's "dedicated mutex lock attached
/// to each task in the chain", implemented as a binary semaphore (guard
/// lifetimes would otherwise tie visitor slots to stack frames, but a
/// worker holds its slot across arbitrary control flow).
///
/// Semantics: at most one worker is *located at* a node at any time. A
/// worker located at a node blocks others from arriving; a worker
/// *executing* a node has released the slot (paper: workers may move past a
/// task that is being executed).
///
/// Perf (EXPERIMENTS.md §Perf #1): slot operations happen on every
/// traversal step, so the common uncontended case is a single CAS; the
/// Mutex+Condvar pair is touched only under contention. States:
/// 0 = free, 1 = held, 2 = held with (possible) waiters.
#[derive(Debug, Default)]
pub struct Occupancy {
    state: AtomicU8,
    lock: Mutex<()>,
    cv: Condvar,
}

impl Occupancy {
    const FREE: u8 = 0;
    const HELD: u8 = 1;
    const CONTENDED: u8 = 2;

    /// Block until the slot is free, then take it.
    #[inline]
    pub fn acquire(&self) {
        if self
            .state
            .compare_exchange(Self::FREE, Self::HELD, Ordering::Acquire, Ordering::Relaxed)
            .is_ok()
        {
            return;
        }
        self.acquire_slow();
    }

    #[cold]
    fn acquire_slow(&self) {
        let mut guard = self.lock.lock().unwrap();
        loop {
            // Mark contended while attempting to take the slot; whoever
            // releases a CONTENDED slot will notify under `lock`, so the
            // wait below cannot miss a wakeup.
            let prev = self.state.swap(Self::CONTENDED, Ordering::Acquire);
            if prev == Self::FREE {
                return; // slot taken (conservatively marked contended)
            }
            guard = self.cv.wait(guard).unwrap();
        }
    }

    /// Take the slot if free; `true` on success.
    #[inline]
    pub fn try_acquire(&self) -> bool {
        self.state
            .compare_exchange(Self::FREE, Self::HELD, Ordering::Acquire, Ordering::Relaxed)
            .is_ok()
    }

    /// Release the slot. Panics if the slot was not held (protocol bug).
    #[inline]
    pub fn release(&self) {
        let prev = self.state.swap(Self::FREE, Ordering::Release);
        assert_ne!(prev, Self::FREE, "releasing a free occupancy slot");
        if prev == Self::CONTENDED {
            // Serialize with waiters' swap-then-wait under `lock`.
            let _guard = self.lock.lock().unwrap();
            self.cv.notify_all();
        }
    }
}

/// Node kind. The chain always contains exactly one `Head` and one `Tail`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeKind {
    /// Start sentinel ("start of the chain"): never executed, never erased.
    Head,
    /// End sentinel: creation happens just before it.
    Tail,
    /// A real task.
    Task,
}

/// prev/next pointers, guarded by the node's link lock.
#[derive(Debug)]
pub struct Links<R> {
    /// Weak to avoid `prev` cycles; upgraded only under the erase lock.
    pub prev: Weak<Node<R>>,
    /// Strong forward pointer; `None` only for the tail sentinel and for
    /// erased (unlinked) nodes.
    pub next: Option<Arc<Node<R>>>,
}

/// A chain node. `R` is the model's recipe type.
#[derive(Debug)]
pub struct Node<R> {
    /// Total order along the chain: head = 0, task i = i + 1, tail =
    /// `u64::MAX`. Insertion happens only at the tail, so chain position
    /// order and `order` agree; link locks are always taken in ascending
    /// `order`, which makes lock ordering trivially acyclic.
    pub(crate) order: u64,
    /// Task sequence number (creation index, 0-based); meaningless for
    /// sentinels. Drives the per-task RNG stream.
    pub(crate) seq: u64,
    pub(crate) kind: NodeKind,
    state: AtomicU8,
    pub(crate) visitor: Occupancy,
    pub(crate) links: Mutex<Links<R>>,
    /// Immutable after creation; `None` for sentinels.
    pub(crate) recipe: Option<R>,
}

impl<R> Node<R> {
    pub(crate) fn sentinel(kind: NodeKind, order: u64) -> Arc<Self> {
        Arc::new(Node {
            order,
            seq: u64::MAX,
            kind,
            state: AtomicU8::new(NodeState::Pending as u8),
            visitor: Occupancy::default(),
            links: Mutex::new(Links {
                prev: Weak::new(),
                next: None,
            }),
            recipe: None,
        })
    }

    pub(crate) fn task(seq: u64, recipe: R) -> Arc<Self> {
        Self::task_linked(seq, recipe, Weak::new(), None)
    }

    /// Build a task node with its links pre-set — the node is not yet
    /// published, so no lock is needed (EXPERIMENTS.md §Perf #2).
    pub(crate) fn task_linked(
        seq: u64,
        recipe: R,
        prev: Weak<Node<R>>,
        next: Option<Arc<Node<R>>>,
    ) -> Arc<Self> {
        Arc::new(Node {
            order: seq + 1,
            seq,
            kind: NodeKind::Task,
            state: AtomicU8::new(NodeState::Pending as u8),
            visitor: Occupancy::default(),
            links: Mutex::new(Links { prev, next }),
            recipe: Some(recipe),
        })
    }

    /// Current lifecycle state.
    #[inline]
    pub fn state(&self) -> NodeState {
        NodeState::from_u8(self.state.load(Ordering::Acquire))
    }

    /// Transition `Pending → Executing`. Caller must hold the visitor slot
    /// (only the located worker may claim execution), which serializes the
    /// transition.
    #[inline]
    pub(crate) fn begin_execution(&self) {
        debug_assert_eq!(self.kind, NodeKind::Task);
        let prev = self.state.swap(NodeState::Executing as u8, Ordering::AcqRel);
        debug_assert_eq!(prev, NodeState::Pending as u8, "double execution");
    }

    /// Transition to `Erased`. Caller must hold the visitor slot and the
    /// erase lock.
    #[inline]
    pub(crate) fn mark_erased(&self) {
        let prev = self.state.swap(NodeState::Erased as u8, Ordering::AcqRel);
        debug_assert_eq!(prev, NodeState::Executing as u8, "erase before execute");
    }

    /// Node kind.
    #[inline]
    pub fn kind(&self) -> NodeKind {
        self.kind
    }

    /// Task sequence number (panics on sentinels).
    #[inline]
    pub fn seq(&self) -> u64 {
        debug_assert_eq!(self.kind, NodeKind::Task);
        self.seq
    }

    /// The recipe (panics on sentinels). Immutable after creation, so this
    /// is safe to read while another worker executes the task.
    #[inline]
    pub fn recipe(&self) -> &R {
        self.recipe.as_ref().expect("sentinel has no recipe")
    }

    /// Snapshot of the forward pointer.
    #[inline]
    pub(crate) fn next(&self) -> Option<Arc<Node<R>>> {
        self.links.lock().unwrap().next.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn occupancy_mutual_exclusion() {
        let occ = Arc::new(Occupancy::default());
        occ.acquire();
        assert!(!occ.try_acquire());
        let o2 = occ.clone();
        let t = std::thread::spawn(move || {
            o2.acquire(); // blocks until main releases
            o2.release();
        });
        std::thread::sleep(Duration::from_millis(20));
        occ.release();
        t.join().unwrap();
        assert!(occ.try_acquire());
        occ.release();
    }

    #[test]
    #[should_panic]
    fn release_unheld_panics() {
        Occupancy::default().release();
    }

    #[test]
    fn node_state_transitions() {
        let n = Node::task(0, 42u32);
        assert_eq!(n.state(), NodeState::Pending);
        n.visitor.acquire();
        n.begin_execution();
        assert_eq!(n.state(), NodeState::Executing);
        n.mark_erased();
        assert_eq!(n.state(), NodeState::Erased);
        assert_eq!(*n.recipe(), 42);
        assert_eq!(n.seq(), 0);
    }

    #[test]
    fn sentinel_orders() {
        let h = Node::<u32>::sentinel(NodeKind::Head, 0);
        let t = Node::<u32>::sentinel(NodeKind::Tail, u64::MAX);
        assert!(h.order < Node::task(0, 1u32).order);
        assert!(Node::task(1_000_000, 1u32).order < t.order);
    }
}
