//! Chain node storage: the per-slot state machine (lifecycle state,
//! generation tag, visitor slot, link lock, inline recipe cell).
//!
//! Since the arena refactor (DESIGN.md §3) a "node" is not an owned
//! allocation but a **slot** in the chain's [`Arena`](super::arena::Arena),
//! addressed by a generation-tagged [`Handle`](super::arena::Handle).
//! The slot carries the same two synchronization devices as the old
//! `Arc`-based node — the visitor slot and the link lock — plus the
//! generation counter that makes recycling safe (see the safety notes on
//! the crate-private `Slot` type below).

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU32, AtomicU8, Ordering};
use std::sync::{Condvar, Mutex};

use super::arena::Handle;

/// Lifecycle of a task node. Sentinels stay `Pending` forever.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum NodeState {
    /// Created, not yet executed.
    Pending = 0,
    /// A worker is executing the task (workers may pass it, absorbing its
    /// recipe).
    Executing = 1,
    /// Executed and unlinked; its slot is on the free list. Visitors
    /// detect this via the generation tag, not this state (a recycled
    /// slot is `Pending` again) — the state exists for the brief
    /// erased-but-not-yet-reused window and for assertions.
    Erased = 2,
}

impl NodeState {
    pub(crate) fn from_u8(v: u8) -> NodeState {
        match v {
            0 => NodeState::Pending,
            1 => NodeState::Executing,
            2 => NodeState::Erased,
            _ => unreachable!("invalid node state {v}"),
        }
    }
}

/// The per-node *visitor slot* — the paper's "dedicated mutex lock attached
/// to each task in the chain", implemented as a binary semaphore (guard
/// lifetimes would otherwise tie visitor slots to stack frames, but a
/// worker holds its slot across arbitrary control flow).
///
/// Semantics: at most one worker is *located at* a node at any time. A
/// worker located at a node blocks others from arriving; a worker
/// *executing* a node has released the slot (paper: workers may move past a
/// task that is being executed).
///
/// The device belongs to the **slot**, not the node incarnation: it is
/// never reset on recycle. A worker that acquires the slot of a recycled
/// node detects the staleness by the generation tag and releases again.
///
/// Perf (EXPERIMENTS.md §Perf #1): slot operations happen on every
/// traversal step, so the common uncontended case is a single CAS; the
/// Mutex+Condvar pair is touched only under contention. States:
/// 0 = free, 1 = held, 2 = held with (possible) waiters.
#[derive(Debug, Default)]
pub struct Occupancy {
    state: AtomicU8,
    lock: Mutex<()>,
    cv: Condvar,
}

impl Occupancy {
    const FREE: u8 = 0;
    const HELD: u8 = 1;
    const CONTENDED: u8 = 2;

    /// Block until the slot is free, then take it.
    #[inline]
    pub fn acquire(&self) {
        if self
            .state
            .compare_exchange(Self::FREE, Self::HELD, Ordering::Acquire, Ordering::Relaxed)
            .is_ok()
        {
            return;
        }
        self.acquire_slow();
    }

    #[cold]
    fn acquire_slow(&self) {
        let mut guard = self.lock.lock().unwrap();
        loop {
            // Mark contended while attempting to take the slot; whoever
            // releases a CONTENDED slot will notify under `lock`, so the
            // wait below cannot miss a wakeup.
            let prev = self.state.swap(Self::CONTENDED, Ordering::Acquire);
            if prev == Self::FREE {
                return; // slot taken (conservatively marked contended)
            }
            guard = self.cv.wait(guard).unwrap();
        }
    }

    /// Take the slot if free; `true` on success.
    #[inline]
    pub fn try_acquire(&self) -> bool {
        self.state
            .compare_exchange(Self::FREE, Self::HELD, Ordering::Acquire, Ordering::Relaxed)
            .is_ok()
    }

    /// Release the slot. Panics if the slot was not held (protocol bug).
    #[inline]
    pub fn release(&self) {
        let prev = self.state.swap(Self::FREE, Ordering::Release);
        assert_ne!(prev, Self::FREE, "releasing a free occupancy slot");
        if prev == Self::CONTENDED {
            // Serialize with waiters' swap-then-wait under `lock`.
            let _guard = self.lock.lock().unwrap();
            self.cv.notify_all();
        }
    }
}

/// Node kind. The chain always contains exactly one `Head` and one `Tail`;
/// they live in the arena's first two slots, so the kind is a property of
/// the slot index and needs no storage.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeKind {
    /// Start sentinel ("start of the chain"): never executed, never erased.
    Head,
    /// End sentinel: creation happens just before it.
    Tail,
    /// A real task.
    Task,
}

/// prev/next handles, guarded by the slot's link lock. [`Handle::NONE`]
/// marks an unlinked end (erased slots, the head's prev, the tail's next).
#[derive(Clone, Copy, Debug)]
pub(crate) struct Links {
    pub(crate) prev: Handle,
    pub(crate) next: Handle,
}

/// The fields of a slot that belong to one node *incarnation*: written at
/// allocation (before publication), cleared at erase. See the safety
/// argument on [`Slot`].
#[derive(Debug)]
pub(crate) struct Meta<R> {
    /// Task sequence number (creation index, 0-based); `u64::MAX` for
    /// sentinels. Drives the per-task RNG stream.
    pub(crate) seq: u64,
    /// The recipe; `None` for sentinels and erased slots.
    pub(crate) recipe: Option<R>,
}

/// One arena slot. `R` is the model's recipe type.
///
/// # Safety argument (recipe/meta access under recycling)
///
/// `meta` sits in an `UnsafeCell` and is mutated at exactly two points,
/// both while holding the slot's `links` mutex:
///
/// 1. **allocation** ([`Chain::fill_tail`](super::Chain::fill_tail) /
///    [`append_tail`](super::Chain::append_tail)): the slot is off the
///    free list and unpublished, so no handle to *this incarnation*
///    exists yet;
/// 2. **erase** ([`Chain::unlink`](super::Chain::unlink)): the erasing
///    worker holds the visitor slot (so no located worker can be
///    borrowing `meta`) and bumps `gen` under the same lock.
///
/// Readers fall into two classes:
///
/// * **pinned readers** hold the visitor slot, or have claimed execution
///   (`Executing` state — only the claimant can erase). The incarnation
///   cannot be erased under them, so `meta` is stable and the unguarded
///   read ([`Chain::recipe`](super::Chain::recipe)) is race-free. The
///   happens-before edge to the allocation writes runs through the link
///   mutex of the node that published the handle.
/// * **validated readers** take the slot's `links` mutex and compare
///   `gen` against their handle's tag
///   ([`Chain::with_recipe`](super::Chain::with_recipe)): a match under
///   the lock proves the incarnation is still live, and the lock excludes
///   both mutation points for the duration of the read.
pub(crate) struct Slot<R> {
    /// Incarnation counter, bumped at erase (under `links`). A handle is
    /// valid iff its tag equals this value.
    pub(crate) gen: AtomicU32,
    /// Lifecycle state of the current incarnation.
    pub(crate) state: AtomicU8,
    /// The visitor slot (location mutual exclusion).
    pub(crate) visitor: Occupancy,
    /// prev/next of the current incarnation.
    pub(crate) links: Mutex<Links>,
    /// Intrusive free-list link (valid only while the slot is free).
    pub(crate) free_next: AtomicU32,
    /// Incarnation data; see the safety argument above.
    pub(crate) meta: UnsafeCell<Meta<R>>,
}

// SAFETY: all shared access to `meta` follows the discipline documented
// on the struct; every other field is a sync primitive or an atomic.
unsafe impl<R: Send> Send for Slot<R> {}
unsafe impl<R: Send + Sync> Sync for Slot<R> {}

impl<R> Slot<R> {
    /// A fresh, free slot (generation 0, no incarnation).
    pub(crate) fn new() -> Self {
        Slot {
            gen: AtomicU32::new(0),
            state: AtomicU8::new(NodeState::Pending as u8),
            visitor: Occupancy::default(),
            links: Mutex::new(Links {
                prev: Handle::NONE,
                next: Handle::NONE,
            }),
            free_next: AtomicU32::new(u32::MAX),
            meta: UnsafeCell::new(Meta {
                seq: u64::MAX,
                recipe: None,
            }),
        }
    }

    /// Current lifecycle state.
    #[inline]
    pub(crate) fn load_state(&self) -> NodeState {
        NodeState::from_u8(self.state.load(Ordering::Acquire))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn occupancy_mutual_exclusion() {
        let occ = Arc::new(Occupancy::default());
        occ.acquire();
        assert!(!occ.try_acquire());
        let o2 = occ.clone();
        let t = std::thread::spawn(move || {
            o2.acquire(); // blocks until main releases
            o2.release();
        });
        std::thread::sleep(Duration::from_millis(20));
        occ.release();
        t.join().unwrap();
        assert!(occ.try_acquire());
        occ.release();
    }

    #[test]
    #[should_panic]
    fn release_unheld_panics() {
        Occupancy::default().release();
    }

    #[test]
    fn fresh_slot_shape() {
        let s: Slot<u32> = Slot::new();
        assert_eq!(s.load_state(), NodeState::Pending);
        assert_eq!(s.gen.load(Ordering::Relaxed), 0);
        let l = s.links.lock().unwrap();
        assert!(l.prev.is_none() && l.next.is_none());
    }
}
