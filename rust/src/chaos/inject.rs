//! The injection side of the chaos harness (DESIGN.md §10).
//!
//! A [`FaultHook`] turns a [`FaultPlan`] into concrete per-epoch
//! injections and collects invariant violations the engines detect
//! while it is installed. Engines accept the hook as an
//! `Option<&mut FaultHook>` and consult it **at epoch boundaries
//! only** — the chain inner loop gains zero per-task branches when no
//! plan is installed, and the per-worker stall is read once per epoch
//! at worker start-up, outside the cycle loop.
//!
//! All draws come from [`Rng::stream`] keyed by the plan seed and the
//! epoch index, so an injection schedule is a pure function of
//! `(plan, epoch, workers)` and any failure replays exactly.

use crate::chaos::invariant::{Invariant, Violation};
use crate::chaos::plan::{CostSkew, FaultPlan};
use crate::sim::rng::Rng;
use crate::vtime::CostModel;
use std::time::Duration;

/// Domain constant separating chaos RNG streams from every simulation
/// stream (the task domain is `0x7A5C_0000_5EED_0001`).
const CHAOS_DOMAIN: u64 = 0x7A5C_0000_C4A0_5001;

/// Wall-clock engines cap each injected sleep at 2 ms so a soak sweep
/// stays fast; virtual engines apply the full virtual duration.
const WALL_CAP_NS: u64 = 2_000_000;

/// The faults one epoch injects, fully resolved per worker.
#[derive(Clone, Debug, PartialEq)]
pub struct EpochFaults {
    /// Per-worker stall, virtual nanoseconds (explicit [`FaultPlan::stalls`]
    /// entries matching this epoch, summed).
    pub stall_ns: Vec<f64>,
    /// Per-worker order-perturbation draw in `[0, order_jitter_ns)`.
    pub jitter_ns: Vec<f64>,
    /// Mean cost-skew multiplier, for engines without per-block costs.
    pub exec_scale: f64,
    /// The raw per-block skews, for the sharded engine's cost probe.
    pub skews: Vec<CostSkew>,
    /// Fence/spillover stagger (wall engines: `worker * fence_delay_ns`).
    pub fence_delay_ns: u64,
}

impl EpochFaults {
    /// True when this epoch injects nothing at all.
    pub fn is_noop(&self) -> bool {
        self.stall_ns.iter().all(|&ns| ns == 0.0)
            && self.jitter_ns.iter().all(|&ns| ns == 0.0)
            && self.exec_scale == 1.0
            && self.skews.is_empty()
            && self.fence_delay_ns == 0
    }

    /// Total virtual delay for one worker (stall + jitter).
    pub fn delay_ns(&self, worker: usize) -> f64 {
        self.stall_ns.get(worker).copied().unwrap_or(0.0)
            + self.jitter_ns.get(worker).copied().unwrap_or(0.0)
    }

    /// Wall-clock sleeps for thread engines: the virtual delay plus the
    /// fence stagger, each capped at 2 ms.
    pub fn wall_stalls(&self) -> Vec<Duration> {
        (0..self.stall_ns.len())
            .map(|w| {
                let ns = self.delay_ns(w) as u64 + self.fence_delay_ns * w as u64;
                Duration::from_nanos(ns.min(WALL_CAP_NS))
            })
            .collect()
    }

    /// The base cost model with this epoch's mean skew folded into the
    /// execution costs (used by the virtual engine, which has no
    /// per-block cost table).
    pub fn scaled_cost(&self, base: &CostModel) -> CostModel {
        let mut c = *base;
        c.exec_fixed_ns *= self.exec_scale;
        c.exec_unit_ns *= self.exec_scale;
        c
    }
}

/// Mutable injection state threaded through an engine run: the plan, an
/// epoch counter, and the violations detected while injecting.
#[derive(Clone, Debug)]
pub struct FaultHook {
    plan: FaultPlan,
    epoch: u64,
    violations: Vec<Violation>,
}

impl FaultHook {
    /// Install a plan. `FaultHook::new(plan).into()` is the usual call
    /// shape at an engine's `run_chaos` entry point.
    pub fn new(plan: FaultPlan) -> Self {
        Self {
            plan,
            epoch: 0,
            violations: Vec::new(),
        }
    }

    /// The installed plan.
    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Epochs injected so far.
    pub fn epochs(&self) -> u64 {
        self.epoch
    }

    /// The epoch cadence an unobserved chaos run should use: the plan's
    /// override when set, the engine default otherwise. Observed runs
    /// must keep the observer's cadence (trace identity is defined at
    /// observation boundaries), so engines only consult this when no
    /// observer is attached.
    pub fn every_or(&self, default_every: u64) -> u64 {
        if self.plan.every > 0 {
            self.plan.every
        } else {
            default_every
        }
    }

    /// Resolve the next epoch's faults and advance the epoch counter.
    /// Deterministic: stream `(plan.seed ^ CHAOS_DOMAIN, epoch)` feeds
    /// the jitter draws, one per worker in worker order.
    pub fn next_epoch(&mut self, workers: usize) -> EpochFaults {
        let epoch = self.epoch;
        self.epoch += 1;
        let mut stall_ns = vec![0.0; workers];
        for s in &self.plan.stalls {
            if s.epoch == epoch && s.worker < workers {
                stall_ns[s.worker] += s.ns;
            }
        }
        let mut rng = Rng::stream(self.plan.seed ^ CHAOS_DOMAIN, epoch);
        let jitter_ns = (0..workers)
            .map(|_| {
                if self.plan.order_jitter_ns > 0.0 {
                    rng.unit_f64() * self.plan.order_jitter_ns
                } else {
                    0.0
                }
            })
            .collect();
        let exec_scale = if self.plan.cost_skew.is_empty() {
            1.0
        } else {
            self.plan.cost_skew.iter().map(|c| c.mul).sum::<f64>()
                / self.plan.cost_skew.len() as f64
        };
        EpochFaults {
            stall_ns,
            jitter_ns,
            exec_scale,
            skews: self.plan.cost_skew.clone(),
            fence_delay_ns: self.plan.fence_delay_ns,
        }
    }

    /// Record an invariant violation detected at an epoch boundary.
    pub fn record_violation(&mut self, invariant: Invariant, detail: impl Into<String>) {
        self.violations.push(Violation {
            invariant,
            detail: detail.into(),
        });
    }

    /// Violations recorded so far.
    pub fn violations(&self) -> &[Violation] {
        &self.violations
    }

    /// Drain the recorded violations (used after a run completes).
    pub fn take_violations(&mut self) -> Vec<Violation> {
        std::mem::take(&mut self.violations)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chaos::plan::bundled_plan;

    #[test]
    fn schedule_is_deterministic_in_plan_and_epoch() {
        let plan = bundled_plan("jitter").unwrap();
        let mut a = FaultHook::new(plan.clone());
        let mut b = FaultHook::new(plan);
        for _ in 0..5 {
            assert_eq!(a.next_epoch(4), b.next_epoch(4));
        }
    }

    #[test]
    fn stalls_land_on_their_epoch_and_worker() {
        let plan = FaultPlan::new("s", 9).stall(1, 2, 500.0).stall(1, 2, 250.0);
        let mut hook = FaultHook::new(plan);
        assert!(hook.next_epoch(2).is_noop()); // epoch 0
        assert!(hook.next_epoch(2).is_noop()); // epoch 1
        let f = hook.next_epoch(2); // epoch 2
        assert_eq!(f.stall_ns, vec![0.0, 750.0]);
        assert!(hook.next_epoch(2).is_noop()); // epoch 3
    }

    #[test]
    fn out_of_range_workers_are_ignored() {
        let plan = FaultPlan::new("wide", 9).stall(7, 0, 500.0);
        let mut hook = FaultHook::new(plan);
        assert!(hook.next_epoch(2).is_noop());
    }

    #[test]
    fn jitter_draws_are_bounded_and_distinct() {
        let mut hook = FaultHook::new(FaultPlan::new("j", 3).jitter(100.0));
        let f = hook.next_epoch(4);
        for &j in &f.jitter_ns {
            assert!((0.0..100.0).contains(&j));
        }
        assert!(
            f.jitter_ns.windows(2).any(|w| w[0] != w[1]),
            "independent draws per worker"
        );
    }

    #[test]
    fn exec_scale_is_the_mean_multiplier() {
        let mut hook = FaultHook::new(FaultPlan::new("k", 1).skew(0, 3.0).skew(1, 1.0));
        let f = hook.next_epoch(1);
        assert!((f.exec_scale - 2.0).abs() < 1e-12);
        let base = CostModel::default();
        let scaled = f.scaled_cost(&base);
        assert!((scaled.exec_unit_ns - base.exec_unit_ns * 2.0).abs() < 1e-12);
        assert!((scaled.visit_ns - base.visit_ns).abs() < 1e-12);
    }

    #[test]
    fn wall_stalls_are_capped_and_staggered() {
        let mut hook = FaultHook::new(
            FaultPlan::new("w", 1).stall(0, 0, 10_000_000_000.0).fence_delay(1_000),
        );
        let f = hook.next_epoch(3);
        let stalls = f.wall_stalls();
        assert_eq!(stalls[0], Duration::from_nanos(WALL_CAP_NS));
        assert_eq!(stalls[1], Duration::from_nanos(1_000));
        assert_eq!(stalls[2], Duration::from_nanos(2_000));
    }

    #[test]
    fn every_override_applies_only_when_set() {
        let hook = FaultHook::new(FaultPlan::new("e", 1).with_every(64));
        assert_eq!(hook.every_or(u64::MAX), 64);
        let hook = FaultHook::new(FaultPlan::new("e", 1));
        assert_eq!(hook.every_or(512), 512);
    }
}
