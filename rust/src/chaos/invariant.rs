//! Runtime invariant checkers for injected runs (DESIGN.md §10).
//!
//! Each checker turns one of the protocol's correctness statements into
//! a function over observable run artifacts:
//!
//! | invariant | statement | evidence |
//! |---|---|---|
//! | trace identity | every engine's epoch trace is byte-identical to the sequential oracle | [`Observations`] equality |
//! | task conservation | every created task is executed exactly once | `tasks_created == tasks_executed` |
//! | arena leak-freedom | at teardown only the chain sentinels are live | `arena_live == 2 × chains` |
//! | fence discipline | no task executes before its fence clears; all fences clear by quiescence | in-engine boundary check (generation-tagged handles) |
//! | rebalancer convergence | ≤ `max_moves` migrations per epoch, load gap non-increasing | in-engine boundary check |
//!
//! The first three are checked here, post-run, from the
//! [`RunReport`]/[`Observations`] a chaos run returns. The last two need
//! in-flight state and are checked inside `sched/engine.rs` at epoch
//! boundaries whenever a [`crate::chaos::FaultHook`] is installed,
//! recording [`Violation`]s into the hook.

use crate::api::Observations;
use crate::protocol::RunReport;
use std::fmt;

/// The invariant a [`Violation`] breaks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Invariant {
    /// Epoch trace differs from the sequential oracle.
    TraceIdentity,
    /// Created and executed task counts diverge.
    TaskConservation,
    /// Arena slots beyond the sentinels are live at teardown.
    ArenaLeakFree,
    /// A fence failed to clear by quiescence, or a chain drained dirty.
    FenceDiscipline,
    /// The rebalancer migrated too much or widened the load gap.
    RebalanceConvergence,
}

impl fmt::Display for Invariant {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Invariant::TraceIdentity => "trace-identity",
            Invariant::TaskConservation => "task-conservation",
            Invariant::ArenaLeakFree => "arena-leak-free",
            Invariant::FenceDiscipline => "fence-discipline",
            Invariant::RebalanceConvergence => "rebalance-convergence",
        })
    }
}

/// One detected invariant violation.
#[derive(Clone, Debug, PartialEq)]
pub struct Violation {
    /// Which invariant broke.
    pub invariant: Invariant,
    /// Human-readable evidence (first diverging frame, counts, ...).
    pub detail: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}] {}", self.invariant, self.detail)
    }
}

/// Compare an injected run's trace against the sequential oracle.
/// `label` names the run in the violation detail (engine, workers, seed).
pub fn check_trace(label: &str, reference: &Observations, got: &Observations) -> Option<Violation> {
    if got == reference {
        return None;
    }
    let detail = if got.len() != reference.len() {
        format!(
            "{label}: trace has {} frames, oracle has {}",
            got.len(),
            reference.len()
        )
    } else {
        let at = reference
            .frames
            .iter()
            .zip(&got.frames)
            .position(|(a, b)| a != b)
            .unwrap_or(0);
        format!(
            "{label}: first divergence at frame {at} (tasks={}): got `{}`, oracle `{}`",
            reference.frames[at].tasks, got.frames[at], reference.frames[at]
        )
    };
    Some(Violation {
        invariant: Invariant::TraceIdentity,
        detail,
    })
}

/// Sentinel slots expected live at teardown: two per chain (head +
/// tail). The sharded engine runs `shards` chains plus the spillover
/// chain; the chain engines run one.
pub fn expected_live(report: &RunReport) -> usize {
    let chains = match &report.sched {
        Some(s) => s.shards + 1,
        None => 1,
    };
    2 * chains
}

/// Post-run report checks: task conservation and arena leak-freedom.
/// Engines that do not use the arena (sequential, stepwise, virtual)
/// report `arena_live == 0` and skip the leak check.
pub fn check_report(label: &str, report: &RunReport) -> Vec<Violation> {
    let mut out = Vec::new();
    let chain = &report.chain;
    if chain.tasks_created != chain.tasks_executed {
        out.push(Violation {
            invariant: Invariant::TaskConservation,
            detail: format!(
                "{label}: created {} tasks but executed {}",
                chain.tasks_created, chain.tasks_executed
            ),
        });
    }
    if chain.arena_live > 0 {
        let expected = expected_live(report);
        if chain.arena_live != expected {
            out.push(Violation {
                invariant: Invariant::ArenaLeakFree,
                detail: format!(
                    "{label}: {} arena slots live at teardown, expected {expected} \
                     sentinels (high water {}, recycled {})",
                    chain.arena_live, chain.arena_high_water, chain.arena_recycled
                ),
            });
        }
        if chain.arena_high_water < chain.arena_live {
            out.push(Violation {
                invariant: Invariant::ArenaLeakFree,
                detail: format!(
                    "{label}: high water {} below live count {}",
                    chain.arena_high_water, chain.arena_live
                ),
            });
        }
    }
    out
}

/// All post-run checks for one injected run: trace identity against the
/// oracle plus the report invariants.
pub fn check_run(
    label: &str,
    reference: &Observations,
    got: &Observations,
    report: &RunReport,
) -> Vec<Violation> {
    let mut out = Vec::new();
    out.extend(check_trace(label, reference, got));
    out.extend(check_report(label, report));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::observe::ObsFrame;
    use crate::api::ObsValue;
    use crate::protocol::{ProtocolStats, SchedStats};

    fn trace(vals: &[i64]) -> Observations {
        Observations {
            every: 10,
            frames: vals
                .iter()
                .enumerate()
                .map(|(i, &v)| ObsFrame {
                    tasks: 10 * i as u64,
                    values: vec![("m".to_string(), ObsValue::Int(v))],
                })
                .collect(),
        }
    }

    #[test]
    fn identical_traces_pass() {
        assert!(check_trace("x", &trace(&[1, 2, 3]), &trace(&[1, 2, 3])).is_none());
    }

    #[test]
    fn divergence_names_the_first_bad_frame() {
        let v = check_trace("x", &trace(&[1, 2, 3]), &trace(&[1, 9, 3])).unwrap();
        assert_eq!(v.invariant, Invariant::TraceIdentity);
        assert!(v.detail.contains("frame 1"), "{}", v.detail);
    }

    #[test]
    fn length_mismatch_is_reported() {
        let v = check_trace("x", &trace(&[1, 2, 3]), &trace(&[1, 2])).unwrap();
        assert!(v.detail.contains("2 frames"), "{}", v.detail);
    }

    fn report(live: usize, shards: Option<usize>) -> RunReport {
        RunReport {
            engine: "test",
            workers: 2,
            time_s: 0.0,
            basis: crate::protocol::TimeBasis::Wall,
            totals: Default::default(),
            per_worker: vec![],
            chain: ProtocolStats {
                tasks_created: 100,
                tasks_executed: 100,
                arena_live: live,
                arena_high_water: 40,
                ..Default::default()
            },
            sched: shards.map(|s| SchedStats {
                shards: s,
                ..Default::default()
            }),
            telemetry: None,
            trace: None,
        }
    }

    #[test]
    fn sentinel_only_teardown_passes() {
        assert!(check_report("x", &report(2, None)).is_empty());
        assert!(check_report("x", &report(8, Some(3))).is_empty());
        // Engines without an arena report zero and skip the check.
        assert!(check_report("x", &report(0, None)).is_empty());
    }

    #[test]
    fn leaked_slot_is_caught() {
        let vs = check_report("x", &report(3, None));
        assert_eq!(vs.len(), 1);
        assert_eq!(vs[0].invariant, Invariant::ArenaLeakFree);
    }

    #[test]
    fn task_loss_is_caught() {
        let mut r = report(2, None);
        r.chain.tasks_executed = 99;
        let vs = check_report("x", &r);
        assert_eq!(vs[0].invariant, Invariant::TaskConservation);
    }
}
