//! Deterministic chaos harness (DESIGN.md §10).
//!
//! Four pieces, one contract:
//!
//! * [`plan`] — seeded, declarative [`FaultPlan`]s (worker stalls,
//!   per-block cost skews, order jitter, fence delays) that serialize
//!   to the crate's TOML subset, so any failure is a committable repro.
//! * [`inject`] — the [`FaultHook`] engines accept as an
//!   `Option<&mut FaultHook>` and consult **at epoch boundaries only**;
//!   with no plan installed the chain hot path carries zero extra
//!   per-task branches.
//! * [`invariant`] — runtime checkers turning the protocol's
//!   correctness statements (trace identity vs the sequential oracle,
//!   task conservation, arena leak-freedom, fence discipline,
//!   rebalancer convergence) into [`Violation`]s.
//! * [`soak`] — the seed-sweep runner: seeds × fault plans × registry
//!   models, with bisection-based shrinking of a failing `(seed, plan)`
//!   pair down to a minimized repro TOML (`cli soak`).
//!
//! The contract under test is the determinism guarantee of DESIGN.md §5:
//! injected schedules may reorder dispatch arbitrarily, but canonical
//! creation order and per-task RNG streams pin final states and epoch
//! traces byte-identical to the sequential engine — under *every* fault
//! plan.

pub mod inject;
pub mod invariant;
pub mod plan;
pub mod soak;

pub use inject::{EpochFaults, FaultHook};
pub use invariant::{Invariant, Violation};
pub use plan::{CostSkew, FaultPlan, StallFault};
pub use soak::{SoakConfig, SoakFailure, SoakReport};
