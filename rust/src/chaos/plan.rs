//! Declarative, seeded fault plans (DESIGN.md §10).
//!
//! A [`FaultPlan`] is the committable unit of chaos: a named, seeded
//! description of every injection the harness performs during a run —
//! worker stalls of bounded virtual duration, per-block cost-skew
//! multipliers, dependence-respecting task-order perturbations, and
//! spillover/fence delays. Plans serialize to the crate's TOML subset
//! ([`FaultPlan::to_toml`]) and parse back ([`FaultPlan::from_toml`])
//! through [`crate::util::toml`], so a failing `(seed, plan)` pair
//! shrinks to a small file that can be committed and replayed
//! byte-for-byte.
//!
//! The TOML shape uses **parallel scalar arrays** rather than
//! array-of-tables — the config parser deliberately rejects `[[...]]`:
//!
//! ```toml
//! [plan]
//! name = "stalls"
//! seed = 7
//! every = 256
//! order_jitter_ns = 50.0
//! fence_delay_ns = 20000
//!
//! [stalls]
//! worker = [0, 1]
//! epoch = [2, 3]
//! ns = [50000.0, 80000.0]
//!
//! [cost_skew]
//! block = [0, 3]
//! mul = [8.0, 0.0]
//! ```

use crate::error::Result;
use crate::util::toml::{self, Value};
use std::fmt::Write as _;

/// A bounded virtual-duration stall of one worker at one epoch boundary.
///
/// The virtual engine adds `ns` to the worker's clock before the epoch
/// runs; the wall-clock engines sleep a capped equivalent. Out-of-range
/// worker indices are ignored by every engine, so a plan shrunk on a
/// wide run stays valid on a narrow one.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StallFault {
    /// Worker index the stall applies to.
    pub worker: usize,
    /// Epoch index at whose boundary the stall is injected.
    pub epoch: u64,
    /// Stall duration in virtual nanoseconds.
    pub ns: f64,
}

/// A per-block cost-skew multiplier.
///
/// The sharded engine feeds `mul` into the EWMA cost probe as a
/// synthetic observation (perturbing the rebalancer's view of block
/// cost); the virtual engine folds the mean multiplier into its
/// execution costs. `mul = 0.0` models a zero-cost block; large values
/// model pathological hot spots.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CostSkew {
    /// Block (shard-map cell) index.
    pub block: u32,
    /// Cost multiplier (must be finite and non-negative).
    pub mul: f64,
}

/// A seeded, declarative fault plan — see the module docs for the
/// serialized shape.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    /// Human-readable plan name (used in repro file names and reports).
    pub name: String,
    /// Seed for the plan's own RNG streams (jitter draws); independent
    /// of the simulation seed so the same plan can sweep many runs.
    pub seed: u64,
    /// Epoch cadence override for unobserved runs (0 = engine default).
    /// Observed runs keep the observer's cadence: trace identity is
    /// defined at observation boundaries.
    pub every: u64,
    /// Amplitude (virtual ns) of the per-epoch, per-worker order
    /// perturbation: each worker's clock is advanced by a deterministic
    /// draw in `[0, amplitude)`, reordering dispatch without touching
    /// the dependence relation (the protocol's discipline makes every
    /// interleaving dependence-respecting by construction).
    pub order_jitter_ns: f64,
    /// Spillover/fence delay: wall engines stagger worker starts by
    /// `worker_index * fence_delay_ns` (capped); the sharded engine
    /// thereby delays fence clearance windows.
    pub fence_delay_ns: u64,
    /// Worker stalls.
    pub stalls: Vec<StallFault>,
    /// Per-block cost skews.
    pub cost_skew: Vec<CostSkew>,
}

impl Default for FaultPlan {
    fn default() -> Self {
        Self {
            name: String::new(),
            seed: 0,
            every: 0,
            order_jitter_ns: 0.0,
            fence_delay_ns: 0,
            stalls: Vec::new(),
            cost_skew: Vec::new(),
        }
    }
}

impl FaultPlan {
    /// An empty (benign) plan with a name and seed.
    pub fn new(name: impl Into<String>, seed: u64) -> Self {
        Self {
            name: name.into(),
            seed,
            ..Self::default()
        }
    }

    /// Add a worker stall.
    pub fn stall(mut self, worker: usize, epoch: u64, ns: f64) -> Self {
        self.stalls.push(StallFault { worker, epoch, ns });
        self
    }

    /// Add a per-block cost skew.
    pub fn skew(mut self, block: u32, mul: f64) -> Self {
        self.cost_skew.push(CostSkew { block, mul });
        self
    }

    /// Set the order-jitter amplitude.
    pub fn jitter(mut self, ns: f64) -> Self {
        self.order_jitter_ns = ns;
        self
    }

    /// Set the fence/spillover delay.
    pub fn fence_delay(mut self, ns: u64) -> Self {
        self.fence_delay_ns = ns;
        self
    }

    /// Set the epoch-cadence override.
    pub fn with_every(mut self, every: u64) -> Self {
        self.every = every;
        self
    }

    /// Number of individually removable faults — the unit the shrinker
    /// minimizes (each stall, each skew, jitter, and the fence delay).
    pub fn fault_count(&self) -> usize {
        self.stalls.len()
            + self.cost_skew.len()
            + usize::from(self.order_jitter_ns > 0.0)
            + usize::from(self.fence_delay_ns > 0)
    }

    /// True when the plan injects nothing.
    pub fn is_benign(&self) -> bool {
        self.fault_count() == 0
    }

    /// Numeric sanity: finite, non-negative durations and multipliers.
    pub fn validate(&self) -> Result<()> {
        crate::ensure!(
            self.order_jitter_ns.is_finite() && self.order_jitter_ns >= 0.0,
            "plan `{}`: order_jitter_ns = {} is invalid",
            self.name,
            self.order_jitter_ns
        );
        for s in &self.stalls {
            crate::ensure!(
                s.ns.is_finite() && s.ns >= 0.0,
                "plan `{}`: stall ns = {} is invalid",
                self.name,
                s.ns
            );
        }
        for c in &self.cost_skew {
            crate::ensure!(
                c.mul.is_finite() && c.mul >= 0.0,
                "plan `{}`: cost multiplier {} is invalid",
                self.name,
                c.mul
            );
        }
        Ok(())
    }

    /// Serialize to the TOML subset the crate's parser accepts (module
    /// docs show the shape). Round-trips through [`FaultPlan::from_toml`].
    pub fn to_toml(&self) -> String {
        let mut out = String::new();
        out.push_str("[plan]\n");
        let _ = writeln!(out, "name = \"{}\"", escape(&self.name));
        let _ = writeln!(out, "seed = {}", self.seed as i64);
        let _ = writeln!(out, "every = {}", self.every as i64);
        let _ = writeln!(out, "order_jitter_ns = {:?}", self.order_jitter_ns);
        let _ = writeln!(out, "fence_delay_ns = {}", self.fence_delay_ns as i64);
        if !self.stalls.is_empty() {
            out.push_str("\n[stalls]\n");
            let _ = writeln!(
                out,
                "worker = [{}]",
                join(self.stalls.iter().map(|s| s.worker.to_string()))
            );
            let _ = writeln!(
                out,
                "epoch = [{}]",
                join(self.stalls.iter().map(|s| (s.epoch as i64).to_string()))
            );
            let _ = writeln!(
                out,
                "ns = [{}]",
                join(self.stalls.iter().map(|s| format!("{:?}", s.ns)))
            );
        }
        if !self.cost_skew.is_empty() {
            out.push_str("\n[cost_skew]\n");
            let _ = writeln!(
                out,
                "block = [{}]",
                join(self.cost_skew.iter().map(|c| c.block.to_string()))
            );
            let _ = writeln!(
                out,
                "mul = [{}]",
                join(self.cost_skew.iter().map(|c| format!("{:?}", c.mul)))
            );
        }
        out
    }

    /// Parse a plan from its TOML form.
    pub fn from_toml(text: &str) -> Result<Self> {
        let root = toml::parse(text).map_err(|e| crate::err!("fault plan: {e}"))?;
        let plan = root
            .get("plan")
            .and_then(Value::as_table)
            .ok_or_else(|| crate::err!("fault plan: missing [plan] table"))?;
        let mut out = FaultPlan {
            name: plan
                .get("name")
                .and_then(Value::as_str)
                .unwrap_or_default()
                .to_string(),
            seed: get_u64(&root, "plan.seed")?.unwrap_or(0),
            every: get_u64(&root, "plan.every")?.unwrap_or(0),
            order_jitter_ns: get_f64(&root, "plan.order_jitter_ns")?.unwrap_or(0.0),
            fence_delay_ns: get_u64(&root, "plan.fence_delay_ns")?.unwrap_or(0),
            stalls: Vec::new(),
            cost_skew: Vec::new(),
        };
        if root.get("stalls").is_some() {
            let worker = int_array(&root, "stalls.worker")?;
            let epoch = int_array(&root, "stalls.epoch")?;
            let ns = float_array(&root, "stalls.ns")?;
            crate::ensure!(
                worker.len() == epoch.len() && worker.len() == ns.len(),
                "fault plan: [stalls] arrays must have equal lengths \
                 (worker {}, epoch {}, ns {})",
                worker.len(),
                epoch.len(),
                ns.len()
            );
            for i in 0..worker.len() {
                out.stalls.push(StallFault {
                    worker: worker[i] as usize,
                    epoch: epoch[i] as u64,
                    ns: ns[i],
                });
            }
        }
        if root.get("cost_skew").is_some() {
            let block = int_array(&root, "cost_skew.block")?;
            let mul = float_array(&root, "cost_skew.mul")?;
            crate::ensure!(
                block.len() == mul.len(),
                "fault plan: [cost_skew] arrays must have equal lengths \
                 (block {}, mul {})",
                block.len(),
                mul.len()
            );
            for i in 0..block.len() {
                out.cost_skew.push(CostSkew {
                    block: block[i] as u32,
                    mul: mul[i],
                });
            }
        }
        out.validate()?;
        Ok(out)
    }
}

/// The canonical plan suite the soak runner sweeps by default: worker
/// stalls, cost skew against the rebalancer, and pure order jitter.
/// Amplitudes are sized against the default [`crate::vtime::CostModel`]
/// (creation ≈ 250 ns, execution ≈ 5–200 ns) so each plan genuinely
/// reorders dispatch.
pub fn bundled() -> Vec<FaultPlan> {
    vec![
        FaultPlan::new("stalls", 0x57A1_1ED5)
            .stall(0, 1, 45_000.0)
            .stall(1, 2, 90_000.0)
            .stall(0, 3, 20_000.0)
            .stall(2, 2, 65_000.0)
            .fence_delay(10_000),
        FaultPlan::new("skew", 0x5CA1_ED00)
            .skew(0, 8.0)
            .skew(1, 0.25)
            .skew(2, 16.0)
            .skew(3, 0.0)
            .jitter(120.0),
        FaultPlan::new("jitter", 0x71_77E4).jitter(750.0).fence_delay(5_000),
    ]
}

/// Look a bundled plan up by name.
pub fn bundled_plan(name: &str) -> Option<FaultPlan> {
    bundled().into_iter().find(|p| p.name == name)
}

fn escape(s: &str) -> String {
    s.chars()
        .flat_map(|c| match c {
            '"' => vec!['\\', '"'],
            '\\' => vec!['\\', '\\'],
            '\n' => vec!['\\', 'n'],
            c => vec![c],
        })
        .collect()
}

fn join(items: impl Iterator<Item = String>) -> String {
    items.collect::<Vec<_>>().join(", ")
}

fn get_u64(root: &Value, path: &str) -> Result<Option<u64>> {
    match root.get(path) {
        None => Ok(None),
        Some(v) => v
            .as_int()
            .map(|i| Some(i as u64))
            .ok_or_else(|| crate::err!("fault plan: `{path}` must be an integer")),
    }
}

fn get_f64(root: &Value, path: &str) -> Result<Option<f64>> {
    match root.get(path) {
        None => Ok(None),
        Some(v) => v
            .as_float()
            .map(Some)
            .ok_or_else(|| crate::err!("fault plan: `{path}` must be a number")),
    }
}

fn int_array(root: &Value, path: &str) -> Result<Vec<i64>> {
    let arr = root
        .get(path)
        .and_then(Value::as_array)
        .ok_or_else(|| crate::err!("fault plan: `{path}` must be an array"))?;
    arr.iter()
        .map(|v| {
            v.as_int()
                .ok_or_else(|| crate::err!("fault plan: `{path}` must hold integers"))
        })
        .collect()
}

fn float_array(root: &Value, path: &str) -> Result<Vec<f64>> {
    let arr = root
        .get(path)
        .and_then(Value::as_array)
        .ok_or_else(|| crate::err!("fault plan: `{path}` must be an array"))?;
    arr.iter()
        .map(|v| {
            v.as_float()
                .ok_or_else(|| crate::err!("fault plan: `{path}` must hold numbers"))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bundled_plans_round_trip_through_toml() {
        for plan in bundled() {
            plan.validate().unwrap();
            assert!(!plan.is_benign(), "{}", plan.name);
            let text = plan.to_toml();
            let back = FaultPlan::from_toml(&text)
                .unwrap_or_else(|e| panic!("{}: {e}\n{text}", plan.name));
            assert_eq!(back, plan, "round-trip of `{}`\n{text}", plan.name);
        }
    }

    #[test]
    fn empty_plan_round_trips() {
        let plan = FaultPlan::new("noop", 7);
        assert!(plan.is_benign());
        assert_eq!(FaultPlan::from_toml(&plan.to_toml()).unwrap(), plan);
    }

    #[test]
    fn large_seeds_round_trip_via_wrapping_cast() {
        let plan = FaultPlan::new("big", u64::MAX - 3);
        assert_eq!(FaultPlan::from_toml(&plan.to_toml()).unwrap().seed, plan.seed);
    }

    #[test]
    fn rejects_mismatched_parallel_arrays() {
        let text = "[plan]\nseed = 1\n[stalls]\nworker = [0, 1]\nepoch = [0]\nns = [1.0, 2.0]\n";
        assert!(FaultPlan::from_toml(text).is_err());
    }

    #[test]
    fn rejects_missing_plan_table() {
        assert!(FaultPlan::from_toml("seed = 1\n").is_err());
    }

    #[test]
    fn rejects_invalid_amplitudes() {
        let plan = FaultPlan::new("bad", 1).jitter(f64::NAN);
        assert!(plan.validate().is_err());
        let neg = FaultPlan::new("neg", 1).stall(0, 0, -1.0);
        assert!(neg.validate().is_err());
    }

    #[test]
    fn fault_count_counts_every_removable_unit() {
        let plan = FaultPlan::new("p", 1)
            .stall(0, 0, 1.0)
            .skew(0, 2.0)
            .jitter(10.0)
            .fence_delay(5);
        assert_eq!(plan.fault_count(), 4);
    }
}
