//! The seed-sweep soak runner (DESIGN.md §10, `cli soak`).
//!
//! Sweeps `seeds × fault plans × registry models`, running each
//! combination under injection on the virtual-time engine and (for
//! sharded-capable models) the sharded adaptive scheduler, and checks
//! every run against the sequential oracle with the
//! [`invariant`](crate::chaos::invariant) suite. A failing
//! `(seed, plan)` pair is **shrunk** — delta-debugging over the plan's
//! removable faults — to a minimized plan, serialized as a repro TOML
//! whose comment header records the model, seed, worker count, and the
//! violations observed, so the failure can be committed and replayed.
//!
//! Everything is deterministic: seeds derive from `base_seed` by a
//! fixed mix, plans are seeded, and the engines under test are the
//! deterministic ones — a red soak reproduces byte-for-byte.

use std::fmt::Write as _;

use crate::api::observe::Observer;
use crate::api::registry::{self, BuildCtx, ModelInfo};
use crate::api::{DynModel, Observations};
use crate::chaos::inject::FaultHook;
use crate::chaos::invariant::{self, Invariant, Violation};
use crate::chaos::plan::{self, FaultPlan};
use crate::error::Result;
use crate::protocol::ProtocolConfig;
use crate::sched::ShardedConfig;
use crate::util::json::Json;
use crate::vtime::CostModel;

/// What one soak sweep covers.
#[derive(Clone, Debug)]
pub struct SoakConfig {
    /// Registry model names (must be sharded-capable — the soak
    /// exercises the sharded engine alongside the virtual one).
    pub models: Vec<String>,
    /// Fault plans to inject (default: [`plan::bundled`]).
    pub plans: Vec<FaultPlan>,
    /// Number of simulation seeds swept per (model, plan).
    pub seeds: u64,
    /// Base of the seed derivation (each swept seed is a fixed mix of
    /// this and the sweep index).
    pub base_seed: u64,
    /// Worker count for the injected runs.
    pub workers: usize,
}

impl Default for SoakConfig {
    fn default() -> Self {
        Self {
            models: vec!["sir".into(), "voter".into(), "ising".into()],
            plans: plan::bundled(),
            seeds: 8,
            base_seed: 0xADA9,
            workers: 3,
        }
    }
}

/// One failing `(model, seed, plan)` combination, with its minimized
/// repro.
#[derive(Clone, Debug)]
pub struct SoakFailure {
    /// Registry model name.
    pub model: String,
    /// Simulation seed of the failing run.
    pub seed: u64,
    /// Name of the originally-failing plan.
    pub plan: String,
    /// Violations the original plan produced.
    pub violations: Vec<Violation>,
    /// The plan after shrinking (still failing, minimal).
    pub shrunk: FaultPlan,
    /// The committable repro file: comment header + shrunk plan TOML.
    pub repro_toml: String,
    /// Telemetry snapshot (JSON) from a diagnostic re-run of the shrunk
    /// plan on the sharded engine — written next to the repro TOML.
    pub telemetry_json: String,
    /// Perfetto trace from the same diagnostic re-run (full mode), when
    /// the re-run produced one.
    pub trace_json: Option<String>,
}

/// Outcome of one soak sweep.
#[derive(Clone, Debug, Default)]
pub struct SoakReport {
    /// `(model, seed, plan)` combinations checked.
    pub runs: u64,
    /// Combinations that violated an invariant, minimized.
    pub failures: Vec<SoakFailure>,
}

impl SoakReport {
    /// Whether the sweep was green.
    pub fn ok(&self) -> bool {
        self.failures.is_empty()
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        if self.ok() {
            format!("soak: {} injected runs, all invariants held", self.runs)
        } else {
            format!(
                "soak: {} injected runs, {} FAILED (first: {})",
                self.runs,
                self.failures.len(),
                self.failures[0].violations[0]
            )
        }
    }

    /// Machine-readable form for `cli soak --json`.
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("runs".into(), Json::from(self.runs)),
            ("ok".into(), Json::from(self.ok())),
            (
                "failures".into(),
                Json::Arr(
                    self.failures
                        .iter()
                        .map(|f| {
                            Json::Obj(vec![
                                ("model".into(), Json::from(f.model.clone())),
                                ("seed".into(), Json::from(f.seed)),
                                ("plan".into(), Json::from(f.plan.clone())),
                                (
                                    "violations".into(),
                                    Json::Arr(
                                        f.violations
                                            .iter()
                                            .map(|v| Json::from(v.to_string()))
                                            .collect(),
                                    ),
                                ),
                                (
                                    "shrunk_faults".into(),
                                    Json::from(f.shrunk.fault_count()),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// The soak's per-model workload: registry defaults clamped to a small,
/// fast shape (the conformance matrix's philosophy — coverage breadth
/// over run length).
#[derive(Clone, Copy, Debug)]
struct Workload {
    size: usize,
    agents: usize,
    steps: u64,
    cadence: u64,
}

fn workload(info: &ModelInfo) -> Workload {
    let steps = info.validate_steps.clamp(1, 2_400);
    Workload {
        size: info.default_sizes.first().copied().unwrap_or(1).min(25),
        agents: info.default_agents.min(360),
        steps,
        cadence: (steps / 4).max(1),
    }
}

fn build(name: &str, wl: &Workload, seed: u64) -> Result<Box<dyn DynModel>> {
    registry::build(
        name,
        &BuildCtx {
            size: wl.size,
            agents: wl.agents,
            steps: wl.steps,
            seed,
            layout: crate::sim::soa::Layout::env_default(),
            params: Default::default(),
        },
    )
}

/// Derive the i-th swept simulation seed from the base (golden-ratio
/// mix, so nearby indices land on unrelated streams).
fn derive_seed(base: u64, i: u64) -> u64 {
    base.wrapping_add(i.wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// Sequential oracle trace for one (model, seed).
fn oracle(name: &str, wl: &Workload, seed: u64) -> Result<Observations> {
    let m = build(name, wl, seed)?;
    let mut obs = Observer::new(wl.cadence);
    m.run_sequential(seed, crate::trace::TraceMode::Off, Some(&mut obs));
    obs.finish()
}

/// Run one `(model, seed, plan)` combination on both injected engines
/// and collect every violation (post-run checks + in-engine boundary
/// checks recorded into the hook).
fn check_combo(
    name: &str,
    wl: &Workload,
    seed: u64,
    p: &FaultPlan,
    workers: usize,
    reference: &Observations,
) -> Result<Vec<Violation>> {
    let mut out = Vec::new();

    // Virtual-time engine: full virtual-duration injections.
    let m = build(name, wl, seed)?;
    let mut hook = FaultHook::new(p.clone());
    let mut obs = Observer::new(wl.cadence);
    let vcfg = ProtocolConfig {
        workers,
        seed,
        ..Default::default()
    };
    let report = m.run_virtual_chaos(&vcfg, &CostModel::default(), Some(&mut obs), &mut hook);
    let label = format!("{name} virtual n={workers} seed={seed} plan={}", p.name);
    out.extend(invariant::check_run(&label, reference, &obs.finish()?, &report));
    out.extend(hook.take_violations());
    if let Err(e) = m.check_consistency() {
        out.push(Violation {
            invariant: Invariant::TraceIdentity,
            detail: format!("{label}: {e}"),
        });
    }

    // Sharded adaptive scheduler: capped wall stalls + probe skew.
    let m = build(name, wl, seed)?;
    let mut hook = FaultHook::new(p.clone());
    let mut obs = Observer::new(wl.cadence);
    let scfg = ShardedConfig {
        workers,
        seed,
        ..Default::default()
    };
    let report = m.run_sharded_chaos(&scfg, Some(&mut obs), &mut hook)?;
    let label = format!("{name} sharded n={workers} seed={seed} plan={}", p.name);
    out.extend(invariant::check_run(&label, reference, &obs.finish()?, &report));
    out.extend(hook.take_violations());
    if let Err(e) = m.check_consistency() {
        out.push(Violation {
            invariant: Invariant::TraceIdentity,
            detail: format!("{label}: {e}"),
        });
    }
    Ok(out)
}

/// Observability artifacts for one failing combination: re-run the
/// shrunk plan once on the sharded injected engine with telemetry
/// sampling on and full causal tracing — both semantically inert, so
/// the diagnostic re-run reproduces the failing schedule byte for byte
/// — and serialize what it saw.
fn capture_artifacts(
    name: &str,
    wl: &Workload,
    seed: u64,
    p: &FaultPlan,
    workers: usize,
) -> Result<(String, Option<String>)> {
    let m = build(name, wl, seed)?;
    let mut hook = FaultHook::new(p.clone());
    let scfg = ShardedConfig {
        workers,
        seed,
        telemetry: crate::telemetry::TelemetryMode::On,
        trace: crate::trace::TraceMode::Full,
        ..Default::default()
    };
    let report = m.run_sharded_chaos(&scfg, None, &mut hook)?;
    let telemetry = report
        .telemetry
        .as_ref()
        .map(|t| t.to_json().render())
        .unwrap_or_else(|| "{}".to_string());
    let trace = report.trace.as_ref().map(crate::trace::perfetto::export);
    Ok((telemetry, trace))
}

/// Run a soak sweep. Deterministic in the config; a non-empty
/// [`SoakReport::failures`] carries minimized repro TOMLs.
pub fn run(cfg: &SoakConfig) -> Result<SoakReport> {
    crate::ensure!(cfg.seeds > 0, "soak needs at least one seed");
    crate::ensure!(!cfg.models.is_empty(), "soak needs at least one model");
    crate::ensure!(!cfg.plans.is_empty(), "soak needs at least one fault plan");
    crate::ensure!(cfg.workers >= 1, "soak needs at least one worker");
    for p in &cfg.plans {
        p.validate()?;
    }
    let mut report = SoakReport::default();
    for name in &cfg.models {
        let info = registry::info(name)?;
        crate::ensure!(
            info.has_sharded_form,
            "soak model `{name}` must be sharded-capable (the sweep covers the sharded engine)"
        );
        let wl = workload(&info);
        for i in 0..cfg.seeds {
            let seed = derive_seed(cfg.base_seed, i);
            let reference = oracle(name, &wl, seed)?;
            for p in &cfg.plans {
                report.runs += 1;
                let violations = check_combo(name, &wl, seed, p, cfg.workers, &reference)?;
                if violations.is_empty() {
                    continue;
                }
                // Red: minimize the plan against the same (model, seed)
                // and package the repro.
                let shrunk = shrink(p, |cand| {
                    check_combo(name, &wl, seed, cand, cfg.workers, &reference)
                        .map(|v| !v.is_empty())
                        .unwrap_or(true)
                });
                let repro_toml = repro_toml(name, seed, cfg.workers, &shrunk, &violations);
                let (telemetry_json, trace_json) =
                    capture_artifacts(name, &wl, seed, &shrunk, cfg.workers)?;
                report.failures.push(SoakFailure {
                    model: name.clone(),
                    seed,
                    plan: p.name.clone(),
                    violations,
                    shrunk,
                    repro_toml,
                    telemetry_json,
                    trace_json,
                });
            }
        }
    }
    Ok(report)
}

/// Minimize a failing plan: delta-debug the stall and skew lists, then
/// drop the scalar faults (jitter, fence delay) if the failure
/// survives without them. `still_fails` must return `true` while the
/// candidate plan still reproduces the failure; the returned plan is
/// 1-minimal over [`FaultPlan::fault_count`] units (removing any single
/// remaining fault makes the failure vanish — or the test was flaky,
/// which seeded determinism rules out).
pub fn shrink(p: &FaultPlan, mut still_fails: impl FnMut(&FaultPlan) -> bool) -> FaultPlan {
    let mut best = p.clone();
    let stalls = ddmin(&best.stalls, |cand| {
        let mut probe = best.clone();
        probe.stalls = cand.to_vec();
        still_fails(&probe)
    });
    best.stalls = stalls;
    let skews = ddmin(&best.cost_skew, |cand| {
        let mut probe = best.clone();
        probe.cost_skew = cand.to_vec();
        still_fails(&probe)
    });
    best.cost_skew = skews;
    if best.order_jitter_ns > 0.0 {
        let mut probe = best.clone();
        probe.order_jitter_ns = 0.0;
        if still_fails(&probe) {
            best = probe;
        }
    }
    if best.fence_delay_ns > 0 {
        let mut probe = best.clone();
        probe.fence_delay_ns = 0;
        if still_fails(&probe) {
            best = probe;
        }
    }
    best
}

/// Classic ddmin over a list: repeatedly remove chunks (bisection down
/// to singletons) while the failure persists. `fails` receives a
/// candidate subset and answers whether the failure still reproduces.
fn ddmin<T: Clone>(items: &[T], mut fails: impl FnMut(&[T]) -> bool) -> Vec<T> {
    let mut cur = items.to_vec();
    if cur.is_empty() {
        return cur;
    }
    let mut chunk = cur.len().div_ceil(2);
    loop {
        let mut removed_any = false;
        let mut i = 0;
        while i < cur.len() {
            let end = (i + chunk).min(cur.len());
            let candidate: Vec<T> = cur[..i].iter().chain(&cur[end..]).cloned().collect();
            if fails(&candidate) {
                cur = candidate;
                removed_any = true;
                // Keep `i`: the next chunk slid into this index.
            } else {
                i = end;
            }
        }
        if chunk == 1 {
            if !removed_any || cur.is_empty() {
                return cur;
            }
        } else {
            chunk /= 2;
        }
    }
}

/// The committable repro file: a comment header naming the failing
/// combination and the violations, followed by the shrunk plan's TOML
/// (comments are legal in the crate's TOML subset, so the file parses
/// back with [`FaultPlan::from_toml`] as-is).
pub fn repro_toml(
    model: &str,
    seed: u64,
    workers: usize,
    shrunk: &FaultPlan,
    violations: &[Violation],
) -> String {
    let mut out = String::new();
    out.push_str("# adapar chaos repro (DESIGN.md \u{a7}10)\n");
    let _ = writeln!(out, "# model = {model}, sim seed = {seed}, workers = {workers}");
    out.push_str("# violations under the original plan:\n");
    for v in violations {
        let _ = writeln!(out, "#   {}", v.to_string().replace('\n', " "));
    }
    out.push('\n');
    out.push_str(&shrunk.to_toml());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chaos::plan::StallFault;

    #[test]
    fn ddmin_minimizes_to_the_triggering_element() {
        let items: Vec<u32> = (0..9).collect();
        let mut calls = 0;
        let min = ddmin(&items, |cand| {
            calls += 1;
            cand.contains(&5)
        });
        assert_eq!(min, vec![5]);
        assert!(calls < 64, "bisection, not brute force: {calls} calls");
    }

    #[test]
    fn ddmin_keeps_a_required_pair() {
        let items: Vec<u32> = (0..8).collect();
        let min = ddmin(&items, |cand| cand.contains(&1) && cand.contains(&6));
        assert_eq!(min, vec![1, 6]);
    }

    #[test]
    fn ddmin_of_an_unfailing_list_returns_it_unchanged() {
        // Defensive: `fails` is false even for the full list (flaky
        // caller); ddmin must not loop forever or empty the list.
        let items = vec![1, 2, 3];
        assert_eq!(ddmin(&items, |_| false), items);
    }

    #[test]
    fn shrink_isolates_the_culprit_fault() {
        let p = FaultPlan::new("wide", 3)
            .stall(0, 0, 10.0)
            .stall(1, 2, 20.0)
            .stall(2, 4, 30.0)
            .skew(0, 2.0)
            .jitter(50.0)
            .fence_delay(100);
        // The "engine" fails iff a stall on worker 1 is injected.
        let min = shrink(&p, |cand| cand.stalls.iter().any(|s| s.worker == 1));
        assert_eq!(
            min.stalls,
            vec![StallFault {
                worker: 1,
                epoch: 2,
                ns: 20.0
            }]
        );
        assert!(min.cost_skew.is_empty());
        assert_eq!(min.order_jitter_ns, 0.0);
        assert_eq!(min.fence_delay_ns, 0);
        assert_eq!(min.fault_count(), 1);
    }

    #[test]
    fn shrink_keeps_scalar_faults_that_matter() {
        let p = FaultPlan::new("j", 3).stall(0, 0, 10.0).jitter(50.0);
        let min = shrink(&p, |cand| cand.order_jitter_ns > 0.0);
        assert!(min.stalls.is_empty());
        assert_eq!(min.order_jitter_ns, 50.0);
        assert_eq!(min.fault_count(), 1);
    }

    #[test]
    fn repro_header_is_comment_only_and_parses_back() {
        let shrunk = FaultPlan::new("min", 7).stall(1, 2, 500.0);
        let v = vec![Violation {
            invariant: Invariant::TraceIdentity,
            detail: "diverged".into(),
        }];
        let text = repro_toml("sir", 42, 4, &shrunk, &v);
        assert!(text.starts_with('#'));
        assert!(text.contains("model = sir, sim seed = 42, workers = 4"));
        assert_eq!(FaultPlan::from_toml(&text).unwrap(), shrunk);
    }

    #[test]
    fn derived_seeds_are_distinct() {
        let seeds: Vec<u64> = (0..32).map(|i| derive_seed(0xADA9, i)).collect();
        let mut dedup = seeds.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), seeds.len());
    }

    #[test]
    fn tiny_sweep_over_sir_is_green() {
        // One model, one seed, the bundled plans: the determinism
        // contract must hold under every injection (the full sweep is
        // rust/tests/chaos.rs and the nightly CI soak).
        let report = run(&SoakConfig {
            models: vec!["sir".into()],
            seeds: 1,
            workers: 2,
            ..Default::default()
        })
        .unwrap();
        assert_eq!(report.runs, 3, "one seed x three bundled plans");
        assert!(report.ok(), "{}", report.summary());
        assert!(report.summary().contains("all invariants held"));
        assert!(report.to_json().render().contains("\"ok\":true"));
    }
}
