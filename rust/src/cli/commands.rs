//! CLI subcommand implementations — thin wrappers over the
//! [`Simulation`] facade and the sweep coordinator. No per-model logic
//! lives here: model names, defaults and parameters all resolve through
//! the registry.

use std::path::PathBuf;

use crate::api::observe::ObservePlan;
use crate::api::{registry, EngineKind, Params, SimOutcome, Simulation};
use crate::coordinator::config::SweepConfig;
use crate::coordinator::ledger;
use crate::coordinator::report::{figure_pivot, sweep_json, write_bench_json, write_report};
use crate::coordinator::run_sweep;
use crate::error::{Context, Result};
use crate::util::bench::fmt_secs;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::toml::Value;
use crate::vtime::calibrate;

/// Parse `--params k=v,k2=v2` into a bag, sniffing scalar types.
fn params_from(args: &Args) -> Result<Params> {
    let mut params = Params::new();
    let Some(raw) = args.get("params") else {
        return Ok(params);
    };
    for pair in raw.split(',').filter(|s| !s.trim().is_empty()) {
        let (k, v) = pair
            .split_once('=')
            .with_context(|| format!("--params entry `{pair}` is not key=value"))?;
        let v = v.trim();
        let value = if let Ok(i) = v.parse::<i64>() {
            Value::Int(i)
        } else if let Ok(f) = v.parse::<f64>() {
            Value::Float(f)
        } else if let Ok(b) = v.parse::<bool>() {
            Value::Bool(b)
        } else {
            Value::Str(v.to_string())
        };
        params.set(k.trim(), value);
    }
    Ok(params)
}

fn sweep_config_from(args: &Args) -> Result<SweepConfig> {
    let mut cfg = if let Some(path) = args.get("config") {
        SweepConfig::from_file(path)?
    } else if let Some(preset) = args.get("preset") {
        SweepConfig::preset(preset)?
    } else {
        SweepConfig::default()
    };
    if let Some(m) = args.get("model") {
        cfg.model = m.to_string();
        // Model-appropriate default grid if none was given explicitly: an
        // empty `sizes` defers to the registry's per-model default.
        if args.get("sizes").is_none() && args.get("config").is_none() && args.get("preset").is_none()
        {
            cfg.sizes = Vec::new();
        }
    }
    if let Some(e) = args.get("engine") {
        cfg.engine = e.parse()?;
    }
    cfg.sizes = args.get_list::<usize>("sizes", &cfg.sizes)?;
    cfg.workers = args.get_list::<usize>("workers", &cfg.workers)?;
    cfg.seeds = args.get_list::<u64>("seeds", &cfg.seeds)?;
    cfg.tasks_per_cycle = args.get_parse("c", cfg.tasks_per_cycle)?;
    cfg.batch = args.get_parse("batch", cfg.batch)?;
    cfg.agents = args.get_parse("agents", cfg.agents)?;
    cfg.steps = args.get_parse("steps", cfg.steps)?;
    if args.has_flag("paper-scale") {
        cfg.paper_scale = true;
    }
    if args.has_flag("calibrate") {
        cfg.calibrate = true;
    }
    // Per-key override on top of the config file's [params] table, like
    // every other CLI option.
    cfg.params.merge(&params_from(args)?);
    // `--move-radius` is common enough (the lattice quickstart) to get a
    // first-class flag on top of the generic `--params` bag.
    if args.get("move-radius").is_some() {
        let r = args.get_parse("move-radius", 0usize)?;
        cfg.params.set("move_radius", r as i64);
    }
    cfg.validate()?;
    Ok(cfg)
}

/// `adapar models` — list every registered model with an explicit
/// engine-support column (sourced from [`ModelInfo::engines`], the same
/// capability data the conformance matrix iterates) and its defaults.
///
/// [`ModelInfo::engines`]: crate::api::ModelInfo::engines
pub fn models(_args: &Args) -> Result<()> {
    println!("registered models:");
    println!("  {:<10} {:<46} summary", "name", "engines");
    for info in registry::models() {
        println!("  {:<10} {:<46} {}", info.name, info.engines().join("|"), info.summary);
        println!(
            "  {:<10} {:<46} defaults: N={}, steps={}, sizes={:?}",
            "", "", info.default_agents, info.default_steps, info.default_sizes
        );
        if !info.aliases.is_empty() {
            println!("  {:<10} {:<46} aliases: {}", "", "", info.aliases.join(", "));
        }
    }
    Ok(())
}

/// Observation plan from `--every` / `--observe <file>`; a `.jsonl`
/// suffix selects the JSON-lines sink, anything else gets CSV. The
/// progress line (sized by the source's `size_hint`, counting frames when
/// the hint is `None`) is attached for human runs with a cadence.
fn observe_plan_from(args: &Args, with_progress: bool) -> Result<ObservePlan> {
    let mut plan = ObservePlan::every(args.get_parse("every", 0u64)?);
    if let Some(path) = args.get("observe") {
        plan = if path.ends_with(".jsonl") {
            plan.jsonl(path)
        } else {
            plan.csv(path)
        };
        crate::ensure!(
            plan.active(),
            "--observe needs a cadence: add --every <tasks>"
        );
    }
    if plan.active() && with_progress {
        plan = plan.progress();
    }
    Ok(plan)
}

/// The `--json` payload for one run. `sampling_lossy` flags a saturated
/// telemetry run (dropped histogram samples) so downstream consumers
/// don't trust under-counted histograms silently.
fn run_json(cfg: &SweepConfig, out: &SimOutcome, size: usize, seed: u64, window: u64) -> Json {
    let lossy = out
        .report
        .telemetry
        .as_ref()
        .is_some_and(|t| t.dropped_total() > 0);
    Json::Obj(vec![
        ("model".into(), Json::from(cfg.model.clone())),
        ("size".into(), Json::from(size)),
        ("seed".into(), Json::from(seed)),
        ("window".into(), Json::from(window)),
        // Peak live heap (bytes) — zero unless the counting allocator is
        // installed (`bench-alloc` builds); null would hide the schema.
        (
            "peak_alloc_bytes".into(),
            Json::from(crate::util::alloc::peak_bytes()),
        ),
        ("sampling_lossy".into(), Json::from(lossy)),
        ("report".into(), out.report.to_json()),
        ("observations".into(), out.observable.to_json()),
    ])
}

/// `adapar run` — one simulation through the facade, one line of truth.
pub fn run(args: &Args) -> Result<()> {
    let cfg = sweep_config_from(args)?;
    let engine = match args.get("engine") {
        Some(e) => e.parse()?,
        None => EngineKind::Parallel,
    };
    let workers = args.get_parse("workers", 2usize)?;
    let size = args.get_parse(
        "size",
        cfg.effective_sizes().first().copied().unwrap_or(1),
    )?;
    let seed = args.get_parse("seed", 1u64)?;
    // `--window <n>` bounds live tasks per chain (0 = materialized);
    // `--streaming` is shorthand for the default window. Both default
    // from ADAPAR_WINDOW / ADAPAR_STREAMING (ISSUE 10).
    let mut window = args.get_parse("window", crate::model::stream::env_window())?;
    if args.has_flag("streaming") && window == 0 {
        window = crate::model::stream::DEFAULT_WINDOW;
    }
    let json = args.has_flag("json");
    let plan = observe_plan_from(args, !json)?;
    let telemetry = args.get_parse(
        "telemetry",
        crate::telemetry::TelemetryMode::env_default(),
    )?;
    // `--trace <file>` implies full tracing unless `--trace-mode` says
    // otherwise; without a file the mode still controls collection (the
    // summary lands in the report).
    let trace_path = args.get("trace").map(PathBuf::from);
    let trace_mode = args.get_parse(
        "trace-mode",
        if trace_path.is_some() {
            crate::trace::TraceMode::Full
        } else {
            crate::trace::TraceMode::env_default()
        },
    )?;
    crate::ensure!(
        trace_path.is_none() || trace_mode != crate::trace::TraceMode::Off,
        "--trace needs tracing enabled: drop `--trace-mode off` or use spans|full"
    );
    let out = Simulation::builder()
        .model(cfg.model.clone())
        .engine(engine)
        .workers(workers)
        .tasks_per_cycle(cfg.tasks_per_cycle)
        .batch(cfg.batch)
        .seed(seed)
        .window(window)
        .agents(cfg.agents)
        .steps(cfg.steps)
        .size(size)
        .paper_scale(cfg.paper_scale)
        .params(cfg.params.clone())
        .observe(plan)
        .telemetry(telemetry)
        .trace(trace_mode)
        .run()?;
    // Saturated telemetry rings drop histogram samples; say so out loud
    // (stderr, so `--json` stdout stays machine-readable).
    if let Some(t) = &out.report.telemetry {
        let dropped = t.dropped_total();
        if dropped > 0 {
            eprintln!(
                "warning: telemetry rings saturated — {dropped} histogram sample(s) dropped; \
                 histograms under-count (lossless counters are unaffected)"
            );
        }
    }
    if let Some(path) = &trace_path {
        let tr = out
            .report
            .trace
            .as_ref()
            .with_context(|| "engine returned no trace despite tracing being enabled")?;
        crate::util::create_parent_dirs(path)?;
        let mut text = crate::trace::perfetto::export(tr);
        text.push('\n');
        std::fs::write(path, text)
            .with_context(|| format!("writing trace {}", path.display()))?;
        eprintln!(
            "wrote trace {} ({} events, {} edges) — open at ui.perfetto.dev or run \
             `adapar trace-analyze {}`",
            path.display(),
            tr.events.len(),
            tr.edges.len(),
            path.display()
        );
    }
    if json {
        println!("{}", run_json(&cfg, &out, size, seed, window).render());
        return Ok(());
    }
    println!(
        "model={} engine={engine} size={size} workers={workers} seed={seed}",
        cfg.model
    );
    println!("T = {} ({})", fmt_secs(out.report.time_s), out.report.basis);
    println!(
        "tasks: executed={} created={} skipped={} passed={} retries={} cycles={} max_chain={}",
        out.report.totals.executed,
        out.report.totals.created,
        out.report.totals.skipped_dependent,
        out.report.totals.passed_executing,
        out.report.totals.erased_retries,
        out.report.totals.cycles,
        out.report.chain.max_chain_len
    );
    if out.report.chain.tail_locks > 0 {
        println!(
            "chain: batch={} tail_locks={} tasks/lock={:.1} arena={}/{} slots ({} recycled)",
            out.report.chain.batch,
            out.report.chain.tail_locks,
            out.report.chain.tasks_per_tail_lock(),
            out.report.chain.arena_high_water,
            out.report.chain.arena_capacity,
            out.report.chain.arena_recycled
        );
    }
    // Memory line (ISSUE 10): always printed — the arena high-water is
    // the bounded-memory contract's observable, window 0 = materialized.
    {
        let peak = crate::util::alloc::peak_bytes();
        let peak_note = if peak > 0 {
            format!(" peak_alloc={:.1} MiB", peak as f64 / (1024.0 * 1024.0))
        } else {
            String::new()
        };
        println!(
            "memory: window={window} arena_high_water={} arena_capacity={}{peak_note}",
            out.report.chain.arena_high_water, out.report.chain.arena_capacity
        );
    }
    if out.report.per_worker.len() > 1 {
        let loads: Vec<String> = out
            .report
            .per_worker
            .iter()
            .map(|w| format!("w{}:{}", w.worker, w.executed))
            .collect();
        println!("per-worker executed: {}", loads.join(" "));
    }
    if let Some(sched) = &out.report.sched {
        println!(
            "sched: shards={} partition={} local={} boundary={} ({:.1}%) migrations={} \
             rebalances={} edge_cut={}",
            sched.shards,
            sched.partition,
            sched.local_tasks,
            sched.boundary_tasks,
            sched.boundary_ratio() * 100.0,
            sched.migrations,
            sched.rebalances,
            sched.edge_cut
        );
        let loads: Vec<String> = sched
            .per_shard_executed
            .iter()
            .enumerate()
            .map(|(s, n)| format!("s{s}:{n}"))
            .collect();
        println!("per-shard executed: {}", loads.join(" "));
    }
    if out.observable.len() > 1 {
        println!(
            "observations: {} frames (every {} tasks)",
            out.observable.len(),
            out.observable.every
        );
    }
    println!("observable: {}", out.observable);
    Ok(())
}

/// `adapar sweep` — the figure generator.
pub fn sweep(args: &Args) -> Result<()> {
    crate::ensure!(
        args.get("every").is_none() && args.get("observe").is_none(),
        "sweep aggregates timings and does not record per-run traces; \
         use `run --every/--observe` for observation"
    );
    let cfg = sweep_config_from(args)?;
    let stem = args
        .get("preset")
        .map(str::to_string)
        .unwrap_or_else(|| format!("{}_{}", cfg.model, cfg.engine));
    let out_dir = PathBuf::from(args.get("out").unwrap_or("target/figures"));
    eprintln!(
        "sweep: model={} engine={} sizes={:?} workers={:?} seeds={:?} (N={}, steps={})",
        cfg.model,
        cfg.engine,
        cfg.effective_sizes(),
        cfg.workers,
        cfg.seeds,
        cfg.effective_agents(),
        cfg.effective_steps()
    );
    let res = run_sweep(&cfg)?;
    if args.has_flag("json") {
        println!("{}", sweep_json(&res).render());
    } else {
        println!("{}", figure_pivot(&res).to_markdown());
    }
    let csv = write_report(&res, &out_dir, &stem)?;
    eprintln!(
        "wrote {} and {}",
        csv.display(),
        out_dir.join(format!("{stem}.md")).display()
    );
    // Figure presets double as perf-trajectory benchmarks: emit the
    // BENCH_*.json artifact alongside the figure data.
    if let Some(preset) = args.get("preset") {
        let bench = write_bench_json(&res, &out_dir.join(format!("BENCH_{preset}.json")))?;
        eprintln!("wrote {}", bench.display());
    }
    Ok(())
}

/// `adapar calibrate` — print this machine's measured cost model.
pub fn calibrate_cmd(_args: &Args) -> Result<()> {
    eprintln!("calibrating protocol micro-action costs (~1 s)...");
    let c = calibrate();
    println!("# measured protocol costs (ns), paste into vtime::CostModel");
    println!("enter_ns      = {:.1}", c.enter_ns);
    println!("visit_ns      = {:.1}", c.visit_ns);
    println!("absorb_ns     = {:.1}", c.absorb_ns);
    println!("create_ns     = {:.1}", c.create_ns);
    println!("erase_ns      = {:.1}", c.erase_ns);
    println!("cycle_end_ns  = {:.1}", c.cycle_end_ns);
    println!("retry_ns      = {:.1}", c.retry_ns);
    println!("exec_fixed_ns = {:.1}", c.exec_fixed_ns);
    println!("idle_ns       = {:.1}", c.idle_ns);
    Ok(())
}

/// `adapar validate` — parallel == sequential, printed as a checklist.
/// With `--every <n>` the comparison covers the whole epoch trace, not
/// just the final state (the observation determinism contract).
pub fn validate(args: &Args) -> Result<()> {
    crate::ensure!(
        args.get("observe").is_none(),
        "validate compares traces in memory and writes no files; \
         use `run --observe` to export one"
    );
    let mut cfg = sweep_config_from(args)?;
    cfg.engine = EngineKind::Parallel;
    let workers = args.get_list::<usize>("workers", &[1, 2, 3, 4])?;
    let size = args.get_parse(
        "size",
        cfg.effective_sizes().first().copied().unwrap_or(1),
    )?;
    let seed = args.get_parse("seed", 1u64)?;
    let every = args.get_parse("every", 0u64)?;
    // Shrink default workloads: validation is about equality, not timing.
    if cfg.steps == 0 {
        cfg.steps = registry::info(&cfg.model)?.validate_steps;
    }
    if cfg.agents == 0 {
        cfg.agents = 500;
    }
    let sim = |engine: EngineKind, workers: usize| {
        Simulation::builder()
            .model(cfg.model.clone())
            .engine(engine)
            .workers(workers)
            .tasks_per_cycle(cfg.tasks_per_cycle)
            .batch(cfg.batch)
            .seed(seed)
            .agents(cfg.agents)
            .steps(cfg.steps)
            .size(size)
            .params(cfg.params.clone())
            .every(every)
            .run()
    };

    let reference = sim(EngineKind::Sequential, 1)?.observable;
    println!(
        "sequential reference ({} frame{}): {reference}",
        reference.len(),
        if reference.len() == 1 { "" } else { "s" }
    );
    // Engine rows come from the registry's capability data, so a model
    // gaining (or losing) an engine automatically changes its checklist.
    let info = registry::info(&cfg.model)?;
    let mut all_ok = true;
    let mut row = |engine: EngineKind, n: usize| -> Result<()> {
        let got = sim(engine, n)?.observable;
        let ok = got == reference;
        all_ok &= ok;
        println!(
            "{:<10} n={n}: {} ({got})",
            engine.to_string(),
            if ok { "OK" } else { "MISMATCH" }
        );
        Ok(())
    };
    for engine in [EngineKind::Parallel, EngineKind::Stepwise, EngineKind::Sharded] {
        if !info.supports(engine) {
            println!("{:<10} (unsupported: not in the model's engine set)", engine.to_string());
            continue;
        }
        for &n in &workers {
            row(engine, n)?;
        }
    }
    row(EngineKind::Virtual, 3)?;
    crate::ensure!(all_ok, "validation failed: engines disagree");
    println!("validation passed: all supported engines agree on the observation trace");
    Ok(())
}

/// `adapar soak` — the chaos sweep (DESIGN.md §10): `--seeds` seeds ×
/// bundled fault plans × sharded-capable registry models, each run
/// under injection on the virtual-time and sharded engines and checked
/// against the sequential oracle by the invariant suite. A failing
/// `(seed, plan)` pair is shrunk to a minimized plan and written as a
/// committable repro TOML under `--out`; the command then returns an
/// error (nonzero exit) so CI fails and uploads the repros.
pub fn soak(args: &Args) -> Result<()> {
    use crate::chaos::{plan, soak};
    use crate::model::testkit::env_soak_seeds;

    let defaults = soak::SoakConfig::default();
    let plans = match args.get("plans") {
        None => defaults.plans,
        Some(raw) => raw
            .split(',')
            .filter(|s| !s.trim().is_empty())
            .map(|name| {
                plan::bundled_plan(name.trim())
                    .with_context(|| format!("unknown bundled fault plan `{name}`"))
            })
            .collect::<Result<Vec<_>>>()?,
    };
    let cfg = soak::SoakConfig {
        models: args.get_list::<String>("models", &defaults.models)?,
        plans,
        seeds: args.get_parse("seeds", env_soak_seeds(defaults.seeds))?,
        base_seed: args.get_parse("seed", defaults.base_seed)?,
        workers: args.get_parse("workers", defaults.workers)?,
    };

    let report = soak::run(&cfg)?;

    if !report.ok() {
        let out_dir = PathBuf::from(args.get("out").unwrap_or("target/soak"));
        std::fs::create_dir_all(&out_dir)
            .with_context(|| format!("creating {}", out_dir.display()))?;
        for f in &report.failures {
            let stem = format!("repro-{}-{}-{:#x}", f.model, f.plan, f.seed);
            let path = out_dir.join(format!("{stem}.toml"));
            std::fs::write(&path, &f.repro_toml)
                .with_context(|| format!("writing {}", path.display()))?;
            eprintln!("wrote {}", path.display());
            // Observability artifacts from the diagnostic re-run of the
            // shrunk plan: telemetry snapshot + full Perfetto trace.
            let tpath = out_dir.join(format!("{stem}-telemetry.json"));
            std::fs::write(&tpath, &f.telemetry_json)
                .with_context(|| format!("writing {}", tpath.display()))?;
            eprintln!("wrote {}", tpath.display());
            if let Some(trace) = &f.trace_json {
                let trpath = out_dir.join(format!("{stem}-trace.json"));
                std::fs::write(&trpath, trace)
                    .with_context(|| format!("writing {}", trpath.display()))?;
                eprintln!("wrote {}", trpath.display());
            }
        }
    }

    if args.has_flag("json") {
        println!("{}", report.to_json().render());
    } else {
        println!("{}", report.summary());
        for f in &report.failures {
            println!(
                "  FAIL model={} seed={:#x} plan={} ({} violation{}, shrunk to {} fault{})",
                f.model,
                f.seed,
                f.plan,
                f.violations.len(),
                if f.violations.len() == 1 { "" } else { "s" },
                f.shrunk.fault_count(),
                if f.shrunk.fault_count() == 1 { "" } else { "s" },
            );
            for v in &f.violations {
                println!("    {v}");
            }
        }
    }

    crate::ensure!(
        report.ok(),
        "soak found {} invariant-violating combination(s); repros written",
        report.failures.len()
    );
    Ok(())
}

/// `adapar trace-analyze <trace.json>` — work–span analysis of a trace
/// written by `run --trace`: T1 (total work), T∞ (critical path), the
/// per-epoch achievable-speedup bound T1/T∞, and the exact attribution
/// of the gap between the ideal makespan T1/W and the measured window
/// (exec skew, fence waits, spillover serialization, rebalance, idle).
pub fn trace_analyze(args: &Args) -> Result<()> {
    let path = args
        .positional
        .first()
        .map(String::as_str)
        .or_else(|| args.get("trace"))
        .with_context(|| "usage: adapar trace-analyze <trace.json> [--json]")?;
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading trace {path}"))?;
    let trace = crate::trace::perfetto::parse(&text)
        .map_err(crate::error::Error::msg)
        .with_context(|| format!("parsing trace {path}"))?;
    let analysis = crate::trace::analyze::analyze(&trace);
    if args.has_flag("json") {
        println!("{}", analysis.to_json().render());
    } else {
        print!("{}", analysis.render_text());
    }
    Ok(())
}

/// `adapar perf-diff` — the run-over-run perf gate. Runs the fixed
/// deterministic ledger scenarios, compares against the committed
/// baseline (`--ledger`), and exits nonzero on any structural or schema
/// regression. Wall-clock drift is tolerance-checked and only reported
/// under `--lenient` / `ADAPAR_BENCH_LENIENT=1` (the CI default, since
/// runner machines vary). `--update` regenerates the baseline instead of
/// gating; `--seed-regression` deliberately perturbs one pinned metric
/// so CI can prove the gate actually fails.
pub fn perf_diff(args: &Args) -> Result<()> {
    let ledger_path = PathBuf::from(
        args.get("ledger")
            .unwrap_or("experiments/ledger/BENCH_baseline.json"),
    );
    eprintln!("perf-diff: running ledger scenarios (deterministic, single-worker)...");
    let mut fresh = ledger::collect()?;

    if args.has_flag("update") {
        let tolerance = ledger::Ledger::load(&ledger_path)
            .map(|l| l.tolerance)
            .unwrap_or(ledger::DEFAULT_TOLERANCE);
        // Wall-clock baselines only mean something from the designated
        // reference machine; a casual `--update` keeps them unpinned and
        // says so, so the provisional baseline can't pass for a pinned one.
        let pin_wall =
            std::env::var("ADAPAR_PIN_WALL").is_ok_and(|v| v != "0" && !v.is_empty());
        let updated = ledger::Ledger::pinned(&fresh, tolerance, pin_wall);
        updated.write(&ledger_path)?;
        let unpinned = updated.unpinned_wall();
        let notice = (unpinned > 0).then(|| {
            format!(
                "{unpinned} wall metric{} unpinned — run `just ledger-update` on a \
                 reference machine (ADAPAR_PIN_WALL=1) to pin wall-clock baselines",
                if unpinned == 1 { "" } else { "s" }
            )
        });
        if args.has_flag("json") {
            println!(
                "{}",
                Json::Obj(vec![
                    (
                        "updated".into(),
                        Json::from(ledger_path.display().to_string()),
                    ),
                    ("provisional".into(), Json::from(updated.provisional)),
                    ("unpinned_wall".into(), Json::from(unpinned)),
                    (
                        "notice".into(),
                        notice.clone().map(Json::from).unwrap_or(Json::Null),
                    ),
                ])
                .render()
            );
        } else {
            println!(
                "perf-diff: wrote {} ({})",
                ledger_path.display(),
                if updated.provisional {
                    "structural metrics pinned"
                } else {
                    "all metrics pinned"
                }
            );
        }
        if let Some(n) = notice {
            eprintln!("perf-diff: {n}");
        }
        return Ok(());
    }

    let base = ledger::Ledger::load(&ledger_path)?;
    if args.has_flag("seed-regression") {
        let which = ledger::seed_regression(&base, &mut fresh)?;
        eprintln!("perf-diff: seeded a fake regression in {which}");
    }
    let lenient = args.has_flag("lenient")
        || std::env::var("ADAPAR_BENCH_LENIENT").is_ok_and(|v| v != "0" && !v.is_empty());
    let diff = ledger::diff(&base, &fresh, lenient);

    if let Some(path) = args.get("report") {
        let path = PathBuf::from(path);
        crate::util::create_parent_dirs(&path)?;
        let mut text = diff.to_json().render();
        text.push('\n');
        std::fs::write(&path, text)
            .with_context(|| format!("writing diff report {}", path.display()))?;
        eprintln!("perf-diff: wrote report {}", path.display());
    }

    if args.has_flag("json") {
        println!("{}", diff.to_json().render());
    } else {
        for n in &diff.notes {
            println!("  ok    {n}");
        }
        for w in &diff.warnings {
            println!("  warn  {w}");
        }
        for f in &diff.failures {
            println!("  FAIL  {f}");
        }
    }
    if base.provisional {
        eprintln!(
            "perf-diff: baseline is provisional (unpinned metrics); \
             run `just ledger-update` on a reference machine to pin it"
        );
    }
    crate::ensure!(
        diff.ok(),
        "perf-diff: {} regression(s) against {}",
        diff.failures.len(),
        ledger_path.display()
    );
    println!(
        "perf-diff: ok ({} checked, {} warning(s)) against {}",
        diff.notes.len(),
        diff.warnings.len(),
        ledger_path.display()
    );
    Ok(())
}

/// `adapar artifacts-check` — compile all AOT artifacts, smoke-test one.
#[cfg(feature = "xla")]
pub fn artifacts_check(_args: &Args) -> Result<()> {
    use crate::runtime::{Manifest, XlaRuntime};
    let dir = Manifest::default_dir();
    let manifest = Manifest::load(&dir)
        .with_context(|| format!("no artifacts in {} — run `make artifacts`", dir.display()))?;
    let rt = XlaRuntime::cpu()?;
    println!("PJRT platform={} devices={}", rt.platform(), rt.device_count());
    for e in manifest.entries() {
        rt.load_hlo_text(&e.path)
            .with_context(|| format!("compiling {}", e.name))?;
        println!("  {} ... compiles OK", e.name);
    }
    // Smoke: one Axelrod interaction through the kernel.
    if manifest.by_kind("axelrod").is_some() {
        let interactor =
            crate::runtime::xla_engine::XlaAxelrodInteractor::from_manifest(&rt, &manifest)?;
        let f = interactor.features();
        let src = vec![1i32; f];
        let mut tgt = vec![1i32; f];
        tgt[0] = 2;
        let out = interactor.interact(&src, &tgt, 0.0, 0.0)?;
        crate::ensure!(out == src, "smoke interaction should copy the differing trait");
        println!("  axelrod kernel smoke ... OK (copied differing trait)");
    }
    println!("artifacts check passed");
    Ok(())
}

/// `adapar artifacts-check` without the `xla` feature: a clear refusal.
#[cfg(not(feature = "xla"))]
pub fn artifacts_check(_args: &Args) -> Result<()> {
    crate::bail!(
        "adapar was built without the `xla` feature; rebuild with \
         `--features xla` (requires the PJRT/XLA toolchain) to check artifacts"
    )
}
