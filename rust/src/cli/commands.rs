//! CLI subcommand implementations.

use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::coordinator::config::{EngineKind, ModelKind, SweepConfig};
use crate::coordinator::report::{figure_pivot, write_report};
use crate::coordinator::{run_once, run_sweep};
use crate::util::bench::fmt_secs;
use crate::util::cli::Args;
use crate::vtime::{calibrate, CostModel};

fn sweep_config_from(args: &Args) -> Result<SweepConfig> {
    let mut cfg = if let Some(path) = args.get("config") {
        SweepConfig::from_file(path)?
    } else if let Some(preset) = args.get("preset") {
        SweepConfig::preset(preset)?
    } else {
        SweepConfig::default()
    };
    if let Some(m) = args.get("model") {
        cfg.model = m.parse()?;
        // Model-appropriate default grid if none was given explicitly.
        if args.get("sizes").is_none() && args.get("config").is_none() && args.get("preset").is_none() {
            cfg.sizes = match cfg.model {
                ModelKind::Axelrod => vec![25, 50, 100, 200, 400, 800],
                ModelKind::Sir => vec![10, 20, 50, 100, 200, 500, 1000],
                _ => vec![1],
            };
        }
    }
    if let Some(e) = args.get("engine") {
        cfg.engine = e.parse()?;
    }
    cfg.sizes = args.get_list::<usize>("sizes", &cfg.sizes)?;
    cfg.workers = args.get_list::<usize>("workers", &cfg.workers)?;
    cfg.seeds = args.get_list::<u64>("seeds", &cfg.seeds)?;
    cfg.tasks_per_cycle = args.get_parse("c", cfg.tasks_per_cycle)?;
    cfg.agents = args.get_parse("agents", cfg.agents)?;
    cfg.steps = args.get_parse("steps", cfg.steps)?;
    if args.has_flag("paper-scale") {
        cfg.paper_scale = true;
    }
    if args.has_flag("calibrate") {
        cfg.calibrate = true;
    }
    cfg.validate()?;
    Ok(cfg)
}

/// `adapar run` — one simulation, one line of truth.
pub fn run(args: &Args) -> Result<()> {
    let mut cfg = sweep_config_from(args)?;
    if args.get("engine").is_none() {
        cfg.engine = EngineKind::Parallel;
    }
    let workers = args.get_parse("workers", 2usize)?;
    let size = args.get_parse("size", *cfg.sizes.first().unwrap())?;
    let seed = args.get_parse("seed", 1u64)?;
    let cost = CostModel::default();
    let out = run_once(&cfg, size, workers, seed, &cost)?;
    println!(
        "model={} engine={} size={size} workers={workers} seed={seed}",
        cfg.model, cfg.engine
    );
    println!("T = {}", fmt_secs(out.time_s));
    println!(
        "tasks: executed={} created={} skipped={} passed={} retries={} cycles={} max_chain={}",
        out.totals.executed,
        out.totals.created,
        out.totals.skipped_dependent,
        out.totals.passed_executing,
        out.totals.erased_retries,
        out.totals.cycles,
        out.max_chain_len
    );
    println!("observable: {}", out.observable);
    Ok(())
}

/// `adapar sweep` — the figure generator.
pub fn sweep(args: &Args) -> Result<()> {
    let cfg = sweep_config_from(args)?;
    let stem = args
        .get("preset")
        .map(str::to_string)
        .unwrap_or_else(|| format!("{}_{}", cfg.model, cfg.engine));
    let out_dir = PathBuf::from(args.get("out").unwrap_or("target/figures"));
    eprintln!(
        "sweep: model={} engine={} sizes={:?} workers={:?} seeds={:?} (N={}, steps={})",
        cfg.model,
        cfg.engine,
        cfg.sizes,
        cfg.workers,
        cfg.seeds,
        cfg.effective_agents(),
        cfg.effective_steps()
    );
    let res = run_sweep(&cfg)?;
    println!("{}", figure_pivot(&res).to_markdown());
    let csv = write_report(&res, &out_dir, &stem)?;
    eprintln!("wrote {} and {}", csv.display(), out_dir.join(format!("{stem}.md")).display());
    Ok(())
}

/// `adapar calibrate` — print this machine's measured cost model.
pub fn calibrate_cmd(_args: &Args) -> Result<()> {
    eprintln!("calibrating protocol micro-action costs (~1 s)...");
    let c = calibrate();
    println!("# measured protocol costs (ns), paste into vtime::CostModel");
    println!("enter_ns      = {:.1}", c.enter_ns);
    println!("visit_ns      = {:.1}", c.visit_ns);
    println!("absorb_ns     = {:.1}", c.absorb_ns);
    println!("create_ns     = {:.1}", c.create_ns);
    println!("erase_ns      = {:.1}", c.erase_ns);
    println!("cycle_end_ns  = {:.1}", c.cycle_end_ns);
    println!("retry_ns      = {:.1}", c.retry_ns);
    println!("exec_fixed_ns = {:.1}", c.exec_fixed_ns);
    println!("idle_ns       = {:.1}", c.idle_ns);
    Ok(())
}

/// `adapar validate` — parallel == sequential, printed as a checklist.
pub fn validate(args: &Args) -> Result<()> {
    let mut cfg = sweep_config_from(args)?;
    cfg.engine = EngineKind::Parallel;
    let workers = args.get_list::<usize>("workers", &[1, 2, 3, 4])?;
    let size = args.get_parse("size", *cfg.sizes.first().unwrap())?;
    let seed = args.get_parse("seed", 1u64)?;
    // Shrink default workloads: validation is about equality, not timing.
    if cfg.steps == 0 {
        cfg.steps = match cfg.model {
            ModelKind::Axelrod | ModelKind::Voter | ModelKind::Ising | ModelKind::Schelling => 20_000,
            ModelKind::Sir => 60,
        };
    }
    if cfg.agents == 0 {
        cfg.agents = 500;
    }
    let cost = CostModel::default();

    let reference = {
        let mut c = cfg.clone();
        c.engine = EngineKind::Sequential;
        run_once(&c, size, 1, seed, &cost)?.observable
    };
    println!("sequential reference: {reference}");
    let mut all_ok = true;
    for &n in &workers {
        let got = run_once(&cfg, size, n, seed, &cost)?.observable;
        let ok = got == reference;
        all_ok &= ok;
        println!("parallel n={n}: {} ({got})", if ok { "OK" } else { "MISMATCH" });
    }
    {
        let mut c = cfg.clone();
        c.engine = EngineKind::Virtual;
        let got = run_once(&c, size, 3, seed, &cost)?.observable;
        let ok = got == reference;
        all_ok &= ok;
        println!("virtual  n=3: {} ({got})", if ok { "OK" } else { "MISMATCH" });
    }
    anyhow::ensure!(all_ok, "validation failed: engines disagree");
    println!("validation passed: all engines agree on the model observable");
    Ok(())
}

/// `adapar artifacts-check` — compile all AOT artifacts, smoke-test one.
pub fn artifacts_check(_args: &Args) -> Result<()> {
    use crate::runtime::{Manifest, XlaRuntime};
    let dir = Manifest::default_dir();
    let manifest = Manifest::load(&dir)
        .with_context(|| format!("no artifacts in {} — run `make artifacts`", dir.display()))?;
    let rt = XlaRuntime::cpu()?;
    println!("PJRT platform={} devices={}", rt.platform(), rt.device_count());
    for e in manifest.entries() {
        rt.load_hlo_text(&e.path)
            .with_context(|| format!("compiling {}", e.name))?;
        println!("  {} ... compiles OK", e.name);
    }
    // Smoke: one Axelrod interaction through the kernel.
    if manifest.by_kind("axelrod").is_some() {
        let interactor =
            crate::runtime::xla_engine::XlaAxelrodInteractor::from_manifest(&rt, &manifest)?;
        let f = interactor.features();
        let src = vec![1i32; f];
        let mut tgt = vec![1i32; f];
        tgt[0] = 2;
        let out = interactor.interact(&src, &tgt, 0.0, 0.0)?;
        anyhow::ensure!(out == src, "smoke interaction should copy the differing trait");
        println!("  axelrod kernel smoke ... OK (copied differing trait)");
    }
    println!("artifacts check passed");
    Ok(())
}
