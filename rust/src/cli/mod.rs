//! The `adapar` command-line interface (the launcher).
//!
//! ```text
//! adapar run        --model sir --engine parallel --workers 4 --size 50
//! adapar run        --model sir --engine sharded  --workers 4 --size 50 --trace t.json
//! adapar trace-analyze t.json
//! adapar sweep      --preset fig3 [--engine virtual] [--out target/figures]
//! adapar sweep      --config experiments/fig2.toml
//! adapar models
//! adapar calibrate
//! adapar validate   --model axelrod [--workers 1,2,4]
//! adapar artifacts-check
//! ```

pub mod commands;

use crate::error::Result;
use crate::util::cli::{Args, Spec};

const SPEC: Spec = Spec {
    options: &[
        "model", "engine", "workers", "size", "sizes", "seeds", "seed", "steps", "agents",
        "c", "batch", "window", "config", "preset", "out", "sample", "params", "every",
        "observe", "move-radius", "models", "plans", "telemetry", "trace", "trace-mode",
        "ledger", "report",
    ],
    flags: &[
        "paper-scale", "calibrate", "help", "json", "update", "seed-regression", "lenient",
        "streaming",
    ],
};

const USAGE: &str = "\
adapar — adaptive parallelization of multi-agent simulations (Băbeanu et al. 2023)

USAGE:
  adapar <command> [options]

COMMANDS:
  run              run one simulation and print timing + protocol counters
  sweep            run a (size × workers × seeds) grid and emit figure data
  models           list every registered model (bundled + user-registered)
  calibrate        measure this machine's protocol micro-action costs
  validate         assert parallel == sequential bit-for-bit for a model
  soak             chaos sweep: seeds × fault plans × models under injection,
                   shrinking any failure to a committable repro TOML
  trace-analyze    critical-path analysis of a --trace file: T1, T-inf,
                   per-epoch speedup bound, gap attribution
  perf-diff        compare fresh deterministic bench metrics against a
                   committed ledger baseline (structural = hard gate,
                   wall-clock = tolerance)
  artifacts-check  compile every AOT artifact and smoke-test the XLA path

COMMON OPTIONS:
  --model <name>                        any registered model (see `adapar models`) [axelrod]
  --engine <parallel|sequential|virtual|stepwise|sharded>
                                        execution engine [run: parallel, sweep: virtual]
  --workers <n | list>                  worker count(s) [run: 2, sweep: 1,2,3,4,5]
  --size <s> / --sizes <list>           task-size proxy (F or s)
  --seeds <list> / --seed <s>           simulation seeds
  --steps <n> / --agents <n>            workload overrides
  --c <n>                               tasks-per-cycle cap C [6]
  --batch <n>                           creation batch size B: tasks linked per tail-lock
                                        acquisition, clamped to the cycle's remaining C
                                        (1 = classic protocol; results identical at any B)
  --window <n>                          run: streaming-window cap on live tasks per chain
                                        (0 = materialized; results identical at any window;
                                        env ADAPAR_WINDOW sets the default)
  --streaming                           run: shorthand for the default window (4096); env
                                        ADAPAR_STREAMING=1 does the same
  --params <k=v,k2=v2>                  model-specific parameters (registry bag)
  --move-radius <r>                     schelling: bound relocations to Chebyshev radius r
                                        (0 = unbounded; >0 makes sharded runs mostly local)
  --config <file.toml>                  sweep config file (experiments/*.toml)
  --preset <fig2|fig3>                  paper-figure sweep preset
  --out <dir>                           output dir for sweep reports [target/figures]
  --models <list>                       soak: registry models to sweep [sir,voter,ising]
  --plans <list>                        soak: bundled fault plans [stalls,skew,jitter]
  --seeds <n>                           soak: seeds per (model, plan); env ADAPAR_SOAK_SEEDS
                                        overrides the default [8]
  --every <n>                           run/validate: record typed observations every n tasks
  --observe <file.csv|file.jsonl>       run: also stream the observation trace to a file
  --telemetry <on|off|saturate>         histogram sampling mode (inert: results identical
                                        in any mode); env ADAPAR_TELEMETRY sets the default
  --trace <file.json>                   run: write a Perfetto-loadable causal trace (open at
                                        ui.perfetto.dev, analyze with `trace-analyze`)
  --trace-mode <off|spans|full>         causal-tracing mode (inert: results identical in any
                                        mode); env ADAPAR_TRACE sets the default; --trace
                                        implies `full` unless set explicitly
  --ledger <file.json>                  perf-diff: baseline ledger
                                        [experiments/ledger/BENCH_baseline.json]
  --report <file.json>                  perf-diff: also write the diff report as JSON
  --update                              perf-diff: regenerate the baseline from fresh metrics
  --seed-regression                     perf-diff: perturb one pinned metric (CI self-test;
                                        the diff must then exit nonzero)
  --lenient                             perf-diff: report wall-clock drift instead of failing
                                        (env ADAPAR_BENCH_LENIENT=1 does the same)
  --json                                run/sweep: machine-readable JSON on stdout
  --paper-scale                         use the paper's full workload sizes
  --calibrate                           calibrate the virtual cost model first
  --help                                this text
";

/// Entry point used by `main.rs`.
pub fn main_with_args(raw: Vec<String>) -> Result<()> {
    let args = Args::parse(raw, &SPEC)?;
    if args.has_flag("help") || args.subcommand.is_none() {
        println!("{USAGE}");
        return Ok(());
    }
    match args.subcommand.as_deref().unwrap() {
        "run" => commands::run(&args),
        "sweep" => commands::sweep(&args),
        "models" => commands::models(&args),
        "calibrate" => commands::calibrate_cmd(&args),
        "validate" => commands::validate(&args),
        "soak" => commands::soak(&args),
        "trace-analyze" => commands::trace_analyze(&args),
        "perf-diff" => commands::perf_diff(&args),
        "artifacts-check" => commands::artifacts_check(&args),
        other => crate::bail!("unknown command `{other}`; try --help"),
    }
}
