//! Experiment configuration: TOML files with CLI overrides.
//!
//! A sweep config names the model (a **registry name** — bundled or
//! user-registered), the engine, the grid (task-size proxy values ×
//! worker counts × seeds) and the workload scale. Model-specific knobs go
//! in the `[params]` table and reach the model factory as a
//! [`Params`] bag. Preset files for the paper's figures live in
//! `experiments/` (`fig2.toml`, `fig3.toml`).

use std::path::Path;

use crate::api::registry;
use crate::api::Params;
use crate::error::{Context, Result};
use crate::util::toml::{parse, Value};

pub use crate::api::EngineKind;

/// A full sweep specification.
#[derive(Clone, Debug)]
pub struct SweepConfig {
    /// Registry name of the model under test.
    pub model: String,
    /// Engine producing the T values.
    pub engine: EngineKind,
    /// Task-size proxy values (`F` for Axelrod, `s` for SIR). Empty means
    /// "use the model's registered default grid".
    pub sizes: Vec<usize>,
    /// Worker counts (the figures' `n`).
    pub workers: Vec<usize>,
    /// Seeds (the paper averages over 5 instances).
    pub seeds: Vec<u64>,
    /// `C` — max creations per worker cycle (paper: 6, effect negligible).
    pub tasks_per_cycle: u32,
    /// `B` — creation/routing batch size on the chain engines (tasks
    /// linked per tail-lock acquisition; results are identical at any
    /// value, only lock amortization changes).
    pub batch: u32,
    /// Number of agents `N` (0 = per-scale model default).
    pub agents: usize,
    /// Steps (0 = per-scale model default).
    pub steps: u64,
    /// Use the paper's full workload sizes.
    pub paper_scale: bool,
    /// Calibrate the virtual cost model from native microbenches instead
    /// of the built-in defaults.
    pub calibrate: bool,
    /// Model-specific parameters forwarded to the registry factory.
    pub params: Params,
}

impl Default for SweepConfig {
    fn default() -> Self {
        Self {
            model: "axelrod".to_string(),
            engine: EngineKind::Virtual,
            sizes: Vec::new(),
            workers: vec![1, 2, 3, 4, 5],
            seeds: vec![1, 2, 3, 4, 5],
            tasks_per_cycle: 6,
            batch: crate::protocol::DEFAULT_BATCH,
            agents: 0,
            steps: 0,
            paper_scale: false,
            calibrate: false,
            params: Params::new(),
        }
    }
}

impl SweepConfig {
    /// Figure presets.
    pub fn preset(name: &str) -> Result<Self> {
        Ok(match name {
            "fig2" => Self {
                model: "axelrod".to_string(),
                sizes: vec![25, 50, 100, 200, 400, 800],
                ..Default::default()
            },
            "fig3" => Self {
                model: "sir".to_string(),
                sizes: vec![10, 20, 50, 100, 200, 500, 1000],
                ..Default::default()
            },
            // Scale tier (ISSUE 10): the ≥1M-agent workloads. Meant to
            // run with a streaming window (ADAPAR_STREAMING=1 or
            // `run --streaming`) so chain memory stays bounded.
            "scale-sir" => {
                let mut params = Params::new();
                params.set("long_links", 8i64);
                Self {
                    model: "sir".to_string(),
                    engine: EngineKind::Parallel,
                    sizes: vec![1_000],
                    workers: vec![4],
                    seeds: vec![1],
                    agents: 1 << 20,
                    steps: 10,
                    params,
                    ..Default::default()
                }
            }
            "scale-ising" => Self {
                model: "ising".to_string(),
                engine: EngineKind::Parallel,
                sizes: vec![1],
                workers: vec![4],
                seeds: vec![1],
                agents: 1024 * 1024,
                steps: 500_000,
                ..Default::default()
            },
            other => crate::bail!("unknown preset `{other}` (fig2|fig3|scale-sir|scale-ising)"),
        })
    }

    /// Effective agent count for the current scale (registry default when
    /// unset).
    pub fn effective_agents(&self) -> usize {
        if self.agents != 0 {
            return self.agents;
        }
        registry::info(&self.model)
            .map(|i| i.agents_for(self.paper_scale))
            .unwrap_or(1_000)
    }

    /// Effective step count for the current scale (registry default when
    /// unset).
    pub fn effective_steps(&self) -> u64 {
        if self.steps != 0 {
            return self.steps;
        }
        registry::info(&self.model)
            .map(|i| i.steps_for(self.paper_scale))
            .unwrap_or(10_000)
    }

    /// The size grid: explicit values, or the model's registered default.
    pub fn effective_sizes(&self) -> Vec<usize> {
        if !self.sizes.is_empty() {
            return self.sizes.clone();
        }
        registry::info(&self.model)
            .map(|i| i.default_sizes)
            .unwrap_or_else(|_| vec![1])
    }

    /// Load from a TOML file.
    pub fn from_file<P: AsRef<Path>>(path: P) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::from_toml(&text)
    }

    /// Parse from TOML text.
    pub fn from_toml(text: &str) -> Result<Self> {
        let root = parse(text)?;
        let mut cfg = SweepConfig::default();
        if let Some(v) = root.get("model") {
            cfg.model = v.as_str().context("model must be a string")?.to_string();
        }
        if let Some(v) = root.get("engine") {
            cfg.engine = v.as_str().context("engine must be a string")?.parse()?;
        }
        if let Some(v) = root.get("sizes") {
            cfg.sizes = int_list(v, "sizes")?;
        }
        if let Some(v) = root.get("workers") {
            cfg.workers = int_list(v, "workers")?;
        }
        if let Some(v) = root.get("seeds") {
            cfg.seeds = int_list(v, "seeds")?.into_iter().map(|x| x as u64).collect();
        }
        if let Some(v) = root.get("tasks_per_cycle") {
            cfg.tasks_per_cycle = v.as_int().context("tasks_per_cycle")? as u32;
        }
        if let Some(v) = root.get("batch") {
            cfg.batch = v.as_int().context("batch")? as u32;
        }
        if let Some(v) = root.get("agents") {
            cfg.agents = v.as_int().context("agents")? as usize;
        }
        if let Some(v) = root.get("steps") {
            cfg.steps = v.as_int().context("steps")? as u64;
        }
        if let Some(v) = root.get("paper_scale") {
            cfg.paper_scale = v.as_bool().context("paper_scale")?;
        }
        if let Some(v) = root.get("calibrate") {
            cfg.calibrate = v.as_bool().context("calibrate")?;
        }
        if let Some(v) = root.get("params") {
            cfg.params = Params::from_table(v.as_table().context("params must be a table")?);
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Sanity checks (consults the global model registry, so a model
    /// registered at runtime validates with zero coordinator edits).
    pub fn validate(&self) -> Result<()> {
        if self.workers.is_empty() || self.seeds.is_empty() {
            crate::bail!("workers and seeds must be non-empty");
        }
        if self.workers.iter().any(|&w| w == 0 || w > 64) {
            crate::bail!("workers must be in 1..=64");
        }
        if self.tasks_per_cycle == 0 {
            crate::bail!("tasks_per_cycle must be >= 1");
        }
        if self.batch == 0 {
            crate::bail!("batch must be >= 1");
        }
        let info = registry::info(&self.model)?;
        if self.engine == EngineKind::Stepwise && !info.has_sync_form {
            crate::bail!(
                "the stepwise baseline requires a synchronous model; `{}` has none \
                 (that is the paper's point about sequential-form models)",
                self.model
            );
        }
        if self.engine == EngineKind::Sharded && !info.has_sharded_form {
            crate::bail!(
                "the sharded engine requires a footprint topology; `{}` exposes none \
                 (implement ShardableModel and register with with_sharding)",
                self.model
            );
        }
        Ok(())
    }
}

fn int_list(v: &Value, what: &str) -> Result<Vec<usize>> {
    let arr = v
        .as_array()
        .with_context(|| format!("{what} must be an array"))?;
    if arr.is_empty() {
        crate::bail!("{what} must be non-empty");
    }
    arr.iter()
        .map(|x| {
            x.as_int()
                .map(|i| i as usize)
                .with_context(|| format!("{what} must contain integers"))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_are_valid() {
        for p in ["fig2", "fig3", "scale-sir", "scale-ising"] {
            SweepConfig::preset(p).unwrap().validate().unwrap();
        }
        assert!(SweepConfig::preset("fig9").is_err());
        let scale = SweepConfig::preset("scale-sir").unwrap();
        assert!(scale.effective_agents() >= 1 << 20, "scale tier is >= 1M agents");
        assert_eq!(scale.params.usize_or("long_links", 0).unwrap(), 8);
    }

    #[test]
    fn parses_full_toml() {
        let cfg = SweepConfig::from_toml(
            r#"
model = "sir"
engine = "virtual"
sizes = [10, 50]
workers = [1, 4]
seeds = [7]
tasks_per_cycle = 2
steps = 99
paper_scale = false

[params]
p_si = 0.5
degree = 10
"#,
        )
        .unwrap();
        assert_eq!(cfg.model, "sir");
        assert_eq!(cfg.engine, EngineKind::Virtual);
        assert_eq!(cfg.sizes, vec![10, 50]);
        assert_eq!(cfg.workers, vec![1, 4]);
        assert_eq!(cfg.seeds, vec![7]);
        assert_eq!(cfg.effective_steps(), 99);
        assert_eq!(cfg.params.f64_or("p_si", 0.8).unwrap(), 0.5);
        assert_eq!(cfg.params.usize_or("degree", 14).unwrap(), 10);
    }

    #[test]
    fn stepwise_requires_a_sync_model() {
        let err = SweepConfig::from_toml("model = \"axelrod\"\nengine = \"stepwise\"");
        assert!(err.is_err());
        let ok = SweepConfig::from_toml("model = \"sir\"\nengine = \"stepwise\"");
        assert!(ok.is_ok());
    }

    #[test]
    fn scale_defaults_come_from_the_registry() {
        let mut cfg = SweepConfig::default();
        assert_eq!(cfg.effective_agents(), 2_000);
        cfg.paper_scale = true;
        assert_eq!(cfg.effective_agents(), 10_000);
        assert_eq!(cfg.effective_steps(), 2_000_000);
        assert_eq!(cfg.effective_sizes(), vec![25, 50, 100, 200, 400, 800]);
        cfg.sizes = vec![3];
        assert_eq!(cfg.effective_sizes(), vec![3]);
    }

    #[test]
    fn unknown_model_is_rejected_with_a_listing() {
        let err = SweepConfig::from_toml("model = \"nope\"").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("unknown model `nope`"), "{msg}");
        assert!(msg.contains("axelrod"), "{msg}");
    }

    #[test]
    fn engine_roundtrip() {
        for e in ["parallel", "sequential", "virtual", "stepwise", "sharded"] {
            let k: EngineKind = e.parse().unwrap();
            assert_eq!(k.to_string(), e);
        }
    }
}
