//! Experiment configuration: TOML files with CLI overrides.
//!
//! A sweep config names the model, the engine(s), the grid (task-size
//! proxy values × worker counts × seeds) and the workload scale. Preset
//! files for the paper's figures live in `experiments/` (`fig2.toml`,
//! `fig3.toml`).

use std::path::Path;
use std::str::FromStr;

use anyhow::{bail, Context, Result};

use crate::util::toml::{parse, Value};

/// Which MABS model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    /// Axelrod cultural dynamics (§4.1, Fig. 2).
    Axelrod,
    /// SIR disease spreading (§4.2, Fig. 3).
    Sir,
    /// Voter model (extra).
    Voter,
    /// Ising/Glauber (extra).
    Ising,
    /// Schelling segregation with moving agents (future-work extension).
    Schelling,
}

impl FromStr for ModelKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "axelrod" | "cultural" => ModelKind::Axelrod,
            "sir" | "epidemic" => ModelKind::Sir,
            "voter" => ModelKind::Voter,
            "ising" => ModelKind::Ising,
            "schelling" => ModelKind::Schelling,
            other => bail!("unknown model `{other}` (axelrod|sir|voter|ising|schelling)"),
        })
    }
}

impl std::fmt::Display for ModelKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            ModelKind::Axelrod => "axelrod",
            ModelKind::Sir => "sir",
            ModelKind::Voter => "voter",
            ModelKind::Ising => "ising",
            ModelKind::Schelling => "schelling",
        };
        f.write_str(s)
    }
}

/// Which execution engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// The paper's adaptive protocol on real threads.
    Parallel,
    /// Canonical single-threaded execution.
    Sequential,
    /// The virtual-core testbed (reproduces multi-core figures on a
    /// single-core host).
    Virtual,
    /// The barrier-based step-parallel baseline (synchronous models only).
    Stepwise,
}

impl FromStr for EngineKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "parallel" | "protocol" => EngineKind::Parallel,
            "sequential" | "seq" => EngineKind::Sequential,
            "virtual" | "vtime" => EngineKind::Virtual,
            "stepwise" | "barrier" => EngineKind::Stepwise,
            other => bail!("unknown engine `{other}` (parallel|sequential|virtual|stepwise)"),
        })
    }
}

impl std::fmt::Display for EngineKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            EngineKind::Parallel => "parallel",
            EngineKind::Sequential => "sequential",
            EngineKind::Virtual => "virtual",
            EngineKind::Stepwise => "stepwise",
        };
        f.write_str(s)
    }
}

/// A full sweep specification.
#[derive(Clone, Debug)]
pub struct SweepConfig {
    /// Model under test.
    pub model: ModelKind,
    /// Engine producing the T values.
    pub engine: EngineKind,
    /// Task-size proxy values (`F` for Axelrod, `s` for SIR).
    pub sizes: Vec<usize>,
    /// Worker counts (the figures' `n`).
    pub workers: Vec<usize>,
    /// Seeds (the paper averages over 5 instances).
    pub seeds: Vec<u64>,
    /// `C` — max creations per worker cycle (paper: 6, effect negligible).
    pub tasks_per_cycle: u32,
    /// Number of agents `N` (0 = per-scale default).
    pub agents: usize,
    /// Steps (0 = per-scale default).
    pub steps: u64,
    /// Use the paper's full workload sizes.
    pub paper_scale: bool,
    /// Calibrate the virtual cost model from native microbenches instead
    /// of the built-in defaults.
    pub calibrate: bool,
}

impl Default for SweepConfig {
    fn default() -> Self {
        Self {
            model: ModelKind::Axelrod,
            engine: EngineKind::Virtual,
            sizes: vec![25, 50, 100, 200, 400, 800],
            workers: vec![1, 2, 3, 4, 5],
            seeds: vec![1, 2, 3, 4, 5],
            tasks_per_cycle: 6,
            agents: 0,
            steps: 0,
            paper_scale: false,
            calibrate: false,
        }
    }
}

impl SweepConfig {
    /// Figure presets.
    pub fn preset(name: &str) -> Result<Self> {
        Ok(match name {
            "fig2" => Self {
                model: ModelKind::Axelrod,
                sizes: vec![25, 50, 100, 200, 400, 800],
                ..Default::default()
            },
            "fig3" => Self {
                model: ModelKind::Sir,
                sizes: vec![10, 20, 50, 100, 200, 500, 1000],
                ..Default::default()
            },
            other => bail!("unknown preset `{other}` (fig2|fig3)"),
        })
    }

    /// Effective agent count for the current scale.
    pub fn effective_agents(&self) -> usize {
        if self.agents != 0 {
            return self.agents;
        }
        match (self.model, self.paper_scale) {
            (ModelKind::Axelrod, true) => 10_000,
            (ModelKind::Axelrod, false) => 2_000,
            (ModelKind::Sir, true) => 4_000,
            (ModelKind::Sir, false) => 4_000, // N is modest already
            (ModelKind::Voter, _) => 2_000,
            (ModelKind::Ising, _) => 64 * 64,
            (ModelKind::Schelling, _) => 1_800,
        }
    }

    /// Effective step count for the current scale.
    pub fn effective_steps(&self) -> u64 {
        if self.steps != 0 {
            return self.steps;
        }
        match (self.model, self.paper_scale) {
            (ModelKind::Axelrod, true) => 2_000_000,
            (ModelKind::Axelrod, false) => 60_000,
            (ModelKind::Sir, true) => 3_000,
            (ModelKind::Sir, false) => 120,
            (ModelKind::Voter, _) => 100_000,
            (ModelKind::Ising, _) => 100_000,
            (ModelKind::Schelling, _) => 100_000,
        }
    }

    /// Load from a TOML file, then apply this config's non-default CLI
    /// overrides on top? No — the file is the base; callers override
    /// explicitly. Returns the parsed config.
    pub fn from_file<P: AsRef<Path>>(path: P) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::from_toml(&text)
    }

    /// Parse from TOML text.
    pub fn from_toml(text: &str) -> Result<Self> {
        let root = parse(text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let mut cfg = SweepConfig::default();
        if let Some(v) = root.get("model") {
            cfg.model = v.as_str().context("model must be a string")?.parse()?;
        }
        if let Some(v) = root.get("engine") {
            cfg.engine = v.as_str().context("engine must be a string")?.parse()?;
        }
        if let Some(v) = root.get("sizes") {
            cfg.sizes = int_list(v, "sizes")?;
        }
        if let Some(v) = root.get("workers") {
            cfg.workers = int_list(v, "workers")?;
        }
        if let Some(v) = root.get("seeds") {
            cfg.seeds = int_list(v, "seeds")?.into_iter().map(|x| x as u64).collect();
        }
        if let Some(v) = root.get("tasks_per_cycle") {
            cfg.tasks_per_cycle = v.as_int().context("tasks_per_cycle")? as u32;
        }
        if let Some(v) = root.get("agents") {
            cfg.agents = v.as_int().context("agents")? as usize;
        }
        if let Some(v) = root.get("steps") {
            cfg.steps = v.as_int().context("steps")? as u64;
        }
        if let Some(v) = root.get("paper_scale") {
            cfg.paper_scale = v.as_bool().context("paper_scale")?;
        }
        if let Some(v) = root.get("calibrate") {
            cfg.calibrate = v.as_bool().context("calibrate")?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Sanity checks.
    pub fn validate(&self) -> Result<()> {
        if self.sizes.is_empty() || self.workers.is_empty() || self.seeds.is_empty() {
            bail!("sizes, workers and seeds must be non-empty");
        }
        if self.workers.iter().any(|&w| w == 0 || w > 64) {
            bail!("workers must be in 1..=64");
        }
        if self.tasks_per_cycle == 0 {
            bail!("tasks_per_cycle must be >= 1");
        }
        if self.engine == EngineKind::Stepwise && self.model != ModelKind::Sir {
            bail!(
                "the stepwise baseline requires a synchronous model; only `sir` has one \
                 (that is the paper's point about sequential-form models)"
            );
        }
        Ok(())
    }
}

fn int_list(v: &Value, what: &str) -> Result<Vec<usize>> {
    let arr = v.as_array().with_context(|| format!("{what} must be an array"))?;
    arr.iter()
        .map(|x| {
            x.as_int()
                .map(|i| i as usize)
                .with_context(|| format!("{what} must contain integers"))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_are_valid() {
        for p in ["fig2", "fig3"] {
            SweepConfig::preset(p).unwrap().validate().unwrap();
        }
        assert!(SweepConfig::preset("fig9").is_err());
    }

    #[test]
    fn parses_full_toml() {
        let cfg = SweepConfig::from_toml(
            r#"
model = "sir"
engine = "virtual"
sizes = [10, 50]
workers = [1, 4]
seeds = [7]
tasks_per_cycle = 2
steps = 99
paper_scale = false
"#,
        )
        .unwrap();
        assert_eq!(cfg.model, ModelKind::Sir);
        assert_eq!(cfg.engine, EngineKind::Virtual);
        assert_eq!(cfg.sizes, vec![10, 50]);
        assert_eq!(cfg.workers, vec![1, 4]);
        assert_eq!(cfg.seeds, vec![7]);
        assert_eq!(cfg.effective_steps(), 99);
    }

    #[test]
    fn stepwise_requires_sir() {
        let err = SweepConfig::from_toml("model = \"axelrod\"\nengine = \"stepwise\"");
        assert!(err.is_err());
    }

    #[test]
    fn scale_defaults() {
        let mut cfg = SweepConfig::default();
        assert_eq!(cfg.effective_agents(), 2_000);
        cfg.paper_scale = true;
        assert_eq!(cfg.effective_agents(), 10_000);
        assert_eq!(cfg.effective_steps(), 2_000_000);
    }

    #[test]
    fn model_and_engine_roundtrip() {
        for m in ["axelrod", "sir", "voter", "ising"] {
            let k: ModelKind = m.parse().unwrap();
            assert_eq!(k.to_string(), m);
        }
        for e in ["parallel", "sequential", "virtual", "stepwise"] {
            let k: EngineKind = e.parse().unwrap();
            assert_eq!(k.to_string(), e);
        }
    }
}
