//! The sweep grid runner: `sizes × workers × seeds`, with per-point SEM
//! aggregation — the paper's experimental methodology.

use crate::api::registry::{self, BuildCtx};
use crate::coordinator::config::{EngineKind, SweepConfig};
use crate::coordinator::runner::run_once;
use crate::error::Result;
use crate::util::stats::Online;
use crate::vtime::{calibrate, CostModel};

/// Aggregated result for one `(size, workers)` grid point.
#[derive(Clone, Debug)]
pub struct PointResult {
    /// Task-size proxy value.
    pub size: usize,
    /// Worker count `n`.
    pub workers: usize,
    /// Mean `T` over seeds (seconds).
    pub mean_s: f64,
    /// Standard error of the mean.
    pub sem_s: f64,
    /// Per-seed times.
    pub times_s: Vec<f64>,
    /// Mean protocol-overhead ratio (skips+passes+retries vs executions).
    pub overhead: f64,
    /// Mean high-water chain length.
    pub max_chain: f64,
}

/// A completed sweep.
#[derive(Clone, Debug)]
pub struct SweepResult {
    /// The configuration that produced it.
    pub config: SweepConfig,
    /// Grid points in `(size, workers)` iteration order.
    pub points: Vec<PointResult>,
    /// The cost model used (virtual engine only; defaults otherwise).
    pub cost: CostModel,
}

impl SweepResult {
    /// Look up a grid point.
    pub fn point(&self, size: usize, workers: usize) -> Option<&PointResult> {
        self.points
            .iter()
            .find(|p| p.size == size && p.workers == workers)
    }

    /// `T(1)/T(n)` speedup at a size, if both points exist.
    pub fn speedup(&self, size: usize, workers: usize) -> Option<f64> {
        let t1 = self.point(size, 1)?.mean_s;
        let tn = self.point(size, workers)?.mean_s;
        Some(t1 / tn)
    }
}

/// Build the cost model for a sweep: built-in defaults, or calibrated
/// protocol costs plus a per-model exec-unit measurement at a
/// representative size. Model-agnostic: the throwaway calibration
/// instance comes from the registry and measures itself through
/// [`crate::api::DynModel::calibrate_exec_unit`].
pub fn sweep_cost_model(cfg: &SweepConfig) -> Result<CostModel> {
    if !cfg.calibrate {
        return Ok(CostModel::default());
    }
    let mut cost = calibrate();
    // Calibrate exec-unit cost on a mid-grid throwaway instance.
    let sizes = cfg.effective_sizes();
    let size = sizes.get(sizes.len() / 2).copied().unwrap_or(1);
    let sample = 4_000u64;
    let throwaway = registry::build(
        &cfg.model,
        &BuildCtx {
            size,
            agents: cfg.effective_agents(),
            steps: cfg.effective_steps(),
            seed: 0,
            layout: crate::sim::soa::Layout::env_default(),
            params: cfg.params.clone(),
        },
    )?;
    cost.exec_unit_ns = throwaway.calibrate_exec_unit(sample, &cost);
    Ok(cost)
}

/// Run the full grid. Progress goes to the log; figure emission is the
/// caller's job (`coordinator::report`).
pub fn run_sweep(cfg: &SweepConfig) -> Result<SweepResult> {
    cfg.validate()?;
    let cost = sweep_cost_model(cfg)?;
    let sizes = cfg.effective_sizes();
    let mut points = Vec::with_capacity(sizes.len() * cfg.workers.len());
    for &size in &sizes {
        for &workers in &cfg.workers {
            if workers > 1 && cfg.engine == EngineKind::Sequential {
                continue; // sequential has no worker dimension
            }
            let mut acc = Online::new();
            let mut times = Vec::with_capacity(cfg.seeds.len());
            let mut overhead = Online::new();
            let mut max_chain = Online::new();
            for &seed in &cfg.seeds {
                let out = run_once(cfg, size, workers, seed, &cost)?;
                acc.push(out.time_s);
                times.push(out.time_s);
                let wasted = out.totals.skipped_dependent
                    + out.totals.passed_executing
                    + out.totals.erased_retries;
                let denom = (wasted + out.totals.executed).max(1);
                overhead.push(wasted as f64 / denom as f64);
                max_chain.push(out.max_chain_len as f64);
            }
            crate::log_info!(
                "sweep {} {} size={size} n={workers}: T={:.4}s ± {:.4}",
                cfg.model,
                cfg.engine,
                acc.mean(),
                acc.sem()
            );
            points.push(PointResult {
                size,
                workers,
                mean_s: acc.mean(),
                sem_s: acc.sem(),
                times_s: times,
                overhead: overhead.mean(),
                max_chain: max_chain.mean(),
            });
        }
    }
    Ok(SweepResult {
        config: cfg.clone(),
        points,
        cost,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_sweep(engine: EngineKind) -> SweepConfig {
        SweepConfig {
            model: "sir".to_string(),
            engine,
            sizes: vec![15, 60],
            workers: vec![1, 3],
            seeds: vec![1, 2],
            agents: 240,
            steps: 25,
            ..Default::default()
        }
    }

    #[test]
    fn virtual_sweep_covers_grid() {
        let res = run_sweep(&tiny_sweep(EngineKind::Virtual)).unwrap();
        assert_eq!(res.points.len(), 4);
        for p in &res.points {
            assert!(p.mean_s > 0.0);
            assert_eq!(p.times_s.len(), 2);
        }
        assert!(res.point(15, 1).is_some());
        assert!(res.speedup(60, 3).is_some());
    }

    #[test]
    fn sequential_sweep_skips_worker_dimension() {
        let res = run_sweep(&tiny_sweep(EngineKind::Sequential)).unwrap();
        // Only workers=1 points remain.
        assert_eq!(res.points.len(), 2);
        assert!(res.points.iter().all(|p| p.workers == 1));
    }

    #[test]
    fn parallel_sweep_runs() {
        let res = run_sweep(&tiny_sweep(EngineKind::Parallel)).unwrap();
        assert_eq!(res.points.len(), 4);
    }
}
