//! The run-over-run perf ledger behind `adapar perf-diff`.
//!
//! A ledger is a committed JSON baseline of **structural** metrics from
//! a fixed set of deterministic single-worker workloads: task counts,
//! tail-lock counts, chain depth, arena occupancy, edge cut — numbers
//! that depend only on the protocol, never on the clock. Because the
//! workloads are seeded and single-worker, every metric is reproducible
//! bit-for-bit on any machine, so the diff is a **hard gate**: a changed
//! structural value means the protocol's behavior changed, and the PR
//! either updates the baseline deliberately (`perf-diff --update`, the
//! `just ledger-update` target) or fixes the regression.
//!
//! Wall-clock (`wall_s`) rides along for trend visibility but is noisy
//! and machine-dependent, so it is compared against a relative
//! `tolerance` and only *reported* when `--lenient` (or
//! `ADAPAR_BENCH_LENIENT=1`, the CI default) is set.
//!
//! A `null` in the baseline marks a metric as **unpinned**: the diff
//! prints the fresh value without gating on it. The committed seed
//! baseline pins only hand-derivable task counts and leaves the rest
//! unpinned until a toolchain run regenerates it.

use std::path::Path;

use crate::api::{EngineKind, Simulation};
use crate::error::{Context, Result};
use crate::protocol::RunReport;
use crate::util::json::Json;

/// Ledger schema version; bumped on any metric/shape change.
pub const SCHEMA: i64 = 1;

/// Default relative tolerance for wall-clock comparisons.
pub const DEFAULT_TOLERANCE: f64 = 0.25;

/// Fresh metrics for one named bench scenario, in canonical key order.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchMetrics {
    /// Scenario name (the ledger's bench key).
    pub name: String,
    /// `(metric, value)` pairs; `wall_*` keys are wall-clock, everything
    /// else is structural.
    pub metrics: Vec<(String, f64)>,
}

/// Whether a metric key is wall-clock (tolerance-compared) rather than
/// structural (exact-compared).
pub fn is_wall_metric(key: &str) -> bool {
    key.starts_with("wall_")
}

fn chain_metrics(report: &RunReport) -> Vec<(String, f64)> {
    vec![
        ("tasks_created".into(), report.chain.tasks_created as f64),
        ("tasks_executed".into(), report.chain.tasks_executed as f64),
        ("tail_locks".into(), report.chain.tail_locks as f64),
        ("max_chain_len".into(), report.chain.max_chain_len as f64),
        (
            "arena_high_water".into(),
            report.chain.arena_high_water as f64,
        ),
        ("arena_recycled".into(), report.chain.arena_recycled as f64),
        ("bytes_per_task".into(), report.chain.bytes_per_task()),
        ("wall_s".into(), report.time_s),
    ]
}

fn sched_metrics(report: &RunReport) -> Vec<(String, f64)> {
    let sched = report.sched.as_ref().expect("sharded run reports sched");
    vec![
        ("tasks_created".into(), report.chain.tasks_created as f64),
        ("tasks_executed".into(), report.chain.tasks_executed as f64),
        ("local_tasks".into(), sched.local_tasks as f64),
        ("boundary_tasks".into(), sched.boundary_tasks as f64),
        ("edge_cut".into(), sched.edge_cut as f64),
        ("migrations".into(), sched.migrations as f64),
        ("rebalances".into(), sched.rebalances as f64),
        ("tail_locks".into(), report.chain.tail_locks as f64),
        (
            "arena_high_water".into(),
            report.chain.arena_high_water as f64,
        ),
        ("bytes_per_task".into(), report.chain.bytes_per_task()),
        ("wall_s".into(), report.time_s),
    ]
}

/// Run every ledger scenario and return its metrics. Scenarios are
/// single-worker and seeded, so the structural metrics are deterministic
/// on any host; only `wall_s` varies run to run.
pub fn collect() -> Result<Vec<BenchMetrics>> {
    // The layout is pinned (never read from `ADAPAR_LAYOUT`) so the
    // ledger's structural metrics — `bytes_per_task` in particular —
    // stay reproducible regardless of the environment.
    // The window is likewise pinned per scenario (never from
    // ADAPAR_WINDOW/ADAPAR_STREAMING): `arena_high_water` is structural
    // and must not depend on the environment the gate runs in.
    let chain = |model: &str, agents: usize, steps: u64, size: usize, seed: u64, window: u64| {
        Simulation::builder()
            .model(model)
            .engine(EngineKind::Parallel)
            .workers(1)
            .batch(16)
            .agents(agents)
            .steps(steps)
            .size(size)
            .seed(seed)
            .window(window)
            .layout(crate::sim::soa::Layout::Packed)
            .run()
    };
    let voter = chain("voter", 240, 4_000, 0, 7, 0)?;
    let sir = chain("sir", 200, 50, 20, 11, 0)?;
    // The same SIR workload through a 32-task streaming window (ISSUE
    // 10): results are identical, but `arena_high_water` must collapse
    // from ~workload-sized to window-sized.
    let sir_streamed = chain("sir", 200, 50, 20, 11, 32)?;
    let sched = Simulation::builder()
        .model("voter")
        .engine(EngineKind::Sharded)
        .workers(1)
        .batch(16)
        .agents(240)
        .steps(4_000)
        .seed(7)
        .window(0)
        .layout(crate::sim::soa::Layout::Packed)
        .run()?;
    Ok(vec![
        BenchMetrics {
            name: "chain_voter".into(),
            metrics: chain_metrics(&voter.report),
        },
        BenchMetrics {
            name: "chain_sir".into(),
            metrics: chain_metrics(&sir.report),
        },
        BenchMetrics {
            name: "chain_sir_streamed".into(),
            metrics: chain_metrics(&sir_streamed.report),
        },
        BenchMetrics {
            name: "sched_voter".into(),
            metrics: sched_metrics(&sched.report),
        },
    ])
}

/// A parsed baseline ledger.
#[derive(Clone, Debug, PartialEq)]
pub struct Ledger {
    /// Schema version (must equal [`SCHEMA`]).
    pub schema: i64,
    /// `true` while the baseline still carries unpinned (`null`) values.
    pub provisional: bool,
    /// Relative wall-clock tolerance.
    pub tolerance: f64,
    /// `(bench, [(metric, pinned value)])`; `None` = unpinned.
    pub benches: Vec<(String, Vec<(String, Option<f64>)>)>,
}

impl Ledger {
    /// Parse a ledger from JSON text.
    pub fn from_json_text(text: &str) -> Result<Ledger> {
        let root = Json::parse(text).map_err(crate::error::Error::msg)?;
        let schema = root
            .get("schema")
            .and_then(Json::as_i64)
            .ok_or("ledger is missing a numeric `schema` field")?;
        let provisional = matches!(root.get("provisional"), Some(Json::Bool(true)));
        let tolerance = root
            .get("tolerance")
            .and_then(Json::as_f64)
            .unwrap_or(DEFAULT_TOLERANCE);
        let mut benches = Vec::new();
        for (name, entry) in root
            .get("benches")
            .and_then(Json::as_obj)
            .ok_or("ledger is missing the `benches` object")?
        {
            let mut metrics = Vec::new();
            for (key, value) in entry
                .as_obj()
                .ok_or_else(|| format!("ledger bench `{name}` is not an object"))?
            {
                let pinned = match value {
                    Json::Null => None,
                    v => Some(v.as_f64().ok_or_else(|| {
                        format!("ledger metric `{name}.{key}` is not a number or null")
                    })?),
                };
                metrics.push((key.clone(), pinned));
            }
            benches.push((name.clone(), metrics));
        }
        Ok(Ledger {
            schema,
            provisional,
            tolerance,
            benches,
        })
    }

    /// Load a ledger file.
    pub fn load(path: &Path) -> Result<Ledger> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading ledger {}", path.display()))?;
        Self::from_json_text(&text)
            .with_context(|| format!("parsing ledger {}", path.display()))
    }

    /// An updated ledger from fresh metrics (the `--update` output).
    /// Structural metrics are always pinned; `wall_*` metrics are only
    /// pinned when `pin_wall` (the reference-machine run) — otherwise
    /// they stay `null` and the ledger remains provisional, since wall
    /// times measured on an arbitrary machine make a meaningless gate.
    pub fn pinned(fresh: &[BenchMetrics], tolerance: f64, pin_wall: bool) -> Ledger {
        let benches: Vec<_> = fresh
            .iter()
            .map(|b| {
                (
                    b.name.clone(),
                    b.metrics
                        .iter()
                        .map(|(k, v)| {
                            let pin = pin_wall || !is_wall_metric(k);
                            (k.clone(), pin.then_some(*v))
                        })
                        .collect::<Vec<_>>(),
                )
            })
            .collect();
        let provisional = benches
            .iter()
            .any(|(_, ms)| ms.iter().any(|(_, v)| v.is_none()));
        Ledger {
            schema: SCHEMA,
            provisional,
            tolerance,
            benches,
        }
    }

    /// How many `wall_*` metrics are unpinned (`null`) in this ledger.
    pub fn unpinned_wall(&self) -> usize {
        self.benches
            .iter()
            .flat_map(|(_, ms)| ms.iter())
            .filter(|(k, v)| is_wall_metric(k) && v.is_none())
            .count()
    }

    /// The ledger as a JSON tree (field order is canonical, so
    /// regeneration is byte-stable).
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("schema".into(), Json::from(self.schema)),
            ("provisional".into(), Json::from(self.provisional)),
            ("tolerance".into(), Json::from(self.tolerance)),
            (
                "benches".into(),
                Json::Obj(
                    self.benches
                        .iter()
                        .map(|(name, metrics)| {
                            (
                                name.clone(),
                                Json::Obj(
                                    metrics
                                        .iter()
                                        .map(|(k, v)| {
                                            (
                                                k.clone(),
                                                match v {
                                                    None => Json::Null,
                                                    Some(x) => Json::from(*x),
                                                },
                                            )
                                        })
                                        .collect(),
                                ),
                            )
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Write the ledger (trailing newline, parent dirs created).
    pub fn write(&self, path: &Path) -> Result<()> {
        crate::util::create_parent_dirs(path)?;
        let mut text = self.to_json().render();
        text.push('\n');
        std::fs::write(path, text)
            .with_context(|| format!("writing ledger {}", path.display()))
    }
}

/// Outcome of one baseline-vs-fresh comparison.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Diff {
    /// Hard failures: schema mismatches and structural regressions (and
    /// over-tolerance wall-clock when not lenient).
    pub failures: Vec<String>,
    /// Report-only findings (over-tolerance wall-clock under lenient).
    pub warnings: Vec<String>,
    /// Informational lines: matches and unpinned metrics.
    pub notes: Vec<String>,
}

impl Diff {
    /// Whether the gate passes.
    pub fn ok(&self) -> bool {
        self.failures.is_empty()
    }

    /// The diff as a JSON report artifact.
    pub fn to_json(&self) -> Json {
        let arr = |xs: &[String]| Json::Arr(xs.iter().map(|s| Json::from(s.clone())).collect());
        Json::Obj(vec![
            ("ok".into(), Json::from(self.ok())),
            ("failures".into(), arr(&self.failures)),
            ("warnings".into(), arr(&self.warnings)),
            ("notes".into(), arr(&self.notes)),
        ])
    }
}

/// Compare fresh metrics against a baseline. Structural metrics must
/// match a pinned baseline value exactly; `wall_*` metrics compare
/// within `base.tolerance` (a miss is a warning under `lenient`, a
/// failure otherwise); bench/metric sets must agree exactly (schema
/// gate).
pub fn diff(base: &Ledger, fresh: &[BenchMetrics], lenient: bool) -> Diff {
    let mut d = Diff::default();
    if base.schema != SCHEMA {
        d.failures.push(format!(
            "schema mismatch: ledger has {}, this binary expects {SCHEMA} \
             (regenerate with `perf-diff --update`)",
            base.schema
        ));
        return d;
    }
    for (name, _) in &base.benches {
        if !fresh.iter().any(|b| &b.name == name) {
            d.failures
                .push(format!("bench `{name}` is in the ledger but no longer runs"));
        }
    }
    for b in fresh {
        let Some((_, baseline)) = base.benches.iter().find(|(n, _)| n == &b.name) else {
            d.failures
                .push(format!("bench `{}` is not in the ledger", b.name));
            continue;
        };
        for (key, _) in baseline {
            if !b.metrics.iter().any(|(k, _)| k == key) {
                d.failures
                    .push(format!("metric `{}.{key}` is pinned but no longer emitted", b.name));
            }
        }
        for (key, got) in &b.metrics {
            let Some((_, pinned)) = baseline.iter().find(|(k, _)| k == key) else {
                d.failures
                    .push(format!("metric `{}.{key}` is not in the ledger", b.name));
                continue;
            };
            match (pinned, is_wall_metric(key)) {
                (None, _) => d
                    .notes
                    .push(format!("{}.{key}: unpinned (fresh {got})", b.name)),
                (Some(want), false) => {
                    if got == want {
                        d.notes.push(format!("{}.{key}: {got} (match)", b.name));
                    } else {
                        d.failures.push(format!(
                            "{}.{key}: structural regression — baseline {want}, got {got}",
                            b.name
                        ));
                    }
                }
                (Some(want), true) => {
                    let rel = if *want == 0.0 {
                        if *got == 0.0 {
                            0.0
                        } else {
                            f64::INFINITY
                        }
                    } else {
                        (got - want).abs() / want.abs()
                    };
                    if rel <= base.tolerance {
                        d.notes.push(format!(
                            "{}.{key}: {got:.6}s vs {want:.6}s ({:+.1}%, within tolerance)",
                            b.name,
                            100.0 * (got - want) / want.abs()
                        ));
                    } else {
                        let line = format!(
                            "{}.{key}: wall-clock drift — baseline {want:.6}s, got {got:.6}s \
                             ({:.0}% > {:.0}% tolerance)",
                            b.name,
                            100.0 * rel,
                            100.0 * base.tolerance
                        );
                        if lenient {
                            d.warnings.push(line);
                        } else {
                            d.failures.push(line);
                        }
                    }
                }
            }
        }
    }
    d
}

/// Perturb the first pinned structural metric in `fresh` (the CI
/// self-test: proves the gate exits nonzero on a seeded regression).
/// Errors if the baseline pins nothing structural.
pub fn seed_regression(base: &Ledger, fresh: &mut [BenchMetrics]) -> Result<String> {
    for b in fresh.iter_mut() {
        let Some((_, baseline)) = base.benches.iter().find(|(n, _)| n == &b.name) else {
            continue;
        };
        for (key, got) in b.metrics.iter_mut() {
            let pinned = baseline
                .iter()
                .any(|(k, v)| k == key && v.is_some() && !is_wall_metric(k));
            if pinned {
                *got += 1.0;
                return Ok(format!("{}.{key}", b.name));
            }
        }
    }
    crate::bail!("cannot seed a regression: the ledger pins no structural metric")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fresh() -> Vec<BenchMetrics> {
        vec![BenchMetrics {
            name: "b".into(),
            metrics: vec![
                ("tasks_executed".into(), 100.0),
                ("wall_s".into(), 1.0),
            ],
        }]
    }

    fn base(executed: Option<f64>, wall: Option<f64>) -> Ledger {
        Ledger {
            schema: SCHEMA,
            provisional: false,
            tolerance: 0.25,
            benches: vec![(
                "b".into(),
                vec![("tasks_executed".into(), executed), ("wall_s".into(), wall)],
            )],
        }
    }

    #[test]
    fn update_pins_structural_but_not_wall_metrics() {
        let l = Ledger::pinned(&fresh(), 0.25, false);
        assert!(l.provisional, "unpinned wall metrics keep it provisional");
        assert_eq!(l.unpinned_wall(), 1);
        let (_, metrics) = &l.benches[0];
        assert_eq!(metrics[0], ("tasks_executed".into(), Some(100.0)));
        assert_eq!(metrics[1], ("wall_s".into(), None));
        // The reference-machine run pins everything.
        let r = Ledger::pinned(&fresh(), 0.25, true);
        assert!(!r.provisional);
        assert_eq!(r.unpinned_wall(), 0);
        assert_eq!(r.benches[0].1[1], ("wall_s".into(), Some(1.0)));
    }

    #[test]
    fn ledger_json_round_trips() {
        let l = base(Some(100.0), None);
        let back = Ledger::from_json_text(&l.to_json().render()).unwrap();
        assert_eq!(back, l);
    }

    #[test]
    fn matching_structural_metrics_pass() {
        let d = diff(&base(Some(100.0), None), &fresh(), false);
        assert!(d.ok(), "{:?}", d.failures);
        assert!(d.notes.iter().any(|n| n.contains("match")));
        assert!(d.notes.iter().any(|n| n.contains("unpinned")));
    }

    #[test]
    fn structural_mismatch_is_a_hard_failure_even_when_lenient() {
        let d = diff(&base(Some(99.0), None), &fresh(), true);
        assert!(!d.ok());
        assert!(d.failures[0].contains("structural regression"), "{:?}", d.failures);
    }

    #[test]
    fn wall_drift_is_lenient_dependent() {
        let strict = diff(&base(Some(100.0), Some(0.5)), &fresh(), false);
        assert!(!strict.ok());
        let lenient = diff(&base(Some(100.0), Some(0.5)), &fresh(), true);
        assert!(lenient.ok());
        assert_eq!(lenient.warnings.len(), 1);
        let close = diff(&base(Some(100.0), Some(0.9)), &fresh(), false);
        assert!(close.ok(), "within 25% tolerance: {:?}", close.failures);
    }

    #[test]
    fn schema_and_shape_mismatches_fail() {
        let mut wrong = base(Some(100.0), None);
        wrong.schema = SCHEMA + 1;
        assert!(!diff(&wrong, &fresh(), true).ok());

        let mut extra = base(Some(100.0), None);
        extra.benches[0].1.push(("gone".into(), Some(1.0)));
        let d = diff(&extra, &fresh(), true);
        assert!(d.failures.iter().any(|f| f.contains("no longer emitted")), "{:?}", d.failures);

        let renamed = Ledger {
            benches: vec![("other".into(), vec![])],
            ..base(None, None)
        };
        let d = diff(&renamed, &fresh(), true);
        assert!(d.failures.iter().any(|f| f.contains("no longer runs")));
        assert!(d.failures.iter().any(|f| f.contains("not in the ledger")));
    }

    #[test]
    fn seeded_regression_perturbs_a_pinned_structural_metric() {
        let b = base(Some(100.0), None);
        let mut f = fresh();
        let which = seed_regression(&b, &mut f).unwrap();
        assert_eq!(which, "b.tasks_executed");
        assert_eq!(f[0].metrics[0].1, 101.0);
        assert!(!diff(&b, &f, true).ok());

        let unpinned = base(None, Some(1.0));
        assert!(seed_regression(&unpinned, &mut fresh()).is_err());
    }

    #[test]
    fn collect_produces_deterministic_structural_metrics() {
        let a = collect().unwrap();
        let b = collect().unwrap();
        assert_eq!(a.len(), 4);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.name, y.name);
            for ((k, vx), (_, vy)) in x.metrics.iter().zip(&y.metrics) {
                if !is_wall_metric(k) {
                    assert_eq!(vx, vy, "{}.{k} must be deterministic", x.name);
                }
            }
        }
        // The hand-derivable pins in the committed baseline.
        let by_name = |n: &str| a.iter().find(|b| b.name == n).unwrap();
        let metric = |b: &BenchMetrics, k: &str| {
            b.metrics.iter().find(|(key, _)| key == k).unwrap().1
        };
        assert_eq!(metric(by_name("chain_voter"), "tasks_executed"), 4_000.0);
        assert_eq!(metric(by_name("chain_sir"), "tasks_executed"), 2_000.0);
        assert_eq!(metric(by_name("chain_sir_streamed"), "tasks_executed"), 2_000.0);
        assert_eq!(metric(by_name("sched_voter"), "tasks_executed"), 4_000.0);
        // The streaming scenario's whole point: identical task counts,
        // window-bounded arena (32 + 2 sentinels) strictly below the
        // materialized run's high-water.
        let streamed_hw = metric(by_name("chain_sir_streamed"), "arena_high_water");
        assert!(streamed_hw <= 34.0, "streamed high-water {streamed_hw} > window + 2");
        assert!(
            streamed_hw < metric(by_name("chain_sir"), "arena_high_water"),
            "streaming must lower the arena high-water"
        );
    }
}
