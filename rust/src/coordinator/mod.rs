//! Experiment coordination: the launcher layer that turns configs into
//! figure data.
//!
//! * [`config`] — experiment configuration (TOML files + CLI overrides).
//! * [`experiment`] — the sweep grid runner (size × workers × seeds with
//!   SEM aggregation — the paper's methodology: "T is averaged over 5
//!   simulation instances with different starting seeds").
//! * [`report`] — figure-series tables (markdown pivot + CSV).
//! * [`runner`] — single-run dispatch across engines and models.
//! * [`ledger`] — the run-over-run perf ledger behind `adapar perf-diff`
//!   (deterministic structural metrics hard-gated against a committed
//!   baseline; wall-clock compared leniently).

pub mod config;
pub mod experiment;
pub mod ledger;
pub mod report;
pub mod runner;

pub use config::{EngineKind, SweepConfig};
pub use experiment::{run_sweep, PointResult, SweepResult};
pub use ledger::{BenchMetrics, Ledger};
pub use runner::{run_once, simulation_for, RunOutcome};
