//! Figure-series emission: long-form CSV plus a markdown pivot shaped
//! like the paper's figures (rows = task size, one column per worker
//! count — the figures' curve families).

use std::path::{Path, PathBuf};

use crate::coordinator::experiment::SweepResult;
use crate::error::Result;
use crate::util::bench::fmt_secs;
use crate::util::csv::Table;
use crate::util::json::Json;

/// Long-form table: one row per grid point.
pub fn long_table(res: &SweepResult) -> Table {
    let mut t = Table::new([
        "model", "engine", "size", "workers", "mean_s", "sem_s", "overhead", "max_chain",
    ]);
    for p in &res.points {
        t.push([
            res.config.model.clone(),
            res.config.engine.to_string(),
            p.size.to_string(),
            p.workers.to_string(),
            format!("{:.9}", p.mean_s),
            format!("{:.9}", p.sem_s),
            format!("{:.4}", p.overhead),
            format!("{:.1}", p.max_chain),
        ]);
    }
    t
}

/// Pivot table shaped like the paper's figures: `size` rows, `T(n)`
/// columns (mean ± sem), plus the `T(1)/T(n_max)` speedup.
pub fn figure_pivot(res: &SweepResult) -> Table {
    let workers: Vec<usize> = {
        let mut ws: Vec<usize> = res.points.iter().map(|p| p.workers).collect();
        ws.sort_unstable();
        ws.dedup();
        ws
    };
    let sizes: Vec<usize> = {
        let mut ss: Vec<usize> = res.points.iter().map(|p| p.size).collect();
        ss.sort_unstable();
        ss.dedup();
        ss
    };
    let mut header = vec!["size".to_string()];
    header.extend(workers.iter().map(|w| format!("T(n={w})")));
    if workers.len() > 1 {
        header.push(format!("T(1)/T({})", workers[workers.len() - 1]));
    }
    let mut t = Table::new(header);
    for &size in &sizes {
        let mut row = vec![size.to_string()];
        for &w in &workers {
            match res.point(size, w) {
                Some(p) => row.push(format!("{} ±{}", fmt_secs(p.mean_s), fmt_secs(p.sem_s))),
                None => row.push("-".into()),
            }
        }
        if workers.len() > 1 {
            match res.speedup(size, workers[workers.len() - 1]) {
                Some(s) => row.push(format!("{s:.2}×")),
                None => row.push("-".into()),
            }
        }
        t.push(row);
    }
    t
}

/// Write both renderings under `dir` with the given file stem; returns the
/// CSV path.
pub fn write_report(res: &SweepResult, dir: &Path, stem: &str) -> Result<PathBuf> {
    std::fs::create_dir_all(dir)?;
    let csv_path = dir.join(format!("{stem}.csv"));
    long_table(res).write_csv(&csv_path)?;
    let md_path = dir.join(format!("{stem}.md"));
    std::fs::write(&md_path, figure_pivot(res).to_markdown())?;
    Ok(csv_path)
}

/// The whole sweep as one JSON object: the effective configuration, every
/// grid point with its per-seed times, and the `T(1)/T(n_max)` speedups.
/// This is the `--json` CLI payload and the `BENCH_*.json` schema.
pub fn sweep_json(res: &SweepResult) -> Json {
    let cfg = &res.config;
    let n_max = res.points.iter().map(|p| p.workers).max().unwrap_or(1);
    let mut sizes: Vec<usize> = res.points.iter().map(|p| p.size).collect();
    sizes.sort_unstable();
    sizes.dedup();
    let speedups: Vec<Json> = sizes
        .iter()
        .filter_map(|&size| {
            res.speedup(size, n_max).map(|s| {
                Json::Obj(vec![
                    ("size".into(), Json::from(size)),
                    ("workers".into(), Json::from(n_max)),
                    ("speedup".into(), Json::from(s)),
                ])
            })
        })
        .collect();
    Json::Obj(vec![
        ("model".into(), Json::from(cfg.model.clone())),
        ("engine".into(), Json::from(cfg.engine.to_string())),
        ("agents".into(), Json::from(cfg.effective_agents())),
        ("steps".into(), Json::from(cfg.effective_steps())),
        ("paper_scale".into(), Json::from(cfg.paper_scale)),
        (
            "seeds".into(),
            Json::Arr(cfg.seeds.iter().map(|&s| Json::from(s)).collect()),
        ),
        (
            "points".into(),
            Json::Arr(
                res.points
                    .iter()
                    .map(|p| {
                        Json::Obj(vec![
                            ("size".into(), Json::from(p.size)),
                            ("workers".into(), Json::from(p.workers)),
                            ("mean_s".into(), Json::from(p.mean_s)),
                            ("sem_s".into(), Json::from(p.sem_s)),
                            (
                                "times_s".into(),
                                Json::Arr(p.times_s.iter().map(|&t| Json::from(t)).collect()),
                            ),
                            ("overhead".into(), Json::from(p.overhead)),
                            ("max_chain".into(), Json::from(p.max_chain)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("speedups".into(), Json::Arr(speedups)),
    ])
}

/// Write the sweep as a perf-trajectory artifact (`BENCH_fig2.json`,
/// `BENCH_fig3.json`, ...); returns the path written.
pub fn write_bench_json(res: &SweepResult, path: &Path) -> Result<PathBuf> {
    crate::util::create_parent_dirs(path)?;
    let mut text = sweep_json(res).render();
    text.push('\n');
    std::fs::write(path, text)?;
    Ok(path.to_path_buf())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::{EngineKind, SweepConfig};
    use crate::coordinator::experiment::run_sweep;

    fn result() -> SweepResult {
        run_sweep(&SweepConfig {
            model: "sir".to_string(),
            engine: EngineKind::Virtual,
            sizes: vec![20, 40],
            workers: vec![1, 2],
            seeds: vec![3],
            agents: 160,
            steps: 15,
            ..Default::default()
        })
        .unwrap()
    }

    #[test]
    fn long_table_has_one_row_per_point() {
        let res = result();
        let t = long_table(&res);
        assert_eq!(t.len(), res.points.len());
        assert_eq!(t.col("mean_s"), Some(4));
    }

    #[test]
    fn pivot_is_sizes_by_workers() {
        let res = result();
        let t = figure_pivot(&res);
        assert_eq!(t.len(), 2); // two sizes
        assert_eq!(t.width(), 1 + 2 + 1); // size + two n columns + speedup
        let md = t.to_markdown();
        assert!(md.contains("T(n=1)"));
        assert!(md.contains("T(1)/T(2)"));
    }

    #[test]
    fn sweep_json_has_config_points_and_speedups() {
        let res = result();
        let json = sweep_json(&res).render();
        assert!(json.starts_with(r#"{"model":"sir","engine":"virtual""#), "{json}");
        assert!(json.contains(r#""points":[{"size":20,"workers":1"#), "{json}");
        assert!(json.contains(r#""speedup":"#), "{json}");

        let dir = std::env::temp_dir().join("adapar_bench_json_test");
        let path = write_bench_json(&res, &dir.join("BENCH_unit.json")).unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        assert_eq!(text.trim_end(), json);
    }

    #[test]
    fn report_files_written() {
        let res = result();
        let dir = std::env::temp_dir().join("adapar_report_test");
        let csv = write_report(&res, &dir, "unit").unwrap();
        assert!(csv.exists());
        assert!(dir.join("unit.md").exists());
        let parsed = crate::util::csv::parse_csv(&std::fs::read_to_string(csv).unwrap()).unwrap();
        assert_eq!(parsed.len(), res.points.len());
    }
}
