//! Single-run dispatch: one registry lookup + one engine dispatch through
//! the [`Simulation`] facade. No per-model or per-engine matching happens
//! here — a model registered at runtime is runnable from sweeps and the
//! CLI with zero edits to this file.

use crate::api::observe::Observations;
use crate::api::{SimOutcome, Simulation};
use crate::coordinator::config::SweepConfig;
use crate::error::Result;
use crate::protocol::WorkerStats;
use crate::vtime::CostModel;

/// Outcome of one run.
#[derive(Clone, Debug)]
pub struct RunOutcome {
    /// The measured `T` in seconds (wall clock, or virtual time for the
    /// virtual engine — see `RunReport::basis`).
    pub time_s: f64,
    /// Aggregated protocol counters (zeroed for sequential/stepwise).
    pub totals: WorkerStats,
    /// High-water chain length.
    pub max_chain_len: usize,
    /// The typed observation trace (final frame only unless the sweep
    /// requested a cadence) — structurally comparable across engines.
    pub observations: Observations,
}

impl From<SimOutcome> for RunOutcome {
    fn from(out: SimOutcome) -> Self {
        RunOutcome {
            time_s: out.report.time_s,
            totals: out.report.totals,
            max_chain_len: out.report.chain.max_chain_len,
            observations: out.observable,
        }
    }
}

/// The facade invocation for one `(size, workers, seed)` point of a sweep.
pub fn simulation_for(
    cfg: &SweepConfig,
    size: usize,
    workers: usize,
    seed: u64,
    cost: &CostModel,
) -> Simulation {
    Simulation::builder()
        .model(cfg.model.clone())
        .engine(cfg.engine)
        .workers(workers)
        .tasks_per_cycle(cfg.tasks_per_cycle)
        .batch(cfg.batch)
        .seed(seed)
        .agents(cfg.agents)
        .steps(cfg.steps)
        .size(size)
        .paper_scale(cfg.paper_scale)
        .params(cfg.params.clone())
        .cost(*cost)
        .build()
}

/// Run one `(size, workers, seed)` point of a sweep. `cost` supplies the
/// virtual engine's cost model (ignored by the other engines).
pub fn run_once(
    cfg: &SweepConfig,
    size: usize,
    workers: usize,
    seed: u64,
    cost: &CostModel,
) -> Result<RunOutcome> {
    simulation_for(cfg, size, workers, seed, cost)
        .run()
        .map(RunOutcome::from)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::EngineKind;

    fn tiny(model: &str, engine: EngineKind) -> SweepConfig {
        SweepConfig {
            model: model.to_string(),
            engine,
            sizes: vec![10],
            workers: vec![2],
            seeds: vec![1],
            agents: 120,
            steps: 40,
            ..Default::default()
        }
    }

    #[test]
    fn all_models_run_on_all_legal_engines() {
        let cost = CostModel::default();
        for model in crate::api::registry::model_names() {
            for engine in [
                EngineKind::Sequential,
                EngineKind::Parallel,
                EngineKind::Virtual,
            ] {
                let cfg = tiny(&model, engine);
                let out = run_once(&cfg, 10, 2, 1, &cost)
                    .unwrap_or_else(|e| panic!("{model}/{engine}: {e}"));
                assert!(out.time_s >= 0.0);
                assert!(!out.observations.is_empty());
            }
            // Stepwise runs exactly on the models that declare a sync form.
            let cfg = tiny(&model, EngineKind::Stepwise);
            let res = run_once(&cfg, 10, 2, 1, &cost);
            let has_sync = crate::api::registry::info(&model).unwrap().has_sync_form;
            assert_eq!(res.is_ok(), has_sync, "{model} stepwise");
            // Sharded runs exactly on the models that expose a topology.
            let cfg = tiny(&model, EngineKind::Sharded);
            let res = run_once(&cfg, 10, 2, 1, &cost);
            let has_sharded = crate::api::registry::info(&model).unwrap().has_sharded_form;
            assert_eq!(res.is_ok(), has_sharded, "{model} sharded");
        }
    }

    #[test]
    fn run_once_matches_direct_facade_use() {
        let cost = CostModel::default();
        let cfg = tiny("sir", EngineKind::Sequential);
        let a = run_once(&cfg, 10, 1, 3, &cost).unwrap();
        let b = simulation_for(&cfg, 10, 1, 3, &cost).run().unwrap();
        assert_eq!(a.observations, b.observable);
    }
}
