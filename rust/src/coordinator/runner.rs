//! Single-run dispatch: build the configured model, run it on the chosen
//! engine, return timing + protocol counters + model observables.

use anyhow::Result;

use crate::coordinator::config::{EngineKind, ModelKind, SweepConfig};
use crate::models::axelrod::{AxelrodModel, AxelrodParams};
use crate::models::ising::{IsingModel, IsingParams};
use crate::models::schelling::{SchellingModel, SchellingParams};
use crate::models::sir::{SirModel, SirParams};
use crate::models::voter::{VoterModel, VoterParams};
use crate::protocol::{
    ParallelEngine, ProtocolConfig, RunReport, SequentialEngine, StepwiseEngine, WorkerStats,
};
use crate::sim::graph::ring_lattice;
use crate::vtime::{CostModel, VirtualEngine};

/// Outcome of one run.
#[derive(Clone, Debug)]
pub struct RunOutcome {
    /// The measured `T` in seconds (wall clock, or virtual time for the
    /// virtual engine).
    pub time_s: f64,
    /// Aggregated protocol counters (zeroed for sequential/stepwise).
    pub totals: WorkerStats,
    /// High-water chain length.
    pub max_chain_len: usize,
    /// Human-readable model observable (e.g. SIR census) for sanity.
    pub observable: String,
}

fn outcome_from_report(report: &RunReport, observable: String) -> RunOutcome {
    RunOutcome {
        time_s: report.wall.as_secs_f64(),
        totals: report.totals.clone(),
        max_chain_len: report.chain.max_chain_len,
        observable,
    }
}

/// Run one `(size, workers, seed)` point of a sweep. `cost` supplies the
/// virtual engine's cost model (ignored by the other engines).
pub fn run_once(
    cfg: &SweepConfig,
    size: usize,
    workers: usize,
    seed: u64,
    cost: &CostModel,
) -> Result<RunOutcome> {
    let agents = cfg.effective_agents();
    let steps = cfg.effective_steps();
    match cfg.model {
        ModelKind::Axelrod => {
            let params = AxelrodParams {
                agents,
                features: size,
                traits: 3,
                omega: 0.95,
                steps,
            };
            let model = AxelrodModel::new(params, seed ^ 0x1217);
            let obs = |m: &AxelrodModel| format!("traits[0..4]={:?}", &m.snapshot()[..4]);
            Ok(match cfg.engine {
                EngineKind::Sequential => {
                    let r = SequentialEngine::new(seed).run(&model);
                    outcome_from_report(&r, obs(&model))
                }
                EngineKind::Parallel => {
                    let r = ParallelEngine::new(ProtocolConfig {
                        workers,
                        tasks_per_cycle: cfg.tasks_per_cycle,
                        seed,
                        collect_timing: false,
                    })
                    .run(&model);
                    outcome_from_report(&r, obs(&model))
                }
                EngineKind::Virtual => {
                    let r = VirtualEngine {
                        workers,
                        tasks_per_cycle: cfg.tasks_per_cycle,
                        seed,
                        cost: *cost,
                    }
                    .run(&model);
                    RunOutcome {
                        time_s: r.virtual_time_s,
                        totals: r.totals,
                        max_chain_len: r.chain.max_chain_len,
                        observable: obs(&model),
                    }
                }
                EngineKind::Stepwise => anyhow::bail!("axelrod has no synchronous form"),
            })
        }
        ModelKind::Sir => {
            let params = SirParams {
                agents,
                subset_size: size,
                steps,
                ..SirParams::default()
            };
            let model = SirModel::new(params, seed ^ 0x51);
            let obs = |m: &SirModel| {
                let (s, i, r) = m.census();
                format!("census S={s} I={i} R={r}")
            };
            Ok(match cfg.engine {
                EngineKind::Sequential => {
                    let r = SequentialEngine::new(seed).run(&model);
                    outcome_from_report(&r, obs(&model))
                }
                EngineKind::Parallel => {
                    let r = ParallelEngine::new(ProtocolConfig {
                        workers,
                        tasks_per_cycle: cfg.tasks_per_cycle,
                        seed,
                        collect_timing: false,
                    })
                    .run(&model);
                    outcome_from_report(&r, obs(&model))
                }
                EngineKind::Virtual => {
                    let r = VirtualEngine {
                        workers,
                        tasks_per_cycle: cfg.tasks_per_cycle,
                        seed,
                        cost: *cost,
                    }
                    .run(&model);
                    RunOutcome {
                        time_s: r.virtual_time_s,
                        totals: r.totals,
                        max_chain_len: r.chain.max_chain_len,
                        observable: obs(&model),
                    }
                }
                EngineKind::Stepwise => {
                    let r = StepwiseEngine::new(workers, seed).run(&model);
                    outcome_from_report(&r, obs(&model))
                }
            })
        }
        ModelKind::Voter => {
            let model = VoterModel::new(
                ring_lattice(agents, 6),
                VoterParams {
                    opinions: 3,
                    steps,
                },
                seed ^ 0x70,
            );
            let obs = |m: &VoterModel| format!("tally={:?}", m.tally());
            run_generic(cfg, &model, workers, seed, cost, obs(&model))
        }
        ModelKind::Ising => {
            let side = (agents as f64).sqrt() as usize;
            let model = IsingModel::new(
                IsingParams {
                    side: side.max(8),
                    temperature: 2.269,
                    steps,
                },
                seed ^ 0x15,
            );
            let obs = format!("m={:+.4}", model.magnetization());
            run_generic(cfg, &model, workers, seed, cost, obs)
        }
        ModelKind::Schelling => {
            // ~78% occupancy on the smallest torus that fits `agents`.
            let side = ((agents as f64 / 0.78).sqrt().ceil() as usize).max(8);
            let model = SchellingModel::new(
                SchellingParams {
                    side,
                    agents,
                    tolerance: 0.4,
                    steps,
                },
                seed ^ 0x5C,
            );
            let out = run_generic(
                cfg,
                &model,
                workers,
                seed,
                cost,
                String::new(),
            )?;
            model
                .check_consistency()
                .map_err(|e| anyhow::anyhow!("schelling state corrupted: {e}"))?;
            Ok(RunOutcome {
                observable: format!("segregation={:.4}", model.segregation()),
                ..out
            })
        }
    }
}

fn run_generic<M: crate::model::Model>(
    cfg: &SweepConfig,
    model: &M,
    workers: usize,
    seed: u64,
    cost: &CostModel,
    observable: String,
) -> Result<RunOutcome> {
    Ok(match cfg.engine {
        EngineKind::Sequential => {
            let r = SequentialEngine::new(seed).run(model);
            outcome_from_report(&r, observable)
        }
        EngineKind::Parallel => {
            let r = ParallelEngine::new(ProtocolConfig {
                workers,
                tasks_per_cycle: cfg.tasks_per_cycle,
                seed,
                collect_timing: false,
            })
            .run(model);
            outcome_from_report(&r, observable)
        }
        EngineKind::Virtual => {
            let r = VirtualEngine {
                workers,
                tasks_per_cycle: cfg.tasks_per_cycle,
                seed,
                cost: *cost,
            }
            .run(model);
            RunOutcome {
                time_s: r.virtual_time_s,
                totals: r.totals,
                max_chain_len: r.chain.max_chain_len,
                observable,
            }
        }
        EngineKind::Stepwise => anyhow::bail!("model has no synchronous form"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(model: ModelKind, engine: EngineKind) -> SweepConfig {
        SweepConfig {
            model,
            engine,
            sizes: vec![10],
            workers: vec![2],
            seeds: vec![1],
            agents: 120,
            steps: 40,
            ..Default::default()
        }
    }

    #[test]
    fn all_models_run_on_all_legal_engines() {
        let cost = CostModel::default();
        for model in [
            ModelKind::Axelrod,
            ModelKind::Sir,
            ModelKind::Voter,
            ModelKind::Ising,
            ModelKind::Schelling,
        ] {
            for engine in [EngineKind::Sequential, EngineKind::Parallel, EngineKind::Virtual] {
                let cfg = tiny(model, engine);
                let out = run_once(&cfg, 10, 2, 1, &cost)
                    .unwrap_or_else(|e| panic!("{model}/{engine}: {e}"));
                assert!(out.time_s >= 0.0);
                assert!(!out.observable.is_empty());
            }
        }
        // Stepwise: sir only.
        let cfg = tiny(ModelKind::Sir, EngineKind::Stepwise);
        run_once(&cfg, 10, 2, 1, &cost).unwrap();
        let cfg = tiny(ModelKind::Axelrod, EngineKind::Stepwise);
        assert!(run_once(&cfg, 10, 2, 1, &cost).is_err());
    }
}
