//! Crate-local error type for the public API.
//!
//! The crate registry is offline in this build environment, so the error
//! plumbing normally pulled from `anyhow`/`thiserror` is hand-rolled here:
//! a single boxed-message error with an optional source chain, the
//! [`Context`] extension trait for `Result`/`Option`, and the
//! [`bail!`](crate::bail)/[`ensure!`](crate::ensure)/[`err!`](crate::err)
//! macros. Every public fallible API in the crate returns
//! [`crate::Result`], which is an alias for `Result<T, Error>`.

use std::fmt;

/// Crate-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// The crate error: a message plus an optional chained source.
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Build an error from a message.
    pub fn msg(msg: impl Into<String>) -> Self {
        Self {
            msg: msg.into(),
            source: None,
        }
    }

    /// Build an error from any `std::error::Error` value.
    pub fn new<E>(source: E) -> Self
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        Self {
            msg: source.to_string(),
            source: Some(Box::new(source)),
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context(self, msg: impl Into<String>) -> Self {
        Self {
            msg: msg.into(),
            source: Some(Box::new(self)),
        }
    }

    /// The full `outer: inner: ...` chain as one string.
    pub fn chain(&self) -> String {
        let mut out = self.msg.clone();
        let mut cur: Option<&(dyn std::error::Error + 'static)> = self
            .source
            .as_deref()
            .map(|e| e as &(dyn std::error::Error + 'static));
        while let Some(e) = cur {
            out.push_str(": ");
            out.push_str(&e.to_string());
            cur = e.source();
        }
        out
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` prints the whole chain, like anyhow's alternate mode.
            f.write_str(&self.chain())
        } else {
            f.write_str(&self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain())
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        self.source
            .as_deref()
            .map(|e| e as &(dyn std::error::Error + 'static))
    }
}

impl From<String> for Error {
    fn from(msg: String) -> Self {
        Error::msg(msg)
    }
}

impl From<&str> for Error {
    fn from(msg: &str) -> Self {
        Error::msg(msg)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::new(e)
    }
}

impl From<std::fmt::Error> for Error {
    fn from(e: std::fmt::Error) -> Self {
        Error::new(e)
    }
}

impl From<std::num::ParseIntError> for Error {
    fn from(e: std::num::ParseIntError) -> Self {
        Error::new(e)
    }
}

impl From<std::num::ParseFloatError> for Error {
    fn from(e: std::num::ParseFloatError) -> Self {
        Error::new(e)
    }
}

impl From<crate::util::cli::CliError> for Error {
    fn from(e: crate::util::cli::CliError) -> Self {
        Error::new(e)
    }
}

impl From<crate::util::toml::ParseError> for Error {
    fn from(e: crate::util::toml::ParseError) -> Self {
        Error::new(e)
    }
}

/// Attach context to fallible values (`Result`/`Option`), mirroring the
/// `anyhow::Context` surface the crate used to rely on.
pub trait Context<T> {
    /// Wrap the error with a fixed message.
    fn context(self, msg: impl Into<String>) -> Result<T>;
    /// Wrap the error with a lazily-built message.
    fn with_context<S: Into<String>, F: FnOnce() -> S>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for Result<T, E>
where
    E: Into<Error>,
{
    fn context(self, msg: impl Into<String>) -> Result<T> {
        self.map_err(|e| e.into().context(msg))
    }

    fn with_context<S: Into<String>, F: FnOnce() -> S>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl Into<String>) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg))
    }

    fn with_context<S: Into<String>, F: FnOnce() -> S>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::err!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn context_chains_and_alternate_prints_chain() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading config")
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: gone");
        assert!(std::error::Error::source(&e).is_some());
    }

    #[test]
    fn option_context() {
        let e = None::<u32>.with_context(|| "missing key").unwrap_err();
        assert_eq!(e.to_string(), "missing key");
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u32) -> crate::Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("unlucky");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(7).unwrap_err().to_string(), "unlucky");
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
    }
}
