//! # adapar — Adaptive parallelization of multi-agent simulations
//!
//! Rust + JAX + Pallas reproduction of Băbeanu, Filatova, Kwakkel,
//! Yorke-Smith, *"Adaptive parallelization of multi-agent simulations with
//! localized dynamics"* (2023).
//!
//! The library implements the paper's **worker–chain protocol** for
//! shared-memory, adaptive, asynchronous parallel execution of multi-agent
//! based simulations (MABS), together with every substrate the evaluation
//! depends on:
//!
//! * [`chain`] — the task chain: a lock-coupled doubly-linked list with
//!   head/tail sentinels, per-task occupancy + link locks, and an erase
//!   lock — stored in an index-based node arena with generation-tagged
//!   handles, slot recycling (steady-state execution allocates nothing)
//!   and batched task creation (`--batch`).
//! * [`model`] — the model plug-in interface: [`model::Recipe`],
//!   [`model::Record`], [`model::TaskSource`] (the paper's *recipe* /
//!   *record* concepts, §3.5).
//! * [`protocol`] — the engines: the adaptive [`protocol::ParallelEngine`]
//!   (the paper's contribution), the [`protocol::SequentialEngine`] ground
//!   truth, and the related-work [`protocol::StepwiseEngine`] barrier
//!   baseline.
//! * [`models`] — MABS models: Axelrod cultural dynamics (§4.1), SIR
//!   disease spreading (§4.2), plus voter and Ising models exercising the
//!   same interface.
//! * [`sim`] — simulation substrates: deterministic RNG streams, CSR
//!   graphs + generators + partitions + aggregate graphs, shared state,
//!   and the bit-packed SoA state layer with locality relabeling
//!   ([`sim::soa`], DESIGN.md §13).
//! * [`vtime`] — the virtual-core testbed: a deterministic discrete-event
//!   simulation of the protocol with a calibrated cost model (reproduces
//!   the paper's multi-core figures on a single-core host).
//! * [`sched`] — the sharded adaptive scheduler: per-shard chains over a
//!   BFS edge-cut partition of the model's footprint topology, a
//!   spillover chain with dependence-preserving fences for cross-shard
//!   tasks, and an EWMA-cost-driven rebalancer migrating blocks between
//!   shards at epoch boundaries (`--engine sharded`).
//! * [`runtime`] — PJRT/XLA runtime loading the AOT-compiled JAX+Pallas
//!   artifacts (`artifacts/*.hlo.txt`) and an XLA-backed task-execution
//!   engine.
//! * [`api`] — the public execution API: the object-safe [`Engine`]
//!   trait over interchangeable backends, the dynamic model
//!   [`api::registry`] (name + parameter bag → runnable model), the typed
//!   observation pipeline ([`api::observe`]: named metrics, deterministic
//!   epoch snapshots, CSV/JSON-lines sinks), and the builder-style
//!   [`Simulation`] facade — the single entry point used by the CLI,
//!   sweeps, benches and examples.
//! * [`telemetry`] — the always-on metrics core: a [`MetricsRegistry`]
//!   of named instruments, per-worker SPSC sample rings drained by a
//!   background aggregator into mergeable percentile histograms, and
//!   the [`TelemetrySnapshot`] every engine attaches to its report —
//!   semantically inert by construction (DESIGN.md §11).
//! * [`trace`] — causal task tracing: opt-in per-worker timeline spans
//!   with task/block/shard ids and causal edges (footprint order, fence
//!   releases), collected through SPSC rings into a background
//!   aggregator, exported as Chrome/Perfetto `trace_event` JSON
//!   (`--trace`) and replayed by the critical-path analyzer
//!   (`cli trace-analyze`: T1, T∞, per-epoch speedup bounds, gap
//!   attribution) — semantically inert like telemetry (DESIGN.md §12).
//! * [`chaos`] — the deterministic chaos harness: seeded declarative
//!   fault plans (stalls, cost skews, jitter, fence delays) injected at
//!   epoch boundaries, invariant checkers against the sequential
//!   oracle, and a seed-sweep soak runner with ddmin shrinking of
//!   failures to committable repro TOMLs (`cli soak`).
//! * [`coordinator`] — experiment orchestration: config system, sweep grid
//!   runner, reports.
//! * [`error`] — the crate-local error type ([`Error`]/[`Result`]) every
//!   public fallible API returns.
//! * [`util`] — hand-rolled substrates (the crate registry is offline):
//!   CLI args, bench harness, TOML-subset config parser, property-testing
//!   mini-framework, statistics.
//!
//! See `DESIGN.md` for the full system inventory and `EXPERIMENTS.md` for
//! paper-vs-measured results.

pub mod api;
pub mod chain;
pub mod chaos;
pub mod cli;
pub mod coordinator;
pub mod error;
pub mod model;
pub mod models;
pub mod protocol;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod telemetry;
pub mod trace;
pub mod util;
pub mod vtime;

pub use api::{
    engine_for, BuildCtx, DynModel, Engine, EngineKind, ModelInfo, ObsFrame, ObsValue, Observable,
    Observations, ObservePlan, Observer, Params, Registry, Runnable, SimOutcome, Simulation,
    SimulationBuilder,
};
pub use error::{Context, Error};
pub use sched::{PartitionHint, PartitionPolicy, ShardableModel, ShardedConfig, ShardedEngine};
pub use sim::soa::{Layout, PackedStates, Relabeling};
pub use telemetry::{MetricsRegistry, TelemetryMode, TelemetrySnapshot};
pub use trace::{Trace, TraceCore, TraceHandle, TraceMode};

/// Crate-wide result type.
pub type Result<T> = error::Result<T>;
