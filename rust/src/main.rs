//! `adapar` CLI entrypoint. See `cli` module for the command surface.

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = adapar::cli::main_with_args(raw) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
