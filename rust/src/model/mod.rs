//! The model plug-in interface — the paper's *recipe* / *record* concepts
//! (§3.5).
//!
//! > "The interface can be understood in terms of two generic concepts:
//! > 1. recipe: model-side counterpart of the task; 2. record: model-side
//! > counterpart of the worker."
//!
//! A MABS plugs into the protocol by providing:
//!
//! * a **recipe** type — the information a task holds after creation and
//!   needs for execution (e.g. the two interacting agents' ids);
//! * a **record** type ([`Record`]) — the information a worker accumulates
//!   while iterating the chain, with the procedure for deciding whether the
//!   task at hand depends on any previously-encountered task;
//! * a **task source** ([`TaskSource`]) — the "global, model-specific
//!   routine" (§3.3) that creates the next task; invoked serially under the
//!   chain's tail lock, so it may hold the creation RNG stream and step
//!   counters without further synchronization;
//! * an **executor** ([`Model::execute`]) — carries out a task's
//!   operations, mutating shared simulation state. Execution randomness
//!   must come exclusively from the per-task stream derived from
//!   `(seed, task_seq)` so that parallel execution is bit-identical to
//!   sequential execution (DESIGN.md §6).
//!
//! ## Task depth (§3.4)
//!
//! The creation/execution split ("task depth") is expressed by how much
//! work [`TaskSource::next_task`] performs versus [`Model::execute`]: both
//! experiments in the paper perform selection/indexing at creation and the
//! bulk of the computation at execution, and the bundled models follow
//! suit.

pub mod stream;
pub mod testkit;

pub use stream::{RetireHandle, StreamingSource, Window, DEFAULT_WINDOW};

use crate::sim::rng::TaskRng;

/// Marker bounds for recipe payloads. Recipes are immutable after creation
/// and shared read-only between workers (absorption reads them while the
/// executing worker may be running the task).
pub trait Recipe: Clone + std::fmt::Debug + Send + Sync + 'static {}
impl<T: Clone + std::fmt::Debug + Send + Sync + 'static> Recipe for T {}

/// Per-worker dependence bookkeeping — the paper's *record*.
///
/// Implementations must be **conservative**: if the execution of a task
/// with recipe `r` could read state written by — or write state read or
/// written by — any absorbed task, `depends` must return `true`.
pub trait Record: Send {
    /// The recipe type this record understands.
    type Recipe: Recipe;

    /// Does a task with recipe `r` depend on any absorbed task?
    fn depends(&self, r: &Self::Recipe) -> bool;

    /// Integrate a passed (incomplete) task's information.
    fn absorb(&mut self, r: &Self::Recipe);

    /// Reset at the start of a new cycle. Must not allocate at steady
    /// state (called once per cycle on the hot path).
    fn reset(&mut self);
}

/// The global task-creation routine — invoked by at most one worker at a
/// time (under the chain's tail lock), hence `&mut self`.
pub trait TaskSource: Send {
    /// The recipe type produced.
    type Recipe: Recipe;

    /// Create the next task, or `None` when the simulation is complete.
    /// The implementation owns the creation RNG stream; successive calls
    /// define the canonical (sequential) task order.
    fn next_task(&mut self) -> Option<Self::Recipe>;

    /// Create up to `max` tasks in one call, pushing them onto `buf` in
    /// canonical order; returns how many were produced. The chain
    /// engines use this to link a whole batch under a single tail-lock
    /// acquisition ([`Chain::fill_tail`](crate::chain::Chain::fill_tail)).
    ///
    /// Producing fewer than `max` means the source — or, for epoch-gated
    /// sources, the current epoch's budget — is exhausted *for now*;
    /// batches therefore never cross an epoch boundary.
    ///
    /// The provided implementation drains [`next_task`]
    /// (every bundled source uses it); overrides must be observationally
    /// identical — same tasks, same order, same internal RNG draws — so
    /// that the canonical task order is independent of the batch size
    /// (DESIGN.md §3).
    ///
    /// [`next_task`]: TaskSource::next_task
    fn next_batch(&mut self, buf: &mut Vec<Self::Recipe>, max: usize) -> usize {
        let mut produced = 0;
        while produced < max {
            match self.next_task() {
                Some(recipe) => {
                    buf.push(recipe);
                    produced += 1;
                }
                None => break,
            }
        }
        produced
    }

    /// Optional hint: number of tasks this source will still produce, if
    /// known. The observation pipeline uses it to pre-size epoch traces
    /// and the CLI progress line; the chain engines use it (together
    /// with `DynModel::task_count_hint`) to pre-size the node arena.
    /// Callers must degrade gracefully on `None`.
    fn size_hint(&self) -> Option<u64> {
        None
    }

    /// Whether the last `None` from [`next_task`](TaskSource::next_task)
    /// was a **temporary** streaming-window stall rather than true
    /// exhaustion: room reappears once outstanding tasks retire, so the
    /// caller should keep cycling instead of latching end-of-source.
    /// Plain sources never stall (the default); the windowed adapters
    /// ([`StreamingSource`], the engines' `EpochGate`) override this.
    fn stalled(&self) -> bool {
        false
    }

    /// Clamp this source to a bounded materialization [`Window`]
    /// (ISSUE 10): the returned adapter emits the same tasks in the
    /// same canonical order, but `next_task` yields `None` — a
    /// *temporary* stall, see [`stalled`](TaskSource::stalled) —
    /// whenever `emitted - retired` would exceed the window cap.
    fn stream(self, window: Window) -> StreamingSource<Self>
    where
        Self: Sized,
    {
        StreamingSource::new(self, window)
    }
}

/// A MABS model pluggable into every engine (parallel, sequential,
/// virtual-time).
///
/// The model owns its shared state (via `sim::state::SharedSim` internally)
/// and is shared by reference across workers; hence `Sync`.
pub trait Model: Send + Sync + 'static {
    /// Task payload type.
    type Recipe: Recipe;
    /// Worker record type.
    type Record: Record<Recipe = Self::Recipe>;
    /// Task source type.
    type Source: TaskSource<Recipe = Self::Recipe>;

    /// Construct the task source for a run with the given seed.
    fn source(&self, seed: u64) -> Self::Source;

    /// Construct a fresh (empty) worker record.
    fn record(&self) -> Self::Record;

    /// Execute a task.
    ///
    /// `rng` is the task's private execution stream (already derived from
    /// `(seed, task_seq)` by the engine); implementations must draw all
    /// execution randomness from it.
    ///
    /// # Contract
    /// May mutate shared state only within the task's conservative write
    /// footprint (the one `Self::Record` protects), and read only within
    /// its read footprint. The engines guarantee no conflicting task runs
    /// concurrently.
    fn execute(&self, recipe: &Self::Recipe, rng: &mut TaskRng);

    /// Relative execution cost of a task, in abstract *work units*
    /// proportional to basic operations (used by the virtual-core testbed's
    /// calibrated cost model; see `vtime::CostModel`). The default treats
    /// all tasks as unit cost.
    fn task_work(&self, _recipe: &Self::Recipe) -> f64 {
        1.0
    }

    /// Average agent-*state* bytes one task reads + writes under the
    /// model's current storage layout (DESIGN.md §13). Structural — a
    /// fixed property of (layout, parameters), never measured on the hot
    /// path — and feeds the `chain.bytes_per_task` instrument and the
    /// packed-vs-legacy bench gate. The default (0) opts a model out of
    /// the byte accounting.
    fn state_bytes_per_task(&self) -> f64 {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // A trivially small model used to sanity-check the trait surface: a
    // counter model where task i increments cell (i % cells).
    pub struct CounterModel {
        pub cells: crate::sim::state::SharedSim<Vec<u64>>,
        pub tasks: u64,
    }

    #[derive(Clone, Debug)]
    pub struct CounterRecipe {
        pub cell: u32,
    }

    pub struct CounterRecord {
        seen: crate::util::u32set::U32Set,
    }

    impl Record for CounterRecord {
        type Recipe = CounterRecipe;
        fn depends(&self, r: &CounterRecipe) -> bool {
            self.seen.contains(r.cell)
        }
        fn absorb(&mut self, r: &CounterRecipe) {
            self.seen.insert(r.cell);
        }
        fn reset(&mut self) {
            self.seen.clear();
        }
    }

    pub struct CounterSource {
        next: u64,
        tasks: u64,
        cells: u32,
    }

    impl TaskSource for CounterSource {
        type Recipe = CounterRecipe;
        fn next_task(&mut self) -> Option<CounterRecipe> {
            if self.next >= self.tasks {
                return None;
            }
            let cell = (self.next % self.cells as u64) as u32;
            self.next += 1;
            Some(CounterRecipe { cell })
        }
        fn size_hint(&self) -> Option<u64> {
            Some(self.tasks)
        }
    }

    impl Model for CounterModel {
        type Recipe = CounterRecipe;
        type Record = CounterRecord;
        type Source = CounterSource;
        fn source(&self, _seed: u64) -> CounterSource {
            let cells = unsafe { self.cells.get() }.len() as u32;
            CounterSource {
                next: 0,
                tasks: self.tasks,
                cells,
            }
        }
        fn record(&self) -> CounterRecord {
            CounterRecord {
                seen: Default::default(),
            }
        }
        fn execute(&self, recipe: &CounterRecipe, _rng: &mut TaskRng) {
            unsafe {
                self.cells.get_mut()[recipe.cell as usize] += 1;
            }
        }
    }

    #[test]
    fn counter_model_sequential_semantics() {
        let m = CounterModel {
            cells: crate::sim::state::SharedSim::new(vec![0; 4]),
            tasks: 10,
        };
        let mut src = m.source(0);
        let mut seq = 0u64;
        while let Some(r) = src.next_task() {
            let mut rng = TaskRng::for_task(0, seq);
            m.execute(&r, &mut rng);
            seq += 1;
        }
        assert_eq!(seq, 10);
        assert_eq!(m.cells.into_inner(), vec![3, 3, 2, 2]);
    }

    #[test]
    fn next_batch_drains_in_canonical_order() {
        let m = CounterModel {
            cells: crate::sim::state::SharedSim::new(vec![0; 4]),
            tasks: 10,
        };
        let mut src = m.source(0);
        let mut buf = Vec::new();
        assert_eq!(src.next_batch(&mut buf, 4), 4);
        assert_eq!(src.next_batch(&mut buf, 4), 4);
        assert_eq!(src.next_batch(&mut buf, 4), 2, "short batch at exhaustion");
        assert_eq!(src.next_batch(&mut buf, 4), 0);
        let cells: Vec<u32> = buf.iter().map(|r| r.cell).collect();
        let want: Vec<u32> = (0..10u32).map(|i| i % 4).collect();
        assert_eq!(cells, want, "batching must preserve the canonical order");
    }

    #[test]
    fn record_conservativeness() {
        let m = CounterModel {
            cells: crate::sim::state::SharedSim::new(vec![0; 4]),
            tasks: 4,
        };
        let mut rec = m.record();
        let a = CounterRecipe { cell: 1 };
        let b = CounterRecipe { cell: 2 };
        assert!(!rec.depends(&a));
        rec.absorb(&a);
        assert!(rec.depends(&a), "same cell conflicts");
        assert!(!rec.depends(&b), "distinct cells commute");
        rec.reset();
        assert!(!rec.depends(&a));
    }
}
