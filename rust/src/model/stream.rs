//! Streaming task sources (ISSUE 10): a bounded **window** over any
//! [`TaskSource`] so an engine materializes at most `W` outstanding
//! tasks instead of a whole epoch.
//!
//! ## The window contract
//!
//! A [`Window`] tracks two monotone counters: `emitted` (tasks drawn
//! from the source, owned by the draining side) and `retired` (tasks
//! whose chain node has been erased, bumped through a [`RetireHandle`]
//! by whichever worker performs the erase). The *outstanding* count is
//! `emitted - retired`; the window **has room** while it is below the
//! cap. Draining stops — temporarily — when the window is full, and
//! resumes as soon as executions retire tasks.
//!
//! Crucially, windowing changes only *when* tasks are materialized,
//! never *which* tasks exist or in what canonical order: the underlying
//! source is still drawn strictly in creation order, sequence numbers
//! and per-task RNG streams are untouched, and epoch boundaries still
//! happen only at true budget/exhaustion points. Observation traces are
//! therefore byte-identical to the materialized path (DESIGN.md §14).
//!
//! `retired` is read with `Relaxed` ordering: a stale (low) read makes
//! the window look *fuller* than it is, which can only delay draining —
//! the cap is never overshot, so the memory bound is unconditional.
//!
//! ## Two consumers
//!
//! * The engines window their [`EpochGate`](crate::api::observe::EpochGate)
//!   directly (`set_window`), because the gate must distinguish a
//!   *temporary* window stall from true source exhaustion.
//! * [`StreamingSource`] is the standalone adapter for tests and
//!   embedders driving a source by hand. **Warning:** its `next_task`
//!   returns `None` while the window is full; callers that treat `None`
//!   as permanent exhaustion (the `EpochGate` constructor among them)
//!   must not wrap a `StreamingSource` — check
//!   [`stalled`](TaskSource::stalled) instead.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::TaskSource;

/// Default window size when streaming is enabled without an explicit
/// width (`ADAPAR_STREAMING=1`, or `--streaming` on the CLI). Large
/// enough that every worker keeps a full creation batch in flight at
/// default `C`/`B`, small enough to bound the arena far below any
/// million-task workload.
pub const DEFAULT_WINDOW: u64 = 4096;

/// Resolve the facade's default window from the environment:
/// `ADAPAR_WINDOW=<n>` pins an explicit width (0 = materialized),
/// otherwise `ADAPAR_STREAMING` ∈ {1, on, true, yes} selects
/// [`DEFAULT_WINDOW`]. Unset ⇒ 0 (materialized).
pub fn env_window() -> u64 {
    if let Ok(v) = std::env::var("ADAPAR_WINDOW") {
        if let Ok(w) = v.trim().parse::<u64>() {
            return w;
        }
    }
    match std::env::var("ADAPAR_STREAMING") {
        Ok(v) if matches!(v.trim(), "1" | "on" | "true" | "yes") => DEFAULT_WINDOW,
        _ => 0,
    }
}

/// A bounded materialization window: cap plus the shared retirement
/// counter. Cloning shares the counter (all clones describe the same
/// window).
#[derive(Clone, Debug)]
pub struct Window {
    cap: u64,
    retired: Arc<AtomicU64>,
}

impl Window {
    /// A window admitting at most `cap ≥ 1` outstanding tasks.
    pub fn new(cap: u64) -> Self {
        assert!(cap >= 1, "window cap must be at least 1");
        Self {
            cap,
            retired: Arc::new(AtomicU64::new(0)),
        }
    }

    /// The cap.
    #[inline]
    pub fn cap(&self) -> u64 {
        self.cap
    }

    /// Tasks retired so far.
    #[inline]
    pub fn retired(&self) -> u64 {
        self.retired.load(Ordering::Relaxed)
    }

    /// Whether a source that has emitted `emitted` tasks may emit one
    /// more. Conservative under concurrent retirement (see module docs).
    #[inline]
    pub fn has_room(&self, emitted: u64) -> bool {
        emitted.saturating_sub(self.retired()) < self.cap
    }

    /// A cloneable handle workers use to report erased tasks.
    #[inline]
    pub fn handle(&self) -> RetireHandle {
        RetireHandle(Arc::clone(&self.retired))
    }
}

/// Shared retirement counter handle: bump once per erased task.
#[derive(Clone, Debug)]
pub struct RetireHandle(Arc<AtomicU64>);

impl RetireHandle {
    /// Report `n` erased tasks.
    #[inline]
    pub fn retire(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
}

/// A [`TaskSource`] adapter that clamps materialization to a window.
///
/// `next_task` returns `None` both when the window is (temporarily)
/// full and when the inner source is exhausted; disambiguate with
/// [`stalled`](TaskSource::stalled). Canonical order and the emitted
/// task sequence are exactly the inner source's.
#[derive(Debug)]
pub struct StreamingSource<S: TaskSource> {
    inner: S,
    window: Window,
    emitted: u64,
    inner_done: bool,
}

impl<S: TaskSource> StreamingSource<S> {
    /// Wrap `inner` in `window`.
    pub fn new(inner: S, window: Window) -> Self {
        Self {
            inner,
            window,
            emitted: 0,
            inner_done: false,
        }
    }

    /// Tasks emitted so far.
    #[inline]
    pub fn emitted(&self) -> u64 {
        self.emitted
    }

    /// The window (share its [`RetireHandle`] with the executing side).
    #[inline]
    pub fn window(&self) -> &Window {
        &self.window
    }

    /// Shorthand for `self.window().handle()`.
    #[inline]
    pub fn retire_handle(&self) -> RetireHandle {
        self.window.handle()
    }
}

impl<S: TaskSource> TaskSource for StreamingSource<S> {
    type Recipe = S::Recipe;

    fn next_task(&mut self) -> Option<Self::Recipe> {
        if self.inner_done || !self.window.has_room(self.emitted) {
            return None;
        }
        match self.inner.next_task() {
            Some(r) => {
                self.emitted += 1;
                Some(r)
            }
            None => {
                self.inner_done = true;
                None
            }
        }
    }

    fn size_hint(&self) -> Option<u64> {
        self.inner.size_hint()
    }

    /// A *temporary* stall: the window is full but the inner source can
    /// still produce.
    fn stalled(&self) -> bool {
        !self.inner_done && !self.window.has_room(self.emitted)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Seq {
        next: u64,
        total: u64,
    }

    impl TaskSource for Seq {
        type Recipe = u64;
        fn next_task(&mut self) -> Option<u64> {
            (self.next < self.total).then(|| {
                let v = self.next;
                self.next += 1;
                v
            })
        }
        fn size_hint(&self) -> Option<u64> {
            Some(self.total - self.next)
        }
    }

    #[test]
    fn window_clamps_outstanding_and_reopens_on_retire() {
        let mut s = Seq { next: 0, total: 10 }.stream(Window::new(3));
        let handle = s.retire_handle();
        assert_eq!(s.next_task(), Some(0));
        assert_eq!(s.next_task(), Some(1));
        assert_eq!(s.next_task(), Some(2));
        assert_eq!(s.next_task(), None, "window full");
        assert!(s.stalled());
        handle.retire(2);
        assert_eq!(s.next_task(), Some(3));
        assert_eq!(s.next_task(), Some(4));
        assert_eq!(s.next_task(), None);
        assert!(s.stalled());
    }

    #[test]
    fn exhaustion_is_not_a_stall() {
        let mut s = Seq { next: 0, total: 2 }.stream(Window::new(8));
        assert_eq!(s.next_task(), Some(0));
        assert_eq!(s.next_task(), Some(1));
        assert_eq!(s.next_task(), None);
        assert!(!s.stalled(), "true exhaustion");
    }

    #[test]
    fn full_drain_preserves_the_sequence() {
        let mut s = Seq { next: 0, total: 100 }.stream(Window::new(1));
        let handle = s.retire_handle();
        let mut got = Vec::new();
        while got.len() < 100 {
            match s.next_task() {
                Some(v) => got.push(v),
                None => {
                    assert!(s.stalled());
                    handle.retire(1);
                }
            }
        }
        assert_eq!(s.next_task(), None);
        assert!(!s.stalled());
        assert_eq!(got, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn env_window_resolution() {
        // Uses the documented precedence without touching process env
        // (other tests run in parallel): just pin the constant.
        assert!(DEFAULT_WINDOW >= 1);
    }
}
