//! Tiny models for tests, property tests, and protocol microbenches.
//!
//! Not part of the scientific surface — these exist so the protocol can be
//! exercised against workloads with precisely controlled conflict
//! structure (something the real MABS models cannot offer).

use crate::model::{Model, Record, TaskSource};
use crate::sim::rng::{Rng, TaskRng};
use crate::sim::state::SharedSim;
use crate::util::u32set::U32Set;

/// Worker counts for the determinism/conformance matrices: all of 1/2/4,
/// or the single count pinned by `ADAPAR_SHARDED_WORKERS` (the CI matrix
/// jobs set it so each runner covers one count). Shared by
/// `rust/tests/sharded.rs` and `rust/tests/conformance.rs` so the pinning
/// contract lives in one place.
pub fn env_worker_counts() -> Vec<usize> {
    match std::env::var("ADAPAR_SHARDED_WORKERS") {
        Ok(v) => vec![v.parse().expect("ADAPAR_SHARDED_WORKERS must be a number")],
        Err(_) => vec![1, 2, 4],
    }
}

/// Creation batch sizes `B` for the conformance matrix: both extremes
/// (`1` = the classic unbatched protocol, `64` = deep batching), or the
/// single size pinned by `ADAPAR_BATCH` (the CI matrix jobs set it so
/// each runner covers one size). Shared by `rust/tests/conformance.rs`
/// and `rust/tests/chain.rs`.
pub fn env_batches() -> Vec<u32> {
    match std::env::var("ADAPAR_BATCH") {
        Ok(v) => vec![v.parse().expect("ADAPAR_BATCH must be a number")],
        Err(_) => vec![1, 64],
    }
}

/// Streaming windows `W` for the conformance matrix: materialized (`0`),
/// the degenerate one-task window, an awkward prime, and a deep window —
/// or the windows pinned by `ADAPAR_STREAM_WINDOWS` (comma list; the CI
/// matrix jobs set it so each runner covers a subset). The window is
/// semantically inert back-pressure (ISSUE 10, DESIGN.md §14), so every
/// window must leave every observation trace byte-identical — this axis
/// is the test of that claim. Shared by `rust/tests/conformance.rs` and
/// `rust/tests/stream.rs`.
pub fn env_stream_windows() -> Vec<u64> {
    match std::env::var("ADAPAR_STREAM_WINDOWS") {
        Ok(v) => v
            .split(',')
            .filter(|s| !s.trim().is_empty())
            .map(|s| {
                s.trim()
                    .parse()
                    .expect("ADAPAR_STREAM_WINDOWS must list window sizes (0 = materialized)")
            })
            .collect(),
        Err(_) => vec![0, 1, 7, 64],
    }
}

/// Telemetry modes for the conformance matrix: all three (sampling on,
/// off, and saturated 4-slot rings), or the single mode pinned by
/// `ADAPAR_TELEMETRY_MODES`. Telemetry is semantically inert, so every
/// mode must leave every trace byte-identical — this axis is the test of
/// that claim. Shared by `rust/tests/conformance.rs` and
/// `rust/tests/telemetry.rs`.
pub fn env_telemetry_modes() -> Vec<crate::telemetry::TelemetryMode> {
    use crate::telemetry::TelemetryMode;
    match std::env::var("ADAPAR_TELEMETRY_MODES") {
        Ok(v) => v
            .split(',')
            .filter(|s| !s.trim().is_empty())
            .map(|s| {
                s.trim()
                    .parse()
                    .expect("ADAPAR_TELEMETRY_MODES must list on|off|saturate")
            })
            .collect(),
        Err(_) => vec![
            TelemetryMode::On,
            TelemetryMode::Off,
            TelemetryMode::Saturated,
        ],
    }
}

/// Trace modes for the conformance matrix: all three (off, spans-only,
/// full causal recording), or the modes pinned by `ADAPAR_TRACE_MODES`
/// (comma list). Causal tracing is semantically inert, so every mode
/// must leave every observation trace byte-identical — this axis is the
/// test of that claim. Shared by `rust/tests/conformance.rs` and
/// `rust/tests/trace.rs`.
pub fn env_trace_modes() -> Vec<crate::trace::TraceMode> {
    use crate::trace::TraceMode;
    match std::env::var("ADAPAR_TRACE_MODES") {
        Ok(v) => v
            .split(',')
            .filter(|s| !s.trim().is_empty())
            .map(|s| {
                s.trim()
                    .parse()
                    .expect("ADAPAR_TRACE_MODES must list off|spans|full")
            })
            .collect(),
        Err(_) => vec![TraceMode::Off, TraceMode::Spans, TraceMode::Full],
    }
}

/// State layouts for the conformance matrix: all three (legacy AoS,
/// bit-packed SoA with locality relabeling, bit-packed linear), or the
/// layouts pinned by `ADAPAR_LAYOUTS` (comma list — the CI matrix jobs
/// set it so each runner covers a subset). The layout is semantically
/// inert storage, so every layout must leave every observation trace
/// byte-identical — this axis is the test of that claim. Shared by
/// `rust/tests/conformance.rs` and `rust/tests/soa.rs`.
pub fn env_layouts() -> Vec<crate::sim::soa::Layout> {
    use crate::sim::soa::Layout;
    match std::env::var("ADAPAR_LAYOUTS") {
        Ok(v) => v
            .split(',')
            .filter(|s| !s.trim().is_empty())
            .map(|s| {
                s.trim()
                    .parse()
                    .expect("ADAPAR_LAYOUTS must list legacy|packed|packed-linear")
            })
            .collect(),
        Err(_) => Layout::ALL.to_vec(),
    }
}

/// Seed count for soak sweeps: the full-depth default, or the count
/// pinned by `ADAPAR_SOAK_SEEDS` (PR-gate CI sets a small value so the
/// chaos sweep stays fast; the nightly soak job leaves it unset and
/// passes `--seeds 32` to `cli soak` instead). Shared by
/// `rust/tests/chaos.rs` and `cli soak`.
pub fn env_soak_seeds(default: u64) -> u64 {
    match std::env::var("ADAPAR_SOAK_SEEDS") {
        Ok(v) => v.parse().expect("ADAPAR_SOAK_SEEDS must be a number"),
        Err(_) => default,
    }
}

/// Random-increment model: each task touches one cell chosen by the
/// creation stream and applies a non-commutative update derived from the
/// task stream. Two tasks conflict iff they touch the same cell, so
/// `n_cells` dials the conflict density (1 = fully sequential,
/// large = almost embarrassingly parallel).
pub struct IncModel {
    /// Cell array (shared state).
    pub cells: SharedSim<Vec<u64>>,
    /// Number of cells (conflict knob).
    pub n_cells: u32,
    /// Number of tasks to generate.
    pub tasks: u64,
    /// Extra per-task busy work (iterations of a mixing loop), to emulate
    /// heavier task bodies in scheduling tests.
    pub work: u32,
}

impl IncModel {
    /// Fresh model with zeroed cells and no extra busy work.
    pub fn new(tasks: u64, n_cells: u32) -> Self {
        Self {
            cells: SharedSim::new(vec![0; n_cells as usize]),
            n_cells,
            tasks,
            work: 0,
        }
    }

    /// Fresh model with `work` units of artificial per-task computation.
    pub fn with_work(tasks: u64, n_cells: u32, work: u32) -> Self {
        Self {
            work,
            ..Self::new(tasks, n_cells)
        }
    }

    /// Snapshot the cell array (requires no concurrent run).
    pub fn cells_snapshot(&self) -> Vec<u64> {
        unsafe { self.cells.get() }.clone()
    }
}

/// Recipe: the single cell a task reads and writes.
#[derive(Clone, Debug)]
pub struct IncRecipe {
    /// Target cell.
    pub cell: u32,
}

/// Record: set of cells touched by absorbed tasks.
pub struct IncRecord {
    seen: U32Set,
}

impl Record for IncRecord {
    type Recipe = IncRecipe;
    fn depends(&self, r: &IncRecipe) -> bool {
        self.seen.contains(r.cell)
    }
    fn absorb(&mut self, r: &IncRecipe) {
        self.seen.insert(r.cell);
    }
    fn reset(&mut self) {
        self.seen.clear();
    }
}

/// Source: draws uniformly random cells from the creation stream.
pub struct IncSource {
    rng: Rng,
    left: u64,
    n_cells: u32,
}

impl TaskSource for IncSource {
    type Recipe = IncRecipe;
    fn next_task(&mut self) -> Option<IncRecipe> {
        if self.left == 0 {
            return None;
        }
        self.left -= 1;
        Some(IncRecipe {
            cell: self.rng.below(self.n_cells as u64) as u32,
        })
    }
    fn size_hint(&self) -> Option<u64> {
        Some(self.left)
    }
}

impl Model for IncModel {
    type Recipe = IncRecipe;
    type Record = IncRecord;
    type Source = IncSource;

    fn source(&self, seed: u64) -> IncSource {
        IncSource {
            rng: Rng::stream(seed, 0xC0FFEE),
            left: self.tasks,
            n_cells: self.n_cells,
        }
    }

    fn record(&self) -> IncRecord {
        IncRecord { seen: U32Set::new() }
    }

    fn execute(&self, r: &IncRecipe, rng: &mut TaskRng) {
        let mut v = rng.below(1000);
        // Optional busy work: data-dependent mixing the optimizer cannot
        // remove, emulating a task body of tunable size.
        for _ in 0..self.work {
            v = v.wrapping_mul(0x9E37_79B9_7F4A_7C15).rotate_left(17) ^ 0xA5A5;
        }
        unsafe {
            let cells = self.cells.get_mut();
            // Non-commutative read-modify-write: racing or reordered
            // conflicting executions change the result, so determinism
            // tests detect protocol violations.
            let old = cells[r.cell as usize];
            cells[r.cell as usize] = old.wrapping_add(v).wrapping_mul(3);
        }
    }

    fn task_work(&self, _r: &IncRecipe) -> f64 {
        1.0 + self.work as f64
    }
}

impl crate::sched::ShardableModel for IncModel {
    /// Cells are independent (conflicts are same-cell only), so the
    /// topology is edgeless: the BFS partitioner falls back to contiguous
    /// index ranges and every task is shard-local.
    fn sched_topology(&self) -> crate::sim::graph::Csr {
        crate::sim::graph::Csr::from_edges(self.n_cells as usize, &[])
    }

    fn footprint(&self, r: &IncRecipe, out: &mut Vec<u32>) {
        out.push(r.cell);
    }
}

/// Stall-schedule model: an [`IncModel`]-style cell updater whose
/// *declared* per-task cost (`task_work`) cycles through a configurable
/// schedule in creation order. The chaos harness and cost-model tests
/// use it to feed the EWMA probes known-extreme distributions (zero-cost
/// tasks, 1000× skew, alternating spikes) without touching wall time —
/// the cost is declarative, the body stays O(1).
pub struct StallModel {
    inner: IncModel,
    /// Cost schedule; task `i` (creation order) declares
    /// `costs[i % costs.len()]`.
    pub costs: Vec<f64>,
}

impl StallModel {
    /// Fresh model over `n_cells` cells with the given cost schedule.
    /// An empty schedule means unit cost everywhere.
    pub fn new(tasks: u64, n_cells: u32, costs: Vec<f64>) -> Self {
        Self {
            inner: IncModel::new(tasks, n_cells),
            costs,
        }
    }

    /// Snapshot the cell array (requires no concurrent run).
    pub fn cells_snapshot(&self) -> Vec<u64> {
        self.inner.cells_snapshot()
    }

    /// The cost task `seq` declares.
    pub fn cost_at(&self, seq: u64) -> f64 {
        if self.costs.is_empty() {
            1.0
        } else {
            self.costs[(seq % self.costs.len() as u64) as usize]
        }
    }
}

/// Recipe: target cell plus the creation-order sequence number that
/// pins the task's place in the cost schedule.
#[derive(Clone, Debug)]
pub struct StallRecipe {
    /// Target cell.
    pub cell: u32,
    /// Creation-order index (drives the cost schedule).
    pub seq: u64,
}

/// Source: wraps [`IncSource`] and stamps each recipe with its
/// creation-order index.
pub struct StallSource {
    inner: IncSource,
    seq: u64,
}

impl TaskSource for StallSource {
    type Recipe = StallRecipe;
    fn next_task(&mut self) -> Option<StallRecipe> {
        let r = self.inner.next_task()?;
        let seq = self.seq;
        self.seq += 1;
        Some(StallRecipe { cell: r.cell, seq })
    }
    fn size_hint(&self) -> Option<u64> {
        self.inner.size_hint()
    }
}

/// Record: same same-cell conflict structure as [`IncRecord`].
pub struct StallRecord {
    seen: U32Set,
}

impl Record for StallRecord {
    type Recipe = StallRecipe;
    fn depends(&self, r: &StallRecipe) -> bool {
        self.seen.contains(r.cell)
    }
    fn absorb(&mut self, r: &StallRecipe) {
        self.seen.insert(r.cell);
    }
    fn reset(&mut self) {
        self.seen.clear();
    }
}

impl Model for StallModel {
    type Recipe = StallRecipe;
    type Record = StallRecord;
    type Source = StallSource;

    fn source(&self, seed: u64) -> StallSource {
        StallSource {
            inner: self.inner.source(seed),
            seq: 0,
        }
    }

    fn record(&self) -> StallRecord {
        StallRecord { seen: U32Set::new() }
    }

    fn execute(&self, r: &StallRecipe, rng: &mut TaskRng) {
        self.inner.execute(
            &IncRecipe { cell: r.cell },
            rng,
        );
    }

    fn task_work(&self, r: &StallRecipe) -> f64 {
        self.cost_at(r.seq)
    }
}

impl crate::sched::ShardableModel for StallModel {
    fn sched_topology(&self) -> crate::sim::graph::Csr {
        crate::sched::ShardableModel::sched_topology(&self.inner)
    }

    fn footprint(&self, r: &StallRecipe, out: &mut Vec<u32>) {
        out.push(r.cell);
    }
}

/// Convenience: build a fresh [`IncModel`].
pub fn fresh_inc_model(tasks: u64, n_cells: u32) -> IncModel {
    IncModel::new(tasks, n_cells)
}

/// Convenience: snapshot an [`IncModel`]'s cells.
pub fn inc_cells(model: &IncModel) -> Vec<u64> {
    model.cells_snapshot()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn source_is_finite_and_fused() {
        let m = IncModel::new(3, 4);
        let mut s = m.source(0);
        assert!(s.next_task().is_some());
        assert!(s.next_task().is_some());
        assert!(s.next_task().is_some());
        assert!(s.next_task().is_none());
        assert!(s.next_task().is_none(), "source must stay exhausted");
    }

    #[test]
    fn work_knob_changes_task_work() {
        let m0 = IncModel::new(1, 1);
        let m9 = IncModel::with_work(1, 1, 9);
        let r = IncRecipe { cell: 0 };
        assert_eq!(m0.task_work(&r), 1.0);
        assert_eq!(m9.task_work(&r), 10.0);
    }

    #[test]
    fn stall_model_cycles_its_cost_schedule() {
        let m = StallModel::new(7, 4, vec![0.0, 5.0, 1000.0]);
        let mut s = m.source(1);
        let mut seen = Vec::new();
        while let Some(r) = s.next_task() {
            seen.push(m.task_work(&r));
        }
        assert_eq!(seen, vec![0.0, 5.0, 1000.0, 0.0, 5.0, 1000.0, 0.0]);
    }

    #[test]
    fn stall_model_with_empty_schedule_is_unit_cost() {
        let m = StallModel::new(2, 2, Vec::new());
        let r = StallRecipe { cell: 0, seq: 17 };
        assert_eq!(m.task_work(&r), 1.0);
    }
}
