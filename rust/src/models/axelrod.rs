//! Axelrod-type cultural dynamics (paper §4.1, after Axelrod 1997 and
//! Băbeanu et al. 2018).
//!
//! `N` agents on a complete graph, each holding `F` cultural traits with
//! `q` possible values per feature. Each simulation step draws an ordered
//! pair (*source*, *target*); the target may copy one of the source's
//! differing traits, with a probability given by the pair's cultural
//! overlap, "intended to mimic social influence".
//!
//! ## Exact interaction rule used here
//!
//! Let `o = |{f : σ_f = τ_f}| / F` be the overlap. The pair is *eligible*
//! iff `1 - ω ≤ o < 1` (the bounded-confidence window; `ω = 0.95` in the
//! paper's setup — the published specification of the authors' exact
//! variant (ultrametric initial conditions etc.) is not reproducible from
//! the paper alone, and only the O(F) cost profile and the write footprint
//! matter for the protocol experiment; see DESIGN.md §2). If eligible,
//! with probability `o` the target copies the source's value on one
//! uniformly-chosen differing feature.
//!
//! ## Protocol mapping (paper §4.1)
//!
//! * granularity: one task = one pairwise interaction;
//! * depth: creation draws the ordered pair (creation stream); execution
//!   does the O(F) comparison and the probabilistic copy (task stream);
//! * recipe: the two agent ids;
//! * record: "a task at hand is considered dependent if either the source
//!   or the target agent was a **target** in any task previously
//!   encountered by the worker" — targets are the only written agents.
//!
//! ### Documented deviation (conservative correction)
//!
//! The paper's rule as quoted covers read-after-write and
//! write-after-write conflicts but **not write-after-read**: if our target
//! is the *source* of a previously-encountered (incomplete) task, we would
//! overwrite a value that task has yet to read, so sequential semantics
//! require a dependence there too. The determinism suite fails with the
//! literal rule and passes with the corrected one:
//! `depends(s,t) = t∈targets ∨ s∈targets ∨ t∈sources`. (The authors'
//! variant may be symmetric — both agents updated — in which case the
//! published rule is equivalent; with preassigned roles it is not.) See
//! DESIGN.md §2.

use crate::model::{Model, Record, TaskSource};
use crate::sim::rng::{Rng, TaskRng};
use crate::sim::state::SharedSim;
use crate::util::u32set::U32Set;

/// Model parameters (paper values in parentheses).
#[derive(Clone, Copy, Debug)]
pub struct AxelrodParams {
    /// Number of agents (10⁴).
    pub agents: usize,
    /// Number of cultural features `F` — the Fig. 2 task-size proxy `s`.
    pub features: usize,
    /// Possible traits per feature `q` (3).
    pub traits: u8,
    /// Bounded-confidence threshold `ω` (0.95).
    pub omega: f64,
    /// Number of interaction steps == number of tasks (2×10⁶).
    pub steps: u64,
}

impl Default for AxelrodParams {
    fn default() -> Self {
        Self {
            agents: 10_000,
            features: 100,
            traits: 3,
            omega: 0.95,
            steps: 2_000_000,
        }
    }
}

impl AxelrodParams {
    /// The paper's full Fig. 2 configuration at a given `F`.
    pub fn paper(features: usize) -> Self {
        Self {
            features,
            ..Self::default()
        }
    }

    /// Scaled-down configuration for CI-sized runs.
    pub fn scaled(features: usize, agents: usize, steps: u64) -> Self {
        Self {
            agents,
            features,
            steps,
            ..Self::default()
        }
    }
}

/// Shared simulation state: the trait matrix, row-major `(agents,
/// features)`.
pub struct AxelrodState {
    traits: Vec<u8>,
    features: usize,
}

impl AxelrodState {
    /// Uniform random initial culture (outside measured time).
    pub fn random(params: &AxelrodParams, rng: &mut Rng) -> Self {
        let traits = (0..params.agents * params.features)
            .map(|_| rng.below(params.traits as u64) as u8)
            .collect();
        Self {
            traits,
            features: params.features,
        }
    }

    /// Trait vector of one agent.
    #[inline]
    pub fn agent(&self, a: usize) -> &[u8] {
        &self.traits[a * self.features..(a + 1) * self.features]
    }

    #[inline]
    fn agent_mut(&mut self, a: usize) -> &mut [u8] {
        &mut self.traits[a * self.features..(a + 1) * self.features]
    }

    /// Full matrix (for tests / XLA marshalling).
    pub fn raw(&self) -> &[u8] {
        &self.traits
    }

    /// Mean pairwise overlap over a sample of pairs (order parameter used
    /// by examples; not part of the protocol experiment).
    pub fn sample_overlap(&self, pairs: usize, rng: &mut Rng) -> f64 {
        let n = self.traits.len() / self.features;
        let mut acc = 0.0;
        for _ in 0..pairs {
            let (a, b) = rng.distinct_pair(n);
            let (va, vb) = (self.agent(a), self.agent(b));
            let same = va.iter().zip(vb).filter(|(x, y)| x == y).count();
            acc += same as f64 / self.features as f64;
        }
        acc / pairs as f64
    }
}

/// The pluggable model.
pub struct AxelrodModel {
    /// Parameters.
    pub params: AxelrodParams,
    state: SharedSim<AxelrodState>,
}

impl AxelrodModel {
    /// Build with a random initial state derived from `init_seed` (kept
    /// separate from the run seed, mirroring the paper's "initial states,
    /// whose generation does not contribute to T").
    pub fn new(params: AxelrodParams, init_seed: u64) -> Self {
        let mut rng = Rng::stream(init_seed, 0xA11CE);
        Self {
            state: SharedSim::new(AxelrodState::random(&params, &mut rng)),
            params,
        }
    }

    /// Snapshot of the trait matrix (quiescent use).
    pub fn snapshot(&self) -> Vec<u8> {
        unsafe { self.state.get() }.raw().to_vec()
    }

    /// Read-only state access (quiescent use).
    pub fn state(&self) -> &SharedSim<AxelrodState> {
        &self.state
    }

    /// Cultural-domain statistics: the number of distinct trait vectors
    /// and the population of the most common one (quiescent use).
    pub fn domain_stats(&self) -> (usize, usize) {
        let state = unsafe { self.state.get() };
        let f = self.params.features;
        let mut counts: std::collections::HashMap<&[u8], usize> = std::collections::HashMap::new();
        for row in state.raw().chunks_exact(f.max(1)) {
            *counts.entry(row).or_insert(0) += 1;
        }
        let largest = counts.values().copied().max().unwrap_or(0);
        (counts.len(), largest)
    }

    /// Overwrite one agent's trait row (XLA task engine / integration
    /// tests; quiescent use only — not protocol-safe).
    pub fn write_agent_row(&self, agent: usize, row: &[i32]) {
        assert_eq!(row.len(), self.params.features);
        let state = unsafe { self.state.get_mut() };
        for (dst, &v) in state.agent_mut(agent).iter_mut().zip(row) {
            *dst = v as u8;
        }
    }
}

impl crate::sched::ShardableModel for AxelrodModel {
    /// Axelrod pairs are drawn from the complete graph — there is no
    /// locality to exploit, and materializing K_N is pointless — so the
    /// topology is edgeless: the BFS partitioner degrades to contiguous
    /// agent ranges and most interactions become spillover traffic.
    /// `sharded` on Axelrod is therefore a correctness/stress
    /// configuration (exercised by rust/tests/sharded.rs), not a
    /// performance one.
    fn sched_topology(&self) -> crate::sim::graph::Csr {
        crate::sim::graph::Csr::from_edges(self.params.agents, &[])
    }

    /// An interaction reads `{source, target}` and writes `{target}`;
    /// the target leads as the home block (it is the written agent).
    fn footprint(&self, r: &Interaction, out: &mut Vec<u32>) {
        out.push(r.target);
        if r.source != r.target {
            out.push(r.source);
        }
    }
}

impl crate::api::observe::Observable for AxelrodModel {
    /// Cultural-domain counts — the paper's Fig. 2 model's trajectory
    /// quantity: how many distinct cultures survive, and how dominant the
    /// largest one is.
    fn observe(&self) -> crate::api::observe::Metrics {
        use crate::api::observe::ObsValue;
        let (domains, largest) = self.domain_stats();
        vec![
            ("domains".to_string(), ObsValue::Int(domains as i64)),
            ("largest_domain".to_string(), ObsValue::Int(largest as i64)),
        ]
    }
}

/// Task payload: the interacting ordered pair.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Interaction {
    /// Influencing agent (read-only).
    pub source: u32,
    /// Influenced agent (read/write).
    pub target: u32,
}

/// Worker record: agents that appeared as targets (written) and as sources
/// (read) in absorbed tasks. See the module docs for why both are needed.
pub struct AxelrodRecord {
    targets: U32Set,
    sources: U32Set,
}

impl Record for AxelrodRecord {
    type Recipe = Interaction;

    #[inline]
    fn depends(&self, r: &Interaction) -> bool {
        // We read {source, target} and write {target}. An absorbed task
        // (s', t') read {s', t'} and wrote {t'}:
        //   RAW/WAW: s ∈ targets  ∨  t ∈ targets
        //   WAR:     t ∈ sources
        self.targets.contains(r.source)
            || self.targets.contains(r.target)
            || self.sources.contains(r.target)
    }

    #[inline]
    fn absorb(&mut self, r: &Interaction) {
        self.targets.insert(r.target);
        self.sources.insert(r.source);
    }

    #[inline]
    fn reset(&mut self) {
        self.targets.clear();
        self.sources.clear();
    }
}

/// Task source: draws the random ordered pair per step (task *creation*
/// work, per the paper's chosen task depth).
pub struct AxelrodSource {
    rng: Rng,
    remaining: u64,
    agents: usize,
}

impl TaskSource for AxelrodSource {
    type Recipe = Interaction;

    fn next_task(&mut self) -> Option<Interaction> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        let (source, target) = self.rng.distinct_pair(self.agents);
        Some(Interaction {
            source: source as u32,
            target: target as u32,
        })
    }

    fn size_hint(&self) -> Option<u64> {
        Some(self.remaining)
    }
}

impl Model for AxelrodModel {
    type Recipe = Interaction;
    type Record = AxelrodRecord;
    type Source = AxelrodSource;

    fn source(&self, seed: u64) -> AxelrodSource {
        AxelrodSource {
            rng: Rng::stream(seed, 0xAE1),
            remaining: self.params.steps,
            agents: self.params.agents,
        }
    }

    fn record(&self) -> AxelrodRecord {
        AxelrodRecord {
            targets: U32Set::new(),
            sources: U32Set::new(),
        }
    }

    fn execute(&self, r: &Interaction, rng: &mut TaskRng) {
        let f = self.params.features;
        // SAFETY: the record guarantees no concurrent task writes agent
        // `target` or reads/writes conflicting rows (module docs; DESIGN
        // §6). We touch exactly rows `source` (read) and `target` (r/w).
        let state = unsafe { self.state.get_mut() };

        // O(F) overlap scan — the bulk of the interaction (paper: "the
        // bulk of one interaction is built around an iteration over all
        // features").
        let mut same = 0usize;
        {
            let src = state.agent(r.source as usize);
            let tgt = state.agent(r.target as usize);
            for i in 0..f {
                same += (src[i] == tgt[i]) as usize;
            }
        }
        let overlap = same as f64 / f as f64;
        // Draw both uniforms unconditionally so the stream consumption is
        // identical to the XLA kernel path (which evaluates the whole
        // batch data-parallel); the decision arithmetic below is pure f64
        // and matches `python/compile/kernels/axelrod.py` bit for bit.
        let u_interact = rng.unit_f64();
        let u_pick = rng.unit_f64();
        if overlap >= 1.0 || overlap < 1.0 - self.params.omega {
            return; // identical or outside the confidence window
        }
        if u_interact >= overlap {
            return;
        }
        // Copy differing feature number floor(u_pick · d) (0-based among
        // the d differing features, in feature order).
        let differing = f - same;
        debug_assert!(differing > 0);
        let pick = ((u_pick * differing as f64) as usize).min(differing - 1);
        let mut seen = 0usize;
        for i in 0..f {
            let sv = state.agent(r.source as usize)[i];
            if sv != state.agent(r.target as usize)[i] {
                if seen == pick {
                    state.agent_mut(r.target as usize)[i] = sv;
                    return;
                }
                seen += 1;
            }
        }
        unreachable!("differing feature must exist");
    }

    fn task_work(&self, _r: &Interaction) -> f64 {
        // Execution cost is dominated by the O(F) feature scan.
        self.params.features as f64
    }

    /// AoS estimate (the model keeps byte traits, DESIGN.md §13): an
    /// interaction reads both agents' F-byte trait rows and writes at
    /// most one trait.
    fn state_bytes_per_task(&self) -> f64 {
        2.0 * self.params.features as f64 + 1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::{ParallelEngine, ProtocolConfig, SequentialEngine};

    fn small() -> AxelrodParams {
        AxelrodParams {
            agents: 40,
            features: 12,
            traits: 3,
            omega: 0.95,
            steps: 3_000,
        }
    }

    #[test]
    fn initial_state_is_reproducible_and_in_range() {
        let m1 = AxelrodModel::new(small(), 9);
        let m2 = AxelrodModel::new(small(), 9);
        let m3 = AxelrodModel::new(small(), 10);
        assert_eq!(m1.snapshot(), m2.snapshot());
        assert_ne!(m1.snapshot(), m3.snapshot());
        assert!(m1.snapshot().iter().all(|&t| t < 3));
    }

    #[test]
    fn sequential_run_changes_state_toward_consensus() {
        let model = AxelrodModel::new(small(), 1);
        let before = model.snapshot();
        let mut rng = Rng::new(5);
        let o_before = unsafe { model.state.get() }.sample_overlap(300, &mut rng);
        SequentialEngine::new(2).run(&model);
        let after = model.snapshot();
        assert_ne!(before, after, "interactions must change traits");
        let o_after = unsafe { model.state.get() }.sample_overlap(300, &mut rng);
        assert!(
            o_after > o_before,
            "social influence should raise mean overlap ({o_before:.3} -> {o_after:.3})"
        );
    }

    #[test]
    fn parallel_matches_sequential_bitwise() {
        let seed = 77;
        let reference = {
            let m = AxelrodModel::new(small(), 3);
            SequentialEngine::new(seed).run(&m);
            m.snapshot()
        };
        for workers in [1, 2, 4] {
            let m = AxelrodModel::new(small(), 3);
            ParallelEngine::new(ProtocolConfig {
                workers,
                seed,
                ..Default::default()
            })
            .run(&m);
            assert_eq!(m.snapshot(), reference, "n={workers} diverged");
        }
    }

    #[test]
    fn record_rule_matches_paper() {
        let m = AxelrodModel::new(small(), 0);
        let mut rec = m.record();
        let t1 = Interaction { source: 1, target: 2 };
        assert!(!rec.depends(&t1));
        rec.absorb(&t1); // agent 2 was a target, agent 1 a source
        assert!(rec.depends(&Interaction { source: 2, target: 5 }), "source was a target (RAW)");
        assert!(rec.depends(&Interaction { source: 9, target: 2 }), "target was a target (WAW)");
        assert!(
            rec.depends(&Interaction { source: 9, target: 1 }),
            "target was a source: write-after-read must be ordered"
        );
        assert!(
            !rec.depends(&Interaction { source: 1, target: 5 }),
            "reading a previously-read agent is no conflict"
        );
        rec.reset();
        assert!(!rec.depends(&Interaction { source: 2, target: 5 }));
    }

    #[test]
    fn identical_agents_never_interact() {
        // Force all-equal traits: overlap = 1 everywhere => no-op run.
        let params = small();
        let model = AxelrodModel::new(params, 0);
        unsafe {
            model.state.get_mut().traits.iter_mut().for_each(|t| *t = 1);
        }
        let before = model.snapshot();
        SequentialEngine::new(4).run(&model);
        assert_eq!(model.snapshot(), before);
    }

    #[test]
    fn task_work_scales_with_features() {
        let m = AxelrodModel::new(AxelrodParams { features: 200, ..small() }, 0);
        assert_eq!(m.task_work(&Interaction { source: 0, target: 1 }), 200.0);
    }
}
