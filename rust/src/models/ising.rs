//! Ising model with Glauber (single-spin-flip) dynamics on a 2D periodic
//! lattice — a sequential, one-update-per-step MABS whose dependence
//! footprint is a full graph neighbourhood (site + 4 neighbours), unlike
//! the pairwise models.
//!
//! Each step draws a random site and flips it with the heat-bath
//! probability `1 / (1 + exp(ΔE / T))`, where `ΔE = 2 J σ_i Σ σ_j`.
//!
//! Protocol mapping: recipe = site id; a task reads `{i} ∪ N(i)` and
//! writes `{i}`, so a task on site `i` conflicts with an absorbed task on
//! site `j` iff `j ∈ {i} ∪ N(i)` — the record keeps absorbed *sites* and
//! tests the whole neighbourhood. This exercises records whose `depends`
//! does O(k) set probes.

use std::sync::Arc;

use crate::model::{Model, Record, TaskSource};
use crate::sim::graph::{grid_partition, lattice2d, Csr};
use crate::sim::rng::{Rng, TaskRng};
use crate::sim::soa::{Layout, PackedStates, Relabeling};
use crate::sim::state::SharedSim;
use crate::util::u32set::U32Set;

/// Packed spin encoding: bit 1 ⇔ spin +1, bit 0 ⇔ spin −1.
#[inline]
fn spin_of(bit: u8) -> i32 {
    bit as i32 * 2 - 1
}

/// Parameters.
#[derive(Clone, Copy, Debug)]
pub struct IsingParams {
    /// Lattice side (N = side²).
    pub side: usize,
    /// Temperature in units of J/k_B (critical ≈ 2.269).
    pub temperature: f64,
    /// Number of flip attempts (== tasks).
    pub steps: u64,
}

impl Default for IsingParams {
    fn default() -> Self {
        Self {
            side: 64,
            temperature: 2.0,
            steps: 200_000,
        }
    }
}

/// Storage backend for the spin array, selected by [`Layout`].
enum SpinStore {
    /// Spins stored as ±1 (i8).
    Legacy(SharedSim<Vec<i8>>),
    /// 1-bit lanes ([`spin_of`] encoding); under [`Layout::Packed`]
    /// agent slots follow the torus tiling so grid shards are contiguous.
    Packed(PackedStates),
}

/// The pluggable model.
pub struct IsingModel {
    /// Parameters.
    pub params: IsingParams,
    graph: Arc<Csr>,
    store: SpinStore,
    layout: Layout,
}

impl IsingModel {
    /// Build with uniform random spins under the ambient default layout
    /// ([`Layout::env_default`]).
    pub fn new(params: IsingParams, init_seed: u64) -> Self {
        Self::with_layout(params, init_seed, Layout::env_default())
    }

    /// Build with an explicit storage layout. Spins are drawn in logical
    /// site order regardless of layout, and the packed arithmetic decodes
    /// to the same ±1 integers, so all layouts run byte-identically.
    pub fn with_layout(params: IsingParams, init_seed: u64, layout: Layout) -> Self {
        let graph = lattice2d(params.side);
        let mut rng = Rng::stream(init_seed, 0x1516);
        let spins: Vec<i8> = (0..graph.n())
            .map(|_| if rng.bernoulli(0.5) { 1i8 } else { -1i8 })
            .collect();
        let store = match layout {
            Layout::Legacy => SpinStore::Legacy(SharedSim::new(spins)),
            Layout::Packed | Layout::PackedLinear => {
                let n = graph.n();
                let order = if layout == Layout::Packed {
                    // Tile the torus so each ~64-site tile packs into a
                    // word of 1-bit lanes.
                    let tiles = (n / 64).clamp(1, n.max(1));
                    Relabeling::from_partition(&grid_partition(params.side, params.side, tiles))
                } else {
                    Relabeling::identity(n)
                };
                let ps = PackedStates::new(1, &order);
                for (i, &s) in spins.iter().enumerate() {
                    ps.set(i, u8::from(s > 0));
                }
                SpinStore::Packed(ps)
            }
        };
        Self {
            params,
            graph: Arc::new(graph),
            store,
            layout,
        }
    }

    /// The active storage layout.
    pub fn layout(&self) -> Layout {
        self.layout
    }

    /// Snapshot (quiescent use).
    pub fn snapshot(&self) -> Vec<i8> {
        match &self.store {
            SpinStore::Legacy(st) => unsafe { st.get() }.clone(),
            SpinStore::Packed(ps) => (0..ps.len()).map(|i| spin_of(ps.get(i)) as i8).collect(),
        }
    }

    /// Magnetization per site, in [-1, 1].
    pub fn magnetization(&self) -> f64 {
        let spins = self.snapshot();
        spins.iter().map(|&s| s as i64).sum::<i64>() as f64 / spins.len() as f64
    }

    /// Energy per site (J = 1).
    pub fn energy(&self) -> f64 {
        let spins = self.snapshot();
        let mut e = 0i64;
        for (v, nbrs) in self.graph.iter() {
            for &u in nbrs {
                if (u as usize) > v {
                    e -= (spins[v] as i64) * (spins[u as usize] as i64);
                }
            }
        }
        e as f64 / spins.len() as f64
    }
}

/// Task payload: the site to update.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FlipAttempt {
    /// Site id.
    pub site: u32,
}

/// Record: absorbed sites; dependence = neighbourhood overlap.
pub struct IsingRecord {
    sites: U32Set,
    graph: Arc<Csr>,
}

impl Record for IsingRecord {
    type Recipe = FlipAttempt;

    #[inline]
    fn depends(&self, r: &FlipAttempt) -> bool {
        // A task writes its site and reads site + neighbours; an absorbed
        // task may have written its own site. Conflict iff the absorbed
        // site is in our closed neighbourhood, or our site is in *its*
        // closed neighbourhood — symmetric on undirected graphs, so one
        // direction suffices.
        if self.sites.contains(r.site) {
            return true;
        }
        self.graph
            .neighbors(r.site as usize)
            .iter()
            .any(|&nb| self.sites.contains(nb))
    }

    #[inline]
    fn absorb(&mut self, r: &FlipAttempt) {
        self.sites.insert(r.site);
    }

    #[inline]
    fn reset(&mut self) {
        self.sites.clear();
    }
}

impl crate::sched::ShardableModel for IsingModel {
    /// Footprint blocks are the lattice sites; the interaction topology
    /// is the torus itself, and the grid hint routes the sharded engine
    /// to the strip/block tiling instead of BFS growth.
    fn sched_topology(&self) -> Csr {
        (*self.graph).clone()
    }

    /// A Glauber flip reads `{site} ∪ N(site)` and writes `{site}` — the
    /// exact 5-cell footprint [`IsingRecord::depends`] tests against, so
    /// disjoint footprints imply independence. The site leads as the
    /// home block (it is the written cell).
    fn footprint(&self, r: &FlipAttempt, out: &mut Vec<u32>) {
        out.push(r.site);
        out.extend_from_slice(self.graph.neighbors(r.site as usize));
    }

    fn partition_hint(&self) -> crate::sched::PartitionHint {
        crate::sched::PartitionHint::Grid {
            rows: self.params.side,
            cols: self.params.side,
        }
    }
}

impl crate::api::observe::Observable for IsingModel {
    /// Magnetization and energy per site — the standard order parameters.
    fn observe(&self) -> crate::api::observe::Metrics {
        use crate::api::observe::ObsValue;
        vec![
            (
                "magnetization".to_string(),
                ObsValue::Float(self.magnetization()),
            ),
            ("energy".to_string(), ObsValue::Float(self.energy())),
        ]
    }
}

/// Source: uniform random sites.
pub struct IsingSource {
    rng: Rng,
    n: usize,
    remaining: u64,
}

impl TaskSource for IsingSource {
    type Recipe = FlipAttempt;
    fn next_task(&mut self) -> Option<FlipAttempt> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        Some(FlipAttempt {
            site: self.rng.index(self.n) as u32,
        })
    }
    fn size_hint(&self) -> Option<u64> {
        Some(self.remaining)
    }
}

impl Model for IsingModel {
    type Recipe = FlipAttempt;
    type Record = IsingRecord;
    type Source = IsingSource;

    fn source(&self, seed: u64) -> IsingSource {
        IsingSource {
            rng: Rng::stream(seed, 0x15),
            n: self.graph.n(),
            remaining: self.params.steps,
        }
    }

    fn record(&self) -> IsingRecord {
        IsingRecord {
            sites: U32Set::new(),
            graph: self.graph.clone(),
        }
    }

    fn execute(&self, r: &FlipAttempt, rng: &mut TaskRng) {
        let i = r.site as usize;
        // Both stores decode to the same ±1 integers before any floating-
        // point op, so `delta_e` (and therefore the accept decision and
        // the RNG stream consumption) is layout-independent.
        let (si, field): (i32, i32) = match &self.store {
            SpinStore::Legacy(st) => {
                // SAFETY: record discipline — writes {site}, reads
                // {site} ∪ N(site), disjoint from every concurrently-
                // executing task's footprint (DESIGN.md §6).
                let spins = unsafe { st.get_mut() };
                (
                    spins[i] as i32,
                    self.graph
                        .neighbors(i)
                        .iter()
                        .map(|&nb| spins[nb as usize] as i32)
                        .sum(),
                )
            }
            SpinStore::Packed(ps) => (
                spin_of(ps.get(i)),
                self.graph
                    .neighbors(i)
                    .iter()
                    .map(|&nb| spin_of(ps.get(nb as usize)))
                    .sum(),
            ),
        };
        let delta_e = 2.0 * si as f64 * field as f64;
        // Heat-bath acceptance; one uniform per attempt keeps the stream
        // schedule-independent.
        let accept = rng.unit_f64() < 1.0 / (1.0 + (delta_e / self.params.temperature).exp());
        if accept {
            match &self.store {
                SpinStore::Legacy(st) => {
                    // SAFETY: as above.
                    let spins = unsafe { st.get_mut() };
                    spins[i] = -spins[i];
                }
                SpinStore::Packed(ps) => ps.set(i, ps.get(i) ^ 1),
            }
        }
    }

    fn task_work(&self, r: &FlipAttempt) -> f64 {
        1.0 + self.graph.degree(r.site as usize) as f64
    }

    /// A flip reads 5 lanes (site + 4 neighbours) and writes 1.
    fn state_bytes_per_task(&self) -> f64 {
        let lane_bytes = match &self.store {
            SpinStore::Legacy(_) => 1.0,
            SpinStore::Packed(ps) => ps.bytes_per_lane(),
        };
        6.0 * lane_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::{ParallelEngine, ProtocolConfig, SequentialEngine};

    fn small(steps: u64) -> IsingParams {
        IsingParams {
            side: 12,
            temperature: 2.0,
            steps,
        }
    }

    #[test]
    fn spins_stay_plus_minus_one() {
        let m = IsingModel::new(small(20_000), 3);
        SequentialEngine::new(1).run(&m);
        assert!(m.snapshot().iter().all(|&s| s == 1 || s == -1));
    }

    #[test]
    fn cold_dynamics_lower_energy() {
        let m = IsingModel::new(
            IsingParams {
                side: 16,
                temperature: 1.0,
                steps: 60_000,
            },
            7,
        );
        let e0 = m.energy();
        SequentialEngine::new(2).run(&m);
        let e1 = m.energy();
        assert!(e1 < e0, "quench must lower energy ({e0:.3} -> {e1:.3})");
        assert!(m.magnetization().abs() <= 1.0);
    }

    #[test]
    fn parallel_matches_sequential_bitwise() {
        let seed = 19;
        let reference = {
            let m = IsingModel::new(small(15_000), 4);
            SequentialEngine::new(seed).run(&m);
            m.snapshot()
        };
        for workers in [2, 4] {
            let m = IsingModel::new(small(15_000), 4);
            ParallelEngine::new(ProtocolConfig {
                workers,
                seed,
                ..Default::default()
            })
            .run(&m);
            assert_eq!(m.snapshot(), reference, "n={workers}");
        }
    }

    #[test]
    fn sharded_matches_sequential_bitwise_on_the_grid_partition() {
        use crate::sched::{ShardedConfig, ShardedEngine};
        let seed = 23;
        let reference = {
            let m = IsingModel::new(small(12_000), 6);
            SequentialEngine::new(seed).run(&m);
            m.snapshot()
        };
        for workers in [1, 2, 4] {
            let m = IsingModel::new(small(12_000), 6);
            let report = ShardedEngine::new(ShardedConfig {
                workers,
                seed,
                ..Default::default()
            })
            .run(&m);
            assert_eq!(m.snapshot(), reference, "n={workers} diverged");
            let sched = report.sched.as_ref().unwrap();
            assert_eq!(sched.partition, "grid", "grid hint must reach the engine");
            assert_eq!(sched.local_tasks + sched.boundary_tasks, 12_000);
        }
    }

    #[test]
    fn every_layout_is_byte_identical() {
        let seed = 29;
        let reference = {
            let m = IsingModel::with_layout(small(8_000), 4, Layout::Legacy);
            SequentialEngine::new(seed).run(&m);
            m.snapshot()
        };
        for layout in Layout::ALL {
            let m = IsingModel::with_layout(small(8_000), 4, layout);
            SequentialEngine::new(seed).run(&m);
            assert_eq!(m.snapshot(), reference, "{layout} diverged from legacy");
        }
    }

    #[test]
    fn packed_layout_shrinks_bytes_per_task() {
        // 1-bit spins: 8× smaller than the i8 per lane.
        let legacy = IsingModel::with_layout(small(10), 0, Layout::Legacy);
        let packed = IsingModel::with_layout(small(10), 0, Layout::Packed);
        assert_eq!(legacy.state_bytes_per_task(), 6.0);
        assert_eq!(packed.state_bytes_per_task(), 0.75);
    }

    #[test]
    fn record_uses_neighbourhood() {
        let m = IsingModel::new(small(10), 0);
        let mut rec = m.record();
        // Sites on a 12×12 torus: 0's neighbours are 1, 11, 12, 132.
        rec.absorb(&FlipAttempt { site: 0 });
        assert!(rec.depends(&FlipAttempt { site: 0 }));
        assert!(rec.depends(&FlipAttempt { site: 1 }));
        assert!(rec.depends(&FlipAttempt { site: 12 }));
        assert!(!rec.depends(&FlipAttempt { site: 2 }));
        assert!(!rec.depends(&FlipAttempt { site: 50 }));
    }
}
