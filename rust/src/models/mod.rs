//! MABS models plugged into the protocol.
//!
//! * [`axelrod`] — Axelrod-type cultural dynamics (paper §4.1): fully
//!   sequential, one pairwise interaction per step. The experiment behind
//!   Fig. 2.
//! * [`sir`] — SIR-type epidemic on a ring lattice (paper §4.2):
//!   synchronous two-phase dynamics over a fixed partition of agents. The
//!   experiment behind Fig. 3. Also implements the step-parallel baseline
//!   interface.
//! * [`voter`] — voter model on an arbitrary graph: a second sequential
//!   pairwise model exercising the interface (and the overhead benches,
//!   since its tasks are tiny).
//! * [`ising`] — Ising/Glauber single-spin dynamics on a 2D torus: a
//!   sequential model whose dependence footprint is a whole graph
//!   neighbourhood rather than a pair.
//!
//! Every model provides: the protocol plug-in (recipe/record/source +
//! execute) and initial-state generation whose randomness is *outside* the
//! measured simulation (paper: initial state generation "does not
//! contribute to T").

pub mod axelrod;
pub mod ising;
pub mod schelling;
pub mod sir;
pub mod voter;
