//! Schelling segregation with **moving agents** — the paper's future-work
//! item ("applications of our protocol to simulations with non-stationary
//! agents", §5), implemented as an extension model.
//!
//! Agents of two types live on a 2D torus with vacancies. A task is one
//! relocation attempt between a *pair of cells* drawn at creation: if the
//! source cell hosts an agent, the destination cell is vacant, and the
//! agent is unsatisfied (same-type neighbour fraction below `tolerance`),
//! the agent relocates.
//!
//! ## Sound record for movers
//!
//! Movement breaks the stationary-footprint assumption: a task touches
//! *wherever the agent currently is*. Keying tasks by **cells instead of
//! agents** restores a creation-time-known footprint: a task reads and
//! writes only within the closed 3×3 neighbourhoods of its two cells, so
//! the record claims `N⁺(from) ∪ N⁺(to)` and no state needs to be read
//! during creation or dependence checking. Two tasks whose claims are
//! disjoint cannot observe each other's agents at all — dependence
//! checking stays purely structural, and the determinism suite covers the
//! model like the stationary ones.

use crate::model::{Model, Record, TaskSource};
use crate::sim::rng::{Rng, TaskRng};
use crate::sim::state::SharedSim;
use crate::util::u32set::U32Set;

/// Parameters.
#[derive(Clone, Copy, Debug)]
pub struct SchellingParams {
    /// Torus side; `side²` cells.
    pub side: usize,
    /// Number of agents (must leave vacancies).
    pub agents: usize,
    /// Minimum same-type neighbour fraction an agent tolerates.
    pub tolerance: f64,
    /// Relocation attempts (== tasks).
    pub steps: u64,
}

impl Default for SchellingParams {
    fn default() -> Self {
        Self {
            side: 48,
            agents: 1_800, // ~78% occupancy
            tolerance: 0.4,
            steps: 100_000,
        }
    }
}

/// Grid cell content: `EMPTY` or agent id.
const EMPTY: u32 = u32::MAX;

/// Shared state.
pub struct SchellingState {
    /// Cell → agent id or `EMPTY`.
    pub grid: Vec<u32>,
    /// Agent id → cell (observable bookkeeping; written only when the
    /// resident of a claimed cell moves).
    pub pos: Vec<u32>,
    /// Agent id → type (0/1); immutable after init.
    pub kind: Vec<u8>,
}

/// The pluggable model.
pub struct SchellingModel {
    /// Parameters.
    pub params: SchellingParams,
    state: SharedSim<SchellingState>,
}

/// Task payload: the cell pair (footprint known at creation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MoveAttempt {
    /// Source cell (move its resident, if any and unhappy).
    pub from: u32,
    /// Destination cell (must be vacant).
    pub to: u32,
}

impl SchellingModel {
    /// Build with random placement.
    pub fn new(params: SchellingParams, init_seed: u64) -> Self {
        let cells = params.side * params.side;
        assert!(params.agents < cells, "need vacancies");
        let mut rng = Rng::stream(init_seed, 0x5CE1);
        let mut cell_ids: Vec<u32> = (0..cells as u32).collect();
        rng.shuffle(&mut cell_ids);
        let mut grid = vec![EMPTY; cells];
        let mut pos = vec![0u32; params.agents];
        let mut kind = vec![0u8; params.agents];
        for a in 0..params.agents {
            let c = cell_ids[a];
            grid[c as usize] = a as u32;
            pos[a] = c;
            kind[a] = (rng.bernoulli(0.5)) as u8;
        }
        Self {
            params,
            state: SharedSim::new(SchellingState { grid, pos, kind }),
        }
    }

    /// Closed 3×3 neighbourhood of a cell on the torus (9 cells).
    pub fn neighborhood(side: usize, cell: u32) -> [u32; 9] {
        let (r, c) = ((cell as usize) / side, (cell as usize) % side);
        let mut out = [0u32; 9];
        let mut i = 0;
        for dr in [side - 1, 0, 1] {
            for dc in [side - 1, 0, 1] {
                let rr = (r + dr) % side;
                let cc = (c + dc) % side;
                out[i] = (rr * side + cc) as u32;
                i += 1;
            }
        }
        out
    }

    /// Satisfaction test at `cell` for an agent of type `k` (reads the 8
    /// open-neighbourhood cells).
    fn satisfied(&self, state: &SchellingState, cell: u32, k: u8) -> bool {
        let mut same = 0usize;
        let mut occupied = 0usize;
        for &nb in &Self::neighborhood(self.params.side, cell) {
            if nb == cell {
                continue;
            }
            let resident = state.grid[nb as usize];
            if resident != EMPTY {
                occupied += 1;
                same += (state.kind[resident as usize] == k) as usize;
            }
        }
        if occupied == 0 {
            return true; // isolated agents are content
        }
        (same as f64 / occupied as f64) >= self.params.tolerance
    }

    /// Snapshot of the grid (quiescent use).
    pub fn snapshot(&self) -> Vec<u32> {
        unsafe { self.state.get() }.grid.clone()
    }

    /// Mean same-type fraction over occupied neighbourhoods — the
    /// segregation order parameter.
    pub fn segregation(&self) -> f64 {
        let state = unsafe { self.state.get() };
        let mut acc = 0.0;
        let mut n = 0usize;
        for a in 0..self.params.agents {
            let cell = state.pos[a];
            let mut same = 0usize;
            let mut occ = 0usize;
            for &nb in &Self::neighborhood(self.params.side, cell) {
                if nb == cell {
                    continue;
                }
                let r = state.grid[nb as usize];
                if r != EMPTY {
                    occ += 1;
                    same += (state.kind[r as usize] == state.kind[a]) as usize;
                }
            }
            if occ > 0 {
                acc += same as f64 / occ as f64;
                n += 1;
            }
        }
        acc / n.max(1) as f64
    }

    /// Structural invariant: `grid` and `pos` agree, each agent exactly
    /// once.
    pub fn check_consistency(&self) -> Result<(), String> {
        let state = unsafe { self.state.get() };
        let mut seen = vec![false; self.params.agents];
        for (cell, &resident) in state.grid.iter().enumerate() {
            if resident != EMPTY {
                let a = resident as usize;
                if a >= seen.len() {
                    return Err(format!("bogus agent id {a}"));
                }
                if seen[a] {
                    return Err(format!("agent {a} appears twice"));
                }
                seen[a] = true;
                if state.pos[a] as usize != cell {
                    return Err(format!("agent {a}: pos={} cell={cell}", state.pos[a]));
                }
            }
        }
        if !seen.iter().all(|&s| s) {
            return Err("agent missing from grid".into());
        }
        Ok(())
    }
}

impl crate::api::observe::Observable for SchellingModel {
    /// The segregation order parameter plus the count of satisfied
    /// agents.
    fn observe(&self) -> crate::api::observe::Metrics {
        use crate::api::observe::ObsValue;
        let state = unsafe { self.state.get() };
        let satisfied = (0..self.params.agents)
            .filter(|&a| self.satisfied(state, state.pos[a], state.kind[a]))
            .count();
        vec![
            ("segregation".to_string(), ObsValue::Float(self.segregation())),
            ("satisfied".to_string(), ObsValue::Int(satisfied as i64)),
        ]
    }
}

/// Record: claimed cells (closed neighbourhoods of both task cells).
pub struct SchellingRecord {
    cells: U32Set,
    side: usize,
}

impl Record for SchellingRecord {
    type Recipe = MoveAttempt;

    fn depends(&self, r: &MoveAttempt) -> bool {
        for base in [r.from, r.to] {
            for nb in SchellingModel::neighborhood(self.side, base) {
                if self.cells.contains(nb) {
                    return true;
                }
            }
        }
        false
    }

    fn absorb(&mut self, r: &MoveAttempt) {
        for base in [r.from, r.to] {
            for nb in SchellingModel::neighborhood(self.side, base) {
                self.cells.insert(nb);
            }
        }
    }

    fn reset(&mut self) {
        self.cells.clear();
    }
}

/// Source: two uniform random cells per attempt; no state reads.
pub struct SchellingSource {
    rng: Rng,
    remaining: u64,
    cells: usize,
}

impl TaskSource for SchellingSource {
    type Recipe = MoveAttempt;
    fn next_task(&mut self) -> Option<MoveAttempt> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        let (from, to) = self.rng.distinct_pair(self.cells);
        Some(MoveAttempt {
            from: from as u32,
            to: to as u32,
        })
    }
    fn size_hint(&self) -> Option<u64> {
        Some(self.remaining)
    }
}

impl Model for SchellingModel {
    type Recipe = MoveAttempt;
    type Record = SchellingRecord;
    type Source = SchellingSource;

    fn source(&self, seed: u64) -> SchellingSource {
        SchellingSource {
            rng: Rng::stream(seed, 0x5E11),
            remaining: self.params.steps,
            cells: self.params.side * self.params.side,
        }
    }

    fn record(&self) -> SchellingRecord {
        SchellingRecord {
            cells: U32Set::new(),
            side: self.params.side,
        }
    }

    fn execute(&self, r: &MoveAttempt, _rng: &mut TaskRng) {
        // SAFETY: record discipline — every access below is within
        // N⁺(from) ∪ N⁺(to), plus `pos[resident]` where `resident` lives
        // in the claimed cell `from` (any other task that could touch this
        // agent must have claimed `from` too). See module docs.
        let state = unsafe { self.state.get_mut() };
        let resident = state.grid[r.from as usize];
        if resident == EMPTY || state.grid[r.to as usize] != EMPTY {
            return;
        }
        let k = state.kind[resident as usize];
        if self.satisfied(state, r.from, k) {
            return; // content agents stay
        }
        state.grid[r.from as usize] = EMPTY;
        state.grid[r.to as usize] = resident;
        state.pos[resident as usize] = r.to;
    }

    fn task_work(&self, _r: &MoveAttempt) -> f64 {
        // Two 3×3 neighbourhood scans.
        18.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::{ParallelEngine, ProtocolConfig, SequentialEngine};
    use crate::vtime::{CostModel, VirtualEngine};

    fn small(steps: u64) -> SchellingParams {
        SchellingParams {
            side: 16,
            agents: 180,
            tolerance: 0.5,
            steps,
        }
    }

    #[test]
    fn initial_state_is_consistent() {
        let m = SchellingModel::new(small(0), 3);
        m.check_consistency().unwrap();
    }

    #[test]
    fn dynamics_increase_segregation_and_stay_consistent() {
        let m = SchellingModel::new(small(60_000), 5);
        let before = m.segregation();
        SequentialEngine::new(9).run(&m);
        m.check_consistency().unwrap();
        let after = m.segregation();
        assert!(
            after > before + 0.05,
            "segregation should rise: {before:.3} -> {after:.3}"
        );
    }

    #[test]
    fn parallel_and_virtual_match_sequential_bitwise() {
        let seed = 77;
        let reference = {
            let m = SchellingModel::new(small(15_000), 2);
            SequentialEngine::new(seed).run(&m);
            m.snapshot()
        };
        for workers in [2, 4] {
            let m = SchellingModel::new(small(15_000), 2);
            ParallelEngine::new(ProtocolConfig {
                workers,
                seed,
                ..Default::default()
            })
            .run(&m);
            assert_eq!(m.snapshot(), reference, "parallel n={workers}");
            m.check_consistency().unwrap();
        }
        let m = SchellingModel::new(small(15_000), 2);
        VirtualEngine {
            workers: 3,
            tasks_per_cycle: 6,
            seed,
            cost: CostModel::default(),
        }
        .run(&m);
        assert_eq!(m.snapshot(), reference, "virtual");
    }

    #[test]
    fn record_claims_both_neighbourhoods() {
        let m = SchellingModel::new(small(0), 0);
        let mut rec = m.record();
        rec.absorb(&MoveAttempt { from: 0, to: 100 });
        // Overlap with N⁺(from): cell 1 is adjacent to 0.
        assert!(rec.depends(&MoveAttempt { from: 1, to: 200 }));
        // Overlap with N⁺(to): 101 adjacent to 100.
        assert!(rec.depends(&MoveAttempt { from: 200, to: 101 }));
        // Far pair: (8,8)=136 and (12,12)=204 on a 16-torus.
        assert!(!rec.depends(&MoveAttempt { from: 136, to: 204 }));
        rec.reset();
        assert!(!rec.depends(&MoveAttempt { from: 0, to: 100 }));
    }

    #[test]
    fn moves_respect_vacancy_and_tolerance() {
        let m = SchellingModel::new(small(0), 1);
        let before = m.snapshot();
        // Occupied destination: no-op.
        let occupied_to = (0..before.len())
            .find(|&c| before[c] != EMPTY)
            .unwrap() as u32;
        let occupied_from = (0..before.len())
            .rfind(|&c| before[c] != EMPTY)
            .unwrap() as u32;
        let mut rng = crate::sim::rng::TaskRng::for_task(0, 0);
        m.execute(&MoveAttempt { from: occupied_from, to: occupied_to }, &mut rng);
        assert_eq!(m.snapshot(), before);
        // Empty source: no-op.
        let empty = (0..before.len()).find(|&c| before[c] == EMPTY).unwrap() as u32;
        let empty2 = (0..before.len()).rfind(|&c| before[c] == EMPTY).unwrap() as u32;
        m.execute(&MoveAttempt { from: empty, to: empty2 }, &mut rng);
        assert_eq!(m.snapshot(), before);
    }
}
