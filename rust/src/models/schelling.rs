//! Schelling segregation with **moving agents** — the paper's future-work
//! item ("applications of our protocol to simulations with non-stationary
//! agents", §5), implemented as an extension model.
//!
//! Agents of two types live on a 2D torus with vacancies. A task is one
//! relocation attempt between a *pair of cells* drawn at creation: if the
//! source cell hosts an agent, the destination cell is vacant, and the
//! agent is unsatisfied (same-type neighbour fraction below `tolerance`),
//! the agent relocates.
//!
//! ## Sound record for movers
//!
//! Movement breaks the stationary-footprint assumption: a task touches
//! *wherever the agent currently is*. Keying tasks by **cells instead of
//! agents** restores a creation-time-known footprint: a task reads and
//! writes only within the closed 3×3 neighbourhoods of its two cells, so
//! the record claims `N⁺(from) ∪ N⁺(to)` and no state needs to be read
//! during creation or dependence checking. Two tasks whose claims are
//! disjoint cannot observe each other's agents at all — dependence
//! checking stays purely structural, and the determinism suite covers the
//! model like the stationary ones.
//!
//! ## Bounded relocation (`move_radius`)
//!
//! With the default `move_radius = 0` the destination cell is drawn
//! uniformly over the whole torus — the classic unbounded dynamics, but
//! a worst case for the sharded scheduler (almost every footprint spans
//! shards). Setting `move_radius = r > 0` restricts each relocation
//! attempt to a destination within Chebyshev radius `r` of the source,
//! drawn at creation. The task footprint stays the same conservative
//! two-block union `N⁺(from) ∪ N⁺(to)` — now two nearby 3×3 blocks, so
//! under a grid shard tiling most attempts are shard-local and the
//! sharded engine scales on the lattice (DESIGN.md §8a).

use crate::model::{Model, Record, TaskSource};
use crate::sim::rng::{Rng, TaskRng};
use crate::sim::state::SharedSim;
use crate::util::u32set::U32Set;

/// Parameters.
#[derive(Clone, Copy, Debug)]
pub struct SchellingParams {
    /// Torus side; `side²` cells.
    pub side: usize,
    /// Number of agents (must leave vacancies).
    pub agents: usize,
    /// Minimum same-type neighbour fraction an agent tolerates.
    pub tolerance: f64,
    /// Relocation attempts (== tasks).
    pub steps: u64,
    /// Bounded relocation: destinations are drawn within this Chebyshev
    /// radius of the source (`0` = unbounded, the classic dynamics).
    pub move_radius: usize,
}

impl Default for SchellingParams {
    fn default() -> Self {
        Self {
            side: 48,
            agents: 1_800, // ~78% occupancy
            tolerance: 0.4,
            steps: 100_000,
            move_radius: 0,
        }
    }
}

/// Grid cell content: `EMPTY` or agent id.
const EMPTY: u32 = u32::MAX;

/// Shared state.
pub struct SchellingState {
    /// Cell → agent id or `EMPTY`.
    pub grid: Vec<u32>,
    /// Agent id → cell (observable bookkeeping; written only when the
    /// resident of a claimed cell moves).
    pub pos: Vec<u32>,
    /// Agent id → type (0/1); immutable after init.
    pub kind: Vec<u8>,
}

/// The pluggable model.
pub struct SchellingModel {
    /// Parameters.
    pub params: SchellingParams,
    state: SharedSim<SchellingState>,
}

/// Task payload: the cell pair (footprint known at creation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MoveAttempt {
    /// Source cell (move its resident, if any and unhappy).
    pub from: u32,
    /// Destination cell (must be vacant).
    pub to: u32,
}

impl SchellingModel {
    /// Build with random placement.
    pub fn new(params: SchellingParams, init_seed: u64) -> Self {
        let cells = params.side * params.side;
        assert!(params.agents < cells, "need vacancies");
        assert!(
            2 * params.move_radius < params.side,
            "move_radius box must fit the torus (2r < side)"
        );
        let mut rng = Rng::stream(init_seed, 0x5CE1);
        let mut cell_ids: Vec<u32> = (0..cells as u32).collect();
        rng.shuffle(&mut cell_ids);
        let mut grid = vec![EMPTY; cells];
        let mut pos = vec![0u32; params.agents];
        let mut kind = vec![0u8; params.agents];
        for a in 0..params.agents {
            let c = cell_ids[a];
            grid[c as usize] = a as u32;
            pos[a] = c;
            kind[a] = (rng.bernoulli(0.5)) as u8;
        }
        Self {
            params,
            state: SharedSim::new(SchellingState { grid, pos, kind }),
        }
    }

    /// Closed 3×3 neighbourhood of a cell on the torus (9 cells).
    pub fn neighborhood(side: usize, cell: u32) -> [u32; 9] {
        let (r, c) = ((cell as usize) / side, (cell as usize) % side);
        let mut out = [0u32; 9];
        let mut i = 0;
        for dr in [side - 1, 0, 1] {
            for dc in [side - 1, 0, 1] {
                let rr = (r + dr) % side;
                let cc = (c + dc) % side;
                out[i] = (rr * side + cc) as u32;
                i += 1;
            }
        }
        out
    }

    /// Satisfaction test at `cell` for an agent of type `k` (reads the 8
    /// open-neighbourhood cells).
    fn satisfied(&self, state: &SchellingState, cell: u32, k: u8) -> bool {
        let mut same = 0usize;
        let mut occupied = 0usize;
        for &nb in &Self::neighborhood(self.params.side, cell) {
            if nb == cell {
                continue;
            }
            let resident = state.grid[nb as usize];
            if resident != EMPTY {
                occupied += 1;
                same += (state.kind[resident as usize] == k) as usize;
            }
        }
        if occupied == 0 {
            return true; // isolated agents are content
        }
        (same as f64 / occupied as f64) >= self.params.tolerance
    }

    /// Snapshot of the grid (quiescent use).
    pub fn snapshot(&self) -> Vec<u32> {
        unsafe { self.state.get() }.grid.clone()
    }

    /// Mean same-type fraction over occupied neighbourhoods — the
    /// segregation order parameter.
    pub fn segregation(&self) -> f64 {
        let state = unsafe { self.state.get() };
        let mut acc = 0.0;
        let mut n = 0usize;
        for a in 0..self.params.agents {
            let cell = state.pos[a];
            let mut same = 0usize;
            let mut occ = 0usize;
            for &nb in &Self::neighborhood(self.params.side, cell) {
                if nb == cell {
                    continue;
                }
                let r = state.grid[nb as usize];
                if r != EMPTY {
                    occ += 1;
                    same += (state.kind[r as usize] == state.kind[a]) as usize;
                }
            }
            if occ > 0 {
                acc += same as f64 / occ as f64;
                n += 1;
            }
        }
        acc / n.max(1) as f64
    }

    /// Structural invariant: `grid` and `pos` agree, each agent exactly
    /// once.
    pub fn check_consistency(&self) -> Result<(), String> {
        let state = unsafe { self.state.get() };
        let mut seen = vec![false; self.params.agents];
        for (cell, &resident) in state.grid.iter().enumerate() {
            if resident != EMPTY {
                let a = resident as usize;
                if a >= seen.len() {
                    return Err(format!("bogus agent id {a}"));
                }
                if seen[a] {
                    return Err(format!("agent {a} appears twice"));
                }
                seen[a] = true;
                if state.pos[a] as usize != cell {
                    return Err(format!("agent {a}: pos={} cell={cell}", state.pos[a]));
                }
            }
        }
        if !seen.iter().all(|&s| s) {
            return Err("agent missing from grid".into());
        }
        Ok(())
    }
}

impl crate::sched::ShardableModel for SchellingModel {
    /// Footprint blocks are the torus cells; the 4-neighbour lattice is
    /// enough for partitioning (the diagonal reads only widen footprints,
    /// never the cut-relevant adjacency structure), and the grid hint
    /// selects the strip/block tiling.
    fn sched_topology(&self) -> crate::sim::graph::Csr {
        crate::sim::graph::lattice2d(self.params.side)
    }

    /// Exactly the cells [`SchellingRecord`] claims: the closed 3×3
    /// neighbourhoods of both task cells. `depends` true in either
    /// absorption direction means the two unions intersect, so the
    /// footprint contract holds for bounded *and* unbounded relocation
    /// (the bounded variant merely keeps the two blocks adjacent, hence
    /// mostly shard-local under the grid tiling). `from` leads as the
    /// home block (it hosts the moving agent).
    fn footprint(&self, r: &MoveAttempt, out: &mut Vec<u32>) {
        out.push(r.from);
        for base in [r.from, r.to] {
            for nb in Self::neighborhood(self.params.side, base) {
                if !out.contains(&nb) {
                    out.push(nb);
                }
            }
        }
    }

    fn partition_hint(&self) -> crate::sched::PartitionHint {
        crate::sched::PartitionHint::Grid {
            rows: self.params.side,
            cols: self.params.side,
        }
    }
}

impl crate::api::observe::Observable for SchellingModel {
    /// The segregation order parameter plus the count of satisfied
    /// agents.
    fn observe(&self) -> crate::api::observe::Metrics {
        use crate::api::observe::ObsValue;
        let state = unsafe { self.state.get() };
        let satisfied = (0..self.params.agents)
            .filter(|&a| self.satisfied(state, state.pos[a], state.kind[a]))
            .count();
        vec![
            ("segregation".to_string(), ObsValue::Float(self.segregation())),
            ("satisfied".to_string(), ObsValue::Int(satisfied as i64)),
        ]
    }
}

/// Record: claimed cells (closed neighbourhoods of both task cells).
pub struct SchellingRecord {
    cells: U32Set,
    side: usize,
}

impl Record for SchellingRecord {
    type Recipe = MoveAttempt;

    fn depends(&self, r: &MoveAttempt) -> bool {
        for base in [r.from, r.to] {
            for nb in SchellingModel::neighborhood(self.side, base) {
                if self.cells.contains(nb) {
                    return true;
                }
            }
        }
        false
    }

    fn absorb(&mut self, r: &MoveAttempt) {
        for base in [r.from, r.to] {
            for nb in SchellingModel::neighborhood(self.side, base) {
                self.cells.insert(nb);
            }
        }
    }

    fn reset(&mut self) {
        self.cells.clear();
    }
}

/// Source: a uniform source cell plus a destination — uniform over the
/// whole torus (unbounded), or uniform over the Chebyshev-radius box
/// around the source (bounded relocation). No state reads either way.
pub struct SchellingSource {
    rng: Rng,
    remaining: u64,
    cells: usize,
    side: usize,
    move_radius: usize,
}

impl TaskSource for SchellingSource {
    type Recipe = MoveAttempt;
    fn next_task(&mut self) -> Option<MoveAttempt> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        if self.move_radius == 0 {
            let (from, to) = self.rng.distinct_pair(self.cells);
            return Some(MoveAttempt {
                from: from as u32,
                to: to as u32,
            });
        }
        // Bounded: `to` uniform over the (2r+1)² box around `from`,
        // excluding the centre (one draw, centre index skipped, so the
        // RNG schedule is a fixed two draws per attempt).
        let (r, d) = (self.move_radius, 2 * self.move_radius + 1);
        let from = self.rng.index(self.cells);
        let mut k = self.rng.index(d * d - 1);
        if k >= r * d + r {
            k += 1; // skip the centre offset (0, 0)
        }
        let (fr, fc) = (from / self.side, from % self.side);
        let tr = (fr + self.side + k / d - r) % self.side;
        let tc = (fc + self.side + k % d - r) % self.side;
        Some(MoveAttempt {
            from: from as u32,
            to: (tr * self.side + tc) as u32,
        })
    }
    fn size_hint(&self) -> Option<u64> {
        Some(self.remaining)
    }
}

impl Model for SchellingModel {
    type Recipe = MoveAttempt;
    type Record = SchellingRecord;
    type Source = SchellingSource;

    fn source(&self, seed: u64) -> SchellingSource {
        SchellingSource {
            rng: Rng::stream(seed, 0x5E11),
            remaining: self.params.steps,
            cells: self.params.side * self.params.side,
            side: self.params.side,
            move_radius: self.params.move_radius,
        }
    }

    fn record(&self) -> SchellingRecord {
        SchellingRecord {
            cells: U32Set::new(),
            side: self.params.side,
        }
    }

    fn execute(&self, r: &MoveAttempt, _rng: &mut TaskRng) {
        // SAFETY: record discipline — every access below is within
        // N⁺(from) ∪ N⁺(to), plus `pos[resident]` where `resident` lives
        // in the claimed cell `from` (any other task that could touch this
        // agent must have claimed `from` too). See module docs.
        let state = unsafe { self.state.get_mut() };
        let resident = state.grid[r.from as usize];
        if resident == EMPTY || state.grid[r.to as usize] != EMPTY {
            return;
        }
        let k = state.kind[resident as usize];
        if self.satisfied(state, r.from, k) {
            return; // content agents stay
        }
        state.grid[r.from as usize] = EMPTY;
        state.grid[r.to as usize] = resident;
        state.pos[resident as usize] = r.to;
    }

    fn task_work(&self, _r: &MoveAttempt) -> f64 {
        // Two 3×3 neighbourhood scans.
        18.0
    }

    /// AoS estimate (the model keeps its u32 grid/pos vecs, DESIGN.md
    /// §13): two 3×3 scans of 4-byte grid cells, one kind-byte read, and
    /// on a move two grid-cell writes plus the 4-byte position update.
    fn state_bytes_per_task(&self) -> f64 {
        18.0 * 4.0 + 1.0 + 2.0 * 4.0 + 4.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::{ParallelEngine, ProtocolConfig, SequentialEngine};
    use crate::vtime::{CostModel, VirtualEngine};

    fn small(steps: u64) -> SchellingParams {
        SchellingParams {
            side: 16,
            agents: 180,
            tolerance: 0.5,
            steps,
            move_radius: 0,
        }
    }

    #[test]
    fn initial_state_is_consistent() {
        let m = SchellingModel::new(small(0), 3);
        m.check_consistency().unwrap();
    }

    #[test]
    fn dynamics_increase_segregation_and_stay_consistent() {
        let m = SchellingModel::new(small(60_000), 5);
        let before = m.segregation();
        SequentialEngine::new(9).run(&m);
        m.check_consistency().unwrap();
        let after = m.segregation();
        assert!(
            after > before + 0.05,
            "segregation should rise: {before:.3} -> {after:.3}"
        );
    }

    #[test]
    fn parallel_and_virtual_match_sequential_bitwise() {
        let seed = 77;
        let reference = {
            let m = SchellingModel::new(small(15_000), 2);
            SequentialEngine::new(seed).run(&m);
            m.snapshot()
        };
        for workers in [2, 4] {
            let m = SchellingModel::new(small(15_000), 2);
            ParallelEngine::new(ProtocolConfig {
                workers,
                seed,
                ..Default::default()
            })
            .run(&m);
            assert_eq!(m.snapshot(), reference, "parallel n={workers}");
            m.check_consistency().unwrap();
        }
        let m = SchellingModel::new(small(15_000), 2);
        VirtualEngine {
            workers: 3,
            tasks_per_cycle: 6,
            seed,
            cost: CostModel::default(),
            trace: crate::trace::TraceMode::Off,
            window: 0,
        }
        .run(&m);
        assert_eq!(m.snapshot(), reference, "virtual");
    }

    #[test]
    fn record_claims_both_neighbourhoods() {
        let m = SchellingModel::new(small(0), 0);
        let mut rec = m.record();
        rec.absorb(&MoveAttempt { from: 0, to: 100 });
        // Overlap with N⁺(from): cell 1 is adjacent to 0.
        assert!(rec.depends(&MoveAttempt { from: 1, to: 200 }));
        // Overlap with N⁺(to): 101 adjacent to 100.
        assert!(rec.depends(&MoveAttempt { from: 200, to: 101 }));
        // Far pair: (8,8)=136 and (12,12)=204 on a 16-torus.
        assert!(!rec.depends(&MoveAttempt { from: 136, to: 204 }));
        rec.reset();
        assert!(!rec.depends(&MoveAttempt { from: 0, to: 100 }));
    }

    #[test]
    fn bounded_source_stays_within_the_radius() {
        let params = SchellingParams {
            move_radius: 2,
            ..small(500)
        };
        let m = SchellingModel::new(params, 4);
        let mut src = m.source(8);
        let side = params.side as i64;
        let mut seen = 0;
        while let Some(t) = src.next_task() {
            seen += 1;
            assert_ne!(t.from, t.to, "centre offset must be skipped");
            let (fr, fc) = (t.from as i64 / side, t.from as i64 % side);
            let (tr, tc) = (t.to as i64 / side, t.to as i64 % side);
            let wrap = |d: i64| d.rem_euclid(side).min((-d).rem_euclid(side));
            assert!(
                wrap(tr - fr) <= 2 && wrap(tc - fc) <= 2,
                "{t:?} escapes the radius-2 box"
            );
        }
        assert_eq!(seen, 500);
    }

    #[test]
    fn bounded_dynamics_match_bitwise_across_engines() {
        let params = SchellingParams {
            move_radius: 2,
            ..small(20_000)
        };
        let seed = 31;
        let reference = {
            let m = SchellingModel::new(params, 6);
            SequentialEngine::new(seed).run(&m);
            m.check_consistency().unwrap();
            m.snapshot()
        };
        for workers in [2, 4] {
            let m = SchellingModel::new(params, 6);
            ParallelEngine::new(ProtocolConfig {
                workers,
                seed,
                ..Default::default()
            })
            .run(&m);
            assert_eq!(m.snapshot(), reference, "parallel n={workers}");
        }
        for workers in [1, 2, 4] {
            use crate::sched::{ShardedConfig, ShardedEngine};
            let m = SchellingModel::new(params, 6);
            let report = ShardedEngine::new(ShardedConfig {
                workers,
                seed,
                ..Default::default()
            })
            .run(&m);
            assert_eq!(m.snapshot(), reference, "sharded n={workers}");
            m.check_consistency().unwrap();
            let sched = report.sched.as_ref().unwrap();
            assert_eq!(sched.partition, "grid");
            // On this small 16-torus the radius-2 footprints span ~1/4 of
            // a strip, so only the 2-shard split keeps a clear local
            // majority (narrower strips cut more boxes).
            if workers == 2 {
                assert!(
                    sched.local_tasks > sched.boundary_tasks,
                    "radius-2 moves must be mostly shard-local: {sched:?}"
                );
            }
        }
    }

    #[test]
    fn moves_respect_vacancy_and_tolerance() {
        let m = SchellingModel::new(small(0), 1);
        let before = m.snapshot();
        // Occupied destination: no-op.
        let occupied_to = (0..before.len())
            .find(|&c| before[c] != EMPTY)
            .unwrap() as u32;
        let occupied_from = (0..before.len())
            .rfind(|&c| before[c] != EMPTY)
            .unwrap() as u32;
        let mut rng = crate::sim::rng::TaskRng::for_task(0, 0);
        m.execute(&MoveAttempt { from: occupied_from, to: occupied_to }, &mut rng);
        assert_eq!(m.snapshot(), before);
        // Empty source: no-op.
        let empty = (0..before.len()).find(|&c| before[c] == EMPTY).unwrap() as u32;
        let empty2 = (0..before.len()).rfind(|&c| before[c] == EMPTY).unwrap() as u32;
        m.execute(&MoveAttempt { from: empty, to: empty2 }, &mut rng);
        assert_eq!(m.snapshot(), before);
    }
}
