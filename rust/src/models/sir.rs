//! SIR-type epidemic model on a ring lattice (paper §4.2).
//!
//! `N` agents on a fixed constant-degree-`k` ring-like graph; states
//! S(usceptible) → I(nfected) → R(ecovered) → S with probabilities
//! `p_SI · (infected fraction of neighbours)`, `p_IR`, `p_RS`. All agents
//! update synchronously per step, "conditionally on nearest-neighbours'
//! states during the previous step" — the classic two-buffer scheme.
//!
//! ## Protocol mapping (paper §4.2)
//!
//! * The system is partitioned once into equal contiguous subsets of size
//!   `s` (the Fig. 3 task-size proxy and granularity knob).
//! * Two task types per step and subset: **compute** (type 1: write the
//!   subset's new states from current states of the subset and its
//!   neighbours) and **swap** (type 2: publish new states into current).
//! * The recipe holds the subset id and the type flag; creation does no
//!   other work (the paper's chosen depth for this experiment).
//! * Record rules:
//!   - compute(b) depends on a previously-encountered swap(b') with
//!     `b' = b` or `b' ~ b` in the aggregate graph (paper, verbatim);
//!   - swap(b) depends on a previously-encountered compute(b') with
//!     `b' = b` **or `b' ~ b`** — the paper states "the same agent subset"
//!     only, but compute(b') *reads* current states of connected subsets,
//!     which swap(b) writes; the literal rule admits executions that
//!     diverge from the sequential semantics (our determinism suite
//!     detects this), so we use the conservative correction. See DESIGN.md
//!     §2 "Documented protocol deviation".
//! * The subset adjacency ("aggregate graph") is computed once after
//!   initial-state generation and, following the paper, *is* part of the
//!   measured run when using [`SirModel::build_timed`].

use crate::model::{Model, Record, TaskSource};
use crate::protocol::SyncModel;
use crate::sim::graph::{aggregate_graph, contact_graph, contiguous_partition, Csr, Partition};
use crate::sim::rng::{Rng, TaskRng};
use crate::sim::soa::{Layout, PackedStates, Relabeling};
use crate::sim::state::SharedSim;
use crate::util::bitset::BitSet;

/// SIR health occupies 2 bits per agent when packed (3 states).
const SIR_BITS: u32 = 2;

/// Agent epidemic state.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum Health {
    /// Susceptible.
    S = 0,
    /// Infected.
    I = 1,
    /// Recovered.
    R = 2,
}

/// Model parameters (paper values in parentheses).
#[derive(Clone, Copy, Debug)]
pub struct SirParams {
    /// Number of agents (4×10³).
    pub agents: usize,
    /// Ring-lattice degree `k` (14).
    pub degree: usize,
    /// Infection probability scale `p_SI` (0.8).
    pub p_si: f64,
    /// Recovery probability `p_IR` (0.1).
    pub p_ir: f64,
    /// Immunity-loss probability `p_RS` (0.3).
    pub p_rs: f64,
    /// Steps (3×10³).
    pub steps: u64,
    /// Subset size `s` — Fig. 3's task-size proxy / chain granularity.
    pub subset_size: usize,
    /// Initially infected fraction (not specified in the paper; fixed at
    /// 0.1 so the epidemic neither dies out instantly nor saturates).
    pub initial_infected: f64,
    /// Seeded long-range strides added to the ring lattice (the scale
    /// tier's contact graph, ISSUE 10). Each adds 2 to every vertex's
    /// degree; `0` is the paper's pure ring lattice, byte-identical to
    /// every prior run.
    pub long_links: usize,
}

impl Default for SirParams {
    fn default() -> Self {
        Self {
            agents: 4_000,
            degree: 14,
            p_si: 0.8,
            p_ir: 0.1,
            p_rs: 0.3,
            steps: 3_000,
            subset_size: 100,
            initial_infected: 0.1,
            long_links: 0,
        }
    }
}

impl SirParams {
    /// The paper's Fig. 3 configuration at subset size `s`.
    pub fn paper(subset_size: usize) -> Self {
        Self {
            subset_size,
            ..Self::default()
        }
    }

    /// Scaled-down configuration for CI-sized runs.
    pub fn scaled(subset_size: usize, agents: usize, steps: u64) -> Self {
        Self {
            agents,
            steps,
            subset_size,
            ..Self::paper(subset_size)
        }
    }

    /// Number of subsets `P`.
    pub fn blocks(&self) -> usize {
        self.agents.div_ceil(self.subset_size)
    }
}

/// Double-buffered epidemic state (legacy AoS layout).
pub struct SirState {
    /// Current states (read by compute, written by swap).
    pub cur: Vec<u8>,
    /// Next states (written by compute, read by swap).
    pub new: Vec<u8>,
}

/// Storage backend for the double buffer, selected by [`Layout`].
enum SirStore {
    /// One byte per agent in two plain vectors.
    Legacy(SharedSim<SirState>),
    /// 2-bit lanes; under [`Layout::Packed`] the buffers are word-aligned
    /// per block so swap publishes whole words.
    Packed {
        cur: PackedStates,
        new: PackedStates,
    },
}

/// The pluggable model.
pub struct SirModel {
    /// Parameters.
    pub params: SirParams,
    graph: Csr,
    partition: Partition,
    /// The aggregate (block-adjacency) graph; doubles as the sharded
    /// scheduler's footprint topology.
    aggregate: Csr,
    /// Per-block dependence mask: `{b} ∪ neighbours(b)` in the aggregate
    /// graph. Shared with every worker record.
    masks: std::sync::Arc<Vec<BitSet>>,
    store: SirStore,
    layout: Layout,
    /// Time spent building the aggregate graph (part of measured T per the
    /// paper; reported so benches can add it).
    pub setup_cost: std::time::Duration,
}

impl SirModel {
    /// Build the model with the ambient default layout
    /// ([`Layout::env_default`]).
    pub fn new(params: SirParams, init_seed: u64) -> Self {
        Self::with_layout(params, init_seed, Layout::env_default())
    }

    /// Build the model: graph, initial state (untimed, from `init_seed`),
    /// partition and aggregate graph (timed — the paper includes this in
    /// `T`). The layout selects the state store; the initial-state RNG
    /// stream and every logical id are layout-independent, so all layouts
    /// start (and stay) byte-identical.
    pub fn with_layout(params: SirParams, init_seed: u64, layout: Layout) -> Self {
        // `long_links = 0` makes this exactly the paper's ring lattice.
        let graph = contact_graph(params.agents, params.degree, params.long_links, init_seed);
        let mut rng = Rng::stream(init_seed, 0x51A);
        let cur: Vec<u8> = (0..params.agents)
            .map(|_| {
                if rng.bernoulli(params.initial_infected) {
                    Health::I as u8
                } else {
                    Health::S as u8
                }
            })
            .collect();

        let t0 = std::time::Instant::now();
        let partition = contiguous_partition(params.agents, params.subset_size);
        // Ragged-tail hardening: the partition, the parameter-level block
        // count, and the per-block member lists must tell one story even
        // when `subset_size` does not divide `agents`.
        assert_eq!(
            partition.blocks(),
            params.blocks(),
            "partition disagrees with SirParams::blocks() at agents={} s={}",
            params.agents,
            params.subset_size
        );
        assert_eq!(
            (0..partition.blocks()).map(|b| partition.members(b).len()).sum::<usize>(),
            params.agents,
            "partition must cover every agent exactly once"
        );
        let agg = aggregate_graph(&graph, &partition);
        let blocks = partition.blocks();
        let mut masks = Vec::with_capacity(blocks);
        for b in 0..blocks {
            let mut m = BitSet::new(blocks);
            m.set(b);
            for &nb in agg.neighbors(b) {
                m.set(nb as usize);
            }
            masks.push(m);
        }
        let setup_cost = t0.elapsed();

        let store = match layout {
            Layout::Legacy => {
                let new = cur.clone();
                SirStore::Legacy(SharedSim::new(SirState { cur, new }))
            }
            Layout::Packed | Layout::PackedLinear => {
                // The contiguous partition makes block-by-block slot
                // assignment the identity, so Packed's only physical
                // difference from PackedLinear is word alignment of
                // blocks (and the whole-word swap it enables).
                let pc = match layout {
                    Layout::Packed => PackedStates::block_aligned(SIR_BITS, &partition),
                    _ => PackedStates::new(SIR_BITS, &Relabeling::identity(params.agents)),
                };
                for (i, &v) in cur.iter().enumerate() {
                    pc.set(i, v);
                }
                let pn = pc.duplicate();
                SirStore::Packed { cur: pc, new: pn }
            }
        };
        Self {
            params,
            graph,
            partition,
            aggregate: agg,
            masks: std::sync::Arc::new(masks),
            store,
            layout,
            setup_cost,
        }
    }

    /// The active storage layout.
    pub fn layout(&self) -> Layout {
        self.layout
    }

    /// Number of subsets.
    pub fn blocks(&self) -> usize {
        self.partition.blocks()
    }

    /// The interaction graph.
    pub fn graph(&self) -> &Csr {
        &self.graph
    }

    /// The fixed partition.
    pub fn partition(&self) -> &Partition {
        &self.partition
    }

    /// Constant vertex degree of the contact graph: the ring-lattice
    /// band plus both ends of every long-range stride.
    pub fn effective_degree(&self) -> usize {
        self.params.degree + 2 * self.params.long_links
    }

    /// Snapshot of current states (quiescent use).
    pub fn snapshot(&self) -> Vec<u8> {
        match &self.store {
            SirStore::Legacy(st) => unsafe { st.get() }.cur.clone(),
            SirStore::Packed { cur, .. } => cur.snapshot_bytes(),
        }
    }

    /// Raw state access for the XLA task engine (crate-internal). Only
    /// the legacy layout exposes plain buffers; the XLA engine gates on
    /// [`SirModel::layout`] at manifest load.
    ///
    /// # Safety
    /// Same contract as `SharedSim::get_mut`: caller must uphold the
    /// record discipline for everything it touches.
    pub(crate) unsafe fn state_mut(&self) -> &mut SirState {
        match &self.store {
            SirStore::Legacy(st) => st.get_mut(),
            SirStore::Packed { .. } => {
                panic!("SirModel::state_mut needs the legacy layout (ADAPAR_LAYOUT=legacy)")
            }
        }
    }

    /// (S, I, R) counts (quiescent use).
    pub fn census(&self) -> (usize, usize, usize) {
        let mut c = [0usize; 3];
        match &self.store {
            SirStore::Legacy(st) => {
                for &s in &unsafe { st.get() }.cur {
                    c[s as usize] += 1;
                }
            }
            SirStore::Packed { cur, .. } => {
                for i in 0..self.params.agents {
                    c[cur.get(i) as usize] += 1;
                }
            }
        }
        (c[0], c[1], c[2])
    }

    /// One agent's compute transition — shared by both storage backends
    /// so the two paths cannot drift. Draws exactly one uniform per agent
    /// so the stream is schedule- and layout-independent.
    #[inline]
    fn compute_block_with(
        &self,
        block: usize,
        rng: &mut TaskRng,
        read: impl Fn(usize) -> u8,
        mut write: impl FnMut(usize, u8),
    ) {
        let k = self.effective_degree() as f64;
        for &a in self.partition.members(block) {
            let a = a as usize;
            let u = rng.unit_f64();
            let next = match read(a) {
                0 => {
                    // S → I with p_SI · (infected neighbour fraction)
                    let infected = self
                        .graph
                        .neighbors(a)
                        .iter()
                        .filter(|&&nb| read(nb as usize) == 1)
                        .count();
                    if u < self.params.p_si * (infected as f64 / k) {
                        1
                    } else {
                        0
                    }
                }
                1 => {
                    if u < self.params.p_ir {
                        2
                    } else {
                        1
                    }
                }
                _ => {
                    if u < self.params.p_rs {
                        0
                    } else {
                        2
                    }
                }
            };
            write(a, next);
        }
    }

    /// Compute phase for one block: write `new` states of the block's
    /// agents from `cur` states.
    fn compute_block(&self, block: usize, rng: &mut TaskRng) {
        match &self.store {
            SirStore::Legacy(st) => {
                // SAFETY: record discipline — no concurrent swap of this
                // block or a connected block (they write `cur` rows we
                // read), no concurrent compute of this block (writes our
                // `new` rows). Distinct-block computes write disjoint
                // `new` rows and only share reads of `cur`. (DESIGN.md §6.)
                let state = unsafe { st.get_mut() };
                let SirState { cur, new } = state;
                self.compute_block_with(block, rng, |a| cur[a], |a, v| new[a] = v);
            }
            // Same record discipline; lane-level CAS additionally keeps
            // writes lossless where independent blocks share a word (the
            // unaligned PackedLinear case).
            SirStore::Packed { cur, new } => {
                self.compute_block_with(block, rng, |a| cur.get(a), |a, v| new.set(a, v));
            }
        }
    }

    /// Swap phase for one block: publish `new` into `cur`.
    fn swap_block(&self, block: usize) {
        match &self.store {
            SirStore::Legacy(st) => {
                // SAFETY: record discipline — no concurrent compute of
                // this or a connected block (they read our `cur` rows);
                // swaps of distinct blocks touch disjoint rows.
                // (DESIGN.md §6.)
                let state = unsafe { st.get_mut() };
                for &a in self.partition.members(block) {
                    state.cur[a as usize] = state.new[a as usize];
                }
            }
            SirStore::Packed { cur, new } => {
                if cur.is_block_aligned() {
                    // The block owns its words outright: publish them whole.
                    cur.copy_block_from(new, block);
                } else {
                    for &a in self.partition.members(block) {
                        cur.set(a as usize, new.get(a as usize));
                    }
                }
            }
        }
    }

    /// The canonical task sequence number for `(step, phase, block)` —
    /// shared by the chain engines (via source order) and the stepwise
    /// baseline so that all engines use identical RNG streams.
    pub fn task_seq(&self, step: u64, phase: usize, block: usize) -> u64 {
        let p = self.blocks() as u64;
        step * 2 * p + phase as u64 * p + block as u64
    }
}

/// Task type flag (paper: "a binary flag indicating the task's type").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SirPhase {
    /// Type 1: compute new states of a subset.
    Compute,
    /// Type 2: publish new states of a subset.
    Swap,
}

/// Task payload: subset id + type flag.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SirTask {
    /// Which task type.
    pub phase: SirPhase,
    /// Subset (block) id.
    pub block: u32,
}

/// Worker record: which subsets appeared in absorbed compute/swap tasks.
pub struct SirRecord {
    seen_compute: BitSet,
    seen_swap: BitSet,
    masks: std::sync::Arc<Vec<BitSet>>,
}

impl Record for SirRecord {
    type Recipe = SirTask;

    #[inline]
    fn depends(&self, r: &SirTask) -> bool {
        let mask = &self.masks[r.block as usize];
        match r.phase {
            // compute(b) reads cur[b ∪ nbrs(b)]: conflicts with absorbed
            // swaps there (paper's rule, verbatim).
            SirPhase::Compute => self.seen_swap.intersects(mask),
            // swap(b) writes cur[b]: conflicts with absorbed computes of b
            // or connected blocks (conservative correction, see module
            // docs).
            SirPhase::Swap => self.seen_compute.intersects(mask),
        }
    }

    #[inline]
    fn absorb(&mut self, r: &SirTask) {
        match r.phase {
            SirPhase::Compute => self.seen_compute.set(r.block as usize),
            SirPhase::Swap => self.seen_swap.set(r.block as usize),
        }
    }

    #[inline]
    fn reset(&mut self) {
        self.seen_compute.clear();
        self.seen_swap.clear();
    }
}

/// Task source: `steps × (P computes, then P swaps)`, no creation-time
/// randomness.
pub struct SirSource {
    blocks: u64,
    steps: u64,
    next: u64,
}

impl TaskSource for SirSource {
    type Recipe = SirTask;

    fn next_task(&mut self) -> Option<SirTask> {
        let total = self.steps * 2 * self.blocks;
        if self.next >= total {
            return None;
        }
        let within = self.next % (2 * self.blocks);
        let task = if within < self.blocks {
            SirTask {
                phase: SirPhase::Compute,
                block: within as u32,
            }
        } else {
            SirTask {
                phase: SirPhase::Swap,
                block: (within - self.blocks) as u32,
            }
        };
        self.next += 1;
        Some(task)
    }

    fn size_hint(&self) -> Option<u64> {
        Some(self.steps * 2 * self.blocks - self.next)
    }
}

// The masks are shared between the model and every record; an Arc avoids
// per-record clones of the whole mask table.
impl Model for SirModel {
    type Recipe = SirTask;
    type Record = SirRecord;
    type Source = SirSource;

    fn source(&self, _seed: u64) -> SirSource {
        SirSource {
            blocks: self.blocks() as u64,
            steps: self.params.steps,
            next: 0,
        }
    }

    fn record(&self) -> SirRecord {
        SirRecord {
            seen_compute: BitSet::new(self.blocks()),
            seen_swap: BitSet::new(self.blocks()),
            masks: self.masks.clone(),
        }
    }

    fn execute(&self, r: &SirTask, rng: &mut TaskRng) {
        match r.phase {
            SirPhase::Compute => self.compute_block(r.block as usize, rng),
            SirPhase::Swap => self.swap_block(r.block as usize),
        }
    }

    fn task_work(&self, r: &SirTask) -> f64 {
        let members = self.partition.members(r.block as usize).len() as f64;
        match r.phase {
            // Per-agent: one RNG draw + a k-neighbour scan when susceptible.
            SirPhase::Compute => members * (1.0 + self.effective_degree() as f64 * 0.5),
            SirPhase::Swap => members * 0.25,
        }
    }

    /// Structural state traffic, averaged over the two task types: a
    /// compute reads ~μ·(k+1) lanes and writes μ, a swap moves 2μ lanes
    /// (μ = mean block size, k = degree) → μ·(k+4)/2 lanes per task,
    /// scaled by the layout's bytes per lane (1 legacy, 1/4 packed).
    fn state_bytes_per_task(&self) -> f64 {
        let mu = self.params.agents as f64 / self.blocks() as f64;
        let lane_bytes = match &self.store {
            SirStore::Legacy(_) => 1.0,
            SirStore::Packed { cur, .. } => cur.bytes_per_lane(),
        };
        mu * (self.effective_degree() as f64 + 4.0) / 2.0 * lane_bytes
    }
}

impl crate::sched::ShardableModel for SirModel {
    /// Footprint blocks are the model's own agent subsets; their
    /// interaction topology is the aggregate graph (ring-like for the
    /// paper's configuration, so BFS sharding yields near-contiguous
    /// runs of subsets with narrow seams between shards).
    fn sched_topology(&self) -> Csr {
        self.aggregate.clone()
    }

    /// Conservative footprint of either phase: `{b} ∪ neighbours(b)` in
    /// the aggregate graph — exactly the mask [`SirRecord::depends`]
    /// tests against, so disjoint footprints imply independence.
    fn footprint(&self, r: &SirTask, out: &mut Vec<u32>) {
        out.push(r.block);
        out.extend_from_slice(self.aggregate.neighbors(r.block as usize));
    }
}

impl crate::api::observe::Observable for SirModel {
    /// The epidemic census — the paper's Fig. 3 trajectory quantity.
    fn observe(&self) -> crate::api::observe::Metrics {
        let (s, i, r) = self.census();
        vec![(
            "census".to_string(),
            crate::api::observe::ObsValue::counts([
                ("S", s as i64),
                ("I", i as i64),
                ("R", r as i64),
            ]),
        )]
    }
}

impl SyncModel for SirModel {
    fn steps(&self) -> u64 {
        self.params.steps
    }
    fn phases(&self) -> usize {
        2
    }
    fn blocks(&self, _phase: usize) -> usize {
        self.partition.blocks()
    }
    fn run_block(&self, seed: u64, step: u64, phase: usize, block: usize) {
        let mut rng = TaskRng::for_task(seed, self.task_seq(step, phase, block));
        match phase {
            0 => self.compute_block(block, &mut rng),
            _ => self.swap_block(block),
        }
    }
    fn state_bytes_per_task(&self) -> f64 {
        Model::state_bytes_per_task(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::{ParallelEngine, ProtocolConfig, SequentialEngine, StepwiseEngine};

    fn small(s: usize) -> SirParams {
        SirParams::scaled(s, 300, 40)
    }

    #[test]
    fn source_order_is_computes_then_swaps_per_step() {
        let m = SirModel::new(small(50), 0);
        let mut src = m.source(0);
        let p = m.blocks();
        for step in 0..2 {
            for b in 0..p {
                let t = src.next_task().unwrap();
                assert_eq!((t.phase, t.block), (SirPhase::Compute, b as u32), "step {step}");
            }
            for b in 0..p {
                let t = src.next_task().unwrap();
                assert_eq!((t.phase, t.block), (SirPhase::Swap, b as u32));
            }
        }
    }

    #[test]
    fn census_conserves_agents_and_epidemic_moves() {
        let m = SirModel::new(small(50), 1);
        let (s0, i0, r0) = m.census();
        assert_eq!(s0 + i0 + r0, 300);
        assert!(i0 > 0, "some agents start infected");
        assert_eq!(r0, 0);
        SequentialEngine::new(3).run(&m);
        let (s1, i1, r1) = m.census();
        assert_eq!(s1 + i1 + r1, 300);
        assert!(r1 > 0 || i1 != i0, "dynamics must move the state");
    }

    #[test]
    fn parallel_matches_sequential_bitwise() {
        let seed = 13;
        for s in [10, 30, 150] {
            let reference = {
                let m = SirModel::new(small(s), 5);
                SequentialEngine::new(seed).run(&m);
                m.snapshot()
            };
            for workers in [1, 2, 4] {
                let m = SirModel::new(small(s), 5);
                ParallelEngine::new(ProtocolConfig {
                    workers,
                    seed,
                    ..Default::default()
                })
                .run(&m);
                assert_eq!(m.snapshot(), reference, "s={s} n={workers} diverged");
            }
        }
    }

    #[test]
    fn stepwise_matches_sequential_bitwise() {
        let seed = 21;
        let reference = {
            let m = SirModel::new(small(30), 2);
            SequentialEngine::new(seed).run(&m);
            m.snapshot()
        };
        for workers in [1, 2, 3] {
            let m = SirModel::new(small(30), 2);
            StepwiseEngine::new(workers, seed).run(&m);
            assert_eq!(m.snapshot(), reference, "stepwise n={workers} diverged");
        }
    }

    #[test]
    fn record_rules() {
        let m = SirModel::new(small(30), 0);
        let mut rec = m.record();
        let c0 = SirTask { phase: SirPhase::Compute, block: 0 };
        let s0 = SirTask { phase: SirPhase::Swap, block: 0 };
        let s1 = SirTask { phase: SirPhase::Swap, block: 1 };
        let c5 = SirTask { phase: SirPhase::Compute, block: 5 };

        assert!(!rec.depends(&c0) && !rec.depends(&s0));
        rec.absorb(&c0);
        assert!(rec.depends(&s0), "swap(0) after pending compute(0)");
        assert!(rec.depends(&s1), "swap(1) conflicts with compute(0): compute(0) reads cur of connected block 1 (conservative correction)");
        assert!(!rec.depends(&c5), "far-away compute is independent");

        rec.reset();
        rec.absorb(&s0);
        assert!(rec.depends(&c0), "compute(0) after pending swap(0)");
        let c1 = SirTask { phase: SirPhase::Compute, block: 1 };
        assert!(rec.depends(&c1), "compute(1) reads cur of connected block 0");
        assert!(!rec.depends(&c5));
    }

    #[test]
    fn task_seq_mapping_is_bijective_over_a_step() {
        let m = SirModel::new(small(30), 0);
        let p = m.blocks();
        let mut seen = std::collections::BTreeSet::new();
        for step in 0..3 {
            for phase in 0..2 {
                for b in 0..p {
                    assert!(seen.insert(m.task_seq(step, phase, b)));
                }
            }
        }
        assert_eq!(seen.len(), 3 * 2 * p);
        assert_eq!(*seen.iter().next().unwrap(), 0);
        assert_eq!(*seen.iter().last().unwrap(), (3 * 2 * p - 1) as u64);
    }

    #[test]
    fn long_links_raise_degree_and_stay_deterministic() {
        let params = SirParams {
            long_links: 3,
            ..small(30)
        };
        let seed = 17;
        let reference = {
            let m = SirModel::new(params, 5);
            assert_eq!(m.effective_degree(), 14 + 6);
            for v in 0..m.params.agents {
                assert_eq!(m.graph().degree(v), 20, "degree stays constant");
            }
            SequentialEngine::new(seed).run(&m);
            m.snapshot()
        };
        for workers in [2, 4] {
            let m = SirModel::new(params, 5);
            ParallelEngine::new(ProtocolConfig {
                workers,
                seed,
                ..Default::default()
            })
            .run(&m);
            assert_eq!(m.snapshot(), reference, "n={workers} diverged");
        }
        // `long_links = 0` keeps the paper's exact ring lattice.
        let plain = SirModel::new(small(30), 5);
        assert_eq!(
            plain.graph(),
            &crate::sim::graph::ring_lattice(300, 14),
            "zero long links must reproduce the ring lattice"
        );
    }

    #[test]
    fn setup_cost_is_measured() {
        let m = SirModel::new(small(10), 0);
        // Aggregate-graph construction takes nonzero (but tiny) time.
        assert!(m.setup_cost.as_nanos() > 0);
    }

    #[test]
    fn every_layout_is_byte_identical() {
        use crate::sim::soa::Layout;
        let reference = {
            let m = SirModel::with_layout(small(30), 5, Layout::Legacy);
            SequentialEngine::new(9).run(&m);
            m.snapshot()
        };
        for layout in Layout::ALL {
            let m = SirModel::with_layout(small(30), 5, layout);
            assert_eq!(m.layout(), layout);
            SequentialEngine::new(9).run(&m);
            assert_eq!(m.snapshot(), reference, "{layout} diverged from legacy");
        }
    }

    #[test]
    fn packed_layout_shrinks_bytes_per_task() {
        use crate::sim::soa::Layout;
        let legacy = SirModel::with_layout(small(30), 0, Layout::Legacy);
        let packed = SirModel::with_layout(small(30), 0, Layout::Packed);
        assert!(legacy.state_bytes_per_task() > 0.0);
        // 2-bit lanes: exactly a 4× structural reduction.
        assert_eq!(
            packed.state_bytes_per_task() * 4.0,
            legacy.state_bytes_per_task()
        );
    }
}
