//! Voter model on an arbitrary graph — a second *sequential* pairwise MABS
//! exercising the protocol interface.
//!
//! Each step draws a random *listener* and a uniformly random neighbour
//! (*speaker*); the listener adopts the speaker's opinion. Tasks are tiny
//! (a single copy), making this the stress model for protocol-overhead
//! ablations: virtually all time is protocol, none is model.
//!
//! Protocol mapping mirrors Axelrod: recipe = (speaker, listener); only
//! listeners are written, so the record keeps the set of absorbed
//! listeners.

use std::sync::Arc;

use crate::model::{Model, Record, TaskSource};
use crate::sim::graph::{bfs_partition, Csr};
use crate::sim::rng::{Rng, TaskRng};
use crate::sim::soa::{bits_for, Layout, PackedStates, Relabeling};
use crate::sim::state::SharedSim;
use crate::util::u32set::U32Set;

/// Parameters.
#[derive(Clone, Copy, Debug)]
pub struct VoterParams {
    /// Number of opinions.
    pub opinions: u8,
    /// Number of update steps (== tasks).
    pub steps: u64,
}

impl Default for VoterParams {
    fn default() -> Self {
        Self {
            opinions: 2,
            steps: 100_000,
        }
    }
}

/// Storage backend for the opinion array, selected by [`Layout`].
enum OpinionStore {
    /// One byte per agent.
    Legacy(SharedSim<Vec<u8>>),
    /// `bits_for(opinions)`-bit lanes; under [`Layout::Packed`] agent
    /// slots follow a BFS partition of the voter graph so neighbourhoods
    /// are word-adjacent.
    Packed(PackedStates),
}

/// The pluggable model. Owns the topology (any connected graph works).
pub struct VoterModel {
    /// Parameters.
    pub params: VoterParams,
    graph: Arc<Csr>,
    store: OpinionStore,
    layout: Layout,
}

impl VoterModel {
    /// Build with uniform random initial opinions under the ambient
    /// default layout ([`Layout::env_default`]).
    pub fn new(graph: Csr, params: VoterParams, init_seed: u64) -> Self {
        Self::with_layout(graph, params, init_seed, Layout::env_default())
    }

    /// Build with an explicit storage layout. The initial-opinion RNG
    /// stream is drawn in logical id order regardless of layout, so all
    /// layouts start byte-identical.
    pub fn with_layout(graph: Csr, params: VoterParams, init_seed: u64, layout: Layout) -> Self {
        let mut rng = Rng::stream(init_seed, 0x707E);
        let opinions: Vec<u8> = (0..graph.n())
            .map(|_| rng.below(params.opinions as u64) as u8)
            .collect();
        let store = match layout {
            Layout::Legacy => OpinionStore::Legacy(SharedSim::new(opinions)),
            Layout::Packed | Layout::PackedLinear => {
                let n = graph.n();
                let order = if layout == Layout::Packed {
                    // ~64 agents per block: one cache line of byte-lanes,
                    // a word or two once packed.
                    let blocks = (n / 64).clamp(1, n.max(1));
                    Relabeling::from_partition(&bfs_partition(&graph, blocks))
                } else {
                    Relabeling::identity(n)
                };
                let ps = PackedStates::new(bits_for(params.opinions.max(1) as usize), &order);
                for (i, &v) in opinions.iter().enumerate() {
                    ps.set(i, v);
                }
                OpinionStore::Packed(ps)
            }
        };
        Self {
            params,
            graph: Arc::new(graph),
            store,
            layout,
        }
    }

    /// The active storage layout.
    pub fn layout(&self) -> Layout {
        self.layout
    }

    /// Snapshot of opinions (quiescent use).
    pub fn snapshot(&self) -> Vec<u8> {
        match &self.store {
            OpinionStore::Legacy(ops) => unsafe { ops.get() }.clone(),
            OpinionStore::Packed(ps) => ps.snapshot_bytes(),
        }
    }

    /// Count of agents holding each opinion.
    pub fn tally(&self) -> Vec<usize> {
        let mut out = vec![0usize; self.params.opinions as usize];
        match &self.store {
            OpinionStore::Legacy(ops) => {
                for &o in unsafe { ops.get() }.iter() {
                    out[o as usize] += 1;
                }
            }
            OpinionStore::Packed(ps) => {
                for i in 0..ps.len() {
                    out[ps.get(i) as usize] += 1;
                }
            }
        }
        out
    }
}

impl crate::sched::ShardableModel for VoterModel {
    /// Footprint blocks are the agents themselves; the interaction
    /// topology is the voter graph (speakers are always neighbours of
    /// their listener, so BFS sharding keeps most pairs shard-local).
    fn sched_topology(&self) -> crate::sim::graph::Csr {
        (*self.graph).clone()
    }

    /// A step reads `{speaker, listener}` and writes `{listener}`; the
    /// listener leads as the home block (it is the written agent).
    fn footprint(&self, r: &VoterStep, out: &mut Vec<u32>) {
        out.push(r.listener);
        if r.speaker != r.listener {
            out.push(r.speaker);
        }
    }
}

impl crate::api::observe::Observable for VoterModel {
    /// Opinion census (labelled by opinion index) plus the number of
    /// surviving opinions ("domains").
    fn observe(&self) -> crate::api::observe::Metrics {
        use crate::api::observe::ObsValue;
        let tally = self.tally();
        let surviving = tally.iter().filter(|&&n| n > 0).count();
        vec![
            (
                "tally".to_string(),
                ObsValue::Counts(
                    tally
                        .iter()
                        .enumerate()
                        .map(|(op, &n)| (op.to_string(), n as i64))
                        .collect(),
                ),
            ),
            ("opinions".to_string(), ObsValue::Int(surviving as i64)),
        ]
    }
}

/// Task payload.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct VoterStep {
    /// Opinion source (read).
    pub speaker: u32,
    /// Opinion adopter (written).
    pub listener: u32,
}

/// Record: listeners (written) and speakers (read) of absorbed tasks.
/// Both are needed for the same reason as in `models::axelrod`: writing an
/// agent that a pending earlier task will *read* (write-after-read) must
/// also be ordered.
pub struct VoterRecord {
    listeners: U32Set,
    speakers: U32Set,
}

impl Record for VoterRecord {
    type Recipe = VoterStep;
    #[inline]
    fn depends(&self, r: &VoterStep) -> bool {
        self.listeners.contains(r.speaker)
            || self.listeners.contains(r.listener)
            || self.speakers.contains(r.listener)
    }
    #[inline]
    fn absorb(&mut self, r: &VoterStep) {
        self.listeners.insert(r.listener);
        self.speakers.insert(r.speaker);
    }
    #[inline]
    fn reset(&mut self) {
        self.listeners.clear();
        self.speakers.clear();
    }
}

/// Source: draws (listener, uniform neighbour) pairs.
pub struct VoterSource {
    rng: Rng,
    graph: Arc<Csr>,
    remaining: u64,
}

impl TaskSource for VoterSource {
    type Recipe = VoterStep;
    fn next_task(&mut self) -> Option<VoterStep> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        let listener = self.rng.index(self.graph.n());
        let nbrs = self.graph.neighbors(listener);
        let speaker = *self.rng.choose(nbrs);
        Some(VoterStep {
            speaker,
            listener: listener as u32,
        })
    }
    fn size_hint(&self) -> Option<u64> {
        Some(self.remaining)
    }
}

impl Model for VoterModel {
    type Recipe = VoterStep;
    type Record = VoterRecord;
    type Source = VoterSource;

    fn source(&self, seed: u64) -> VoterSource {
        VoterSource {
            rng: Rng::stream(seed, 0x0707),
            graph: self.graph.clone(),
            remaining: self.params.steps,
        }
    }

    fn record(&self) -> VoterRecord {
        VoterRecord {
            listeners: U32Set::new(),
            speakers: U32Set::new(),
        }
    }

    fn execute(&self, r: &VoterStep, _rng: &mut TaskRng) {
        match &self.store {
            OpinionStore::Legacy(st) => {
                // SAFETY: record discipline — only row `listener` is
                // written; the speaker row is only read and no absorbed
                // incomplete task wrote either (DESIGN.md §6).
                unsafe {
                    let ops = st.get_mut();
                    ops[r.listener as usize] = ops[r.speaker as usize];
                }
            }
            // Same discipline; the CAS lane write stays lossless when an
            // independent task's listener shares the listener's word.
            OpinionStore::Packed(ps) => {
                ps.set(r.listener as usize, ps.get(r.speaker as usize));
            }
        }
    }

    fn task_work(&self, _r: &VoterStep) -> f64 {
        1.0
    }

    /// A step reads one lane (speaker) and writes one (listener).
    fn state_bytes_per_task(&self) -> f64 {
        let lane_bytes = match &self.store {
            OpinionStore::Legacy(_) => 1.0,
            OpinionStore::Packed(ps) => ps.bytes_per_lane(),
        };
        2.0 * lane_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::{ParallelEngine, ProtocolConfig, SequentialEngine};
    use crate::sim::graph::ring_lattice;

    fn model(steps: u64, seed: u64) -> VoterModel {
        VoterModel::new(
            ring_lattice(200, 6),
            VoterParams {
                opinions: 3,
                steps,
            },
            seed,
        )
    }

    #[test]
    fn tally_is_conserved() {
        let m = model(5_000, 4);
        assert_eq!(m.tally().iter().sum::<usize>(), 200);
        SequentialEngine::new(8).run(&m);
        assert_eq!(m.tally().iter().sum::<usize>(), 200);
    }

    #[test]
    fn parallel_matches_sequential_bitwise() {
        let seed = 31;
        let reference = {
            let m = model(8_000, 6);
            SequentialEngine::new(seed).run(&m);
            m.snapshot()
        };
        for workers in [2, 4] {
            let m = model(8_000, 6);
            ParallelEngine::new(ProtocolConfig {
                workers,
                seed,
                ..Default::default()
            })
            .run(&m);
            assert_eq!(m.snapshot(), reference, "n={workers}");
        }
    }

    #[test]
    fn every_layout_is_byte_identical() {
        let seed = 17;
        let reference = {
            let m = VoterModel::with_layout(
                ring_lattice(200, 6),
                VoterParams { opinions: 3, steps: 4_000 },
                6,
                Layout::Legacy,
            );
            SequentialEngine::new(seed).run(&m);
            m.snapshot()
        };
        for layout in Layout::ALL {
            let m = VoterModel::with_layout(
                ring_lattice(200, 6),
                VoterParams { opinions: 3, steps: 4_000 },
                6,
                layout,
            );
            SequentialEngine::new(seed).run(&m);
            assert_eq!(m.snapshot(), reference, "{layout} diverged from legacy");
        }
    }

    #[test]
    fn packed_layout_shrinks_bytes_per_task() {
        let mk = |layout| {
            VoterModel::with_layout(
                ring_lattice(64, 4),
                VoterParams { opinions: 3, steps: 10 },
                0,
                layout,
            )
        };
        // 3 opinions → 2-bit lanes → 4× smaller than a byte per lane.
        assert_eq!(mk(Layout::Legacy).state_bytes_per_task(), 2.0);
        assert_eq!(mk(Layout::Packed).state_bytes_per_task(), 0.5);
    }

    #[test]
    fn speakers_are_neighbors() {
        let m = model(1000, 0);
        let mut src = m.source(9);
        while let Some(t) = src.next_task() {
            assert!(m
                .graph
                .neighbors(t.listener as usize)
                .contains(&t.speaker));
        }
    }
}
