//! The parallel engine: spawns `n` workers over a fresh chain and runs the
//! model to completion.

use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::api::observe::{EpochGate, ObsProbe, Observer};
use crate::chain::Chain;
use crate::chaos::FaultHook;
use crate::model::{Model, TaskSource};
use crate::telemetry::{MetricsRegistry, TelemetryMode};
use crate::trace::{TraceCore, TraceHandle, TraceMode};

use super::stats::{ProtocolStats, RunReport, StdInstruments, TimeBasis, WorkerStats};
use super::worker::{worker_loop, RunCtx};

/// Default creation batch size `B` (tasks linked per tail-lock
/// acquisition; the effective batch is additionally clamped by the
/// cycle's remaining `C` allowance — at the paper-default `C = 6` that
/// makes 6 the default effective batch). Tuned so the tail lock stops
/// being the creation bottleneck at high worker counts while a batch
/// still stays well below a cache page of recipes; `--batch 1`
/// restores the classic one-task-per-acquisition protocol byte for
/// byte.
pub const DEFAULT_BATCH: u32 = 16;

/// Workflow parameters (§3.4: "workflow parameters are, notably, n, the
/// number of workers, and C, the maximum number of created tasks per
/// cycle").
#[derive(Clone, Copy, Debug)]
pub struct ProtocolConfig {
    /// `n` — number of workers (one dedicated thread each).
    pub workers: usize,
    /// `C` — maximum tasks created per worker per cycle (paper default 6).
    /// Exact: batches are clamped to the cycle's remaining allowance,
    /// so `C` bounds per-cycle chain growth regardless of `B`.
    pub tasks_per_cycle: u32,
    /// `B` — maximum tasks linked per tail-lock acquisition
    /// ([`Chain::fill_tail`]); the effective batch is `min(B, remaining
    /// C)`, so deep batching needs `C ≥ B`. Any value yields the same
    /// canonical task order and the same final state; only lock
    /// amortization changes (DESIGN.md §3).
    pub batch: u32,
    /// Simulation seed (drives creation and per-task execution streams).
    pub seed: u64,
    /// Whether to time each task execution (small overhead; off for
    /// timing-sensitive benches, on for profiling).
    pub collect_timing: bool,
    /// Ring/aggregator layer mode (the lossless counter layer is always
    /// on). Semantically inert: any value yields the identical trace
    /// (DESIGN.md §11). Defaults from `ADAPAR_TELEMETRY`.
    pub telemetry: TelemetryMode,
    /// Causal-tracing mode (timeline spans + causal edges, DESIGN.md
    /// §12). Semantically inert like `telemetry`. Defaults from
    /// `ADAPAR_TRACE` (off unless set).
    pub trace: TraceMode,
    /// `W` — streaming materialization window (ISSUE 10, DESIGN.md §14):
    /// at most this many tasks outstanding (created, not yet erased) at
    /// any instant; `0` disables streaming (materialized epochs, the
    /// classic behavior). Semantically inert — the canonical task
    /// order, RNG streams and observation traces are byte-identical for
    /// every window — only peak arena residency changes. Defaults from
    /// `ADAPAR_WINDOW` / `ADAPAR_STREAMING` (0 unless set).
    pub window: u64,
}

impl Default for ProtocolConfig {
    fn default() -> Self {
        Self {
            workers: std::thread::available_parallelism()
                .map(std::num::NonZeroUsize::get)
                .unwrap_or(2),
            tasks_per_cycle: 6,
            batch: DEFAULT_BATCH,
            seed: 0,
            collect_timing: false,
            telemetry: TelemetryMode::env_default(),
            trace: TraceMode::env_default(),
            window: crate::model::stream::env_window(),
        }
    }
}

/// Burst padding over the per-worker creation allowance when estimating
/// peak live tasks (ISSUE 10 satellite: the clamp that keeps a huge
/// `size_hint` from ever pre-sizing an O(total-tasks) arena).
pub(crate) const LIVE_SAFETY: usize = 4;

/// Arena pre-size for a chain run: the slab only ever needs to hold the
/// *live* tasks (erased slots recycle), which the creation discipline
/// bounds at roughly `workers · max(C, B)` — padded ×[`LIVE_SAFETY`]
/// for bursts — and the source's [`size_hint`](TaskSource::size_hint)
/// bounds from above (a 100-task run should not reserve thousands of
/// slots). The live estimate also caps the hint, never the other way
/// around: a million-task hint pre-sizes only the live-task bound. A
/// streaming window (`window > 0`) additionally clamps to
/// `window + max(C, B)` — the window *is* the outstanding-task bound,
/// plus one creation burst of slack. A low estimate costs amortized
/// chunk growth, never correctness.
pub(crate) fn chain_capacity(
    hint: Option<u64>,
    workers: usize,
    tasks_per_cycle: u32,
    batch: u32,
    window: u64,
) -> usize {
    let per_worker = tasks_per_cycle.max(batch).max(1) as usize;
    let live_estimate = workers
        .max(1)
        .saturating_mul(per_worker)
        .saturating_mul(LIVE_SAFETY);
    let est = match hint {
        Some(total) => total.min(live_estimate as u64) as usize,
        None => live_estimate,
    };
    if window > 0 {
        est.min((window as usize).saturating_add(per_worker))
    } else {
        est
    }
}

/// The paper's adaptive, asynchronous shared-memory engine.
pub struct ParallelEngine {
    cfg: ProtocolConfig,
}

impl ParallelEngine {
    /// Create an engine with the given configuration.
    pub fn new(cfg: ProtocolConfig) -> Self {
        assert!(cfg.workers >= 1, "need at least one worker");
        assert!(cfg.tasks_per_cycle >= 1, "C must be at least 1");
        assert!(cfg.batch >= 1, "B must be at least 1");
        Self { cfg }
    }

    /// Configuration accessor.
    pub fn config(&self) -> &ProtocolConfig {
        &self.cfg
    }

    /// Run `model` to completion (until its task source is exhausted and
    /// every created task has been executed).
    pub fn run<M: Model>(&self, model: &M) -> RunReport {
        self.run_epochs(model, None, None)
    }

    /// Run with epoch snapshots: at every `observer.every()` canonical
    /// tasks the engine stops task creation, lets the workers **drain the
    /// chain to quiescence**, records a frame via `probe`, and resumes —
    /// so the trace is bit-identical to the sequential engine's at the
    /// same seed (DESIGN.md §6a). Snapshot time is included in the
    /// reported wall time.
    pub fn run_observed<M: Model>(
        &self,
        model: &M,
        probe: ObsProbe<'_>,
        observer: &mut Observer,
    ) -> RunReport {
        self.run_epochs(model, Some((probe, observer)), None)
    }

    /// Run under fault injection (DESIGN.md §10): each epoch's stalls
    /// become capped wall-clock sleeps taken by each worker **once**, at
    /// epoch start-up, perturbing the thread interleaving without adding
    /// any per-task branch. Determinism does not depend on timing, so an
    /// injected run must still match the sequential oracle exactly.
    pub fn run_chaos<M: Model>(&self, model: &M, hook: &mut FaultHook) -> RunReport {
        self.run_epochs(model, None, Some(hook))
    }

    /// [`run_chaos`](Self::run_chaos) with epoch snapshots.
    pub fn run_chaos_observed<M: Model>(
        &self,
        model: &M,
        probe: ObsProbe<'_>,
        observer: &mut Observer,
        hook: &mut FaultHook,
    ) -> RunReport {
        self.run_epochs(model, Some((probe, observer)), Some(hook))
    }

    /// The single run loop: one iteration per epoch (exactly one epoch
    /// when unobserved). Worker threads are scoped per epoch; the
    /// coordinating thread snapshots between scopes, when no task is in
    /// flight.
    fn run_epochs<M: Model>(
        &self,
        model: &M,
        mut obs: Option<(ObsProbe<'_>, &mut Observer)>,
        mut hook: Option<&mut FaultHook>,
    ) -> RunReport {
        let every = match &obs {
            Some((_, o)) => o.gate_cadence(),
            None => match &hook {
                Some(h) => h.every_or(u64::MAX),
                None => u64::MAX,
            },
        };
        let inner_source = model.source(self.cfg.seed);
        // Pre-size the node arena from the source's own forecast — the
        // previously launcher-only `size_hint` now shapes the hot path.
        let cap = chain_capacity(
            inner_source.size_hint(),
            self.cfg.workers,
            self.cfg.tasks_per_cycle,
            self.cfg.batch,
            self.cfg.window,
        );
        let mut chain: Chain<M::Recipe> = Chain::with_capacity(cap);
        let mut gate = EpochGate::new(inner_source);
        if self.cfg.window > 0 {
            gate.set_window(Some(crate::model::Window::new(self.cfg.window)));
        }
        let retire = gate.retire_handle();
        let source = Mutex::new(gate);
        // The registry is the single source of truth for run statistics:
        // workers publish onto their rows at each epoch's end, and the
        // report's `per_worker`/`chain` stats are views reconstructed
        // from the final snapshot.
        let mut reg = MetricsRegistry::new();
        let ids = StdInstruments::register(&mut reg);
        let tele = reg.start(self.cfg.workers, self.cfg.telemetry);
        // Causal tracing (inert, off by default): worker lanes record
        // exec spans, the coordinator lane records epoch marks.
        let trc = TraceCore::start(self.cfg.trace, self.cfg.workers, "parallel", "wall");
        let trc_coord = match &trc {
            Some(c) => c.coordinator(),
            None => TraceHandle::disabled(),
        };

        if let Some((probe, observer)) = obs.as_mut() {
            observer.record_initial(*probe);
        }
        let t0 = Instant::now();
        loop {
            // Epoch-boundary injection: resolve this epoch's wall stalls
            // (empty on clean runs) and hand them to the workers through
            // the context — consulted once per worker per epoch.
            let stalls: Vec<Duration> = match hook.as_mut() {
                Some(h) => h.next_epoch(self.cfg.workers).wall_stalls(),
                None => Vec::new(),
            };
            let ctx = RunCtx {
                chain: &chain,
                model,
                source: &source,
                seed: self.cfg.seed,
                tasks_per_cycle: self.cfg.tasks_per_cycle,
                batch: self.cfg.batch,
                collect_timing: self.cfg.collect_timing,
                stalls: &stalls,
                retire: retire.clone(),
            };
            source.lock().unwrap().open(every);
            if self.cfg.workers == 1 {
                // Run in-place: a single worker needs no extra thread,
                // which keeps T(n=1) free of spawn overhead.
                worker_loop(&ctx, 0, tele.handle(0), TraceHandle::lane(trc.as_ref(), 0), &ids);
            } else {
                std::thread::scope(|s| {
                    let handles: Vec<_> = (0..self.cfg.workers)
                        .map(|w| {
                            let ctx_ref = &ctx;
                            let ids_ref = &ids;
                            let h = tele.handle(w);
                            let th = TraceHandle::lane(trc.as_ref(), w);
                            s.spawn(move || worker_loop(ctx_ref, w, h, th, ids_ref))
                        })
                        .collect();
                    for h in handles {
                        h.join().expect("worker panicked");
                    }
                });
            }

            // Quiescent: the epoch's budget (or the source) ran out and
            // every created task has been executed.
            debug_assert!(chain.is_empty(), "epoch drained with live tasks");
            debug_assert_eq!(chain.created(), chain.erased());
            let done = {
                let mut gate = source.lock().unwrap();
                if let Some((probe, observer)) = obs.as_mut() {
                    observer.record(gate.emitted(), probe());
                }
                trc_coord.epoch_mark(gate.emitted());
                gate.finished()
            };
            if done {
                break;
            }
            chain.reopen();
            // Quiescent shrink (ISSUE 10): release arena chunks a burst
            // may have grown beyond the steady-state estimate, so
            // `arena_capacity` tracks live tasks across epochs too.
            chain.shrink_on_quiesce(cap);
        }
        let wall = t0.elapsed();

        // Publish the end-of-run chain/arena stats onto the global row,
        // fence the aggregator (workers are joined — every publish and
        // every ring sample is visible), and rebuild the report's stats
        // as views over the snapshot.
        ids.publish_chain(
            &tele,
            &ProtocolStats {
                tasks_created: chain.created(),
                tasks_executed: chain.erased(),
                max_chain_len: chain.max_len(),
                tail_locks: chain.tail_locks(),
                batch: self.cfg.batch,
                arena_capacity: chain.arena_capacity(),
                arena_high_water: chain.arena_high_water(),
                arena_recycled: chain.arena_recycled(),
                arena_live: chain.arena_live(),
                state_bytes: super::stats::state_bytes_total(
                    model.state_bytes_per_task(),
                    chain.erased(),
                ),
            },
        );
        let snap = tele.finish();
        let per_worker: Vec<WorkerStats> = (0..self.cfg.workers)
            .map(|w| WorkerStats::from_snapshot(&snap, w))
            .collect();
        let mut totals = WorkerStats::default();
        for w in &per_worker {
            totals.merge(w);
        }
        RunReport {
            engine: "parallel",
            workers: self.cfg.workers,
            time_s: wall.as_secs_f64(),
            basis: TimeBasis::Wall,
            totals,
            per_worker,
            chain: ProtocolStats::from_snapshot(&snap, self.cfg.batch),
            sched: None,
            telemetry: Some(snap),
            trace: trc.map(TraceCore::finish),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testkit::IncModel;
    use crate::protocol::SequentialEngine;

    fn run_sequentially(model: &IncModel, seed: u64) -> Vec<u64> {
        SequentialEngine::new(seed).run(model);
        model.cells_snapshot()
    }

    fn fresh(tasks: u64, n_cells: u32) -> IncModel {
        IncModel::new(tasks, n_cells)
    }

    #[test]
    fn single_worker_matches_sequential() {
        let seed = 42;
        let expected = run_sequentially(&fresh(500, 16), seed);
        let model = fresh(500, 16);
        let report = ParallelEngine::new(ProtocolConfig {
            workers: 1,
            seed,
            ..Default::default()
        })
        .run(&model);
        assert_eq!(model.cells_snapshot(), expected);
        assert_eq!(report.totals.executed, 500);
        assert_eq!(report.chain.tasks_created, 500);
    }

    #[test]
    fn four_workers_match_sequential_exactly() {
        let seed = 7;
        let expected = run_sequentially(&fresh(2000, 8), seed);
        for workers in [2, 3, 4] {
            let model = fresh(2000, 8);
            let report = ParallelEngine::new(ProtocolConfig {
                workers,
                seed,
                ..Default::default()
            })
            .run(&model);
            assert_eq!(
                model.cells_snapshot(),
                expected,
                "divergence with {workers} workers"
            );
            assert_eq!(report.totals.executed, 2000);
            assert_eq!(report.recompute_totals().executed, 2000);
        }
    }

    #[test]
    fn stats_are_consistent() {
        let model = fresh(300, 4);
        let report = ParallelEngine::new(ProtocolConfig {
            workers: 3,
            seed: 1,
            collect_timing: true,
            ..Default::default()
        })
        .run(&model);
        assert_eq!(report.totals.created, 300);
        assert_eq!(report.totals.executed, 300);
        assert_eq!(report.chain.tasks_created, 300);
        assert_eq!(report.chain.tasks_executed, 300);
        assert!(report.chain.max_chain_len >= 1);
        assert!(report.totals.cycles >= 300, "each execution ends a cycle");
        assert!(report.summary().contains("parallel"));
        assert_eq!(report.chain.batch, DEFAULT_BATCH);
        assert!(report.chain.tail_locks > 0);
        assert!(
            report.chain.tail_locks <= report.chain.tasks_created,
            "each creation lock links at least one task"
        );
        assert!(report.chain.arena_capacity >= report.chain.arena_high_water);
        assert!(report.chain.arena_high_water >= 2, "sentinels always live");
    }

    #[test]
    fn tasks_per_cycle_cap_respected_and_still_completes() {
        for c in [1, 2, 6, 64] {
            let model = fresh(400, 4);
            let report = ParallelEngine::new(ProtocolConfig {
                workers: 2,
                tasks_per_cycle: c,
                seed: 3,
                ..Default::default()
            })
            .run(&model);
            assert_eq!(report.totals.executed, 400, "C={c}");
        }
    }

    #[test]
    fn every_batch_size_is_state_identical() {
        let seed = 17;
        let expected = run_sequentially(&fresh(1500, 8), seed);
        for batch in [1, 2, 7, 16, 64] {
            for workers in [1, 2, 4] {
                let model = fresh(1500, 8);
                let report = ParallelEngine::new(ProtocolConfig {
                    workers,
                    tasks_per_cycle: 64, // C ≥ B: every batch size binds
                    batch,
                    seed,
                    ..Default::default()
                })
                .run(&model);
                assert_eq!(
                    model.cells_snapshot(),
                    expected,
                    "B={batch} n={workers} diverged"
                );
                assert_eq!(report.chain.batch, batch);
            }
        }
    }

    #[test]
    fn batching_amortizes_tail_locks() {
        let locks_at = |batch: u32| {
            let model = fresh(4_000, 64);
            let report = ParallelEngine::new(ProtocolConfig {
                workers: 1,
                tasks_per_cycle: 64,
                batch,
                seed: 5,
                ..Default::default()
            })
            .run(&model);
            assert_eq!(report.totals.executed, 4_000);
            report.chain.tail_locks
        };
        let b1 = locks_at(1);
        let b64 = locks_at(64);
        assert!(
            b64 * 10 <= b1,
            "B=64 must take ≥10× fewer creation locks than B=1: {b64} vs {b1}"
        );
    }

    #[test]
    fn arena_recycles_instead_of_growing() {
        let model = fresh(10_000, 16);
        let report = ParallelEngine::new(ProtocolConfig {
            workers: 2,
            seed: 9,
            ..Default::default()
        })
        .run(&model);
        assert_eq!(report.totals.executed, 10_000);
        assert!(
            report.chain.arena_capacity < 10_000,
            "slab must stay far below one slot per task: {}",
            report.chain.arena_capacity
        );
        assert!(
            report.chain.arena_recycled > 9_000,
            "steady state must recycle: {}",
            report.chain.arena_recycled
        );
    }

    #[test]
    fn heavy_contention_single_cell() {
        // Every task conflicts with every other: maximum dependence. The
        // protocol must serialize them while staying deadlock-free.
        let seed = 11;
        let expected = run_sequentially(&fresh(300, 1), seed);
        let model = fresh(300, 1);
        let report = ParallelEngine::new(ProtocolConfig {
            workers: 4,
            seed,
            ..Default::default()
        })
        .run(&model);
        assert_eq!(model.cells_snapshot(), expected);
        assert_eq!(report.totals.executed, 300);
        // Note: skipped/passed counters are timing-dependent (they require
        // true interleaving, which a single-core host provides only via
        // preemption), so the assertion here is determinism, not counters.
    }

    #[test]
    fn injected_runs_stay_state_identical_and_leak_free() {
        use crate::chaos::{plan, FaultHook};
        let seed = 13;
        let expected = run_sequentially(&fresh(1200, 8), seed);
        for p in plan::bundled() {
            let model = fresh(1200, 8);
            let mut hook = FaultHook::new(p.clone().with_every(300));
            let report = ParallelEngine::new(ProtocolConfig {
                workers: 3,
                seed,
                ..Default::default()
            })
            .run_chaos(&model, &mut hook);
            assert_eq!(model.cells_snapshot(), expected, "plan `{}`", p.name);
            assert_eq!(
                report.chain.arena_live, 2,
                "plan `{}`: only the sentinels may be live at teardown",
                p.name
            );
            assert!(hook.epochs() >= 2, "plan `{}` must span epochs", p.name);
        }
    }

    #[test]
    fn capacity_heuristic_respects_hint_and_floor() {
        assert_eq!(chain_capacity(Some(10), 4, 6, 16, 0), 10, "small run, small slab");
        let est = chain_capacity(None, 4, 6, 16, 0);
        assert_eq!(est, 4 * 16 * LIVE_SAFETY);
        assert_eq!(
            chain_capacity(Some(1 << 40), 4, 6, 16, 0),
            est,
            "hint caps at live estimate"
        );
        assert_eq!(chain_capacity(Some(0), 1, 1, 1, 0), 0, "arena clamps internally");
        // A streaming window additionally clamps to window + one burst.
        assert_eq!(chain_capacity(Some(1 << 40), 4, 6, 16, 32), 32 + 16);
        assert_eq!(
            chain_capacity(Some(1 << 40), 4, 6, 16, 1 << 30),
            est,
            "a huge window never raises the estimate"
        );
    }

    #[test]
    fn streaming_window_bounds_arena_and_matches_sequential() {
        // ISSUE 10: a windowed run must be state-identical to the
        // sequential engine while the arena high water stays within the
        // window (+2 sentinels) — O(W), not O(total tasks).
        let seed = 21;
        let expected = run_sequentially(&fresh(5_000, 8), seed);
        for workers in [1, 2, 4] {
            let model = fresh(5_000, 8);
            let report = ParallelEngine::new(ProtocolConfig {
                workers,
                tasks_per_cycle: 64,
                batch: 16,
                seed,
                window: 32,
                ..Default::default()
            })
            .run(&model);
            assert_eq!(model.cells_snapshot(), expected, "n={workers} diverged");
            assert_eq!(report.totals.executed, 5_000);
            assert!(
                report.chain.arena_high_water <= 32 + 2,
                "n={workers}: high water {} exceeds the window",
                report.chain.arena_high_water
            );
        }
    }

    #[test]
    fn window_of_one_serializes_but_completes() {
        let seed = 33;
        let expected = run_sequentially(&fresh(400, 4), seed);
        let model = fresh(400, 4);
        let report = ParallelEngine::new(ProtocolConfig {
            workers: 3,
            seed,
            window: 1,
            ..Default::default()
        })
        .run(&model);
        assert_eq!(model.cells_snapshot(), expected);
        assert_eq!(report.totals.executed, 400);
        assert!(report.chain.arena_high_water <= 3, "1 task + 2 sentinels");
    }
}
