//! Execution engines.
//!
//! * [`ParallelEngine`] — the paper's contribution: adaptive, asynchronous
//!   worker–chain execution (§3.3).
//! * [`SequentialEngine`] — canonical single-threaded execution; the ground
//!   truth for determinism tests and the T(n=1) baseline conceptually free
//!   of protocol overhead.
//! * [`StepwiseEngine`] — the related-work baseline the paper argues
//!   against (§2): strict per-step splitting with barriers between phases.
//!
//! All engines execute the *same* model with the *same* per-task RNG
//! streams, so their final states are bit-identical (the determinism test
//! suite's core assertion).

pub mod engine;
pub mod sequential;
pub mod stats;
pub mod stepwise;
pub mod worker;

pub use engine::{ParallelEngine, ProtocolConfig, DEFAULT_BATCH};
pub use sequential::SequentialEngine;
pub use stats::{
    post_hoc_snapshot, ProtocolStats, RunReport, SchedStats, StdInstruments, TimeBasis, WorkerStats,
};
pub use stepwise::{StepwiseEngine, SyncModel};
