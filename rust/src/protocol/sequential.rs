//! Canonical sequential execution — the semantics every parallel engine
//! must reproduce bit-for-bit, and the `T` baseline without protocol
//! overhead.

use std::time::Instant;

use crate::api::observe::{ObsProbe, Observer};
use crate::model::{Model, TaskSource};
use crate::sim::rng::TaskRng;
use crate::trace::{TraceCore, TraceHandle, TraceMode, NONE_ID, NONE_SHARD};

use super::stats::{post_hoc_snapshot, ProtocolStats, RunReport, TimeBasis, WorkerStats};

/// Single-threaded engine: executes tasks in creation order with the same
/// per-task RNG streams as the parallel engine.
#[derive(Clone, Copy, Debug, Default)]
pub struct SequentialEngine {
    /// Simulation seed.
    pub seed: u64,
    /// Causal-tracing mode (inert; sequential traces carry program-order
    /// edges, so their critical path equals their total work).
    pub trace: TraceMode,
}

impl SequentialEngine {
    /// Create with a seed (tracing defaults from `ADAPAR_TRACE`).
    pub fn new(seed: u64) -> Self {
        Self {
            seed,
            trace: TraceMode::env_default(),
        }
    }

    /// Run to source exhaustion.
    pub fn run<M: Model>(&self, model: &M) -> RunReport {
        self.run_epochs(model, None)
    }

    /// Run with epoch snapshots — the reference trace every parallel
    /// engine must reproduce byte for byte. A frame is recorded at task
    /// count 0, after every `observer.every()` executed tasks, and at the
    /// end of the run (the final partial epoch).
    pub fn run_observed<M: Model>(
        &self,
        model: &M,
        probe: ObsProbe<'_>,
        observer: &mut Observer,
    ) -> RunReport {
        self.run_epochs(model, Some((probe, observer)))
    }

    fn run_epochs<M: Model>(
        &self,
        model: &M,
        mut obs: Option<(ObsProbe<'_>, &mut Observer)>,
    ) -> RunReport {
        let mut source = model.source(self.seed);
        if let Some((probe, observer)) = obs.as_mut() {
            observer.record_initial(*probe);
        }
        let trc = TraceCore::start(self.trace, 1, "sequential", "wall");
        let th = TraceHandle::lane(trc.as_ref(), 0);
        let t0 = Instant::now();
        let mut executed = 0u64;
        while let Some(recipe) = source.next_task() {
            let mut rng = TaskRng::for_task(self.seed, executed);
            let span_t0 = if th.active() { th.now() } else { 0 };
            model.execute(&recipe, &mut rng);
            if th.active() {
                th.exec(executed, NONE_ID, NONE_SHARD, span_t0, th.now());
            }
            executed += 1;
            if let Some((probe, observer)) = obs.as_mut() {
                if observer.due(executed) {
                    observer.record(executed, probe());
                    th.epoch_mark(executed);
                }
            }
        }
        if let Some((probe, observer)) = obs.as_mut() {
            observer.record(executed, probe());
        }
        th.epoch_mark(executed);
        let wall = t0.elapsed();
        let stats = WorkerStats {
            cycles: executed,
            executed,
            created: executed,
            busy_time: wall,
            ..Default::default()
        };
        let chain = ProtocolStats {
            tasks_created: executed,
            tasks_executed: executed,
            max_chain_len: 1,
            batch: 1,
            state_bytes: super::stats::state_bytes_total(model.state_bytes_per_task(), executed),
            ..Default::default()
        };
        let per_worker = vec![stats.clone()];
        RunReport {
            engine: "sequential",
            workers: 1,
            time_s: wall.as_secs_f64(),
            basis: TimeBasis::Wall,
            totals: stats,
            telemetry: Some(post_hoc_snapshot(&per_worker, &chain)),
            per_worker,
            chain,
            sched: None,
            trace: trc.map(TraceCore::finish),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testkit::{fresh_inc_model, inc_cells};

    #[test]
    fn executes_all_tasks_in_order() {
        let model = fresh_inc_model(100, 4);
        let report = SequentialEngine::new(5).run(&model);
        assert_eq!(report.totals.executed, 100);
        assert_eq!(report.engine, "sequential");
        let cells = inc_cells(&model);
        assert!(cells.iter().any(|&c| c != 0));
    }

    #[test]
    fn same_seed_same_state_different_seed_differs() {
        let m1 = fresh_inc_model(200, 8);
        let m2 = fresh_inc_model(200, 8);
        let m3 = fresh_inc_model(200, 8);
        SequentialEngine::new(1).run(&m1);
        SequentialEngine::new(1).run(&m2);
        SequentialEngine::new(2).run(&m3);
        assert_eq!(inc_cells(&m1), inc_cells(&m2));
        assert_ne!(inc_cells(&m1), inc_cells(&m3));
    }
}
