//! Protocol execution statistics.
//!
//! The paper's overhead discussion (§4) is driven by exactly these
//! quantities: how often workers skip dependent tasks, pass executing
//! tasks, retry over erased nodes, and how long chains grow. The ablation
//! benches report them alongside wall-clock time.

use std::time::Duration;

use crate::telemetry::{
    CounterId, HistId, MetricsRegistry, TelemetryCore, TelemetrySnapshot, WorkerTelemetry,
};
use crate::util::json::Json;

/// Counters collected by one worker across a run.
#[derive(Clone, Debug, Default)]
pub struct WorkerStats {
    /// Id of the worker that produced these counters (position in
    /// `RunReport::per_worker`; meaningless on merged totals, whose JSON
    /// serialization therefore omits it). Surfaced in the `--json`
    /// report so per-worker load imbalance is visible in run output.
    pub worker: usize,
    /// Completed chain-exploration cycles.
    pub cycles: u64,
    /// Tasks executed (and erased) by this worker.
    pub executed: u64,
    /// Tasks created by this worker.
    pub created: u64,
    /// Tasks passed because the record reported a dependence.
    pub skipped_dependent: u64,
    /// Tasks passed because another worker was executing them.
    pub passed_executing: u64,
    /// Arrivals at erased nodes (forced retries from the previous node).
    pub erased_retries: u64,
    /// Cycles that neither executed nor created anything (idle spins).
    pub idle_cycles: u64,
    /// Total time spent inside `Model::execute` (only if timing enabled).
    pub exec_time: Duration,
    /// Total wall time of this worker's loop.
    pub busy_time: Duration,
}

impl WorkerStats {
    /// The counters as a JSON object (durations in seconds), including
    /// the worker id — the per-worker serialization.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![("worker".into(), Json::from(self.worker))];
        fields.extend(self.counter_fields());
        Json::Obj(fields)
    }

    /// The counters as a JSON object **without** a worker id — the
    /// serialization for merged totals, where an id would misattribute
    /// the aggregate to worker 0.
    pub fn to_json_totals(&self) -> Json {
        Json::Obj(self.counter_fields())
    }

    fn counter_fields(&self) -> Vec<(String, Json)> {
        vec![
            ("cycles".into(), Json::from(self.cycles)),
            ("executed".into(), Json::from(self.executed)),
            ("created".into(), Json::from(self.created)),
            (
                "skipped_dependent".into(),
                Json::from(self.skipped_dependent),
            ),
            ("passed_executing".into(), Json::from(self.passed_executing)),
            ("erased_retries".into(), Json::from(self.erased_retries)),
            ("idle_cycles".into(), Json::from(self.idle_cycles)),
            ("exec_time_s".into(), Json::from(self.exec_time.as_secs_f64())),
            ("busy_time_s".into(), Json::from(self.busy_time.as_secs_f64())),
        ]
    }

    /// Merge another worker's counters into this one. The `worker` id is
    /// left untouched (merged totals keep their own identity).
    pub fn merge(&mut self, o: &WorkerStats) {
        self.cycles += o.cycles;
        self.executed += o.executed;
        self.created += o.created;
        self.skipped_dependent += o.skipped_dependent;
        self.passed_executing += o.passed_executing;
        self.erased_retries += o.erased_retries;
        self.idle_cycles += o.idle_cycles;
        self.exec_time += o.exec_time;
        self.busy_time += o.busy_time;
    }

    /// Reconstruct worker `w`'s counters from a registry snapshot — the
    /// "stats are a view over the registry" direction: engines publish
    /// through [`StdInstruments`] and read back through this.
    pub fn from_snapshot(snap: &TelemetrySnapshot, w: usize) -> Self {
        WorkerStats {
            worker: w,
            cycles: snap.counter_worker("worker.cycles", w),
            executed: snap.counter_worker("worker.executed", w),
            created: snap.counter_worker("worker.created", w),
            skipped_dependent: snap.counter_worker("worker.skipped_dependent", w),
            passed_executing: snap.counter_worker("worker.passed_executing", w),
            erased_retries: snap.counter_worker("worker.erased_retries", w),
            idle_cycles: snap.counter_worker("worker.idle_cycles", w),
            exec_time: Duration::from_nanos(snap.counter_worker("worker.exec_time_ns", w)),
            busy_time: Duration::from_nanos(snap.counter_worker("worker.busy_time_ns", w)),
        }
    }
}

/// The standard instrument set every chain engine publishes through:
/// the per-worker protocol counters (`worker.*`), the chain/arena
/// counters (`chain.*`), and the two hot-path sample streams
/// (`chain.batch_fill` — tasks linked per tail-lock hold — and
/// `chain.exec_ns` — per-task execution nanoseconds, sampled only when
/// timing collection is on). [`WorkerStats`]/[`ProtocolStats`] are
/// reconstructed from the resulting snapshot, so the registry is the
/// single source of truth for run statistics.
#[derive(Clone, Copy, Debug)]
pub struct StdInstruments {
    /// `worker.cycles`
    pub cycles: CounterId,
    /// `worker.executed`
    pub executed: CounterId,
    /// `worker.created`
    pub created: CounterId,
    /// `worker.skipped_dependent`
    pub skipped_dependent: CounterId,
    /// `worker.passed_executing`
    pub passed_executing: CounterId,
    /// `worker.erased_retries`
    pub erased_retries: CounterId,
    /// `worker.idle_cycles`
    pub idle_cycles: CounterId,
    /// `worker.exec_time_ns`
    pub exec_time_ns: CounterId,
    /// `worker.busy_time_ns`
    pub busy_time_ns: CounterId,
    /// `chain.tasks_created`
    pub chain_tasks_created: CounterId,
    /// `chain.tasks_executed`
    pub chain_tasks_executed: CounterId,
    /// `chain.max_chain_len`
    pub chain_max_chain_len: CounterId,
    /// `chain.tail_locks`
    pub chain_tail_locks: CounterId,
    /// `chain.arena_capacity`
    pub chain_arena_capacity: CounterId,
    /// `chain.arena_high_water`
    pub chain_arena_high_water: CounterId,
    /// `chain.arena_recycled`
    pub chain_arena_recycled: CounterId,
    /// `chain.arena_live`
    pub chain_arena_live: CounterId,
    /// `chain.state_bytes` — total structural agent-state traffic.
    pub chain_state_bytes: CounterId,
    /// `chain.bytes_per_task` — rounded average state bytes per task
    /// (the DESIGN.md §13 layout instrument; the exact f64 average is
    /// [`ProtocolStats::bytes_per_task`]).
    pub chain_bytes_per_task: CounterId,
    /// `chain.batch_fill` — tasks linked per tail-lock acquisition.
    pub batch_fill: HistId,
    /// `chain.exec_ns` — per-task execution time in nanoseconds.
    pub exec_ns: HistId,
}

/// Saturating `Duration` → nanoseconds for counter publication.
fn duration_ns(d: Duration) -> u64 {
    u64::try_from(d.as_nanos()).unwrap_or(u64::MAX)
}

impl StdInstruments {
    /// Register the standard instrument set.
    pub fn register(reg: &mut MetricsRegistry) -> Self {
        StdInstruments {
            cycles: reg.counter("worker.cycles"),
            executed: reg.counter("worker.executed"),
            created: reg.counter("worker.created"),
            skipped_dependent: reg.counter("worker.skipped_dependent"),
            passed_executing: reg.counter("worker.passed_executing"),
            erased_retries: reg.counter("worker.erased_retries"),
            idle_cycles: reg.counter("worker.idle_cycles"),
            exec_time_ns: reg.counter("worker.exec_time_ns"),
            busy_time_ns: reg.counter("worker.busy_time_ns"),
            chain_tasks_created: reg.counter("chain.tasks_created"),
            chain_tasks_executed: reg.counter("chain.tasks_executed"),
            chain_max_chain_len: reg.counter("chain.max_chain_len"),
            chain_tail_locks: reg.counter("chain.tail_locks"),
            chain_arena_capacity: reg.counter("chain.arena_capacity"),
            chain_arena_high_water: reg.counter("chain.arena_high_water"),
            chain_arena_recycled: reg.counter("chain.arena_recycled"),
            chain_arena_live: reg.counter("chain.arena_live"),
            chain_state_bytes: reg.counter("chain.state_bytes"),
            chain_bytes_per_task: reg.counter("chain.bytes_per_task"),
            batch_fill: reg.histogram("chain.batch_fill"),
            exec_ns: reg.histogram("chain.exec_ns"),
        }
    }

    /// Publish one worker's accumulated counters onto its registry row
    /// (called once per epoch at the end of the worker loop — off the
    /// per-task hot path).
    pub fn publish_worker(&self, t: &WorkerTelemetry<'_>, s: &WorkerStats) {
        t.add(self.cycles, s.cycles);
        t.add(self.executed, s.executed);
        t.add(self.created, s.created);
        t.add(self.skipped_dependent, s.skipped_dependent);
        t.add(self.passed_executing, s.passed_executing);
        t.add(self.erased_retries, s.erased_retries);
        t.add(self.idle_cycles, s.idle_cycles);
        t.add(self.exec_time_ns, duration_ns(s.exec_time));
        t.add(self.busy_time_ns, duration_ns(s.busy_time));
    }

    /// Publish end-of-run chain/arena statistics onto the engine-global
    /// row.
    pub fn publish_chain(&self, core: &TelemetryCore, chain: &ProtocolStats) {
        core.record(self.chain_tasks_created, chain.tasks_created);
        core.record(self.chain_tasks_executed, chain.tasks_executed);
        core.record(self.chain_max_chain_len, chain.max_chain_len as u64);
        core.record(self.chain_tail_locks, chain.tail_locks);
        core.record(self.chain_arena_capacity, chain.arena_capacity as u64);
        core.record(self.chain_arena_high_water, chain.arena_high_water as u64);
        core.record(self.chain_arena_recycled, chain.arena_recycled);
        core.record(self.chain_arena_live, chain.arena_live as u64);
        core.record(self.chain_state_bytes, chain.state_bytes);
        core.record(self.chain_bytes_per_task, chain.bytes_per_task().round() as u64);
    }
}

/// Total structural state traffic of a run: the model's per-task average
/// times the executed task count, rounded once at the end so engines all
/// derive the counter identically.
pub fn state_bytes_total(bytes_per_task: f64, tasks_executed: u64) -> u64 {
    (bytes_per_task * tasks_executed as f64).round().max(0.0) as u64
}

/// Post-hoc registry publication for engines without live per-worker
/// publishers (sequential, stepwise, virtual): feed the already-merged
/// stats through a counters-only registry so their reports carry the
/// same coherent `telemetry` object as the chain engines.
pub fn post_hoc_snapshot(
    per_worker: &[WorkerStats],
    chain: &ProtocolStats,
) -> TelemetrySnapshot {
    let mut reg = MetricsRegistry::new();
    let ids = StdInstruments::register(&mut reg);
    let core = reg.start(per_worker.len(), crate::telemetry::TelemetryMode::Off);
    for (w, s) in per_worker.iter().enumerate() {
        ids.publish_worker(&core.handle(w), s);
    }
    ids.publish_chain(&core, chain);
    core.finish()
}

/// Chain-level statistics for a run.
#[derive(Clone, Debug, Default)]
pub struct ProtocolStats {
    /// Tasks created in total.
    pub tasks_created: u64,
    /// Tasks executed in total.
    pub tasks_executed: u64,
    /// High-water mark of the chain length.
    pub max_chain_len: usize,
    /// Creation-lock acquisitions across all chains — each amortizes a
    /// whole batch of task creations (`Chain::fill_tail`), so
    /// `tasks_created / tail_locks` is the batching payoff. `0` for
    /// engines without a chain (sequential, stepwise, virtual).
    pub tail_locks: u64,
    /// Creation batch size `B` the run was configured with (`1` for
    /// engines the knob does not apply to).
    pub batch: u32,
    /// Arena slots backed by memory at end of run, summed over all
    /// chains (each includes its two sentinels).
    pub arena_capacity: usize,
    /// High-water mark of simultaneously live arena slots, summed over
    /// all chains — `arena_high_water / arena_capacity` is the peak
    /// occupancy.
    pub arena_high_water: usize,
    /// Node allocations served by recycling an erased slot instead of
    /// fresh memory (the steady-state no-allocation guarantee in action).
    pub arena_recycled: u64,
    /// Arena slots still live at teardown, summed over all chains. A
    /// drained run holds exactly its sentinels (two per chain), so any
    /// excess is a leaked node — the chaos harness's leak-freedom
    /// invariant (DESIGN.md §10). `0` for engines without an arena.
    pub arena_live: usize,
    /// Total structural agent-state bytes the run's tasks read + wrote
    /// under the model's storage layout
    /// ([`Model::state_bytes_per_task`](crate::model::Model::state_bytes_per_task)
    /// × executed; DESIGN.md §13). `0` for models that opt out of the
    /// accounting.
    pub state_bytes: u64,
}

impl ProtocolStats {
    /// Average tasks linked per creation-lock acquisition (`0.0` when no
    /// creation lock was ever taken).
    pub fn tasks_per_tail_lock(&self) -> f64 {
        if self.tail_locks == 0 {
            0.0
        } else {
            self.tasks_created as f64 / self.tail_locks as f64
        }
    }

    /// Average structural state bytes per executed task (`0.0` for a
    /// taskless run or an opted-out model) — the layout comparison
    /// metric the SoA bench gates on.
    pub fn bytes_per_task(&self) -> f64 {
        if self.tasks_executed == 0 {
            0.0
        } else {
            self.state_bytes as f64 / self.tasks_executed as f64
        }
    }

    /// Peak arena occupancy in `[0, 1]` (`0.0` for chainless engines).
    pub fn arena_occupancy(&self) -> f64 {
        if self.arena_capacity == 0 {
            0.0
        } else {
            self.arena_high_water as f64 / self.arena_capacity as f64
        }
    }

    /// Reconstruct the chain statistics from a registry snapshot (the
    /// view counterpart of [`StdInstruments::publish_chain`]). `batch`
    /// is configuration, not measurement, so it is passed through.
    pub fn from_snapshot(snap: &TelemetrySnapshot, batch: u32) -> Self {
        ProtocolStats {
            tasks_created: snap.counter("chain.tasks_created"),
            tasks_executed: snap.counter("chain.tasks_executed"),
            max_chain_len: snap.counter("chain.max_chain_len") as usize,
            tail_locks: snap.counter("chain.tail_locks"),
            batch,
            arena_capacity: snap.counter("chain.arena_capacity") as usize,
            arena_high_water: snap.counter("chain.arena_high_water") as usize,
            arena_recycled: snap.counter("chain.arena_recycled"),
            arena_live: snap.counter("chain.arena_live") as usize,
            state_bytes: snap.counter("chain.state_bytes"),
        }
    }
}

/// Sharded-scheduler telemetry, attached to [`RunReport::sched`] by the
/// sharded engine only (every other engine reports `None`). Quantifies
/// the shard decomposition (edge cut, local/boundary split) and the
/// adaptive loop (migrations per rebalance epoch) — the observability
/// counterpart of DESIGN.md §8.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SchedStats {
    /// Number of shards (per-shard chains).
    pub shards: usize,
    /// Topology edges crossing the *initial* shard assignment (the
    /// partitioner's quality metric).
    pub edge_cut: usize,
    /// Partitioner that built the initial assignment (`"grid"` for the
    /// lattice-native tiling, `"bfs"` for the generic edge-cut growth;
    /// empty on defaulted stats).
    pub partition: &'static str,
    /// Tasks whose footprint stayed inside one shard.
    pub local_tasks: u64,
    /// Cross-shard tasks routed through the spillover chain.
    pub boundary_tasks: u64,
    /// Completed-fence unlinks performed by shard owners.
    pub fence_clears: u64,
    /// Spillover tasks passed because a touched shard was not yet clear.
    pub spill_blocked: u64,
    /// Block→shard migrations performed by the rebalancer.
    pub migrations: u64,
    /// Epoch boundaries at which the rebalancer ran.
    pub rebalances: u64,
    /// Local tasks executed per shard (spillover executions are counted
    /// in `boundary_tasks`, not here) — the per-shard load-imbalance view.
    pub per_shard_executed: Vec<u64>,
    /// Creation-lock acquisitions per shard chain (the spillover chain's
    /// share is `RunReport.chain.tail_locks` minus this vector's sum) —
    /// the per-shard view of the batching amortization.
    pub per_shard_tail_locks: Vec<u64>,
    /// Peak arena occupancy across the shard + spillover chains
    /// (high-water live slots / backed capacity, in `[0, 1]`).
    pub arena_occupancy: f64,
    /// Cycles a worker spent starved by the splitter's live-task
    /// ceiling (backlog full across all shards). The livelock guard
    /// bypass-pulls after a bounded starvation streak, so this counts
    /// pressure, not deadlock.
    pub backpressure_stalls: u64,
}

impl SchedStats {
    /// Fraction of tasks that crossed shards (the spillover ratio).
    pub fn boundary_ratio(&self) -> f64 {
        let total = self.local_tasks + self.boundary_tasks;
        if total == 0 {
            0.0
        } else {
            self.boundary_tasks as f64 / total as f64
        }
    }

    /// The telemetry as a JSON object (for `--json` and bench artifacts).
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("shards".into(), Json::from(self.shards)),
            ("edge_cut".into(), Json::from(self.edge_cut)),
            ("partition".into(), Json::from(self.partition)),
            ("local_tasks".into(), Json::from(self.local_tasks)),
            ("boundary_tasks".into(), Json::from(self.boundary_tasks)),
            ("boundary_ratio".into(), Json::from(self.boundary_ratio())),
            ("fence_clears".into(), Json::from(self.fence_clears)),
            ("spill_blocked".into(), Json::from(self.spill_blocked)),
            ("migrations".into(), Json::from(self.migrations)),
            ("rebalances".into(), Json::from(self.rebalances)),
            (
                "per_shard_executed".into(),
                Json::Arr(
                    self.per_shard_executed
                        .iter()
                        .map(|&n| Json::from(n))
                        .collect(),
                ),
            ),
            (
                "per_shard_tail_locks".into(),
                Json::Arr(
                    self.per_shard_tail_locks
                        .iter()
                        .map(|&n| Json::from(n))
                        .collect(),
                ),
            ),
            ("arena_occupancy".into(), Json::from(self.arena_occupancy)),
            (
                "backpressure_stalls".into(),
                Json::from(self.backpressure_stalls),
            ),
        ])
    }
}

/// How a report's `time_s` was measured.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TimeBasis {
    /// Real wall-clock time (`Instant`-measured).
    Wall,
    /// Deterministic virtual time from the DES testbed's cost model.
    Virtual,
}

impl std::fmt::Display for TimeBasis {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            TimeBasis::Wall => "wall",
            TimeBasis::Virtual => "virtual",
        })
    }
}

/// Result of one engine run — the *same* type for every engine, so the
/// coordinator, benches and facade never special-case a backend. The
/// paper's `T` is [`RunReport::time_s`]; [`RunReport::basis`] records
/// whether it was measured on the wall clock or on the virtual testbed's
/// deterministic clock.
#[derive(Clone, Debug)]
pub struct RunReport {
    /// Engine label (`"parallel"`, `"sequential"`, `"stepwise"`,
    /// `"virtual"`).
    pub engine: &'static str,
    /// Number of workers.
    pub workers: usize,
    /// Duration of the run in seconds (the paper's `T`), wall or virtual
    /// per `basis`.
    pub time_s: f64,
    /// How `time_s` was measured.
    pub basis: TimeBasis,
    /// Aggregated worker counters.
    pub totals: WorkerStats,
    /// Per-worker counters.
    pub per_worker: Vec<WorkerStats>,
    /// Chain statistics.
    pub chain: ProtocolStats,
    /// Sharded-scheduler telemetry (`Some` only for the sharded engine).
    pub sched: Option<SchedStats>,
    /// The full registry snapshot the stats above are views of: every
    /// named counter (per worker + global) and every ring-sampled
    /// histogram, rendered as one coherent `telemetry` object in
    /// `--json`. `None` only on hand-built reports (tests).
    pub telemetry: Option<TelemetrySnapshot>,
    /// The causal trace collected during the run (`Some` only when
    /// `--trace-mode` was not `off`). The CLI exports it to Perfetto
    /// JSON; `to_json` carries only a small summary.
    pub trace: Option<crate::trace::Trace>,
}

impl RunReport {
    /// The run duration as a [`Duration`] (virtual reports round to
    /// nanosecond resolution).
    pub fn duration(&self) -> Duration {
        Duration::from_secs_f64(self.time_s.max(0.0))
    }

    /// Sum of per-worker counters (consistency helper for tests).
    pub fn recompute_totals(&self) -> WorkerStats {
        let mut t = WorkerStats::default();
        for w in &self.per_worker {
            t.merge(w);
        }
        t
    }

    /// Protocol overhead proxy: fraction of task visits that did not lead
    /// to an execution (skips, passes, retries vs executions).
    pub fn overhead_ratio(&self) -> f64 {
        let wasted = self.totals.skipped_dependent
            + self.totals.passed_executing
            + self.totals.erased_retries;
        let total = wasted + self.totals.executed;
        if total == 0 {
            0.0
        } else {
            wasted as f64 / total as f64
        }
    }

    /// The whole report as a JSON object (for `--json` CLI output and
    /// bench artifacts). The `sched` telemetry object appears only for
    /// sharded runs.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("engine".into(), Json::from(self.engine)),
            ("workers".into(), Json::from(self.workers)),
            ("time_s".into(), Json::from(self.time_s)),
            ("basis".into(), Json::from(self.basis.to_string())),
            ("totals".into(), self.totals.to_json_totals()),
            (
                "per_worker".into(),
                Json::Arr(self.per_worker.iter().map(WorkerStats::to_json).collect()),
            ),
            (
                "chain".into(),
                Json::Obj(vec![
                    ("tasks_created".into(), Json::from(self.chain.tasks_created)),
                    (
                        "tasks_executed".into(),
                        Json::from(self.chain.tasks_executed),
                    ),
                    ("max_chain_len".into(), Json::from(self.chain.max_chain_len)),
                    ("batch".into(), Json::from(self.chain.batch)),
                    ("tail_locks".into(), Json::from(self.chain.tail_locks)),
                    (
                        "tasks_per_tail_lock".into(),
                        Json::from(self.chain.tasks_per_tail_lock()),
                    ),
                    (
                        "arena_capacity".into(),
                        Json::from(self.chain.arena_capacity),
                    ),
                    (
                        "arena_high_water".into(),
                        Json::from(self.chain.arena_high_water),
                    ),
                    (
                        "arena_recycled".into(),
                        Json::from(self.chain.arena_recycled),
                    ),
                    ("arena_live".into(), Json::from(self.chain.arena_live)),
                    (
                        "arena_occupancy".into(),
                        Json::from(self.chain.arena_occupancy()),
                    ),
                    ("state_bytes".into(), Json::from(self.chain.state_bytes)),
                    (
                        "bytes_per_task".into(),
                        Json::from(self.chain.bytes_per_task()),
                    ),
                ]),
            ),
            ("overhead_ratio".into(), Json::from(self.overhead_ratio())),
        ];
        if let Some(sched) = &self.sched {
            fields.push(("sched".into(), sched.to_json()));
        }
        if let Some(telemetry) = &self.telemetry {
            fields.push(("telemetry".into(), telemetry.to_json()));
        }
        if let Some(trace) = &self.trace {
            fields.push(("trace".into(), trace.summary_json()));
        }
        Json::Obj(fields)
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{} n={} T={:?}({}) executed={} created={} skipped={} passed={} retries={} cycles={} max_chain={} batch={} tail_locks={}",
            self.engine,
            self.workers,
            self.duration(),
            self.basis,
            self.totals.executed,
            self.totals.created,
            self.totals.skipped_dependent,
            self.totals.passed_executing,
            self.totals.erased_retries,
            self.totals.cycles,
            self.chain.max_chain_len,
            self.chain.batch,
            self.chain.tail_locks,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_adds_counters() {
        let mut a = WorkerStats {
            executed: 3,
            cycles: 5,
            ..Default::default()
        };
        let b = WorkerStats {
            executed: 2,
            skipped_dependent: 7,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.executed, 5);
        assert_eq!(a.skipped_dependent, 7);
        assert_eq!(a.cycles, 5);
    }

    #[test]
    fn overhead_ratio_bounds() {
        let mut r = RunReport {
            engine: "test",
            workers: 1,
            time_s: 0.0,
            basis: TimeBasis::Wall,
            totals: WorkerStats::default(),
            per_worker: vec![],
            chain: ProtocolStats::default(),
            sched: None,
            telemetry: None,
            trace: None,
        };
        assert_eq!(r.overhead_ratio(), 0.0);
        r.totals.executed = 10;
        r.totals.skipped_dependent = 10;
        assert!((r.overhead_ratio() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn worker_id_survives_merge_and_reaches_json() {
        let mut a = WorkerStats {
            worker: 3,
            executed: 1,
            ..Default::default()
        };
        let b = WorkerStats {
            worker: 9,
            executed: 2,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.worker, 3, "merge keeps the receiver's identity");
        assert_eq!(a.executed, 3);
        assert!(a.to_json().render().contains("\"worker\":3"));
        assert!(
            !a.to_json_totals().render().contains("worker"),
            "merged totals must not claim a worker identity"
        );
    }

    #[test]
    fn chain_telemetry_derivations() {
        let s = ProtocolStats {
            tasks_created: 640,
            tasks_executed: 640,
            tail_locks: 10,
            arena_capacity: 128,
            arena_high_water: 32,
            batch: 64,
            state_bytes: 320,
            ..Default::default()
        };
        assert!((s.tasks_per_tail_lock() - 64.0).abs() < 1e-12);
        assert!((s.arena_occupancy() - 0.25).abs() < 1e-12);
        assert!((s.bytes_per_task() - 0.5).abs() < 1e-12);
        let empty = ProtocolStats::default();
        assert_eq!(empty.tasks_per_tail_lock(), 0.0);
        assert_eq!(empty.arena_occupancy(), 0.0);
        assert_eq!(empty.bytes_per_task(), 0.0);
        let r = RunReport {
            engine: "test",
            workers: 1,
            time_s: 0.0,
            basis: TimeBasis::Wall,
            totals: WorkerStats::default(),
            per_worker: vec![],
            chain: s,
            sched: None,
            telemetry: None,
            trace: None,
        };
        let json = r.to_json().render();
        assert!(json.contains("\"batch\":64"), "{json}");
        assert!(json.contains("\"tail_locks\":10"), "{json}");
        assert!(json.contains("\"tasks_per_tail_lock\":64"), "{json}");
        assert!(json.contains("\"arena_recycled\":0"), "{json}");
    }

    #[test]
    fn sched_stats_ratio_and_json() {
        let s = SchedStats {
            shards: 4,
            local_tasks: 75,
            boundary_tasks: 25,
            per_shard_executed: vec![20, 19, 18, 18],
            ..Default::default()
        };
        assert!((s.boundary_ratio() - 0.25).abs() < 1e-12);
        let json = s.to_json().render();
        assert!(json.contains("\"shards\":4"), "{json}");
        assert!(json.contains("\"per_shard_executed\":[20,19,18,18]"), "{json}");
        assert_eq!(SchedStats::default().boundary_ratio(), 0.0);
    }
}
