//! Protocol execution statistics.
//!
//! The paper's overhead discussion (§4) is driven by exactly these
//! quantities: how often workers skip dependent tasks, pass executing
//! tasks, retry over erased nodes, and how long chains grow. The ablation
//! benches report them alongside wall-clock time.

use std::time::Duration;

use crate::util::json::Json;

/// Counters collected by one worker across a run.
#[derive(Clone, Debug, Default)]
pub struct WorkerStats {
    /// Completed chain-exploration cycles.
    pub cycles: u64,
    /// Tasks executed (and erased) by this worker.
    pub executed: u64,
    /// Tasks created by this worker.
    pub created: u64,
    /// Tasks passed because the record reported a dependence.
    pub skipped_dependent: u64,
    /// Tasks passed because another worker was executing them.
    pub passed_executing: u64,
    /// Arrivals at erased nodes (forced retries from the previous node).
    pub erased_retries: u64,
    /// Cycles that neither executed nor created anything (idle spins).
    pub idle_cycles: u64,
    /// Total time spent inside `Model::execute` (only if timing enabled).
    pub exec_time: Duration,
    /// Total wall time of this worker's loop.
    pub busy_time: Duration,
}

impl WorkerStats {
    /// The counters as a JSON object (durations in seconds).
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("cycles".into(), Json::from(self.cycles)),
            ("executed".into(), Json::from(self.executed)),
            ("created".into(), Json::from(self.created)),
            (
                "skipped_dependent".into(),
                Json::from(self.skipped_dependent),
            ),
            ("passed_executing".into(), Json::from(self.passed_executing)),
            ("erased_retries".into(), Json::from(self.erased_retries)),
            ("idle_cycles".into(), Json::from(self.idle_cycles)),
            ("exec_time_s".into(), Json::from(self.exec_time.as_secs_f64())),
            ("busy_time_s".into(), Json::from(self.busy_time.as_secs_f64())),
        ])
    }

    /// Merge another worker's counters into this one.
    pub fn merge(&mut self, o: &WorkerStats) {
        self.cycles += o.cycles;
        self.executed += o.executed;
        self.created += o.created;
        self.skipped_dependent += o.skipped_dependent;
        self.passed_executing += o.passed_executing;
        self.erased_retries += o.erased_retries;
        self.idle_cycles += o.idle_cycles;
        self.exec_time += o.exec_time;
        self.busy_time += o.busy_time;
    }
}

/// Chain-level statistics for a run.
#[derive(Clone, Debug, Default)]
pub struct ProtocolStats {
    /// Tasks created in total.
    pub tasks_created: u64,
    /// Tasks executed in total.
    pub tasks_executed: u64,
    /// High-water mark of the chain length.
    pub max_chain_len: usize,
}

/// How a report's `time_s` was measured.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TimeBasis {
    /// Real wall-clock time (`Instant`-measured).
    Wall,
    /// Deterministic virtual time from the DES testbed's cost model.
    Virtual,
}

impl std::fmt::Display for TimeBasis {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            TimeBasis::Wall => "wall",
            TimeBasis::Virtual => "virtual",
        })
    }
}

/// Result of one engine run — the *same* type for every engine, so the
/// coordinator, benches and facade never special-case a backend. The
/// paper's `T` is [`RunReport::time_s`]; [`RunReport::basis`] records
/// whether it was measured on the wall clock or on the virtual testbed's
/// deterministic clock.
#[derive(Clone, Debug)]
pub struct RunReport {
    /// Engine label (`"parallel"`, `"sequential"`, `"stepwise"`,
    /// `"virtual"`).
    pub engine: &'static str,
    /// Number of workers.
    pub workers: usize,
    /// Duration of the run in seconds (the paper's `T`), wall or virtual
    /// per `basis`.
    pub time_s: f64,
    /// How `time_s` was measured.
    pub basis: TimeBasis,
    /// Aggregated worker counters.
    pub totals: WorkerStats,
    /// Per-worker counters.
    pub per_worker: Vec<WorkerStats>,
    /// Chain statistics.
    pub chain: ProtocolStats,
}

impl RunReport {
    /// The run duration as a [`Duration`] (virtual reports round to
    /// nanosecond resolution).
    pub fn duration(&self) -> Duration {
        Duration::from_secs_f64(self.time_s.max(0.0))
    }

    /// Sum of per-worker counters (consistency helper for tests).
    pub fn recompute_totals(&self) -> WorkerStats {
        let mut t = WorkerStats::default();
        for w in &self.per_worker {
            t.merge(w);
        }
        t
    }

    /// Protocol overhead proxy: fraction of task visits that did not lead
    /// to an execution (skips, passes, retries vs executions).
    pub fn overhead_ratio(&self) -> f64 {
        let wasted = self.totals.skipped_dependent
            + self.totals.passed_executing
            + self.totals.erased_retries;
        let total = wasted + self.totals.executed;
        if total == 0 {
            0.0
        } else {
            wasted as f64 / total as f64
        }
    }

    /// The whole report as a JSON object (for `--json` CLI output and
    /// bench artifacts).
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("engine".into(), Json::from(self.engine)),
            ("workers".into(), Json::from(self.workers)),
            ("time_s".into(), Json::from(self.time_s)),
            ("basis".into(), Json::from(self.basis.to_string())),
            ("totals".into(), self.totals.to_json()),
            (
                "per_worker".into(),
                Json::Arr(self.per_worker.iter().map(WorkerStats::to_json).collect()),
            ),
            (
                "chain".into(),
                Json::Obj(vec![
                    ("tasks_created".into(), Json::from(self.chain.tasks_created)),
                    (
                        "tasks_executed".into(),
                        Json::from(self.chain.tasks_executed),
                    ),
                    ("max_chain_len".into(), Json::from(self.chain.max_chain_len)),
                ]),
            ),
            ("overhead_ratio".into(), Json::from(self.overhead_ratio())),
        ])
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{} n={} T={:?}({}) executed={} created={} skipped={} passed={} retries={} cycles={} max_chain={}",
            self.engine,
            self.workers,
            self.duration(),
            self.basis,
            self.totals.executed,
            self.totals.created,
            self.totals.skipped_dependent,
            self.totals.passed_executing,
            self.totals.erased_retries,
            self.totals.cycles,
            self.chain.max_chain_len,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_adds_counters() {
        let mut a = WorkerStats {
            executed: 3,
            cycles: 5,
            ..Default::default()
        };
        let b = WorkerStats {
            executed: 2,
            skipped_dependent: 7,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.executed, 5);
        assert_eq!(a.skipped_dependent, 7);
        assert_eq!(a.cycles, 5);
    }

    #[test]
    fn overhead_ratio_bounds() {
        let mut r = RunReport {
            engine: "test",
            workers: 1,
            time_s: 0.0,
            basis: TimeBasis::Wall,
            totals: WorkerStats::default(),
            per_worker: vec![],
            chain: ProtocolStats::default(),
        };
        assert_eq!(r.overhead_ratio(), 0.0);
        r.totals.executed = 10;
        r.totals.skipped_dependent = 10;
        assert!((r.overhead_ratio() - 0.5).abs() < 1e-12);
    }
}
