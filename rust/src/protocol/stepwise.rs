//! Step-parallel baseline — the related-work approach the paper argues
//! against (§2):
//!
//! > "parallelization goes hand in hand with strictly splitting the
//! > computation into time steps and updating (a step-dependent subset of)
//! > all agents at each step. [...] computing cores/nodes that eventually
//! > run out of work may not proceed to the next step until the current
//! > step has been completed."
//!
//! This engine implements exactly that: a persistent thread pool that, for
//! each (step, phase), splits the phase's blocks over workers via an atomic
//! work index and joins at a barrier before the next phase may start. Only
//! models with a synchronous many-updates-per-step formulation (e.g. SIR)
//! can implement [`SyncModel`]; purely sequential models (Axelrod, voter,
//! Ising — one update per step) cannot, which is the paper's argument for
//! the chain protocol.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Barrier;
use std::time::Instant;

use crate::api::observe::{ObsProbe, Observer};
use crate::trace::{TraceCore, TraceHandle, TraceMode, NONE_SHARD};

use super::stats::{post_hoc_snapshot, ProtocolStats, RunReport, TimeBasis, WorkerStats};

/// A model in synchronous, phase-structured form.
///
/// Each step consists of `phases()` phases executed in order; within a
/// phase, blocks are mutually independent (the engine may run them in any
/// order, concurrently); a barrier separates consecutive phases.
pub trait SyncModel: Send + Sync {
    /// Number of simulation steps.
    fn steps(&self) -> u64;
    /// Number of phases per step.
    fn phases(&self) -> usize;
    /// Number of independent blocks within `phase`.
    fn blocks(&self, phase: usize) -> usize;
    /// Execute one block. Must only touch state in a way that is
    /// conflict-free against every other block of the same phase.
    /// Randomness must be keyed on `(seed, step, phase, block)` to keep
    /// results independent of scheduling (implementations typically reuse
    /// the chain engines' per-task stream mapping so all engines agree).
    fn run_block(&self, seed: u64, step: u64, phase: usize, block: usize);
    /// Average bytes of agent state a block touches — the sync-form
    /// mirror of [`crate::model::Model::state_bytes_per_task`]. Feeds the
    /// `chain.bytes_per_task` instrument; `0.0` (the default) means
    /// "unknown" and keeps the counters at zero.
    fn state_bytes_per_task(&self) -> f64 {
        0.0
    }
}

/// Barrier-synchronized step-parallel engine.
#[derive(Clone, Copy, Debug)]
pub struct StepwiseEngine {
    /// Number of pool threads.
    pub workers: usize,
    /// Simulation seed.
    pub seed: u64,
    /// Causal-tracing mode (inert). Spans carry the canonical
    /// lexicographic `(step, phase, block)` sequence numbers, so stepwise
    /// traces line up with the chain engines' task ids.
    pub trace: TraceMode,
}

impl StepwiseEngine {
    /// Create with `workers` threads and a seed (tracing defaults from
    /// `ADAPAR_TRACE`).
    pub fn new(workers: usize, seed: u64) -> Self {
        assert!(workers >= 1);
        Self {
            workers,
            seed,
            trace: TraceMode::env_default(),
        }
    }

    /// Run the synchronous model to completion.
    pub fn run<M: SyncModel>(&self, model: &M) -> RunReport {
        let steps = model.steps();
        let phases = model.phases();
        let n = self.workers;
        // Canonical numbering for spans: seq(step, phase, block) =
        // step * per_step + phase_base[phase] + block.
        let mut phase_base = Vec::with_capacity(phases);
        let mut per_step = 0u64;
        for p in 0..phases {
            phase_base.push(per_step);
            per_step += model.blocks(p) as u64;
        }
        let trc = TraceCore::start(self.trace, n, "stepwise", "wall");
        let t0 = Instant::now();
        let executed_blocks = AtomicU64::new(0);

        if n == 1 {
            let th = TraceHandle::lane(trc.as_ref(), 0);
            let mut seq = 0u64;
            for step in 0..steps {
                for phase in 0..phases {
                    for block in 0..model.blocks(phase) {
                        let span_t0 = if th.active() { th.now() } else { 0 };
                        model.run_block(self.seed, step, phase, block);
                        if th.active() {
                            th.exec(seq, block as u64, NONE_SHARD, span_t0, th.now());
                        }
                        seq += 1;
                    }
                }
            }
            executed_blocks.store(steps * per_step, Ordering::Relaxed);
        } else {
            // Persistent pool: every thread walks the same (step, phase)
            // schedule; an atomic index hands out blocks; two barrier
            // waits bracket each phase (work barrier + publish barrier so
            // the shared index reset is seen by all).
            let barrier = Barrier::new(n);
            let next_block = AtomicUsize::new(0);
            std::thread::scope(|s| {
                let mut handles = Vec::with_capacity(n);
                for w in 0..n {
                    let barrier = &barrier;
                    let next_block = &next_block;
                    let phase_base = &phase_base;
                    let seed = self.seed;
                    let th = TraceHandle::lane(trc.as_ref(), w);
                    handles.push(s.spawn(move || {
                        let mut my_blocks = 0u64;
                        for step in 0..steps {
                            for phase in 0..phases {
                                let blocks = model.blocks(phase);
                                loop {
                                    let b = next_block.fetch_add(1, Ordering::AcqRel);
                                    if b >= blocks {
                                        break;
                                    }
                                    let span_t0 = if th.active() { th.now() } else { 0 };
                                    model.run_block(seed, step, phase, b);
                                    if th.active() {
                                        let seq =
                                            step * per_step + phase_base[phase] + b as u64;
                                        th.exec(seq, b as u64, NONE_SHARD, span_t0, th.now());
                                    }
                                    my_blocks += 1;
                                }
                                // Work barrier: phase complete everywhere.
                                let token = barrier.wait();
                                if token.is_leader() {
                                    next_block.store(0, Ordering::Release);
                                }
                                // Publish barrier: index reset visible.
                                barrier.wait();
                            }
                        }
                        my_blocks
                    }));
                }
                for h in handles {
                    let b = h.join().expect("stepwise worker panicked");
                    executed_blocks.fetch_add(b, Ordering::Relaxed);
                }
            });
        }

        let wall = t0.elapsed();
        let executed = executed_blocks.load(Ordering::Relaxed);
        if let Some(c) = &trc {
            c.coordinator().epoch_mark(executed);
        }
        let stats = WorkerStats {
            cycles: steps,
            executed,
            created: executed,
            busy_time: wall,
            ..Default::default()
        };
        let chain = ProtocolStats {
            tasks_created: executed,
            tasks_executed: executed,
            max_chain_len: 0,
            batch: 1,
            state_bytes: super::stats::state_bytes_total(model.state_bytes_per_task(), executed),
            ..Default::default()
        };
        let per_worker = vec![stats.clone()];
        RunReport {
            engine: "stepwise",
            workers: n,
            time_s: wall.as_secs_f64(),
            basis: TimeBasis::Wall,
            totals: stats,
            telemetry: Some(post_hoc_snapshot(&per_worker, &chain)),
            per_worker,
            chain,
            sched: None,
            trace: trc.map(TraceCore::finish),
        }
    }

    /// Run with epoch snapshots.
    ///
    /// Canonical task counting for a [`SyncModel`] is the lexicographic
    /// `(step, phase, block)` order — the same order the model's chain
    /// form emits tasks in (e.g. `SirSource`), which is what makes the
    /// stepwise trace byte-identical to the chain engines' at a fixed
    /// seed. When an epoch boundary falls *inside* a phase, the phase is
    /// split at the boundary block: blocks `0..b` run (in parallel),
    /// the engine joins to quiescence, records a frame, then runs blocks
    /// `b..B`. Within-phase blocks are mutually independent, so splitting
    /// never changes the computed state.
    pub fn run_observed<M: SyncModel>(
        &self,
        model: &M,
        probe: ObsProbe<'_>,
        observer: &mut Observer,
    ) -> RunReport {
        let every = observer.gate_cadence();
        observer.record_initial(probe);
        let trc = TraceCore::start(self.trace, self.workers, "stepwise", "wall");
        let t0 = Instant::now();
        let steps = model.steps();
        let phases = model.phases();
        let mut executed = 0u64;
        let mut next_boundary = every;
        for step in 0..steps {
            for phase in 0..phases {
                let blocks = model.blocks(phase) as u64;
                let mut b0 = 0u64;
                while b0 < blocks {
                    debug_assert!(executed < next_boundary);
                    let b1 = blocks.min(b0 + (next_boundary - executed));
                    self.run_block_range(
                        model,
                        step,
                        phase,
                        b0 as usize,
                        b1 as usize,
                        trc.as_ref(),
                        executed,
                    );
                    executed += b1 - b0;
                    b0 = b1;
                    if executed == next_boundary {
                        observer.record(executed, probe());
                        if let Some(c) = &trc {
                            c.coordinator().epoch_mark(executed);
                        }
                        next_boundary = next_boundary.saturating_add(every);
                    }
                }
            }
        }
        observer.record(executed, probe());
        if let Some(c) = &trc {
            c.coordinator().epoch_mark(executed);
        }
        let wall = t0.elapsed();

        let stats = WorkerStats {
            cycles: steps,
            executed,
            created: executed,
            busy_time: wall,
            ..Default::default()
        };
        let chain = ProtocolStats {
            tasks_created: executed,
            tasks_executed: executed,
            max_chain_len: 0,
            batch: 1,
            state_bytes: super::stats::state_bytes_total(model.state_bytes_per_task(), executed),
            ..Default::default()
        };
        let per_worker = vec![stats.clone()];
        RunReport {
            engine: "stepwise",
            workers: self.workers,
            time_s: wall.as_secs_f64(),
            basis: TimeBasis::Wall,
            totals: stats,
            telemetry: Some(post_hoc_snapshot(&per_worker, &chain)),
            per_worker,
            chain,
            sched: None,
            trace: trc.map(TraceCore::finish),
        }
    }

    /// Execute blocks `b0..b1` of one phase, in parallel over the pool;
    /// returns only once all of them completed (the scope join is the
    /// phase/segment barrier).
    ///
    /// The observed path trades the unobserved run's persistent barrier
    /// pool for per-segment scoped threads: the join *is* the quiescent
    /// point the snapshot needs. The spawn overhead is part of the
    /// observed run's reported `T` (like every other observation cost) —
    /// compare timings with unobserved runs only. Thread count is capped
    /// by the segment's block count so tiny segments stay cheap.
    fn run_block_range<M: SyncModel>(
        &self,
        model: &M,
        step: u64,
        phase: usize,
        b0: usize,
        b1: usize,
        trc: Option<&TraceCore>,
        base_seq: u64,
    ) {
        let threads = self.workers.min(b1 - b0);
        if threads <= 1 {
            let th = TraceHandle::lane(trc, 0);
            for block in b0..b1 {
                let span_t0 = if th.active() { th.now() } else { 0 };
                model.run_block(self.seed, step, phase, block);
                if th.active() {
                    let seq = base_seq + (block - b0) as u64;
                    th.exec(seq, block as u64, NONE_SHARD, span_t0, th.now());
                }
            }
            return;
        }
        let next = AtomicUsize::new(b0);
        std::thread::scope(|s| {
            for w in 0..threads {
                let next = &next;
                let seed = self.seed;
                let th = TraceHandle::lane(trc, w);
                s.spawn(move || loop {
                    let block = next.fetch_add(1, Ordering::Relaxed);
                    if block >= b1 {
                        break;
                    }
                    let span_t0 = if th.active() { th.now() } else { 0 };
                    model.run_block(seed, step, phase, block);
                    if th.active() {
                        let seq = base_seq + (block - b0) as u64;
                        th.exec(seq, block as u64, NONE_SHARD, span_t0, th.now());
                    }
                });
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::state::SharedSim;

    /// Two-phase toy: phase 0 writes `new[b] = cur[b] + 1` per block,
    /// phase 1 copies back. Blocks are disjoint cells.
    struct TwoPhase {
        cur: SharedSim<Vec<u64>>,
        new: SharedSim<Vec<u64>>,
        steps: u64,
    }

    impl SyncModel for TwoPhase {
        fn steps(&self) -> u64 {
            self.steps
        }
        fn phases(&self) -> usize {
            2
        }
        fn blocks(&self, _phase: usize) -> usize {
            unsafe { self.cur.get() }.len()
        }
        fn run_block(&self, _seed: u64, _step: u64, phase: usize, block: usize) {
            unsafe {
                if phase == 0 {
                    self.new.get_mut()[block] = self.cur.get()[block] + 1;
                } else {
                    self.cur.get_mut()[block] = self.new.get()[block];
                }
            }
        }
    }

    #[test]
    fn sequential_and_parallel_agree() {
        for workers in [1, 2, 4] {
            let m = TwoPhase {
                cur: SharedSim::new(vec![0; 17]),
                new: SharedSim::new(vec![0; 17]),
                steps: 25,
            };
            let report = StepwiseEngine::new(workers, 0).run(&m);
            assert_eq!(unsafe { m.cur.get() }.clone(), vec![25u64; 17]);
            assert_eq!(report.totals.executed, 25 * 2 * 17);
            assert_eq!(report.engine, "stepwise");
        }
    }

    #[test]
    fn observed_run_splits_phases_at_exact_boundaries() {
        use crate::api::observe::{frame_count, ObsValue, Observer};
        // 17 blocks × 2 phases × 25 steps = 850 tasks; cadence 23 lands
        // inside phases. The trace must be identical for every pool size
        // and end with the same final state as the unobserved run.
        let trace = |workers: usize| {
            let m = TwoPhase {
                cur: SharedSim::new(vec![0; 17]),
                new: SharedSim::new(vec![0; 17]),
                steps: 25,
            };
            let probe = || {
                vec![(
                    "sum".to_string(),
                    ObsValue::Int(unsafe { m.cur.get() }.iter().sum::<u64>() as i64),
                )]
            };
            let mut obs = Observer::new(23);
            let report = StepwiseEngine::new(workers, 0).run_observed(&m, &probe, &mut obs);
            assert_eq!(report.totals.executed, 850);
            assert_eq!(unsafe { m.cur.get() }.clone(), vec![25u64; 17]);
            obs.finish().unwrap()
        };
        let reference = trace(1);
        assert_eq!(reference.len() as u64, frame_count(23, 850));
        assert_eq!(reference.final_frame().unwrap().tasks, 850);
        assert_eq!(
            reference.value("sum"),
            Some(&ObsValue::Int(25 * 17)),
            "final sum"
        );
        assert_eq!(trace(2), reference);
        assert_eq!(trace(4), reference);
    }
}
