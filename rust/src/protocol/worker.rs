//! The worker loop — the heart of the protocol (§3.3).
//!
//! Each worker repeatedly runs *cycles*: it enters the chain at the head
//! sentinel and advances node by node. At every task it either
//!
//! * **executes** it — if its record reports no dependence on any
//!   previously-encountered (incomplete) task and nobody else is executing
//!   it — then erases it and returns to the start of the chain; or
//! * **absorbs** its recipe into the record and moves on.
//!
//! At the tail it may create new tasks (at most `C` per cycle); a cycle
//! ends after an execution, or at the tail when no task can be created.
//!
//! ## Traversal discipline (deadlock freedom)
//!
//! A worker holds exactly one *visitor slot* (its location) plus,
//! transiently, the slot of the node it is arriving at; slot waits
//! therefore only point *forward* along the chain — a strict total order —
//! so waits cannot cycle. Erasure acquires the erased node's slot while
//! holding nothing else, then the erase lock (whose holder only ever takes
//! leaf link locks). Creation holds the tail slot (its holder never blocks
//! except on leaf link locks). See `chain` module docs for the lock
//! inventory and DESIGN.md §6 for the consistency argument.
//!
//! ## Arrival-at-erased retry
//!
//! A worker that blocked on a node's slot may find the node `Erased` when
//! it finally acquires it (the executor erased it in between). It still
//! holds its previous node's slot, so it simply re-reads that node's `next`
//! pointer — updated by the unlink — and retries. Erased nodes are never
//! traversed through.

use std::sync::Mutex;
use std::time::Instant;

use crate::chain::node::NodeKind;
use crate::chain::{Chain, NodeState};
use crate::model::{Model, Record, TaskSource};
use crate::sim::rng::TaskRng;

use super::stats::WorkerStats;

/// Shared, read-only worker context for one run.
///
/// Generic over the source type `S` so the observed run can interpose an
/// [`EpochGate`](crate::api::observe::EpochGate) without per-task dynamic
/// dispatch; plain runs use `S = M::Source`.
pub(crate) struct RunCtx<'a, M: Model, S: TaskSource<Recipe = M::Recipe>> {
    /// The chain.
    pub chain: &'a Chain<M::Recipe>,
    /// The model (shared state lives inside).
    pub model: &'a M,
    /// The serialized task source ("global, model-specific routine").
    pub source: &'a Mutex<S>,
    /// Simulation seed (drives per-task RNG streams).
    pub seed: u64,
    /// `C`: maximum tasks created per worker cycle.
    pub tasks_per_cycle: u32,
    /// Whether to time each `Model::execute` call (adds two `Instant`
    /// reads per task; off for timing-sensitive benches).
    pub collect_timing: bool,
}

/// Outcome of processing an arrived-at node within a cycle.
enum Processed {
    /// Task executed and erased — the cycle is over.
    ExecutedCycleEnds,
    /// Task absorbed (dependent or being executed) — keep advancing.
    Absorbed,
}

/// Run one worker to completion. Returns its statistics.
pub(crate) fn worker_loop<M: Model, S: TaskSource<Recipe = M::Recipe>>(
    ctx: &RunCtx<'_, M, S>,
    worker_id: usize,
) -> WorkerStats {
    let mut stats = WorkerStats {
        worker: worker_id,
        ..Default::default()
    };
    let mut record = ctx.model.record();
    let loop_start = Instant::now();

    'cycle: loop {
        record.reset();
        stats.cycles += 1;
        let mut created_this_cycle: u32 = 0;
        let did_work_at_cycle_start = stats.executed + stats.created;

        // Enter the chain: the head sentinel's visitor slot doubles as the
        // paper's enter-lock.
        ctx.chain.head().visitor.acquire();
        let mut current = ctx.chain.head().clone();
        // Invariant: we hold `current`'s visitor slot, `current` is live.
        loop {
            let next = match current.next() {
                Some(n) => n,
                None => unreachable!("live non-tail node must have a successor"),
            };

            if ctx.chain.is_tail(&next) {
                // --- creation path -------------------------------------
                if created_this_cycle >= ctx.tasks_per_cycle || ctx.chain.exhausted() {
                    current.visitor.release();
                    break; // cycle ends: "reached the end and cannot create"
                }
                ctx.chain.tail().visitor.acquire();
                // Poll the source while holding the tail slot: creations
                // are serialized, so the creation stream's draw order (and
                // hence the whole chain order) is deterministic.
                let recipe = ctx.source.lock().unwrap().next_task();
                match recipe {
                    None => {
                        ctx.chain.set_exhausted();
                        ctx.chain.tail().visitor.release();
                        current.visitor.release();
                        break; // cycle ends
                    }
                    Some(recipe) => {
                        let node = ctx.chain.append_after(&current, recipe);
                        ctx.chain.tail().visitor.release();
                        created_this_cycle += 1;
                        stats.created += 1;
                        // Move onto the new node. Uncontended: nobody can
                        // read `current.next` while we hold current's slot.
                        node.visitor.acquire();
                        current.visitor.release();
                        current = node;
                        match process(ctx, &current, &mut record, &mut stats) {
                            Processed::ExecutedCycleEnds => continue 'cycle,
                            Processed::Absorbed => continue,
                        }
                    }
                }
            }

            // --- advance path ------------------------------------------
            next.visitor.acquire();
            if next.state() == NodeState::Erased {
                // Executor erased it while we waited; its unlink already
                // rewired `current.next`, so retry from where we stand.
                next.visitor.release();
                stats.erased_retries += 1;
                continue;
            }
            current.visitor.release();
            current = next;
            debug_assert_eq!(current.kind(), NodeKind::Task);
            match process(ctx, &current, &mut record, &mut stats) {
                Processed::ExecutedCycleEnds => continue 'cycle,
                Processed::Absorbed => continue,
            }
        }

        // Cycle ended without an execution. Are we done?
        if ctx.chain.exhausted() && ctx.chain.is_empty() {
            break;
        }
        if stats.executed + stats.created == did_work_at_cycle_start {
            // Nothing executed or created this cycle: other workers hold
            // all remaining work. Yield so the executor(s) get CPU time
            // (essential on machines with fewer cores than workers).
            stats.idle_cycles += 1;
            std::thread::yield_now();
        }
    }

    stats.busy_time = loop_start.elapsed();
    stats
}

/// Handle an arrival at a live task node (visitor slot held).
fn process<M: Model, S: TaskSource<Recipe = M::Recipe>>(
    ctx: &RunCtx<'_, M, S>,
    node: &std::sync::Arc<crate::chain::Node<M::Recipe>>,
    record: &mut M::Record,
    stats: &mut WorkerStats,
) -> Processed {
    match node.state() {
        NodeState::Executing => {
            // Another worker is executing it: absorb and pass (§3.3).
            record.absorb(node.recipe());
            stats.passed_executing += 1;
            Processed::Absorbed
        }
        NodeState::Pending => {
            if record.depends(node.recipe()) {
                record.absorb(node.recipe());
                stats.skipped_dependent += 1;
                Processed::Absorbed
            } else {
                // Execute. Claim the task (we hold the visitor slot, so the
                // transition is ours alone), then free the slot so other
                // workers can pass the executing task.
                node.begin_execution();
                node.visitor.release();

                let mut rng = TaskRng::for_task(ctx.seed, node.seq());
                if ctx.collect_timing {
                    let t0 = Instant::now();
                    ctx.model.execute(node.recipe(), &mut rng);
                    stats.exec_time += t0.elapsed();
                } else {
                    ctx.model.execute(node.recipe(), &mut rng);
                }

                // Erase: re-acquire our node's slot (waiting out any worker
                // currently passing it), unlink under the erase lock.
                node.visitor.acquire();
                ctx.chain.unlink(node);
                node.visitor.release();
                stats.executed += 1;
                Processed::ExecutedCycleEnds
            }
        }
        NodeState::Erased => unreachable!("arrival at erased nodes is retried earlier"),
    }
}
