//! The worker loop — the heart of the protocol (§3.3).
//!
//! Each worker repeatedly runs *cycles*: it enters the chain at the head
//! sentinel and advances node by node. At every task it either
//!
//! * **executes** it — if its record reports no dependence on any
//!   previously-encountered (incomplete) task and nobody else is executing
//!   it — then erases it and returns to the start of the chain; or
//! * **absorbs** its recipe into the record and moves on.
//!
//! At the tail it may create new tasks; creation is **batched**: one
//! tail-slot acquisition links up to `min(B, C - created_this_cycle)`
//! tasks drawn from the source in one go (`Chain::fill_tail`) — the
//! batch never exceeds the cycle's remaining creation allowance, so `C`
//! bounds per-cycle chain growth exactly as in the classic protocol,
//! and `B = 1` reproduces the one-task-per-acquisition behaviour byte
//! for byte. A cycle ends after an execution, or at the tail when no
//! task can be created.
//!
//! ## Traversal discipline (deadlock freedom)
//!
//! A worker holds exactly one *visitor slot* (its location) plus,
//! transiently, the slot of the node it is arriving at; slot waits
//! therefore only point *forward* along the chain — a strict total order —
//! so waits cannot cycle. Erasure acquires the erased node's slot while
//! holding nothing else, then the erase lock (whose holder only ever takes
//! leaf link locks). Creation holds the tail slot (its holder never blocks
//! except on leaf link locks). See `chain` module docs for the lock
//! inventory and DESIGN.md §6 for the consistency argument.
//!
//! ## Arrival-at-stale retry
//!
//! A worker that blocked on a node's slot may find the node gone when it
//! finally acquires it: the executor erased it in between, and with the
//! arena the slot may even host a *different* task already. The
//! generation tag on the worker's handle detects both cases exactly
//! (`Chain::stale`). The worker still holds its previous node's slot, so
//! it simply re-reads that node's `next` pointer — updated by the
//! unlink — and retries. Erased nodes are never traversed through, and a
//! recycled slot can never be mistaken for the node that used to live
//! there (the ABA argument in DESIGN.md §3).

use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::chain::{Chain, Handle, NodeKind, NodeState};
use crate::model::{Model, Record, TaskSource};
use crate::sim::rng::TaskRng;
use crate::telemetry::WorkerTelemetry;
use crate::trace::{TraceHandle, NONE_ID, NONE_SHARD};

use super::stats::{StdInstruments, WorkerStats};

/// Shared, read-only worker context for one run.
///
/// Generic over the source type `S` so the observed run can interpose an
/// [`EpochGate`](crate::api::observe::EpochGate) without per-task dynamic
/// dispatch; plain runs use `S = M::Source`.
pub(crate) struct RunCtx<'a, M: Model, S: TaskSource<Recipe = M::Recipe>> {
    /// The chain.
    pub chain: &'a Chain<M::Recipe>,
    /// The model (shared state lives inside).
    pub model: &'a M,
    /// The serialized task source ("global, model-specific routine").
    pub source: &'a Mutex<S>,
    /// Simulation seed (drives per-task RNG streams).
    pub seed: u64,
    /// `C`: maximum tasks created per worker cycle (checked per batch).
    pub tasks_per_cycle: u32,
    /// `B`: maximum tasks linked per tail-lock acquisition.
    pub batch: u32,
    /// Whether to time each `Model::execute` call (adds two `Instant`
    /// reads per task; off for timing-sensitive benches).
    pub collect_timing: bool,
    /// Per-worker start-up stall for this epoch (chaos harness,
    /// DESIGN.md §10). Empty on clean runs; consulted exactly once per
    /// `worker_loop` call — i.e. once per epoch, before the cycle loop —
    /// so the per-task hot path carries no injection branch.
    pub stalls: &'a [Duration],
    /// Streaming-window retirement handle (ISSUE 10): bumped once per
    /// erased task so the gated source regains materialization room.
    /// `None` on materialized runs — the single `Option` branch per
    /// erase is the whole hot-path cost of the feature when off.
    pub retire: Option<crate::model::RetireHandle>,
}

/// Outcome of processing an arrived-at node within a cycle.
enum Processed {
    /// Task executed and erased — the cycle is over.
    ExecutedCycleEnds,
    /// Task absorbed (dependent or being executed) — keep advancing.
    Absorbed,
}

/// Run one worker to completion. Statistics accumulate locally and are
/// published onto the worker's registry row once, at the end — one
/// batch of relaxed counter adds per epoch, nothing per task. The only
/// per-task telemetry is the (wait-free, drop-on-full) ring sample.
pub(crate) fn worker_loop<M: Model, S: TaskSource<Recipe = M::Recipe>>(
    ctx: &RunCtx<'_, M, S>,
    worker_id: usize,
    tele: WorkerTelemetry<'_>,
    trace: TraceHandle<'_>,
    ids: &StdInstruments,
) {
    let mut stats = WorkerStats {
        worker: worker_id,
        ..Default::default()
    };
    let mut record = ctx.model.record();
    let batch = ctx.batch.max(1) as usize;
    // Reused batch buffer: after its one-time growth to `B` the creation
    // path performs no allocation (recipes move from here into arena
    // slots).
    let mut scratch: Vec<M::Recipe> = Vec::with_capacity(batch);
    // Chaos-harness stall: one check per epoch, never per task.
    if let Some(d) = ctx.stalls.get(worker_id) {
        if !d.is_zero() {
            std::thread::sleep(*d);
        }
    }
    let loop_start = Instant::now();

    'cycle: loop {
        record.reset();
        stats.cycles += 1;
        // Full-mode tracing times whole cycles (idle/walk spans); the
        // clock reads are gated so Spans mode pays only per execution.
        let cycle_t0 = if trace.full() { trace.now() } else { 0 };
        let mut created_this_cycle: u32 = 0;
        let did_work_at_cycle_start = stats.executed + stats.created;

        // Enter the chain: the head sentinel's visitor slot doubles as the
        // paper's enter-lock.
        ctx.chain.acquire(ctx.chain.head());
        let mut current = ctx.chain.head();
        // Invariant: we hold `current`'s visitor slot, `current` is live.
        loop {
            let next = ctx.chain.next(current);
            debug_assert!(!next.is_none(), "live non-tail node must have a successor");

            if ctx.chain.is_tail(next) {
                // --- creation path -------------------------------------
                if created_this_cycle >= ctx.tasks_per_cycle || ctx.chain.exhausted() {
                    ctx.chain.release(current);
                    break; // cycle ends: "reached the end and cannot create"
                }
                ctx.chain.acquire(ctx.chain.tail());
                // Poll the source while holding the tail slot: creations
                // are serialized, so the creation stream's draw order (and
                // hence the whole chain order) is deterministic;
                // `fill_tail` links the batch in exactly the drawn order.
                // The batch is clamped to the cycle's remaining `C`
                // allowance, so batching never loosens the growth cap.
                let want = batch.min((ctx.tasks_per_cycle - created_this_cycle) as usize);
                debug_assert!(scratch.is_empty());
                let (got, stalled) = {
                    let mut src = ctx.source.lock().unwrap();
                    let got = src.next_batch(&mut scratch, want);
                    // Distinguish (under the same lock hold) a temporary
                    // streaming-window stall from true epoch exhaustion:
                    // a stall must NOT latch `exhausted` — the window
                    // reopens as outstanding tasks retire, and ending the
                    // epoch early would corrupt the observation trace.
                    (got, got == 0 && src.stalled())
                };
                if got == 0 {
                    if !stalled {
                        ctx.chain.set_exhausted();
                    }
                    ctx.chain.release(ctx.chain.tail());
                    ctx.chain.release(current);
                    break; // cycle ends
                }
                let first = ctx.chain.fill_tail(current, &mut scratch);
                ctx.chain.release(ctx.chain.tail());
                tele.sample(ids.batch_fill, got as u64);
                created_this_cycle += got as u32;
                stats.created += got as u64;
                // Move onto the first created node. Effectively
                // uncontended: nobody can read `current.next` while we
                // hold current's slot (at worst the slot's previous
                // eraser is a moment from releasing it).
                ctx.chain.acquire(first);
                ctx.chain.release(current);
                current = first;
                match process(ctx, current, &mut record, &mut stats, &tele, trace, ids) {
                    Processed::ExecutedCycleEnds => continue 'cycle,
                    Processed::Absorbed => continue,
                }
            }

            // --- advance path ------------------------------------------
            ctx.chain.acquire(next);
            if ctx.chain.stale(next) {
                // The executor erased it while we waited (the slot may
                // already host a different task); its unlink already
                // rewired `current.next`, so retry from where we stand.
                ctx.chain.release(next);
                stats.erased_retries += 1;
                continue;
            }
            ctx.chain.release(current);
            current = next;
            debug_assert_eq!(ctx.chain.kind(current), NodeKind::Task);
            match process(ctx, current, &mut record, &mut stats, &tele, trace, ids) {
                Processed::ExecutedCycleEnds => continue 'cycle,
                Processed::Absorbed => continue,
            }
        }

        // Cycle ended without an execution. Are we done?
        if ctx.chain.exhausted() && ctx.chain.is_empty() {
            break;
        }
        let idle = stats.executed + stats.created == did_work_at_cycle_start;
        if trace.full() {
            let t1 = trace.now();
            if idle {
                trace.idle(cycle_t0, t1);
            } else {
                trace.walk(cycle_t0, t1);
            }
        }
        if idle {
            // Nothing executed or created this cycle: other workers hold
            // all remaining work. Yield so the executor(s) get CPU time
            // (essential on machines with fewer cores than workers).
            stats.idle_cycles += 1;
            std::thread::yield_now();
        }
    }

    stats.busy_time = loop_start.elapsed();
    ids.publish_worker(&tele, &stats);
}

/// Handle an arrival at a live task node (visitor slot held).
fn process<M: Model, S: TaskSource<Recipe = M::Recipe>>(
    ctx: &RunCtx<'_, M, S>,
    node: Handle,
    record: &mut M::Record,
    stats: &mut WorkerStats,
    tele: &WorkerTelemetry<'_>,
    trace: TraceHandle<'_>,
    ids: &StdInstruments,
) -> Processed {
    match ctx.chain.state(node) {
        NodeState::Executing => {
            // Another worker is executing it: absorb and pass (§3.3).
            // SAFETY: we hold `node`'s visitor slot, so its incarnation
            // cannot be erased (nor its recipe freed) under us.
            record.absorb(unsafe { ctx.chain.recipe(node) });
            stats.passed_executing += 1;
            Processed::Absorbed
        }
        NodeState::Pending => {
            // SAFETY: visitor slot held (as above).
            let depends = record.depends(unsafe { ctx.chain.recipe(node) });
            if depends {
                // SAFETY: visitor slot held (as above).
                record.absorb(unsafe { ctx.chain.recipe(node) });
                stats.skipped_dependent += 1;
                Processed::Absorbed
            } else {
                // Execute. Claim the task (we hold the visitor slot, so
                // the transition is ours alone), then free the slot so
                // other workers can pass the executing task.
                ctx.chain.begin_execution(node);
                // SAFETY: `Executing` is claimed by us and only the
                // claimant erases a node, so `node` stays live — and its
                // recipe allocated — through the execution below even
                // though we release the slot.
                let seq = unsafe { ctx.chain.seq(node) };
                ctx.chain.release(node);

                let mut rng = TaskRng::for_task(ctx.seed, seq);
                // SAFETY: as above — execution claimant keeps the node
                // live.
                let recipe = unsafe { ctx.chain.recipe(node) };
                let span_t0 = if trace.active() { trace.now() } else { 0 };
                if ctx.collect_timing {
                    let t0 = Instant::now();
                    ctx.model.execute(recipe, &mut rng);
                    let dt = t0.elapsed();
                    tele.sample(ids.exec_ns, u64::try_from(dt.as_nanos()).unwrap_or(u64::MAX));
                    stats.exec_time += dt;
                } else {
                    ctx.model.execute(recipe, &mut rng);
                }
                if trace.active() {
                    trace.exec(seq, NONE_ID, NONE_SHARD, span_t0, trace.now());
                }

                // Erase: re-acquire our node's slot (waiting out any worker
                // currently passing it), unlink under the erase lock. The
                // slot goes back to the arena's free list.
                ctx.chain.acquire(node);
                ctx.chain.unlink(node);
                ctx.chain.release(node);
                // Streaming: the erased task's window room reopens here
                // (conservative Relaxed counter — see model::stream).
                if let Some(r) = &ctx.retire {
                    r.retire(1);
                }
                stats.executed += 1;
                Processed::ExecutedCycleEnds
            }
        }
        NodeState::Erased => unreachable!("stale arrivals are retried earlier"),
    }
}
