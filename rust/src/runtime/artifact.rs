//! Artifact manifest parsing (`artifacts/manifest.txt`).
//!
//! Format (written by `python/compile/aot.py`), one artifact per line:
//!
//! ```text
//! # comment
//! <name> path=<file> kind=<kind> key=value ...
//! ```

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{Context, Result};

/// One manifest entry.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactEntry {
    /// Artifact name (first token).
    pub name: String,
    /// Path to the `.hlo.txt` file, resolved against the manifest dir.
    pub path: PathBuf,
    /// Remaining key/value metadata (`kind`, shapes, parameters).
    pub meta: BTreeMap<String, String>,
}

impl ArtifactEntry {
    /// Metadata value by key.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.meta.get(key).map(String::as_str)
    }

    /// Typed metadata value.
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        let raw = self
            .get(key)
            .with_context(|| format!("artifact {}: missing meta key `{key}`", self.name))?;
        raw.parse::<T>()
            .map_err(|e| crate::err!("artifact {}: bad `{key}`={raw}: {e}", self.name))
    }

    /// The `kind` field.
    pub fn kind(&self) -> &str {
        self.get("kind").unwrap_or("")
    }
}

/// A parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    entries: Vec<ArtifactEntry>,
}

impl Manifest {
    /// Load `manifest.txt` from an artifacts directory.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<Self> {
        let dir = dir.as_ref();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text; `dir` resolves relative artifact paths.
    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let mut entries = Vec::new();
        for (i, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut tokens = line.split_whitespace();
            let name = tokens
                .next()
                .with_context(|| format!("manifest line {}: empty", i + 1))?
                .to_string();
            let mut meta = BTreeMap::new();
            for tok in tokens {
                let (k, v) = tok
                    .split_once('=')
                    .with_context(|| format!("manifest line {}: bad token `{tok}`", i + 1))?;
                meta.insert(k.to_string(), v.to_string());
            }
            let rel = meta
                .remove("path")
                .with_context(|| format!("artifact {name}: missing path"))?;
            entries.push(ArtifactEntry {
                name,
                path: dir.join(rel),
                meta,
            });
        }
        Ok(Self { entries })
    }

    /// All entries.
    pub fn entries(&self) -> &[ArtifactEntry] {
        &self.entries
    }

    /// Entry by exact name.
    pub fn by_name(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// First entry of a given kind.
    pub fn by_kind(&self, kind: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.kind() == kind)
    }

    /// The default artifacts directory, honouring `ADAPAR_ARTIFACTS`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("ADAPAR_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# adapar AOT artifact manifest
axelrod_b1_f100 path=axelrod_b1_f100.hlo.txt kind=axelrod b=1 f=100 omega=0.95
sir_block_n300_k14_s30 path=sir_block.hlo.txt kind=sir_block n=300 k=14 s=30 p_si=0.8 p_ir=0.1 p_rs=0.3
";

    #[test]
    fn parses_entries_and_meta() {
        let m = Manifest::parse(SAMPLE, Path::new("/art")).unwrap();
        assert_eq!(m.entries().len(), 2);
        let a = m.by_name("axelrod_b1_f100").unwrap();
        assert_eq!(a.kind(), "axelrod");
        assert_eq!(a.get_parse::<usize>("f").unwrap(), 100);
        assert_eq!(a.path, Path::new("/art/axelrod_b1_f100.hlo.txt"));
        let s = m.by_kind("sir_block").unwrap();
        assert_eq!(s.get_parse::<f64>("p_si").unwrap(), 0.8);
        assert_eq!(s.get_parse::<usize>("s").unwrap(), 30);
    }

    #[test]
    fn missing_path_is_an_error() {
        assert!(Manifest::parse("x kind=foo", Path::new(".")).is_err());
    }

    #[test]
    fn bad_token_is_an_error() {
        assert!(Manifest::parse("x path=a.hlo.txt garbage", Path::new(".")).is_err());
    }

    #[test]
    fn real_manifest_parses_if_present() {
        // Runs against the generated artifacts when they exist (CI builds
        // them via `make artifacts` before `cargo test`).
        let dir = Manifest::default_dir();
        if dir.join("manifest.txt").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.by_kind("axelrod").is_some());
            assert!(m.by_kind("sir_block").is_some());
            for e in m.entries() {
                assert!(e.path.exists(), "{} missing", e.path.display());
            }
        }
    }
}
