//! PJRT CPU client wrapper: compile HLO-text artifacts into executables.
//!
//! HLO **text** is the interchange format (not serialized protos): the
//! image's xla_extension 0.5.1 rejects jax ≥ 0.5 protos with 64-bit
//! instruction ids, while the text parser reassigns ids cleanly. See
//! `python/compile/aot.py` and `/opt/xla-example/README.md`.

use std::path::Path;
use std::sync::Mutex;

use crate::error::{Context, Result};

/// A PJRT client (CPU backend).
pub struct XlaRuntime {
    client: xla::PjRtClient,
}

impl XlaRuntime {
    /// Create the CPU client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client })
    }

    /// Backend platform name (e.g. `"cpu"`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Number of addressable devices.
    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load an HLO-text artifact and compile it.
    pub fn load_hlo_text<P: AsRef<Path>>(&self, path: P) -> Result<Executable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path is not UTF-8")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable {
            inner: Mutex::new(SendExec(exe)),
        })
    }
}

/// Wrapper asserting thread-safety of the underlying PJRT executable.
///
/// SAFETY: `PjRtLoadedExecutable` holds a `std::shared_ptr` to an XLA
/// `PjRtLoadedExecutable`, whose `Execute` is documented thread-safe in
/// PJRT; the Rust wrapper is `!Send` only because it stores a raw pointer.
/// We additionally serialize all calls through the `Mutex` in
/// [`Executable`], so cross-thread use is strictly sequential.
struct SendExec(xla::PjRtLoadedExecutable);
unsafe impl Send for SendExec {}

/// A compiled computation, callable from any thread (calls serialized).
pub struct Executable {
    inner: Mutex<SendExec>,
}

impl Executable {
    /// Execute with the given argument literals; returns the output
    /// literals (the AOT path lowers with `return_tuple=True`, so the
    /// single on-device output tuple is flattened here).
    pub fn call(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let guard = self.inner.lock().unwrap();
        let result = guard.0.execute::<xla::Literal>(args).context("execute")?;
        let out = result[0][0]
            .to_literal_sync()
            .context("device-to-host transfer")?;
        let tuple = out.to_tuple().context("decomposing output tuple")?;
        Ok(tuple)
    }

    /// Execute and return the single output (errors if arity ≠ 1).
    pub fn call1(&self, args: &[xla::Literal]) -> Result<xla::Literal> {
        let mut out = self.call(args)?;
        crate::ensure!(out.len() == 1, "expected 1 output, got {}", out.len());
        Ok(out.pop().unwrap())
    }
}

#[cfg(test)]
mod tests {
    // Compilation/execution is covered by the artifact-gated integration
    // test (rust/tests/xla_integration.rs) — creating PJRT clients in unit
    // tests would pay the startup cost in every `cargo test` shard.
}
