//! Typed literal marshalling helpers for the PJRT boundary.

use crate::error::{Context, Result};

/// 1-D i32 literal from a slice.
pub fn lit_i32(xs: &[i32]) -> xla::Literal {
    xla::Literal::vec1(xs)
}

/// 2-D i32 literal from row-major data.
pub fn lit_i32_2d(xs: &[i32], rows: usize, cols: usize) -> Result<xla::Literal> {
    crate::ensure!(xs.len() == rows * cols, "shape mismatch");
    xla::Literal::vec1(xs)
        .reshape(&[rows as i64, cols as i64])
        .context("reshape")
}

/// 1-D f64 literal from a slice.
pub fn lit_f64(xs: &[f64]) -> xla::Literal {
    xla::Literal::vec1(xs)
}

/// Scalar i32 literal.
pub fn lit_i32_scalar(x: i32) -> xla::Literal {
    xla::Literal::scalar(x)
}

/// Extract an i32 vector from a literal.
pub fn to_vec_i32(lit: &xla::Literal) -> Result<Vec<i32>> {
    lit.to_vec::<i32>().context("literal to Vec<i32>")
}

/// Extract an f64 vector from a literal.
pub fn to_vec_f64(lit: &xla::Literal) -> Result<Vec<f64>> {
    lit.to_vec::<f64>().context("literal to Vec<f64>")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_i32() {
        let lit = lit_i32(&[1, 2, 3]);
        assert_eq!(to_vec_i32(&lit).unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn reshape_checks_arity() {
        assert!(lit_i32_2d(&[1, 2, 3], 2, 2).is_err());
        let ok = lit_i32_2d(&[1, 2, 3, 4], 2, 2).unwrap();
        assert_eq!(to_vec_i32(&ok).unwrap(), vec![1, 2, 3, 4]);
    }

    #[test]
    fn roundtrip_f64_and_scalar() {
        let lit = lit_f64(&[0.5, 0.25]);
        assert_eq!(to_vec_f64(&lit).unwrap(), vec![0.5, 0.25]);
        let s = lit_i32_scalar(7);
        assert_eq!(s.get_first_element::<i32>().unwrap(), 7);
    }
}
