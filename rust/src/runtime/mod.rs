//! PJRT/XLA runtime: load the AOT-compiled JAX+Pallas artifacts
//! (`artifacts/*.hlo.txt`) and execute them from Rust.
//!
//! Python runs only at build time (`make artifacts`); this module makes
//! the compiled computations callable from the L3 coordinator:
//!
//! * [`client`] — PJRT CPU client + HLO-text compilation.
//! * [`artifact`] — `manifest.txt` parsing and artifact lookup.
//! * [`exec`] — typed literal marshalling helpers.
//! * [`xla_engine`] — model variants whose task execution runs through
//!   the compiled kernels ([`xla_engine::XlaSirModel`],
//!   [`xla_engine::XlaAxelrodInteractor`]), validated bitwise against the
//!   native models.

pub mod artifact;
pub mod client;
pub mod exec;
pub mod xla_engine;

pub use artifact::{ArtifactEntry, Manifest};
pub use client::{Executable, XlaRuntime};
