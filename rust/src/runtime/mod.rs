//! PJRT/XLA runtime: load the AOT-compiled JAX+Pallas artifacts
//! (`artifacts/*.hlo.txt`) and execute them from Rust.
//!
//! Python runs only at build time (`make artifacts`); this module makes
//! the compiled computations callable from the L3 coordinator:
//!
//! * [`client`] — PJRT CPU client + HLO-text compilation.
//! * [`artifact`] — `manifest.txt` parsing and artifact lookup.
//! * [`exec`] — typed literal marshalling helpers.
//! * [`xla_engine`] — model variants whose task execution runs through
//!   the compiled kernels ([`xla_engine::XlaSirModel`],
//!   [`xla_engine::XlaAxelrodInteractor`]), validated bitwise against the
//!   native models.

//! The PJRT-backed pieces need the external `xla` crate and the PJRT
//! shared library, which this offline build environment cannot fetch, so
//! they are gated behind the `xla` cargo feature (off by default).
//! Manifest parsing is pure Rust and always available.

pub mod artifact;
#[cfg(feature = "xla")]
pub mod client;
#[cfg(feature = "xla")]
pub mod exec;
#[cfg(feature = "xla")]
pub mod xla_engine;

pub use artifact::{ArtifactEntry, Manifest};
#[cfg(feature = "xla")]
pub use client::{Executable, XlaRuntime};

#[cfg(feature = "xla")]
impl From<xla::Error> for crate::error::Error {
    fn from(e: xla::Error) -> Self {
        crate::error::Error::msg(format!("xla: {e}"))
    }
}
