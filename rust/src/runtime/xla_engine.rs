//! XLA-backed task execution: model variants whose compute runs through
//! the AOT-compiled JAX+Pallas artifacts.
//!
//! These close the three-layer loop: the L3 protocol schedules tasks whose
//! execution calls L2/L1 computations compiled once at build time. Because
//! the native Rust models and the kernels implement identical f64 decision
//! arithmetic and the uniforms are fed from the same per-task streams, the
//! XLA path reproduces native results **bit for bit** (asserted by
//! `rust/tests/xla_integration.rs`).
//!
//! Per-task PJRT dispatch costs ~µs — orders of magnitude above a native
//! task body — so this engine exists for (a) validating the AOT path and
//! (b) the `xla_dispatch` bench quantifying exactly that gap; batch
//! amortization is the production answer (see `axelrod_b32` artifact).

use crate::error::{Context, Result};

use crate::model::Model;
use crate::models::sir::{SirModel, SirPhase, SirRecord, SirSource, SirTask};
use crate::sim::rng::TaskRng;

use super::artifact::Manifest;
use super::client::{Executable, XlaRuntime};
use super::exec::{lit_f64, lit_i32, lit_i32_2d, lit_i32_scalar, to_vec_i32};

/// A single-pair Axelrod interactor backed by the `axelrod_b1_*` artifact.
pub struct XlaAxelrodInteractor {
    exe: Executable,
    features: usize,
    omega: f64,
}

impl XlaAxelrodInteractor {
    /// Load from a manifest (requires an `axelrod` artifact with `b=1`).
    pub fn from_manifest(rt: &XlaRuntime, manifest: &Manifest) -> Result<Self> {
        let entry = manifest
            .entries()
            .iter()
            .find(|e| e.kind() == "axelrod" && e.get("b") == Some("1"))
            .context("no axelrod b=1 artifact in manifest")?;
        let features = entry.get_parse::<usize>("f")?;
        let omega = entry.get_parse::<f64>("omega")?;
        let exe = rt.load_hlo_text(&entry.path)?;
        Ok(Self {
            exe,
            features,
            omega,
        })
    }

    /// Static feature count baked into the artifact.
    pub fn features(&self) -> usize {
        self.features
    }

    /// Bounded-confidence threshold baked into the artifact.
    pub fn omega(&self) -> f64 {
        self.omega
    }

    /// Run one interaction; returns the target's new trait row.
    pub fn interact(
        &self,
        src: &[i32],
        tgt: &[i32],
        u_interact: f64,
        u_pick: f64,
    ) -> Result<Vec<i32>> {
        crate::ensure!(
            src.len() == self.features && tgt.len() == self.features,
            "trait row length mismatch"
        );
        let out = self.exe.call1(&[
            lit_i32_2d(src, 1, self.features)?,
            lit_i32_2d(tgt, 1, self.features)?,
            lit_f64(&[u_interact]),
            lit_f64(&[u_pick]),
        ])?;
        to_vec_i32(&out)
    }
}

/// SIR model whose **compute** tasks run through the `sir_block_*`
/// artifact (swap tasks stay native: they are pure copies).
///
/// Wraps a native [`SirModel`] — same partition, same record rules, same
/// task source — replacing only the task body.
pub struct XlaSirModel {
    inner: SirModel,
    exe: Executable,
    /// Neighbour matrix literal, marshalled once.
    nbrs: Vec<i32>,
    degree: usize,
    block: usize,
}

impl XlaSirModel {
    /// Build from a manifest entry matching the model's shape.
    pub fn from_manifest(rt: &XlaRuntime, manifest: &Manifest, inner: SirModel) -> Result<Self> {
        // The XLA kernel streams the plain byte buffers, which only the
        // legacy layout exposes (DESIGN.md §13).
        crate::ensure!(
            inner.layout() == crate::sim::soa::Layout::Legacy,
            "the XLA SIR engine needs the legacy state layout (ADAPAR_LAYOUT=legacy), got {}",
            inner.layout()
        );
        let n = inner.params.agents;
        let k = inner.params.degree;
        let s = inner.params.subset_size;
        let entry = manifest
            .entries()
            .iter()
            .find(|e| {
                e.kind() == "sir_block"
                    && e.get_parse::<usize>("n").ok() == Some(n)
                    && e.get_parse::<usize>("k").ok() == Some(k)
                    && e.get_parse::<usize>("s").ok() == Some(s)
            })
            .with_context(|| format!("no sir_block artifact for n={n} k={k} s={s}"))?;
        for (key, expect) in [
            ("p_si", inner.params.p_si),
            ("p_ir", inner.params.p_ir),
            ("p_rs", inner.params.p_rs),
        ] {
            let got = entry.get_parse::<f64>(key)?;
            crate::ensure!(
                (got - expect).abs() < 1e-12,
                "artifact {key}={got} != model {key}={expect}"
            );
        }
        let exe = rt.load_hlo_text(&entry.path)?;
        let (degree, nbrs_u32) = inner
            .graph()
            .neighbor_matrix()
            .context("SIR graph must be constant-degree")?;
        let nbrs: Vec<i32> = nbrs_u32.into_iter().map(|x| x as i32).collect();
        Ok(Self {
            inner,
            exe,
            nbrs,
            degree,
            block: s,
        })
    }

    /// The wrapped native model.
    pub fn inner(&self) -> &SirModel {
        &self.inner
    }

    /// Snapshot of current states (quiescent use).
    pub fn snapshot(&self) -> Vec<u8> {
        self.inner.snapshot()
    }

    fn compute_block_xla(&self, block: usize, rng: &mut TaskRng) -> Result<()> {
        let members = self.inner.partition().members(block);
        crate::ensure!(
            members.len() == self.block,
            "artifact block size {} != partition block {}",
            self.block,
            members.len()
        );
        let start = members[0] as i32;
        // One uniform per agent in member order — the same stream layout
        // as the native compute task.
        let u: Vec<f64> = members.iter().map(|_| rng.unit_f64()).collect();
        // SAFETY: record discipline (same footprint as the native compute
        // task; see models::sir). We read `cur` wholesale — the record
        // only guarantees non-conflict for the block's neighbourhood, but
        // concurrent writes touch rows the artifact's gather never uses
        // for this block… which the compiled gather cannot promise. The
        // XLA engine therefore only runs under the sequential engine or
        // with a single worker; `source()`/`record()` still expose the
        // full protocol surface for validation runs.
        let state = unsafe { self.inner.state_mut() };
        let cur_i32: Vec<i32> = state.cur.iter().map(|&x| x as i32).collect();
        let out = self.exe.call1(&[
            lit_i32(&cur_i32),
            lit_i32_2d(&self.nbrs, cur_i32.len(), self.degree)?,
            lit_f64(&u),
            lit_i32_scalar(start),
        ])?;
        let new_block = to_vec_i32(&out)?;
        for (i, &a) in members.iter().enumerate() {
            state.new[a as usize] = new_block[i] as u8;
        }
        Ok(())
    }
}

impl Model for XlaSirModel {
    type Recipe = SirTask;
    type Record = SirRecord;
    type Source = SirSource;

    fn source(&self, seed: u64) -> SirSource {
        self.inner.source(seed)
    }

    fn record(&self) -> SirRecord {
        self.inner.record()
    }

    fn execute(&self, r: &SirTask, rng: &mut TaskRng) {
        match r.phase {
            SirPhase::Compute => self
                .compute_block_xla(r.block as usize, rng)
                .expect("XLA compute task failed"),
            SirPhase::Swap => self.inner.execute(r, rng),
        }
    }

    fn task_work(&self, r: &SirTask) -> f64 {
        self.inner.task_work(r)
    }
}
