//! Per-block cost telemetry and the EWMA cost model driving the
//! rebalancer.
//!
//! Workers time every `Model::execute` call (the per-task timing that
//! `WorkerStats::exec_time` already aggregates) and bill it to the task's
//! *home block* through the lock-free [`CostProbe`]. At each quiescent
//! epoch boundary the engine drains the probe into the [`BlockCost`]
//! model: an exponentially-weighted moving average of ns-per-task and
//! tasks-per-epoch per block, whose product is the block's *load* — the
//! quantity the rebalancer equalizes across shards. EWMA smoothing makes
//! the loop graceful under heterogeneous, drifting per-agent cost (e.g.
//! Axelrod's trait-dependent work): one noisy epoch cannot trigger a
//! migration storm, yet persistent skew is tracked within a few epochs.

use std::sync::atomic::{AtomicU64, Ordering};

use super::shard::ShardMap;

/// Lock-free per-block execution-time accumulator, written by workers on
/// the hot path and drained by the engine between epochs.
pub struct CostProbe {
    cells: Vec<Cell>,
}

#[derive(Default)]
struct Cell {
    ns: AtomicU64,
    tasks: AtomicU64,
}

impl CostProbe {
    /// A probe over `blocks` footprint blocks.
    pub fn new(blocks: usize) -> Self {
        let mut cells = Vec::with_capacity(blocks);
        cells.resize_with(blocks, Cell::default);
        Self { cells }
    }

    /// Number of blocks tracked.
    pub fn blocks(&self) -> usize {
        self.cells.len()
    }

    /// Bill `ns` nanoseconds of execution to `block` (relaxed ordering:
    /// the counters are only read at quiescent boundaries, after the
    /// worker joins).
    #[inline]
    pub fn record(&self, block: u32, ns: u64) {
        let cell = &self.cells[block as usize];
        cell.ns.fetch_add(ns, Ordering::Relaxed);
        cell.tasks.fetch_add(1, Ordering::Relaxed);
    }

    /// Drain one epoch's `(tasks, ns)` per block, resetting the counters.
    pub fn drain(&self) -> Vec<(u64, u64)> {
        self.cells
            .iter()
            .map(|c| (c.tasks.swap(0, Ordering::Relaxed), c.ns.swap(0, Ordering::Relaxed)))
            .collect()
    }
}

/// EWMA per-block cost model: `cost(b)` ≈ expected ns per task of block
/// `b`, `rate(b)` ≈ tasks of block `b` per epoch. `load(b) = cost · rate`
/// is the block's expected work per epoch.
pub struct BlockCost {
    alpha: f64,
    cost_ns: Vec<f64>,
    rate: Vec<f64>,
    seen: Vec<bool>,
}

impl BlockCost {
    /// A model over `blocks` blocks with smoothing factor `alpha`
    /// (weight of the newest epoch, in `(0, 1]`).
    pub fn new(blocks: usize, alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0, "alpha must be in (0, 1]");
        Self {
            alpha,
            cost_ns: vec![0.0; blocks],
            rate: vec![0.0; blocks],
            seen: vec![false; blocks],
        }
    }

    /// Fold one epoch's probe readings into the averages. The first
    /// observation of a block seeds its EWMA directly (no bias toward the
    /// zero prior); the task rate decays for blocks idle this epoch.
    pub fn update(&mut self, probe: &CostProbe) {
        debug_assert_eq!(probe.blocks(), self.cost_ns.len());
        for (b, (tasks, ns)) in probe.drain().into_iter().enumerate() {
            if tasks > 0 {
                let mean = ns as f64 / tasks as f64;
                self.cost_ns[b] = if self.seen[b] {
                    self.alpha * mean + (1.0 - self.alpha) * self.cost_ns[b]
                } else {
                    self.seen[b] = true;
                    mean
                };
            }
            self.rate[b] = self.alpha * tasks as f64 + (1.0 - self.alpha) * self.rate[b];
        }
    }

    /// Expected ns per task of `block` (0 until first observed).
    #[inline]
    pub fn cost_ns(&self, block: usize) -> f64 {
        self.cost_ns[block]
    }

    /// Smoothed tasks per epoch of `block`.
    #[inline]
    pub fn rate(&self, block: usize) -> f64 {
        self.rate[block]
    }

    /// Expected work (ns) of `block` per epoch.
    #[inline]
    pub fn load(&self, block: usize) -> f64 {
        self.cost_ns[block] * self.rate[block]
    }

    /// Expected work per shard under `map` — the imbalance view the
    /// rebalancer equalizes.
    pub fn shard_loads(&self, map: &ShardMap) -> Vec<f64> {
        debug_assert_eq!(map.blocks(), self.cost_ns.len());
        let mut loads = vec![0.0; map.shards()];
        for b in 0..map.blocks() {
            loads[map.shard_of(b as u32) as usize] += self.load(b);
        }
        loads
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::graph::{bfs_partition, ring_lattice};

    #[test]
    fn probe_accumulates_and_drains() {
        let probe = CostProbe::new(3);
        probe.record(0, 100);
        probe.record(0, 300);
        probe.record(2, 50);
        assert_eq!(probe.drain(), vec![(2, 400), (0, 0), (1, 50)]);
        assert_eq!(probe.drain(), vec![(0, 0); 3], "drain resets");
    }

    #[test]
    fn ewma_seeds_then_smooths() {
        let probe = CostProbe::new(1);
        let mut cost = BlockCost::new(1, 0.5);
        assert_eq!(cost.load(0), 0.0);

        probe.record(0, 1000);
        cost.update(&probe);
        assert!((cost.cost_ns(0) - 1000.0).abs() < 1e-9, "first epoch seeds");
        assert!((cost.rate(0) - 0.5).abs() < 1e-9, "rate EWMA from zero prior");

        probe.record(0, 3000);
        cost.update(&probe);
        // cost: 0.5·3000 + 0.5·1000 = 2000
        assert!((cost.cost_ns(0) - 2000.0).abs() < 1e-9);

        // Idle epoch: cost holds, rate decays.
        let rate_before = cost.rate(0);
        cost.update(&probe);
        assert!((cost.cost_ns(0) - 2000.0).abs() < 1e-9);
        assert!(cost.rate(0) < rate_before);
    }

    #[test]
    fn shard_loads_sum_block_loads() {
        let g = ring_lattice(4, 2);
        let map = super::super::shard::ShardMap::from_partition(&bfs_partition(&g, 2));
        let probe = CostProbe::new(4);
        let mut cost = BlockCost::new(4, 1.0);
        for b in 0..4u32 {
            probe.record(b, 100 * (b as u64 + 1));
        }
        cost.update(&probe);
        let loads = cost.shard_loads(&map);
        assert_eq!(loads.len(), 2);
        let total: f64 = loads.iter().sum();
        assert!((total - (100.0 + 200.0 + 300.0 + 400.0)).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn zero_alpha_rejected() {
        let _ = BlockCost::new(1, 0.0);
    }

    #[test]
    fn zero_cost_blocks_carry_zero_load() {
        // Chaos skew mul = 0.0 bills 0 ns per task; the block must read
        // as free (load 0) without poisoning the EWMA with NaN.
        let probe = CostProbe::new(2);
        let mut cost = BlockCost::new(2, 0.5);
        probe.record(0, 0);
        probe.record(0, 0);
        probe.record(1, 500);
        cost.update(&probe);
        assert_eq!(cost.cost_ns(0), 0.0);
        assert_eq!(cost.load(0), 0.0);
        assert!(cost.load(1) > 0.0);
        assert!(cost.load(0).is_finite() && cost.cost_ns(0).is_finite());
    }

    #[test]
    fn extreme_skew_orders_loads_by_magnitude() {
        // A 1e6x cost skew between blocks (chaos "skew" plan territory)
        // must survive the EWMA with the ordering and ratio intact.
        let probe = CostProbe::new(2);
        let mut cost = BlockCost::new(2, 1.0);
        probe.record(0, 1);
        probe.record(1, 1_000_000);
        cost.update(&probe);
        assert!(cost.load(1) > cost.load(0));
        assert!((cost.load(1) / cost.load(0) - 1e6).abs() < 1e-3);
    }

    #[test]
    fn ewma_saturates_at_the_steady_state() {
        // Feeding the same epoch forever must converge to that epoch's
        // mean (fixed point), not drift or overshoot.
        let probe = CostProbe::new(1);
        let mut cost = BlockCost::new(1, 0.25);
        for _ in 0..200 {
            for _ in 0..4 {
                probe.record(0, 800);
            }
            cost.update(&probe);
        }
        assert!((cost.cost_ns(0) - 800.0).abs() < 1e-6, "cost fixed point");
        assert!((cost.rate(0) - 4.0).abs() < 1e-6, "rate fixed point");
        // One outlier epoch moves the average by at most alpha's weight.
        probe.record(0, 8_000_000);
        cost.update(&probe);
        assert!(cost.cost_ns(0) <= 0.25 * 8_000_000.0 + 0.75 * 800.0 + 1e-6);
    }

    #[test]
    fn probe_survives_huge_accumulations() {
        // Sub-u64-overflow but far beyond realistic epochs: the drain
        // path must not wrap or lose counts.
        let probe = CostProbe::new(1);
        for _ in 0..1000 {
            probe.record(0, u32::MAX as u64);
        }
        let drained = probe.drain();
        assert_eq!(drained[0].0, 1000);
        assert_eq!(drained[0].1, 1000 * (u32::MAX as u64));
    }

    #[test]
    fn rate_decays_toward_zero_for_idle_blocks() {
        // Saturation in the other direction: a block that stops seeing
        // tasks must have its load fade so the rebalancer can reclaim it.
        let probe = CostProbe::new(1);
        let mut cost = BlockCost::new(1, 0.5);
        probe.record(0, 1000);
        cost.update(&probe);
        let initial = cost.load(0);
        assert!(initial > 0.0);
        for _ in 0..40 {
            cost.update(&probe);
        }
        assert!(cost.load(0) < initial * 1e-9, "idle load must decay");
        assert_eq!(cost.cost_ns(0), 1000.0, "per-task cost memory persists");
    }
}
