//! The sharded adaptive engine: per-shard chains, a spillover chain for
//! cross-shard tasks, and the epoch-boundary rebalance loop.
//!
//! ## Architecture (DESIGN.md §8)
//!
//! * The model's footprint topology is partitioned once into `shards`
//!   balanced blocks-of-blocks, dispatching on the model's
//!   [`PartitionHint`]: lattice models get the strip/block grid tiling,
//!   everything else the greedy BFS edge-cut partitioner. Each shard
//!   owns a [`Chain`] and each worker owns the shards congruent to its
//!   id (one shard per worker by default).
//! * A mutex-serialized splitter draws tasks from the epoch-gated
//!   source in canonical order — up to `batch` per router-lock hold —
//!   and routes each to its shard chain, or — when its footprint
//!   crosses shards — to the spillover chain with a fence in every
//!   touched shard chain.
//! * Shard owners run the ordinary worker–chain cycle over their own
//!   chain, with two fence rules: an incomplete fence is absorbed (so
//!   later conflicting local tasks wait), a completed fence is unlinked
//!   in passing. Every worker also polls the spillover chain; a boundary
//!   task executes only when, in each touched shard chain, everything
//!   ahead of its fence is complete (checked by a slot-free walk over
//!   generation-validated link snapshots whose `true` verdict is exact
//!   and whose races only yield conservative `false`s).
//! * At each quiescent epoch boundary the engine folds the per-block
//!   execution timings into the EWMA [`BlockCost`] model and lets the
//!   [`Rebalancer`] migrate blocks between shards — the adaptive loop
//!   that keeps heterogeneous per-agent cost balanced. Routing changes
//!   never touch canonical task order or per-task RNG streams, so final
//!   states and epoch traces stay byte-identical to the sequential
//!   engine (rust/tests/sharded.rs).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::api::observe::{ObsProbe, Observer};
use crate::chain::{Chain, Handle, NodeState};
use crate::chaos::{FaultHook, Invariant};
use crate::model::{Model, Record, TaskSource};
use crate::protocol::engine::chain_capacity;
use crate::protocol::{
    ProtocolStats, RunReport, SchedStats, StdInstruments, TimeBasis, WorkerStats, DEFAULT_BATCH,
};
use crate::sim::graph::{bfs_partition, edge_cut, grid_partition, Partition};
use crate::sim::rng::TaskRng;
use crate::telemetry::{CounterId, HistId, MetricsRegistry, TelemetryCore, TelemetryMode, WorkerTelemetry};
use crate::trace::{TraceCore, TraceHandle, TraceMode, NONE_SHARD};

use super::cost::{BlockCost, CostProbe};
use super::rebalance::Rebalancer;
use super::shard::{Boundary, PartitionHint, ShardItem, ShardMap, ShardableModel, Splitter};

/// Which partitioner the engine uses for the initial shard assignment.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PartitionPolicy {
    /// Follow the model's [`PartitionHint`] (grid tiling on lattices,
    /// BFS otherwise) — the production default.
    #[default]
    Auto,
    /// Ignore the hint and always BFS-partition — the comparison
    /// baseline for benches and ablations.
    ForceGeneral,
}

/// Sharded-engine workflow parameters.
#[derive(Clone, Copy, Debug)]
pub struct ShardedConfig {
    /// Number of workers (one dedicated thread each).
    pub workers: usize,
    /// `C` — maximum splitter pulls per worker cycle (the chain
    /// protocol's creation cap, applied to routing; checked per batch).
    pub tasks_per_cycle: u32,
    /// `B` — maximum tasks routed per splitter-lock hold (the sharded
    /// engine's batching knob); the effective batch is `min(B,
    /// remaining C)`, so deep batching needs `C ≥ B`. Routing order is
    /// canonical at any value; only lock amortization changes.
    pub batch: u32,
    /// Simulation seed (canonical creation + per-task execution streams).
    pub seed: u64,
    /// Number of shards; `0` means one per worker. Clamped to the
    /// topology's block count.
    pub shards: usize,
    /// Epoch length in canonical tasks for *unobserved* runs — the
    /// rebalance cadence (`0` disables epoching: one epoch, no
    /// adaptation). Observed runs epoch at the observer's cadence
    /// instead, rebalancing at those same boundaries.
    pub rebalance_every: u64,
    /// EWMA smoothing factor for the per-block cost model.
    pub alpha: f64,
    /// Partitioner selection (see [`PartitionPolicy`]).
    pub partition: PartitionPolicy,
    /// Ring/aggregator layer mode (the lossless counter layer is always
    /// on). Semantically inert: any value yields the identical trace
    /// (DESIGN.md §11). Defaults from `ADAPAR_TELEMETRY`.
    pub telemetry: TelemetryMode,
    /// Causal-tracing mode (timeline spans + causal edges, DESIGN.md
    /// §12). Semantically inert like telemetry. Defaults from
    /// `ADAPAR_TRACE`.
    pub trace: TraceMode,
    /// `W` — streaming materialization window (ISSUE 10, DESIGN.md
    /// §14): at most this many *canonical* tasks outstanding (routed,
    /// not yet executed) at any instant; `0` disables streaming.
    /// Boundary tasks additionally pin one fence per touched shard, so
    /// the node bound is `O(W)` with the fence fan-out as the constant.
    /// Semantically inert (byte-identical traces at any value).
    /// Defaults from `ADAPAR_WINDOW` / `ADAPAR_STREAMING`.
    pub window: u64,
}

impl Default for ShardedConfig {
    fn default() -> Self {
        Self {
            workers: std::thread::available_parallelism()
                .map(std::num::NonZeroUsize::get)
                .unwrap_or(2),
            tasks_per_cycle: 6,
            batch: DEFAULT_BATCH,
            seed: 0,
            shards: 0,
            rebalance_every: 8_192,
            alpha: 0.4,
            partition: PartitionPolicy::Auto,
            telemetry: TelemetryMode::env_default(),
            trace: TraceMode::env_default(),
            window: crate::model::stream::env_window(),
        }
    }
}

/// The sharded adaptive engine.
pub struct ShardedEngine {
    cfg: ShardedConfig,
}

impl ShardedEngine {
    /// Create an engine with the given configuration.
    pub fn new(cfg: ShardedConfig) -> Self {
        assert!(cfg.workers >= 1, "need at least one worker");
        assert!(cfg.tasks_per_cycle >= 1, "C must be at least 1");
        assert!(cfg.batch >= 1, "B must be at least 1");
        assert!(cfg.alpha > 0.0 && cfg.alpha <= 1.0, "alpha must be in (0, 1]");
        Self { cfg }
    }

    /// Configuration accessor.
    pub fn config(&self) -> &ShardedConfig {
        &self.cfg
    }

    /// Run `model` to completion.
    pub fn run<M: ShardableModel>(&self, model: &M) -> RunReport {
        self.run_epochs(model, None, None)
    }

    /// Run with epoch snapshots at the observer's cadence; frames are
    /// taken at drained quiescent boundaries, so the trace is
    /// byte-identical to the sequential engine's at the same seed.
    pub fn run_observed<M: ShardableModel>(
        &self,
        model: &M,
        probe: ObsProbe<'_>,
        observer: &mut Observer,
    ) -> RunReport {
        self.run_epochs(model, Some((probe, observer)), None)
    }

    /// Run with a chaos [`FaultHook`] installed (DESIGN.md §10): worker
    /// stalls and fence staggers become capped wall sleeps at each
    /// epoch's start, cost skews feed synthetic probe observations, and
    /// the engine's boundary invariants (fence discipline, rebalancer
    /// convergence) report into the hook instead of only debug asserts.
    pub fn run_chaos<M: ShardableModel>(&self, model: &M, hook: &mut FaultHook) -> RunReport {
        self.run_epochs(model, None, Some(hook))
    }

    /// Chaos run with epoch observation (the soak runner's shape: inject
    /// faults while snapshotting the trace for byte-comparison).
    pub fn run_chaos_observed<M: ShardableModel>(
        &self,
        model: &M,
        probe: ObsProbe<'_>,
        observer: &mut Observer,
        hook: &mut FaultHook,
    ) -> RunReport {
        self.run_epochs(model, Some((probe, observer)), Some(hook))
    }

    fn run_epochs<M: ShardableModel>(
        &self,
        model: &M,
        mut obs: Option<(ObsProbe<'_>, &mut Observer)>,
        mut hook: Option<&mut FaultHook>,
    ) -> RunReport {
        let topology = model.sched_topology();
        let blocks = topology.n();
        assert!(blocks > 0, "sharded engine needs at least one footprint block");
        let requested = if self.cfg.shards == 0 {
            self.cfg.workers
        } else {
            self.cfg.shards
        };
        let shards = requested.clamp(1, blocks);
        // Partitioner dispatch: the model's hint picks the lattice-native
        // tiling when the footprint blocks form a grid; the policy knob
        // lets benches force the generic baseline for comparison.
        let hint = match self.cfg.partition {
            PartitionPolicy::ForceGeneral => PartitionHint::General,
            PartitionPolicy::Auto => model.partition_hint(),
        };
        let (partition, strategy): (Partition, &'static str) = match hint {
            PartitionHint::Grid { rows, cols } if rows * cols == blocks => {
                (grid_partition(rows, cols, shards), "grid")
            }
            PartitionHint::Grid { rows, cols } => {
                // A hint that disagrees with the topology is a model bug:
                // loud in debug builds, graceful BFS fallback in release.
                debug_assert_eq!(rows * cols, blocks, "grid hint disagrees with topology");
                (bfs_partition(&topology, shards), "bfs")
            }
            PartitionHint::General => (bfs_partition(&topology, shards), "bfs"),
        };
        let cut = edge_cut(&topology, &partition);
        let map = ShardMap::from_partition(&partition);

        let every = match &obs {
            Some((_, o)) => o.gate_cadence(),
            None if self.cfg.rebalance_every == 0 => match hook.as_ref() {
                Some(h) => h.every_or(u64::MAX),
                None => u64::MAX,
            },
            None => self.cfg.rebalance_every,
        };

        let source = model.source(self.cfg.seed);
        // Pre-size every chain's arena: each holds a slice of the live
        // backlog, so a couple of workers' worth of slots per chain is
        // ample; the source hint caps tiny runs (same heuristic as the
        // single-chain engine).
        let size_hint = source.size_hint();
        let per_chain_cap = chain_capacity(
            size_hint,
            2,
            self.cfg.tasks_per_cycle,
            self.cfg.batch,
            self.cfg.window,
        );
        let mut chains: Vec<Chain<ShardItem<M::Recipe>>> = (0..shards)
            .map(|_| Chain::with_capacity(per_chain_cap))
            .collect();
        let mut spill: Chain<Arc<Boundary<M::Recipe>>> = Chain::with_capacity(per_chain_cap);
        let mut sp = Splitter::<M>::new(source, map);
        if self.cfg.window > 0 {
            sp.set_window(Some(crate::model::Window::new(self.cfg.window)));
        }
        let retire = sp.retire_handle();
        let splitter = Mutex::new(sp);
        let costs = CostProbe::new(blocks);
        let closed = AtomicBool::new(false);
        let per_shard_executed: Vec<AtomicU64> =
            (0..shards).map(|_| AtomicU64::new(0)).collect();
        // Backpressure: routing stops while this many tasks are live, so
        // a worker with a drained chain cannot pump the whole epoch into
        // the busy shards' chains (which would make every traversal and
        // readiness walk O(epoch)). Generous enough to keep all workers
        // and shards fed.
        let backlog_cap = (shards.max(self.cfg.workers) * self.cfg.tasks_per_cycle as usize * 8)
            .max(256);

        // The registry is the single source of truth for worker-side
        // statistics: workers publish onto their rows at each epoch's
        // end, and the report's `per_worker`/`chain` stats — plus the
        // worker-side `SchedStats` counters — are views reconstructed
        // from the final snapshot.
        let mut reg = MetricsRegistry::new();
        let ids = SchedInstruments::register(&mut reg, shards);
        let tele = reg.start(self.cfg.workers, self.cfg.telemetry);
        let trc = TraceCore::start(self.cfg.trace, self.cfg.workers, "sharded", "wall");
        let trc_coord = match &trc {
            Some(c) => c.coordinator(),
            None => TraceHandle::disabled(),
        };
        let mut sched = SchedStats {
            shards,
            edge_cut: cut,
            partition: strategy,
            per_shard_executed: vec![0; shards],
            ..Default::default()
        };
        let mut cost_model = BlockCost::new(blocks, self.cfg.alpha);
        let rebalancer = Rebalancer::default();

        if let Some((probe, observer)) = obs.as_mut() {
            observer.record_initial(*probe);
        }
        let t0 = Instant::now();
        loop {
            // Chaos injection happens here, at the epoch boundary, and
            // nowhere else: resolve this epoch's faults once, turn them
            // into per-worker start-up sleeps, and feed the cost skews
            // into the probe so the EWMA model and rebalancer see a
            // perturbed view. `stalls` is empty on clean runs, so the
            // workers' one-shot check reads an empty slice.
            let stalls: Vec<Duration> = match hook.as_mut() {
                Some(h) => {
                    let faults = h.next_epoch(self.cfg.workers);
                    for skew in &faults.skews {
                        if (skew.block as usize) < blocks {
                            costs.record(skew.block, (skew.mul * 1_000.0).max(0.0) as u64);
                        }
                    }
                    faults.wall_stalls()
                }
                None => Vec::new(),
            };
            // The context is rebuilt per epoch (shared borrows only live
            // through one epoch's worker scope) so the chains can be
            // mutably shrunk at the quiescent boundary below.
            let ctx = ShardCtx {
                model,
                chains: &chains,
                spill: &spill,
                splitter: &splitter,
                closed: &closed,
                costs: &costs,
                per_shard_executed: &per_shard_executed,
                workers: self.cfg.workers,
                seed: self.cfg.seed,
                tasks_per_cycle: self.cfg.tasks_per_cycle,
                batch: self.cfg.batch,
                backlog_cap,
                retire: retire.clone(),
            };
            closed.store(false, Ordering::Release);
            splitter.lock().unwrap().open(every);
            if self.cfg.workers == 1 {
                sharded_worker(
                    &ctx,
                    0,
                    stalls.first().copied().unwrap_or_default(),
                    tele.handle(0),
                    TraceHandle::lane(trc.as_ref(), 0),
                    &ids,
                );
            } else {
                std::thread::scope(|s| {
                    let handles: Vec<_> = (0..self.cfg.workers)
                        .map(|w| {
                            let ctx_ref = &ctx;
                            let ids_ref = &ids;
                            let h = tele.handle(w);
                            let th = TraceHandle::lane(trc.as_ref(), w);
                            let stall = stalls.get(w).copied().unwrap_or_default();
                            s.spawn(move || sharded_worker(ctx_ref, w, stall, h, th, ids_ref))
                        })
                        .collect();
                    for h in handles {
                        h.join().expect("sharded worker panicked");
                    }
                });
            }

            // Quiescent: every routed task (and fence) is gone.
            debug_assert!(chains.iter().all(Chain::is_empty), "epoch left live tasks");
            debug_assert!(spill.is_empty(), "epoch left live boundary tasks");
            if let Some(h) = hook.as_mut() {
                // Fence discipline, checked in release builds too while a
                // hook is installed: a quiescent boundary must leave no
                // live task, fence, or boundary node in any chain.
                if !chains.iter().all(Chain::is_empty) || !spill.is_empty() {
                    h.record_violation(
                        Invariant::FenceDiscipline,
                        format!(
                            "epoch boundary left live nodes: chains={:?} spill={}",
                            chains.iter().map(Chain::len).collect::<Vec<_>>(),
                            spill.len()
                        ),
                    );
                }
            }
            let done = {
                let mut sp = splitter.lock().unwrap();
                if let Some((probe, observer)) = obs.as_mut() {
                    observer.record(sp.emitted(), probe());
                }
                trc_coord.epoch_mark(sp.emitted());
                let done = sp.finished();
                if !done && every != u64::MAX {
                    // Close the adaptive loop: fold this epoch's per-block
                    // timings into the EWMA model, then migrate blocks.
                    let rb_t0 = if trc_coord.active() { trc_coord.now() } else { 0 };
                    cost_model.update(&costs);
                    let gap_before = hook
                        .as_ref()
                        .map(|_| load_gap(&cost_model.shard_loads(sp.map_mut())));
                    let moves = rebalancer.rebalance(sp.map_mut(), &cost_model, &topology);
                    if trc_coord.active() {
                        trc_coord.rebalance(moves, rb_t0, trc_coord.now());
                    }
                    sched.migrations += moves;
                    sched.rebalances += 1;
                    if let Some(h) = hook.as_mut() {
                        // Rebalancer convergence: the per-epoch move count
                        // is capped and each move strictly narrows the
                        // modelled shard-load gap, so the gap never widens
                        // across a boundary.
                        if moves > rebalancer.max_moves as u64 {
                            h.record_violation(
                                Invariant::RebalanceConvergence,
                                format!(
                                    "rebalancer moved {moves} blocks, above its cap of {}",
                                    rebalancer.max_moves
                                ),
                            );
                        }
                        let gap_after = load_gap(&cost_model.shard_loads(sp.map_mut()));
                        if let Some(before) = gap_before {
                            if gap_after > before + 1e-9 {
                                h.record_violation(
                                    Invariant::RebalanceConvergence,
                                    format!(
                                        "shard-load gap widened across a rebalance: \
                                         {before:.1} -> {gap_after:.1} ns"
                                    ),
                                );
                            }
                        }
                    }
                }
                done
            };
            if done {
                break;
            }
            // Quiescent shrink (ISSUE 10): release arena chunks a burst
            // may have grown beyond the per-chain steady-state estimate.
            for c in &mut chains {
                c.shrink_on_quiesce(per_chain_cap);
            }
            spill.shrink_on_quiesce(per_chain_cap);
        }
        let wall = t0.elapsed();

        let splitter = splitter.into_inner().unwrap();
        let (local, boundary) = splitter.counts();
        sched.local_tasks = local;
        sched.boundary_tasks = boundary;
        for (slot, counter) in sched.per_shard_executed.iter_mut().zip(&per_shard_executed) {
            *slot = counter.load(Ordering::Relaxed);
        }
        sched.per_shard_tail_locks = chains.iter().map(Chain::tail_locks).collect();
        let arena_capacity = chains.iter().map(Chain::arena_capacity).sum::<usize>()
            + spill.arena_capacity();
        let arena_high_water = chains.iter().map(Chain::arena_high_water).sum::<usize>()
            + spill.arena_high_water();
        sched.arena_occupancy = if arena_capacity == 0 {
            0.0
        } else {
            arena_high_water as f64 / arena_capacity as f64
        };
        let tail_locks =
            chains.iter().map(Chain::tail_locks).sum::<u64>() + spill.tail_locks();
        let arena_recycled = chains.iter().map(Chain::arena_recycled).sum::<u64>()
            + spill.arena_recycled();
        // Drained, every chain (shards + spillover) holds exactly its two
        // sentinels; anything above that is a leaked slot (DESIGN.md §10).
        let arena_live =
            chains.iter().map(Chain::arena_live).sum::<usize>() + spill.arena_live();
        let max_chain_len = chains
            .iter()
            .map(Chain::max_len)
            .chain(std::iter::once(spill.max_len()))
            .max()
            .unwrap_or(0);

        // Publish the engine-side stats onto the global row, fence the
        // aggregator (workers are joined), and rebuild the worker-side
        // stats as views over the snapshot.
        ids.std.publish_chain(
            &tele,
            &ProtocolStats {
                tasks_created: local + boundary,
                tasks_executed: local + boundary,
                max_chain_len,
                tail_locks,
                batch: self.cfg.batch,
                arena_capacity,
                arena_high_water,
                arena_recycled,
                arena_live,
                state_bytes: crate::protocol::stats::state_bytes_total(
                    model.state_bytes_per_task(),
                    local + boundary,
                ),
            },
        );
        ids.publish_engine(&tele, &sched);
        let snap = tele.finish();
        sched.fence_clears = snap.counter("sched.fence_clears");
        sched.spill_blocked = snap.counter("sched.spill_blocked");
        sched.backpressure_stalls = snap.counter("sched.backpressure_stalls");
        let per_worker: Vec<WorkerStats> = (0..self.cfg.workers)
            .map(|w| WorkerStats::from_snapshot(&snap, w))
            .collect();
        let mut totals = WorkerStats::default();
        for w in &per_worker {
            totals.merge(w);
        }
        RunReport {
            engine: "sharded",
            workers: self.cfg.workers,
            time_s: wall.as_secs_f64(),
            basis: TimeBasis::Wall,
            totals,
            per_worker,
            chain: ProtocolStats::from_snapshot(&snap, self.cfg.batch),
            sched: Some(sched),
            telemetry: Some(snap),
            trace: trc.map(|c| {
                let mut tr = c.finish();
                tr.shards = shards;
                tr
            }),
        }
    }
}

/// Shared, read-only context for one sharded run.
struct ShardCtx<'a, M: ShardableModel> {
    model: &'a M,
    chains: &'a [Chain<ShardItem<M::Recipe>>],
    spill: &'a Chain<Arc<Boundary<M::Recipe>>>,
    splitter: &'a Mutex<Splitter<M>>,
    /// Set (under the splitter mutex) when the epoch's task budget — or
    /// the source — is exhausted; no append happens afterwards.
    closed: &'a AtomicBool,
    costs: &'a CostProbe,
    per_shard_executed: &'a [AtomicU64],
    workers: usize,
    seed: u64,
    tasks_per_cycle: u32,
    /// `B`: max tasks routed per router-lock hold.
    batch: u32,
    /// Live-task ceiling across all chains: routing pauses above it.
    backlog_cap: usize,
    /// Streaming-window retirement handle (ISSUE 10): bumped once per
    /// executed canonical task (local or boundary — never per fence) so
    /// the gated source regains materialization room. `None` on
    /// materialized runs.
    retire: Option<crate::model::RetireHandle>,
}

impl<M: ShardableModel> ShardCtx<'_, M> {
    /// Route up to `min(B, budget)` tasks through the splitter under one
    /// router-lock hold — `budget` is the caller's remaining per-cycle
    /// allowance, so batching never loosens the `C` cap; returns how
    /// many were routed (and raises `closed` once the epoch is out of
    /// tasks — a short batch is the exhaustion signal). Safe to call
    /// while holding a visitor slot: the splitter's appends take no
    /// visitor slots ([`Chain::append_tail`]), so appenders and
    /// traversers never wait on each other.
    fn pull(&self, budget: u32) -> u32 {
        let want = self.batch.min(budget).max(1);
        let mut sp = self.splitter.lock().unwrap();
        let got = sp.pull_batch(self.model, self.chains, self.spill, want);
        // A short batch closes the epoch — unless it was a temporary
        // streaming-window stall (checked under the same lock hold):
        // routing room reopens as outstanding tasks retire, and closing
        // early would end the epoch with canonical tasks unrouted,
        // corrupting the observation trace.
        if got < want && !sp.window_stalled() {
            self.closed.store(true, Ordering::Release);
        }
        got
    }

    /// Whether this epoch is over: no more routing will happen (`closed`
    /// is observed first, so chains can only shrink afterwards) and every
    /// chain has drained.
    fn epoch_done(&self) -> bool {
        self.closed.load(Ordering::Acquire)
            && self.spill.is_empty()
            && self.chains.iter().all(Chain::is_empty)
    }

    /// Whether routing should pause: enough tasks are already live.
    /// Purely a throttle — execution drains the backlog and pulls
    /// resume, so this cannot deadlock the epoch.
    fn backlog_full(&self) -> bool {
        let live: usize = self.chains.iter().map(Chain::len).sum::<usize>() + self.spill.len();
        live >= self.backlog_cap
    }
}

/// Spread of the modelled per-shard loads (max − min); the rebalancer's
/// convergence invariant says it never widens across a boundary.
fn load_gap(loads: &[f64]) -> f64 {
    let max = loads.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    let min = loads.iter().copied().fold(f64::INFINITY, f64::min);
    if loads.is_empty() {
        0.0
    } else {
        max - min
    }
}

/// Consecutive starved idle cycles (idle worker, epoch open, backlog at
/// its ceiling) a worker tolerates before bypassing the live-task
/// ceiling for a single task — the livelock guard in
/// [`sharded_worker`].
const BACKPRESSURE_PATIENCE: u32 = 64;

/// The sharded engine's instrument set: the chain engines' standard
/// `worker.*`/`chain.*` instruments plus the `sched.*` counters backing
/// [`SchedStats`] — including per-shard keys (`sched.shard{k}.executed`,
/// `sched.shard{k}.tail_locks`) and per-shard routing-batch histograms
/// (`sched.shard{k}.batch_fill`; pulls not attributable to one shard's
/// tail sample `sched.route.batch_fill`). [`SchedStats`] worker-side
/// counters are views over the snapshot of these.
struct SchedInstruments {
    std: StdInstruments,
    local_tasks: CounterId,
    boundary_tasks: CounterId,
    fence_clears: CounterId,
    spill_blocked: CounterId,
    backpressure_stalls: CounterId,
    migrations: CounterId,
    rebalances: CounterId,
    edge_cut: CounterId,
    shards: CounterId,
    /// `sched.shard{k}.executed` — local executions attributed to shard k.
    shard_executed: Vec<CounterId>,
    /// `sched.shard{k}.tail_locks` — creation-lock holds on shard k's chain.
    shard_tail_locks: Vec<CounterId>,
    /// `sched.shard{k}.batch_fill` — tasks routed per pull at shard k's tail.
    shard_fill: Vec<HistId>,
    /// `sched.route.batch_fill` — idle-path / livelock-bypass pulls.
    route_fill: HistId,
}

impl SchedInstruments {
    fn register(reg: &mut MetricsRegistry, shards: usize) -> Self {
        SchedInstruments {
            std: StdInstruments::register(reg),
            local_tasks: reg.counter("sched.local_tasks"),
            boundary_tasks: reg.counter("sched.boundary_tasks"),
            fence_clears: reg.counter("sched.fence_clears"),
            spill_blocked: reg.counter("sched.spill_blocked"),
            backpressure_stalls: reg.counter("sched.backpressure_stalls"),
            migrations: reg.counter("sched.migrations"),
            rebalances: reg.counter("sched.rebalances"),
            edge_cut: reg.counter("sched.edge_cut"),
            shards: reg.counter("sched.shards"),
            shard_executed: (0..shards)
                .map(|k| reg.counter(&format!("sched.shard{k}.executed")))
                .collect(),
            shard_tail_locks: (0..shards)
                .map(|k| reg.counter(&format!("sched.shard{k}.tail_locks")))
                .collect(),
            shard_fill: (0..shards)
                .map(|k| reg.histogram(&format!("sched.shard{k}.batch_fill")))
                .collect(),
            route_fill: reg.histogram("sched.route.batch_fill"),
        }
    }

    /// Publish the engine-side (non-worker) sched counters onto the
    /// global row at the end of the run.
    fn publish_engine(&self, core: &TelemetryCore, sched: &SchedStats) {
        core.record(self.local_tasks, sched.local_tasks);
        core.record(self.boundary_tasks, sched.boundary_tasks);
        core.record(self.migrations, sched.migrations);
        core.record(self.rebalances, sched.rebalances);
        core.record(self.edge_cut, sched.edge_cut as u64);
        core.record(self.shards, sched.shards as u64);
        for (id, &n) in self.shard_executed.iter().zip(&sched.per_shard_executed) {
            core.record(*id, n);
        }
        for (id, &n) in self.shard_tail_locks.iter().zip(&sched.per_shard_tail_locks) {
            core.record(*id, n);
        }
    }
}

/// Sharded-specific per-worker counters (folded into
/// [`SchedStats`] by the engine).
#[derive(Default)]
struct SchedWorker {
    fence_clears: u64,
    spill_blocked: u64,
    /// Idle cycles spent pressed against the live-task ceiling.
    backpressure_stalls: u64,
}

/// Outcome of one shard/spill cycle.
enum Cycle {
    /// Executed a task (the cycle ends, per the protocol).
    Executed,
    /// Traversed to the end without executing.
    Idle,
}

/// Run one sharded worker to completion of the current epoch. `stall`
/// is the chaos harness's injected start-up sleep for this epoch
/// (zero on clean runs) — applied once here, never inside the cycle
/// loop, so the per-task hot path carries no injection branch.
fn sharded_worker<M: ShardableModel>(
    ctx: &ShardCtx<'_, M>,
    worker_id: usize,
    stall: Duration,
    tele: WorkerTelemetry<'_>,
    trace: TraceHandle<'_>,
    ids: &SchedInstruments,
) {
    let shards = ctx.chains.len();
    // Pinned contiguous ownership: worker w owns the shard range
    // [⌊S·w/n⌋, ⌊S·(w+1)/n⌋) — a partition of 0..S that is recomputed
    // identically every epoch, so a shard's home worker never changes
    // (the rebalancer migrates *blocks* between shards, never shard
    // homes; DESIGN.md §13). Contiguous ranges beat id-congruence for
    // locality: the shard splitter numbers adjacent shards from adjacent
    // regions of the topology, and the SoA relabeling lays those regions
    // out contiguously in memory, so one worker's shards share cache
    // lines and pages. With shards == workers (the default) this is
    // exactly one chain each; extra workers beyond the shard count own
    // an empty range and serve the spillover chain instead.
    let own: Vec<usize> =
        (shards * worker_id / ctx.workers..shards * (worker_id + 1) / ctx.workers).collect();
    let mut stats = WorkerStats {
        worker: worker_id,
        ..Default::default()
    };
    let mut sw = SchedWorker::default();
    let mut record = ctx.model.record();
    if !stall.is_zero() {
        std::thread::sleep(stall);
    }
    let loop_start = Instant::now();

    // Starvation streak: consecutive idle cycles spent against the
    // live-task ceiling while the epoch still has tasks to route.
    let mut starved: u32 = 0;
    loop {
        // Full-mode tracing times idle cycles; the clock reads are gated
        // so Spans mode pays only per execution.
        let cycle_t0 = if trace.full() { trace.now() } else { 0 };
        let mut did_work = false;
        for &s in &own {
            did_work |= matches!(
                shard_cycle(ctx, s, &mut record, &mut stats, &mut sw, &tele, trace, ids),
                Cycle::Executed
            );
        }
        did_work |= matches!(
            spill_cycle(ctx, &mut record, &mut stats, &mut sw, &tele, trace, ids),
            Cycle::Executed
        );
        if !did_work && !ctx.closed.load(Ordering::Acquire) {
            if !ctx.backlog_full() {
                // Idle while the epoch still has tasks: pull a batch
                // ourselves (one cycle's allowance) so shard-less workers
                // (workers > shards) and workers whose chain ran dry keep
                // the pipeline fed.
                let got = ctx.pull(ctx.tasks_per_cycle);
                if got > 0 {
                    tele.sample(ids.route_fill, got as u64);
                    stats.created += got as u64;
                    did_work = true;
                }
            } else {
                // Pressed against the live-task ceiling while idle.
                // Normally other workers' executions drain the backlog
                // and routing resumes — but if every worker idles here
                // simultaneously (all live tasks dependence- or
                // fence-blocked from this worker's view), nobody routes
                // and the ceiling becomes a livelock. After a bounded
                // starvation streak, bypass it for a single task so the
                // canonical front keeps moving; the splitter still routes
                // in canonical order, so determinism is untouched.
                sw.backpressure_stalls += 1;
                starved += 1;
                if starved >= BACKPRESSURE_PATIENCE {
                    let got = ctx.pull(1);
                    if got > 0 {
                        tele.sample(ids.route_fill, got as u64);
                        stats.created += got as u64;
                        did_work = true;
                    }
                }
            }
        }
        if did_work {
            starved = 0;
        } else {
            if ctx.epoch_done() {
                break;
            }
            if trace.full() {
                trace.idle(cycle_t0, trace.now());
            }
            stats.idle_cycles += 1;
            std::thread::yield_now();
        }
    }

    stats.busy_time = loop_start.elapsed();
    // One registry publish per epoch — off the per-task hot path.
    ids.std.publish_worker(&tele, &stats);
    tele.add(ids.fence_clears, sw.fence_clears);
    tele.add(ids.spill_blocked, sw.spill_blocked);
    tele.add(ids.backpressure_stalls, sw.backpressure_stalls);
}

/// One protocol cycle over shard `s`'s chain: traverse from the head,
/// clearing completed fences, absorbing incomplete ones, executing the
/// first dependence-free local task; at the tail, route up to `C` more
/// tasks (in batches of `B`) through the splitter.
fn shard_cycle<M: ShardableModel>(
    ctx: &ShardCtx<'_, M>,
    s: usize,
    record: &mut M::Record,
    stats: &mut WorkerStats,
    sw: &mut SchedWorker,
    tele: &WorkerTelemetry<'_>,
    trace: TraceHandle<'_>,
    ids: &SchedInstruments,
) -> Cycle {
    let chain = &ctx.chains[s];
    record.reset();
    stats.cycles += 1;
    let mut pulled: u32 = 0;
    chain.acquire(chain.head());
    let mut current = chain.head();
    loop {
        let next = chain.next(current);
        debug_assert!(!next.is_none(), "live non-tail node must have a successor");

        if chain.is_tail(next) {
            // --- routing path --------------------------------------
            if pulled >= ctx.tasks_per_cycle
                || ctx.closed.load(Ordering::Acquire)
                || ctx.backlog_full()
            {
                chain.release(current);
                return Cycle::Idle;
            }
            let got = ctx.pull(ctx.tasks_per_cycle - pulled);
            if got > 0 {
                tele.sample(ids.shard_fill[s], got as u64);
                pulled += got;
                stats.created += got as u64;
                // The tasks may have landed right after `current` (then
                // the next iteration walks onto them) or on other chains.
                continue;
            }
            chain.release(current);
            return Cycle::Idle;
        }

        // --- advance path ------------------------------------------
        chain.acquire(next);
        if chain.stale(next) {
            chain.release(next);
            stats.erased_retries += 1;
            continue;
        }
        // Clear a completed fence *from behind* (keeping `current`'s
        // slot): the unlink empties the fence's own links, so the
        // traversal could not continue from it.
        // SAFETY: we hold `next`'s visitor slot, so its incarnation
        // cannot be erased (nor its recipe freed) under us.
        let completed_fence = match unsafe { chain.recipe(next) } {
            ShardItem::Fence(b) if b.done() => Some(b.seq),
            _ => None,
        };
        if let Some(fence_seq) = completed_fence {
            chain.begin_execution(next);
            chain.unlink(next);
            chain.release(next);
            sw.fence_clears += 1;
            trace.fence_clear(fence_seq);
            continue; // current.next was rewired by the unlink
        }
        chain.release(current);
        current = next;
        // SAFETY: we hold `current`'s visitor slot (as above).
        match unsafe { chain.recipe(current) } {
            ShardItem::Fence(b) => {
                // Incomplete boundary task: everything after it that
                // conflicts must wait for it — absorb and pass, exactly
                // like passing a task another worker is executing.
                record.absorb(&b.recipe);
                stats.passed_executing += 1;
            }
            ShardItem::Local { seq, block, recipe } => match chain.state(current) {
                NodeState::Executing => {
                    record.absorb(recipe);
                    stats.passed_executing += 1;
                }
                NodeState::Pending => {
                    if record.depends(recipe) {
                        record.absorb(recipe);
                        stats.skipped_dependent += 1;
                    } else {
                        let (seq, block) = (*seq, *block);
                        execute_and_unlink(
                            ctx, chain, current, seq, block, s as u32, stats, tele, trace, ids,
                        );
                        ctx.per_shard_executed[s].fetch_add(1, Ordering::Relaxed);
                        return Cycle::Executed;
                    }
                }
                NodeState::Erased => unreachable!("stale arrivals are retried earlier"),
            },
        }
    }
}

/// Claim, execute (timing the execution into the cost probe), and erase
/// a chain node standing for canonical task `seq`. The caller holds the
/// node's visitor slot and has established independence.
fn execute_and_unlink<M: ShardableModel, R>(
    ctx: &ShardCtx<'_, M>,
    chain: &Chain<R>,
    node: Handle,
    seq: u64,
    block: u32,
    shard: u32,
    stats: &mut WorkerStats,
    tele: &WorkerTelemetry<'_>,
    trace: TraceHandle<'_>,
    ids: &SchedInstruments,
) where
    R: ShardRecipe<M>,
{
    chain.begin_execution(node);
    chain.release(node);

    let mut rng = TaskRng::for_task(ctx.seed, seq);
    let t0 = Instant::now();
    // SAFETY: `Executing` is claimed by us and only the claimant erases
    // a node, so the recipe stays allocated through the execution even
    // though the visitor slot is released.
    let item = unsafe { chain.recipe(node) };
    ctx.model.execute(R::model_recipe(item), &mut rng);
    let dt = t0.elapsed();
    stats.exec_time += dt;
    tele.sample(ids.std.exec_ns, u64::try_from(dt.as_nanos()).unwrap_or(u64::MAX));
    ctx.costs.record(block, dt.as_nanos() as u64);
    if trace.active() {
        // Reuse the cost probe's clock reads: the span start is the
        // existing `t0` rebased onto the trace anchor, so Spans mode
        // adds no `Instant::now` calls to the execution path.
        let start = trace.rel(t0);
        let end = start.saturating_add(dt.as_nanos() as u64);
        if shard == NONE_SHARD {
            trace.spill(seq, block as u64, start, end);
        } else {
            trace.exec(seq, block as u64, shard, start, end);
        }
    }
    R::publish_done(item);

    chain.acquire(node);
    chain.unlink(node);
    chain.release(node);
    // Streaming: exactly one retire per canonical task — here, where the
    // task's own node (local item or spillover boundary) is erased.
    // Fence unlinks in `shard_cycle` do NOT retire: a fence is not a
    // canonical task, and its boundary already retired on execution.
    if let Some(r) = &ctx.retire {
        r.retire(1);
    }
    stats.executed += 1;
}

/// Internal bridge letting [`execute_and_unlink`] serve both chain
/// flavours: shard chains (items) and the spillover chain (boundaries).
trait ShardRecipe<M: ShardableModel> {
    fn model_recipe(&self) -> &M::Recipe;
    /// Post-execution publication (boundary tasks flip their done flag).
    fn publish_done(&self);
}

impl<M: ShardableModel> ShardRecipe<M> for ShardItem<M::Recipe> {
    fn model_recipe(&self) -> &M::Recipe {
        self.recipe()
    }
    fn publish_done(&self) {}
}

impl<M: ShardableModel> ShardRecipe<M> for Arc<Boundary<M::Recipe>> {
    fn model_recipe(&self) -> &M::Recipe {
        &self.recipe
    }
    fn publish_done(&self) {
        self.mark_done();
    }
}

/// One cycle over the spillover chain: execute the first boundary task
/// that is record-independent *and* whose touched shards are clear.
fn spill_cycle<M: ShardableModel>(
    ctx: &ShardCtx<'_, M>,
    record: &mut M::Record,
    stats: &mut WorkerStats,
    sw: &mut SchedWorker,
    tele: &WorkerTelemetry<'_>,
    trace: TraceHandle<'_>,
    ids: &SchedInstruments,
) -> Cycle {
    let chain = ctx.spill;
    if chain.is_empty() {
        return Cycle::Idle; // cheap fast path: locality means few boundary tasks
    }
    record.reset();
    stats.cycles += 1;
    chain.acquire(chain.head());
    let mut current = chain.head();
    loop {
        let next = chain.next(current);
        debug_assert!(!next.is_none(), "live non-tail node must have a successor");
        if chain.is_tail(next) {
            chain.release(current);
            return Cycle::Idle;
        }
        chain.acquire(next);
        if chain.stale(next) {
            chain.release(next);
            stats.erased_retries += 1;
            continue;
        }
        chain.release(current);
        current = next;
        // SAFETY: we hold `current`'s visitor slot, so its incarnation
        // cannot be erased (nor its recipe freed) under us.
        let boundary = unsafe { chain.recipe(current) };
        match chain.state(current) {
            NodeState::Executing => {
                record.absorb(&boundary.recipe);
                stats.passed_executing += 1;
            }
            NodeState::Pending => {
                if record.depends(&boundary.recipe) {
                    record.absorb(&boundary.recipe);
                    stats.skipped_dependent += 1;
                } else {
                    let wait_t0 = if trace.full() { trace.now() } else { 0 };
                    if !fences_clear(ctx, boundary) {
                        // A touched shard still has live work ahead of our
                        // fence: defer, but absorb so later boundary tasks
                        // stay ordered behind us. Full-mode tracing times
                        // the failed readiness walk as a fence-wait span.
                        if trace.full() {
                            trace.fence_wait(boundary.seq, wait_t0, trace.now());
                        }
                        record.absorb(&boundary.recipe);
                        sw.spill_blocked += 1;
                    } else {
                        let (seq, block) = (boundary.seq, boundary.block);
                        execute_and_unlink(
                            ctx, chain, current, seq, block, NONE_SHARD, stats, tele, trace, ids,
                        );
                        return Cycle::Executed;
                    }
                }
            }
            NodeState::Erased => unreachable!("stale arrivals are retried earlier"),
        }
    }
}

/// What the readiness walk saw at one shard-chain position.
enum Walked {
    /// A live local task ahead of our fence.
    Local,
    /// Our own fence.
    Ours,
    /// Someone else's completed fence (step over it).
    DoneFence,
    /// Someone else's incomplete fence.
    LiveFence,
}

/// Is every item ahead of `b`'s fence complete, in every shard chain `b`
/// touches?
///
/// Slot-free walk over generation-validated link snapshots: pointers are
/// only ever rewired around *erased* nodes (appends happen strictly at
/// the tail, behind the fence), so the walk can skip completed work but
/// never a live node — a `true` verdict is exact. Races with concurrent
/// unlinks at worst invalidate a handle mid-walk (the validated reads
/// return `None` — a recycled slot can never be misread thanks to the
/// generation tag), which restarts the walk from the head, bounded; on
/// exhausting the bound the walk answers a conservative `false` and the
/// caller retries next cycle.
fn fences_clear<M: ShardableModel>(
    ctx: &ShardCtx<'_, M>,
    b: &Arc<Boundary<M::Recipe>>,
) -> bool {
    'shards: for &s in &b.shards {
        let chain = &ctx.chains[s as usize];
        let mut restarts = 0u32;
        let mut node = chain.head();
        loop {
            let Some(next) = chain.next_validated(node) else {
                // The node under us was just erased: restart (bounded).
                restarts += 1;
                if restarts > 8 {
                    return false;
                }
                node = chain.head();
                continue;
            };
            if chain.is_tail(next) {
                // Our own fence is live (b is incomplete, and we hold its
                // spillover slot), so a walk that never skips live nodes
                // must meet it before the tail; answer conservatively if
                // that reasoning is ever violated.
                if cfg!(debug_assertions) {
                    unreachable!("live fence not found in its shard chain");
                }
                return false;
            }
            let seen = chain.with_recipe(next, |item| match item {
                ShardItem::Local { .. } => Walked::Local,
                ShardItem::Fence(f) => {
                    if Arc::ptr_eq(f, b) {
                        Walked::Ours
                    } else if f.done() {
                        Walked::DoneFence
                    } else {
                        Walked::LiveFence
                    }
                }
            });
            match seen {
                None => {
                    // `next` was erased between the pointer read and the
                    // recipe read: restart (bounded).
                    restarts += 1;
                    if restarts > 8 {
                        return false;
                    }
                    node = chain.head();
                }
                Some(Walked::Local) | Some(Walked::LiveFence) => return false,
                Some(Walked::Ours) => continue 'shards, // reached our fence: shard clear
                Some(Walked::DoneFence) => node = next, // step over the completed fence
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testkit::IncModel;
    use crate::model::{Model, TaskSource};
    use crate::protocol::SequentialEngine;
    use crate::sim::graph::{ring_lattice, Csr};
    use crate::sim::rng::Rng;
    use crate::sim::state::SharedSim;
    use crate::util::u32set::U32Set;

    fn cfg(workers: usize, seed: u64) -> ShardedConfig {
        ShardedConfig {
            workers,
            seed,
            ..Default::default()
        }
    }

    #[test]
    fn inc_model_matches_sequential_across_worker_counts() {
        let seed = 9;
        let expected = {
            let m = IncModel::new(2_000, 16);
            SequentialEngine::new(seed).run(&m);
            m.cells_snapshot()
        };
        for workers in [1, 2, 4] {
            let m = IncModel::new(2_000, 16);
            let report = ShardedEngine::new(cfg(workers, seed)).run(&m);
            assert_eq!(m.cells_snapshot(), expected, "n={workers} diverged");
            assert_eq!(report.totals.executed, 2_000);
            assert_eq!(report.chain.tasks_executed, 2_000);
            assert_eq!(report.engine, "sharded");
            let sched = report.sched.as_ref().unwrap();
            assert_eq!(sched.boundary_tasks, 0, "single-cell footprints are local");
            assert_eq!(sched.local_tasks, 2_000);
            assert_eq!(
                sched.per_shard_executed.iter().sum::<u64>(),
                2_000,
                "every local execution is attributed to a shard"
            );
            assert_eq!(
                sched.per_shard_tail_locks.len(),
                sched.shards,
                "per-shard creation-lock telemetry covers every shard"
            );
            assert!(
                sched.arena_occupancy > 0.0 && sched.arena_occupancy <= 1.0,
                "occupancy is a ratio: {}",
                sched.arena_occupancy
            );
            assert!(report.chain.tail_locks > 0);
        }
    }

    /// Pairwise mixing model with tunable cross-shard traffic: each task
    /// reads *and* writes two cells on a ring, mostly nearby (local after
    /// BFS sharding) but with a fraction of long-range pairs that must
    /// travel the spillover chain. Updates are non-commutative, so any
    /// ordering violation between conflicting tasks changes the result.
    struct PairModel {
        cells: SharedSim<Vec<u64>>,
        n: u32,
        tasks: u64,
        far_fraction: f64,
        /// Extra busy-work iterations for tasks whose first cell falls in
        /// the first quarter of the ring (skewed-cost knob for rebalance
        /// tests; 0 = uniform).
        hot_work: u32,
        /// Partitioning strategy advertised to the engine (the dynamics
        /// are hint-independent, so any hint must yield identical state).
        hint: PartitionHint,
    }

    impl PairModel {
        fn new(tasks: u64, n: u32, far_fraction: f64, hot_work: u32) -> Self {
            Self {
                cells: SharedSim::new(vec![1; n as usize]),
                n,
                tasks,
                far_fraction,
                hot_work,
                hint: PartitionHint::General,
            }
        }

        /// Advertise the cells as a `rows × cols` grid.
        fn grid_hint(mut self, rows: usize, cols: usize) -> Self {
            assert_eq!(rows * cols, self.n as usize);
            self.hint = PartitionHint::Grid { rows, cols };
            self
        }

        fn snapshot(&self) -> Vec<u64> {
            unsafe { self.cells.get() }.clone()
        }
    }

    #[derive(Clone, Copy, Debug)]
    struct PairStep {
        a: u32,
        b: u32,
    }

    struct PairRecord {
        touched: U32Set,
    }

    impl crate::model::Record for PairRecord {
        type Recipe = PairStep;
        fn depends(&self, r: &PairStep) -> bool {
            self.touched.contains(r.a) || self.touched.contains(r.b)
        }
        fn absorb(&mut self, r: &PairStep) {
            self.touched.insert(r.a);
            self.touched.insert(r.b);
        }
        fn reset(&mut self) {
            self.touched.clear();
        }
    }

    struct PairSource {
        rng: Rng,
        left: u64,
        n: u32,
        far_fraction: f64,
    }

    impl TaskSource for PairSource {
        type Recipe = PairStep;
        fn next_task(&mut self) -> Option<PairStep> {
            if self.left == 0 {
                return None;
            }
            self.left -= 1;
            let a = self.rng.below(self.n as u64) as u32;
            let b = if self.rng.bernoulli(self.far_fraction) {
                (a + self.n / 2) % self.n // antipodal: crosses any BFS cut
            } else {
                (a + 1) % self.n // neighbour: local except at seams
            };
            Some(PairStep { a, b })
        }
        fn size_hint(&self) -> Option<u64> {
            Some(self.left)
        }
    }

    impl Model for PairModel {
        type Recipe = PairStep;
        type Record = PairRecord;
        type Source = PairSource;

        fn source(&self, seed: u64) -> PairSource {
            PairSource {
                rng: Rng::stream(seed, 0x9A1F),
                left: self.tasks,
                n: self.n,
                far_fraction: self.far_fraction,
            }
        }

        fn record(&self) -> PairRecord {
            PairRecord {
                touched: U32Set::new(),
            }
        }

        fn execute(&self, r: &PairStep, rng: &mut TaskRng) {
            let mut v = rng.below(1 << 20);
            let work = if r.a < self.n / 4 { self.hot_work } else { 0 };
            for _ in 0..work {
                v = v.wrapping_mul(0x9E37_79B9_7F4A_7C15).rotate_left(13) ^ 0x5A5A;
            }
            // SAFETY: record discipline — no concurrent task touches
            // cells `a` or `b` (both are in the conservative footprint).
            unsafe {
                let cells = self.cells.get_mut();
                let (a, b) = (r.a as usize, r.b as usize);
                cells[a] = cells[a].wrapping_mul(3).wrapping_add(cells[b]).wrapping_add(v);
                if a != b {
                    cells[b] = cells[b].wrapping_mul(5) ^ cells[a];
                }
            }
        }
    }

    impl ShardableModel for PairModel {
        fn sched_topology(&self) -> Csr {
            ring_lattice(self.n as usize, 2)
        }
        fn footprint(&self, r: &PairStep, out: &mut Vec<u32>) {
            out.push(r.a);
            if r.b != r.a {
                out.push(r.b);
            }
        }
        fn partition_hint(&self) -> PartitionHint {
            self.hint
        }
    }

    #[test]
    fn partition_hint_dispatch_and_policy_override() {
        let seed = 11;
        let expected = {
            let m = PairModel::new(1_000, 64, 0.1, 0);
            SequentialEngine::new(seed).run(&m);
            m.snapshot()
        };
        // Grid hint: the engine tiles the 8×8 block grid.
        let m = PairModel::new(1_000, 64, 0.1, 0).grid_hint(8, 8);
        let report = ShardedEngine::new(cfg(2, seed)).run(&m);
        assert_eq!(m.snapshot(), expected, "grid-tiled run diverged");
        assert_eq!(report.sched.as_ref().unwrap().partition, "grid");
        // ForceGeneral overrides the hint back to BFS.
        let m = PairModel::new(1_000, 64, 0.1, 0).grid_hint(8, 8);
        let report = ShardedEngine::new(ShardedConfig {
            workers: 2,
            seed,
            partition: PartitionPolicy::ForceGeneral,
            ..Default::default()
        })
        .run(&m);
        assert_eq!(m.snapshot(), expected, "forced-BFS run diverged");
        assert_eq!(report.sched.as_ref().unwrap().partition, "bfs");
        // No hint → the generic partitioner.
        let m = PairModel::new(1_000, 64, 0.1, 0);
        let report = ShardedEngine::new(cfg(2, seed)).run(&m);
        assert_eq!(m.snapshot(), expected);
        assert_eq!(report.sched.as_ref().unwrap().partition, "bfs");
    }

    #[test]
    fn boundary_tasks_flow_through_the_spillover_chain_deterministically() {
        let seed = 21;
        let build = || PairModel::new(3_000, 64, 0.25, 0);
        let expected = {
            let m = build();
            SequentialEngine::new(seed).run(&m);
            m.snapshot()
        };
        for workers in [1, 2, 4] {
            let m = build();
            let report = ShardedEngine::new(cfg(workers, seed)).run(&m);
            assert_eq!(m.snapshot(), expected, "n={workers} diverged");
            let sched = report.sched.as_ref().unwrap();
            assert_eq!(sched.local_tasks + sched.boundary_tasks, 3_000);
            if workers > 1 {
                assert!(
                    sched.boundary_tasks > 0,
                    "antipodal pairs must cross shards: {sched:?}"
                );
            }
        }
    }

    #[test]
    fn every_routing_batch_size_is_state_identical() {
        let seed = 23;
        let build = || PairModel::new(2_000, 64, 0.2, 0);
        let expected = {
            let m = build();
            SequentialEngine::new(seed).run(&m);
            m.snapshot()
        };
        for batch in [1, 7, 64] {
            for workers in [1, 2, 4] {
                let m = build();
                let report = ShardedEngine::new(ShardedConfig {
                    workers,
                    seed,
                    tasks_per_cycle: 64, // C ≥ B: every batch size binds
                    batch,
                    ..Default::default()
                })
                .run(&m);
                assert_eq!(m.snapshot(), expected, "B={batch} n={workers} diverged");
                assert_eq!(report.chain.batch, batch);
            }
        }
    }

    #[test]
    fn aggressive_rebalancing_preserves_determinism() {
        let seed = 5;
        let build = || PairModel::new(4_000, 64, 0.1, 40);
        let expected = {
            let m = build();
            SequentialEngine::new(seed).run(&m);
            m.snapshot()
        };
        for workers in [2, 4] {
            let m = build();
            let report = ShardedEngine::new(ShardedConfig {
                workers,
                seed,
                rebalance_every: 256, // many epochs, many rebalance points
                ..Default::default()
            })
            .run(&m);
            assert_eq!(m.snapshot(), expected, "n={workers} diverged under rebalancing");
            let sched = report.sched.as_ref().unwrap();
            assert!(sched.rebalances > 0, "short epochs must hit the rebalancer");
        }
    }

    #[test]
    fn observed_sharded_run_reproduces_the_sequential_trace() {
        use crate::api::observe::{Metrics, ObsValue, Observer};
        let seed = 13;
        let build = || PairModel::new(1_500, 48, 0.2, 0);
        fn sum_metric(m: &PairModel) -> Metrics {
            let sum = m.snapshot().iter().fold(0u64, |acc, &c| acc.wrapping_add(c));
            vec![("sum".to_string(), ObsValue::Int(sum as i64))]
        }
        let reference = {
            let m = build();
            let probe = || sum_metric(&m);
            let mut obs = Observer::new(200);
            SequentialEngine::new(seed).run_observed(&m, &probe, &mut obs);
            obs.finish().unwrap()
        };
        assert!(reference.len() > 3, "cadence must produce several frames");
        for workers in [1, 2, 4] {
            let m = build();
            let probe = || sum_metric(&m);
            let mut obs = Observer::new(200);
            ShardedEngine::new(cfg(workers, seed)).run_observed(&m, &probe, &mut obs);
            let got = obs.finish().unwrap();
            assert_eq!(got, reference, "sharded n={workers} trace diverged");
        }
    }

    #[test]
    fn more_workers_than_shards_and_vice_versa() {
        let seed = 3;
        let expected = {
            let m = IncModel::new(900, 12);
            SequentialEngine::new(seed).run(&m);
            m.cells_snapshot()
        };
        // 4 workers, 2 shards: shard-less workers only serve the splitter
        // and the spillover chain.
        let m = IncModel::new(900, 12);
        ShardedEngine::new(ShardedConfig {
            workers: 4,
            shards: 2,
            seed,
            ..Default::default()
        })
        .run(&m);
        assert_eq!(m.cells_snapshot(), expected);
        // 2 workers, 6 shards: each worker round-robins over 3 chains.
        let m = IncModel::new(900, 12);
        let report = ShardedEngine::new(ShardedConfig {
            workers: 2,
            shards: 6,
            seed,
            ..Default::default()
        })
        .run(&m);
        assert_eq!(m.cells_snapshot(), expected);
        assert_eq!(report.sched.as_ref().unwrap().shards, 6);
    }

    #[test]
    fn injected_sharded_runs_stay_state_identical_and_leak_free() {
        use crate::chaos::{plan, FaultHook};
        let seed = 31;
        let build = || PairModel::new(2_000, 64, 0.2, 0);
        let expected = {
            let m = build();
            SequentialEngine::new(seed).run(&m);
            m.snapshot()
        };
        for p in plan::bundled() {
            for workers in [1, 2, 4] {
                let m = build();
                let mut hook = FaultHook::new(p.clone());
                let report = ShardedEngine::new(ShardedConfig {
                    workers,
                    seed,
                    rebalance_every: 250, // several epochs, several boundaries
                    ..Default::default()
                })
                .run_chaos(&m, &mut hook);
                assert_eq!(
                    m.snapshot(),
                    expected,
                    "plan={} n={workers} diverged under injection",
                    p.name
                );
                assert_eq!(report.totals.executed, 2_000);
                assert!(hook.epochs() >= 2, "plan={} must span several epochs", p.name);
                assert!(
                    hook.violations().is_empty(),
                    "clean engine must raise no violations: {:?}",
                    hook.violations()
                );
                let shards = report.sched.as_ref().unwrap().shards;
                assert_eq!(
                    report.chain.arena_live,
                    2 * (shards + 1),
                    "drained chains hold exactly their sentinels"
                );
            }
        }
    }

    #[test]
    fn backpressure_stalls_are_counted_and_guarded() {
        // One hot serial shard (single cell → one block → one shard) with
        // more workers than shards: the shard-less workers fill the
        // backlog to its ceiling, then idle against it while the owner
        // drains serially — exactly the regime the livelock guard and
        // its counter cover.
        let seed = 41;
        let expected = {
            let m = IncModel::with_work(1_200, 1, 400);
            SequentialEngine::new(seed).run(&m);
            m.cells_snapshot()
        };
        let m = IncModel::with_work(1_200, 1, 400);
        let report = ShardedEngine::new(ShardedConfig {
            workers: 4,
            seed,
            ..Default::default()
        })
        .run(&m);
        assert_eq!(m.cells_snapshot(), expected, "backpressure run diverged");
        let sched = report.sched.as_ref().unwrap();
        assert_eq!(sched.shards, 1, "single-cell topology clamps to one shard");
        assert!(
            sched.backpressure_stalls > 0,
            "idle workers pressed against a full backlog must be counted: {sched:?}"
        );
    }

    #[test]
    fn shards_clamp_to_block_count() {
        // 3 cells but 8 requested shards: clamps to 3.
        let m = IncModel::new(200, 3);
        let report = ShardedEngine::new(ShardedConfig {
            workers: 2,
            shards: 8,
            seed: 1,
            ..Default::default()
        })
        .run(&m);
        assert_eq!(report.sched.as_ref().unwrap().shards, 3);
        assert_eq!(report.totals.executed, 200);
    }
}
