//! The sharded adaptive scheduler (DESIGN.md §8).
//!
//! Scales the worker–chain protocol past one global chain: the model's
//! agent/block graph is partitioned with a greedy BFS edge-cut
//! partitioner ([`crate::sim::graph::bfs_partition`]), each shard gets
//! its own [`crate::chain::Chain`] owned by a worker, and cross-shard
//! tasks flow through a small spillover chain whose *fences* preserve the
//! protocol's dependence discipline — final states and epoch observation
//! traces stay byte-identical to the sequential engine at a fixed seed.
//! An EWMA per-block cost model, fed by the per-task execution timings,
//! drives a rebalancer that migrates blocks between shards at
//! epoch-quiescence boundaries: the paper's "adaptive, yet graceful"
//! behaviour under heterogeneous per-agent cost, applied to shard
//! ownership.
//!
//! * [`shard`] — the [`ShardableModel`] capability (topology +
//!   conservative per-task footprints), shard-chain items and fences, the
//!   block→shard map, and the serialized splitter/router.
//! * [`cost`] — lock-free per-block timing probe + the EWMA cost model.
//! * [`rebalance`] — the epoch-boundary migration policy.
//! * [`engine`] — [`ShardedEngine`], registered as the fifth engine
//!   (`--engine sharded`).

pub mod cost;
pub mod engine;
pub mod rebalance;
pub mod shard;

pub use cost::{BlockCost, CostProbe};
pub use engine::{PartitionPolicy, ShardedConfig, ShardedEngine};
pub use rebalance::Rebalancer;
pub use shard::{Boundary, PartitionHint, ShardItem, ShardMap, ShardableModel};
