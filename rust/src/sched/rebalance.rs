//! Epoch-boundary rebalancer: greedy block migration between shards,
//! driven by the EWMA cost model.
//!
//! Runs only at quiescent points — every chain is drained, no fence is
//! in flight — so reassigning a block can never reorder in-flight work;
//! it merely changes how the *next* epoch's tasks are routed. Canonical
//! task order and per-task RNG streams are untouched, which is why an
//! adaptively rebalanced run stays byte-identical to the sequential
//! engine (rust/tests/sharded.rs asserts this with an aggressive
//! rebalance cadence).
//!
//! The policy is deliberately simple (diffusion-style): repeatedly move
//! one block from the heaviest shard to the lightest, preferring blocks
//! adjacent to the destination in the topology (keeps the edge cut — and
//! with it the spillover rate — low) and never overshooting (only blocks
//! whose load is at most half the gap move, so every move strictly
//! reduces the imbalance).

use crate::sim::graph::Csr;

use super::cost::BlockCost;
use super::shard::ShardMap;

/// Migration policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct Rebalancer {
    /// Maximum block moves per epoch boundary (bounds the boundary's
    /// cost and the per-epoch routing churn).
    pub max_moves: usize,
    /// Minimum relative imbalance `(max − min) / mean` that triggers any
    /// move — below it the assignment is considered balanced.
    pub threshold: f64,
}

impl Default for Rebalancer {
    fn default() -> Self {
        Self {
            max_moves: 8,
            threshold: 0.2,
        }
    }
}

impl Rebalancer {
    /// Migrate up to `max_moves` blocks; returns the number of moves.
    /// **Quiescent use only.**
    pub fn rebalance(&self, map: &mut ShardMap, cost: &BlockCost, topology: &Csr) -> u64 {
        if map.shards() < 2 {
            return 0;
        }
        let mut moves = 0u64;
        for _ in 0..self.max_moves {
            let loads = cost.shard_loads(map);
            let (hi, lo) = extremes(&loads);
            let gap = loads[hi] - loads[lo];
            let mean = loads.iter().sum::<f64>() / loads.len() as f64;
            if gap <= self.threshold * mean || gap <= 0.0 {
                break;
            }
            // Candidate: a block of the heavy shard with nonzero load at
            // most half the gap (guaranteed strict improvement), ranked
            // by (adjacent-to-destination, load) so the move both evens
            // the loads and keeps the cut small.
            let mut best: Option<(u32, bool, f64)> = None;
            if map.blocks_in(hi as u32) <= 1 {
                break; // cannot empty the heavy shard
            }
            for b in 0..map.blocks() as u32 {
                if map.shard_of(b) != hi as u32 {
                    continue;
                }
                let load = cost.load(b as usize);
                if load <= 0.0 || load > gap / 2.0 {
                    continue;
                }
                let adjacent = topology
                    .neighbors(b as usize)
                    .iter()
                    .any(|&u| map.shard_of(u) == lo as u32);
                let better = best.is_none_or(|(_, best_adj, best_load)| {
                    (adjacent, load) > (best_adj, best_load)
                });
                if better {
                    best = Some((b, adjacent, load));
                }
            }
            match best {
                Some((block, _, _)) => {
                    map.migrate(block, lo as u32);
                    moves += 1;
                }
                None => break,
            }
        }
        moves
    }
}

/// Indices of the largest and smallest entries.
fn extremes(loads: &[f64]) -> (usize, usize) {
    let mut hi = 0;
    let mut lo = 0;
    for (i, &l) in loads.iter().enumerate() {
        if l > loads[hi] {
            hi = i;
        }
        if l < loads[lo] {
            lo = i;
        }
    }
    (hi, lo)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::cost::CostProbe;
    use crate::sim::graph::{bfs_partition, ring_lattice};

    fn loaded_map(weights: &[u64], shards: usize) -> (ShardMap, BlockCost, Csr) {
        let g = ring_lattice(weights.len(), 2);
        let map = ShardMap::from_partition(&bfs_partition(&g, shards));
        let probe = CostProbe::new(weights.len());
        for (b, &w) in weights.iter().enumerate() {
            probe.record(b as u32, w);
        }
        let mut cost = BlockCost::new(weights.len(), 1.0);
        cost.update(&probe);
        (map, cost, g)
    }

    #[test]
    fn balanced_loads_trigger_no_moves() {
        let (mut map, cost, g) = loaded_map(&[100; 8], 2);
        let moved = Rebalancer::default().rebalance(&mut map, &cost, &g);
        assert_eq!(moved, 0);
    }

    #[test]
    fn skewed_loads_migrate_toward_balance() {
        // Blocks 0..4 on shard 0 are 10× heavier; the rebalancer must
        // shift work to shard 1 and strictly reduce the imbalance.
        let weights = [1000, 1000, 1000, 1000, 100, 100, 100, 100];
        let (mut map, cost, g) = loaded_map(&weights, 2);
        let before = cost.shard_loads(&map);
        let gap_before = (before[0] - before[1]).abs();
        let moved = Rebalancer::default().rebalance(&mut map, &cost, &g);
        assert!(moved > 0, "imbalance must trigger migration");
        let after = cost.shard_loads(&map);
        let gap_after = (after[0] - after[1]).abs();
        assert!(gap_after < gap_before, "{before:?} -> {after:?}");
        assert!(map.blocks_in(0) >= 1 && map.blocks_in(1) >= 1);
    }

    #[test]
    fn single_shard_is_a_noop() {
        let (mut map, cost, g) = loaded_map(&[5, 500, 50, 5], 1);
        assert_eq!(Rebalancer::default().rebalance(&mut map, &cost, &g), 0);
    }

    #[test]
    fn moves_are_bounded() {
        let weights: Vec<u64> = (0..32).map(|b| if b < 16 { 900 } else { 1 }).collect();
        let (mut map, cost, g) = loaded_map(&weights, 4);
        let policy = Rebalancer {
            max_moves: 2,
            threshold: 0.0,
        };
        assert!(policy.rebalance(&mut map, &cost, &g) <= 2);
    }
}
