//! Sharding substrate: the [`ShardableModel`] capability, shard-chain
//! items (local tasks and fences), cross-shard [`Boundary`] tasks, the
//! dynamic block→shard [`ShardMap`], and the serialized splitter router.
//!
//! ## Why fences preserve the dependence discipline
//!
//! The single-chain protocol orders any two conflicting tasks by chain
//! position (= canonical creation order). Sharding splits the chain, so
//! the order must be re-established wherever a conflict can cross the
//! split. The splitter routes every task by its conservative *footprint*
//! (the set of blocks it may read or write):
//!
//! * footprint inside one shard → a **local** item on that shard's chain;
//! * footprint spanning shards → a **boundary** task on the spillover
//!   chain, plus a **fence** at the canonical position in *every* touched
//!   shard chain.
//!
//! Conflicting task pairs then fall into four cases (DESIGN.md §8):
//! local/local in one shard (ordinary chain order), boundary before local
//! (the local's worker absorbs the incomplete fence and skips), local
//! before boundary (the boundary's readiness walk sees the live local
//! ahead of its fence and defers), and boundary/boundary (spillover chain
//! order). Routing never touches canonical task numbering or per-task RNG
//! streams, so final states — and epoch traces — stay byte-identical to
//! the sequential engine.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::api::observe::EpochGate;
use crate::chain::Chain;
use crate::model::{Model, TaskSource};
use crate::sim::graph::{Csr, Partition};

/// A model's preferred partitioning strategy for its footprint topology,
/// dispatched on by the sharded engine when building the initial shard
/// assignment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PartitionHint {
    /// No exploitable structure: the greedy BFS edge-cut partitioner.
    General,
    /// The footprint blocks form a `rows × cols` lattice in row-major
    /// order (`block = r * cols + c`): the engine uses
    /// [`grid_partition`](crate::sim::graph::grid_partition)'s
    /// strip/block tiling, whose contiguous rectangular shards cut no
    /// more lattice edges than BFS growth ever does.
    Grid {
        /// Lattice rows.
        rows: usize,
        /// Lattice columns.
        cols: usize,
    },
}

/// A model the sharded engine can partition: it exposes an interaction
/// topology over *footprint blocks* and, per task, the conservative set
/// of blocks the task may touch.
///
/// # Contract
/// If [`Record::depends`](crate::model::Record::depends) can ever order
/// two recipes (in either absorption direction), their footprints must
/// intersect. Disjoint footprints ⇒ the tasks commute. The sharded
/// engine's correctness argument (DESIGN.md §8) rests on exactly this
/// implication; `rust/tests/sharded.rs` enforces it empirically via
/// byte-identity with the sequential engine.
pub trait ShardableModel: Model {
    /// The interaction topology over footprint blocks, used (only) to
    /// compute a low-edge-cut shard assignment. Models without locality
    /// (e.g. Axelrod's complete pair graph) may return an edgeless graph;
    /// sharding then still runs correctly, just with heavy spillover.
    fn sched_topology(&self) -> Csr;

    /// Push the conservative footprint of `recipe` into `out` (cleared by
    /// the caller). Must push at least one block; the **first** entry is
    /// the task's *home* block, used for cost attribution by the EWMA
    /// cost model.
    fn footprint(&self, recipe: &Self::Recipe, out: &mut Vec<u32>);

    /// How the engine should partition
    /// [`sched_topology`](Self::sched_topology) into shards. Lattice
    /// models
    /// override this with [`PartitionHint::Grid`]; the default keeps the
    /// generic BFS edge-cut partitioner.
    fn partition_hint(&self) -> PartitionHint {
        PartitionHint::General
    }
}

/// A cross-shard task: lives on the spillover chain, with a fence at its
/// canonical position in every touched shard chain.
#[derive(Debug)]
pub struct Boundary<R> {
    /// Canonical task sequence number (drives the per-task RNG stream).
    pub seq: u64,
    /// Home block (cost attribution).
    pub block: u32,
    /// The model recipe.
    pub recipe: R,
    /// Sorted ids of the shards holding a fence for this task.
    pub shards: Vec<u32>,
    done: AtomicBool,
}

impl<R> Boundary<R> {
    /// Whether the boundary task has finished executing (its fences can
    /// be cleared and its state effects are visible — the `Release` store
    /// in `mark_done` pairs with this `Acquire`).
    #[inline]
    pub fn done(&self) -> bool {
        self.done.load(Ordering::Acquire)
    }

    /// Publish completion. Called exactly once, by the executing worker,
    /// after [`Model::execute`] returns.
    #[inline]
    pub(crate) fn mark_done(&self) {
        self.done.store(true, Ordering::Release);
    }
}

/// One item of a shard chain: a task local to the shard, or a fence
/// standing in for a cross-shard task.
#[derive(Clone, Debug)]
pub enum ShardItem<R> {
    /// A task whose whole footprint lies inside this shard.
    Local {
        /// Canonical task sequence number (drives the RNG stream).
        seq: u64,
        /// Home block (cost attribution).
        block: u32,
        /// The model recipe.
        recipe: R,
    },
    /// Marker for a boundary task: incomplete ⇒ absorbed by passing
    /// workers (ordering every later conflicting local task after the
    /// boundary task); complete ⇒ unlinked on encounter.
    Fence(Arc<Boundary<R>>),
}

impl<R> ShardItem<R> {
    /// The model recipe this item stands for (fences expose the boundary
    /// task's recipe for record absorption).
    #[inline]
    pub fn recipe(&self) -> &R {
        match self {
            ShardItem::Local { recipe, .. } => recipe,
            ShardItem::Fence(b) => &b.recipe,
        }
    }
}

/// Dynamic block→shard assignment. Built from a [`Partition`] of the
/// topology; mutated only by the rebalancer at quiescent epoch
/// boundaries (no chain holds a task while the map changes, so routing
/// within one epoch is always consistent with one assignment).
#[derive(Clone, Debug)]
pub struct ShardMap {
    shard_of: Vec<u32>,
    counts: Vec<usize>,
}

impl ShardMap {
    /// Adopt a partition's block→shard assignment.
    pub fn from_partition(p: &Partition) -> Self {
        let shard_of: Vec<u32> = (0..p.n()).map(|b| p.block_of(b)).collect();
        let mut counts = vec![0usize; p.blocks()];
        for &s in &shard_of {
            counts[s as usize] += 1;
        }
        Self { shard_of, counts }
    }

    /// Number of shards.
    #[inline]
    pub fn shards(&self) -> usize {
        self.counts.len()
    }

    /// Number of blocks.
    #[inline]
    pub fn blocks(&self) -> usize {
        self.shard_of.len()
    }

    /// Shard owning `block`.
    #[inline]
    pub fn shard_of(&self, block: u32) -> u32 {
        self.shard_of[block as usize]
    }

    /// Number of blocks currently assigned to `shard`.
    #[inline]
    pub fn blocks_in(&self, shard: u32) -> usize {
        self.counts[shard as usize]
    }

    /// Reassign `block` to shard `to`. **Quiescent use only** (the
    /// rebalancer, between epochs).
    pub(crate) fn migrate(&mut self, block: u32, to: u32) {
        let from = self.shard_of[block as usize] as usize;
        debug_assert!(self.counts[from] > 1, "migration must not empty a shard");
        self.counts[from] -= 1;
        self.counts[to as usize] += 1;
        self.shard_of[block as usize] = to;
    }
}

/// The serialized task router: draws tasks from the epoch-gated source in
/// canonical order and appends each — still under the router's lock, so
/// every chain receives a canonical-order subsequence — to its shard
/// chain, or, for a cross-shard footprint, to the spillover chain with a
/// fence in every touched shard chain. Fences are appended *before* the
/// spillover entry, so a boundary task is never visible in the spillover
/// chain without its fences in place.
pub(crate) struct Splitter<M: ShardableModel> {
    gate: EpochGate<M::Source>,
    map: ShardMap,
    footprint: Vec<u32>,
    shard_set: Vec<u32>,
    local_tasks: u64,
    boundary_tasks: u64,
}

impl<M: ShardableModel> Splitter<M> {
    pub(crate) fn new(source: M::Source, map: ShardMap) -> Self {
        Self {
            gate: EpochGate::new(source),
            map,
            footprint: Vec::with_capacity(8),
            shard_set: Vec::with_capacity(4),
            local_tasks: 0,
            boundary_tasks: 0,
        }
    }

    /// Open the next epoch (`every` more canonical tasks).
    pub(crate) fn open(&mut self, every: u64) {
        self.gate.open(every);
    }

    /// Clamp routing to a bounded materialization window (ISSUE 10);
    /// set before the first epoch opens.
    pub(crate) fn set_window(&mut self, window: Option<crate::model::Window>) {
        self.gate.set_window(window);
    }

    /// The window's retirement handle, if streaming is enabled.
    pub(crate) fn retire_handle(&self) -> Option<crate::model::RetireHandle> {
        self.gate.retire_handle()
    }

    /// Whether the last short [`pull_batch`](Self::pull_batch) was a
    /// *temporary* window stall (room reopens as tasks retire) rather
    /// than budget/source exhaustion.
    pub(crate) fn window_stalled(&self) -> bool {
        self.gate.window_stalled()
    }

    /// Canonical tasks routed so far.
    pub(crate) fn emitted(&self) -> u64 {
        self.gate.emitted()
    }

    /// Whether the run is over (delegates to the gate at a quiescent
    /// epoch boundary).
    pub(crate) fn finished(&mut self) -> bool {
        self.gate.finished()
    }

    /// `(local, boundary)` routing counters.
    pub(crate) fn counts(&self) -> (u64, u64) {
        (self.local_tasks, self.boundary_tasks)
    }

    /// Mutable assignment access for the rebalancer (quiescent use).
    pub(crate) fn map_mut(&mut self) -> &mut ShardMap {
        &mut self.map
    }

    /// Route up to `max` tasks under one router-lock hold — the sharded
    /// engine's batching knob (`ShardedConfig.batch`): canonical draw
    /// order is untouched, only the serialization per routed task is
    /// amortized. Returns how many tasks were routed; fewer than `max`
    /// means the epoch budget (or the source) is exhausted.
    pub(crate) fn pull_batch(
        &mut self,
        model: &M,
        chains: &[Chain<ShardItem<M::Recipe>>],
        spill: &Chain<Arc<Boundary<M::Recipe>>>,
        max: u32,
    ) -> u32 {
        let mut routed = 0;
        while routed < max && self.pull(model, chains, spill) {
            routed += 1;
        }
        routed
    }

    /// Route one task. Returns `false` when the epoch budget (or the
    /// source) is exhausted. Must be called under external serialization
    /// (the engine wraps the splitter in a mutex), which also serializes
    /// the [`Chain::append_tail`] calls per the chain's locking contract.
    pub(crate) fn pull(
        &mut self,
        model: &M,
        chains: &[Chain<ShardItem<M::Recipe>>],
        spill: &Chain<Arc<Boundary<M::Recipe>>>,
    ) -> bool {
        let Some(recipe) = self.gate.next_task() else {
            return false;
        };
        let seq = self.gate.emitted() - 1;
        self.footprint.clear();
        model.footprint(&recipe, &mut self.footprint);
        assert!(
            !self.footprint.is_empty(),
            "footprint must name at least one block"
        );
        let home = self.footprint[0];
        self.shard_set.clear();
        for &b in &self.footprint {
            let s = self.map.shard_of(b);
            if !self.shard_set.contains(&s) {
                self.shard_set.push(s);
            }
        }
        if let &[only] = &self.shard_set[..] {
            chains[only as usize].append_tail(ShardItem::Local {
                seq,
                block: home,
                recipe,
            });
            self.local_tasks += 1;
        } else {
            self.shard_set.sort_unstable();
            let boundary = Arc::new(Boundary {
                seq,
                block: home,
                recipe,
                shards: self.shard_set.clone(),
                done: AtomicBool::new(false),
            });
            for &s in &boundary.shards {
                chains[s as usize].append_tail(ShardItem::Fence(boundary.clone()));
            }
            spill.append_tail(boundary);
            self.boundary_tasks += 1;
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chain::NodeState;
    use crate::model::testkit::IncModel;
    use crate::sim::graph::{bfs_partition, ring_lattice};

    #[test]
    fn shard_map_tracks_migrations() {
        let g = ring_lattice(12, 2);
        let p = bfs_partition(&g, 3);
        let mut map = ShardMap::from_partition(&p);
        assert_eq!(map.shards(), 3);
        assert_eq!(map.blocks(), 12);
        assert_eq!(
            (0..3).map(|s| map.blocks_in(s)).sum::<usize>(),
            12,
            "counts partition the blocks"
        );
        let block = (0..12).find(|&b| map.shard_of(b) == 0).unwrap();
        let before = map.blocks_in(0);
        map.migrate(block, 2);
        assert_eq!(map.shard_of(block), 2);
        assert_eq!(map.blocks_in(0), before - 1);
    }

    #[test]
    fn boundary_done_flag() {
        let b: Boundary<u32> = Boundary {
            seq: 5,
            block: 0,
            recipe: 7,
            shards: vec![0, 1],
            done: AtomicBool::new(false),
        };
        assert!(!b.done());
        b.mark_done();
        assert!(b.done());
    }

    #[test]
    fn splitter_routes_single_block_footprints_locally() {
        // IncModel footprints are single cells → every task is local and
        // chains receive canonical-order subsequences.
        let model = IncModel::new(50, 8);
        let topo = <IncModel as ShardableModel>::sched_topology(&model);
        let map = ShardMap::from_partition(&bfs_partition(&topo, 2));
        let mut splitter: Splitter<IncModel> = Splitter::new(model.source(3), map);
        let chains: Vec<Chain<ShardItem<_>>> = (0..2).map(|_| Chain::new()).collect();
        let spill = Chain::new();
        splitter.open(u64::MAX);
        while splitter.pull(&model, &chains, &spill) {}
        assert_eq!(splitter.emitted(), 50);
        assert_eq!(splitter.counts(), (50, 0));
        assert!(spill.is_empty());
        assert_eq!(chains[0].len() + chains[1].len(), 50);
        // Per-chain canonical order: walk each chain and check `seq`
        // strictly increases.
        for chain in &chains {
            let mut last = None;
            let mut node = chain.head();
            loop {
                let next = chain.next(node);
                if chain.is_tail(next) {
                    break;
                }
                assert_eq!(chain.state(next), NodeState::Pending);
                let seq = chain
                    .with_recipe(next, |item| {
                        let ShardItem::Local { seq, .. } = item else {
                            panic!("expected local item");
                        };
                        *seq
                    })
                    .expect("quiescent chain has no stale links");
                assert!(last.is_none_or(|l| l < seq), "canonical order violated");
                last = Some(seq);
                node = next;
            }
        }
    }

    #[test]
    fn pull_batch_routes_under_one_lock_hold_and_reports_exhaustion() {
        let model = IncModel::new(10, 4);
        let topo = <IncModel as ShardableModel>::sched_topology(&model);
        let map = ShardMap::from_partition(&bfs_partition(&topo, 2));
        let mut splitter: Splitter<IncModel> = Splitter::new(model.source(1), map);
        let chains: Vec<Chain<ShardItem<_>>> = (0..2).map(|_| Chain::new()).collect();
        let spill = Chain::new();
        splitter.open(u64::MAX);
        assert_eq!(splitter.pull_batch(&model, &chains, &spill, 4), 4);
        assert_eq!(splitter.pull_batch(&model, &chains, &spill, 8), 6, "short = exhausted");
        assert_eq!(splitter.pull_batch(&model, &chains, &spill, 8), 0);
        assert_eq!(splitter.emitted(), 10);
        assert_eq!(chains[0].len() + chains[1].len(), 10);
    }
}
