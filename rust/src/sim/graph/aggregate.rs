//! Aggregate (quotient) graph over a partition.
//!
//! Paper §4.2: "Connections between agent subsets are encoded in an
//! aggregate graph computed once (just after generating the initial
//! state); this computation contributes to the measured T."

use super::{Csr, Partition};

/// Quotient graph: blocks are vertices; two blocks are adjacent iff some
/// edge of `g` crosses them. Self-edges (intra-block) are not represented.
pub fn aggregate_graph(g: &Csr, p: &Partition) -> Csr {
    assert_eq!(g.n(), p.n());
    let mut edges = std::collections::BTreeSet::new();
    for (v, nbrs) in g.iter() {
        let bv = p.block_of(v);
        for &u in nbrs {
            let bu = p.block_of(u as usize);
            if bu != bv {
                edges.insert((bv.min(bu), bv.max(bu)));
            }
        }
    }
    let edges: Vec<_> = edges.into_iter().collect();
    Csr::from_edges(p.blocks(), &edges)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::graph::{contiguous_partition, ring_lattice};

    #[test]
    fn ring_aggregate_is_ringish() {
        // Ring of 100, k=4 (reach 2), blocks of 10: each block touches the
        // next/previous block only (reach 2 < block size 10).
        let g = ring_lattice(100, 4);
        let p = contiguous_partition(100, 10);
        let a = aggregate_graph(&g, &p);
        assert_eq!(a.n(), 10);
        for b in 0..10 {
            assert_eq!(a.degree(b), 2, "block {b}");
        }
        assert!(a.has_edge(0, 1));
        assert!(a.has_edge(0, 9));
    }

    #[test]
    fn wide_reach_touches_two_blocks_away() {
        // k=14 => reach 7; blocks of 5 => neighbours up to 2 blocks away.
        let g = ring_lattice(50, 14);
        let p = contiguous_partition(50, 5);
        let a = aggregate_graph(&g, &p);
        assert!(a.has_edge(0, 1));
        assert!(a.has_edge(0, 2));
        assert!(!a.has_edge(0, 3));
    }

    #[test]
    fn single_block_has_no_edges() {
        let g = ring_lattice(20, 4);
        let p = contiguous_partition(20, 20);
        let a = aggregate_graph(&g, &p);
        assert_eq!(a.n(), 1);
        assert_eq!(a.m(), 0);
    }

    #[test]
    fn paper_config_aggregate() {
        // N=4000, k=14, s=50: reach 7 < 50 so each block touches exactly
        // one block on each side.
        let g = ring_lattice(4000, 14);
        let p = contiguous_partition(4000, 50);
        let a = aggregate_graph(&g, &p);
        assert_eq!(a.n(), 80);
        for b in 0..80 {
            assert_eq!(a.degree(b), 2);
        }
    }
}
