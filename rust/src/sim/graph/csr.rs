//! Compressed-sparse-row undirected graph storage.

/// An undirected graph in CSR form. Neighbour lists are sorted; parallel
/// edges and self-loops are rejected at construction.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Csr {
    offsets: Vec<usize>,
    neighbors: Vec<u32>,
}

impl Csr {
    /// Build from an edge list over `n` vertices. Edges are undirected;
    /// duplicates and self-loops panic (they indicate generator bugs).
    pub fn from_edges(n: usize, edges: &[(u32, u32)]) -> Self {
        let mut degree = vec![0usize; n];
        for &(a, b) in edges {
            assert_ne!(a, b, "self-loop {a}");
            assert!((a as usize) < n && (b as usize) < n, "edge out of range");
            degree[a as usize] += 1;
            degree[b as usize] += 1;
        }
        let mut offsets = vec![0usize; n + 1];
        for i in 0..n {
            offsets[i + 1] = offsets[i] + degree[i];
        }
        let mut neighbors = vec![0u32; offsets[n]];
        let mut cursor = offsets.clone();
        for &(a, b) in edges {
            neighbors[cursor[a as usize]] = b;
            cursor[a as usize] += 1;
            neighbors[cursor[b as usize]] = a;
            cursor[b as usize] += 1;
        }
        for i in 0..n {
            let span = &mut neighbors[offsets[i]..offsets[i + 1]];
            span.sort_unstable();
            for w in span.windows(2) {
                assert_ne!(w[0], w[1], "duplicate edge at vertex {i}");
            }
        }
        Self { offsets, neighbors }
    }

    /// Build directly from a flat constant-degree neighbour table: `n`
    /// rows of `k` strictly-sorted neighbour ids. O(n·k) with no
    /// intermediate edge list, counting sort, or dense adjacency — the
    /// scale-tier constructor (ISSUE 10): a million-vertex, degree-14
    /// graph streams straight into its final CSR buffer. The table must
    /// be symmetric (`u` in row `v` ⇔ `v` in row `u`); generators that
    /// emit both directions of each edge satisfy this by construction,
    /// and debug builds verify it.
    pub fn from_flat(n: usize, k: usize, neighbors: Vec<u32>) -> Self {
        assert_eq!(neighbors.len(), n * k, "flat table must hold n*k entries");
        let offsets = (0..=n).map(|i| i * k).collect();
        // The same invariants `from_edges` enforces, in one linear pass:
        // in-range, no self-loops, strictly sorted rows (no duplicates).
        for v in 0..n {
            let row = &neighbors[v * k..(v + 1) * k];
            for (i, &u) in row.iter().enumerate() {
                assert!((u as usize) < n, "neighbour out of range at vertex {v}");
                assert_ne!(u as usize, v, "self-loop {v}");
                if i > 0 {
                    assert!(row[i - 1] < u, "row {v} must be strictly sorted");
                }
            }
        }
        let g = Self { offsets, neighbors };
        #[cfg(debug_assertions)]
        for v in 0..n {
            for &u in g.neighbors(v) {
                debug_assert!(g.has_edge(u as usize, v), "asymmetric edge {v}->{u}");
            }
        }
        g
    }

    /// Number of vertices.
    #[inline]
    pub fn n(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Number of undirected edges.
    #[inline]
    pub fn m(&self) -> usize {
        self.neighbors.len() / 2
    }

    /// Degree of vertex `v`.
    #[inline]
    pub fn degree(&self, v: usize) -> usize {
        self.offsets[v + 1] - self.offsets[v]
    }

    /// Sorted neighbour list of vertex `v`.
    #[inline]
    pub fn neighbors(&self, v: usize) -> &[u32] {
        &self.neighbors[self.offsets[v]..self.offsets[v + 1]]
    }

    /// Whether `{a, b}` is an edge (binary search).
    #[inline]
    pub fn has_edge(&self, a: usize, b: usize) -> bool {
        self.neighbors(a).binary_search(&(b as u32)).is_ok()
    }

    /// Iterate all vertices' neighbour slices.
    pub fn iter(&self) -> impl Iterator<Item = (usize, &[u32])> {
        (0..self.n()).map(move |v| (v, self.neighbors(v)))
    }

    /// Flat neighbour matrix `(n, k)` for constant-degree graphs, used to
    /// marshal the topology into the XLA artifacts. Errors if the degree is
    /// not uniform.
    pub fn neighbor_matrix(&self) -> Option<(usize, Vec<u32>)> {
        if self.n() == 0 {
            return Some((0, Vec::new()));
        }
        let k = self.degree(0);
        let mut out = Vec::with_capacity(self.n() * k);
        for v in 0..self.n() {
            if self.degree(v) != k {
                return None;
            }
            out.extend_from_slice(self.neighbors(v));
        }
        Some((k, out))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn triangle() {
        let g = Csr::from_edges(3, &[(0, 1), (1, 2), (0, 2)]);
        assert_eq!(g.n(), 3);
        assert_eq!(g.m(), 3);
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.neighbors(1), &[0, 2]);
        assert!(g.has_edge(2, 0));
        assert!(!g.has_edge(0, 0));
        let (k, mat) = g.neighbor_matrix().unwrap();
        assert_eq!(k, 2);
        assert_eq!(mat, vec![1, 2, 0, 2, 0, 1]);
    }

    #[test]
    fn from_flat_matches_from_edges() {
        // A 5-cycle, built both ways.
        let edges: Vec<(u32, u32)> = (0..5).map(|i| (i, (i + 1) % 5)).collect();
        let by_edges = Csr::from_edges(5, &edges);
        let mut flat = Vec::new();
        for i in 0u32..5 {
            let mut row = [(i + 4) % 5, (i + 1) % 5];
            row.sort_unstable();
            flat.extend_from_slice(&row);
        }
        assert_eq!(Csr::from_flat(5, 2, flat), by_edges);
    }

    #[test]
    #[should_panic]
    fn from_flat_rejects_unsorted_rows() {
        let _ = Csr::from_flat(3, 2, vec![2, 1, 0, 2, 0, 1]);
    }

    #[test]
    fn path_is_not_constant_degree() {
        let g = Csr::from_edges(3, &[(0, 1), (1, 2)]);
        assert_eq!(g.degree(1), 2);
        assert_eq!(g.degree(0), 1);
        assert!(g.neighbor_matrix().is_none());
    }

    #[test]
    #[should_panic]
    fn rejects_self_loop() {
        let _ = Csr::from_edges(2, &[(0, 0)]);
    }

    #[test]
    #[should_panic]
    fn rejects_duplicate_edge() {
        let _ = Csr::from_edges(2, &[(0, 1), (1, 0)]);
    }
}
