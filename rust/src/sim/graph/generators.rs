//! Topology generators.

use super::Csr;
use crate::sim::rng::Rng;

/// Ring lattice with constant even degree `k`: vertex `i` connects to the
/// `k/2` nearest vertices on each side (the paper's SIR topology: "a fixed
/// graph with constant degree k and a ring-like structure", k = 14).
pub fn ring_lattice(n: usize, k: usize) -> Csr {
    assert!(k % 2 == 0, "ring lattice degree must be even");
    assert!(k < n, "degree must be below n");
    let half = k / 2;
    let mut edges = Vec::with_capacity(n * half);
    for i in 0..n {
        for d in 1..=half {
            let j = (i + d) % n;
            edges.push((i as u32, j as u32));
        }
    }
    Csr::from_edges(n, &edges)
}

/// Circulant graph: vertex `i` connects to `i ± s (mod n)` for every
/// stride `s`. Constant degree `2·strides.len()`; `ring_lattice(n, k)`
/// is the special case `strides = 1..=k/2`. Built row-by-row through
/// [`Csr::from_flat`] in O(n·k) with no intermediate edge list — the
/// scale tier's constructor (ISSUE 10).
pub fn circulant(n: usize, strides: &[usize]) -> Csr {
    let k = strides.len() * 2;
    assert!(k < n, "degree must be below n");
    let mut sorted = strides.to_vec();
    sorted.sort_unstable();
    for w in sorted.windows(2) {
        assert_ne!(w[0], w[1], "duplicate stride {}", w[0]);
    }
    for &s in &sorted {
        // `2s < n` keeps `i+s` and `i-s` distinct, so the degree really
        // is constant and no row ever holds a duplicate.
        assert!(s >= 1 && 2 * s < n, "stride {s} must satisfy 1 <= s < n/2");
    }
    let mut neighbors = Vec::with_capacity(n * k);
    let mut row = vec![0u32; k];
    for i in 0..n {
        for (j, &s) in sorted.iter().enumerate() {
            row[2 * j] = ((i + s) % n) as u32;
            row[2 * j + 1] = ((i + n - s) % n) as u32;
        }
        row.sort_unstable();
        neighbors.extend_from_slice(&row);
    }
    Csr::from_flat(n, k, neighbors)
}

/// Degree-bounded synthetic contact graph for the scale tier (ISSUE 10):
/// a ring lattice of local degree `k_local` plus `long_links` seeded
/// long-range strides. Circulant, so the degree stays constant at
/// `k_local + 2·long_links`, construction is a deterministic O(n·k)
/// stream, and no dense adjacency is ever materialized.
/// `long_links = 0` is exactly [`ring_lattice`]`(n, k_local)`.
pub fn contact_graph(n: usize, k_local: usize, long_links: usize, seed: u64) -> Csr {
    assert!(k_local % 2 == 0, "local degree must be even");
    let half = k_local / 2;
    let mut strides: Vec<usize> = (1..=half).collect();
    if long_links > 0 {
        // Distinct strides drawn from (k_local/2, (n-1)/2] — disjoint
        // from the local band, rejection-sampled into a set so the
        // result is seed-deterministic and duplicate-free.
        let lo = half + 1;
        let span = ((n - 1) / 2).saturating_sub(half);
        assert!(
            long_links <= span,
            "cannot place {long_links} distinct long strides in a span of {span}"
        );
        let mut rng = Rng::new(seed);
        let mut chosen = std::collections::BTreeSet::new();
        while chosen.len() < long_links {
            chosen.insert(lo + rng.index(span));
        }
        strides.extend(chosen);
    }
    circulant(n, &strides)
}

/// Complete graph K_n (the Axelrod experiment's "all connected to each
/// other" topology — only used at small n; the Axelrod model itself samples
/// pairs directly and never materializes K_n).
pub fn complete(n: usize) -> Csr {
    let mut edges = Vec::with_capacity(n * (n - 1) / 2);
    for i in 0..n {
        for j in (i + 1)..n {
            edges.push((i as u32, j as u32));
        }
    }
    Csr::from_edges(n, &edges)
}

/// 2D periodic square lattice (`side` × `side`, 4-neighbourhood), used by
/// the Ising model.
pub fn lattice2d(side: usize) -> Csr {
    assert!(side >= 3, "need side >= 3 for distinct torus neighbours");
    let n = side * side;
    let mut edges = Vec::with_capacity(2 * n);
    let at = |r: usize, c: usize| (r * side + c) as u32;
    for r in 0..side {
        for c in 0..side {
            edges.push((at(r, c), at(r, (c + 1) % side)));
            edges.push((at(r, c), at((r + 1) % side, c)));
        }
    }
    Csr::from_edges(n, &edges)
}

/// Erdős–Rényi G(n, m): `m` distinct uniform edges.
pub fn erdos_renyi(n: usize, m: usize, rng: &mut Rng) -> Csr {
    let max_m = n * (n - 1) / 2;
    assert!(m <= max_m, "too many edges requested");
    let mut set = std::collections::BTreeSet::new();
    while set.len() < m {
        let (a, b) = rng.distinct_pair(n);
        let e = (a.min(b) as u32, a.max(b) as u32);
        set.insert(e);
    }
    let edges: Vec<_> = set.into_iter().collect();
    Csr::from_edges(n, &edges)
}

/// Watts–Strogatz small world: start from a ring lattice of degree `k`,
/// rewire each clockwise edge with probability `beta` to a uniform
/// non-duplicate target.
pub fn watts_strogatz(n: usize, k: usize, beta: f64, rng: &mut Rng) -> Csr {
    assert!(k % 2 == 0 && k < n);
    let half = k / 2;
    // adjacency sets for duplicate avoidance during rewiring
    let mut adj: Vec<std::collections::BTreeSet<u32>> = vec![Default::default(); n];
    let add = |adj: &mut Vec<std::collections::BTreeSet<u32>>, a: usize, b: usize| {
        adj[a].insert(b as u32);
        adj[b].insert(a as u32);
    };
    for i in 0..n {
        for d in 1..=half {
            add(&mut adj, i, (i + d) % n);
        }
    }
    for i in 0..n {
        for d in 1..=half {
            let j = (i + d) % n;
            if rng.bernoulli(beta) {
                // Rewire i—j to i—t.
                let mut attempts = 0;
                loop {
                    let t = rng.index(n);
                    if t != i && !adj[i].contains(&(t as u32)) {
                        adj[i].remove(&(j as u32));
                        adj[j].remove(&(i as u32));
                        add(&mut adj, i, t);
                        break;
                    }
                    attempts += 1;
                    if attempts > 32 {
                        break; // saturated vertex: keep the original edge
                    }
                }
            }
        }
    }
    let mut edges = Vec::new();
    for (i, set) in adj.iter().enumerate() {
        for &j in set {
            if (j as usize) > i {
                edges.push((i as u32, j));
            }
        }
    }
    Csr::from_edges(n, &edges)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_lattice_degree_and_structure() {
        let g = ring_lattice(20, 6);
        assert_eq!(g.n(), 20);
        for v in 0..20 {
            assert_eq!(g.degree(v), 6);
        }
        assert!(g.has_edge(0, 1));
        assert!(g.has_edge(0, 3));
        assert!(!g.has_edge(0, 4));
        assert!(g.has_edge(0, 19)); // wraps
        let (k, _) = g.neighbor_matrix().unwrap();
        assert_eq!(k, 6);
    }

    #[test]
    fn paper_sir_topology() {
        // N = 4000, k = 14 — the exact Fig. 3 configuration.
        let g = ring_lattice(4000, 14);
        assert_eq!(g.n(), 4000);
        assert_eq!(g.m(), 4000 * 7);
        assert!(g.neighbor_matrix().is_some());
    }

    #[test]
    fn circulant_generalizes_ring_lattice() {
        assert_eq!(circulant(20, &[1, 2, 3]), ring_lattice(20, 6));
        let g = circulant(11, &[1, 4]);
        for v in 0..11 {
            assert_eq!(g.degree(v), 4);
        }
        assert!(g.has_edge(0, 4));
        assert!(g.has_edge(0, 7)); // 0 - 4 mod 11
    }

    #[test]
    fn contact_graph_is_deterministic_with_constant_degree() {
        assert_eq!(contact_graph(40, 6, 0, 9), ring_lattice(40, 6));
        let g = contact_graph(1_000, 6, 4, 9);
        assert_eq!(g.n(), 1_000);
        for v in 0..g.n() {
            assert_eq!(g.degree(v), 6 + 2 * 4, "degree stays constant");
        }
        assert_eq!(g, contact_graph(1_000, 6, 4, 9), "same seed, same graph");
        assert_ne!(g, contact_graph(1_000, 6, 4, 10), "seed must matter");
    }

    #[test]
    fn complete_graph() {
        let g = complete(5);
        assert_eq!(g.m(), 10);
        for v in 0..5 {
            assert_eq!(g.degree(v), 4);
        }
    }

    #[test]
    fn lattice2d_torus() {
        let g = lattice2d(4);
        assert_eq!(g.n(), 16);
        for v in 0..16 {
            assert_eq!(g.degree(v), 4);
        }
        assert!(g.has_edge(0, 1));
        assert!(g.has_edge(0, 4));
        assert!(g.has_edge(0, 3)); // row wrap
        assert!(g.has_edge(0, 12)); // column wrap
    }

    #[test]
    fn erdos_renyi_edge_count() {
        let mut rng = Rng::new(7);
        let g = erdos_renyi(50, 100, &mut rng);
        assert_eq!(g.n(), 50);
        assert_eq!(g.m(), 100);
    }

    #[test]
    fn watts_strogatz_preserves_edge_count() {
        let mut rng = Rng::new(8);
        let g = watts_strogatz(100, 6, 0.2, &mut rng);
        assert_eq!(g.n(), 100);
        // Rewiring preserves the number of edges (up to rare saturation).
        assert!(g.m() >= 295 && g.m() <= 300, "m = {}", g.m());
    }

    #[test]
    fn watts_strogatz_beta_zero_is_ring() {
        let mut rng = Rng::new(9);
        let g = watts_strogatz(30, 4, 0.0, &mut rng);
        assert_eq!(g, ring_lattice(30, 4));
    }
}
