//! Graph toolkit: CSR storage, topology generators, partitions, and
//! aggregate (quotient) graphs.
//!
//! The paper's SIR experiment (§4.2) runs on "a fixed graph with constant
//! degree k and a ring-like structure" partitioned into equal agent
//! subsets, with subset adjacency captured by an *aggregate graph* computed
//! once after initialization. This module provides that machinery plus the
//! standard topologies used by the extra models and tests.

mod aggregate;
mod csr;
mod generators;
mod partition;

pub use aggregate::aggregate_graph;
pub use csr::Csr;
pub use generators::{
    circulant, complete, contact_graph, erdos_renyi, lattice2d, ring_lattice, watts_strogatz,
};
pub use partition::{
    bfs_partition, contiguous_partition, edge_cut, grid_partition, round_robin_partition,
    Partition,
};
