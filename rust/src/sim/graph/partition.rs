//! Vertex partitions — the SIR experiment's "partition of the system into
//! equal subsets, fixed throughout the simulation" (§4.2). The subset size
//! is the experiment's task-size proxy `s` and sets the chain granularity.
//! [`bfs_partition`] additionally serves the sharded scheduler: it
//! partitions a model's footprint topology into balanced, low-edge-cut
//! shards (DESIGN.md §8). [`grid_partition`] is the lattice-native
//! alternative: on 2D grids a strip/block tiling has provably lower cuts
//! than BFS growth and guarantees contiguous rectangular shards
//! (DESIGN.md §8a).

use super::Csr;

/// A partition of `n` vertices into blocks.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Partition {
    /// block id per vertex
    block_of: Vec<u32>,
    /// vertex list per block
    members: Vec<Vec<u32>>,
}

impl Partition {
    /// Build from a block-id assignment (block ids must be dense `0..B`).
    pub fn from_assignment(block_of: Vec<u32>) -> Self {
        let blocks = block_of.iter().copied().max().map_or(0, |m| m as usize + 1);
        let mut members = vec![Vec::new(); blocks];
        for (v, &b) in block_of.iter().enumerate() {
            members[b as usize].push(v as u32);
        }
        assert!(
            members.iter().all(|m| !m.is_empty()),
            "partition has empty blocks"
        );
        Self { block_of, members }
    }

    /// Number of blocks.
    #[inline]
    pub fn blocks(&self) -> usize {
        self.members.len()
    }

    /// Number of vertices.
    #[inline]
    pub fn n(&self) -> usize {
        self.block_of.len()
    }

    /// Block id of vertex `v`.
    #[inline]
    pub fn block_of(&self, v: usize) -> u32 {
        self.block_of[v]
    }

    /// Members of block `b` (ascending).
    #[inline]
    pub fn members(&self, b: usize) -> &[u32] {
        &self.members[b]
    }

    /// Largest block size.
    pub fn max_block_size(&self) -> usize {
        self.members.iter().map(Vec::len).max().unwrap_or(0)
    }
}

/// Contiguous partition into blocks of size `s` (last block may be
/// smaller). With a ring lattice this minimizes inter-block edges — the
/// paper's implied choice for the ring-like SIR topology.
pub fn contiguous_partition(n: usize, s: usize) -> Partition {
    assert!(s >= 1 && n >= 1);
    let assignment: Vec<u32> = (0..n).map(|v| (v / s) as u32).collect();
    Partition::from_assignment(assignment)
}

/// Round-robin partition into `b` blocks (pessimal locality; used by the
/// granularity ablation to show partition quality matters).
pub fn round_robin_partition(n: usize, b: usize) -> Partition {
    assert!(b >= 1 && b <= n);
    let assignment: Vec<u32> = (0..n).map(|v| (v % b) as u32).collect();
    Partition::from_assignment(assignment)
}

/// Greedy BFS edge-cut partition into `parts` balanced blocks.
///
/// Each block grows breadth-first from the lowest-index unassigned seed
/// vertex until it reaches its balanced target size (`⌈remaining/parts
/// left⌉`, so block sizes differ by at most one); when a block's frontier
/// dries up (disconnected graph, or the component is exhausted) growth
/// continues from the next unassigned seed. On graphs with locality
/// (rings, lattices, small worlds) the blocks come out near-contiguous,
/// so few edges cross blocks — the sharded scheduler's shard assignment
/// (DESIGN.md §8). On an edgeless graph the BFS never fires and the
/// result degrades gracefully to [`contiguous_partition`]-style index
/// ranges.
pub fn bfs_partition(g: &Csr, parts: usize) -> Partition {
    let n = g.n();
    assert!(parts >= 1 && parts <= n, "need 1 <= parts <= n");
    const UNASSIGNED: u32 = u32::MAX;
    let mut assign = vec![UNASSIGNED; n];
    let mut assigned = 0usize;
    let mut next_seed = 0usize;
    let mut queue = std::collections::VecDeque::new();
    for p in 0..parts {
        // Balanced target: spreading the remainder keeps every later
        // block non-empty (the loop invariant `remaining >= parts left`).
        let target = (n - assigned).div_ceil(parts - p);
        queue.clear();
        let mut size = 0usize;
        while size < target {
            let v = match queue.pop_front() {
                Some(v) => v,
                None => {
                    while next_seed < n && assign[next_seed] != UNASSIGNED {
                        next_seed += 1;
                    }
                    debug_assert!(next_seed < n, "targets sum to n");
                    next_seed
                }
            };
            if assign[v] != UNASSIGNED {
                continue; // stale frontier entry
            }
            assign[v] = p as u32;
            size += 1;
            assigned += 1;
            for &u in g.neighbors(v) {
                if assign[u as usize] == UNASSIGNED {
                    queue.push_back(u as usize);
                }
            }
        }
    }
    debug_assert_eq!(assigned, n);
    Partition::from_assignment(assign)
}

/// Split `total` into `parts` contiguous spans whose sizes differ by at
/// most one (larger spans first); every span is non-empty when
/// `total >= parts`.
fn split_even(total: usize, parts: usize) -> Vec<usize> {
    debug_assert!(parts >= 1 && total >= parts);
    let (base, extra) = (total / parts, total % parts);
    (0..parts)
        .map(|i| base + usize::from(i < extra))
        .collect()
}

/// Grid-native partition of a `rows × cols` lattice (vertices in
/// row-major order, `v = r * cols + c`) into `parts` **contiguous
/// rectangular tiles**: rows are split into `pr` horizontal stripes of
/// near-equal height, and each stripe's columns into its share of
/// near-equal-width ranges. Every stripe-count candidate from pure row
/// strips (`pr = parts`) through blocks to pure column strips
/// (`pr = 1`) is scored under a **bounded-imbalance rule**: only
/// candidates whose largest tile is within 25% of the best achievable
/// largest tile compete (with uniform per-block cost the largest shard
/// bounds the makespan, and the rebalancer's per-epoch move budget
/// cannot repair a lopsided initial assignment), and among those the
/// exact torus edge cut decides (row seams cut `cols` vertical edges
/// each, column seams cut the stripe height). On lattice topologies
/// the winner's cut never exceeds the generic [`bfs_partition`]'s
/// ragged growth (property-tested in `rust/tests/graph.rs`).
///
/// Guarantees: exactly `parts` tiles, each a full rectangle (hence
/// connected under 4-neighbour adjacency, without needing the torus
/// wrap); stripe heights differ by at most one row, and tile widths
/// within a stripe differ by at most one column.
pub fn grid_partition(rows: usize, cols: usize, parts: usize) -> Partition {
    assert!(rows >= 1 && cols >= 1, "need a non-empty grid");
    assert!(
        parts >= 1 && parts <= rows * cols,
        "need 1 <= parts <= rows*cols"
    );
    // Candidate: `pr` stripes, stripe i carrying q[i] tiles. Feasible
    // when the widest demand fits the columns; parts <= rows*cols
    // guarantees at least one feasible pr.
    struct Candidate {
        q: Vec<usize>,
        heights: Vec<usize>,
        cut: usize,
        max_tile: usize,
    }
    let mut cands = Vec::new();
    for pr in 1..=parts.min(rows) {
        let q = split_even(parts, pr);
        if q[0] > cols {
            continue; // a stripe would need more tiles than columns
        }
        let heights = split_even(rows, pr);
        let mut cut = if pr > 1 { pr * cols } else { 0 };
        let mut max_tile = 0usize;
        for (&h, &qi) in heights.iter().zip(&q) {
            if qi > 1 {
                cut += qi * h;
            }
            max_tile = max_tile.max(h * cols.div_ceil(qi));
        }
        cands.push(Candidate {
            q,
            heights,
            cut,
            max_tile,
        });
    }
    let best_max = cands
        .iter()
        .map(|c| c.max_tile)
        .min()
        .expect("parts <= rows*cols leaves a feasible stripe count");
    let Candidate { q, heights, .. } = cands
        .into_iter()
        .filter(|c| 4 * c.max_tile <= 5 * best_max)
        .min_by_key(|c| (c.cut, c.max_tile))
        .expect("the best-balanced candidate always passes its own bound");
    let mut assign = vec![0u32; rows * cols];
    let mut tile = 0u32;
    let mut r0 = 0usize;
    for (h, qi) in heights.into_iter().zip(q) {
        let mut c0 = 0usize;
        for w in split_even(cols, qi) {
            for r in r0..r0 + h {
                for c in c0..c0 + w {
                    assign[r * cols + c] = tile;
                }
            }
            c0 += w;
            tile += 1;
        }
        r0 += h;
    }
    Partition::from_assignment(assign)
}

/// Number of edges of `g` whose endpoints lie in different blocks of `p` —
/// the partition-quality metric the BFS partitioner minimizes greedily.
pub fn edge_cut(g: &Csr, p: &Partition) -> usize {
    assert_eq!(g.n(), p.n());
    let mut crossing = 0usize;
    for (v, nbrs) in g.iter() {
        let bv = p.block_of(v);
        crossing += nbrs
            .iter()
            .filter(|&&u| p.block_of(u as usize) != bv)
            .count();
    }
    crossing / 2 // every undirected edge was seen from both endpoints
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contiguous_blocks() {
        let p = contiguous_partition(10, 4);
        assert_eq!(p.blocks(), 3);
        assert_eq!(p.members(0), &[0, 1, 2, 3]);
        assert_eq!(p.members(2), &[8, 9]);
        assert_eq!(p.block_of(5), 1);
        assert_eq!(p.max_block_size(), 4);
    }

    #[test]
    fn exact_division() {
        let p = contiguous_partition(4000, 50);
        assert_eq!(p.blocks(), 80);
        assert!(p.members.iter().all(|m| m.len() == 50));
    }

    #[test]
    fn round_robin_blocks() {
        let p = round_robin_partition(10, 3);
        assert_eq!(p.blocks(), 3);
        assert_eq!(p.members(0), &[0, 3, 6, 9]);
        assert_eq!(p.members(1), &[1, 4, 7]);
    }

    #[test]
    #[should_panic]
    fn empty_block_rejected() {
        let _ = Partition::from_assignment(vec![0, 2]); // block 1 missing
    }

    #[test]
    fn bfs_partition_is_balanced_and_total() {
        use crate::sim::graph::ring_lattice;
        for (n, parts) in [(10, 3), (100, 4), (97, 5), (16, 16)] {
            let g = ring_lattice(n, 4);
            let p = bfs_partition(&g, parts);
            assert_eq!(p.blocks(), parts, "n={n} parts={parts}");
            assert_eq!(p.n(), n);
            let sizes: Vec<usize> = (0..parts).map(|b| p.members(b).len()).collect();
            assert_eq!(sizes.iter().sum::<usize>(), n);
            let (lo, hi) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(hi - lo <= 1, "unbalanced: {sizes:?}");
        }
    }

    #[test]
    fn bfs_partition_beats_round_robin_on_a_ring() {
        use crate::sim::graph::ring_lattice;
        let g = ring_lattice(120, 6);
        let bfs = bfs_partition(&g, 4);
        let rr = round_robin_partition(120, 4);
        // BFS growth keeps blocks near-contiguous: the cut stays within a
        // small multiple of the 4 seams' reach (measured: 28 here), while
        // round-robin cuts all 360 edges.
        assert!(edge_cut(&g, &bfs) <= 40, "cut = {}", edge_cut(&g, &bfs));
        assert!(edge_cut(&g, &bfs) < edge_cut(&g, &rr));
    }

    #[test]
    fn bfs_partition_handles_edgeless_graphs() {
        // No edges: BFS never fires; blocks fall back to index ranges.
        let g = Csr::from_edges(10, &[]);
        let p = bfs_partition(&g, 3);
        assert_eq!(p.blocks(), 3);
        assert_eq!(p.members(0), &[0, 1, 2, 3]);
        assert_eq!(p.members(1), &[4, 5, 6]);
        assert_eq!(p.members(2), &[7, 8, 9]);
    }

    #[test]
    fn grid_partition_tiles_are_rectangles() {
        use crate::sim::graph::lattice2d;
        let p = grid_partition(8, 8, 4);
        assert_eq!(p.blocks(), 4);
        assert_eq!(p.n(), 64);
        // A ragged 3-stripe decomposition would shave the cut to 30, but
        // its 24-cell tile is 1.5× the ideal 16 — outside the 25%
        // imbalance bound — so a perfectly balanced cut-32 tiling wins.
        for b in 0..4 {
            assert_eq!(p.members(b).len(), 16, "tiles must be perfectly balanced");
            let rows: Vec<usize> = p.members(b).iter().map(|&v| v as usize / 8).collect();
            let cols: Vec<usize> = p.members(b).iter().map(|&v| v as usize % 8).collect();
            let (r0, r1) = (*rows.iter().min().unwrap(), *rows.iter().max().unwrap());
            let (c0, c1) = (*cols.iter().min().unwrap(), *cols.iter().max().unwrap());
            assert_eq!(
                (r1 - r0 + 1) * (c1 - c0 + 1),
                p.members(b).len(),
                "tile {b} is not a full rectangle"
            );
        }
        let g = lattice2d(8);
        assert_eq!(edge_cut(&g, &p), 32);
        assert_eq!(p.max_block_size(), 16);
    }

    #[test]
    fn grid_partition_prefers_strips_when_blocks_cannot_tile() {
        use crate::sim::graph::lattice2d;
        // parts = 3 on 9×9: three 3-row strips (cut 27) beat any ragged
        // mixed decomposition (>= 28).
        let p = grid_partition(9, 9, 3);
        let g = lattice2d(9);
        assert_eq!(edge_cut(&g, &p), 27);
        let sizes: Vec<usize> = (0..3).map(|b| p.members(b).len()).collect();
        assert_eq!(sizes, vec![27, 27, 27]);
    }

    #[test]
    fn grid_partition_handles_rectangles_and_extremes() {
        let p = grid_partition(4, 10, 5);
        assert_eq!(p.blocks(), 5);
        assert_eq!(p.n(), 40);
        let whole = grid_partition(6, 6, 1);
        assert_eq!(whole.blocks(), 1);
        let atoms = grid_partition(3, 4, 12);
        assert_eq!(atoms.blocks(), 12);
        assert_eq!(atoms.max_block_size(), 1);
        // parts larger than both side lengths still tiles (ragged stripes).
        let p = grid_partition(4, 4, 7);
        assert_eq!(p.blocks(), 7);
        assert!(p.max_block_size() <= 4);
    }

    #[test]
    fn split_even_is_balanced_and_total() {
        for (total, parts) in [(10, 3), (8, 8), (7, 2), (100, 7)] {
            let spans = split_even(total, parts);
            assert_eq!(spans.len(), parts);
            assert_eq!(spans.iter().sum::<usize>(), total);
            let (lo, hi) = (spans.iter().min().unwrap(), spans.iter().max().unwrap());
            assert!(hi - lo <= 1 && *lo >= 1, "{spans:?}");
        }
    }

    #[test]
    fn bfs_partition_one_part_and_all_parts() {
        use crate::sim::graph::ring_lattice;
        let g = ring_lattice(12, 2);
        let whole = bfs_partition(&g, 1);
        assert_eq!(whole.blocks(), 1);
        assert_eq!(edge_cut(&g, &whole), 0);
        let atoms = bfs_partition(&g, 12);
        assert_eq!(atoms.blocks(), 12);
        assert_eq!(atoms.max_block_size(), 1);
        assert_eq!(edge_cut(&g, &atoms), g.m());
    }
}
