//! Vertex partitions — the SIR experiment's "partition of the system into
//! equal subsets, fixed throughout the simulation" (§4.2). The subset size
//! is the experiment's task-size proxy `s` and sets the chain granularity.

/// A partition of `n` vertices into blocks.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Partition {
    /// block id per vertex
    block_of: Vec<u32>,
    /// vertex list per block
    members: Vec<Vec<u32>>,
}

impl Partition {
    /// Build from a block-id assignment (block ids must be dense `0..B`).
    pub fn from_assignment(block_of: Vec<u32>) -> Self {
        let blocks = block_of.iter().copied().max().map_or(0, |m| m as usize + 1);
        let mut members = vec![Vec::new(); blocks];
        for (v, &b) in block_of.iter().enumerate() {
            members[b as usize].push(v as u32);
        }
        assert!(
            members.iter().all(|m| !m.is_empty()),
            "partition has empty blocks"
        );
        Self { block_of, members }
    }

    /// Number of blocks.
    #[inline]
    pub fn blocks(&self) -> usize {
        self.members.len()
    }

    /// Number of vertices.
    #[inline]
    pub fn n(&self) -> usize {
        self.block_of.len()
    }

    /// Block id of vertex `v`.
    #[inline]
    pub fn block_of(&self, v: usize) -> u32 {
        self.block_of[v]
    }

    /// Members of block `b` (ascending).
    #[inline]
    pub fn members(&self, b: usize) -> &[u32] {
        &self.members[b]
    }

    /// Largest block size.
    pub fn max_block_size(&self) -> usize {
        self.members.iter().map(Vec::len).max().unwrap_or(0)
    }
}

/// Contiguous partition into blocks of size `s` (last block may be
/// smaller). With a ring lattice this minimizes inter-block edges — the
/// paper's implied choice for the ring-like SIR topology.
pub fn contiguous_partition(n: usize, s: usize) -> Partition {
    assert!(s >= 1 && n >= 1);
    let assignment: Vec<u32> = (0..n).map(|v| (v / s) as u32).collect();
    Partition::from_assignment(assignment)
}

/// Round-robin partition into `b` blocks (pessimal locality; used by the
/// granularity ablation to show partition quality matters).
pub fn round_robin_partition(n: usize, b: usize) -> Partition {
    assert!(b >= 1 && b <= n);
    let assignment: Vec<u32> = (0..n).map(|v| (v % b) as u32).collect();
    Partition::from_assignment(assignment)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contiguous_blocks() {
        let p = contiguous_partition(10, 4);
        assert_eq!(p.blocks(), 3);
        assert_eq!(p.members(0), &[0, 1, 2, 3]);
        assert_eq!(p.members(2), &[8, 9]);
        assert_eq!(p.block_of(5), 1);
        assert_eq!(p.max_block_size(), 4);
    }

    #[test]
    fn exact_division() {
        let p = contiguous_partition(4000, 50);
        assert_eq!(p.blocks(), 80);
        assert!(p.members.iter().all(|m| m.len() == 50));
    }

    #[test]
    fn round_robin_blocks() {
        let p = round_robin_partition(10, 3);
        assert_eq!(p.blocks(), 3);
        assert_eq!(p.members(0), &[0, 3, 6, 9]);
        assert_eq!(p.members(1), &[1, 4, 7]);
    }

    #[test]
    #[should_panic]
    fn empty_block_rejected() {
        let _ = Partition::from_assignment(vec![0, 2]); // block 1 missing
    }
}
