//! Simulation substrates: deterministic RNG, shared simulation state, the
//! graph toolkit (topologies, partitions, aggregate graphs), and the
//! bit-packed SoA state layer.

pub mod graph;
pub mod rng;
pub mod soa;
pub mod state;
