//! Simulation substrates: deterministic RNG, shared simulation state, and
//! the graph toolkit (topologies, partitions, aggregate graphs).

pub mod graph;
pub mod rng;
pub mod state;
