//! Deterministic RNG substrate.
//!
//! The protocol's determinism guarantee (parallel execution bit-identical to
//! sequential execution, DESIGN.md §6) requires that randomness is keyed by
//! *logical* position, never by thread identity or wall clock:
//!
//! * task **creation** draws from a single creation stream that advances
//!   under the chain's tail lock (creation is serialized, so the sequence of
//!   draws is a deterministic function of the seed);
//! * task **execution** draws from a private [`TaskRng`] stream derived from
//!   `(simulation seed, task sequence number)` — concurrent executions never
//!   share a stream.
//!
//! Implementations: SplitMix64 (seeding / stream derivation) and
//! xoshiro256++ (the workhorse generator). Both are tiny, fast, and
//! reproduce the reference vectors from the authors' public domain C code.

/// SplitMix64 — used to expand seeds and derive stream keys.
///
/// Reference: Sebastiano Vigna's public-domain implementation.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a new generator from a raw seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — the main generator used by all simulation streams.
///
/// Reference: Blackman & Vigna, public-domain `xoshiro256plusplus.c`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Rng {
    s: [u64; 4],
}

#[inline(always)]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

impl Rng {
    /// Seed via SplitMix64 expansion (never yields the all-zero state).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent stream for logical index `stream` under `seed`.
    ///
    /// Streams are decorrelated by hashing the pair through SplitMix64 with
    /// golden-ratio mixing, then expanding the result into a fresh state.
    pub fn stream(seed: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::new(seed ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        // One extra scramble round to separate (seed, 0) from plain seed.
        let k = sm.next_u64();
        Self::new(k)
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    /// Next 32-bit output (upper bits of the 64-bit output).
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform integer in `[0, bound)` via Lemire's multiply-shift method
    /// (unbiased, uses rejection on the low product half).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0, "below(0) is meaningless");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform `usize` in `[0, bound)`.
    #[inline]
    pub fn index(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform `f64` in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn unit_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial with success probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.unit_f64() < p
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn range_inclusive(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Pick a uniformly random element of a slice.
    #[inline]
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }

    /// Pick a uniformly random *ordered pair* of distinct indices `< n`.
    #[inline]
    pub fn distinct_pair(&mut self, n: usize) -> (usize, usize) {
        debug_assert!(n >= 2);
        let a = self.index(n);
        let mut b = self.index(n - 1);
        if b >= a {
            b += 1;
        }
        (a, b)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }
}

/// Per-task execution stream (see module docs).
///
/// A thin newtype so model code cannot accidentally mix creation-stream and
/// execution-stream randomness.
#[derive(Clone, Debug)]
pub struct TaskRng(Rng);

impl TaskRng {
    /// Derive the execution stream for task `task_seq` under `seed`.
    ///
    /// The domain-separation constant keeps task streams disjoint from
    /// creation streams even for colliding integer arguments.
    pub fn for_task(seed: u64, task_seq: u64) -> Self {
        const TASK_DOMAIN: u64 = 0x7A5C_0000_5EED_0001;
        TaskRng(Rng::stream(seed ^ TASK_DOMAIN, task_seq))
    }
}

impl std::ops::Deref for TaskRng {
    type Target = Rng;
    fn deref(&self) -> &Rng {
        &self.0
    }
}

impl std::ops::DerefMut for TaskRng {
    fn deref_mut(&mut self) -> &mut Rng {
        &mut self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // First outputs for seed 0 from the public-domain reference code.
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(sm.next_u64(), 0x6E78_9E6A_A1B9_65F4);
        assert_eq!(sm.next_u64(), 0x06C4_5D18_8009_454F);
    }

    #[test]
    fn xoshiro_is_deterministic_and_seed_sensitive() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        let mut c = Rng::new(43);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn streams_are_decorrelated() {
        let mut s0 = Rng::stream(7, 0);
        let mut s1 = Rng::stream(7, 1);
        let v0: Vec<u64> = (0..8).map(|_| s0.next_u64()).collect();
        let v1: Vec<u64> = (0..8).map(|_| s1.next_u64()).collect();
        assert_ne!(v0, v1);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(1);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let x = r.below(7) as usize;
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn unit_f64_in_half_open_interval() {
        let mut r = Rng::new(2);
        for _ in 0..10_000 {
            let x = r.unit_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn unit_f64_mean_near_half() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.unit_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn distinct_pair_is_distinct_and_uniformish() {
        let mut r = Rng::new(4);
        let mut counts = [[0u32; 5]; 5];
        for _ in 0..20_000 {
            let (a, b) = r.distinct_pair(5);
            assert_ne!(a, b);
            counts[a][b] += 1;
        }
        // 20 ordered pairs, expect ~1000 each; allow wide tolerance.
        for a in 0..5 {
            for b in 0..5 {
                if a != b {
                    assert!(counts[a][b] > 700, "pair ({a},{b}) count {}", counts[a][b]);
                }
            }
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn task_rng_differs_per_task() {
        let mut t0 = TaskRng::for_task(9, 0);
        let mut t1 = TaskRng::for_task(9, 1);
        assert_ne!(t0.next_u64(), t1.next_u64());
    }
}
