//! Structure-of-arrays agent state: bit-packed state lanes with
//! locality-aware slot placement (DESIGN.md §13).
//!
//! The bundled models carry tiny per-agent states — SIR health is one of
//! three values (2 bits), an Ising spin is one of two (1 bit), voter
//! opinions fit a few bits — yet the legacy layout spends a whole byte
//! (or an `i8`) per agent. BioDynaMo and the TeraAgent engine attribute
//! most of their single-node scaling to flat SoA storage and
//! iteration-space locality rather than scheduling; this module is that
//! layer for our models:
//!
//! * [`PackedStates`] — a flat array of 64-bit words holding fixed-width
//!   state lanes (1/2/4/8 bits). Lane writes go through a CAS loop, so
//!   two protocol-independent tasks whose agents happen to share a word
//!   never lose an update; lane reads are single atomic loads.
//! * [`Relabeling`] — a pure permutation of agent ids onto storage slots
//!   so that each partition block (and therefore each shard built from
//!   the same topology) is contiguous in memory. Logical ids — RNG
//!   streams, task recipes, footprints, observations — are untouched;
//!   only the *physical* slot of an agent moves, which is why every
//!   trace stays byte-identical through the relabeling.
//! * [`Layout`] — the facade-level selector (`ADAPAR_LAYOUT`): legacy
//!   AoS vectors, packed-with-relabeling, or packed-in-identity-order
//!   (isolates the permutation axis in the conformance matrix).
//!
//! ## Memory model
//!
//! [`PackedStates::set`] and [`PackedStates::get`] use `Relaxed`
//! atomics. Cross-task ordering is established by the chain protocol
//! exactly as for [`SharedSim`](crate::sim::state::SharedSim): a task
//! only reads agent lanes that no concurrently-executing task writes
//! (record discipline, DESIGN.md §6), and the chain's acquire/release
//! operations around task publication order everything else. The CAS is
//! *not* for ordering — it only makes sub-word lane writes lossless when
//! two independent tasks write different lanes of the same word.

use std::str::FromStr;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::error::{Error, Result};
use crate::sim::graph::Partition;

/// Agent-state storage layout (facade knob, default from
/// `ADAPAR_LAYOUT`). Semantically inert: every layout yields the
/// identical observation trace and the identical final state under a
/// fixed seed — the conformance matrix runs a dedicated axis over all
/// three to prove it.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Layout {
    /// The historical AoS layout: one `u8`/`i8` per agent in logical id
    /// order (and whatever struct vecs a model already used).
    Legacy,
    /// Bit-packed SoA lanes, with agent slots permuted so each
    /// partition block is contiguous in memory (the default).
    #[default]
    Packed,
    /// Bit-packed SoA lanes in identity (logical id) order — isolates
    /// the packing axis from the relabeling axis.
    PackedLinear,
}

impl Layout {
    /// Every selectable layout (the conformance axis).
    pub const ALL: [Layout; 3] = [Layout::Legacy, Layout::Packed, Layout::PackedLinear];

    /// Canonical label — what [`FromStr`] accepts and `Display` prints.
    pub fn label(self) -> &'static str {
        match self {
            Layout::Legacy => "legacy",
            Layout::Packed => "packed",
            Layout::PackedLinear => "packed-linear",
        }
    }

    /// Whether states are bit-packed under this layout.
    pub fn is_packed(self) -> bool {
        !matches!(self, Layout::Legacy)
    }

    /// Default layout: `ADAPAR_LAYOUT` if set to a valid label, else
    /// [`Layout::Packed`] (unknown values fall back rather than panic —
    /// same tolerance as the telemetry/trace mode envs).
    pub fn env_default() -> Self {
        std::env::var("ADAPAR_LAYOUT")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(Layout::Packed)
    }
}

impl FromStr for Layout {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s.trim() {
            "legacy" | "aos" => Layout::Legacy,
            "packed" | "soa" => Layout::Packed,
            "packed-linear" | "packed_linear" | "linear" => Layout::PackedLinear,
            other => {
                return Err(crate::err!(
                    "unknown layout `{other}`; valid layouts: legacy|packed|packed-linear"
                ))
            }
        })
    }
}

impl std::fmt::Display for Layout {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

/// Smallest word-aligned lane width (1, 2, 4 or 8 bits) that can hold
/// `values` distinct states. Widths are powers of two so lanes never
/// straddle a word boundary.
pub fn bits_for(values: usize) -> u32 {
    debug_assert!((1..=256).contains(&values), "state space must fit a byte");
    match values {
        0..=2 => 1,
        3..=4 => 2,
        5..=16 => 4,
        _ => 8,
    }
}

/// A pure permutation of agent ids onto storage slots.
///
/// `slot_of` maps logical agent id → physical slot; `agent_of` is its
/// inverse. [`Relabeling::from_partition`] assigns slots block by block
/// (members in ascending id order), so every block of the partition —
/// and every shard the scheduler later builds from the same topology —
/// occupies a contiguous slot range.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Relabeling {
    slot_of: Vec<u32>,
    agent_of: Vec<u32>,
}

impl Relabeling {
    /// The identity relabeling on `n` agents.
    pub fn identity(n: usize) -> Self {
        let ids: Vec<u32> = (0..n as u32).collect();
        Self {
            slot_of: ids.clone(),
            agent_of: ids,
        }
    }

    /// Block-contiguous relabeling: slots are assigned block by block in
    /// partition order, members ascending. A contiguous partition (the
    /// SIR subsets) therefore yields the identity.
    pub fn from_partition(p: &Partition) -> Self {
        let mut slot_of = vec![0u32; p.n()];
        let mut agent_of = Vec::with_capacity(p.n());
        for b in 0..p.blocks() {
            for &a in p.members(b) {
                slot_of[a as usize] = agent_of.len() as u32;
                agent_of.push(a);
            }
        }
        let out = Self { slot_of, agent_of };
        debug_assert!(out.is_permutation());
        out
    }

    /// Number of agents.
    pub fn len(&self) -> usize {
        self.slot_of.len()
    }

    /// Whether the relabeling covers zero agents.
    pub fn is_empty(&self) -> bool {
        self.slot_of.is_empty()
    }

    /// Physical slot of logical agent `a`.
    #[inline]
    pub fn slot_of(&self, a: usize) -> u32 {
        self.slot_of[a]
    }

    /// Logical agent stored at physical slot `s`.
    #[inline]
    pub fn agent_of(&self, s: usize) -> u32 {
        self.agent_of[s]
    }

    /// The slot map as a slice (logical id order).
    pub fn slots(&self) -> &[u32] {
        &self.slot_of
    }

    /// The inverse relabeling (swaps the two maps).
    pub fn inverse(&self) -> Self {
        Self {
            slot_of: self.agent_of.clone(),
            agent_of: self.slot_of.clone(),
        }
    }

    /// Whether the relabeling is the identity.
    pub fn is_identity(&self) -> bool {
        self.slot_of.iter().enumerate().all(|(i, &s)| i as u32 == s)
    }

    /// Verify the maps are mutually-inverse bijections on `0..n` — the
    /// "pure permutation" property the conformance argument rests on.
    pub fn is_permutation(&self) -> bool {
        let n = self.slot_of.len();
        self.agent_of.len() == n
            && self
                .slot_of
                .iter()
                .all(|&s| (s as usize) < n)
            && self
                .slot_of
                .iter()
                .enumerate()
                .all(|(a, &s)| self.agent_of[s as usize] as usize == a)
    }
}

/// Bit-packed SoA agent states: fixed-width lanes in a flat array of
/// 64-bit words, addressed through a (possibly permuted, possibly
/// block-aligned) lane map.
///
/// Two constructors:
/// * [`PackedStates::new`] — dense lanes in relabeled slot order.
/// * [`PackedStates::block_aligned`] — each partition block starts at a
///   word boundary (padding lanes stay zero), so block-exclusive tasks
///   touch exclusive words and block publication can copy whole words
///   ([`PackedStates::copy_block_from`]).
pub struct PackedStates {
    bits: u32,
    mask: u64,
    words: Box<[AtomicU64]>,
    /// Logical agent id → lane index. Shared (`Arc`) between buffers of
    /// a double-buffered model so `copy_block_from` can assert the two
    /// sides agree on placement.
    lane_of: Arc<Vec<u32>>,
    /// Per-block word ranges (block-aligned layout only).
    block_words: Option<Arc<Vec<(u32, u32)>>>,
    len: usize,
}

impl PackedStates {
    fn check_bits(bits: u32) {
        assert!(
            matches!(bits, 1 | 2 | 4 | 8),
            "lane width must be 1, 2, 4 or 8 bits, got {bits}"
        );
    }

    fn alloc_words(n: usize) -> Box<[AtomicU64]> {
        (0..n).map(|_| AtomicU64::new(0)).collect()
    }

    /// Dense packing: lane index = relabeled slot.
    pub fn new(bits: u32, order: &Relabeling) -> Self {
        Self::check_bits(bits);
        let lpw = (64 / bits) as usize;
        let lanes = order.len();
        Self {
            bits,
            mask: (1u64 << bits) - 1,
            words: Self::alloc_words(lanes.div_ceil(lpw)),
            lane_of: Arc::new(order.slots().to_vec()),
            block_words: None,
            len: lanes,
        }
    }

    /// Word-aligned block packing: blocks are laid out in partition
    /// order (members ascending — the [`Relabeling::from_partition`]
    /// order), each starting at a fresh word. Distinct blocks never
    /// share a word, so block-exclusive writers need no CAS retries and
    /// [`PackedStates::copy_block_from`] can move whole words.
    pub fn block_aligned(bits: u32, part: &Partition) -> Self {
        Self::check_bits(bits);
        let lpw = (64 / bits) as usize;
        let mut lane_of = vec![0u32; part.n()];
        let mut ranges = Vec::with_capacity(part.blocks());
        let mut next_lane = 0usize;
        for b in 0..part.blocks() {
            debug_assert_eq!(next_lane % lpw, 0, "blocks start word-aligned");
            let w0 = (next_lane / lpw) as u32;
            for &a in part.members(b) {
                lane_of[a as usize] = next_lane as u32;
                next_lane += 1;
            }
            let w1 = next_lane.div_ceil(lpw) as u32;
            ranges.push((w0, w1));
            next_lane = w1 as usize * lpw; // pad the tail to a whole word
        }
        Self {
            bits,
            mask: (1u64 << bits) - 1,
            words: Self::alloc_words(next_lane / lpw),
            lane_of: Arc::new(lane_of),
            block_words: Some(Arc::new(ranges)),
            len: part.n(),
        }
    }

    /// A zeroed twin sharing this buffer's lane map and block ranges —
    /// the second half of a double buffer.
    pub fn like(&self) -> Self {
        Self {
            bits: self.bits,
            mask: self.mask,
            words: Self::alloc_words(self.words.len()),
            lane_of: Arc::clone(&self.lane_of),
            block_words: self.block_words.as_ref().map(Arc::clone),
            len: self.len,
        }
    }

    /// A word-for-word copy sharing the lane map (quiescent use).
    pub fn duplicate(&self) -> Self {
        let out = self.like();
        for (d, s) in out.words.iter().zip(self.words.iter()) {
            d.store(s.load(Ordering::Relaxed), Ordering::Relaxed);
        }
        out
    }

    /// Number of agents.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether the store holds zero agents.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Lane width in bits.
    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// Bytes of state one lane access moves — `bits / 8` (the
    /// structural counterpart of the legacy byte-per-agent).
    pub fn bytes_per_lane(&self) -> f64 {
        self.bits as f64 / 8.0
    }

    /// Whether blocks are word-aligned (built by
    /// [`PackedStates::block_aligned`]).
    pub fn is_block_aligned(&self) -> bool {
        self.block_words.is_some()
    }

    /// Heap footprint of the word array + lane map, in bytes (bench
    /// reporting).
    pub fn heap_bytes(&self) -> usize {
        self.words.len() * 8 + self.lane_of.len() * 4
    }

    /// State of logical agent `i`.
    #[inline]
    pub fn get(&self, i: usize) -> u8 {
        let lane = self.lane_of[i] as usize;
        let lpw = (64 / self.bits) as usize;
        let w = self.words[lane / lpw].load(Ordering::Relaxed);
        ((w >> ((lane % lpw) as u32 * self.bits)) & self.mask) as u8
    }

    /// Set the state of logical agent `i`.
    ///
    /// Lossless under concurrent writers of *other* lanes in the same
    /// word (CAS loop); the record discipline guarantees no concurrent
    /// writer of the *same* lane, so the stored value is deterministic.
    #[inline]
    pub fn set(&self, i: usize, v: u8) {
        debug_assert!(u64::from(v) <= self.mask, "value {v} exceeds {} bits", self.bits);
        let lane = self.lane_of[i] as usize;
        let lpw = (64 / self.bits) as usize;
        let word = &self.words[lane / lpw];
        let shift = (lane % lpw) as u32 * self.bits;
        let lane_mask = self.mask << shift;
        let lane_val = u64::from(v) << shift;
        let mut cur = word.load(Ordering::Relaxed);
        loop {
            let next = (cur & !lane_mask) | lane_val;
            match word.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Publish block `b` from `src` into `self` as whole-word copies.
    /// Requires the block-aligned layout with a shared lane map; a
    /// block-exclusive task owns the block's words outright (no other
    /// block shares them), so plain word stores suffice.
    #[inline]
    pub fn copy_block_from(&self, src: &PackedStates, b: usize) {
        debug_assert!(
            Arc::ptr_eq(&self.lane_of, &src.lane_of),
            "double-buffer sides must share one placement"
        );
        let ranges = self
            .block_words
            .as_ref()
            .expect("copy_block_from needs the block-aligned layout");
        let (w0, w1) = ranges[b];
        for w in w0 as usize..w1 as usize {
            self.words[w].store(src.words[w].load(Ordering::Relaxed), Ordering::Relaxed);
        }
    }

    /// All states in logical id order (quiescent use).
    pub fn snapshot_bytes(&self) -> Vec<u8> {
        (0..self.len).map(|i| self.get(i)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::graph::{contiguous_partition, grid_partition, ring_lattice};
    use crate::sim::graph::bfs_partition;

    #[test]
    fn layout_labels_roundtrip() {
        for l in Layout::ALL {
            assert_eq!(l.label().parse::<Layout>().unwrap(), l);
        }
        assert_eq!("aos".parse::<Layout>().unwrap(), Layout::Legacy);
        assert_eq!("soa".parse::<Layout>().unwrap(), Layout::Packed);
        assert!("nope".parse::<Layout>().is_err());
        assert_eq!(Layout::default(), Layout::Packed);
    }

    #[test]
    fn bits_for_covers_the_state_spaces() {
        assert_eq!(bits_for(2), 1); // Ising spins
        assert_eq!(bits_for(3), 2); // SIR health, 3-opinion voter
        assert_eq!(bits_for(4), 2);
        assert_eq!(bits_for(5), 4);
        assert_eq!(bits_for(16), 4);
        assert_eq!(bits_for(17), 8);
        assert_eq!(bits_for(256), 8);
    }

    #[test]
    fn relabeling_from_contiguous_partition_is_identity() {
        let p = contiguous_partition(257, 16);
        let r = Relabeling::from_partition(&p);
        assert!(r.is_permutation());
        assert!(r.is_identity(), "contiguous blocks keep id order");
    }

    #[test]
    fn relabeling_is_a_pure_permutation_and_inverts() {
        let g = ring_lattice(97, 6);
        let r = Relabeling::from_partition(&bfs_partition(&g, 5));
        assert!(r.is_permutation());
        let inv = r.inverse();
        assert!(inv.is_permutation());
        for a in 0..97 {
            assert_eq!(inv.slot_of(r.slot_of(a) as usize) as usize, a);
            assert_eq!(r.agent_of(r.slot_of(a) as usize) as usize, a);
        }
    }

    #[test]
    fn relabeling_groups_blocks_contiguously() {
        let p = grid_partition(9, 9, 4);
        let r = Relabeling::from_partition(&p);
        assert!(r.is_permutation());
        let mut next = 0u32;
        for b in 0..p.blocks() {
            for &a in p.members(b) {
                assert_eq!(r.slot_of(a as usize), next, "block {b} must be contiguous");
                next += 1;
            }
        }
    }

    #[test]
    fn packed_roundtrip_every_width() {
        for bits in [1u32, 2, 4, 8] {
            let n = 131; // crosses word boundaries at every width
            let ps = PackedStates::new(bits, &Relabeling::identity(n));
            let m = ((1u64 << bits) - 1) as u8;
            for i in 0..n {
                ps.set(i, (i as u8).wrapping_mul(7) & m);
            }
            for i in 0..n {
                assert_eq!(ps.get(i), (i as u8).wrapping_mul(7) & m, "bits={bits} i={i}");
            }
            assert_eq!(ps.snapshot_bytes().len(), n);
        }
    }

    #[test]
    fn packed_respects_a_permuted_lane_map() {
        let g = ring_lattice(40, 4);
        let r = Relabeling::from_partition(&bfs_partition(&g, 4));
        let ps = PackedStates::new(2, &r);
        for i in 0..40 {
            ps.set(i, (i % 4) as u8);
        }
        for i in 0..40 {
            assert_eq!(ps.get(i), (i % 4) as u8, "logical addressing survives relabeling");
        }
    }

    #[test]
    fn block_aligned_blocks_never_share_words() {
        let p = contiguous_partition(257, 16); // ragged tail: 16×16 + 1
        let ps = PackedStates::block_aligned(2, &p);
        assert!(ps.is_block_aligned());
        let lpw = 32; // 64 / 2 bits
        for b in 0..p.blocks() {
            let first = ps.lane_of[p.members(b)[0] as usize] as usize;
            assert_eq!(first % lpw, 0, "block {b} must start word-aligned");
        }
        // The ragged tail block still packs and round-trips.
        for &a in p.members(p.blocks() - 1) {
            ps.set(a as usize, 2);
            assert_eq!(ps.get(a as usize), 2);
        }
    }

    #[test]
    fn block_copy_publishes_exactly_one_block() {
        let p = contiguous_partition(100, 16);
        let cur = PackedStates::block_aligned(2, &p);
        let new = cur.like();
        for i in 0..100 {
            new.set(i, 1);
        }
        cur.copy_block_from(&new, 2);
        for i in 0..100 {
            let expect = u8::from(p.members(2).contains(&(i as u32)));
            assert_eq!(cur.get(i), expect, "i={i}");
        }
    }

    #[test]
    fn duplicate_is_word_identical() {
        let ps = PackedStates::new(4, &Relabeling::identity(77));
        for i in 0..77 {
            ps.set(i, (i % 13) as u8);
        }
        let d = ps.duplicate();
        assert_eq!(d.snapshot_bytes(), ps.snapshot_bytes());
    }

    #[test]
    fn concurrent_disjoint_lane_writes_are_lossless() {
        // 64 one-bit lanes share a single word; 4 threads write disjoint
        // lane ranges concurrently. The CAS loop must lose nothing.
        let ps = PackedStates::new(1, &Relabeling::identity(64));
        std::thread::scope(|s| {
            for t in 0..4usize {
                let ps = &ps;
                s.spawn(move || {
                    for i in (t * 16)..(t * 16 + 16) {
                        ps.set(i, 1);
                    }
                });
            }
        });
        assert!((0..64).all(|i| ps.get(i) == 1), "a lane write was lost");
    }
}
