//! Shared simulation state — the one `unsafe` in the protocol's hot path.
//!
//! # Safety argument (DESIGN.md §6)
//!
//! Agent state is accessed concurrently by workers executing tasks. The
//! protocol guarantees that **tasks executing concurrently are pairwise
//! independent**: a worker only executes a task after verifying, via its
//! record (accumulated over every incomplete task preceding it in the chain
//! during the current cycle), that the task's conservative read/write
//! footprint is disjoint from those of all incomplete predecessors. Records
//! are conservative over-approximations, so disjointness at the record
//! level implies disjointness of the actual memory accesses.
//!
//! Happens-before for *sequentially ordered* (dependent) tasks is
//! established by the chain's mutexes: an executing worker publishes its
//! writes when it releases the erase-side link locks, and any worker that
//! subsequently observes the task as erased acquired those same locks.
//!
//! Therefore: conflicting accesses are totally ordered via lock
//! synchronization, non-conflicting accesses are disjoint — no data race.
//! All uses of [`SharedSim::get_mut`] must go through the protocol (or a
//! single-threaded engine), which is why the method is `unsafe` and the
//! type is not exported beyond the crate's engine/model modules.

use std::cell::UnsafeCell;

/// Interior-mutable, `Sync` wrapper around simulation state `T`.
///
/// See the module docs for the safety argument. The protocol (not this
/// type) enforces mutual exclusion between conflicting accesses.
#[derive(Debug)]
pub struct SharedSim<T> {
    cell: UnsafeCell<T>,
}

// SAFETY: see module-level safety argument. `SharedSim` hands out aliasing
// mutable references only through `unsafe fn get_mut`, whose contract makes
// the caller (the protocol engines) responsible for conflict freedom.
unsafe impl<T: Send> Sync for SharedSim<T> {}
unsafe impl<T: Send> Send for SharedSim<T> {}

impl<T> SharedSim<T> {
    /// Wrap a state value.
    pub fn new(value: T) -> Self {
        Self {
            cell: UnsafeCell::new(value),
        }
    }

    /// Shared reference to the state.
    ///
    /// # Safety
    /// The caller must guarantee no concurrent conflicting mutable access
    /// to the parts of `T` it will read (protocol record discipline).
    #[allow(clippy::mut_from_ref)]
    #[inline]
    pub unsafe fn get(&self) -> &T {
        &*self.cell.get()
    }

    /// Mutable reference to the state.
    ///
    /// # Safety
    /// The caller must guarantee exclusive access to the parts of `T` it
    /// will mutate and absence of concurrent readers of those parts
    /// (protocol record discipline).
    #[allow(clippy::mut_from_ref)]
    #[inline]
    pub unsafe fn get_mut(&self) -> &mut T {
        &mut *self.cell.get()
    }

    /// Consume the wrapper, returning the state (requires unique ownership,
    /// hence safe).
    pub fn into_inner(self) -> T {
        self.cell.into_inner()
    }

    /// Exclusive access through a unique borrow (safe: `&mut self`).
    pub fn get_mut_exclusive(&mut self) -> &mut T {
        self.cell.get_mut()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_threaded_roundtrip() {
        let s = SharedSim::new(vec![1u32, 2, 3]);
        unsafe {
            s.get_mut()[0] = 7;
            assert_eq!(s.get()[0], 7);
        }
        assert_eq!(s.into_inner(), vec![7, 2, 3]);
    }

    #[test]
    fn disjoint_parallel_writes_are_race_free() {
        // Two threads write disjoint halves — the pattern the protocol
        // guarantees. Run under `cargo miri test` for UB checking if
        // available; under plain test this asserts the values.
        let s = std::sync::Arc::new(SharedSim::new(vec![0u64; 1024]));
        let a = s.clone();
        let b = s.clone();
        let ta = std::thread::spawn(move || unsafe {
            for i in 0..512 {
                a.get_mut()[i] = 1;
            }
        });
        let tb = std::thread::spawn(move || unsafe {
            for i in 512..1024 {
                b.get_mut()[i] = 2;
            }
        });
        ta.join().unwrap();
        tb.join().unwrap();
        let v = unsafe { s.get() };
        assert!(v[..512].iter().all(|&x| x == 1));
        assert!(v[512..].iter().all(|&x| x == 2));
    }
}
