//! Always-on, semantically inert metrics core (DESIGN.md §11).
//!
//! Layering:
//!
//! * [`MetricsRegistry`] — a build-time list of named instruments
//!   (counters and histograms). Engines register what they publish,
//!   then [`MetricsRegistry::start`] freezes the set into a
//!   [`TelemetryCore`] for one run.
//! * [`TelemetryCore`] — per-worker counter rows (lossless; one relaxed
//!   `fetch_add` per publish, touched off the per-task hot path) plus
//!   per-worker SPSC sample [`Ring`]s (lossy-but-counted; one push per
//!   sample) drained by a background aggregator thread into mergeable
//!   [`LogHistogram`]s keyed per worker.
//! * [`TelemetrySnapshot`] — the immutable post-run view.
//!   `ProtocolStats`/`SchedStats` are reconstructed *from* it (see
//!   `protocol::stats`), and `--json` renders it as one coherent
//!   `telemetry` object.
//!
//! **Inertness contract:** nothing here feeds back into execution.
//! Counters are write-only until [`TelemetryCore::finish`]; a full ring
//! drops samples (counted) instead of blocking; the aggregator reads
//! only telemetry state. Engines therefore stay trace-identical to
//! sequential with telemetry on, off, or under ring saturation — the
//! conformance matrix asserts exactly that
//! (`rust/tests/conformance.rs`).
//!
//! The counter layer is always on (it *is* the stats plumbing now);
//! [`TelemetryMode`] — default from `ADAPAR_TELEMETRY` — controls only
//! the ring/aggregator layer.

mod ring;

pub use ring::{Ring, WideRing};

use crate::util::histogram::LogHistogram;
use crate::util::json::Json;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Ring/aggregator layer mode for one run. The lossless counter layer
/// runs in every mode.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TelemetryMode {
    /// Rings at production capacity, aggregator thread on (default).
    #[default]
    On,
    /// No rings, no aggregator thread; histograms come back empty.
    Off,
    /// Tiny rings that overflow almost immediately — a test mode
    /// proving saturation stays inert (drops counted, trace unchanged).
    Saturated,
}

impl TelemetryMode {
    /// Mode from `ADAPAR_TELEMETRY` (`off`/`0`/`false` → [`Off`],
    /// `saturate`/`saturated` → [`Saturated`], anything else / unset →
    /// [`On`]).
    ///
    /// [`Off`]: TelemetryMode::Off
    /// [`Saturated`]: TelemetryMode::Saturated
    pub fn env_default() -> Self {
        match std::env::var("ADAPAR_TELEMETRY").as_deref() {
            Ok("off") | Ok("0") | Ok("false") => TelemetryMode::Off,
            Ok("saturate") | Ok("saturated") => TelemetryMode::Saturated,
            _ => TelemetryMode::On,
        }
    }

    /// Ring capacity implied by the mode (0 = no rings).
    pub fn ring_capacity(self) -> usize {
        match self {
            TelemetryMode::On => 4096,
            TelemetryMode::Off => 0,
            TelemetryMode::Saturated => 4,
        }
    }

    /// Short label for reports.
    pub fn label(self) -> &'static str {
        match self {
            TelemetryMode::On => "on",
            TelemetryMode::Off => "off",
            TelemetryMode::Saturated => "saturated",
        }
    }
}

impl std::str::FromStr for TelemetryMode {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "on" | "1" | "true" => Ok(TelemetryMode::On),
            "off" | "0" | "false" => Ok(TelemetryMode::Off),
            "saturate" | "saturated" => Ok(TelemetryMode::Saturated),
            _ => Err(format!("unknown telemetry mode `{s}` (on|off|saturate)")),
        }
    }
}

/// Handle to a registered lossless counter.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CounterId(u32);

/// Handle to a registered histogram (ring-sampled, lossy-but-counted).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HistId(u32);

/// Build-time registry of named instruments. Names are free-form but
/// the convention is dotted prefixes (`worker.*`, `chain.*`,
/// `sched.*`); registration is idempotent per name.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    counters: Vec<String>,
    hists: Vec<String>,
}

impl MetricsRegistry {
    /// Empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Register (or look up) a lossless counter.
    pub fn counter(&mut self, name: &str) -> CounterId {
        if let Some(i) = self.counters.iter().position(|n| n == name) {
            return CounterId(i as u32);
        }
        self.counters.push(name.to_string());
        CounterId((self.counters.len() - 1) as u32)
    }

    /// Register (or look up) a histogram.
    pub fn histogram(&mut self, name: &str) -> HistId {
        if let Some(i) = self.hists.iter().position(|n| n == name) {
            return HistId(i as u32);
        }
        self.hists.push(name.to_string());
        HistId((self.hists.len() - 1) as u32)
    }

    /// Freeze the instrument set and allocate run state for `workers`
    /// publishers (plus one engine-global row). Spawns the background
    /// aggregator thread iff `mode` enables rings and at least one
    /// histogram is registered.
    pub fn start(self, workers: usize, mode: TelemetryMode) -> TelemetryCore {
        let n_c = self.counters.len();
        let counters: Vec<Box<[AtomicU64]>> = (0..=workers)
            .map(|_| (0..n_c).map(|_| AtomicU64::new(0)).collect())
            .collect();
        let (rings, agg) = if mode.ring_capacity() > 0 && !self.hists.is_empty() {
            let rings: Vec<Arc<Ring>> = (0..workers)
                .map(|_| Arc::new(Ring::new(mode.ring_capacity())))
                .collect();
            let stop = Arc::new(AtomicBool::new(false));
            let t_rings = rings.clone();
            let t_stop = Arc::clone(&stop);
            let n_h = self.hists.len();
            let thread = std::thread::Builder::new()
                .name("adapar-telemetry".to_string())
                .spawn(move || aggregate_loop(&t_rings, &t_stop, n_h))
                .expect("spawn telemetry aggregator");
            (rings, Some(AggHandle { stop, thread }))
        } else {
            (Vec::new(), None)
        };
        TelemetryCore {
            mode,
            workers,
            counter_names: self.counters,
            hist_names: self.hists,
            counters,
            rings,
            agg,
        }
    }
}

/// The background aggregator: periodically drain every worker's ring
/// into per-(histogram, worker) [`LogHistogram`]s; on the stop signal,
/// drain once more and return. The stop flag is checked *before* the
/// drain, so everything pushed before [`TelemetryCore::finish`] (the
/// shutdown fence — workers are already joined) lands in the final
/// histograms.
fn aggregate_loop(
    rings: &[Arc<Ring>],
    stop: &AtomicBool,
    n_hists: usize,
) -> Vec<Vec<LogHistogram>> {
    let mut hists = vec![vec![LogHistogram::new(); rings.len()]; n_hists];
    loop {
        let stopping = stop.load(Ordering::Acquire);
        for (w, ring) in rings.iter().enumerate() {
            ring.drain(|id, v| {
                if let Some(h) = hists.get_mut(id as usize) {
                    h[w].record(v);
                }
            });
        }
        if stopping {
            return hists;
        }
        std::thread::park_timeout(Duration::from_micros(200));
    }
}

struct AggHandle {
    stop: Arc<AtomicBool>,
    thread: std::thread::JoinHandle<Vec<Vec<LogHistogram>>>,
}

/// Frozen instrument set plus live run state: per-worker counter rows,
/// per-worker sample rings, and the aggregator thread. Shared by
/// reference with scoped worker threads (all interior state is atomic).
pub struct TelemetryCore {
    mode: TelemetryMode,
    workers: usize,
    counter_names: Vec<String>,
    hist_names: Vec<String>,
    /// `workers + 1` rows of `n_counters` cells; the extra last row is
    /// the engine-global publisher ([`TelemetryCore::record`]).
    counters: Vec<Box<[AtomicU64]>>,
    rings: Vec<Arc<Ring>>,
    agg: Option<AggHandle>,
}

impl TelemetryCore {
    /// The run's ring/aggregator mode.
    pub fn mode(&self) -> TelemetryMode {
        self.mode
    }

    /// Publisher handle for worker `w` (its counter row + its ring).
    pub fn handle(&self, worker: usize) -> WorkerTelemetry<'_> {
        debug_assert!(worker < self.workers);
        WorkerTelemetry { core: self, worker }
    }

    /// Engine-global counter publish (partition metadata, end-of-run
    /// chain stats — anything not attributable to one worker).
    pub fn record(&self, id: CounterId, delta: u64) {
        if delta != 0 {
            self.counters[self.workers][id.0 as usize].fetch_add(delta, Ordering::Relaxed);
        }
    }

    /// Stop the aggregator (final drain included), read every counter
    /// row, and freeze the run's telemetry. Call only after all worker
    /// threads have been joined — that join is the fence making every
    /// publish visible here.
    pub fn finish(self) -> TelemetrySnapshot {
        let TelemetryCore {
            mode,
            workers,
            counter_names,
            hist_names,
            counters,
            rings,
            agg,
        } = self;
        let by_hist = match agg {
            Some(a) => {
                a.stop.store(true, Ordering::Release);
                a.thread.thread().unpark();
                a.thread.join().expect("telemetry aggregator panicked")
            }
            None => Vec::new(),
        };
        let snapshot_counters = counter_names
            .into_iter()
            .enumerate()
            .map(|(i, name)| {
                let rows: Vec<u64> = counters
                    .iter()
                    .map(|row| row[i].load(Ordering::Relaxed))
                    .collect();
                (name, rows)
            })
            .collect();
        let hists = hist_names
            .into_iter()
            .enumerate()
            .map(|(i, name)| (name, by_hist.get(i).cloned().unwrap_or_default()))
            .collect();
        TelemetrySnapshot {
            mode,
            workers,
            counters: snapshot_counters,
            hists,
            ring_capacity: rings.first().map_or(0, |r| r.capacity()),
            dropped: rings.iter().map(|r| r.dropped()).collect(),
        }
    }
}

/// A worker's publishing handle: both operations are wait-free and
/// never feed back into execution.
#[derive(Clone, Copy)]
pub struct WorkerTelemetry<'a> {
    core: &'a TelemetryCore,
    worker: usize,
}

impl WorkerTelemetry<'_> {
    /// Lossless counter add (one relaxed `fetch_add` on this worker's
    /// private row).
    #[inline]
    pub fn add(&self, id: CounterId, delta: u64) {
        if delta != 0 {
            self.core.counters[self.worker][id.0 as usize].fetch_add(delta, Ordering::Relaxed);
        }
    }

    /// Push one histogram sample into this worker's ring. Dropped
    /// (and counted) if the ring is full or the mode disables rings.
    #[inline]
    pub fn sample(&self, id: HistId, value: u64) {
        if let Some(ring) = self.core.rings.get(self.worker) {
            ring.push(id.0, value);
        }
    }
}

/// Immutable end-of-run telemetry: every counter (per worker row +
/// engine-global row), every histogram (per worker, mergeable), and the
/// ring drop accounting.
#[derive(Clone, Debug, Default)]
pub struct TelemetrySnapshot {
    mode: TelemetryMode,
    workers: usize,
    /// `(name, rows)` — `rows.len() == workers + 1`, last row global.
    counters: Vec<(String, Vec<u64>)>,
    /// `(name, per-worker histograms)` (empty vec when rings were off).
    hists: Vec<(String, Vec<LogHistogram>)>,
    ring_capacity: usize,
    dropped: Vec<u64>,
}

impl TelemetrySnapshot {
    /// Publisher (worker) count the run used.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The run's ring/aggregator mode.
    pub fn mode(&self) -> TelemetryMode {
        self.mode
    }

    /// Counter total across all rows (0 for unknown names).
    pub fn counter(&self, name: &str) -> u64 {
        self.rows(name)
            .map(|rows| rows.iter().fold(0u64, |a, &v| a.saturating_add(v)))
            .unwrap_or(0)
    }

    /// Counter value on worker `w`'s row (0 for unknown names).
    pub fn counter_worker(&self, name: &str, w: usize) -> u64 {
        self.rows(name).and_then(|rows| rows.get(w).copied()).unwrap_or(0)
    }

    /// All counters whose name starts with `prefix`, as
    /// `(name, total)` in registration order.
    pub fn counters_prefixed(&self, prefix: &str) -> Vec<(&str, u64)> {
        self.counters
            .iter()
            .filter(|(n, _)| n.starts_with(prefix))
            .map(|(n, rows)| {
                (
                    n.as_str(),
                    rows.iter().fold(0u64, |a, &v| a.saturating_add(v)),
                )
            })
            .collect()
    }

    /// Merged (all-worker) histogram, `None` for unknown names and
    /// `Some(empty)` when rings were off.
    pub fn histogram(&self, name: &str) -> Option<LogHistogram> {
        self.hists.iter().find(|(n, _)| n == name).map(|(_, per_w)| {
            let mut merged = LogHistogram::new();
            for h in per_w {
                merged.merge(h);
            }
            merged
        })
    }

    /// Worker `w`'s histogram for `name`, if rings were on.
    pub fn histogram_worker(&self, name: &str, w: usize) -> Option<&LogHistogram> {
        self.hists
            .iter()
            .find(|(n, _)| n == name)
            .and_then(|(_, per_w)| per_w.get(w))
    }

    /// Samples dropped across all rings.
    pub fn dropped_total(&self) -> u64 {
        self.dropped.iter().sum()
    }

    fn rows(&self, name: &str) -> Option<&[u64]> {
        self.counters
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, rows)| rows.as_slice())
    }

    /// Render the whole snapshot as one JSON object (the `--json`
    /// report's `telemetry` field). Deterministic field order
    /// (registration order).
    pub fn to_json(&self) -> Json {
        let counters = self
            .counters
            .iter()
            .map(|(name, rows)| {
                let total = rows.iter().fold(0u64, |a, &v| a.saturating_add(v));
                let worker_rows = &rows[..self.workers.min(rows.len())];
                let mut obj = vec![("total".to_string(), Json::from(total))];
                if worker_rows.iter().any(|&v| v != 0) {
                    obj.push((
                        "per_worker".to_string(),
                        Json::Arr(worker_rows.iter().map(|&v| Json::from(v)).collect()),
                    ));
                }
                (name.clone(), Json::Obj(obj))
            })
            .collect();
        let hists = self
            .hists
            .iter()
            .map(|(name, per_w)| {
                let mut merged = LogHistogram::new();
                for h in per_w {
                    merged.merge(h);
                }
                let buckets = merged
                    .buckets()
                    .into_iter()
                    .map(|(edge, c)| Json::Arr(vec![Json::from(edge), Json::from(c)]))
                    .collect();
                (
                    name.clone(),
                    Json::Obj(vec![
                        ("count".to_string(), Json::from(merged.count())),
                        ("mean".to_string(), Json::from(merged.mean())),
                        ("p50".to_string(), Json::from(merged.p50())),
                        ("p90".to_string(), Json::from(merged.p90())),
                        ("p99".to_string(), Json::from(merged.p99())),
                        ("buckets".to_string(), Json::Arr(buckets)),
                    ]),
                )
            })
            .collect();
        Json::Obj(vec![
            ("mode".to_string(), Json::from(self.mode.label())),
            ("workers".to_string(), Json::from(self.workers)),
            ("counters".to_string(), Json::Obj(counters)),
            ("histograms".to_string(), Json::Obj(hists)),
            (
                "rings".to_string(),
                Json::Obj(vec![
                    ("capacity".to_string(), Json::from(self.ring_capacity)),
                    (
                        "dropped".to_string(),
                        Json::Arr(self.dropped.iter().map(|&d| Json::from(d)).collect()),
                    ),
                    ("dropped_total".to_string(), Json::from(self.dropped_total())),
                ]),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registration_is_idempotent_per_name() {
        let mut reg = MetricsRegistry::new();
        let a = reg.counter("x.a");
        let b = reg.counter("x.b");
        assert_ne!(a, b);
        assert_eq!(reg.counter("x.a"), a);
        let h = reg.histogram("x.h");
        assert_eq!(reg.histogram("x.h"), h);
    }

    #[test]
    fn counters_accumulate_per_worker_and_globally() {
        let mut reg = MetricsRegistry::new();
        let c = reg.counter("t.count");
        let core = reg.start(2, TelemetryMode::Off);
        core.handle(0).add(c, 3);
        core.handle(1).add(c, 4);
        core.record(c, 10);
        let snap = core.finish();
        assert_eq!(snap.counter("t.count"), 17);
        assert_eq!(snap.counter_worker("t.count", 0), 3);
        assert_eq!(snap.counter_worker("t.count", 1), 4);
        assert_eq!(snap.counter("unknown"), 0);
        assert_eq!(
            snap.counters_prefixed("t."),
            vec![("t.count", 17)]
        );
    }

    #[test]
    fn aggregator_final_flush_loses_no_pre_fence_samples() {
        let mut reg = MetricsRegistry::new();
        let h = reg.histogram("t.lat");
        let core = reg.start(2, TelemetryMode::On);
        // Publish from real threads, then join — the engine's shutdown
        // fence. Everything pushed before finish() must survive even if
        // the aggregator never woke up mid-run.
        std::thread::scope(|s| {
            for w in 0..2 {
                let t = core.handle(w);
                s.spawn(move || {
                    for v in 0..1000u64 {
                        t.sample(h, v);
                    }
                });
            }
        });
        let snap = core.finish();
        let merged = snap.histogram("t.lat").unwrap();
        assert_eq!(
            merged.count() + snap.dropped_total(),
            2000,
            "every pre-fence sample is either aggregated or counted as dropped"
        );
        assert_eq!(snap.dropped_total(), 0, "4096-slot rings cannot overflow here");
        assert_eq!(snap.histogram_worker("t.lat", 0).unwrap().count(), 1000);
    }

    #[test]
    fn saturated_mode_drops_and_counts_without_blocking() {
        let mut reg = MetricsRegistry::new();
        let h = reg.histogram("t.lat");
        let core = reg.start(1, TelemetryMode::Saturated);
        let t = core.handle(0);
        for v in 0..10_000u64 {
            t.sample(h, v); // must never block
        }
        let snap = core.finish();
        let merged = snap.histogram("t.lat").unwrap();
        assert_eq!(merged.count() + snap.dropped_total(), 10_000);
        assert!(snap.dropped_total() > 0, "a 4-slot ring must overflow");
    }

    #[test]
    fn off_mode_spawns_nothing_and_reports_empty_histograms() {
        let mut reg = MetricsRegistry::new();
        let c = reg.counter("t.count");
        let h = reg.histogram("t.lat");
        let core = reg.start(1, TelemetryMode::Off);
        core.handle(0).add(c, 1);
        core.handle(0).sample(h, 99); // silently inert
        let snap = core.finish();
        assert_eq!(snap.counter("t.count"), 1, "counters are always on");
        assert!(snap.histogram("t.lat").unwrap().is_empty());
        assert_eq!(snap.dropped_total(), 0);
    }

    #[test]
    fn snapshot_json_is_one_coherent_object() {
        let mut reg = MetricsRegistry::new();
        let c = reg.counter("chain.tail_locks");
        reg.histogram("chain.batch_fill");
        let core = reg.start(1, TelemetryMode::Off);
        core.record(c, 7);
        let rendered = core.finish().to_json().render();
        assert!(rendered.contains("\"counters\""));
        assert!(rendered.contains("\"chain.tail_locks\":{\"total\":7}"));
        assert!(rendered.contains("\"histograms\""));
        assert!(rendered.contains("\"rings\""));
        assert!(rendered.contains("\"mode\":\"off\""));
    }

    #[test]
    fn mode_parses_from_str_and_env_shapes() {
        assert_eq!("on".parse::<TelemetryMode>().unwrap(), TelemetryMode::On);
        assert_eq!("off".parse::<TelemetryMode>().unwrap(), TelemetryMode::Off);
        assert_eq!(
            "saturate".parse::<TelemetryMode>().unwrap(),
            TelemetryMode::Saturated
        );
        assert!("bogus".parse::<TelemetryMode>().is_err());
        assert_eq!(TelemetryMode::Off.ring_capacity(), 0);
        assert!(TelemetryMode::On.ring_capacity() > TelemetryMode::Saturated.ring_capacity());
    }
}
