//! Fixed-capacity SPSC sample rings (the telemetry + trace hot path).
//!
//! One ring per worker: the worker is the only producer, the background
//! aggregator the only consumer. A push is a handful of relaxed stores
//! plus one release store of the tail — no locks, no allocation, no CAS
//! loop. A full ring **drops** the event (counted in
//! [`WideRing::dropped`]) rather than blocking or overwriting:
//! telemetry loss is acceptable, telemetry back-pressure on the
//! protocol is not (the inertness contract, DESIGN.md §11).
//!
//! [`WideRing<W>`] generalizes the PR 7 sample ring to `W` payload
//! words per slot so a multi-word record (e.g. a trace span: task,
//! block, start, duration — see `crate::trace`) is pushed and dropped
//! *atomically as one event*; a drop can never tear a record in half.
//! The original `(instrument, value)` sample ring is the width-1 case,
//! kept as the [`Ring`] alias with its historic `push`/`drain` API.
//!
//! Every slot is an atomic, so even a (buggy) second producer cannot
//! cause undefined behaviour — only garbled samples.

use std::sync::atomic::{AtomicU32, AtomicU64, AtomicUsize, Ordering};

/// A single-producer single-consumer ring of `(meta, [u64; W])` events
/// with drop-counting overflow behaviour.
pub struct WideRing<const W: usize> {
    /// Index mask (capacity is a power of two).
    mask: usize,
    /// Meta word (instrument id / event tag) per slot.
    meta: Box<[AtomicU32]>,
    /// Payload words, `W` per slot (slot `i` owns `i*W .. i*W+W`).
    vals: Box<[AtomicU64]>,
    /// Consumer cursor (monotonic, wrapped by `mask` on access).
    head: AtomicUsize,
    /// Producer cursor.
    tail: AtomicUsize,
    /// Events rejected because the ring was full.
    dropped: AtomicU64,
}

impl<const W: usize> WideRing<W> {
    /// Ring with at least `capacity` slots (rounded up to a power of
    /// two, minimum 2).
    pub fn new(capacity: usize) -> Self {
        assert!(W >= 1, "a ring slot needs at least one payload word");
        let cap = capacity.max(2).next_power_of_two();
        Self {
            mask: cap - 1,
            meta: (0..cap).map(|_| AtomicU32::new(0)).collect(),
            vals: (0..cap * W).map(|_| AtomicU64::new(0)).collect(),
            head: AtomicUsize::new(0),
            tail: AtomicUsize::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    /// Slot count.
    pub fn capacity(&self) -> usize {
        self.mask + 1
    }

    /// Producer side: push one whole event. Returns `false` (and counts
    /// a drop) when the ring is full — the event is rejected in full,
    /// never torn. Never blocks.
    #[inline]
    pub fn push_event(&self, meta: u32, words: &[u64; W]) -> bool {
        let tail = self.tail.load(Ordering::Relaxed);
        // Acquire pairs with the consumer's release store of `head`: a
        // reused slot is only written after the consumer has finished
        // reading it.
        let head = self.head.load(Ordering::Acquire);
        if tail.wrapping_sub(head) > self.mask {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        let i = tail & self.mask;
        self.meta[i].store(meta, Ordering::Relaxed);
        for (k, &w) in words.iter().enumerate() {
            self.vals[i * W + k].store(w, Ordering::Relaxed);
        }
        // Release publishes the slot contents to the consumer's acquire
        // load of `tail`.
        self.tail.store(tail.wrapping_add(1), Ordering::Release);
        true
    }

    /// Consumer side: drain all currently published events into `f`,
    /// in push order. Returns how many were drained.
    pub fn drain_events(&self, mut f: impl FnMut(u32, [u64; W])) -> usize {
        let mut h = self.head.load(Ordering::Relaxed);
        let tail = self.tail.load(Ordering::Acquire);
        let n = tail.wrapping_sub(h);
        while h != tail {
            let i = h & self.mask;
            let mut words = [0u64; W];
            for (k, w) in words.iter_mut().enumerate() {
                *w = self.vals[i * W + k].load(Ordering::Relaxed);
            }
            f(self.meta[i].load(Ordering::Relaxed), words);
            h = h.wrapping_add(1);
        }
        // Release hands the consumed slots back to the producer.
        self.head.store(h, Ordering::Release);
        n
    }

    /// Events rejected so far because the ring was full.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Published-but-undrained event count (for tests).
    pub fn len(&self) -> usize {
        self.tail
            .load(Ordering::Acquire)
            .wrapping_sub(self.head.load(Ordering::Acquire))
    }

    /// Whether no events are waiting.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// The PR 7 telemetry sample ring: one `(instrument, value)` pair per
/// slot — [`WideRing`] at width 1.
pub type Ring = WideRing<1>;

impl Ring {
    /// Push one sample (width-1 convenience over
    /// [`WideRing::push_event`]). Returns `false` (and counts a drop)
    /// when the ring is full. Never blocks.
    #[inline]
    pub fn push(&self, instrument: u32, value: u64) -> bool {
        self.push_event(instrument, &[value])
    }

    /// Drain all currently published samples into `f`, in push order
    /// (width-1 convenience over [`WideRing::drain_events`]). Returns
    /// how many were drained.
    pub fn drain(&self, mut f: impl FnMut(u32, u64)) -> usize {
        self.drain_events(|id, [v]| f(id, v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_then_drain_preserves_order() {
        let r = Ring::new(8);
        for v in 0..5u64 {
            assert!(r.push(7, v));
        }
        assert_eq!(r.len(), 5);
        let mut got = Vec::new();
        assert_eq!(r.drain(|id, v| got.push((id, v))), 5);
        assert_eq!(got, vec![(7, 0), (7, 1), (7, 2), (7, 3), (7, 4)]);
        assert!(r.is_empty());
    }

    #[test]
    fn overflow_drops_and_counts_without_corruption() {
        let r = Ring::new(4);
        let mut accepted = 0;
        for v in 0..100u64 {
            if r.push(1, v) {
                accepted += 1;
            }
        }
        assert_eq!(accepted, 4, "capacity bounds accepted pushes");
        assert_eq!(r.dropped(), 96, "every rejected push is counted");
        // The accepted prefix survives intact — overwrite-free.
        let mut got = Vec::new();
        r.drain(|_, v| got.push(v));
        assert_eq!(got, vec![0, 1, 2, 3]);
        // Space freed by the drain is usable again.
        assert!(r.push(1, 42));
        let mut got = Vec::new();
        r.drain(|_, v| got.push(v));
        assert_eq!(got, vec![42]);
        assert_eq!(r.dropped(), 96);
    }

    #[test]
    fn capacity_rounds_up_to_power_of_two() {
        assert_eq!(Ring::new(0).capacity(), 2);
        assert_eq!(Ring::new(5).capacity(), 8);
        assert_eq!(Ring::new(8).capacity(), 8);
    }

    #[test]
    fn wide_events_round_trip_whole_records() {
        let r: WideRing<4> = WideRing::new(8);
        assert!(r.push_event(3, &[10, 20, 30, 40]));
        assert!(r.push_event(4, &[u64::MAX, 0, 7, 1]));
        let mut got = Vec::new();
        assert_eq!(r.drain_events(|m, ws| got.push((m, ws))), 2);
        assert_eq!(got, vec![(3, [10, 20, 30, 40]), (4, [u64::MAX, 0, 7, 1])]);
        assert!(r.is_empty());
    }

    #[test]
    fn wide_overflow_rejects_whole_events() {
        let r: WideRing<2> = WideRing::new(2);
        assert!(r.push_event(1, &[1, 2]));
        assert!(r.push_event(2, &[3, 4]));
        assert!(!r.push_event(3, &[5, 6]), "full ring rejects the event");
        assert_eq!(r.dropped(), 1);
        let mut got = Vec::new();
        r.drain_events(|m, ws| got.push((m, ws)));
        // No partial write of the rejected event anywhere.
        assert_eq!(got, vec![(1, [1, 2]), (2, [3, 4])]);
    }

    #[test]
    fn concurrent_producer_consumer_loses_nothing_when_paced() {
        use std::sync::Arc;
        let r = Arc::new(Ring::new(64));
        let p = Arc::clone(&r);
        let producer = std::thread::spawn(move || {
            let mut pushed = 0u64;
            for v in 0..10_000u64 {
                while !p.push(0, v) {
                    std::thread::yield_now();
                }
                pushed += 1;
            }
            pushed
        });
        let mut seen = Vec::new();
        while seen.len() < 10_000 {
            r.drain(|_, v| seen.push(v));
            std::hint::spin_loop();
        }
        assert_eq!(producer.join().unwrap(), 10_000);
        assert_eq!(seen, (0..10_000u64).collect::<Vec<_>>());
        assert_eq!(r.dropped(), 0);
    }
}
